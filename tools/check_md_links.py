#!/usr/bin/env python3
"""Markdown link check + DESIGN.md section-citation check.

Standalone CI face of rust/tests/docs_integrity.rs — nine rules:

1. Every relative link target in a *.md file must exist on disk.
2. Every markdown link with a `#fragment` that points at a markdown
   file (including self-links like `(#anchor)`) must name a real
   heading anchor of the target file, using GitHub's slugification
   (lowercase, punctuation stripped, spaces to dashes).
3. Every DESIGN.md section citation (a § token after the file name) in
   the rust/python sources *and* in the markdown docs must resolve to a
   §-numbered heading there.
4. docs/HANDBOOK.md (the operator's guide) must mention every CLI
   subcommand declared in rust/src/main.rs — including hidden ones —
   so the handbook cannot silently fall behind the binary.
5. DESIGN.md must carry the §9 directional-ledger chapter and the
   ledger implementation (rust/src/energy/comm.rs) must cite it: the
   billing rules documented there define the communication numbers of
   every result file.
6. DESIGN.md must carry the §11 serve/result-cache chapter and the
   cache implementation (rust/src/serve/cache.rs) must cite it: the
   canonical-hash and cache-hit bit-identity argument documented there
   is what every replayed cached byte leans on.
7. DESIGN.md must carry the §12 dynamic-networks chapter and the
   impairment layer (rust/src/coordinator/impairments.rs) must cite
   it: the Gilbert-Elliott semantics, the theory-suppression rationale
   and the byte-identity contract documented there pin the dynamic
   presets' numbers.
8. DESIGN.md must carry the §13 energy-loop chapter and the radio
   model (rust/src/energy/radio.rs) must cite it: the activator-pays
   billing rule, the per-leg erasure semantics, the Pareto pruning
   order and the frontier determinism contract documented there define
   every frontier result file.
9. DESIGN.md must carry the §14 lane-engine chapter and the lane
   engine (rust/src/coordinator/lanes.rs) must cite it: the SoA
   layout, the lane-interleaving bit-identity argument and the
   lanes x threads x shards composition documented there are what
   makes `--lanes` a pure throughput knob.

The scan covers the repo root *and* docs/ recursively (everything but
SKIP_DIRS). Exit status 0 = clean, 1 = at least one dangling reference
(all are listed). Run from anywhere: the repo root is located relative
to this file.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "target", "vendor", "results", "artifacts", "__pycache__"}

LINK_RE = re.compile(r"\]\(([^)\s]+)\)")
# '§' followed by alphanumerics/dashes.
SECTION_RE = re.compile("DESIGN\\.md §([A-Za-z0-9-]+)")
HEADING_RE = re.compile("^#+.*§([A-Za-z0-9-]+)", re.M)
MD_HEADING_RE = re.compile(r"^(#{1,6})\s+(.*)$", re.M)


def walk(suffixes):
    for path in sorted(ROOT.rglob("*")):
        if path.is_dir():
            continue
        if any(part in SKIP_DIRS for part in path.relative_to(ROOT).parts):
            continue
        if path.suffix in suffixes:
            yield path


def github_slug(heading):
    """GitHub's anchor slug for a heading: lowercase, keep only
    alphanumerics / spaces / hyphens / underscores, spaces to hyphens."""
    slug = heading.strip().lower()
    slug = re.sub(r"[^\w\- ]", "", slug, flags=re.UNICODE)
    return slug.replace(" ", "-")


def heading_anchors(md_path, cache={}):
    """All GitHub-style anchors of a markdown file (with the `-1`, `-2`
    suffixes GitHub appends to duplicate headings)."""
    if md_path in cache:
        return cache[md_path]
    anchors = set()
    counts = {}
    text = md_path.read_text(encoding="utf-8", errors="replace")
    for _, title in MD_HEADING_RE.findall(text):
        slug = github_slug(title)
        n = counts.get(slug, 0)
        counts[slug] = n + 1
        anchors.add(slug if n == 0 else f"{slug}-{n}")
    cache[md_path] = anchors
    return anchors


def check_md_links(errors):
    for md in walk({".md"}):
        text = md.read_text(encoding="utf-8", errors="replace")
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "mailto:")):
                continue
            path_part, _, fragment = target.partition("#")
            resolved = (md.parent / path_part).resolve() if path_part else md.resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: dangling link -> {target}")
                continue
            # Anchor fragments are only checkable for markdown targets.
            if fragment and resolved.suffix == ".md":
                if fragment not in heading_anchors(resolved):
                    errors.append(
                        f"{md.relative_to(ROOT)}: link -> {target} names no "
                        f"heading anchor of {resolved.relative_to(ROOT)}"
                    )


def check_design_citations(errors):
    design = ROOT / "DESIGN.md"
    if not design.exists():
        errors.append("DESIGN.md missing at repo root (cited throughout the sources)")
        return
    anchors = set(HEADING_RE.findall(design.read_text(encoding="utf-8")))
    if not anchors:
        errors.append("DESIGN.md has no §-numbered headings")
        return
    me = Path(__file__).resolve()
    # Markdown docs are part of the checked set: EXPERIMENTS.md and
    # README.md cite DESIGN.md sections in prose, and a renumbering
    # must not silently strand them. DESIGN.md itself is exempt (its
    # own heading lines contain the tokens being defined).
    for src in walk({".rs", ".py", ".md"}):
        if src.resolve() in (me, design.resolve()):
            continue
        text = src.read_text(encoding="utf-8", errors="replace")
        for token in SECTION_RE.findall(text):
            if token not in anchors:
                errors.append(
                    f"{src.relative_to(ROOT)}: citation §{token} "
                    f"has no heading in DESIGN.md (anchors: {sorted(anchors)})"
                )


COMMAND_RE = re.compile(r'Command::new\(\s*"([a-z0-9-]+)"')


def check_handbook_cli_coverage(errors):
    """Rule 4: the operator's handbook documents every CLI subcommand."""
    handbook = ROOT / "docs" / "HANDBOOK.md"
    if not handbook.exists():
        errors.append("docs/HANDBOOK.md missing (the operator's guide)")
        return
    main_rs = ROOT / "rust" / "src" / "main.rs"
    commands = COMMAND_RE.findall(main_rs.read_text(encoding="utf-8"))
    if not commands:
        errors.append("rust/src/main.rs: no Command::new declarations found "
                      "(CLI coverage scanner broke?)")
        return
    text = handbook.read_text(encoding="utf-8")
    for cmd in commands:
        if f"`{cmd}`" not in text and f"`dcd-lms {cmd}" not in text:
            errors.append(
                f"docs/HANDBOOK.md: CLI subcommand `{cmd}` (declared in "
                f"rust/src/main.rs) is undocumented"
            )


def check_ledger_chapter(errors):
    """Rule 5: the §9 ledger chapter and its in-code citation pair up."""
    design = ROOT / "DESIGN.md"
    if design.exists():
        headings = [
            line
            for line in design.read_text(encoding="utf-8").splitlines()
            if line.startswith("#") and "§9" in line
        ]
        if not headings:
            errors.append("DESIGN.md: the §9 ledger chapter is missing")
    comm = ROOT / "rust" / "src" / "energy" / "comm.rs"
    if not comm.exists():
        errors.append("rust/src/energy/comm.rs missing (the directional ledger)")
    elif "DESIGN.md §9" not in comm.read_text(encoding="utf-8"):
        errors.append("rust/src/energy/comm.rs does not cite DESIGN.md §9")


def check_serve_chapter(errors):
    """Rule 6: the §11 serve/cache chapter and its in-code citation pair up."""
    design = ROOT / "DESIGN.md"
    if design.exists():
        headings = [
            line
            for line in design.read_text(encoding="utf-8").splitlines()
            if line.startswith("#") and "§11" in line
        ]
        if not headings:
            errors.append("DESIGN.md: the §11 serve/result-cache chapter is missing")
    cache = ROOT / "rust" / "src" / "serve" / "cache.rs"
    if not cache.exists():
        errors.append("rust/src/serve/cache.rs missing (the content-addressed cache)")
    elif "DESIGN.md §11" not in cache.read_text(encoding="utf-8"):
        errors.append("rust/src/serve/cache.rs does not cite DESIGN.md §11")


def check_dynamics_chapter(errors):
    """Rule 7: the §12 dynamics chapter and its in-code citation pair up."""
    design = ROOT / "DESIGN.md"
    if design.exists():
        headings = [
            line
            for line in design.read_text(encoding="utf-8").splitlines()
            if line.startswith("#") and "§12" in line
        ]
        if not headings:
            errors.append("DESIGN.md: the §12 dynamic-networks chapter is missing")
    imp = ROOT / "rust" / "src" / "coordinator" / "impairments.rs"
    if not imp.exists():
        errors.append("rust/src/coordinator/impairments.rs missing (the impairment layer)")
    elif "DESIGN.md §12" not in imp.read_text(encoding="utf-8"):
        errors.append("rust/src/coordinator/impairments.rs does not cite DESIGN.md §12")


def check_energy_chapter(errors):
    """Rule 8: the §13 energy-loop chapter and its in-code citation pair up."""
    design = ROOT / "DESIGN.md"
    if design.exists():
        headings = [
            line
            for line in design.read_text(encoding="utf-8").splitlines()
            if line.startswith("#") and "§13" in line
        ]
        if not headings:
            errors.append("DESIGN.md: the §13 energy-loop chapter is missing")
    radio = ROOT / "rust" / "src" / "energy" / "radio.rs"
    if not radio.exists():
        errors.append("rust/src/energy/radio.rs missing (the priced radio model)")
    elif "DESIGN.md §13" not in radio.read_text(encoding="utf-8"):
        errors.append("rust/src/energy/radio.rs does not cite DESIGN.md §13")


def check_lanes_chapter(errors):
    """Rule 9: the §14 lane-engine chapter and its in-code citation pair up."""
    design = ROOT / "DESIGN.md"
    if design.exists():
        headings = [
            line
            for line in design.read_text(encoding="utf-8").splitlines()
            if line.startswith("#") and "§14" in line
        ]
        if not headings:
            errors.append("DESIGN.md: the §14 lane-engine chapter is missing")
    lanes = ROOT / "rust" / "src" / "coordinator" / "lanes.rs"
    if not lanes.exists():
        errors.append("rust/src/coordinator/lanes.rs missing (the run-batched lane engine)")
    elif "DESIGN.md §14" not in lanes.read_text(encoding="utf-8"):
        errors.append("rust/src/coordinator/lanes.rs does not cite DESIGN.md §14")


def main():
    errors = []
    # Guard: the walk must include docs/ (a SKIP_DIRS regression would
    # silently stop checking the handbook).
    if not any(p.relative_to(ROOT).parts[0] == "docs" for p in walk({".md"})):
        errors.append("markdown walk found nothing under docs/ (scanner broke?)")
    check_md_links(errors)
    check_design_citations(errors)
    check_handbook_cli_coverage(errors)
    check_ledger_chapter(errors)
    check_serve_chapter(errors)
    check_dynamics_chapter(errors)
    check_energy_chapter(errors)
    check_lanes_chapter(errors)
    if errors:
        print("documentation integrity check FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("documentation integrity check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
