#!/usr/bin/env python3
"""Markdown link check + DESIGN.md section-citation check.

Standalone CI face of rust/tests/docs_integrity.rs — the same two rules:

1. Every relative link target in a *.md file must exist on disk.
2. Every DESIGN.md section citation (a § token after the file name) in
   the rust/python sources must resolve to a §-numbered heading there.

Exit status 0 = clean, 1 = at least one dangling reference (all are
listed). Run from anywhere: the repo root is located relative to this
file.
"""

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SKIP_DIRS = {".git", "target", "vendor", "results", "artifacts", "__pycache__"}

LINK_RE = re.compile(r"\]\(([^)\s]+)\)")
# '§' followed by alphanumerics/dashes.
SECTION_RE = re.compile("DESIGN\\.md §([A-Za-z0-9-]+)")
HEADING_RE = re.compile("^#+.*§([A-Za-z0-9-]+)", re.M)


def walk(suffixes):
    for path in sorted(ROOT.rglob("*")):
        if path.is_dir():
            continue
        if any(part in SKIP_DIRS for part in path.relative_to(ROOT).parts):
            continue
        if path.suffix in suffixes:
            yield path


def check_md_links(errors):
    for md in walk({".md"}):
        text = md.read_text(encoding="utf-8", errors="replace")
        for target in LINK_RE.findall(text):
            if target.startswith(("http://", "https://", "#", "mailto:")):
                continue
            path_part = target.split("#", 1)[0]
            if not path_part:
                continue
            resolved = (md.parent / path_part).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(ROOT)}: dangling link -> {target}")


def check_design_citations(errors):
    design = ROOT / "DESIGN.md"
    if not design.exists():
        errors.append("DESIGN.md missing at repo root (cited throughout the sources)")
        return
    anchors = set(HEADING_RE.findall(design.read_text(encoding="utf-8")))
    if not anchors:
        errors.append("DESIGN.md has no §-numbered headings")
        return
    me = Path(__file__).resolve()
    for src in walk({".rs", ".py"}):
        if src.resolve() == me:
            continue
        text = src.read_text(encoding="utf-8", errors="replace")
        for token in SECTION_RE.findall(text):
            if token not in anchors:
                errors.append(
                    f"{src.relative_to(ROOT)}: citation §{token} "
                    f"has no heading in DESIGN.md (anchors: {sorted(anchors)})"
                )


def main():
    errors = []
    check_md_links(errors)
    check_design_citations(errors)
    if errors:
        print("documentation integrity check FAILED:")
        for e in errors:
            print(f"  {e}")
        return 1
    print("documentation integrity check passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
