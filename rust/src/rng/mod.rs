//! RNG substrate: PCG64, Gaussian sampling, subset/mask sampling.
//!
//! The `rand` crate is unavailable offline (DESIGN.md §2, S2); this module
//! provides everything the simulators need with explicit, reproducible
//! seeding. The generator is PCG-XSL-RR-128/64 (O'Neill 2014), the same
//! algorithm as `rand_pcg::Pcg64`.

/// PCG-XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller variate.
    spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed with a stream id; distinct `(seed, stream)` pairs give
    /// independent sequences (used to decorrelate nodes / MC runs).
    pub fn new(seed: u64, stream: u64) -> Self {
        let initstate = ((seed as u128) << 64) | (seed as u128 ^ 0x9e37_79b9_7f4a_7c15);
        let initseq = ((stream as u128) << 64) | (stream as u128).wrapping_add(0xda3e_39cb_94b9_5bdb);
        let mut rng = Self { state: 0, inc: (initseq << 1) | 1, spare: None };
        rng.step();
        rng.state = rng.state.wrapping_add(initstate);
        rng.step();
        rng
    }

    fn step(&mut self) {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
    }

    /// Next 64 uniform random bits.
    pub fn next_u64(&mut self) -> u64 {
        self.step();
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform double in [0, 1) with 53 bits of precision.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire's rejection method).
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "next_below: empty range");
        let bound = bound as u64;
        // 128-bit multiply-shift with rejection to kill modulo bias.
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= (u64::MAX - bound + 1) % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Standard normal via the Marsaglia polar method (both variates
    /// used). Chosen over Box–Muller after profiling: sincos dominated
    /// the WSN simulator's flat profile (EXPERIMENTS.md §Perf); polar
    /// needs one ln + one sqrt per *pair* and no trigonometry, at the
    /// cost of a ~21.5 % rejection rate.
    pub fn next_gaussian(&mut self) -> f64 {
        match self.spare.take() {
            Some(z) => z,
            None => loop {
                let x = 2.0 * self.next_f64() - 1.0;
                let y = 2.0 * self.next_f64() - 1.0;
                let s = x * x + y * y;
                if s < 1.0 && s > 0.0 {
                    let f = (-2.0 * s.ln() / s).sqrt();
                    self.spare = Some(y * f);
                    break x * f;
                }
            },
        }
    }

    /// Fill `out` with i.i.d. N(0, sigma^2) samples.
    pub fn fill_gaussian(&mut self, out: &mut [f64], sigma: f64) {
        for x in out.iter_mut() {
            *x = sigma * self.next_gaussian();
        }
    }

    /// Sample `m` distinct indices from `[0, n)` (partial Fisher–Yates),
    /// returned in arbitrary order.
    pub fn sample_indices(&mut self, n: usize, m: usize, scratch: &mut Vec<usize>) {
        assert!(m <= n, "sample_indices: m > n");
        scratch.clear();
        scratch.extend(0..n);
        for i in 0..m {
            let j = i + self.next_below(n - i);
            scratch.swap(i, j);
        }
        scratch.truncate(m);
    }

    /// Write a 0/1 mask of length `n` with exactly `m` ones into `mask`
    /// (an f32 slice, matching the artifact calling convention).
    pub fn fill_mask(&mut self, mask: &mut [f32], m: usize, scratch: &mut Vec<usize>) {
        let n = mask.len();
        mask.iter_mut().for_each(|x| *x = 0.0);
        self.sample_indices(n, m, scratch);
        for &i in scratch.iter() {
            mask[i] = 1.0;
        }
    }

    /// Bernoulli(p).
    pub fn next_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 0);
        let mut c = Pcg64::new(42, 1);
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let zs: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(xs, ys);
        assert_ne!(xs, zs);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Pcg64::new(7, 3);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Pcg64::new(11, 0);
        let n = 200_000;
        let (mut s1, mut s2, mut s4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.next_gaussian();
            s1 += x;
            s2 += x * x;
            s4 += x * x * x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64;
        let kurt = s4 / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
        assert!((kurt - 3.0).abs() < 0.1, "4th moment {kurt}");
    }

    #[test]
    fn next_below_unbiased() {
        let mut rng = Pcg64::new(5, 5);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.next_below(7)] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!(
                (c as i64 - expect as i64).unsigned_abs() < (expect / 10) as u64,
                "counts {counts:?}"
            );
        }
    }

    #[test]
    fn masks_have_exact_popcount() {
        let mut rng = Pcg64::new(9, 0);
        let mut scratch = Vec::new();
        for m in 0..=6 {
            let mut mask = vec![0f32; 6];
            rng.fill_mask(&mut mask, m, &mut scratch);
            assert_eq!(mask.iter().filter(|&&x| x == 1.0).count(), m);
            assert!(mask.iter().all(|&x| x == 0.0 || x == 1.0));
        }
    }

    #[test]
    fn mask_marginal_is_m_over_l() {
        // E{H} = (M/L) I — identity (13) of the paper, sampled.
        let mut rng = Pcg64::new(13, 0);
        let (l, m, trials) = (5usize, 3usize, 50_000usize);
        let mut hits = vec![0usize; l];
        let mut scratch = Vec::new();
        let mut mask = vec![0f32; l];
        for _ in 0..trials {
            rng.fill_mask(&mut mask, m, &mut scratch);
            for (h, &x) in hits.iter_mut().zip(mask.iter()) {
                if x == 1.0 {
                    *h += 1;
                }
            }
        }
        let p = m as f64 / l as f64;
        for &h in &hits {
            let freq = h as f64 / trials as f64;
            assert!((freq - p).abs() < 0.01, "freq {freq} vs {p}");
        }
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(17, 1);
        let mut scratch = Vec::new();
        for _ in 0..100 {
            rng.sample_indices(10, 4, &mut scratch);
            let mut sorted = scratch.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4);
            assert!(sorted.iter().all(|&i| i < 10));
        }
    }
}
