//! The content-addressed result cache behind `dcd-lms serve`
//! (DESIGN.md §11).
//!
//! A job's cache key is the SHA-256 of `(code tag, canonical scenario
//! INI)`. Canonicalization goes through the scenario layer's own
//! lossless round-trip — `Scenario::parse_str` fills every default and
//! `to_ini_string` emits each key in one fixed section/key order — so
//! two textually different but semantically identical INIs (key order,
//! whitespace, comments, spelled-out defaults) collapse to one entry,
//! while *every* semantic key (including the seed and the schedule
//! knobs that are recorded in the results-JSON manifest) keeps its own
//! entry. The only value rewritten beyond that round-trip is
//! `record_every = 0`, which is resolved to its effective stride — the
//! artifacts are a pure function of the effective value (DESIGN.md §11
//! spells out the bit-identity argument).
//!
//! On disk an entry is the *verbatim* artifact triple `run_scenario`
//! wrote — `<name>.csv`, `<name>.json`, `<name>_ledger.csv` — plus an
//! `entry.json` manifest, under `<root>/<key[..2]>/<key>/`. Entries are
//! committed by renaming a fully-written staging directory into place,
//! so readers never observe a torn entry; eviction is FIFO by a
//! persisted monotonic sequence number (`--cache-max-entries`).

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::jsonio::{obj, Json};
use crate::scenario::Scenario;

/// The code-version tag folded into every cache key: results are only
/// reusable across daemon restarts of the *same* simulator build, so a
/// crate-version bump (or a frame-protocol bump, which tracks result
/// semantics) invalidates the whole cache rather than ever serving
/// stale bytes.
pub fn code_tag() -> String {
    format!(
        "dcd-lms/{}+proto{}.{}",
        env!("CARGO_PKG_VERSION"),
        crate::shard::PROTOCOL_VERSION,
        crate::shard::SESSION_PROTOCOL_VERSION,
    )
}

/// The canonical execution form of a scenario: the parse → serialize
/// round-trip (fixed key order, defaults filled in) with two
/// artifact-neutral rewrites — `record_every = 0` resolved to its
/// effective stride, and `[schedule] lanes` erased (the lane engine is
/// byte-identical at every width, DESIGN.md §14, so lane width must not
/// split the cache). The daemon *executes* this form (at the submitted
/// lane width), which is why a cached artifact is byte-identical to
/// recomputing the submitted text (DESIGN.md §11).
pub fn canonical_scenario(sc: &Scenario) -> Scenario {
    let mut c = sc.clone();
    c.record_every = c.effective_record_every();
    c.lanes = crate::coordinator::LaneCount::default();
    c
}

/// Canonical INI text of a scenario spec (see [`canonical_scenario`]).
pub fn canonical_spec(src: &str) -> Result<String, String> {
    let sc = Scenario::parse_str(src)?;
    Ok(canonical_scenario(&sc).to_ini_string())
}

/// The content-addressed cache key of a scenario: SHA-256 over the
/// code tag and the canonical INI (which carries the seed).
pub fn job_key(sc: &Scenario) -> String {
    let text = format!("{}\n{}", code_tag(), canonical_scenario(sc).to_ini_string());
    sha256_hex(text.as_bytes())
}

/// One cached artifact triple, read back as text (the session protocol
/// ships artifacts inline so `--via` clients write identical files).
#[derive(Debug, Clone)]
pub struct CachedResult {
    /// The entry's cache key (SHA-256 hex).
    pub key: String,
    /// Scenario name — the artifact file stem.
    pub name: String,
    /// `<name>.csv` bytes.
    pub csv: String,
    /// `<name>.json` bytes.
    pub json: String,
    /// `<name>_ledger.csv` bytes.
    pub ledger_csv: String,
}

/// The on-disk cache. All mutating operations serialize on one lock;
/// concurrent daemons sharing a root are additionally protected by the
/// atomic rename commit (the loser of a commit race simply adopts the
/// winner's entry).
pub struct ResultCache {
    root: PathBuf,
    max_entries: usize,
    lock: Mutex<()>,
}

impl ResultCache {
    /// Open (creating if needed) a cache rooted at `root`.
    /// `max_entries = 0` disables eviction.
    pub fn open(root: &str, max_entries: usize) -> Result<Self, String> {
        let root = PathBuf::from(root);
        std::fs::create_dir_all(&root)
            .map_err(|e| format!("creating cache root {}: {e}", root.display()))?;
        Ok(Self { root, max_entries, lock: Mutex::new(()) })
    }

    fn entry_dir(&self, key: &str) -> PathBuf {
        self.root.join(&key[..2]).join(key)
    }

    /// Cheap existence probe (no artifact reads).
    pub fn contains(&self, key: &str) -> bool {
        key.len() == 64 && self.entry_dir(key).join("entry.json").is_file()
    }

    /// Read an entry's artifacts back, bumping its hit counter
    /// (best effort — a failed bump never fails the lookup).
    pub fn lookup(&self, key: &str) -> Option<CachedResult> {
        if !self.contains(key) {
            return None;
        }
        let dir = self.entry_dir(key);
        let manifest = Json::parse(&std::fs::read_to_string(dir.join("entry.json")).ok()?).ok()?;
        let name = manifest.get("name").as_str()?.to_string();
        let result = CachedResult {
            key: key.to_string(),
            name: name.clone(),
            csv: std::fs::read_to_string(dir.join(format!("{name}.csv"))).ok()?,
            json: std::fs::read_to_string(dir.join(format!("{name}.json"))).ok()?,
            ledger_csv: std::fs::read_to_string(dir.join(format!("{name}_ledger.csv"))).ok()?,
        };
        let _guard = self.lock.lock().expect("cache lock poisoned");
        if let (Some(mut m), Some(hits)) =
            (manifest.as_obj().cloned(), manifest.get("hits").as_u64())
        {
            m.insert("hits".to_string(), Json::Num((hits + 1) as f64));
            let _ = std::fs::write(dir.join("entry.json"), Json::Obj(m).to_string_pretty());
        }
        Some(result)
    }

    /// A private staging directory for one job's artifacts; the caller
    /// runs the scenario into it and then [`ResultCache::commit`]s.
    pub fn staging_dir(&self, key: &str, token: u64) -> Result<PathBuf, String> {
        let dir = self
            .root
            .join(format!("staging-{}-{}-{token}", &key[..12], std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir)
            .map_err(|e| format!("creating staging dir {}: {e}", dir.display()))?;
        Ok(dir)
    }

    /// Atomically publish a fully-written staging directory as the
    /// entry for `key`: write the `entry.json` manifest, rename into
    /// place, then apply FIFO eviction. If another writer won the race
    /// the staging copy is discarded and the existing entry is read
    /// back — either way the returned artifacts are the entry's bytes.
    pub fn commit(
        &self,
        key: &str,
        sc: &Scenario,
        staging: &Path,
    ) -> Result<CachedResult, String> {
        let guard = self.lock.lock().expect("cache lock poisoned");
        let seq = self.max_seq() + 1;
        let manifest = obj(vec![
            ("key", Json::Str(key.to_string())),
            ("name", Json::Str(sc.name.clone())),
            ("seq", Json::Num(seq as f64)),
            ("hits", Json::Num(0.0)),
            ("code_tag", Json::Str(code_tag())),
            ("spec", Json::Str(canonical_scenario(sc).to_ini_string())),
        ]);
        std::fs::write(staging.join("entry.json"), manifest.to_string_pretty())
            .map_err(|e| format!("writing cache manifest: {e}"))?;
        let dir = self.entry_dir(key);
        let parent = dir.parent().expect("entry dir has a shard parent");
        std::fs::create_dir_all(parent)
            .map_err(|e| format!("creating cache shard {}: {e}", parent.display()))?;
        if let Err(e) = std::fs::rename(staging, &dir) {
            // Lost a commit race (the rename target already exists) —
            // adopt the published entry.
            std::fs::remove_dir_all(staging).ok();
            if !self.contains(key) {
                return Err(format!("publishing cache entry {}: {e}", dir.display()));
            }
        }
        self.evict_locked();
        drop(guard);
        self.lookup(key)
            .ok_or_else(|| format!("cache entry {key} vanished after commit"))
    }

    /// All `(seq, entry_dir)` pairs currently in the cache.
    fn entries(&self) -> Vec<(u64, PathBuf)> {
        let mut out = Vec::new();
        let Ok(shards) = std::fs::read_dir(&self.root) else {
            return out;
        };
        for shard in shards.flatten() {
            // Entry shards are two-hex-char directories; staging dirs
            // and strays are skipped.
            if shard.file_name().to_string_lossy().len() != 2 {
                continue;
            }
            let Ok(dirs) = std::fs::read_dir(shard.path()) else {
                continue;
            };
            for entry in dirs.flatten() {
                let manifest = entry.path().join("entry.json");
                let Ok(text) = std::fs::read_to_string(&manifest) else {
                    continue;
                };
                let seq = Json::parse(&text)
                    .ok()
                    .and_then(|m| m.get("seq").as_u64())
                    .unwrap_or(0);
                out.push((seq, entry.path()));
            }
        }
        out
    }

    /// Number of committed entries.
    pub fn len(&self) -> usize {
        self.entries().len()
    }

    /// True when the cache holds no entries.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn max_seq(&self) -> u64 {
        self.entries().into_iter().map(|(seq, _)| seq).max().unwrap_or(0)
    }

    /// FIFO eviction: drop lowest-sequence entries until at most
    /// `max_entries` remain (no-op when the knob is 0).
    fn evict_locked(&self) {
        if self.max_entries == 0 {
            return;
        }
        let mut entries = self.entries();
        if entries.len() <= self.max_entries {
            return;
        }
        entries.sort_by_key(|(seq, _)| *seq);
        let excess = entries.len() - self.max_entries;
        for (_, dir) in entries.into_iter().take(excess) {
            std::fs::remove_dir_all(dir).ok();
        }
    }
}

// ---------------------------------------------------------------------------
// SHA-256 (FIPS 180-4), self-contained: no crypto crates ship in this
// offline environment (DESIGN.md §2), and a cache key only needs a
// stable collision-resistant digest, not a vetted crypto stack.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

/// SHA-256 digest as lowercase hex.
pub fn sha256_hex(data: &[u8]) -> String {
    let mut h: [u32; 8] = [
        0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab,
        0x5be0cd19,
    ];
    let bit_len = (data.len() as u64).wrapping_mul(8);
    let mut msg = data.to_vec();
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());
    let mut w = [0u32; 64];
    for block in msg.chunks_exact(64) {
        for (i, word) in w.iter_mut().take(16).enumerate() {
            *word = u32::from_be_bytes([
                block[4 * i],
                block[4 * i + 1],
                block[4 * i + 2],
                block[4 * i + 3],
            ]);
        }
        for i in 16..64 {
            let s0 = w[i - 15].rotate_right(7) ^ w[i - 15].rotate_right(18) ^ (w[i - 15] >> 3);
            let s1 = w[i - 2].rotate_right(17) ^ w[i - 2].rotate_right(19) ^ (w[i - 2] >> 10);
            w[i] = w[i - 16]
                .wrapping_add(s0)
                .wrapping_add(w[i - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for i in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[i])
                .wrapping_add(w[i]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (slot, v) in h.iter_mut().zip([a, b, c, d, e, f, g, hh]) {
            *slot = slot.wrapping_add(v);
        }
    }
    let mut hex = String::with_capacity(64);
    for x in h {
        hex.push_str(&format!("{x:08x}"));
    }
    hex
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::find;

    #[test]
    fn sha256_matches_fips_vectors() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        // Multi-block message (200 bytes spans four blocks with padding).
        assert_eq!(
            sha256_hex(&[b'a'; 200]),
            "c2a908d98f5df987ade41b5fce213067efbcc21ef2240212a41e54b5e7c28ae5"
        );
    }

    #[test]
    fn key_is_invariant_to_representation_not_semantics() {
        let base = find("paper-10-node").unwrap();
        let canonical = base.to_ini_string();
        // Key order, whitespace, comments and spelled-out defaults all
        // collapse to the same key...
        let scrambled = format!(
            "# a comment\n[schedule]\nseed={}\nruns = {}\n\n[scenario]\n  name = {}\n\
             description = {}\n",
            base.seed, base.runs, base.name, base.description
        );
        let a = job_key(&Scenario::parse_str(&canonical).unwrap());
        let b = job_key(&Scenario::parse_str(&scrambled).unwrap());
        assert_eq!(a, b, "representation must not change the cache key");
        // ...and `record_every = 0` is resolved to its effective stride.
        let mut resolved = base.clone();
        assert_eq!(resolved.record_every, 0);
        resolved.record_every = resolved.effective_record_every();
        assert_eq!(job_key(&base), job_key(&resolved));
        // But every semantic perturbation gets its own key.
        let mut seeded = base.clone();
        seeded.seed += 1;
        assert_ne!(job_key(&base), job_key(&seeded));
    }

    #[test]
    fn cache_roundtrips_and_evicts_fifo() {
        let root = std::env::temp_dir().join("dcd_cache_unit_test");
        std::fs::remove_dir_all(&root).ok();
        let cache = ResultCache::open(root.to_str().unwrap(), 2).unwrap();
        let mut keys = Vec::new();
        for i in 0..3u64 {
            let mut sc = find("paper-10-node").unwrap();
            sc.seed = 1000 + i;
            let key = job_key(&sc);
            let staging = cache.staging_dir(&key, i).unwrap();
            std::fs::write(staging.join(format!("{}.csv", sc.name)), format!("csv{i}")).unwrap();
            std::fs::write(staging.join(format!("{}.json", sc.name)), format!("json{i}")).unwrap();
            std::fs::write(
                staging.join(format!("{}_ledger.csv", sc.name)),
                format!("ledger{i}"),
            )
            .unwrap();
            let back = cache.commit(&key, &sc, &staging).unwrap();
            assert_eq!(back.csv, format!("csv{i}"));
            keys.push(key);
        }
        // FIFO eviction at max_entries = 2: the first entry is gone.
        assert_eq!(cache.len(), 2);
        assert!(!cache.contains(&keys[0]));
        assert!(cache.contains(&keys[1]) && cache.contains(&keys[2]));
        let hit = cache.lookup(&keys[2]).unwrap();
        assert_eq!(hit.ledger_csv, "ledger2");
        std::fs::remove_dir_all(&root).ok();
    }
}
