//! Resident scenario service with a content-addressed result cache
//! (DESIGN.md §11).
//!
//! `dcd-lms serve` keeps one process resident so repeated scenario
//! runs pay the simulation cost once: clients submit scenario INI
//! specs over a newline-JSON **session protocol** (v3, see
//! `serve/session.rs` and [`crate::shard::SESSION_PROTOCOL_VERSION`]),
//! a bounded FIFO [`queue::JobQueue`] fans them over a worker pool,
//! and every result is committed to a [`cache::ResultCache`] keyed by
//! the canonical hash of (normalized scenario INI, seed inclusive,
//! code-version tag). A resubmit of the same spec returns the stored
//! artifact triple byte-for-byte with **zero** simulation work — the
//! bit-identity argument is DESIGN.md §11's: every computed job routes
//! through the same deterministic run-order fold as `scenario run`, so
//! the cached bytes and a recomputation are the same bytes.
//!
//! Two front doors:
//! * [`serve_stdio`] — one session on stdin/stdout (systemd-style
//!   socket activation, tests, and piping).
//! * [`serve_tcp`] — a listener accepting many concurrent sessions; a
//!   client disconnect mid-stream never cancels its job (the queue
//!   owns jobs, sessions merely observe), so the result still lands in
//!   the cache for the retry.
//!
//! Operations guide: docs/HANDBOOK.md, "Resident serve daemon".

pub mod cache;
pub mod queue;
pub mod session;

use std::io::{BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

pub use cache::{canonical_scenario, canonical_spec, code_tag, job_key, CachedResult, ResultCache};
pub use queue::{sim_runs, JobEvent, JobQueue, JobState};
pub use session::{run_via, serve_session, stop_via, SessionEnd, SessionFrame};

/// Tunables for a resident daemon (CLI flags of `dcd-lms serve`).
pub struct ServeConfig {
    /// Cache root directory (created if absent).
    pub cache_dir: String,
    /// Worker threads draining the job queue.
    pub workers: usize,
    /// Maximum queued-but-not-running jobs before submits are refused.
    pub queue_depth: usize,
    /// FIFO eviction bound for the result cache (0 = unlimited).
    pub max_entries: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { cache_dir: "serve-cache".to_string(), workers: 2, queue_depth: 64, max_entries: 0 }
    }
}

/// A running daemon: the job queue (which owns the cache and worker
/// pool). Sessions borrow it; it outlives every session.
pub struct Daemon {
    /// The bounded FIFO queue all sessions submit into.
    pub queue: JobQueue,
}

impl Daemon {
    /// Open the cache and start the worker pool.
    pub fn start(cfg: &ServeConfig) -> Result<Daemon, String> {
        let cache = Arc::new(ResultCache::open(&cfg.cache_dir, cfg.max_entries)?);
        Ok(Daemon { queue: JobQueue::start(cache, cfg.workers, cfg.queue_depth) })
    }
}

/// Run one session over stdin/stdout, then drain and exit. EOF without
/// a `shutdown` frame still drains — piped submits always finish.
pub fn serve_stdio(cfg: &ServeConfig) -> Result<(), String> {
    let daemon = Daemon::start(cfg)?;
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let _ = serve_session(&daemon, stdin.lock(), stdout.lock());
    daemon.queue.shutdown();
    Ok(())
}

/// Listen on `listen` (e.g. `127.0.0.1:7717`, port 0 for ephemeral)
/// and serve concurrent sessions until one sends `shutdown`. Prints
/// `serve: listening on <addr>` once ready — scripts parse that line
/// for the bound port.
pub fn serve_tcp(cfg: &ServeConfig, listen: &str) -> Result<(), String> {
    let listener =
        TcpListener::bind(listen).map_err(|e| format!("binding {listen}: {e}"))?;
    let local = listener
        .local_addr()
        .map_err(|e| format!("reading the bound address: {e}"))?;
    println!("serve: listening on {local}");
    let _ = std::io::stdout().flush();
    let daemon = Arc::new(Daemon::start(cfg)?);
    let stop = Arc::new(AtomicBool::new(false));
    let mut sessions = Vec::new();
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        let stream = match conn {
            Ok(s) => s,
            Err(_) => continue,
        };
        let daemon = Arc::clone(&daemon);
        let stop = Arc::clone(&stop);
        sessions.push(std::thread::spawn(move || {
            let reader = match stream.try_clone() {
                Ok(s) => BufReader::new(s),
                Err(_) => return,
            };
            if serve_session(&daemon, reader, stream) == SessionEnd::Shutdown {
                stop.store(true, Ordering::SeqCst);
                // Self-connect to unblock the accept loop.
                let _ = TcpStream::connect(local);
            }
        }));
    }
    for handle in sessions {
        let _ = handle.join();
    }
    daemon.queue.shutdown();
    println!("serve: stopped");
    Ok(())
}
