//! Bounded FIFO job queue behind the serve daemon (DESIGN.md §11).
//!
//! Jobs are scenario runs keyed by the content-addressed cache key of
//! `serve/cache.rs`. A fixed pool of worker threads pops jobs in
//! submission order; each job first probes the cache (a hit costs zero
//! simulation work — audited by the global [`sim_runs`] counter), then
//! coalesces with any in-flight computation of the same key, and only
//! computes when it is the first holder of that key. Results are
//! committed to the cache atomically and fanned out to per-job event
//! listeners (the session threads streaming `wait: true` submits).
//!
//! The queue is bounded: submits past `depth` pending jobs are refused
//! with a `queue full` error rather than buffered without limit, so a
//! runaway client cannot exhaust the daemon's memory.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

use crate::scenario::{run_scenario_with_progress, Scenario};

use super::cache::{canonical_scenario, job_key, CachedResult, ResultCache};

/// Realizations actually simulated by this process since start — only
/// bumped when a job *computes* (never on a cache hit), so the cache
/// property tests can assert "resubmit = zero simulation work".
static SIM_RUNS: AtomicU64 = AtomicU64::new(0);

/// Read the daemon-wide simulated-realizations counter.
pub fn sim_runs() -> u64 {
    SIM_RUNS.load(Ordering::SeqCst)
}

/// Events streamed to a waiting submitter.
pub enum JobEvent {
    /// One shard of the job finished.
    Progress {
        /// Index of the shard that completed.
        shard: usize,
        /// Shards completed so far.
        done: usize,
        /// Total shards.
        total: usize,
    },
    /// Terminal success.
    Done {
        /// The committed (or already-cached) artifact triple.
        result: Arc<CachedResult>,
        /// True when served from the cache with zero simulation work.
        cached: bool,
    },
    /// Terminal failure.
    Failed {
        /// Why the run failed.
        message: String,
    },
}

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    /// Waiting in the FIFO.
    Queued,
    /// A worker owns it (probing the cache, waiting on a twin, or
    /// simulating).
    Running,
    /// Finished; artifacts available via [`JobQueue::result_of`].
    Done {
        /// True when served from the cache.
        cached: bool,
    },
    /// The run errored.
    Failed(String),
    /// Cancelled while still queued.
    Cancelled,
}

impl JobState {
    /// Human/state-frame label.
    pub fn label(&self) -> String {
        match self {
            JobState::Queued => "queued".to_string(),
            JobState::Running => "running".to_string(),
            JobState::Done { .. } => "done".to_string(),
            JobState::Failed(e) => format!("failed: {e}"),
            JobState::Cancelled => "cancelled".to_string(),
        }
    }
}

struct JobRecord {
    sc: Scenario,
    key: String,
    state: JobState,
    listeners: Vec<Sender<JobEvent>>,
    result: Option<Arc<CachedResult>>,
}

struct QueueState {
    next_id: u64,
    pending: VecDeque<u64>,
    jobs: HashMap<u64, JobRecord>,
    /// Keys currently being computed — twins wait instead of
    /// duplicating the work.
    computing: HashSet<String>,
    running: usize,
    draining: bool,
}

struct QueueInner {
    state: Mutex<QueueState>,
    cv: Condvar,
    cache: Arc<ResultCache>,
    depth: usize,
}

/// The daemon's job queue: worker pool + bounded FIFO + result cache.
pub struct JobQueue {
    inner: Arc<QueueInner>,
    workers: Mutex<Vec<JoinHandle<()>>>,
}

impl JobQueue {
    /// Start `workers` worker threads over `cache`, refusing submits
    /// once `depth` jobs are pending.
    pub fn start(cache: Arc<ResultCache>, workers: usize, depth: usize) -> JobQueue {
        let inner = Arc::new(QueueInner {
            state: Mutex::new(QueueState {
                next_id: 1,
                pending: VecDeque::new(),
                jobs: HashMap::new(),
                computing: HashSet::new(),
                running: 0,
                draining: false,
            }),
            cv: Condvar::new(),
            cache,
            depth: depth.max(1),
        });
        let handles = (0..workers.max(1))
            .map(|i| {
                let inner = Arc::clone(&inner);
                std::thread::Builder::new()
                    .name(format!("serve-worker-{i}"))
                    .spawn(move || worker_loop(&inner))
                    .expect("spawn serve worker")
            })
            .collect();
        JobQueue { inner, workers: Mutex::new(handles) }
    }

    /// The result cache this queue commits into.
    pub fn cache(&self) -> &ResultCache {
        &self.inner.cache
    }

    /// Enqueue a validated scenario. Returns the job id, its cache
    /// key, whether the cache already holds that key, and — for
    /// subscribing submits — the event stream.
    #[allow(clippy::type_complexity)]
    pub fn submit(
        &self,
        sc: Scenario,
        subscribe: bool,
    ) -> Result<(u64, String, bool, Option<Receiver<JobEvent>>), String> {
        let key = job_key(&sc);
        let cached = self.inner.cache.contains(&key);
        let mut st = self.inner.state.lock().expect("queue lock");
        if st.draining {
            return Err("daemon is draining and not accepting new jobs".to_string());
        }
        if st.pending.len() >= self.inner.depth {
            return Err(format!("queue full ({} jobs pending)", self.inner.depth));
        }
        let id = st.next_id;
        st.next_id += 1;
        let (listeners, events) = if subscribe {
            let (tx, rx) = channel();
            (vec![tx], Some(rx))
        } else {
            (Vec::new(), None)
        };
        st.jobs.insert(
            id,
            JobRecord {
                sc,
                key: key.clone(),
                state: JobState::Queued,
                listeners,
                result: None,
            },
        );
        st.pending.push_back(id);
        self.inner.cv.notify_all();
        Ok((id, key, cached, events))
    }

    /// State label for a job id (`None` for unknown ids).
    pub fn state_label(&self, id: u64) -> Option<String> {
        let st = self.inner.state.lock().expect("queue lock");
        st.jobs.get(&id).map(|rec| rec.state.label())
    }

    /// The artifact triple of a finished job, with its cache-hit flag.
    pub fn result_of(&self, id: u64) -> Option<(Arc<CachedResult>, bool)> {
        let st = self.inner.state.lock().expect("queue lock");
        let rec = st.jobs.get(&id)?;
        match (&rec.state, &rec.result) {
            (JobState::Done { cached }, Some(result)) => Some((Arc::clone(result), *cached)),
            _ => None,
        }
    }

    /// Cancel a job that has not started yet. Running or finished jobs
    /// are refused — a cancel must never tear half-finished artifacts.
    pub fn cancel(&self, id: u64) -> Result<(), String> {
        let mut st = self.inner.state.lock().expect("queue lock");
        let rec = st
            .jobs
            .get_mut(&id)
            .ok_or_else(|| format!("unknown job {id}"))?;
        match rec.state {
            JobState::Queued => {
                rec.state = JobState::Cancelled;
                for tx in rec.listeners.drain(..) {
                    let _ = tx.send(JobEvent::Failed { message: "cancelled".to_string() });
                }
                st.pending.retain(|&q| q != id);
                Ok(())
            }
            _ => Err(format!(
                "job {id} is {}; only queued jobs can be cancelled",
                rec.state.label()
            )),
        }
    }

    /// Stop accepting jobs and block until everything queued or
    /// running has finished.
    pub fn drain(&self) {
        let mut st = self.inner.state.lock().expect("queue lock");
        st.draining = true;
        self.inner.cv.notify_all();
        while !st.pending.is_empty() || st.running > 0 {
            st = self.inner.cv.wait(st).expect("queue lock");
        }
    }

    /// Drain and join the worker pool (the daemon's last act).
    pub fn shutdown(&self) {
        self.drain();
        let handles: Vec<_> = self.workers.lock().expect("worker handles").drain(..).collect();
        for handle in handles {
            let _ = handle.join();
        }
    }
}

fn worker_loop(inner: &Arc<QueueInner>) {
    loop {
        // Pop the next job and mark it running under one lock, so a
        // cancel can never slip between pop and claim.
        let (id, sc, key) = {
            let mut st = inner.state.lock().expect("queue lock");
            loop {
                if let Some(id) = st.pending.pop_front() {
                    st.running += 1;
                    let rec = st.jobs.get_mut(&id).expect("popped job has a record");
                    rec.state = JobState::Running;
                    break (id, rec.sc.clone(), rec.key.clone());
                }
                if st.draining {
                    return;
                }
                st = inner.cv.wait(st).expect("queue lock");
            }
        };
        let outcome = run_one(inner, id, &sc, &key);
        let mut st = inner.state.lock().expect("queue lock");
        st.running -= 1;
        let rec = st.jobs.get_mut(&id).expect("finished job has a record");
        match outcome {
            Ok((result, cached)) => {
                rec.state = JobState::Done { cached };
                rec.result = Some(Arc::clone(&result));
                for tx in rec.listeners.drain(..) {
                    let _ = tx.send(JobEvent::Done { result: Arc::clone(&result), cached });
                }
            }
            Err(message) => {
                rec.state = JobState::Failed(message.clone());
                for tx in rec.listeners.drain(..) {
                    let _ = tx.send(JobEvent::Failed { message: message.clone() });
                }
            }
        }
        inner.cv.notify_all();
    }
}

/// Serve one job: cache probe → twin coalescing → compute + commit.
fn run_one(
    inner: &Arc<QueueInner>,
    id: u64,
    sc: &Scenario,
    key: &str,
) -> Result<(Arc<CachedResult>, bool), String> {
    loop {
        if let Some(hit) = inner.cache.lookup(key) {
            return Ok((Arc::new(hit), true));
        }
        let mut st = inner.state.lock().expect("queue lock");
        if st.computing.insert(key.to_string()) {
            break;
        }
        // A twin is computing this key; wait and re-probe the cache.
        drop(inner.cv.wait(st).expect("queue lock"));
    }
    let outcome = compute(inner, id, sc, key);
    let mut st = inner.state.lock().expect("queue lock");
    st.computing.remove(key);
    drop(st);
    inner.cv.notify_all();
    outcome
}

fn compute(
    inner: &Arc<QueueInner>,
    id: u64,
    sc: &Scenario,
    key: &str,
) -> Result<(Arc<CachedResult>, bool), String> {
    let mut canon = canonical_scenario(sc);
    // Canonicalization erases the lane width (cache-key neutral); run
    // at the submitted width anyway — it only changes throughput, the
    // artifact bytes are identical at every width (DESIGN.md §14).
    canon.lanes = sc.lanes;
    let staging = inner.cache.staging_dir(key, id)?;
    let staging_str = staging
        .to_str()
        .ok_or("staging path is not valid UTF-8")?
        .to_string();
    let report = |shard: usize, done: usize, total: usize| {
        let mut st = inner.state.lock().expect("queue lock");
        if let Some(rec) = st.jobs.get_mut(&id) {
            rec.listeners
                .retain(|tx| tx.send(JobEvent::Progress { shard, done, total }).is_ok());
        }
    };
    let run = run_scenario_with_progress(&canon, Some(&staging_str), true, Some(&report));
    if let Err(e) = run {
        let _ = std::fs::remove_dir_all(&staging);
        return Err(e);
    }
    SIM_RUNS.fetch_add(canon.runs as u64, Ordering::SeqCst);
    let result = inner.cache.commit(key, &canon, &staging)?;
    Ok((Arc::new(result), false))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::find;

    fn tmp(tag: &str) -> String {
        let dir = std::env::temp_dir().join(format!("dcd-serve-queue-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir.to_str().expect("utf-8 temp path").to_string()
    }

    fn small_scenario(seed: u64) -> Scenario {
        let mut sc = find("paper-10-node").expect("builtin scenario").clone();
        sc.runs = 2;
        sc.iters = 200;
        sc.seed = seed;
        sc.threads = 1;
        sc.shards = 1;
        sc
    }

    #[test]
    fn queue_computes_then_serves_from_cache() {
        let root = tmp("hit");
        let cache = Arc::new(ResultCache::open(&root, 0).expect("open cache"));
        let queue = JobQueue::start(cache, 2, 8);
        let (a, key_a, cached_a, rx_a) = queue.submit(small_scenario(2024), true).unwrap();
        assert!(!cached_a);
        let before = sim_runs();
        let mut done = None;
        for event in rx_a.unwrap() {
            if let JobEvent::Done { result, cached } = event {
                done = Some((result, cached));
                break;
            }
        }
        let (first, cached) = done.expect("terminal event");
        assert!(!cached, "first run must compute");
        assert_eq!(first.key, key_a);
        assert!(sim_runs() >= before + 2, "compute must count its runs");

        // Resubmit: byte-identical artifacts, zero additional work.
        let mid = sim_runs();
        let (b, key_b, cached_b, rx_b) = queue.submit(small_scenario(2024), true).unwrap();
        assert_ne!(a, b);
        assert_eq!(key_a, key_b);
        assert!(cached_b, "submit-time probe must see the entry");
        let mut done = None;
        for event in rx_b.unwrap() {
            if let JobEvent::Done { result, cached } = event {
                done = Some((result, cached));
                break;
            }
        }
        let (second, cached) = done.expect("terminal event");
        assert!(cached);
        assert_eq!(first.csv, second.csv);
        assert_eq!(first.json, second.json);
        assert_eq!(first.ledger_csv, second.ledger_csv);
        assert_eq!(sim_runs(), mid, "cache hit must do zero simulation work");

        assert_eq!(queue.state_label(a).unwrap(), "done");
        queue.shutdown();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn cancel_only_reaches_queued_jobs() {
        let root = tmp("cancel");
        let cache = Arc::new(ResultCache::open(&root, 0).expect("open cache"));
        // No free worker: one worker, keep it busy with the first job.
        let queue = JobQueue::start(cache, 1, 8);
        let (a, _, _, rx) = queue.submit(small_scenario(1), true).unwrap();
        // Three more behind the single worker; the last is certainly
        // still queued when the cancel lands.
        let _ = queue.submit(small_scenario(2), false).unwrap();
        let _ = queue.submit(small_scenario(3), false).unwrap();
        let (b, _, _, _) = queue.submit(small_scenario(4), false).unwrap();
        queue.cancel(b).expect("queued job cancels");
        assert_eq!(queue.state_label(b).unwrap(), "cancelled");
        assert!(queue.cancel(b).is_err(), "double cancel refused");
        for event in rx.unwrap() {
            if matches!(event, JobEvent::Done { .. } | JobEvent::Failed { .. }) {
                break;
            }
        }
        assert!(queue.cancel(a).is_err(), "finished job refuses cancel");
        assert!(queue.cancel(999).is_err(), "unknown id refused");
        queue.shutdown();
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn queue_depth_is_enforced() {
        let root = tmp("depth");
        let cache = Arc::new(ResultCache::open(&root, 0).expect("open cache"));
        let queue = JobQueue::start(cache, 1, 1);
        // Worker may or may not have popped the first job yet; keep
        // submitting until the bound trips — it must trip within
        // depth+1 distinct seeds.
        let mut refused = None;
        for seed in 0..64 {
            if let Err(e) = queue.submit(small_scenario(100 + seed), false) {
                refused = Some(e);
                break;
            }
        }
        let msg = refused.expect("bounded queue must refuse eventually");
        assert!(msg.contains("queue full"), "{msg}");
        queue.shutdown();
        let _ = std::fs::remove_dir_all(&root);
    }
}
