//! The v3 **session** frame grammar spoken between `dcd-lms serve` and
//! its clients (DESIGN.md §11), plus the server-side session loop and
//! the `scenario run --via <addr>` client.
//!
//! Like the v2 worker-pipe grammar (`shard/protocol.rs`), frames are
//! newline-delimited JSON objects carrying a version (`"v"`, here
//! [`SESSION_PROTOCOL_VERSION`]) and a `"type"` tag. Clients send
//! `submit` / `status` / `result` / `cancel` / `shutdown`; the daemon
//! answers `accepted`, streams `progress` per completed shard, and
//! terminates a waited submit with a `result` frame that carries the
//! three artifact texts inline — so a `--via` client writes files
//! byte-identical to a local run.
//!
//! A malformed or unexpected frame never kills the session (and never
//! panics — fuzz-tested in `rust/tests/protocol_fuzz.rs`): the daemon
//! answers an `error` frame naming the 1-based input frame index and
//! the offending field, then keeps reading.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;

use crate::jsonio::{obj, Json};
use crate::scenario::Scenario;
use crate::shard::SESSION_PROTOCOL_VERSION;

use super::queue::{sim_runs, JobEvent};
use super::Daemon;

/// One v3 session frame (client → daemon or daemon → client; the
/// direction is part of the contract, and a frame arriving in the
/// wrong direction is answered with an `error` frame).
#[derive(Debug, Clone)]
pub enum SessionFrame {
    /// Client → daemon: run this scenario INI. With `wait` (the
    /// default) the daemon streams progress and the terminal result on
    /// this session; with `wait = false` the client polls `status` and
    /// fetches the result later.
    Submit {
        /// Scenario INI text (any representation; the daemon
        /// canonicalizes it for the cache key).
        spec: String,
        /// Stream progress + result on this session (default true).
        wait: bool,
    },
    /// Client → daemon: report a job's state.
    Status {
        /// Job id from the `accepted` frame.
        job: u64,
    },
    /// Client → daemon: fetch the result of a finished job.
    ResultRequest {
        /// Job id from the `accepted` frame.
        job: u64,
    },
    /// Client → daemon: cancel a still-queued job.
    Cancel {
        /// Job id from the `accepted` frame.
        job: u64,
    },
    /// Client → daemon: drain the queue (finish running and queued
    /// jobs, accept no new ones), answer [`SessionFrame::Bye`], stop.
    Shutdown,
    /// Daemon → client: the submit was queued (or will be served from
    /// the cache — `cached` is the submit-time probe).
    Accepted {
        /// Daemon-assigned job id.
        job: u64,
        /// Content-addressed cache key (SHA-256 hex, DESIGN.md §11).
        key: String,
        /// Whether the cache already held this key at submit time.
        cached: bool,
    },
    /// Daemon → client: one shard of the job finished.
    Progress {
        /// Job id.
        job: u64,
        /// Index of the shard that just completed.
        shard: usize,
        /// Shards completed so far.
        done: usize,
        /// Total shards in the job.
        total: usize,
    },
    /// Daemon → client: terminal success, artifacts inline.
    Result {
        /// Job id.
        job: u64,
        /// Cache key the artifacts live under.
        key: String,
        /// True when served from the cache with zero simulation work.
        cached: bool,
        /// Scenario name — the artifact file stem.
        name: String,
        /// `<name>.csv` text.
        csv: String,
        /// `<name>.json` text.
        json: String,
        /// `<name>_ledger.csv` text.
        ledger_csv: String,
    },
    /// Daemon → client: answer to `status` / `cancel`.
    Report {
        /// Job id.
        job: u64,
        /// Job state: `queued | running | done | cancelled` or
        /// `failed: <reason>`.
        state: String,
        /// Daemon-wide realizations simulated so far (the cache
        /// tests' zero-work counter).
        sim_runs: u64,
    },
    /// Daemon → client: shutdown acknowledged, session over.
    Bye,
    /// Daemon → client: a frame could not be honored. The session
    /// stays open.
    Error {
        /// 1-based index of the offending input frame on this session
        /// (0 when the error is not tied to one input line).
        frame: u64,
        /// What went wrong, naming the offending field.
        message: String,
    },
}

fn num(x: usize) -> Json {
    Json::Num(x as f64)
}

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .as_u64()
        .ok_or_else(|| format!("frame field {key:?} must be an exact u64"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| format!("frame field {key:?} must be a non-negative integer"))
}

fn get_str(v: &Json, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .as_str()
        .ok_or_else(|| format!("frame field {key:?} must be a string"))?
        .to_string())
}

impl SessionFrame {
    /// Serialize as one line of compact JSON.
    pub fn encode(&self) -> String {
        let v = ("v", Json::Num(SESSION_PROTOCOL_VERSION as f64));
        let doc = match self {
            SessionFrame::Submit { spec, wait } => obj(vec![
                v,
                ("type", Json::Str("submit".into())),
                ("spec", Json::Str(spec.clone())),
                ("wait", Json::Bool(*wait)),
            ]),
            SessionFrame::Status { job } => obj(vec![
                v,
                ("type", Json::Str("status".into())),
                ("job", Json::Num(*job as f64)),
            ]),
            SessionFrame::ResultRequest { job } => obj(vec![
                v,
                ("type", Json::Str("result".into())),
                ("job", Json::Num(*job as f64)),
            ]),
            SessionFrame::Cancel { job } => obj(vec![
                v,
                ("type", Json::Str("cancel".into())),
                ("job", Json::Num(*job as f64)),
            ]),
            SessionFrame::Shutdown => obj(vec![v, ("type", Json::Str("shutdown".into()))]),
            SessionFrame::Accepted { job, key, cached } => obj(vec![
                v,
                ("type", Json::Str("accepted".into())),
                ("job", Json::Num(*job as f64)),
                ("key", Json::Str(key.clone())),
                ("cached", Json::Bool(*cached)),
            ]),
            SessionFrame::Progress { job, shard, done, total } => obj(vec![
                v,
                ("type", Json::Str("progress".into())),
                ("job", Json::Num(*job as f64)),
                ("shard", num(*shard)),
                ("done", num(*done)),
                ("total", num(*total)),
            ]),
            SessionFrame::Result { job, key, cached, name, csv, json, ledger_csv } => obj(vec![
                v,
                ("type", Json::Str("result".into())),
                ("job", Json::Num(*job as f64)),
                ("key", Json::Str(key.clone())),
                ("cached", Json::Bool(*cached)),
                ("name", Json::Str(name.clone())),
                (
                    "artifacts",
                    obj(vec![
                        ("csv", Json::Str(csv.clone())),
                        ("json", Json::Str(json.clone())),
                        ("ledger_csv", Json::Str(ledger_csv.clone())),
                    ]),
                ),
            ]),
            SessionFrame::Report { job, state, sim_runs } => obj(vec![
                v,
                ("type", Json::Str("report".into())),
                ("job", Json::Num(*job as f64)),
                ("state", Json::Str(state.clone())),
                ("sim_runs", Json::Num(*sim_runs as f64)),
            ]),
            SessionFrame::Bye => obj(vec![v, ("type", Json::Str("bye".into()))]),
            SessionFrame::Error { frame, message } => obj(vec![
                v,
                ("type", Json::Str("error".into())),
                ("frame", Json::Num(*frame as f64)),
                ("message", Json::Str(message.clone())),
            ]),
        };
        doc.to_string_compact()
    }

    /// Parse one session frame line; errors carry enough context to
    /// point at the offending field.
    pub fn decode(line: &str) -> Result<SessionFrame, String> {
        let doc = Json::parse(line.trim())
            .map_err(|e| format!("session protocol: not a JSON frame ({e})"))?;
        let version = get_u64(&doc, "v")
            .map_err(|e| format!("session protocol: {e} (missing version?)"))?;
        if version != SESSION_PROTOCOL_VERSION {
            return Err(format!(
                "session protocol: frame version {version} != supported \
                 {SESSION_PROTOCOL_VERSION} (v2 is the shard worker pipe; mixed binaries?)"
            ));
        }
        let ty = get_str(&doc, "type").map_err(|e| format!("session protocol: {e}"))?;
        let frame = match ty.as_str() {
            "submit" => SessionFrame::Submit {
                spec: get_str(&doc, "spec")?,
                wait: match doc.get("wait") {
                    Json::Null => true,
                    Json::Bool(b) => *b,
                    _ => return Err("frame field \"wait\" must be a boolean".to_string()),
                },
            },
            "status" => SessionFrame::Status { job: get_u64(&doc, "job")? },
            "cancel" => SessionFrame::Cancel { job: get_u64(&doc, "job")? },
            "shutdown" => SessionFrame::Shutdown,
            // `result` is a request (client → daemon) without artifacts
            // and the terminal answer (daemon → client) with them.
            "result" => {
                let job = get_u64(&doc, "job")?;
                let artifacts = doc.get("artifacts");
                if matches!(artifacts, Json::Null) {
                    SessionFrame::ResultRequest { job }
                } else {
                    SessionFrame::Result {
                        job,
                        key: get_str(&doc, "key")?,
                        cached: doc
                            .get("cached")
                            .as_bool()
                            .ok_or("frame field \"cached\" must be a boolean")?,
                        name: get_str(&doc, "name")?,
                        csv: get_str(artifacts, "csv")?,
                        json: get_str(artifacts, "json")?,
                        ledger_csv: get_str(artifacts, "ledger_csv")?,
                    }
                }
            }
            "accepted" => SessionFrame::Accepted {
                job: get_u64(&doc, "job")?,
                key: get_str(&doc, "key")?,
                cached: doc
                    .get("cached")
                    .as_bool()
                    .ok_or("frame field \"cached\" must be a boolean")?,
            },
            "progress" => SessionFrame::Progress {
                job: get_u64(&doc, "job")?,
                shard: get_usize(&doc, "shard")?,
                done: get_usize(&doc, "done")?,
                total: get_usize(&doc, "total")?,
            },
            "report" => SessionFrame::Report {
                job: get_u64(&doc, "job")?,
                state: get_str(&doc, "state")?,
                sim_runs: get_u64(&doc, "sim_runs")?,
            },
            "bye" => SessionFrame::Bye,
            "error" => SessionFrame::Error {
                frame: get_u64(&doc, "frame")?,
                message: get_str(&doc, "message")?,
            },
            other => {
                return Err(format!(
                    "session protocol: unknown frame type {other:?} (expected submit | status \
                     | result | cancel | shutdown | accepted | progress | report | bye | error)"
                ))
            }
        };
        Ok(frame)
    }
}

/// Why a session loop returned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SessionEnd {
    /// The client went away (EOF or a failed write). Jobs it submitted
    /// keep running; their results land in the cache.
    Disconnect,
    /// The client asked the daemon to shut down (queue already
    /// drained, `bye` sent).
    Shutdown,
}

fn send(writer: &mut impl Write, frame: &SessionFrame) -> std::io::Result<()> {
    writeln!(writer, "{}", frame.encode())?;
    writer.flush()
}

/// Drive one client session over any line stream (stdio or one TCP
/// connection). Never panics and never returns on malformed input —
/// only on EOF, a dead client, or an honored shutdown frame.
pub fn serve_session(
    daemon: &Daemon,
    reader: impl BufRead,
    mut writer: impl Write,
) -> SessionEnd {
    for (lineno, line) in reader.lines().enumerate() {
        let frame_no = (lineno + 1) as u64;
        let line = match line {
            Ok(l) => l,
            Err(_) => return SessionEnd::Disconnect,
        };
        if line.trim().is_empty() {
            continue;
        }
        let refuse = |message: String| SessionFrame::Error { frame: frame_no, message };
        let frame = match SessionFrame::decode(&line) {
            Ok(f) => f,
            Err(e) => {
                if send(&mut writer, &refuse(format!("frame {frame_no}: {e}"))).is_err() {
                    return SessionEnd::Disconnect;
                }
                continue;
            }
        };
        let answer = match frame {
            SessionFrame::Submit { spec, wait } => {
                match handle_submit(daemon, &spec, wait, frame_no, &mut writer) {
                    Ok(()) => continue,
                    Err(SubmitEnd::Refused(message)) => refuse(message),
                    Err(SubmitEnd::Disconnect) => return SessionEnd::Disconnect,
                }
            }
            SessionFrame::Status { job } => match daemon.queue.state_label(job) {
                Some(state) => SessionFrame::Report { job, state, sim_runs: sim_runs() },
                None => refuse(format!("frame {frame_no}: unknown job {job}")),
            },
            SessionFrame::ResultRequest { job } => match daemon.queue.result_of(job) {
                Some((result, cached)) => SessionFrame::Result {
                    job,
                    key: result.key.clone(),
                    cached,
                    name: result.name.clone(),
                    csv: result.csv.clone(),
                    json: result.json.clone(),
                    ledger_csv: result.ledger_csv.clone(),
                },
                None => refuse(format!(
                    "frame {frame_no}: job {job} has no result ({})",
                    daemon
                        .queue
                        .state_label(job)
                        .unwrap_or_else(|| "unknown job".to_string())
                )),
            },
            SessionFrame::Cancel { job } => match daemon.queue.cancel(job) {
                Ok(()) => SessionFrame::Report {
                    job,
                    state: "cancelled".to_string(),
                    sim_runs: sim_runs(),
                },
                Err(e) => refuse(format!("frame {frame_no}: {e}")),
            },
            SessionFrame::Shutdown => {
                daemon.queue.drain();
                let _ = send(&mut writer, &SessionFrame::Bye);
                return SessionEnd::Shutdown;
            }
            // Daemon → client frames arriving at the daemon.
            other => refuse(format!(
                "frame {frame_no}: {} is a daemon-to-client frame",
                frame_type_name(&other)
            )),
        };
        if send(&mut writer, &answer).is_err() {
            return SessionEnd::Disconnect;
        }
    }
    SessionEnd::Disconnect
}

enum SubmitEnd {
    /// Answer with an error frame, session continues.
    Refused(String),
    /// The client is gone.
    Disconnect,
}

/// Handle one submit frame: validate, enqueue, and (for `wait`
/// submits) forward the job's event stream until the terminal frame.
fn handle_submit(
    daemon: &Daemon,
    spec: &str,
    wait: bool,
    frame_no: u64,
    writer: &mut impl Write,
) -> Result<(), SubmitEnd> {
    let sc = Scenario::parse_str(spec)
        .and_then(|sc| sc.validate().map(|()| sc))
        .map_err(|e| SubmitEnd::Refused(format!("frame {frame_no}: submit: {e}")))?;
    let (job, key, cached, events) = daemon
        .queue
        .submit(sc, wait)
        .map_err(|e| SubmitEnd::Refused(format!("frame {frame_no}: submit: {e}")))?;
    send(writer, &SessionFrame::Accepted { job, key, cached })
        .map_err(|_| SubmitEnd::Disconnect)?;
    let Some(events) = events else {
        return Ok(());
    };
    for event in events {
        let frame = match event {
            JobEvent::Progress { shard, done, total } => {
                SessionFrame::Progress { job, shard, done, total }
            }
            JobEvent::Done { result, cached } => {
                let frame = SessionFrame::Result {
                    job,
                    key: result.key.clone(),
                    cached,
                    name: result.name.clone(),
                    csv: result.csv.clone(),
                    json: result.json.clone(),
                    ledger_csv: result.ledger_csv.clone(),
                };
                send(writer, &frame).map_err(|_| SubmitEnd::Disconnect)?;
                return Ok(());
            }
            JobEvent::Failed { message } => SessionFrame::Error {
                frame: frame_no,
                message: format!("frame {frame_no}: job {job} failed: {message}"),
            },
        };
        let terminal = matches!(frame, SessionFrame::Error { .. });
        send(writer, &frame).map_err(|_| SubmitEnd::Disconnect)?;
        if terminal {
            return Ok(());
        }
    }
    // All senders dropped without a terminal event (should not happen).
    Err(SubmitEnd::Refused(format!(
        "frame {frame_no}: job {job} event stream ended without a result"
    )))
}

fn frame_type_name(f: &SessionFrame) -> &'static str {
    match f {
        SessionFrame::Submit { .. } => "submit",
        SessionFrame::Status { .. } => "status",
        SessionFrame::ResultRequest { .. } => "result-request",
        SessionFrame::Cancel { .. } => "cancel",
        SessionFrame::Shutdown => "shutdown",
        SessionFrame::Accepted { .. } => "accepted",
        SessionFrame::Progress { .. } => "progress",
        SessionFrame::Result { .. } => "result",
        SessionFrame::Report { .. } => "report",
        SessionFrame::Bye => "bye",
        SessionFrame::Error { .. } => "error",
    }
}

// ---------------------------------------------------------------------------
// Client side (`scenario run --via <addr>`, `serve --stop <addr>`).

fn connect(addr: &str) -> Result<(BufReader<TcpStream>, TcpStream), String> {
    let stream = TcpStream::connect(addr)
        .map_err(|e| format!("connecting to serve daemon at {addr}: {e}"))?;
    let writer = stream
        .try_clone()
        .map_err(|e| format!("cloning the session stream: {e}"))?;
    Ok((BufReader::new(stream), writer))
}

/// Submit a scenario to a resident daemon and stream it to completion,
/// writing the artifact triple into `out_dir` byte-identically to a
/// local `scenario run`. Prints one `cache hit` / `cache miss` line
/// (the CI smoke gate greps for it).
pub fn run_via(
    addr: &str,
    sc: &Scenario,
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<(), String> {
    let (reader, mut writer) = connect(addr)?;
    let submit = SessionFrame::Submit { spec: sc.to_ini_string(), wait: true };
    send(&mut writer, &submit).map_err(|e| format!("sending the submit frame: {e}"))?;
    for line in reader.lines() {
        let line = line.map_err(|e| format!("reading from the daemon: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        match SessionFrame::decode(&line).map_err(|e| format!("daemon sent {e}"))? {
            SessionFrame::Accepted { job, key, cached } => {
                if !quiet {
                    println!(
                        "serve: job {job} accepted (key {}…, {})",
                        key.get(..12).unwrap_or(&key),
                        if cached { "cached" } else { "queued" }
                    );
                }
            }
            SessionFrame::Progress { job, shard, done, total } => {
                if !quiet {
                    println!("serve: job {job} shard {shard} finished ({done}/{total})");
                }
            }
            SessionFrame::Result { job, key, cached, name, csv, json, ledger_csv } => {
                println!(
                    "serve: job {job} {} (key {}…)",
                    if cached { "cache hit" } else { "cache miss" },
                    key.get(..12).unwrap_or(&key),
                );
                if let Some(dir) = out_dir {
                    std::fs::create_dir_all(dir)
                        .map_err(|e| format!("creating {dir}: {e}"))?;
                    std::fs::write(format!("{dir}/{name}.csv"), csv)
                        .map_err(|e| format!("writing {dir}/{name}.csv: {e}"))?;
                    std::fs::write(format!("{dir}/{name}.json"), json)
                        .map_err(|e| format!("writing {dir}/{name}.json: {e}"))?;
                    std::fs::write(format!("{dir}/{name}_ledger.csv"), ledger_csv)
                        .map_err(|e| format!("writing {dir}/{name}_ledger.csv: {e}"))?;
                    if !quiet {
                        println!("serve: wrote {dir}/{name}.csv, .json and _ledger.csv");
                    }
                }
                return Ok(());
            }
            SessionFrame::Error { frame, message } => {
                return Err(format!("serve daemon refused (frame {frame}): {message}"))
            }
            other => {
                return Err(format!(
                    "unexpected {} frame from the daemon",
                    frame_type_name(&other)
                ))
            }
        }
    }
    Err("daemon closed the session before sending a result".to_string())
}

/// Ask a resident daemon to drain its queue and stop.
pub fn stop_via(addr: &str) -> Result<(), String> {
    let (reader, mut writer) = connect(addr)?;
    send(&mut writer, &SessionFrame::Shutdown)
        .map_err(|e| format!("sending the shutdown frame: {e}"))?;
    for line in reader.lines() {
        let line = line.map_err(|e| format!("reading from the daemon: {e}"))?;
        if line.trim().is_empty() {
            continue;
        }
        match SessionFrame::decode(&line).map_err(|e| format!("daemon sent {e}"))? {
            SessionFrame::Bye => {
                println!("serve: daemon at {addr} drained and stopped");
                return Ok(());
            }
            SessionFrame::Error { frame, message } => {
                return Err(format!("serve daemon refused (frame {frame}): {message}"))
            }
            _ => continue,
        }
    }
    Err("daemon closed the session without acknowledging shutdown".to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn session_frames_roundtrip() {
        let frames = vec![
            SessionFrame::Submit { spec: "[scenario]\nname = x\n".into(), wait: false },
            SessionFrame::Status { job: 7 },
            SessionFrame::ResultRequest { job: 7 },
            SessionFrame::Cancel { job: 9 },
            SessionFrame::Shutdown,
            SessionFrame::Accepted { job: 1, key: "ab".repeat(32), cached: true },
            SessionFrame::Progress { job: 1, shard: 2, done: 3, total: 4 },
            SessionFrame::Result {
                job: 1,
                key: "cd".repeat(32),
                cached: false,
                name: "paper-10-node".into(),
                csv: "x,y\n1,2\n".into(),
                json: "{}\n".into(),
                ledger_csv: "src,dst,scalars,bits\n".into(),
            },
            SessionFrame::Report { job: 1, state: "running".into(), sim_runs: 42 },
            SessionFrame::Bye,
            SessionFrame::Error { frame: 3, message: "boom".into() },
        ];
        for frame in frames {
            let line = frame.encode();
            assert!(!line.contains('\n'), "frame spans lines: {line}");
            let back = SessionFrame::decode(&line).unwrap();
            assert_eq!(frame_type_name(&frame), frame_type_name(&back));
            assert_eq!(line, back.encode(), "unstable reencode for {line}");
        }
    }

    #[test]
    fn session_decode_rejects_with_context() {
        // The worker-pipe version is not a session version.
        let err = SessionFrame::decode("{\"v\":2,\"type\":\"submit\",\"spec\":\"\"}").unwrap_err();
        assert!(err.contains("version 2"), "{err}");
        let err = SessionFrame::decode("{\"v\":3,\"type\":\"warp\"}").unwrap_err();
        assert!(err.contains("warp"), "{err}");
        let err = SessionFrame::decode("{\"v\":3,\"type\":\"status\"}").unwrap_err();
        assert!(err.contains("job"), "{err}");
        // A counter past 2^53 cannot ride in an f64 frame field.
        let err = SessionFrame::decode("{\"v\":3,\"type\":\"status\",\"job\":9007199254740994}")
            .unwrap_err();
        assert!(err.contains("job"), "{err}");
        let err =
            SessionFrame::decode("{\"v\":3,\"type\":\"submit\",\"spec\":\"\",\"wait\":1}")
                .unwrap_err();
        assert!(err.contains("wait"), "{err}");
    }
}
