//! Layer-3 coordinator: the paper's system surface.
//!
//! * [`bus`] — typed partial-vector messages and per-node mailboxes with
//!   delivery accounting (the wire protocol of Alg. 1).
//! * [`agent`] — a per-node DCD agent state machine speaking that
//!   protocol; N agents + the bus reproduce exactly one vectorised DCD
//!   iteration (property-tested), validating the message protocol.
//! * [`round`] — synchronous round scheduler: drives any
//!   [`Algorithm`](crate::algorithms::Algorithm)
//!   over streaming data, records MSD traces and communication costs
//!   (Experiments 1 and 2).
//! * [`wsn`] — energy-aware event-driven scheduler (virtual time): each
//!   node duty-cycles per the ENO model and updates asynchronously with
//!   the freshest available neighbour state (Experiment 3); carries the
//!   same [`impairments`] layer as the round scheduler, so nodes gate
//!   on charge *and* events and every exchange is billed in the
//!   directional ledger (DESIGN.md §9).
//! * [`runner`] — Monte-Carlo orchestration over both engines: the
//!   message-level rust engine and the AOT-compiled xla engine.
//! * [`lanes`] — the run-batched lane engine (DESIGN.md §14): B
//!   realizations advanced in SoA lockstep per scheduler pass,
//!   bit-identical per lane to the scalar round scheduler.
//! * [`impairments`] — the link-impairment layer (per-edge erasures,
//!   probabilistic / event-triggered communication gating, quantized
//!   state) that the round scheduler wraps around any algorithm; the
//!   scenario subsystem (DESIGN.md §4) configures it declaratively.
//!
//! Scheduling is deterministic (seeded virtual time) rather than
//! wall-clock threaded: on this single-core target determinism buys
//! reproducible experiments and exact engine-equivalence tests; a
//! thread-per-agent mode over the same bus is exercised in
//! `rust/tests/integration.rs` to validate the protocol under real
//! concurrency.

pub mod agent;
pub mod bus;
pub mod dynamics;
pub mod impairments;
pub mod lanes;
pub mod round;
pub mod runner;
pub mod wsn;

pub use dynamics::{DynamicsConfig, DynamicsState};
pub use impairments::{AdaptivePolicy, DropModel, Gating, LinkImpairments, LinkStateStats};
pub use lanes::LaneCount;
pub use round::{RoundScheduler, RunResult};
pub use runner::{MonteCarlo, McResult, SchedulerOptions};
pub use wsn::{WsnConfig, WsnResult, WsnSimulation};
