//! Energy-aware WSN scheduler (Experiment 3, Fig. 4).
//!
//! Event-driven simulation over virtual time: every node duty-cycles per
//! the ENO model (`energy::NodeEnergy`); when a node wakes *and* its
//! capacitor is above V_ref it performs one asynchronous algorithm
//! update using the freshest available neighbour state (the standard
//! asynchronous-diffusion model, cf. paper refs. [10], [15]), spends the
//! Table I active-phase energy, then sleeps for the duration given by
//! eq. (70). Nodes below V_ref skip the update and recharge.
//!
//! Outputs match Fig. 4: network MSD vs virtual time (right) and mean
//! sleep duration / harvested energy vs time (center).

use crate::algorithms::NetworkConfig;
use crate::datamodel::DataModel;
use crate::energy::{ActiveEnergy, EnergyParams, NodeEnergy};
use crate::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Which algorithm runs on the motes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WsnAlgo {
    /// ATC diffusion LMS (C ≠ I): gradients + estimates, 2L per link.
    Diffusion,
    /// Reduced-communication diffusion [29].
    Rcd { m_links: usize },
    /// Partial-diffusion LMS [32].
    Partial { m: usize },
    /// Compressed diffusion LMS (Q = I).
    Cd { m: usize },
    /// Doubly-compressed diffusion LMS; `combine` selects A = I or A ≠ I.
    Dcd { m: usize, m_grad: usize, combine: bool },
}

impl WsnAlgo {
    /// Display label used in figure legends and result-CSV headers.
    pub fn label(&self) -> String {
        match self {
            WsnAlgo::Diffusion => "diffusion-lms".into(),
            WsnAlgo::Rcd { .. } => "rcd".into(),
            WsnAlgo::Partial { .. } => "partial-diffusion".into(),
            WsnAlgo::Cd { .. } => "cd".into(),
            WsnAlgo::Dcd { combine, .. } => {
                if *combine {
                    "dcd (A!=I)".into()
                } else {
                    "dcd (A=I)".into()
                }
            }
        }
    }

    /// Table I active-phase energy e_a (J) for one activation.
    pub fn active_energy(&self) -> f64 {
        match self {
            WsnAlgo::Diffusion => ActiveEnergy::DIFFUSION.0,
            WsnAlgo::Rcd { .. } => ActiveEnergy::RCD.0,
            WsnAlgo::Partial { .. } => ActiveEnergy::PARTIAL.0,
            WsnAlgo::Cd { .. } => ActiveEnergy::CD.0,
            WsnAlgo::Dcd { .. } => ActiveEnergy::DCD.0,
        }
    }
}

/// WSN experiment configuration.
#[derive(Clone)]
pub struct WsnConfig {
    /// Graph, combiners and step sizes of the network.
    pub net: NetworkConfig,
    /// Which algorithm runs on the motes.
    pub algo: WsnAlgo,
    /// ENO energy-model constants (Table I).
    pub energy: EnergyParams,
    /// Per-node harvest scales (lighting levels on the hill).
    pub harvest_scale: Vec<f64>,
    /// Virtual-time horizon (seconds).
    pub duration: f64,
    /// MSD/telemetry sampling interval (seconds).
    pub sample_dt: f64,
}

/// Time series produced by the simulation.
#[derive(Debug, Clone)]
pub struct WsnResult {
    /// Sample times (s).
    pub time: Vec<f64>,
    /// Network MSD (linear) at each sample time.
    pub msd: Vec<f64>,
    /// Mean sleep duration chosen during each interval (s).
    pub mean_sleep: Vec<f64>,
    /// Mean harvested energy per cycle during each interval (J).
    pub mean_harvest: Vec<f64>,
    /// Total node activations.
    pub activations: u64,
    /// Activations skipped for lack of charge.
    pub skipped: u64,
}

/// The event-driven simulation.
pub struct WsnSimulation {
    cfg: WsnConfig,
    model: DataModel,
}

impl WsnSimulation {
    /// Assemble a simulation; panics on a node-count mismatch between
    /// the network, the harvest scales and the data model.
    pub fn new(cfg: WsnConfig, model: DataModel) -> Self {
        assert_eq!(cfg.net.n_nodes(), model.n_nodes);
        assert_eq!(cfg.harvest_scale.len(), model.n_nodes);
        Self { cfg, model }
    }

    /// One full realization over the virtual-time horizon: every node
    /// duty-cycles per the ENO model and the sampled telemetry/MSD land
    /// in the returned [`WsnResult`]. Deterministic in `seed` (the
    /// Monte-Carlo drivers use per-run seeds `base + r·7919 + 1`).
    pub fn run(&self, seed: u64) -> WsnResult {
        let n = self.model.n_nodes;
        let l = self.model.dim;
        let mut rng = Pcg64::new(seed, 0);
        let mut energies: Vec<NodeEnergy> = (0..n)
            .map(|k| NodeEnergy::new(self.cfg.energy.clone(), self.cfg.harvest_scale[k]))
            .collect();
        let mut w = vec![0.0f64; n * l];
        let mut scratch = Vec::new();
        let mut mask32 = vec![0f32; l];
        // Reused regressor buffers (no allocation per activation; §Perf).
        let mut uk_buf = vec![0.0f64; l];
        let mut un_buf = vec![0.0f64; l];

        // Event queue ordered by wake time (f64 as ordered bits).
        let mut queue: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for k in 0..n {
            // Small jitter avoids artificial phase lock.
            let t0 = rng.next_f64() * 0.5;
            queue.push(Reverse((time_key(t0), k)));
        }

        let n_samples = (self.cfg.duration / self.cfg.sample_dt).ceil() as usize;
        let mut time = Vec::with_capacity(n_samples);
        let mut msd = Vec::with_capacity(n_samples);
        let mut mean_sleep = Vec::with_capacity(n_samples);
        let mut mean_harvest = Vec::with_capacity(n_samples);
        let mut next_sample = self.cfg.sample_dt;
        let (mut sleep_acc, mut sleep_cnt) = (0.0, 0u64);
        let (mut harv_acc, mut harv_cnt) = (0.0, 0u64);
        let mut activations = 0u64;
        let mut skipped = 0u64;

        while let Some(Reverse((tk, k))) = queue.pop() {
            let now = key_time(tk);
            if now > self.cfg.duration {
                break;
            }
            // Flush MSD samples up to `now` (state piecewise constant).
            while next_sample <= now && time.len() < n_samples {
                time.push(next_sample);
                msd.push(network_msd(&w, &self.model.wo));
                mean_sleep.push(if sleep_cnt > 0 { sleep_acc / sleep_cnt as f64 } else { 0.0 });
                mean_harvest.push(if harv_cnt > 0 { harv_acc / harv_cnt as f64 } else { 0.0 });
                sleep_acc = 0.0;
                sleep_cnt = 0;
                harv_acc = 0.0;
                harv_cnt = 0;
                next_sample += self.cfg.sample_dt;
            }

            let e_a = if energies[k].can_activate() {
                activations += 1;
                self.update_node(k, &mut w, &mut rng, &mut scratch, &mut mask32,
                                 &mut uk_buf, &mut un_buf);
                self.cfg.algo.active_energy()
            } else {
                skipped += 1;
                0.0
            };
            harv_acc += energies[k].harvest(now, &mut rng);
            harv_cnt += 1;
            let t_s = energies[k].cycle(e_a, now, &mut rng);
            sleep_acc += t_s;
            sleep_cnt += 1;
            queue.push(Reverse((time_key(now + t_s), k)));
        }
        // Trailing samples.
        while time.len() < n_samples {
            time.push(next_sample);
            msd.push(network_msd(&w, &self.model.wo));
            mean_sleep.push(if sleep_cnt > 0 { sleep_acc / sleep_cnt as f64 } else { 0.0 });
            mean_harvest.push(if harv_cnt > 0 { harv_acc / harv_cnt as f64 } else { 0.0 });
            sleep_acc = 0.0;
            sleep_cnt = 0;
            harv_acc = 0.0;
            harv_cnt = 0;
            next_sample += self.cfg.sample_dt;
        }

        WsnResult { time, msd, mean_sleep, mean_harvest, activations, skipped }
    }

    /// One asynchronous update of node k using the freshest neighbour
    /// state. Fresh measurements are drawn at poll time for every node
    /// involved (streaming data).
    #[allow(clippy::too_many_arguments)]
    fn update_node(
        &self,
        k: usize,
        w: &mut [f64],
        rng: &mut Pcg64,
        scratch: &mut Vec<usize>,
        mask32: &mut [f32],
        uk_buf: &mut [f64],
        un_buf: &mut [f64],
    ) {
        let net = &self.cfg.net;
        let l = self.model.dim;
        let mu = net.mu[k];
        let dk = self.sample_node_into(k, rng, uk_buf);
        let uk = &*uk_buf;
        let wk: Vec<f64> = w[k * l..(k + 1) * l].to_vec();
        let e_self = dk - dot(uk, &wk);

        match self.cfg.algo {
            WsnAlgo::Diffusion => {
                // psi_k from own + neighbour gradients evaluated at w_k.
                let mut psi: Vec<f64> = wk.clone();
                let c_kk = net.c[(k, k)];
                for j in 0..l {
                    psi[j] += mu * c_kk * uk[j] * e_self;
                }
                for &nb in net.graph.neighbors(k) {
                    let c_lk = net.c[(nb, k)];
                    let dn = self.sample_node_into(nb, rng, un_buf);
                    let un = &*un_buf;
                    let e = dn - dot(un, &wk);
                    for j in 0..l {
                        psi[j] += mu * c_lk * un[j] * e;
                    }
                }
                // Combine with neighbours' current estimates.
                let a_kk = net.a[(k, k)];
                let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
                for &nb in net.graph.neighbors(k) {
                    let a_lk = net.a[(nb, k)];
                    for j in 0..l {
                        out[j] += a_lk * w[nb * l + j];
                    }
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Rcd { m_links } => {
                let mut psi: Vec<f64> = wk.clone();
                for j in 0..l {
                    psi[j] += mu * uk[j] * e_self;
                }
                let nbrs = net.graph.neighbors(k);
                let m = m_links.min(nbrs.len());
                rng.sample_indices(nbrs.len(), m, scratch);
                let mut h_kk = 1.0;
                let mut out = vec![0.0; l];
                for &idx in scratch.iter() {
                    let nb = nbrs[idx];
                    let a_lk = net.a[(nb, k)];
                    h_kk -= a_lk;
                    for j in 0..l {
                        out[j] += a_lk * w[nb * l + j];
                    }
                }
                for j in 0..l {
                    out[j] += h_kk * psi[j];
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Partial { m } => {
                let mut psi: Vec<f64> = wk.clone();
                for j in 0..l {
                    psi[j] += mu * uk[j] * e_self;
                }
                let a_kk = net.a[(k, k)];
                let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
                for &nb in net.graph.neighbors(k) {
                    let a_lk = net.a[(nb, k)];
                    rng.fill_mask(mask32, m, scratch);
                    for j in 0..l {
                        let hl = mask32[j] as f64;
                        out[j] += a_lk * (hl * w[nb * l + j] + (1.0 - hl) * psi[j]);
                    }
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Cd { m } => {
                self.dcd_like_update(k, w, rng, scratch, mask32, uk_buf, un_buf, m, l, true, false);
            }
            WsnAlgo::Dcd { m, m_grad, combine } => {
                self.dcd_like_update(k, w, rng, scratch, mask32, uk_buf, un_buf, m, m_grad, false, combine);
            }
        }
    }

    /// Shared CD/DCD async update. `q_full` ⇒ full gradients (CD);
    /// `combine` ⇒ A ≠ I (masked-estimate combine), else A = I.
    #[allow(clippy::too_many_arguments)]
    fn dcd_like_update(
        &self,
        k: usize,
        w: &mut [f64],
        rng: &mut Pcg64,
        scratch: &mut Vec<usize>,
        mask32: &mut [f32],
        uk_buf: &mut [f64],
        un_buf: &mut [f64],
        m: usize,
        m_grad: usize,
        q_full: bool,
        combine: bool,
    ) {
        let net = &self.cfg.net;
        let l = self.model.dim;
        let mu = net.mu[k];
        let dk = self.sample_node_into(k, rng, uk_buf);
        let uk = &*uk_buf;
        let wk: Vec<f64> = w[k * l..(k + 1) * l].to_vec();
        let e_self = dk - dot(uk, &wk);

        // H_k for this activation.
        let mut hk = vec![0.0f64; l];
        rng.fill_mask(mask32, m, scratch);
        for j in 0..l {
            hk[j] = mask32[j] as f64;
        }

        let mut psi: Vec<f64> = wk.clone();
        let c_kk = net.c[(k, k)];
        for j in 0..l {
            psi[j] += mu * c_kk * uk[j] * e_self;
        }
        // Cache (neighbour, its H_l-masked current estimate) for combine.
        let mut cached: Vec<(usize, Vec<f64>)> = Vec::new();
        for &nb in net.graph.neighbors(k) {
            let c_lk = net.c[(nb, k)];
            let dn = self.sample_node_into(nb, rng, un_buf);
            let un = &*un_buf;
            // Filled point at the neighbour: H_k w_k + (1 - H_k) w_l.
            let mut e = dn;
            for j in 0..l {
                let filled = hk[j] * wk[j] + (1.0 - hk[j]) * w[nb * l + j];
                e -= un[j] * filled;
            }
            // Q_l mask.
            let mut ql = vec![1.0f64; l];
            if !q_full {
                rng.fill_mask(mask32, m_grad, scratch);
                for j in 0..l {
                    ql[j] = mask32[j] as f64;
                }
            }
            if c_lk != 0.0 {
                for j in 0..l {
                    let g = ql[j] * (un[j] * e) + (1.0 - ql[j]) * (uk[j] * e_self);
                    psi[j] += mu * c_lk * g;
                }
            }
            if combine {
                // The neighbour's estimate-mask for this exchange.
                rng.fill_mask(mask32, m, scratch);
                let masked: Vec<f64> = (0..l).map(|j| mask32[j] as f64).collect();
                cached.push((nb, masked));
            }
        }

        if combine {
            let a_kk = net.a[(k, k)];
            let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
            for (nb, hl) in &cached {
                let a_lk = net.a[(*nb, k)];
                for j in 0..l {
                    out[j] += a_lk * (hl[j] * w[nb * l + j] + (1.0 - hl[j]) * psi[j]);
                }
            }
            w[k * l..(k + 1) * l].copy_from_slice(&out);
        } else {
            w[k * l..(k + 1) * l].copy_from_slice(&psi);
        }
    }

    /// Fill `u` with a fresh regressor for node k and return d (hot path:
    /// caller provides the buffer, no allocation per poll).
    fn sample_node_into(&self, k: usize, rng: &mut Pcg64, u: &mut [f64]) -> f64 {
        let su = self.model.sigma_u2[k].sqrt();
        let sv = self.model.sigma_v2[k].sqrt();
        let mut dot_wo = 0.0;
        for (x, &woj) in u.iter_mut().zip(self.model.wo.iter()) {
            *x = su * rng.next_gaussian();
            dot_wo += *x * woj;
        }
        dot_wo + sv * rng.next_gaussian()
    }
}

fn network_msd(w: &[f64], wo: &[f64]) -> f64 {
    let l = wo.len();
    let n = w.len() / l;
    let mut total = 0.0;
    for k in 0..n {
        for j in 0..l {
            let d = wo[j] - w[k * l + j];
            total += d * d;
        }
    }
    total / n as f64
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

/// Order-preserving f64→u64 key for the event queue (times are >= 0).
#[inline]
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

#[inline]
fn key_time(k: u64) -> f64 {
    f64::from_bits(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn small_cfg(algo: WsnAlgo, duration: f64) -> (WsnConfig, DataModel) {
        let mut rng = Pcg64::new(42, 0);
        let n = 8;
        let l = 6;
        let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
        let graph = Graph::ring(n, 2);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l };
        let cfg = WsnConfig {
            net,
            algo,
            energy: EnergyParams::default(),
            harvest_scale: (0..n).map(|k| 0.4 + 0.05 * k as f64).collect(),
            duration,
            sample_dt: duration / 50.0,
        };
        (cfg, model)
    }

    #[test]
    fn wsn_msd_decreases_for_all_algorithms() {
        for algo in [
            WsnAlgo::Diffusion,
            WsnAlgo::Rcd { m_links: 2 },
            WsnAlgo::Partial { m: 2 },
            WsnAlgo::Cd { m: 4 },
            WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false },
            WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true },
        ] {
            let (cfg, model) = small_cfg(algo, 2000.0);
            let sim = WsnSimulation::new(cfg, model);
            let res = sim.run(1);
            assert_eq!(res.time.len(), 50);
            let first = res.msd[5];
            let last = *res.msd.last().unwrap();
            assert!(
                last < first,
                "{}: msd {first} -> {last}",
                algo.label()
            );
            assert!(res.activations > 0);
        }
    }

    #[test]
    fn sleep_durations_within_bounds() {
        let (cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 3000.0);
        let sim = WsnSimulation::new(cfg, model);
        let res = sim.run(3);
        for &s in &res.mean_sleep {
            assert!(s <= 300.0 + 1e-9, "sleep {s}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, model) = small_cfg(WsnAlgo::Cd { m: 3 }, 500.0);
        let sim = WsnSimulation::new(cfg.clone(), model.clone());
        let r1 = sim.run(7);
        let sim2 = WsnSimulation::new(cfg, model);
        let r2 = sim2.run(7);
        assert_eq!(r1.msd, r2.msd);
        assert_eq!(r1.activations, r2.activations);
    }

    #[test]
    fn lighter_algorithm_gets_more_activations() {
        let (cfg_d, model_d) = small_cfg(WsnAlgo::Diffusion, 4000.0);
        let (cfg_c, model_c) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 4000.0);
        let heavy = WsnSimulation::new(cfg_d, model_d).run(11);
        let light = WsnSimulation::new(cfg_c, model_c).run(11);
        assert!(
            light.activations > heavy.activations,
            "light {} heavy {}",
            light.activations,
            heavy.activations
        );
    }
}
