//! Energy-aware WSN scheduler (Experiment 3, Fig. 4).
//!
//! Event-driven simulation over virtual time: every node duty-cycles per
//! the ENO model (`energy::NodeEnergy`); when a node wakes *and* its
//! capacitor is above V_ref it performs one asynchronous algorithm
//! update using the freshest available neighbour state (the standard
//! asynchronous-diffusion model, cf. paper refs. [10], [15]), spends the
//! Table I active-phase energy, then sleeps for the duration given by
//! eq. (70). Nodes below V_ref skip the update and recharge.
//!
//! # Link impairments and the ledger
//!
//! The simulation carries the same [`LinkImpairments`] layer as the
//! synchronous round scheduler, so energy-harvesting scenarios gate on
//! charge *and* events (DESIGN.md §9):
//!
//! * **Gating** — on top of the charge gate (V ≥ V_ref), a woken node
//!   consults the transmit gate (`prob:p` duty-cycling or `event:δ`
//!   change detection against its last-broadcast state). A gated node
//!   spends its active phase on a purely local LMS update: it polls no
//!   neighbour, transmits nothing, and is billed nothing.
//! * **Drops** — each neighbour exchange of a transmitting node is
//!   erased independently with `drop_prob`. The erased party's
//!   contribution falls back to the node's own information (the
//!   completion rule of eqs. (11)–(12)), estimate frames stay billed
//!   (transmitter pays), and solicited gradient replies whose request
//!   leg was erased are never transmitted or billed.
//! * **Quantization** — the updated state is snapped to the Δ grid and
//!   payloads are billed at the grid-index width.
//! * **Radio energy** — with a non-zero [`RadioEnergy`], every
//!   transmitting activation debits the *activating* node's capacitor
//!   with the exchange's radio joules on top of `e_a`: its own frames
//!   at the tx rate plus the frames its neighbours send it at the rx
//!   rate (neighbours are wake-on-radio responders; DESIGN.md §13).
//!   The billed bits come from integer ledger snapshots around the
//!   exchange, so the debit consumes no randomness, and the zero-cost
//!   default adds a literal `+ 0.0` — the exact legacy trajectory.
//!
//! All impairment decisions draw from a dedicated PCG64 stream
//! (`seed ^ LINK_SEED_SALT`), so the ideal configuration replays the
//! exact legacy trajectory, and billed bits are deterministic for any
//! worker-thread or shard layout (integer ledger counters; tested).
//! Dropped exchanges keep **draw parity** with the ideal path — every
//! data-stream RNG draw still happens, only its application is gated —
//! so a lossy run keeps the ideal run's activation schedule and its
//! bill reconciles exactly with the legacy transmitter-only bill
//! (`scalars + suppressed_scalars`). A *gated* activation genuinely
//! does less work (no neighbour measurements), so gating legitimately
//! changes the trajectory.
//!
//! Outputs match Fig. 4: network MSD vs virtual time (right) and mean
//! sleep duration / harvested energy vs time (center), plus the
//! directional communication ledger of DESIGN.md §9.

use crate::algorithms::NetworkConfig;
use crate::datamodel::DataModel;
use crate::energy::{
    ActiveEnergy, CommLedger, CommMeter, EnergyParams, NodeEnergy, Purpose, RadioEnergy,
};
use crate::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::impairments::{quantize_in_place, DropModel, Gating, LinkImpairments, LINK_SEED_SALT};

/// Which algorithm runs on the motes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WsnAlgo {
    /// ATC diffusion LMS (C ≠ I): gradients + estimates, 2L per link.
    Diffusion,
    /// Reduced-communication diffusion [29].
    Rcd { m_links: usize },
    /// Partial-diffusion LMS [32].
    Partial { m: usize },
    /// Compressed diffusion LMS (Q = I).
    Cd { m: usize },
    /// Doubly-compressed diffusion LMS; `combine` selects A = I or A ≠ I.
    Dcd { m: usize, m_grad: usize, combine: bool },
}

impl WsnAlgo {
    /// Display label used in figure legends and result-CSV headers.
    pub fn label(&self) -> String {
        match self {
            WsnAlgo::Diffusion => "diffusion-lms".into(),
            WsnAlgo::Rcd { .. } => "rcd".into(),
            WsnAlgo::Partial { .. } => "partial-diffusion".into(),
            WsnAlgo::Cd { .. } => "cd".into(),
            WsnAlgo::Dcd { combine, .. } => {
                if *combine {
                    "dcd (A!=I)".into()
                } else {
                    "dcd (A=I)".into()
                }
            }
        }
    }

    /// Table I active-phase energy e_a (J) for one activation.
    pub fn active_energy(&self) -> f64 {
        match self {
            WsnAlgo::Diffusion => ActiveEnergy::DIFFUSION.0,
            WsnAlgo::Rcd { .. } => ActiveEnergy::RCD.0,
            WsnAlgo::Partial { .. } => ActiveEnergy::PARTIAL.0,
            WsnAlgo::Cd { .. } => ActiveEnergy::CD.0,
            WsnAlgo::Dcd { .. } => ActiveEnergy::DCD.0,
        }
    }
}

/// WSN experiment configuration.
#[derive(Clone)]
pub struct WsnConfig {
    /// Graph, combiners and step sizes of the network.
    pub net: NetworkConfig,
    /// Which algorithm runs on the motes.
    pub algo: WsnAlgo,
    /// ENO energy-model constants (Table I).
    pub energy: EnergyParams,
    /// Per-node harvest scales (lighting levels on the hill).
    pub harvest_scale: Vec<f64>,
    /// Virtual-time horizon (seconds).
    pub duration: f64,
    /// MSD/telemetry sampling interval (seconds).
    pub sample_dt: f64,
    /// Link-impairment layer wrapped around every activation
    /// ([`LinkImpairments::ideal`] = the exact legacy path).
    pub impairments: LinkImpairments,
    /// Per-bit radio costs debited from the activating node's charge
    /// alongside `e_a` ([`RadioEnergy::zero`] = no debit and no ledger
    /// snapshots — the exact legacy path; DESIGN.md §13).
    pub radio: RadioEnergy,
}

/// Time series produced by the simulation.
#[derive(Debug, Clone)]
pub struct WsnResult {
    /// Sample times (s).
    pub time: Vec<f64>,
    /// Network MSD (linear) at each sample time.
    pub msd: Vec<f64>,
    /// Mean sleep duration chosen during each interval (s).
    pub mean_sleep: Vec<f64>,
    /// Mean harvested energy per cycle during each interval (J).
    pub mean_harvest: Vec<f64>,
    /// Total node activations (active phases with charge; includes the
    /// gated ones — the active-phase energy is spent either way).
    pub activations: u64,
    /// Activations skipped for lack of charge.
    pub skipped: u64,
    /// Activations whose transmit gate was closed (subset of
    /// `activations`): the node ran a purely local update and was
    /// billed nothing.
    pub gated: u64,
    /// Per-node activation counts (length N); `per_node_activations[k]
    /// × e_a` is node k's exact active-phase energy spend.
    pub per_node_activations: Vec<u64>,
    /// The run's directional communication bill (DESIGN.md §9).
    pub ledger: CommLedger,
    /// Per-node radio energy debited over the run (J; length N, all
    /// zero for the zero-cost radio). The whole exchange is debited
    /// from the *activating* node (DESIGN.md §13): node k's total is
    /// `tx_j_per_bit · (bits k transmitted during its own activations)
    /// + rx_j_per_bit · (bits its neighbours sent it during those
    /// activations)`, recomputed at the end from integer bit counters
    /// so it cross-foots exactly with the ledger's bill.
    pub radio_joules: Vec<f64>,
}

/// Reusable per-run buffers of the event loop (no allocation per
/// activation; §Perf).
struct Scratch {
    scratch: Vec<usize>,
    mask32: Vec<f32>,
    uk: Vec<f64>,
    un: Vec<f64>,
    /// Per-neighbour request-delivery outcomes of one activation.
    deliv: Vec<bool>,
    /// CSR row offsets into `link_bad` (directed slot `row_off[k] + j`
    /// is node k's j-th incoming link). Empty for memoryless drops.
    row_off: Vec<usize>,
    /// Per-directed-link Gilbert–Elliott chain state (DESIGN.md §12);
    /// persists across activations, lazily seeded from the stationary
    /// distribution on the first bursty draw.
    link_bad: Vec<bool>,
    /// Whether `link_bad` has been seeded yet.
    markov_ready: bool,
}

/// The event-driven simulation.
pub struct WsnSimulation {
    cfg: WsnConfig,
    model: DataModel,
}

impl WsnSimulation {
    /// Assemble a simulation; panics on a node-count mismatch between
    /// the network, the harvest scales and the data model.
    pub fn new(cfg: WsnConfig, model: DataModel) -> Self {
        assert_eq!(cfg.net.n_nodes(), model.n_nodes);
        assert_eq!(cfg.harvest_scale.len(), model.n_nodes);
        cfg.impairments.validate().expect("invalid WSN impairments");
        Self { cfg, model }
    }

    /// One full realization over the virtual-time horizon: every node
    /// duty-cycles per the ENO model and the sampled telemetry/MSD land
    /// in the returned [`WsnResult`]. Deterministic in `seed` (the
    /// Monte-Carlo drivers use per-run seeds `base + r·7919 + 1`); link
    /// impairments draw from the salted `seed ^ LINK_SEED_SALT` stream
    /// so the ideal configuration replays the legacy trajectory exactly.
    pub fn run(&self, seed: u64) -> WsnResult {
        let n = self.model.n_nodes;
        let l = self.model.dim;
        let imp = &self.cfg.impairments;
        let mut rng = Pcg64::new(seed, 0);
        let mut imp_rng = Pcg64::new(seed ^ LINK_SEED_SALT, 0);
        let mut energies: Vec<NodeEnergy> = (0..n)
            .map(|k| NodeEnergy::new(self.cfg.energy.clone(), self.cfg.harvest_scale[k]))
            .collect();
        let mut w = vec![0.0f64; n * l];
        let mut comm = CommMeter::new(n);
        comm.set_quant_step(imp.quant_step);
        // Last-broadcast reference states w̃ (event gating).
        let mut last_broadcast = vec![0.0f64; n * l];
        // Bursty (Gilbert–Elliott) drops keep one chain per directed
        // link across activations; memoryless models draw i.i.d. and
        // need no state (exact legacy RNG consumption).
        let (row_off, link_bad) = if imp.drop.iid_prob().is_none() {
            let mut row_off = Vec::with_capacity(n + 1);
            let mut total = 0usize;
            for k in 0..n {
                row_off.push(total);
                total += self.cfg.net.graph.neighbors(k).len();
            }
            row_off.push(total);
            (row_off, vec![false; total])
        } else {
            (Vec::new(), Vec::new())
        };
        let mut sb = Scratch {
            scratch: Vec::new(),
            mask32: vec![0f32; l],
            uk: vec![0.0f64; l],
            un: vec![0.0f64; l],
            deliv: Vec::new(),
            row_off,
            link_bad,
            markov_ready: false,
        };

        // Event queue ordered by wake time (f64 as ordered bits).
        let mut queue: BinaryHeap<Reverse<(u64, usize)>> = BinaryHeap::new();
        for k in 0..n {
            // Small jitter avoids artificial phase lock.
            let t0 = rng.next_f64() * 0.5;
            queue.push(Reverse((time_key(t0), k)));
        }

        let n_samples = (self.cfg.duration / self.cfg.sample_dt).ceil() as usize;
        let mut time = Vec::with_capacity(n_samples);
        let mut msd = Vec::with_capacity(n_samples);
        let mut mean_sleep = Vec::with_capacity(n_samples);
        let mut mean_harvest = Vec::with_capacity(n_samples);
        let mut next_sample = self.cfg.sample_dt;
        let (mut sleep_acc, mut sleep_cnt) = (0.0, 0u64);
        let (mut harv_acc, mut harv_cnt) = (0.0, 0u64);
        let mut activations = 0u64;
        let mut skipped = 0u64;
        let mut gated = 0u64;
        let mut per_node_activations = vec![0u64; n];
        // Integer scalar counters behind the radio bill: what node k
        // transmitted / received during its *own* activations
        // (activator-pays attribution; DESIGN.md §13).
        let radio = self.cfg.radio;
        let radio_on = !radio.is_zero();
        let mut tx_scal = vec![0u64; n];
        let mut rx_scal = vec![0u64; n];

        while let Some(Reverse((tk, k))) = queue.pop() {
            let now = key_time(tk);
            if now > self.cfg.duration {
                break;
            }
            // Flush MSD samples up to `now` (state piecewise constant).
            while next_sample <= now && time.len() < n_samples {
                time.push(next_sample);
                msd.push(network_msd(&w, &self.model.wo));
                mean_sleep.push(if sleep_cnt > 0 { sleep_acc / sleep_cnt as f64 } else { 0.0 });
                mean_harvest.push(if harv_cnt > 0 { harv_acc / harv_cnt as f64 } else { 0.0 });
                sleep_acc = 0.0;
                sleep_cnt = 0;
                harv_acc = 0.0;
                harv_cnt = 0;
                next_sample += self.cfg.sample_dt;
            }

            let e_a = if energies[k].can_activate() {
                activations += 1;
                per_node_activations[k] += 1;
                // Charge gate passed; now the transmit gate (§9: gate
                // on charge *and* events).
                let silent = match imp.gating {
                    Gating::Always => false,
                    Gating::Probabilistic(p) => !imp_rng.next_bool(p),
                    Gating::EventTriggered(delta) => {
                        let wk = &w[k * l..(k + 1) * l];
                        let lb = &last_broadcast[k * l..(k + 1) * l];
                        let moved: f64 = wk
                            .iter()
                            .zip(lb.iter())
                            .map(|(a, b)| (a - b) * (a - b))
                            .sum();
                        moved <= delta
                    }
                };
                let mut radio_cost = 0.0;
                if silent {
                    gated += 1;
                    self.local_update(k, &mut w, &mut rng, &mut sb);
                } else {
                    if let Gating::EventTriggered(_) = imp.gating {
                        // Transmitting refreshes the reference state
                        // with the broadcast (pre-update) estimate.
                        last_broadcast[k * l..(k + 1) * l]
                            .copy_from_slice(&w[k * l..(k + 1) * l]);
                    }
                    // Snapshot the integer ledger around the exchange:
                    // the delta billed to k is what it transmitted, the
                    // rest of the delta is what its neighbours sent it
                    // (solicited replies / polled estimates).
                    let (tx0, all0) = {
                        let led = comm.ledger();
                        (led.per_node[k], led.scalars)
                    };
                    self.update_node(k, &mut w, &mut rng, &mut imp_rng, &mut comm, &mut sb);
                    if radio_on {
                        let led = comm.ledger();
                        let width = led.bits_per_scalar as u64;
                        let dt = led.per_node[k] - tx0;
                        let dr = led.scalars - all0 - dt;
                        tx_scal[k] += dt;
                        rx_scal[k] += dr;
                        radio_cost = radio.cost(dt * width, dr * width);
                    }
                }
                if imp.quant_step > 0.0 {
                    quantize_in_place(&mut w[k * l..(k + 1) * l], imp.quant_step);
                }
                self.cfg.algo.active_energy() + radio_cost
            } else {
                skipped += 1;
                0.0
            };
            harv_acc += energies[k].harvest(now, &mut rng);
            harv_cnt += 1;
            let t_s = energies[k].cycle(e_a, now, &mut rng);
            sleep_acc += t_s;
            sleep_cnt += 1;
            queue.push(Reverse((time_key(now + t_s), k)));
        }
        // Trailing samples.
        while time.len() < n_samples {
            time.push(next_sample);
            msd.push(network_msd(&w, &self.model.wo));
            mean_sleep.push(if sleep_cnt > 0 { sleep_acc / sleep_cnt as f64 } else { 0.0 });
            mean_harvest.push(if harv_cnt > 0 { harv_acc / harv_cnt as f64 } else { 0.0 });
            sleep_acc = 0.0;
            sleep_cnt = 0;
            harv_acc = 0.0;
            harv_cnt = 0;
            next_sample += self.cfg.sample_dt;
        }

        let ledger = comm.into_ledger();
        // Recompute each node's radio total from the integer bit
        // counters (not by summing the per-activation float debits): a
        // plain product identity that cross-foots exactly with the
        // ledger's billed bits (DESIGN.md §13; tested).
        let width = ledger.bits_per_scalar as u64;
        let radio_joules = (0..n)
            .map(|k| radio.cost(tx_scal[k] * width, rx_scal[k] * width))
            .collect();

        WsnResult {
            time,
            msd,
            mean_sleep,
            mean_harvest,
            activations,
            skipped,
            gated,
            per_node_activations,
            ledger,
            radio_joules,
        }
    }

    /// A gated node's active phase: one purely local LMS step (the
    /// whole adapt mass on the node's own gradient — exactly the C
    /// column collapse a silent node gets in the synchronous model).
    /// No neighbour is polled and nothing is billed.
    fn local_update(&self, k: usize, w: &mut [f64], rng: &mut Pcg64, sb: &mut Scratch) {
        let l = self.model.dim;
        let mu = self.cfg.net.mu[k];
        let dk = self.sample_node_into(k, rng, &mut sb.uk);
        let wk = &mut w[k * l..(k + 1) * l];
        let e = dk - dot(&sb.uk, wk);
        for (wj, &uj) in wk.iter_mut().zip(sb.uk.iter()) {
            *wj += mu * uj * e;
        }
    }

    /// Draw this activation's per-neighbour request-delivery outcomes
    /// into `sb.deliv` (all delivered on ideal links — no RNG draw).
    /// Memoryless drop models keep the exact historical i.i.d. draw;
    /// a bursty `markov:*` model steps node k's per-directed-link
    /// Gilbert–Elliott chains instead (lazy-redraw semantics, identical
    /// to the round scheduler's; DESIGN.md §12).
    fn draw_deliveries(&self, k: usize, degree: usize, imp_rng: &mut Pcg64, sb: &mut Scratch) {
        sb.deliv.clear();
        if let Some(p) = self.cfg.impairments.drop.iid_prob() {
            for _ in 0..degree {
                sb.deliv.push(!(p > 0.0 && imp_rng.next_bool(p)));
            }
            return;
        }
        let DropModel::Markov { p_bad, p_gb, p_bg } = self.cfg.impairments.drop else {
            unreachable!("every non-i.i.d. drop model is markov");
        };
        if !sb.markov_ready {
            let pi = self.cfg.impairments.drop.mean_drop();
            for bad in sb.link_bad.iter_mut() {
                *bad = imp_rng.next_bool(pi);
            }
            sb.markov_ready = true;
        }
        let base = sb.row_off[k];
        for slot in 0..degree {
            let bad = sb.link_bad[base + slot];
            let redraw = imp_rng.next_bool(if bad { p_bg } else { p_gb });
            let nbad = if redraw { imp_rng.next_bool(p_bad) } else { bad };
            sb.link_bad[base + slot] = nbad;
            sb.deliv.push(!nbad);
        }
    }

    /// One asynchronous update of node k using the freshest neighbour
    /// state. Fresh measurements are drawn at poll time for every node
    /// involved (streaming data); exchanges are billed in the ledger
    /// and erased exchanges fall back to the node's own information.
    fn update_node(
        &self,
        k: usize,
        w: &mut [f64],
        rng: &mut Pcg64,
        imp_rng: &mut Pcg64,
        comm: &mut CommMeter,
        sb: &mut Scratch,
    ) {
        let net = &self.cfg.net;
        let l = self.model.dim;
        let mu = net.mu[k];
        let degree = net.graph.neighbors(k).len();
        self.draw_deliveries(k, degree, imp_rng, sb);
        let dk = self.sample_node_into(k, rng, &mut sb.uk);
        let wk: Vec<f64> = w[k * l..(k + 1) * l].to_vec();
        let e_self = dk - dot(&sb.uk, &wk);

        match self.cfg.algo {
            WsnAlgo::Diffusion => {
                // psi_k from own + neighbour gradients evaluated at w_k.
                let mut psi: Vec<f64> = wk.clone();
                let c_kk = net.c[(k, k)];
                for j in 0..l {
                    psi[j] += mu * c_kk * sb.uk[j] * e_self;
                }
                for (i, &nb) in net.graph.neighbors(k).iter().enumerate() {
                    let c_lk = net.c[(nb, k)];
                    // k broadcasts its full estimate; the neighbour's
                    // full-gradient reply exists only when the request
                    // arrived. The neighbour's measurement is drawn
                    // either way (draw parity: drops never perturb the
                    // data stream, so a lossy run keeps the ideal run's
                    // activation schedule).
                    comm.send(k, nb, Purpose::Estimate, l);
                    comm.send_solicited(nb, k, Purpose::Gradient, l, sb.deliv[i]);
                    let dn = self.sample_node_into(nb, rng, &mut sb.un);
                    if sb.deliv[i] {
                        let e = dn - dot(&sb.un, &wk);
                        for j in 0..l {
                            psi[j] += mu * c_lk * sb.un[j] * e;
                        }
                    } else {
                        // Completion: the erased neighbour's adapt mass
                        // falls to the self gradient (eq. (12)).
                        for j in 0..l {
                            psi[j] += mu * c_lk * sb.uk[j] * e_self;
                        }
                    }
                }
                // Combine with the neighbours' current estimates; an
                // erased link falls back to the node's own psi.
                let a_kk = net.a[(k, k)];
                let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
                for (i, &nb) in net.graph.neighbors(k).iter().enumerate() {
                    let a_lk = net.a[(nb, k)];
                    if sb.deliv[i] {
                        for j in 0..l {
                            out[j] += a_lk * w[nb * l + j];
                        }
                    } else {
                        for j in 0..l {
                            out[j] += a_lk * psi[j];
                        }
                    }
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Rcd { m_links } => {
                let mut psi: Vec<f64> = wk.clone();
                for j in 0..l {
                    psi[j] += mu * sb.uk[j] * e_self;
                }
                let nbrs = net.graph.neighbors(k);
                let m = m_links.min(nbrs.len());
                rng.sample_indices(nbrs.len(), m, &mut sb.scratch);
                let mut h_kk = 1.0;
                let mut out = vec![0.0; l];
                for s in 0..m {
                    let idx = sb.scratch[s];
                    let nb = nbrs[idx];
                    // The polled neighbour transmits its full psi; the
                    // transmitter pays whether or not the frame lands
                    // (receiver-side erasure).
                    comm.send(nb, k, Purpose::Estimate, l);
                    if !sb.deliv[idx] {
                        // Erased: treated exactly like an unselected
                        // neighbour (mass stays on the diagonal).
                        continue;
                    }
                    let a_lk = net.a[(nb, k)];
                    h_kk -= a_lk;
                    for j in 0..l {
                        out[j] += a_lk * w[nb * l + j];
                    }
                }
                for j in 0..l {
                    out[j] += h_kk * psi[j];
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Partial { m } => {
                let mut psi: Vec<f64> = wk.clone();
                for j in 0..l {
                    psi[j] += mu * sb.uk[j] * e_self;
                }
                let a_kk = net.a[(k, k)];
                let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
                for (i, &nb) in net.graph.neighbors(k).iter().enumerate() {
                    let a_lk = net.a[(nb, k)];
                    // The neighbour ships M masked entries; transmitter
                    // pays, an erased frame completes from psi. The
                    // mask is drawn either way (draw parity).
                    comm.send(nb, k, Purpose::Estimate, m);
                    rng.fill_mask(&mut sb.mask32, m, &mut sb.scratch);
                    if sb.deliv[i] {
                        for j in 0..l {
                            let hl = sb.mask32[j] as f64;
                            out[j] += a_lk * (hl * w[nb * l + j] + (1.0 - hl) * psi[j]);
                        }
                    } else {
                        for j in 0..l {
                            out[j] += a_lk * psi[j];
                        }
                    }
                }
                w[k * l..(k + 1) * l].copy_from_slice(&out);
            }
            WsnAlgo::Cd { m } => {
                self.dcd_like_update(k, w, rng, comm, sb, m, l, true, false);
            }
            WsnAlgo::Dcd { m, m_grad, combine } => {
                self.dcd_like_update(k, w, rng, comm, sb, m, m_grad, false, combine);
            }
        }
    }

    /// Shared CD/DCD async update. `q_full` ⇒ full gradients (CD);
    /// `combine` ⇒ A ≠ I (masked-estimate combine), else A = I.
    /// `sb.deliv` and `sb.uk` are already populated by `update_node`.
    #[allow(clippy::too_many_arguments)]
    fn dcd_like_update(
        &self,
        k: usize,
        w: &mut [f64],
        rng: &mut Pcg64,
        comm: &mut CommMeter,
        sb: &mut Scratch,
        m: usize,
        m_grad: usize,
        q_full: bool,
        combine: bool,
    ) {
        let net = &self.cfg.net;
        let l = self.model.dim;
        let mu = net.mu[k];
        // Fresh local measurement for this activation (the second draw
        // for node k, exactly like the pre-ledger code path — ideal
        // runs must replay the legacy RNG sequence bit for bit).
        let dk = self.sample_node_into(k, rng, &mut sb.uk);
        let wk: Vec<f64> = w[k * l..(k + 1) * l].to_vec();
        let e_self = dk - dot(&sb.uk, &wk);

        // H_k for this activation.
        let mut hk = vec![0.0f64; l];
        rng.fill_mask(&mut sb.mask32, m, &mut sb.scratch);
        for j in 0..l {
            hk[j] = sb.mask32[j] as f64;
        }

        let mut psi: Vec<f64> = wk.clone();
        let c_kk = net.c[(k, k)];
        for j in 0..l {
            psi[j] += mu * c_kk * sb.uk[j] * e_self;
        }
        // Cache (neighbour, its H_l-masked current estimate) for combine.
        let mut cached: Vec<(usize, Vec<f64>)> = Vec::new();
        for (i, &nb) in net.graph.neighbors(k).iter().enumerate() {
            let c_lk = net.c[(nb, k)];
            let delivered = sb.deliv[i];
            // k broadcasts its H_k-masked estimate (M scalars); the
            // masked-gradient reply exists only when it arrived. Every
            // RNG draw below happens whether or not the exchange was
            // erased (draw parity: drops never perturb the data
            // stream).
            comm.send(k, nb, Purpose::Estimate, m);
            comm.send_solicited(nb, k, Purpose::Gradient, m_grad, delivered);
            let dn = self.sample_node_into(nb, rng, &mut sb.un);
            // Filled point at the neighbour: H_k w_k + (1 - H_k) w_l.
            let mut e = dn;
            for j in 0..l {
                let filled = hk[j] * wk[j] + (1.0 - hk[j]) * w[nb * l + j];
                e -= sb.un[j] * filled;
            }
            // Q_l mask.
            let mut ql = vec![1.0f64; l];
            if !q_full {
                rng.fill_mask(&mut sb.mask32, m_grad, &mut sb.scratch);
                for j in 0..l {
                    ql[j] = sb.mask32[j] as f64;
                }
            }
            if c_lk != 0.0 {
                if delivered {
                    for j in 0..l {
                        let g = ql[j] * (sb.un[j] * e) + (1.0 - ql[j]) * (sb.uk[j] * e_self);
                        psi[j] += mu * c_lk * g;
                    }
                } else {
                    // Completion (eq. (12)): the whole reply falls back
                    // to the node's own gradient.
                    for j in 0..l {
                        psi[j] += mu * c_lk * sb.uk[j] * e_self;
                    }
                }
            }
            if combine {
                // The neighbour's estimate-mask for this exchange
                // (carried by the same reply frame — no extra billing,
                // matching the synchronous accounting). An erased
                // exchange caches nothing: the combine completes from
                // the node's own intermediate estimate.
                rng.fill_mask(&mut sb.mask32, m, &mut sb.scratch);
                if delivered {
                    let masked: Vec<f64> = (0..l).map(|j| sb.mask32[j] as f64).collect();
                    cached.push((nb, masked));
                }
            }
        }

        if combine {
            let a_kk = net.a[(k, k)];
            let mut out: Vec<f64> = psi.iter().map(|&x| a_kk * x).collect();
            // `cached` is in neighbour order, with the erased exchanges
            // missing — walk the two lists in lockstep.
            let mut ci = 0usize;
            for &nb in net.graph.neighbors(k) {
                let a_lk = net.a[(nb, k)];
                if ci < cached.len() && cached[ci].0 == nb {
                    let hl = &cached[ci].1;
                    for j in 0..l {
                        out[j] += a_lk * (hl[j] * w[nb * l + j] + (1.0 - hl[j]) * psi[j]);
                    }
                    ci += 1;
                } else {
                    // Erased exchange: complete from the node's own
                    // intermediate estimate (H_l = 0 case).
                    for j in 0..l {
                        out[j] += a_lk * psi[j];
                    }
                }
            }
            w[k * l..(k + 1) * l].copy_from_slice(&out);
        } else {
            w[k * l..(k + 1) * l].copy_from_slice(&psi);
        }
    }

    /// Fill `u` with a fresh regressor for node k and return d (hot path:
    /// caller provides the buffer, no allocation per poll).
    fn sample_node_into(&self, k: usize, rng: &mut Pcg64, u: &mut [f64]) -> f64 {
        let su = self.model.sigma_u2[k].sqrt();
        let sv = self.model.sigma_v2[k].sqrt();
        let mut dot_wo = 0.0;
        for (x, &woj) in u.iter_mut().zip(self.model.wo.iter()) {
            *x = su * rng.next_gaussian();
            dot_wo += *x * woj;
        }
        dot_wo + sv * rng.next_gaussian()
    }
}

fn network_msd(w: &[f64], wo: &[f64]) -> f64 {
    let l = wo.len();
    let n = w.len() / l;
    let mut total = 0.0;
    for k in 0..n {
        for j in 0..l {
            let d = wo[j] - w[k * l + j];
            total += d * d;
        }
    }
    total / n as f64
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

/// Order-preserving f64→u64 key for the event queue (times are >= 0).
#[inline]
fn time_key(t: f64) -> u64 {
    debug_assert!(t >= 0.0);
    t.to_bits()
}

#[inline]
fn key_time(k: u64) -> f64 {
    f64::from_bits(k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn small_cfg(algo: WsnAlgo, duration: f64) -> (WsnConfig, DataModel) {
        let mut rng = Pcg64::new(42, 0);
        let n = 8;
        let l = 6;
        let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
        let graph = Graph::ring(n, 2);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l };
        let cfg = WsnConfig {
            net,
            algo,
            energy: EnergyParams::default(),
            harvest_scale: (0..n).map(|k| 0.4 + 0.05 * k as f64).collect(),
            duration,
            sample_dt: duration / 50.0,
            impairments: LinkImpairments::ideal(),
            radio: RadioEnergy::zero(),
        };
        (cfg, model)
    }

    #[test]
    fn wsn_msd_decreases_for_all_algorithms() {
        for algo in [
            WsnAlgo::Diffusion,
            WsnAlgo::Rcd { m_links: 2 },
            WsnAlgo::Partial { m: 2 },
            WsnAlgo::Cd { m: 4 },
            WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false },
            WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true },
        ] {
            let (cfg, model) = small_cfg(algo, 2000.0);
            let sim = WsnSimulation::new(cfg, model);
            let res = sim.run(1);
            assert_eq!(res.time.len(), 50);
            let first = res.msd[5];
            let last = *res.msd.last().unwrap();
            assert!(
                last < first,
                "{}: msd {first} -> {last}",
                algo.label()
            );
            assert!(res.activations > 0);
            assert_eq!(res.gated, 0, "ideal links gate nothing");
            // Ledger invariants: per-node activations sum to the total,
            // the bill is broken down consistently, and an ideal run
            // suppresses nothing.
            assert_eq!(
                res.per_node_activations.iter().sum::<u64>(),
                res.activations
            );
            assert!(res.ledger.scalars > 0);
            assert_eq!(res.ledger.suppressed_scalars, 0);
            assert_eq!(
                res.ledger.per_node.iter().sum::<u64>(),
                res.ledger.scalars
            );
            assert_eq!(
                res.ledger.per_purpose.iter().sum::<u64>(),
                res.ledger.scalars
            );
        }
    }

    #[test]
    fn sleep_durations_within_bounds() {
        let (cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 3000.0);
        let sim = WsnSimulation::new(cfg, model);
        let res = sim.run(3);
        for &s in &res.mean_sleep {
            assert!(s <= 300.0 + 1e-9, "sleep {s}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (cfg, model) = small_cfg(WsnAlgo::Cd { m: 3 }, 500.0);
        let sim = WsnSimulation::new(cfg.clone(), model.clone());
        let r1 = sim.run(7);
        let sim2 = WsnSimulation::new(cfg, model);
        let r2 = sim2.run(7);
        assert_eq!(r1.msd, r2.msd);
        assert_eq!(r1.activations, r2.activations);
        assert_eq!(r1.ledger, r2.ledger);
    }

    #[test]
    fn lighter_algorithm_gets_more_activations() {
        let (cfg_d, model_d) = small_cfg(WsnAlgo::Diffusion, 4000.0);
        let (cfg_c, model_c) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 4000.0);
        let heavy = WsnSimulation::new(cfg_d, model_d).run(11);
        let light = WsnSimulation::new(cfg_c, model_c).run(11);
        assert!(
            light.activations > heavy.activations,
            "light {} heavy {}",
            light.activations,
            heavy.activations
        );
    }

    /// Event gating on top of the charge gate: gated activations run a
    /// purely local update and bill nothing, so the billed bits drop
    /// strictly below the always-on bill, and the simulation stays
    /// deterministic in the seed.
    #[test]
    fn event_gating_cuts_the_bill_and_stays_deterministic() {
        let (mut cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 4000.0);
        let ideal = WsnSimulation::new(cfg.clone(), model.clone()).run(9);
        cfg.impairments = LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::EventTriggered(1e-2),
            quant_step: 0.0,
            per_leg: false,
        };
        let gated = WsnSimulation::new(cfg.clone(), model.clone()).run(9);
        assert!(gated.gated > 0, "the event gate never closed");
        assert!(
            gated.ledger.bits() < ideal.ledger.bits(),
            "gated bill {} not below ideal {}",
            gated.ledger.bits(),
            ideal.ledger.bits()
        );
        // MSD still improves (local updates keep learning).
        assert!(*gated.msd.last().unwrap() < gated.msd[5]);
        let again = WsnSimulation::new(cfg, model).run(9);
        assert_eq!(gated.msd, again.msd);
        assert_eq!(gated.ledger, again.ledger);
    }

    /// Drops: estimate frames stay billed (transmitter pays) while the
    /// dead request legs' replies are suppressed and tracked — the
    /// exact bill reconciles with the legacy transmitter-only bill.
    #[test]
    fn drops_suppress_solicited_replies_only() {
        let (mut cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false }, 3000.0);
        let ideal = WsnSimulation::new(cfg.clone(), model.clone()).run(5);
        cfg.impairments = LinkImpairments {
            drop: DropModel::Iid(0.5),
            gating: Gating::Always,
            quant_step: 0.0,
            per_leg: false,
        };
        let lossy = WsnSimulation::new(cfg, model).run(5);
        // Same activation schedule (impairments ride a salted stream).
        assert_eq!(ideal.activations, lossy.activations);
        assert_eq!(
            ideal.ledger.purpose_scalars(Purpose::Estimate),
            lossy.ledger.purpose_scalars(Purpose::Estimate)
        );
        assert!(lossy.ledger.suppressed_scalars > 0);
        assert_eq!(lossy.ledger.legacy_scalars(), ideal.ledger.scalars);
        assert!(*lossy.msd.last().unwrap() < lossy.msd[5]);
    }

    /// A memoryless `markov:p,1,1` spec redraws every sample and is
    /// exactly the i.i.d. process — bit-identical trajectory and bill.
    /// A bursty chain shares the stationary loss rate but correlates
    /// the erasures: still deterministic, but a different trajectory on
    /// the same activation schedule.
    #[test]
    fn wsn_memoryless_markov_matches_iid_bitwise() {
        let (mut cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false }, 3000.0);
        cfg.impairments.drop = DropModel::Iid(0.3);
        let iid = WsnSimulation::new(cfg.clone(), model.clone()).run(5);
        cfg.impairments.drop = DropModel::Markov { p_bad: 0.3, p_gb: 1.0, p_bg: 1.0 };
        let memoryless = WsnSimulation::new(cfg.clone(), model.clone()).run(5);
        assert_eq!(iid.msd, memoryless.msd);
        assert_eq!(iid.ledger, memoryless.ledger);
        cfg.impairments.drop = DropModel::Markov { p_bad: 0.3, p_gb: 0.2, p_bg: 0.2 };
        let bursty = WsnSimulation::new(cfg.clone(), model.clone()).run(5);
        let again = WsnSimulation::new(cfg, model).run(5);
        assert_eq!(bursty.msd, again.msd, "bursty WSN run must be deterministic");
        assert_ne!(bursty.msd, iid.msd, "burstiness should alter the trajectory");
        // The salted impairment stream leaves the activation schedule
        // untouched either way.
        assert_eq!(iid.activations, bursty.activations);
    }

    /// Activator-pays radio debit (DESIGN.md §13) with dyadic per-bit
    /// rates: every product and sum below is an exact f64, so the
    /// per-node radio bill cross-foots *exactly* with the ledger.
    /// DCD's activator transmits every Estimate scalar and receives
    /// every delivered Gradient scalar — on ideal and on lossy links
    /// (a suppressed reply costs nobody anything).
    #[test]
    fn radio_bill_cross_foots_exactly_with_the_ledger() {
        let tx = (2f64).powi(-20);
        let rx = (2f64).powi(-22);
        for drop in [DropModel::none(), DropModel::Iid(0.4)] {
            let (mut cfg, model) =
                small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false }, 2000.0);
            cfg.radio = RadioEnergy { tx_j_per_bit: tx, rx_j_per_bit: rx };
            cfg.impairments.drop = drop;
            let res = WsnSimulation::new(cfg, model).run(13);
            let w = res.ledger.bits_per_scalar as u64;
            let est_bits = res.ledger.purpose_scalars(Purpose::Estimate) * w;
            let grad_bits = res.ledger.purpose_scalars(Purpose::Gradient) * w;
            let total: f64 = res.radio_joules.iter().sum();
            assert!(total > 0.0);
            assert_eq!(total, tx * est_bits as f64 + rx * grad_bits as f64);
            if drop == DropModel::none() {
                // Ideal ring(8, 2), M = M∇ = 2: each activation moves
                // deg·M = 8 estimate scalars out and 8 gradient scalars
                // back, all billed — a per-node closed form.
                for k in 0..8 {
                    let bits = res.per_node_activations[k] * 8 * w;
                    assert_eq!(res.radio_joules[k], tx * bits as f64 + rx * bits as f64);
                }
            }
        }
    }

    /// RCD inverts the traffic direction: the activator polls and its
    /// neighbours transmit, so under activator-pays every billed bit is
    /// charged at the *rx* rate and a tx-only radio debits nothing.
    #[test]
    fn rcd_radio_bill_is_receive_only() {
        let (mut cfg, model) = small_cfg(WsnAlgo::Rcd { m_links: 2 }, 2000.0);
        cfg.radio = RadioEnergy { tx_j_per_bit: (2f64).powi(-18), rx_j_per_bit: 0.0 };
        let tx_only = WsnSimulation::new(cfg, model).run(21);
        assert!(tx_only.ledger.scalars > 0);
        assert_eq!(tx_only.radio_joules, vec![0.0; 8]);

        let rx = (2f64).powi(-21);
        let (mut cfg, model) = small_cfg(WsnAlgo::Rcd { m_links: 2 }, 2000.0);
        cfg.radio = RadioEnergy { tx_j_per_bit: 0.0, rx_j_per_bit: rx };
        let rx_only = WsnSimulation::new(cfg, model).run(21);
        let bits = rx_only.ledger.bits();
        let total: f64 = rx_only.radio_joules.iter().sum();
        assert_eq!(total, rx * bits as f64);
    }

    /// The zero-cost radio is the exact legacy path: `e_a + 0.0`
    /// preserves the bits of every positive debit and no extra RNG is
    /// consumed, so the trajectory, schedule and bill are unchanged.
    #[test]
    fn zero_radio_is_bitwise_legacy() {
        let (cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: true }, 2000.0);
        let base = WsnSimulation::new(cfg.clone(), model.clone()).run(7);
        let mut cfg2 = cfg;
        cfg2.radio = RadioEnergy::zero();
        let again = WsnSimulation::new(cfg2, model).run(7);
        assert_eq!(base.msd, again.msd);
        assert_eq!(base.activations, again.activations);
        assert_eq!(base.ledger, again.ledger);
        assert_eq!(again.radio_joules, vec![0.0; 8]);
    }

    /// ENO closed form (eq. (70)): the sleep fixed point scales
    /// linearly in the per-activation energy, so pricing DCD's radio
    /// exchange at the Table-I gap (8.58e-2 − 5.4e-3 = 8.04e-2 J over
    /// the 512 + 512 bits of a ring(8,2) M = M∇ = 2 exchange) makes a
    /// radio-loaded DCD activation cost exactly what a diffusion
    /// activation costs — its activation rate must collapse from the
    /// free-radio rate down to diffusion's.
    #[test]
    fn radio_draw_lowers_activation_rate_to_the_eno_prediction() {
        let (cfg_free, model_free) =
            small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false }, 4000.0);
        let free = WsnSimulation::new(cfg_free, model_free).run(11);

        let rate = (ActiveEnergy::DIFFUSION.0 - ActiveEnergy::DCD.0) / 1024.0;
        let (mut cfg, model) = small_cfg(WsnAlgo::Dcd { m: 2, m_grad: 2, combine: false }, 4000.0);
        cfg.radio = RadioEnergy { tx_j_per_bit: rate, rx_j_per_bit: rate };
        let loaded = WsnSimulation::new(cfg, model).run(11);

        let (cfg_d, model_d) = small_cfg(WsnAlgo::Diffusion, 4000.0);
        let diffusion = WsnSimulation::new(cfg_d, model_d).run(11);

        assert!(
            (loaded.activations as f64) < 0.8 * free.activations as f64,
            "radio load {} not well below free {}",
            loaded.activations,
            free.activations
        );
        // Same per-activation energy as diffusion ⇒ same ENO schedule
        // up to sampling noise (different RNG consumption patterns).
        let ratio = loaded.activations as f64 / diffusion.activations as f64;
        assert!(
            (0.75..=1.25).contains(&ratio),
            "radio-loaded DCD {} vs diffusion {} (ratio {ratio:.3})",
            loaded.activations,
            diffusion.activations
        );
    }

    /// Quantization snaps the stored state to the grid and bills
    /// payloads at the grid-index width.
    #[test]
    fn quantized_wsn_state_stays_on_grid() {
        let (mut cfg, model) = small_cfg(WsnAlgo::Partial { m: 3 }, 2000.0);
        let step = 1e-3;
        cfg.impairments = LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::Always,
            quant_step: step,
            per_leg: false,
        };
        let sim = WsnSimulation::new(cfg, model);
        let res = sim.run(3);
        assert_eq!(res.ledger.bits_per_scalar, crate::energy::payload_bits(step));
        assert!(res.ledger.bits() < res.ledger.scalars * 64);
        assert!(*res.msd.last().unwrap() < res.msd[5]);
    }
}
