//! Network-dynamics layer (DESIGN.md §12): node join/leave churn,
//! mobility-driven topology rewiring, and the adaptive-combiner policy
//! handle, wrapped around any [`Algorithm`] by the round scheduler.
//!
//! Every scenario the system expressed before this layer was static —
//! fixed topology, fixed membership, fixed optimum. Ad-hoc WSNs are
//! not: nodes die and rejoin (battery, duty cycling), radios move in
//! and out of range, and the estimand drifts. This module owns the
//! first two axes; the drifting optimum lives in
//! [`crate::datamodel::DriftModel`] because it perturbs the *data*
//! process, not the network.
//!
//! * **Churn** — each node independently leaves an iteration with
//!   probability `leave` and, once absent, rejoins with probability
//!   `join`. An absent node is fully off the air: it transmits nothing,
//!   is billed nothing, solicits nothing (it folds into the impairment
//!   layer's silence mask), and its step size is masked to zero so it
//!   freezes in place until it returns. When the spec demands
//!   `require_connected`, a departure that would disconnect the active
//!   subgraph is vetoed (the draw is still consumed, so the RNG
//!   sequence is membership-independent in count per node-state).
//! * **Mobility rewiring** — nodes orbit their home placement with
//!   radius `rewire` and period `rewire_period` (deterministic phases,
//!   golden-angle-spread per node: no RNG consumed), and a support edge
//!   is live exactly when the current distance is within the connection
//!   `radius`. The combiners are built once over the *support graph*
//!   ([`crate::topology::Graph::with_mobility_support`]); liveness only
//!   toggles per-slot masks, so the per-iteration cost is O(E) with
//!   zero allocation — the same in-place discipline as the impairment
//!   layer (`tests/alloc_free.rs`).
//! * **Adaptive combiners** — this layer carries the
//!   [`AdaptivePolicy`] the impairment state consults on its periodic
//!   re-weighting clock ([`super::impairments::ADAPTIVE_PERIOD`]).
//!
//! Determinism: churn draws come from a dedicated PCG64 stream
//! (`seed ^ DYN_SEED_SALT`, same stream id as the run), so dynamics
//! never perturb the data or impairment sequences and runs stay
//! bit-identical for any thread/shard layout.

use crate::algorithms::Algorithm;
use crate::rng::Pcg64;

pub use super::impairments::AdaptivePolicy;

/// Salt XOR-ed into the master seed for the dynamics RNG stream, so
/// churn draws are decorrelated from (and do not consume) the data and
/// impairment streams.
pub const DYN_SEED_SALT: u64 = 0x6479_6e61_6d69_6373; // "dynamics"

/// Golden-angle phase spread between node orbits, so no two nodes'
/// mobility trajectories ever synchronize.
const GOLDEN_ANGLE: f64 = 2.399963229728653;

/// Declarative network-dynamics model for one scenario (the runtime
/// face of the `[dynamics]` INI section — see `scenario/spec.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicsConfig {
    /// Per-iteration probability that an active node leaves.
    pub leave: f64,
    /// Per-iteration probability that an absent node rejoins.
    pub join: f64,
    /// Veto departures that would disconnect the active subgraph.
    pub require_connected: bool,
    /// Mobility orbit radius ρ around each node's home placement
    /// (0 = no mobility).
    pub rewire: f64,
    /// Mobility orbit period in iterations.
    pub rewire_period: usize,
    /// Link reach: a mobile edge is live when the current node distance
    /// is within this radius (the geometric topology's radius).
    pub radius: f64,
    /// Adaptive combination-weight policy (DESIGN.md §12).
    pub adaptive: AdaptivePolicy,
}

impl Default for DynamicsConfig {
    fn default() -> Self {
        Self {
            leave: 0.0,
            join: 0.0,
            require_connected: false,
            rewire: 0.0,
            rewire_period: 1000,
            radius: 0.0,
            adaptive: AdaptivePolicy::Static,
        }
    }
}

impl DynamicsConfig {
    /// True when every axis is off — the scheduler then skips the layer
    /// entirely and the run is byte-identical to the static path.
    pub fn is_static(&self) -> bool {
        self.leave == 0.0
            && self.join == 0.0
            && self.rewire == 0.0
            && self.adaptive == AdaptivePolicy::Static
    }
}

fn dist(a: (f64, f64), b: (f64, f64)) -> f64 {
    ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt()
}

/// Per-run mutable state of the dynamics layer: membership mask, the
/// masked step-size backup, mobility positions, and per-slot edge
/// liveness. All buffers are allocated once in [`DynamicsState::new`];
/// [`DynamicsState::advance`] is allocation-free.
pub struct DynamicsState {
    cfg: DynamicsConfig,
    /// Membership mask (false = node currently absent).
    active: Vec<bool>,
    /// Pristine per-node step sizes (what `restore` reinstalls).
    mu0: Vec<f64>,
    /// Home placements (mobility only; empty otherwise).
    home: Vec<(f64, f64)>,
    /// Current placements (mobility scratch).
    pos: Vec<(f64, f64)>,
    /// Always-live slots: support edges longer than `radius + 2ρ` at
    /// home can only be the generator's connectivity stitches — they
    /// model a long-range backbone link and never die to mobility.
    protected: Vec<bool>,
    /// Per-directed-slot mobility liveness (empty when mobility is off,
    /// which [`DynamicsState::edge_alive`] reads as "always live").
    edge_live: Vec<bool>,
    /// Directed-link slot base per receiver (same layout as the
    /// impairment layer's per-link vectors).
    row_off: Vec<usize>,
    /// BFS scratch for the connectivity veto.
    seen: Vec<bool>,
    stack: Vec<usize>,
    iter: usize,
    rng: Pcg64,
}

impl DynamicsState {
    /// Capture the network's pristine step sizes and placements and
    /// seed the dynamics stream for one run (`stream` is the
    /// Monte-Carlo run stream, as for the impairment state).
    pub fn new(
        cfg: DynamicsConfig,
        net: &crate::algorithms::NetworkConfig,
        seed: u64,
        stream: u64,
    ) -> Self {
        let n = net.n_nodes();
        let mut row_off = Vec::with_capacity(n + 1);
        let mut slots = 0usize;
        for k in 0..n {
            row_off.push(slots);
            slots += net.graph.neighbors(k).len();
        }
        row_off.push(slots);
        let mobility = cfg.rewire > 0.0 && net.graph.positions.is_some();
        let home: Vec<(f64, f64)> = if mobility {
            net.graph.positions.clone().unwrap()
        } else {
            Vec::new()
        };
        let mut protected = Vec::new();
        let mut edge_live = Vec::new();
        if mobility {
            protected.resize(slots, false);
            edge_live.resize(slots, true);
            let reach = cfg.radius + 2.0 * cfg.rewire;
            for k in 0..n {
                for (slot, &lnb) in net.graph.neighbors(k).iter().enumerate() {
                    protected[row_off[k] + slot] = dist(home[k], home[lnb]) > reach;
                }
            }
        }
        Self {
            cfg,
            active: vec![true; n],
            mu0: net.mu.clone(),
            pos: home.clone(),
            home,
            protected,
            edge_live,
            row_off,
            seen: Vec::with_capacity(n),
            stack: Vec::with_capacity(n),
            iter: 0,
            rng: Pcg64::new(seed ^ DYN_SEED_SALT, stream),
        }
    }

    /// Advance one iteration: churn draws (leave/join, connectivity
    /// veto), mobility orbit + edge-liveness refresh, and the per-node
    /// step-size mask. Called by the impairment layer at the top of
    /// [`super::impairments::ImpairmentState::begin_iteration_dynamic`].
    pub fn advance(&mut self, alg: &mut dyn Algorithm) {
        self.iter += 1;
        let n = self.active.len();
        let churn = self.cfg.leave > 0.0 || self.cfg.join > 0.0;
        if churn {
            {
                let graph = &alg.network().graph;
                for k in 0..n {
                    if self.active[k] {
                        if self.rng.next_bool(self.cfg.leave) {
                            self.active[k] = false;
                            let last_one = self.active.iter().all(|&a| !a);
                            let veto = last_one
                                || (self.cfg.require_connected
                                    && !graph.is_connected_subset(
                                        &self.active,
                                        &mut self.seen,
                                        &mut self.stack,
                                    ));
                            if veto {
                                self.active[k] = true;
                            }
                        }
                    } else if self.rng.next_bool(self.cfg.join) {
                        self.active[k] = true;
                    }
                }
            }
            // An absent node freezes: its step size is masked to zero,
            // so it neither adapts nor combines fresh information, and
            // rejoins exactly where it left off.
            let mu = &mut alg.network_mut().mu;
            mu.copy_from_slice(&self.mu0);
            for (k, &a) in self.active.iter().enumerate() {
                if !a {
                    mu[k] = 0.0;
                }
            }
        }
        if !self.edge_live.is_empty() {
            let period = self.cfg.rewire_period.max(1);
            let base =
                2.0 * std::f64::consts::PI * (self.iter % period) as f64 / period as f64;
            for (k, p) in self.pos.iter_mut().enumerate() {
                let th = base + GOLDEN_ANGLE * k as f64;
                *p = (
                    self.home[k].0 + self.cfg.rewire * th.cos(),
                    self.home[k].1 + self.cfg.rewire * th.sin(),
                );
            }
            let graph = &alg.network().graph;
            for k in 0..n {
                for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                    let sidx = self.row_off[k] + slot;
                    self.edge_live[sidx] = self.protected[sidx]
                        || dist(self.pos[k], self.pos[lnb]) <= self.cfg.radius;
                }
            }
        }
    }

    /// Whether node `k` is currently present.
    #[inline]
    pub fn is_active(&self, k: usize) -> bool {
        self.active[k]
    }

    /// Number of currently present nodes.
    pub fn active_count(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    /// The membership mask (valid after [`Self::advance`]).
    pub fn active(&self) -> &[bool] {
        &self.active
    }

    /// The adaptive-combiner policy the impairment layer should apply
    /// on its refresh clock.
    #[inline]
    pub fn adaptive(&self) -> AdaptivePolicy {
        self.cfg.adaptive
    }

    /// Whether the directed support link `graph.neighbors(k)[slot] → k`
    /// is structurally alive this iteration: both endpoints present and
    /// (under mobility) the slot within radio reach.
    #[inline]
    pub fn edge_alive(&self, k: usize, slot: usize, lnb: usize) -> bool {
        self.active[k]
            && self.active[lnb]
            && (self.edge_live.is_empty() || self.edge_live[self.row_off[k] + slot])
    }

    /// Put the pristine step sizes back (paired with the impairment
    /// state's combiner restore, so a reused algorithm instance sees
    /// its original configuration).
    pub fn restore(&self, alg: &mut dyn Algorithm) {
        alg.network_mut().mu.copy_from_slice(&self.mu0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{CommMeter, Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    fn net(n: usize, l: usize) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l }
    }

    #[test]
    fn static_config_is_static() {
        assert!(DynamicsConfig::default().is_static());
        let c = DynamicsConfig { leave: 0.01, ..DynamicsConfig::default() };
        assert!(!c.is_static());
        let c = DynamicsConfig {
            adaptive: AdaptivePolicy::Metropolis,
            ..DynamicsConfig::default()
        };
        assert!(!c.is_static());
    }

    #[test]
    fn churn_masks_step_sizes_and_restore_reinstalls() {
        let cfg = net(8, 2);
        let mut alg = Dcd::new(cfg.clone(), 1, 1);
        let dc = DynamicsConfig { leave: 0.9, join: 0.0, ..DynamicsConfig::default() };
        let mut ds = DynamicsState::new(dc, alg.network(), 42, 1);
        for _ in 0..20 {
            ds.advance(&mut alg);
        }
        assert!(ds.active_count() >= 1, "the last node can never leave");
        let mu = &alg.network().mu;
        for k in 0..8 {
            if ds.is_active(k) {
                assert_eq!(mu[k], 0.05);
            } else {
                assert_eq!(mu[k], 0.0);
            }
        }
        // With heavy leave pressure somebody must have left.
        assert!(ds.active_count() < 8);
        ds.restore(&mut alg);
        assert_eq!(alg.network().mu, cfg.mu);
    }

    #[test]
    fn connectivity_veto_keeps_active_subgraph_connected() {
        // A path graph: removing an interior node disconnects it, so
        // with the veto on, only the endpoints may ever leave.
        let graph = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let cfg = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 2 };
        let mut alg = Dcd::new(cfg, 1, 1);
        let dc = DynamicsConfig {
            leave: 0.5,
            join: 0.2,
            require_connected: true,
            ..DynamicsConfig::default()
        };
        let mut ds = DynamicsState::new(dc, alg.network(), 7, 3);
        let mut seen = Vec::new();
        let mut stack = Vec::new();
        for _ in 0..200 {
            ds.advance(&mut alg);
            assert!(
                alg.network().graph.is_connected_subset(ds.active(), &mut seen, &mut stack),
                "active subgraph disconnected: {:?}",
                ds.active()
            );
        }
    }

    #[test]
    fn mobility_toggles_edges_but_keeps_protected_backbone() {
        let mut rng = Pcg64::new(5, 9);
        let base = Graph::random_geometric(24, 0.22, &mut rng);
        let radius = 0.22;
        let rho = 0.08;
        let graph = base.with_mobility_support(radius, rho);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let n = graph.n();
        let cfg = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: 2 };
        let mut alg = Dcd::new(cfg, 1, 1);
        let dc = DynamicsConfig {
            rewire: rho,
            rewire_period: 40,
            radius,
            ..DynamicsConfig::default()
        };
        let mut ds = DynamicsState::new(dc, alg.network(), 11, 1);
        let mut ever_dead = 0usize;
        let mut ever_live = 0usize;
        for _ in 0..40 {
            ds.advance(&mut alg);
            let g = &alg.network().graph;
            for k in 0..n {
                for (slot, &lnb) in g.neighbors(k).iter().enumerate() {
                    if ds.edge_alive(k, slot, lnb) {
                        ever_live += 1;
                    } else {
                        ever_dead += 1;
                    }
                }
            }
        }
        // Mobility must actually toggle membership both ways.
        assert!(ever_live > 0 && ever_dead > 0, "live {ever_live} dead {ever_dead}");
        // No churn configured: everybody stays active.
        assert_eq!(ds.active_count(), n);
    }

    #[test]
    fn dynamics_layer_composes_with_impairments() {
        use super::super::impairments::{ImpairmentState, LinkImpairments};
        let cfg = net(6, 2);
        let mut alg = Dcd::new(cfg, 1, 1);
        let mut comm = CommMeter::new(6);
        let imp = LinkImpairments::ideal();
        let mut state = ImpairmentState::new(alg.network(), 9, 1);
        let dc = DynamicsConfig { leave: 1.0, join: 0.0, ..DynamicsConfig::default() };
        let mut ds = DynamicsState::new(dc, alg.network(), 9, 1);
        // leave = 1.0 with no veto: everyone but the last guard leaves,
        // and every surviving node's incoming mass collapses to itself.
        state.begin_iteration_dynamic(&imp, Some(&mut ds), &mut alg, &mut comm);
        state.begin_iteration_dynamic(&imp, Some(&mut ds), &mut alg, &mut comm);
        assert_eq!(ds.active_count(), 1);
        let a = &alg.network().a;
        for k in 0..6 {
            assert!((a[(k, k)] - 1.0).abs() < 1e-12, "node {k} not isolated");
        }
    }
}
