//! Monte-Carlo orchestration over both execution engines.
//!
//! * `run_rust` — message-level per-agent simulation (f64), any
//!   [`Algorithm`], fanned across worker threads (one realization per
//!   claim; see the determinism note below).
//! * `run_xla` — the AOT-compiled vectorised engine: generates data and
//!   selection masks on the rust side, feeds T-step chunks to the PJRT
//!   executable, threads the carried weights between chunks.
//!
//! Both engines consume the same [`DataModel`] and report the same
//! [`McResult`]; `rust/tests/engines_agree.rs` drives them with identical
//! inputs and asserts trajectory agreement.
//!
//! # Determinism of the parallel runner
//!
//! Realization `r` always draws from its own `Pcg64::new(seed, r + 1)`
//! stream, so the trace of each run is independent of which worker
//! executes it; workers hand their finished traces back by run index and
//! the accumulators are folded **sequentially in run order** after the
//! join. The result is bit-identical for any thread count (asserted by
//! `parallel_runner_bit_identical_to_serial` below).

use crate::algorithms::Algorithm;
use crate::datamodel::{DataModel, DriftModel};
use crate::metrics::TraceAccumulator;
use crate::rng::Pcg64;
use crate::runtime::Runtime;
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

use super::dynamics::DynamicsConfig;
use super::impairments::{LinkImpairments, LinkStateStats};
use super::round::{RoundScheduler, RunResult};

/// Per-run scheduler configuration beyond the data model: link
/// impairments, network dynamics (churn / mobility / adaptive
/// combiners) and the drifting optimum. The default is the exact
/// legacy ideal-static path. One value of this struct is built per
/// scenario and shared by the in-process runner and the shard workers,
/// so every execution route configures the round scheduler identically
/// (bit-identity across shards × threads).
#[derive(Debug, Clone, Default)]
pub struct SchedulerOptions {
    /// Optional link-impairment model (None = ideal links).
    pub impairments: Option<LinkImpairments>,
    /// Optional network-dynamics model (None/static = fixed network).
    pub dynamics: Option<DynamicsConfig>,
    /// Time variation of the optimum w°(i).
    pub drift: DriftModel,
}

impl SchedulerOptions {
    /// Options carrying only a link-impairment model (the historical
    /// call shape).
    pub fn from_impairments(imp: Option<&LinkImpairments>) -> Self {
        Self { impairments: imp.cloned(), ..Self::default() }
    }

    /// Install these options on a scheduler.
    fn configure(&self, sched: &mut RoundScheduler<'_>) {
        sched.impairments = self.impairments.clone();
        sched.dynamics = self.dynamics.clone();
        sched.drift = self.drift;
    }
}

/// Monte-Carlo configuration.
#[derive(Debug, Clone)]
pub struct MonteCarlo {
    /// Independent realizations to average.
    pub runs: usize,
    /// Iterations per realization.
    pub iters: usize,
    /// Master seed; realization `r` draws from stream `r + 1`.
    pub seed: u64,
    /// Thin the recorded MSD trace (1 = every iteration).
    pub record_every: usize,
    /// Worker threads for the rust engine: 0 = auto (`DCD_MC_THREADS`
    /// env var, else the machine's available parallelism).
    pub threads: usize,
}

/// Split `runs` realizations into `shards` contiguous run-index ranges
/// `(start, count)`, in run order, as evenly as possible (the first
/// `runs % shards` shards get one extra run). Empty ranges are never
/// emitted: with more shards than runs the plan has `runs` singleton
/// entries. This is the shard layout the multi-process supervisor
/// executes (DESIGN.md §8); keeping the ranges contiguous *and* merging
/// shard outputs back in run order is what preserves bit-identity with
/// [`MonteCarlo::run_rust_serial`].
pub fn shard_ranges(runs: usize, shards: usize) -> Vec<(usize, usize)> {
    let shards = shards.clamp(1, runs.max(1));
    let base = runs / shards;
    let extra = runs % shards;
    let mut ranges = Vec::with_capacity(shards);
    let mut start = 0;
    for i in 0..shards {
        let count = base + usize::from(i < extra);
        if count > 0 {
            ranges.push((start, count));
            start += count;
        }
    }
    ranges
}

/// Resolve a requested worker count: explicit value wins, else the
/// `DCD_MC_THREADS` env var, else available parallelism — always capped
/// by the number of independent jobs.
pub fn resolve_threads(requested: usize, jobs: usize) -> usize {
    let auto = || {
        std::env::var("DCD_MC_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&t| t > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    };
    let t = if requested > 0 { requested } else { auto() };
    t.min(jobs.max(1))
}

/// Execute `jobs` independent tasks across up to `threads` scoped worker
/// threads, returning the results **in job order** regardless of
/// scheduling: workers claim job indices from a shared counter and the
/// finished results are reassembled by index after the join. With
/// `threads <= 1` the tasks run inline, in order — identical outputs by
/// construction. Shared by the Monte-Carlo runner and the WSN driver.
pub fn parallel_ordered<T: Send>(
    jobs: usize,
    threads: usize,
    task: impl Fn(usize) -> T + Sync,
) -> Vec<T> {
    let threads = threads.min(jobs.max(1));
    if threads <= 1 {
        return (0..jobs).map(&task).collect();
    }
    let mut slots: Vec<Option<T>> = (0..jobs).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for _ in 0..threads {
            let next = &next;
            let task = &task;
            handles.push(scope.spawn(move || {
                let mut done = Vec::new();
                loop {
                    let r = next.fetch_add(1, Ordering::Relaxed);
                    if r >= jobs {
                        break;
                    }
                    done.push((r, task(r)));
                }
                done
            }));
        }
        for handle in handles {
            for (r, res) in handle.join().expect("parallel worker panicked") {
                slots[r] = Some(res);
            }
        }
    });
    slots.into_iter().map(|s| s.expect("missing job result")).collect()
}

/// Averaged result.
#[derive(Debug, Clone)]
pub struct McResult {
    /// Mean network MSD (linear) per recorded iteration.
    pub msd: Vec<f64>,
    /// Steady-state estimate (mean of the trailing 10%).
    pub steady_state: f64,
    /// Mean scalars transmitted per run (rust engine only; 0 for xla).
    pub scalars_per_run: f64,
    /// Number of realizations averaged.
    pub runs: usize,
    /// Directional communication bill summed over all realizations
    /// (integer counters, so the total is order-independent —
    /// bit-identical for any thread/shard layout; DESIGN.md §9). Empty
    /// (zero-node) for the xla engine, which carries no meter.
    pub ledger: crate::algorithms::CommLedger,
    /// Markov link-state occupancy counters summed over all
    /// realizations (integer counters, order-independent; empty for
    /// i.i.d. drop models — DESIGN.md §12).
    pub linkstate: LinkStateStats,
}

/// Parameters of the compiled (xla) engine for one algorithm.
#[derive(Debug, Clone)]
pub enum XlaAlgo {
    /// Generalised DCD step (covers diffusion-LMS and CD by mask choice).
    Dcd { m: usize, m_grad: usize },
    /// Textbook ATC diffusion LMS.
    Atc,
    /// Reduced-communication diffusion.
    Rcd { m_links: usize },
    /// Partial-diffusion LMS.
    Partial { m: usize },
}

impl XlaAlgo {
    /// The artifact-manifest algorithm name this variant executes.
    pub fn module_algo(&self) -> &'static str {
        match self {
            XlaAlgo::Dcd { .. } => "dcd",
            XlaAlgo::Atc => "atc",
            XlaAlgo::Rcd { .. } => "rcd",
            XlaAlgo::Partial { .. } => "partial",
        }
    }
}

impl MonteCarlo {
    /// Rust engine: average `runs` independent trajectories of
    /// `make_alg()`, fanned across [`MonteCarlo::threads`] workers.
    /// Bit-identical to [`Self::run_rust_serial`] for any thread count.
    pub fn run_rust(
        &self,
        model: &DataModel,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
    ) -> McResult {
        self.run_rust_with(model, None, make_alg)
    }

    /// [`Self::run_rust`] with an optional link-impairment model wrapped
    /// around every iteration (the scenario subsystem's entry point).
    /// Impairment decisions are drawn per run from a dedicated PCG64
    /// stream, so the result stays bit-identical for any thread count.
    pub fn run_rust_with(
        &self,
        model: &DataModel,
        impairments: Option<&LinkImpairments>,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
    ) -> McResult {
        self.run_rust_opts(model, &SchedulerOptions::from_impairments(impairments), make_alg)
    }

    /// [`Self::run_rust`] with the full scheduler configuration —
    /// impairments, network dynamics and the drifting optimum. Every
    /// dynamic axis draws from its own per-run stream, so bit-identity
    /// for any thread count carries over unchanged.
    pub fn run_rust_opts(
        &self,
        model: &DataModel,
        opts: &SchedulerOptions,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
    ) -> McResult {
        let threads = resolve_threads(self.threads, self.runs);
        if threads <= 1 {
            return self.run_rust_serial_opts(model, opts, make_alg);
        }
        self.merge(self.run_rust_range_opts(model, opts, make_alg, 0, self.runs).into_iter())
    }

    /// Execute the contiguous realization block
    /// `[run_start, run_start + count)` and return the per-run results
    /// **in run order**. Realization `r` always draws from stream
    /// `r + 1` of the master seed, so a block produces exactly the
    /// per-run results the full runner would — this is what a shard
    /// worker process executes (DESIGN.md §8). Within the block the
    /// runs fan across [`MonteCarlo::threads`] workers.
    pub fn run_rust_range(
        &self,
        model: &DataModel,
        impairments: Option<&LinkImpairments>,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
        run_start: usize,
        count: usize,
    ) -> Vec<RunResult> {
        self.run_rust_range_opts(
            model,
            &SchedulerOptions::from_impairments(impairments),
            make_alg,
            run_start,
            count,
        )
    }

    /// [`Self::run_rust_range`] with the full scheduler configuration.
    pub fn run_rust_range_opts(
        &self,
        model: &DataModel,
        opts: &SchedulerOptions,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
        run_start: usize,
        count: usize,
    ) -> Vec<RunResult> {
        let threads = resolve_threads(self.threads, count);
        parallel_ordered(count, threads, |i| {
            let mut sched = RoundScheduler::new(model);
            sched.record_every = self.record_every.max(1);
            opts.configure(&mut sched);
            let mut alg = make_alg();
            sched.run(alg.as_mut(), self.iters, self.seed, (run_start + i) as u64 + 1)
        })
    }

    /// [`Self::run_rust_opts`] through the lane engine (DESIGN.md §14):
    /// runs are packed `lanes` at a time into SoA blocks and advanced in
    /// lockstep, bit-identical to the scalar path at every
    /// lanes × threads combination. `lanes <= 1`, a non-static dynamics
    /// model or an algorithm without a batched face all fall back to the
    /// scalar runner, so this is always safe to call.
    pub fn run_rust_lanes_opts(
        &self,
        model: &DataModel,
        opts: &SchedulerOptions,
        lanes: usize,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
    ) -> McResult {
        self.merge(
            self.run_rust_lanes_range_opts(model, opts, lanes, make_alg, 0, self.runs)
                .into_iter(),
        )
    }

    /// [`Self::run_rust_range_opts`] through the lane engine: the block
    /// `[run_start, run_start + count)` is split into consecutive lane
    /// blocks of (at most) `lanes` runs, the blocks fan across
    /// [`MonteCarlo::threads`] workers, and the per-run results come
    /// back **in run order** — exactly the scalar range's realizations,
    /// byte for byte. This is also what a shard worker executes when the
    /// scenario requests lanes, so lanes × threads × shards all compose.
    ///
    /// Configurations without a batched path (scalar-only algorithms,
    /// network dynamics, single-run blocks) are routed to the scalar
    /// scheduler per block; mixed layouts still fold identically because
    /// both engines produce the same bytes.
    pub fn run_rust_lanes_range_opts(
        &self,
        model: &DataModel,
        opts: &SchedulerOptions,
        lanes: usize,
        make_alg: impl Fn() -> Box<dyn Algorithm> + Sync,
        run_start: usize,
        count: usize,
    ) -> Vec<RunResult> {
        let dynamic = opts.dynamics.as_ref().map(|d| !d.is_static()).unwrap_or(false);
        let batchable = lanes > 1 && !dynamic && make_alg().as_batch().is_some();
        if !batchable {
            return self.run_rust_range_opts(model, opts, make_alg, run_start, count);
        }
        let blocks: Vec<(usize, usize)> = (0..count)
            .step_by(lanes)
            .map(|off| (run_start + off, lanes.min(count - off)))
            .collect();
        let threads = resolve_threads(self.threads, blocks.len());
        let per_block = parallel_ordered(blocks.len(), threads, |i| {
            let (start, width) = blocks[i];
            if width == 1 {
                // A trailing singleton block gains nothing from SoA
                // packing; the scalar scheduler produces the same bytes.
                return self.run_rust_range_opts(model, opts, &make_alg, start, 1);
            }
            let mut alg = make_alg();
            super::lanes::run_lane_block(
                model,
                opts,
                alg.as_mut(),
                self.iters,
                self.seed,
                self.record_every.max(1),
                start,
                width,
            )
        });
        per_block.into_iter().flatten().collect()
    }

    /// Serial reference path (also the `threads == 1` fast path); the
    /// parallel runner must reproduce it bit-for-bit.
    pub fn run_rust_serial(
        &self,
        model: &DataModel,
        make_alg: impl Fn() -> Box<dyn Algorithm>,
    ) -> McResult {
        self.run_rust_serial_with(model, None, make_alg)
    }

    /// Serial reference path with an optional link-impairment model.
    pub fn run_rust_serial_with(
        &self,
        model: &DataModel,
        impairments: Option<&LinkImpairments>,
        make_alg: impl Fn() -> Box<dyn Algorithm>,
    ) -> McResult {
        self.run_rust_serial_opts(
            model,
            &SchedulerOptions::from_impairments(impairments),
            make_alg,
        )
    }

    /// Serial reference path with the full scheduler configuration.
    pub fn run_rust_serial_opts(
        &self,
        model: &DataModel,
        opts: &SchedulerOptions,
        make_alg: impl Fn() -> Box<dyn Algorithm>,
    ) -> McResult {
        let mut sched = RoundScheduler::new(model);
        sched.record_every = self.record_every.max(1);
        opts.configure(&mut sched);
        self.merge((0..self.runs).map(|r| {
            let mut alg = make_alg();
            sched.run(alg.as_mut(), self.iters, self.seed, r as u64 + 1)
        }))
    }

    /// Fold per-run results in run order (the order of the iterator) so
    /// the floating-point accumulation is independent of scheduling.
    /// The multi-process shard supervisor reuses this exact fold after
    /// reassembling worker outputs by run index, which is why sharded
    /// results stay bit-identical to [`Self::run_rust_serial`]
    /// (DESIGN.md §8).
    pub(crate) fn merge(&self, results: impl Iterator<Item = RunResult>) -> McResult {
        let mut acc = TraceAccumulator::new();
        let mut scalars = 0.0;
        let mut ledger = crate::algorithms::CommLedger::empty(0);
        let mut linkstate = LinkStateStats::default();
        for res in results {
            acc.add(&res.msd);
            scalars += res.ledger.scalars as f64;
            ledger.merge(&res.ledger);
            linkstate.merge(&res.linkstate);
        }
        let msd = acc.mean();
        let tail = (msd.len() / 10).max(1);
        McResult {
            steady_state: acc.steady_state(tail),
            msd,
            scalars_per_run: scalars / self.runs as f64,
            runs: self.runs,
            ledger,
            linkstate,
        }
    }

    /// Compiled engine: run the AOT module `<algo>_<config>` from the
    /// artifact manifest. `c`/`a`/`mu` follow the artifact layout.
    #[allow(clippy::too_many_arguments)]
    pub fn run_xla(
        &self,
        rt: &mut Runtime,
        config: &str,
        algo: &XlaAlgo,
        model: &DataModel,
        c: &[f32],
        a: &[f32],
        mu: &[f32],
    ) -> Result<McResult> {
        let spec = rt
            .manifest()
            .find(algo.module_algo(), config)
            .ok_or_else(|| anyhow!("no artifact for {}/{}", algo.module_algo(), config))?
            .clone();
        let (n, l, t) = (spec.n_nodes, spec.dim, spec.chunk_len);
        if n != model.n_nodes || l != model.dim {
            return Err(anyhow!(
                "artifact {} is ({n},{l}), model is ({},{})",
                spec.name,
                model.n_nodes,
                model.dim
            ));
        }
        let n_chunks = self.iters.div_ceil(t);
        let wo32 = model.wo_f32();
        let mut acc = TraceAccumulator::new();

        for r in 0..self.runs {
            let mut rng = Pcg64::new(self.seed, r as u64 + 1);
            let mut w = vec![0f32; n * l];
            let mut trace: Vec<f64> = Vec::with_capacity(n_chunks * t);
            let mut u_buf = vec![0f32; t * n * l];
            let mut d_buf = vec![0f32; t * n];
            let mut scratch = Vec::new();
            for _chunk in 0..n_chunks {
                model.sample_block_f32(&mut rng, t, &mut u_buf, &mut d_buf);
                let masks = gen_masks(algo, n, l, t, &mut rng, &mut scratch);
                let mut inputs: Vec<&[f32]> = vec![&w, &u_buf, &d_buf];
                for m in &masks {
                    inputs.push(m);
                }
                match algo {
                    XlaAlgo::Dcd { .. } | XlaAlgo::Atc => inputs.push(c),
                    _ => {}
                }
                inputs.push(a);
                inputs.push(mu);
                inputs.push(&wo32);
                let out = rt.execute_chunk(&spec.name, &inputs)?;
                w = out.w_final;
                // Per-node squared deviations -> network MSD per step.
                for step in 0..t {
                    let row = &out.msd[step * n..(step + 1) * n];
                    trace.push(row.iter().map(|&x| x as f64).sum::<f64>() / n as f64);
                }
            }
            trace.truncate(self.iters);
            let rec = self.record_every.max(1);
            let thinned: Vec<f64> = trace
                .iter()
                .copied()
                .enumerate()
                .filter(|(i, _)| (i + 1) % rec == 0)
                .map(|(_, v)| v)
                .collect();
            acc.add(&thinned);
        }
        let msd = acc.mean();
        let tail = (msd.len() / 10).max(1);
        Ok(McResult {
            steady_state: acc.steady_state(tail),
            msd,
            scalars_per_run: 0.0,
            runs: self.runs,
            ledger: crate::algorithms::CommLedger::empty(0),
            linkstate: LinkStateStats::default(),
        })
    }
}

/// Generate per-chunk mask tensors in the artifact layout.
fn gen_masks(
    algo: &XlaAlgo,
    n: usize,
    l: usize,
    t: usize,
    rng: &mut Pcg64,
    scratch: &mut Vec<usize>,
) -> Vec<Vec<f32>> {
    match algo {
        XlaAlgo::Dcd { m, m_grad } => {
            let mut h = vec![0f32; t * n * l];
            let mut q = vec![0f32; t * n * l];
            for slot in 0..t * n {
                rng.fill_mask(&mut h[slot * l..(slot + 1) * l], *m, scratch);
                rng.fill_mask(&mut q[slot * l..(slot + 1) * l], *m_grad, scratch);
            }
            vec![h, q]
        }
        XlaAlgo::Atc => vec![],
        XlaAlgo::Rcd { m_links } => {
            // S[t, l, k] = 1 iff node k polls neighbour l. Off-graph pairs
            // stay 0; the step function multiplies by A's support anyway,
            // but we only select true neighbours: that requires the graph,
            // which the artifact does not carry — instead we select among
            // *all* other nodes and rely on A's zero weights to nullify
            // non-neighbours. To keep the effective poll count right we
            // select among the support of column k of A, encoded by the
            // caller via `XLA_RCD_SUPPORT` thread-local (see set_rcd_support).
            let mut s = vec![0f32; t * n * n];
            RCD_SUPPORT.with(|sup| {
                let sup = sup.borrow();
                let support = sup.as_ref().expect(
                    "set_rcd_support(graph) must be called before running the rcd xla engine",
                );
                for ti in 0..t {
                    for k in 0..n {
                        let nbrs = &support[k];
                        let m = (*m_links).min(nbrs.len());
                        rng.sample_indices(nbrs.len(), m, scratch);
                        for &idx in scratch.iter() {
                            s[ti * n * n + nbrs[idx] * n + k] = 1.0;
                        }
                    }
                }
            });
            vec![s]
        }
        XlaAlgo::Partial { m } => {
            let mut h = vec![0f32; t * n * l];
            for slot in 0..t * n {
                rng.fill_mask(&mut h[slot * l..(slot + 1) * l], *m, scratch);
            }
            vec![h]
        }
    }
}

thread_local! {
    static RCD_SUPPORT: std::cell::RefCell<Option<Vec<Vec<usize>>>> =
        const { std::cell::RefCell::new(None) };
}

/// Register the neighbour lists used by the RCD mask generator (the HLO
/// artifact is topology-agnostic; selection must follow the graph).
pub fn set_rcd_support(graph: &crate::topology::Graph) {
    let lists: Vec<Vec<usize>> = (0..graph.n()).map(|k| graph.neighbors(k).to_vec()).collect();
    RCD_SUPPORT.with(|s| *s.borrow_mut() = Some(lists));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    fn small_case() -> (DataModel, NetworkConfig) {
        let mut rng = Pcg64::new(5, 0);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::topology::Combiner::eye(5);
        (model, NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 })
    }

    #[test]
    fn rust_engine_mc_converges() {
        let (model, net) = small_case();
        let mc = MonteCarlo { runs: 4, iters: 500, seed: 11, record_every: 1, threads: 0 };
        let res = mc.run_rust(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        assert_eq!(res.msd.len(), 500);
        assert!(res.steady_state < res.msd[0]);
        assert!(res.scalars_per_run > 0.0);
        assert_eq!(res.runs, 4);
    }

    /// The parallel runner must reproduce the serial runner bit-for-bit
    /// at 1, 2 and 4 worker threads (per-realization PCG64 streams +
    /// run-order merge).
    #[test]
    fn parallel_runner_bit_identical_to_serial() {
        let (model, net) = small_case();
        let base = MonteCarlo { runs: 6, iters: 300, seed: 17, record_every: 2, threads: 1 };
        let serial = base.run_rust_serial(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        for threads in [1usize, 2, 4] {
            let mc = MonteCarlo { threads, ..base.clone() };
            let par = mc.run_rust(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
            assert_eq!(par.msd, serial.msd, "threads = {threads}");
            assert_eq!(
                par.steady_state.to_bits(),
                serial.steady_state.to_bits(),
                "threads = {threads}"
            );
            assert_eq!(par.scalars_per_run.to_bits(), serial.scalars_per_run.to_bits());
            assert_eq!(par.ledger, serial.ledger, "threads = {threads}");
            assert_eq!(par.runs, serial.runs);
        }
    }

    /// The impairment layer preserves the bit-identity guarantee: its
    /// decisions come from a per-run stream, not from shared state.
    #[test]
    fn impaired_parallel_bit_identical_to_serial() {
        use crate::coordinator::impairments::{Gating, LinkImpairments};
        let (model, net) = small_case();
        let imp = LinkImpairments {
            drop: crate::coordinator::impairments::DropModel::Iid(0.3),
            gating: Gating::Probabilistic(0.8),
            quant_step: 1e-4,
            per_leg: false,
        };
        let base = MonteCarlo { runs: 6, iters: 200, seed: 23, record_every: 1, threads: 1 };
        let serial =
            base.run_rust_serial_with(&model, Some(&imp), || Box::new(Dcd::new(net.clone(), 2, 1)));
        for threads in [2usize, 4] {
            let mc = MonteCarlo { threads, ..base.clone() };
            let par =
                mc.run_rust_with(&model, Some(&imp), || Box::new(Dcd::new(net.clone(), 2, 1)));
            assert_eq!(par.msd, serial.msd, "threads = {threads}");
            assert_eq!(par.scalars_per_run.to_bits(), serial.scalars_per_run.to_bits());
            assert_eq!(par.ledger, serial.ledger, "threads = {threads}");
        }
        // And the impairment stream never perturbs the data stream: the
        // ideal run matches the plain runner bit-for-bit.
        let plain = base.run_rust(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        let ideal = base.run_rust_with(&model, Some(&LinkImpairments::ideal()), || {
            Box::new(Dcd::new(net.clone(), 2, 1))
        });
        assert_eq!(plain.msd, ideal.msd);
    }

    /// Every dynamic axis (markov drops, churn, drift, adaptive
    /// combiners) draws from per-run streams, so the parallel runner
    /// stays bit-identical to the serial one — and the linkstate
    /// occupancy counters merge order-independently.
    #[test]
    fn dynamic_axes_parallel_bit_identical_to_serial() {
        use crate::coordinator::dynamics::DynamicsConfig;
        use crate::coordinator::impairments::{AdaptivePolicy, DropModel, LinkImpairments};
        let (model, _) = small_case();
        // Metropolis A so churn/adaptive actually re-weight something.
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let opts = SchedulerOptions {
            impairments: Some(LinkImpairments {
                drop: DropModel::Markov { p_bad: 0.25, p_gb: 0.3, p_bg: 0.3 },
                ..LinkImpairments::ideal()
            }),
            dynamics: Some(DynamicsConfig {
                leave: 0.01,
                join: 0.2,
                require_connected: true,
                adaptive: AdaptivePolicy::Metropolis,
                ..DynamicsConfig::default()
            }),
            drift: DriftModel::Walk { sigma: 1e-3 },
        };
        let base = MonteCarlo { runs: 6, iters: 200, seed: 29, record_every: 1, threads: 1 };
        let serial =
            base.run_rust_serial_opts(&model, &opts, || Box::new(Dcd::new(net.clone(), 2, 1)));
        assert!(!serial.linkstate.is_empty(), "bursty chain must tally occupancy");
        for threads in [2usize, 4] {
            let mc = MonteCarlo { threads, ..base.clone() };
            let par = mc.run_rust_opts(&model, &opts, || Box::new(Dcd::new(net.clone(), 2, 1)));
            assert_eq!(par.msd, serial.msd, "threads = {threads}");
            assert_eq!(par.ledger, serial.ledger, "threads = {threads}");
            assert_eq!(par.linkstate, serial.linkstate, "threads = {threads}");
        }
        // Default options are exactly the historical plain path.
        let plain = base.run_rust(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        let defaulted = base.run_rust_opts(&model, &SchedulerOptions::default(), || {
            Box::new(Dcd::new(net.clone(), 2, 1))
        });
        assert_eq!(plain.msd, defaulted.msd);
        assert_eq!(plain.ledger, defaulted.ledger);
    }

    /// The lane engine reproduces the serial runner bit-for-bit at
    /// every lanes × threads combination, ideal and impaired, including
    /// a lane width that does not divide the run count (trailing
    /// partial + singleton blocks).
    #[test]
    fn laned_runner_bit_identical_to_serial() {
        use crate::algorithms::DiffusionLms;
        use crate::coordinator::impairments::{Gating, LinkImpairments};
        let (model, _) = small_case();
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let impaired = SchedulerOptions {
            impairments: Some(LinkImpairments {
                drop: crate::coordinator::impairments::DropModel::Iid(0.3),
                gating: Gating::Probabilistic(0.8),
                quant_step: 1e-4,
                per_leg: false,
            }),
            ..SchedulerOptions::default()
        };
        for opts in [SchedulerOptions::default(), impaired] {
            let base = MonteCarlo { runs: 7, iters: 150, seed: 19, record_every: 1, threads: 1 };
            let serial = base
                .run_rust_serial_opts(&model, &opts, || Box::new(DiffusionLms::new(net.clone())));
            for lanes in [1usize, 2, 3, 4, 16] {
                for threads in [1usize, 2] {
                    let mc = MonteCarlo { threads, ..base.clone() };
                    let laned = mc.run_rust_lanes_opts(&model, &opts, lanes, || {
                        Box::new(DiffusionLms::new(net.clone()))
                    });
                    assert_eq!(laned.msd, serial.msd, "lanes {lanes} threads {threads}");
                    assert_eq!(
                        laned.steady_state.to_bits(),
                        serial.steady_state.to_bits(),
                        "lanes {lanes} threads {threads}"
                    );
                    assert_eq!(laned.ledger, serial.ledger, "lanes {lanes} threads {threads}");
                    assert_eq!(laned.runs, serial.runs);
                }
            }
        }
    }

    /// Laned ranges slot into the shard fold: per-run results from lane
    /// blocks concatenate to exactly the serial realizations.
    #[test]
    fn laned_range_runs_merge_to_full_result() {
        let (model, net) = small_case();
        let mc = MonteCarlo { runs: 7, iters: 150, seed: 37, record_every: 1, threads: 1 };
        let serial = mc.run_rust_serial(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        let opts = SchedulerOptions::default();
        for shards in [1usize, 2, 3] {
            let mut pieces = Vec::new();
            for (start, count) in shard_ranges(mc.runs, shards) {
                pieces.extend(mc.run_rust_lanes_range_opts(
                    &model,
                    &opts,
                    4,
                    || Box::new(Dcd::new(net.clone(), 2, 1)),
                    start,
                    count,
                ));
            }
            let merged = mc.merge(pieces.into_iter());
            assert_eq!(merged.msd, serial.msd, "shards = {shards}");
            assert_eq!(merged.ledger, serial.ledger, "shards = {shards}");
        }
        // A scalar-only configuration (noisy DCD links) silently takes
        // the scalar path and still reproduces the serial bytes.
        let noisy_serial = mc.run_rust_serial(&model, || {
            Box::new(Dcd::new(net.clone(), 2, 1).with_link_noise(0.05))
        });
        let noisy_laned = mc.run_rust_lanes_opts(&model, &opts, 4, || {
            Box::new(Dcd::new(net.clone(), 2, 1).with_link_noise(0.05))
        });
        assert_eq!(noisy_laned.msd, noisy_serial.msd);
        assert_eq!(noisy_laned.ledger, noisy_serial.ledger);
    }

    /// Contiguous shard plans: cover every run exactly once, in order,
    /// as evenly as possible, and never emit empty ranges.
    #[test]
    fn shard_plan_covers_runs_contiguously() {
        assert_eq!(shard_ranges(10, 1), vec![(0, 10)]);
        assert_eq!(shard_ranges(10, 2), vec![(0, 5), (5, 5)]);
        assert_eq!(shard_ranges(10, 4), vec![(0, 3), (3, 3), (6, 2), (8, 2)]);
        assert_eq!(shard_ranges(3, 8), vec![(0, 1), (1, 1), (2, 1)]);
        assert_eq!(shard_ranges(5, 0), vec![(0, 5)]); // clamped to 1
        assert_eq!(shard_ranges(0, 4), Vec::<(usize, usize)>::new());
        for (runs, shards) in [(100, 7), (17, 4), (1, 1), (2, 2)] {
            let plan = shard_ranges(runs, shards);
            let mut next = 0;
            for &(start, count) in &plan {
                assert_eq!(start, next, "gap in plan {plan:?}");
                assert!(count > 0);
                next = start + count;
            }
            assert_eq!(next, runs, "plan {plan:?} does not cover {runs} runs");
        }
    }

    /// Per-run results from a sharded range plan, concatenated in run
    /// order, are exactly the serial runner's realizations: merging them
    /// reproduces the full result bit-for-bit.
    #[test]
    fn range_runs_merge_to_full_result() {
        let (model, net) = small_case();
        let mc = MonteCarlo { runs: 7, iters: 200, seed: 31, record_every: 1, threads: 1 };
        let serial = mc.run_rust_serial(&model, || Box::new(Dcd::new(net.clone(), 2, 1)));
        for shards in [2usize, 3, 7] {
            let mut pieces = Vec::new();
            for (start, count) in shard_ranges(mc.runs, shards) {
                pieces.extend(mc.run_rust_range(
                    &model,
                    None,
                    || Box::new(Dcd::new(net.clone(), 2, 1)),
                    start,
                    count,
                ));
            }
            let merged = mc.merge(pieces.into_iter());
            assert_eq!(merged.msd, serial.msd, "shards = {shards}");
            assert_eq!(
                merged.steady_state.to_bits(),
                serial.steady_state.to_bits(),
                "shards = {shards}"
            );
            assert_eq!(
                merged.scalars_per_run.to_bits(),
                serial.scalars_per_run.to_bits()
            );
            assert_eq!(merged.ledger, serial.ledger, "shards = {shards}");
        }
    }

    /// resolve_threads: explicit request wins and is capped by the job
    /// count; auto mode always yields at least one worker.
    #[test]
    fn thread_resolution_rules() {
        assert_eq!(resolve_threads(3, 100), 3);
        assert_eq!(resolve_threads(8, 2), 2);
        assert_eq!(resolve_threads(1, 0), 1);
        assert!(resolve_threads(0, 64) >= 1);
    }
}
