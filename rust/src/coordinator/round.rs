//! Synchronous round scheduler: drives any [`Algorithm`] over streaming
//! data from a [`DataModel`], recording MSD traces and communication
//! costs (Experiments 1 and 2).
//!
//! When [`RoundScheduler::impairments`] is set (and not a no-op), every
//! iteration is wrapped by the link-impairment layer of
//! [`super::impairments`]: link events are drawn from a dedicated RNG
//! stream, the algorithm's combination matrices are swapped for that
//! iteration's effective versions, gated transmitters are muted in the
//! meter, and the post-step state is quantized. With `impairments: None`
//! the code path is byte-for-byte the legacy ideal-links loop.

use crate::algorithms::{Algorithm, CommLedger, CommMeter, StepData};
use crate::datamodel::{DataModel, DriftModel};
use crate::rng::Pcg64;

use super::dynamics::{DynamicsConfig, DynamicsState};
use super::impairments::{quantize_in_place, ImpairmentState, LinkImpairments, LinkStateStats};

/// Result of a single run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Network MSD (linear) after each iteration.
    pub msd: Vec<f64>,
    /// The run's directional communication bill: billed scalars/bits
    /// with per-node, per-link and per-purpose breakdowns
    /// (DESIGN.md §9).
    pub ledger: CommLedger,
    /// Markov link-state occupancy counters (DESIGN.md §12); empty for
    /// i.i.d. drop models, which never sample the chain.
    pub linkstate: LinkStateStats,
}

/// Synchronous round scheduler.
pub struct RoundScheduler<'a> {
    /// The streaming data source every iteration samples from.
    pub model: &'a DataModel,
    /// Record MSD every `record_every` iterations (1 = every iteration).
    pub record_every: usize,
    /// Optional link-impairment model wrapped around every iteration
    /// (`None` = ideal links, the exact legacy path).
    pub impairments: Option<LinkImpairments>,
    /// Optional network-dynamics model — churn, mobility rewiring and
    /// the adaptive-combiner policy (`None`/static = the legacy path).
    pub dynamics: Option<DynamicsConfig>,
    /// Time variation of the optimum w°(i) for tracking experiments
    /// ([`DriftModel::None`] = the paper's fixed w°).
    pub drift: DriftModel,
}

impl<'a> RoundScheduler<'a> {
    /// A scheduler over `model` recording every iteration, ideal links.
    pub fn new(model: &'a DataModel) -> Self {
        Self {
            model,
            record_every: 1,
            impairments: None,
            dynamics: None,
            drift: DriftModel::None,
        }
    }

    /// Run `iters` iterations of `alg` with the given seed; the algorithm
    /// is reset first.
    pub fn run(&self, alg: &mut dyn Algorithm, iters: usize, seed: u64, stream: u64) -> RunResult {
        let n = self.model.n_nodes;
        let l = self.model.dim;
        let mut rng = Pcg64::new(seed, stream);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut msd = Vec::with_capacity(iters / self.record_every + 1);
        // The impairment layer activates only for a non-trivial model, so
        // ideal runs take the legacy path (and never touch the link RNG);
        // quantization-only models skip the link-event state entirely.
        let imp = self.impairments.as_ref().filter(|imp| !imp.is_ideal());
        if let Some(imp) = imp {
            // Quantized payloads cost fewer bits per scalar (§9).
            comm.set_quant_step(imp.quant_step);
        }
        // Network dynamics ride the same per-iteration rebuild machinery
        // as link events, so an active dynamics layer forces the
        // impairment state into existence even under ideal links.
        let mut dyn_state = self
            .dynamics
            .as_ref()
            .filter(|d| !d.is_static())
            .map(|d| DynamicsState::new(d.clone(), alg.network(), seed, stream));
        let ideal = LinkImpairments::ideal();
        let imp_link = imp.unwrap_or(&ideal);
        let mut state = match imp {
            Some(i) if i.affects_links() => {
                Some(ImpairmentState::new(alg.network(), seed, stream))
            }
            _ if dyn_state.is_some() => Some(ImpairmentState::new(alg.network(), seed, stream)),
            _ => None,
        };
        // The drifting optimum is part of the data process: it advances
        // from the data RNG, before each snapshot, and the MSD is always
        // measured against the *current* w°(i). A no-drift model draws
        // nothing, so static scenarios stay byte-identical.
        let drifting = !self.drift.is_none();
        let mut wo_cur = self.model.wo.clone();
        alg.reset();
        for i in 0..iters {
            if drifting {
                self.drift.advance(&mut wo_cur, &mut rng);
            }
            self.model.sample_iteration_at(&wo_cur, &mut rng, &mut u, &mut d);
            if let Some(state) = state.as_mut() {
                state.begin_iteration_dynamic(imp_link, dyn_state.as_mut(), alg, &mut comm);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            if let Some(imp) = imp {
                if imp.quant_step > 0.0 {
                    quantize_in_place(alg.weights_mut(), imp.quant_step);
                }
            }
            if (i + 1) % self.record_every == 0 {
                msd.push(alg.msd(&wo_cur));
            }
        }
        if let Some(ds) = &dyn_state {
            ds.restore(alg);
        }
        let linkstate = match state {
            Some(s) => {
                s.restore(alg, &mut comm);
                s.into_stats()
            }
            None => LinkStateStats::default(),
        };
        RunResult { msd, ledger: comm.into_ledger(), linkstate }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    #[test]
    fn scheduler_records_and_meters() {
        let mut rng = Pcg64::new(2, 2);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::topology::Combiner::eye(5);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let mut alg = Dcd::new(net, 2, 1);
        let sched = RoundScheduler::new(&model);
        let res = sched.run(&mut alg, 400, 7, 0);
        assert_eq!(res.msd.len(), 400);
        assert!(res.msd[399] < res.msd[0]);
        // 5 nodes x 2 neighbours x (2 + 1) scalars x 400 iterations.
        assert_eq!(res.ledger.scalars, 5 * 2 * 3 * 400);
        assert_eq!(res.ledger.bits(), 5 * 2 * 3 * 400 * 64);
        assert_eq!(res.ledger.suppressed_scalars, 0);
    }

    #[test]
    fn record_every_thins_trace() {
        let mut rng = Pcg64::new(3, 3);
        let model = DataModel::paper(4, 2, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(4, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 4], dim: 2 };
        let mut alg = Dcd::new(net, 1, 1);
        let mut sched = RoundScheduler::new(&model);
        sched.record_every = 10;
        let res = sched.run(&mut alg, 100, 1, 0);
        assert_eq!(res.msd.len(), 10);
    }

    #[test]
    fn trivial_impairments_match_ideal_path_exactly() {
        let mut rng = Pcg64::new(6, 6);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let ideal = RoundScheduler::new(&model);
        let mut wrapped = RoundScheduler::new(&model);
        wrapped.impairments = Some(crate::coordinator::impairments::LinkImpairments::ideal());
        let mut a1 = Dcd::new(net.clone(), 2, 1);
        let mut a2 = Dcd::new(net, 2, 1);
        let r1 = ideal.run(&mut a1, 120, 3, 1);
        let r2 = wrapped.run(&mut a2, 120, 3, 1);
        assert_eq!(r1.msd, r2.msd);
        assert_eq!(r1.ledger, r2.ledger);
    }

    #[test]
    fn drops_degrade_msd_and_suppress_dead_replies() {
        use crate::coordinator::impairments::LinkImpairments;
        let mut rng = Pcg64::new(8, 8);
        let model = DataModel::paper(6, 4, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(6, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 6], dim: 4 };
        let run_with = |drop_prob: f64| {
            let mut sched = RoundScheduler::new(&model);
            sched.impairments = Some(LinkImpairments::with_drop_prob(drop_prob));
            let mut alg = Dcd::new(net.clone(), 2, 1);
            sched.run(&mut alg, 2_000, 5, 1)
        };
        let clean = run_with(0.0);
        let lossy = run_with(0.6);
        // Estimate broadcasts are billed whether or not the packet lands
        // (transmitter pays), but a gradient reply whose soliciting
        // broadcast was erased is never transmitted: the exact bill is
        // strictly below the old transmitter-only meter's, and the two
        // reconcile through the suppressed counter (DESIGN.md §9).
        use crate::algorithms::Purpose;
        assert_eq!(
            clean.ledger.purpose_scalars(Purpose::Estimate),
            lossy.ledger.purpose_scalars(Purpose::Estimate)
        );
        assert!(
            lossy.ledger.scalars < clean.ledger.scalars,
            "lossy bill {} not below clean {}",
            lossy.ledger.scalars,
            clean.ledger.scalars
        );
        assert!(lossy.ledger.suppressed_scalars > 0);
        assert_eq!(lossy.ledger.legacy_scalars(), clean.ledger.scalars);
        let tail = |r: &RunResult| r.msd[1_800..].iter().sum::<f64>() / 200.0;
        assert!(
            tail(&lossy) > tail(&clean),
            "lossy {} <= clean {}",
            tail(&lossy),
            tail(&clean)
        );
        assert!(tail(&lossy).is_finite());
    }

    #[test]
    fn gating_cuts_billing_roughly_in_half() {
        use crate::coordinator::impairments::{Gating, LinkImpairments};
        let mut rng = Pcg64::new(9, 9);
        let model = DataModel::paper(6, 4, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(6, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 6], dim: 4 };
        let run_with = |gating: Gating| {
            let mut sched = RoundScheduler::new(&model);
            sched.impairments =
                Some(LinkImpairments { gating, ..LinkImpairments::ideal() });
            let mut alg = Dcd::new(net.clone(), 2, 1);
            sched.run(&mut alg, 1_000, 5, 1)
        };
        let always = run_with(Gating::Always);
        let half = run_with(Gating::Probabilistic(0.5));
        // The old transmitter-only bill halves with the gate...
        let legacy_ratio =
            half.ledger.legacy_scalars() as f64 / always.ledger.scalars as f64;
        assert!((0.4..0.6).contains(&legacy_ratio), "legacy ratio {legacy_ratio}");
        // ... and the exact bill is strictly lower still: a reply leg
        // needs the soliciting node on the air too (rate p² not p), so
        // with DCD(2, 1) the expectation is (p·2 + p²·1)/3 = 5/12.
        let exact_ratio = half.ledger.scalars as f64 / always.ledger.scalars as f64;
        assert!(
            exact_ratio < legacy_ratio,
            "exact {exact_ratio} not below legacy {legacy_ratio}"
        );
        assert!((0.33..0.5).contains(&exact_ratio), "exact ratio {exact_ratio}");
    }

    #[test]
    fn quantized_state_stays_on_grid() {
        use crate::coordinator::impairments::LinkImpairments;
        let mut rng = Pcg64::new(10, 10);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let step = 1e-3;
        let mut sched = RoundScheduler::new(&model);
        sched.impairments = Some(LinkImpairments {
            quant_step: step,
            ..LinkImpairments::ideal()
        });
        let mut alg = Dcd::new(net, 2, 1);
        let res = sched.run(&mut alg, 800, 5, 1);
        for &x in alg.weights() {
            let q = x / step;
            assert!((q - q.round()).abs() < 1e-6, "{x} off the grid");
        }
        // Still converges to within a few grid cells of the target.
        assert!(res.msd[799] < res.msd[0]);
        // Quantized payloads are billed at the grid-index width, not 64
        // bits per scalar (DESIGN.md §9).
        assert_eq!(
            res.ledger.bits_per_scalar,
            crate::energy::payload_bits(step)
        );
        assert!(res.ledger.bits() < res.ledger.scalars * 64);
    }

    /// The byte-identity contract of DESIGN.md §12: a zero-memory
    /// Markov spec redraws the chain every sample and must therefore
    /// reproduce the i.i.d. path bit for bit — MSD, ledger, everything.
    #[test]
    fn memoryless_markov_is_bitwise_iid() {
        use crate::coordinator::impairments::{DropModel, LinkImpairments};
        let mut rng = Pcg64::new(12, 12);
        let model = DataModel::paper(6, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(6, 2);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 6], dim: 3 };
        let run_with = |drop: DropModel| {
            let mut sched = RoundScheduler::new(&model);
            sched.impairments =
                Some(LinkImpairments { drop, ..LinkImpairments::ideal() });
            let mut alg = Dcd::new(net.clone(), 2, 1);
            sched.run(&mut alg, 500, 5, 1)
        };
        let iid = run_with(DropModel::Iid(0.3));
        let mk = run_with(DropModel::Markov { p_bad: 0.3, p_gb: 1.0, p_bg: 1.0 });
        assert_eq!(iid.msd, mk.msd);
        assert_eq!(iid.ledger, mk.ledger);
        // Memoryless chains never sample chain state; bursty ones do.
        assert!(iid.linkstate.is_empty());
        assert!(mk.linkstate.is_empty());
        let bursty = run_with(DropModel::Markov { p_bad: 0.3, p_gb: 0.2, p_bg: 0.2 });
        assert!(!bursty.linkstate.is_empty());
        assert!(bursty.linkstate.bad_fraction().unwrap() > 0.0);
        assert!(bursty.msd[499].is_finite());
    }

    /// Drift integrates with the scheduler: a random-walk optimum keeps
    /// the steady-state MSD strictly above the static run's, and a
    /// `DriftModel::None` scheduler is byte-identical to the legacy path.
    #[test]
    fn drifting_optimum_raises_tracking_floor() {
        let mut rng = Pcg64::new(14, 14);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let run_with = |drift: DriftModel| {
            let mut sched = RoundScheduler::new(&model);
            sched.drift = drift;
            let mut alg = Dcd::new(net.clone(), 2, 1);
            sched.run(&mut alg, 2_000, 5, 1)
        };
        let fixed = run_with(DriftModel::None);
        let legacy = {
            let sched = RoundScheduler::new(&model);
            let mut alg = Dcd::new(net.clone(), 2, 1);
            sched.run(&mut alg, 2_000, 5, 1)
        };
        assert_eq!(fixed.msd, legacy.msd);
        let walk = run_with(DriftModel::Walk { sigma: 5e-3 });
        let tail = |r: &RunResult| r.msd[1_800..].iter().sum::<f64>() / 200.0;
        assert!(
            tail(&walk) > 3.0 * tail(&fixed),
            "walk tail {} not above static tail {}",
            tail(&walk),
            tail(&fixed)
        );
        let rot = run_with(DriftModel::Rotate { omega: 0.02 });
        assert!(tail(&rot) > tail(&fixed));
    }

    #[test]
    fn identical_seeds_identical_runs() {
        let mut rng = Pcg64::new(4, 4);
        let model = DataModel::paper(4, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(4, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::topology::Combiner::eye(4);
        let net = NetworkConfig { graph, c, a, mu: vec![0.03; 4], dim: 3 };
        let sched = RoundScheduler::new(&model);
        let mut a1 = Dcd::new(net.clone(), 2, 1);
        let mut a2 = Dcd::new(net, 2, 1);
        let r1 = sched.run(&mut a1, 50, 9, 1);
        let r2 = sched.run(&mut a2, 50, 9, 1);
        assert_eq!(r1.msd, r2.msd);
    }
}
