//! Synchronous round scheduler: drives any [`Algorithm`] over streaming
//! data from a [`DataModel`], recording MSD traces and communication
//! costs (Experiments 1 and 2).

use crate::algorithms::{Algorithm, CommMeter, StepData};
use crate::datamodel::DataModel;
use crate::rng::Pcg64;

/// Result of a single run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Network MSD (linear) after each iteration.
    pub msd: Vec<f64>,
    /// Total scalars transmitted.
    pub scalars: u64,
    /// Total messages transmitted.
    pub messages: u64,
}

/// Synchronous round scheduler.
pub struct RoundScheduler<'a> {
    pub model: &'a DataModel,
    /// Record MSD every `record_every` iterations (1 = every iteration).
    pub record_every: usize,
}

impl<'a> RoundScheduler<'a> {
    pub fn new(model: &'a DataModel) -> Self {
        Self { model, record_every: 1 }
    }

    /// Run `iters` iterations of `alg` with the given seed; the algorithm
    /// is reset first.
    pub fn run(&self, alg: &mut dyn Algorithm, iters: usize, seed: u64, stream: u64) -> RunResult {
        let n = self.model.n_nodes;
        let l = self.model.dim;
        let mut rng = Pcg64::new(seed, stream);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut msd = Vec::with_capacity(iters / self.record_every + 1);
        alg.reset();
        for i in 0..iters {
            self.model.sample_iteration(&mut rng, &mut u, &mut d);
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            if (i + 1) % self.record_every == 0 {
                msd.push(alg.msd(&self.model.wo));
            }
        }
        RunResult { msd, scalars: comm.scalars, messages: comm.messages }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    #[test]
    fn scheduler_records_and_meters() {
        let mut rng = Pcg64::new(2, 2);
        let model = DataModel::paper(5, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(5, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::linalg::Mat::eye(5);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 5], dim: 3 };
        let mut alg = Dcd::new(net, 2, 1);
        let sched = RoundScheduler::new(&model);
        let res = sched.run(&mut alg, 400, 7, 0);
        assert_eq!(res.msd.len(), 400);
        assert!(res.msd[399] < res.msd[0]);
        // 5 nodes x 2 neighbours x (2 + 1) scalars x 400 iterations.
        assert_eq!(res.scalars, 5 * 2 * 3 * 400);
    }

    #[test]
    fn record_every_thins_trace() {
        let mut rng = Pcg64::new(3, 3);
        let model = DataModel::paper(4, 2, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(4, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.05; 4], dim: 2 };
        let mut alg = Dcd::new(net, 1, 1);
        let mut sched = RoundScheduler::new(&model);
        sched.record_every = 10;
        let res = sched.run(&mut alg, 100, 1, 0);
        assert_eq!(res.msd.len(), 10);
    }

    #[test]
    fn identical_seeds_identical_runs() {
        let mut rng = Pcg64::new(4, 4);
        let model = DataModel::paper(4, 3, 1.0, 1.0, 1e-3, &mut rng);
        let graph = Graph::ring(4, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::linalg::Mat::eye(4);
        let net = NetworkConfig { graph, c, a, mu: vec![0.03; 4], dim: 3 };
        let sched = RoundScheduler::new(&model);
        let mut a1 = Dcd::new(net.clone(), 2, 1);
        let mut a2 = Dcd::new(net, 2, 1);
        let r1 = sched.run(&mut a1, 50, 9, 1);
        let r2 = sched.run(&mut a2, 50, 9, 1);
        assert_eq!(r1.msd, r2.msd);
    }
}
