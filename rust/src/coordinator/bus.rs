//! Message bus: the wire protocol of Alg. 1 as typed messages with
//! per-node mailboxes and delivery accounting.
//!
//! Entries travel as (index, value) pairs — exactly what a mote would put
//! in a frame for a partial vector. The bus is deliberately simple:
//! `send` enqueues into the destination mailbox, `drain` empties it.
//! It is `Send + Sync` (mutex-guarded mailboxes) so the same code runs
//! under the deterministic scheduler and under thread-per-agent tests.
//!
//! Accounting goes through the same directional [`CommMeter`] ledger the
//! frame-level engine bills into (DESIGN.md §9): every message carries
//! its `(source, destination, purpose)` triple, `send_lossy` records
//! in-flight erasures in the ledger's dropped counters, and
//! [`Bus::set_quant_step`] installs the quantized payload width — the
//! message-level and matrix-level engines share one metering model
//! instead of two parallel counter sets.

use crate::algorithms::{CommLedger, CommMeter, Purpose};
use std::collections::VecDeque;
use std::sync::Mutex;

/// A partial vector: selected entries of an L-vector.
#[derive(Debug, Clone, PartialEq)]
pub struct PartialVector {
    /// Selected indices (ascending).
    pub idx: Vec<u16>,
    /// Values, aligned with `idx`.
    pub val: Vec<f64>,
}

impl PartialVector {
    /// Extract the masked entries of `full` (mask = 0/1 slice).
    pub fn from_mask(full: &[f64], mask: &[f64]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, (&x, &m)) in full.iter().zip(mask.iter()).enumerate() {
            if m != 0.0 {
                idx.push(i as u16);
                val.push(x);
            }
        }
        Self { idx, val }
    }

    /// Scatter into `out`, leaving unlisted entries untouched (the
    /// receiver's own values fill the gaps — the paper's completion rule).
    pub fn fill_into(&self, out: &mut [f64]) {
        for (&i, &v) in self.idx.iter().zip(self.val.iter()) {
            out[i as usize] = v;
        }
    }

    pub fn len(&self) -> usize {
        self.idx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.idx.is_empty()
    }
}

/// Protocol messages of the DCD exchange (Alg. 1 lines 4–5).
#[derive(Debug, Clone, PartialEq)]
pub enum Message {
    /// Adapt phase, k → l: the masked estimate H_k ∘ w_k.
    Estimate { from: usize, body: PartialVector },
    /// Adapt phase, l → k: the masked gradient Q_l ∘ ∇J_l(filled point).
    Gradient { from: usize, body: PartialVector },
}

impl Message {
    pub fn from_node(&self) -> usize {
        match self {
            Message::Estimate { from, .. } | Message::Gradient { from, .. } => *from,
        }
    }

    pub fn scalar_count(&self) -> usize {
        match self {
            Message::Estimate { body, .. } | Message::Gradient { body, .. } => body.len(),
        }
    }

    /// The ledger purpose of this message (DESIGN.md §9).
    pub fn purpose(&self) -> Purpose {
        match self {
            Message::Estimate { .. } => Purpose::Estimate,
            Message::Gradient { .. } => Purpose::Gradient,
        }
    }
}

/// Per-node mailboxes billing into the shared directional ledger.
pub struct Bus {
    mailboxes: Vec<Mutex<VecDeque<Message>>>,
    ledger: Mutex<CommMeter>,
}

impl Bus {
    /// A bus with one empty mailbox per node and a zeroed ledger.
    pub fn new(n_nodes: usize) -> Self {
        Self {
            mailboxes: (0..n_nodes).map(|_| Mutex::new(VecDeque::new())).collect(),
            ledger: Mutex::new(CommMeter::new(n_nodes)),
        }
    }

    /// Number of mailboxes (nodes) on the bus.
    pub fn n_nodes(&self) -> usize {
        self.mailboxes.len()
    }

    /// Install the quantized payload width (Δ grid) for billed bits —
    /// the accounting face of [`super::agent::Agent::set_quant_step`],
    /// which quantizes the transmitted values themselves.
    pub fn set_quant_step(&self, quant_step: f64) {
        self.ledger.lock().unwrap().set_quant_step(quant_step);
    }

    /// Deliver `msg` into the mailbox of node `to`, billing its
    /// transmitter in the ledger.
    pub fn send(&self, to: usize, msg: Message) {
        self.ledger.lock().unwrap().send_lossy(
            msg.from_node(),
            to,
            msg.purpose(),
            msg.scalar_count(),
            true,
        );
        self.mailboxes[to].lock().unwrap().push_back(msg);
    }

    /// Send over a lossy link: with `delivered == false` the frame was
    /// transmitted (and billed — the transmitter pays either way) but
    /// erased in flight: it never reaches the mailbox and lands in the
    /// ledger's dropped counters (the message-level face of the
    /// coordinator's packet-drop impairment).
    pub fn send_lossy(&self, to: usize, msg: Message, delivered: bool) {
        self.ledger.lock().unwrap().send_lossy(
            msg.from_node(),
            to,
            msg.purpose(),
            msg.scalar_count(),
            delivered,
        );
        if delivered {
            self.mailboxes[to].lock().unwrap().push_back(msg);
        }
    }

    /// Drain all pending messages for `node`.
    pub fn drain(&self, node: usize) -> Vec<Message> {
        self.mailboxes[node].lock().unwrap().drain(..).collect()
    }

    /// Non-destructive pending count (diagnostics).
    pub fn pending(&self, node: usize) -> usize {
        self.mailboxes[node].lock().unwrap().len()
    }

    /// Snapshot of the bus's directional ledger.
    pub fn ledger(&self) -> CommLedger {
        self.ledger.lock().unwrap().ledger().clone()
    }

    /// Total scalars delivered into mailboxes (billed minus erased).
    pub fn delivered_scalars(&self) -> u64 {
        let m = self.ledger.lock().unwrap();
        m.ledger().scalars - m.ledger().dropped_scalars
    }

    /// Total frames delivered into mailboxes.
    pub fn delivered_messages(&self) -> u64 {
        let m = self.ledger.lock().unwrap();
        m.ledger().messages - m.ledger().dropped_messages
    }

    /// Total scalars transmitted but erased by lossy links.
    pub fn dropped_scalars(&self) -> u64 {
        self.ledger.lock().unwrap().ledger().dropped_scalars
    }

    /// Total frames transmitted but erased by lossy links.
    pub fn dropped_messages(&self) -> u64 {
        self.ledger.lock().unwrap().ledger().dropped_messages
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn partial_vector_mask_roundtrip() {
        let full = [1.0, 2.0, 3.0, 4.0];
        let mask = [0.0, 1.0, 0.0, 1.0];
        let pv = PartialVector::from_mask(&full, &mask);
        assert_eq!(pv.idx, vec![1, 3]);
        assert_eq!(pv.val, vec![2.0, 4.0]);
        let mut out = [9.0; 4];
        pv.fill_into(&mut out);
        assert_eq!(out, [9.0, 2.0, 9.0, 4.0]);
    }

    #[test]
    fn bus_delivery_and_accounting() {
        let bus = Bus::new(3);
        let pv = PartialVector { idx: vec![0, 2], val: vec![1.0, 2.0] };
        bus.send(1, Message::Estimate { from: 0, body: pv.clone() });
        bus.send(1, Message::Gradient { from: 2, body: pv });
        assert_eq!(bus.pending(1), 2);
        assert_eq!(bus.pending(0), 0);
        let msgs = bus.drain(1);
        assert_eq!(msgs.len(), 2);
        assert_eq!(msgs[0].from_node(), 0);
        assert_eq!(bus.delivered_scalars(), 4);
        assert_eq!(bus.delivered_messages(), 2);
        assert_eq!(bus.pending(1), 0);
    }

    #[test]
    fn lossy_send_accounts_for_erasures() {
        let bus = Bus::new(2);
        let pv = PartialVector { idx: vec![0, 1, 2], val: vec![1.0, 2.0, 3.0] };
        bus.send_lossy(1, Message::Estimate { from: 0, body: pv.clone() }, true);
        bus.send_lossy(1, Message::Estimate { from: 0, body: pv }, false);
        assert_eq!(bus.pending(1), 1);
        assert_eq!(bus.delivered_messages(), 1);
        assert_eq!(bus.delivered_scalars(), 3);
        assert_eq!(bus.dropped_messages(), 1);
        assert_eq!(bus.dropped_scalars(), 3);
        // The transmitter paid for both frames, on the directed link.
        let ledger = bus.ledger();
        assert_eq!(ledger.scalars, 6);
        assert_eq!(ledger.link_scalars(0, 1), 6);
        assert_eq!(ledger.purpose_scalars(Purpose::Estimate), 6);
    }

    /// Quantized payloads are billed at the grid-index width — the
    /// accounting half of the agent's `set_quant_step` wire face.
    #[test]
    fn quantized_payload_width_reaches_the_bus_ledger() {
        let bus = Bus::new(2);
        bus.set_quant_step(1e-3);
        let pv = PartialVector { idx: vec![0, 1], val: vec![0.001, 0.002] };
        bus.send(1, Message::Estimate { from: 0, body: pv });
        let ledger = bus.ledger();
        assert_eq!(ledger.bits_per_scalar, crate::energy::payload_bits(1e-3));
        assert_eq!(ledger.bits(), 2 * ledger.bits_per_scalar as u64);
    }

    #[test]
    fn bus_is_thread_safe() {
        use std::sync::Arc;
        let bus = Arc::new(Bus::new(2));
        let handles: Vec<_> = (0..4)
            .map(|t| {
                let bus = bus.clone();
                std::thread::spawn(move || {
                    for _ in 0..100 {
                        let pv = PartialVector { idx: vec![0], val: vec![t as f64] };
                        bus.send(t % 2, Message::Estimate { from: t, body: pv });
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(bus.delivered_messages(), 400);
        assert_eq!(bus.drain(0).len() + bus.drain(1).len(), 400);
    }
}
