//! Link-impairment layer: per-edge erasures, communication gating and
//! finite-precision state for *any* [`Algorithm`](crate::algorithms::Algorithm).
//!
//! The paper's experiments assume ideal links; the scenario subsystem
//! (DESIGN.md §4) relaxes that along the axes the follow-up literature
//! studies:
//!
//! * **Packet drops** — every directed link `(l → k)` fails to deliver
//!   according to a [`DropModel`]: either independently with probability
//!   `p` per iteration ([`DropModel::Iid`], the receiver-side erasure
//!   model of the probabilistic-link analyses, cf. Arablouei et al.,
//!   arXiv:1408.5845), or through a two-state Gilbert–Elliott Markov
//!   chain ([`DropModel::Markov`]) whose Bad state produces *bursts* of
//!   consecutive erasures (DESIGN.md §12). The transmitter still pays
//!   for the frame (the energy is spent whether or not the packet
//!   lands), so communication metering is unchanged; the receiver falls
//!   back to its own information.
//! * **Communication gating** — a per-node transmit gate: a gated node
//!   stays off the air for the whole iteration (its transmissions are
//!   neither delivered *nor billed*). [`Gating::Probabilistic`] is random
//!   duty-cycling; [`Gating::EventTriggered`] transmits only when the
//!   estimate moved by more than a threshold since the last broadcast
//!   (the event-based diffusion strategy of Wang et al.,
//!   arXiv:1803.00368).
//! * **Quantization** — every node keeps its estimate on a uniform grid
//!   of step `quant_step` (finite-precision motes): the state is snapped
//!   after each update, so every scalar a node later puts on the wire is
//!   a grid point.
//!
//! The layer is generic over algorithms because it acts only through the
//! shared plumbing: a missing delivery re-allocates the corresponding
//! combination-matrix mass to the receiver's self weight (exactly the
//! completion rule of paper eqs. (11)–(12), and the `h_kk` reweighting of
//! RCD), gating mutes the transmitter in the shared [`CommMeter`], and
//! quantization goes through [`Algorithm::weights_mut`]. No algorithm
//! contains impairment-specific code.
//!
//! Determinism: impairment decisions are drawn from a dedicated PCG64
//! stream (`seed ^ LINK_SEED_SALT`, same stream id as the data RNG), so
//! enabling impairments never perturbs the data sequence, and runs remain
//! bit-identical for any worker-thread count.

use crate::algorithms::{Algorithm, CommMeter, NetworkConfig};
use crate::energy::comm::LinkOutcomes;
use crate::rng::Pcg64;
use crate::topology::{Combiner, Graph};

/// Salt XOR-ed into the master seed for the impairment RNG stream, so
/// link events are decorrelated from (and do not consume) the data RNG.
pub const LINK_SEED_SALT: u64 = 0x6c69_6e6b_7374_6174; // "linkstat"

/// Per-node transmit-gate policy (who goes on the air this iteration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Gating {
    /// Every node transmits every iteration (the paper's setting).
    Always,
    /// Each node independently transmits with probability `p` per
    /// iteration (random duty-cycling).
    Probabilistic(f64),
    /// Event-triggered communication (arXiv:1803.00368): node `k`
    /// transmits only when `‖w_k − w̃_k‖² > δ`, where `w̃_k` is the state
    /// it last put on the air; transmitting refreshes `w̃_k`.
    EventTriggered(f64),
}

impl Gating {
    /// Per-iteration transmit probability, when the gate is a Bernoulli
    /// process the closed-form impaired-link theory can average over
    /// (DESIGN.md §7): [`Gating::Always`] → 1, [`Gating::Probabilistic`]
    /// → p. Event-triggered gating depends on the trajectory itself and
    /// has no fixed transmit probability — `None`.
    pub fn transmit_prob(&self) -> Option<f64> {
        match self {
            Gating::Always => Some(1.0),
            Gating::Probabilistic(p) => Some(*p),
            Gating::EventTriggered(_) => None,
        }
    }
}

impl std::fmt::Display for Gating {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Gating::Always => write!(f, "always"),
            Gating::Probabilistic(p) => write!(f, "prob:{p}"),
            Gating::EventTriggered(d) => write!(f, "event:{d}"),
        }
    }
}

impl std::str::FromStr for Gating {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "always" {
            return Ok(Gating::Always);
        }
        if let Some(p) = s.strip_prefix("prob:") {
            return p
                .parse::<f64>()
                .map(Gating::Probabilistic)
                .map_err(|e| format!("gating {s:?}: {e}"));
        }
        if let Some(d) = s.strip_prefix("event:") {
            return d
                .parse::<f64>()
                .map(Gating::EventTriggered)
                .map_err(|e| format!("gating {s:?}: {e}"));
        }
        Err(format!(
            "gating {s:?}: expected always | prob:<p> | event:<delta>"
        ))
    }
}

/// Per-directed-link erasure process (DESIGN.md §12).
///
/// [`DropModel::Iid`] is the historical independent-Bernoulli draw.
/// [`DropModel::Markov`] is a two-state Gilbert–Elliott chain in "lazy
/// redraw" form: each time the link is sampled, the state is redrawn
/// with probability `p_gb` (from Good) or `p_bg` (from Bad), and a
/// redraw lands Bad with probability `p_bad`; the frame is erased iff
/// the state is Bad. The parameterization is chosen so that
/// `p_gb = p_bg = 1` redraws every step — i.e. the chain is *exactly*
/// the i.i.d. Bernoulli(`p_bad`) process, which is what makes
/// `markov:p,1,1` specs byte-identical to `prob:p` specs.
///
/// Closed forms (pinned by `rust/tests/dynamics.rs`):
/// * stationary Bad occupancy
///   `π_B = p_gb·p_bad / (p_gb·p_bad + p_bg·(1 − p_bad))`
///   (equal to `p_bad` whenever `p_gb = p_bg`);
/// * bad-burst lengths are geometric with success probability
///   `q = p_bg·(1 − p_bad)`, hence mean burst `1/q`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DropModel {
    /// Independent erasure with probability `p` per sampled frame.
    Iid(f64),
    /// Gilbert–Elliott bursty erasures (lazy-redraw parameterization).
    Markov {
        /// P(redraw lands Bad) — also the stationary erasure rate when
        /// `p_gb = p_bg`.
        p_bad: f64,
        /// P(redraw | state Good), in `(0, 1]`.
        p_gb: f64,
        /// P(redraw | state Bad), in `(0, 1]`.
        p_bg: f64,
    },
}

impl DropModel {
    /// The no-drop model.
    pub fn none() -> Self {
        DropModel::Iid(0.0)
    }

    /// True when the process can never erase a frame.
    pub fn drops_nothing(&self) -> bool {
        match *self {
            DropModel::Iid(p) => p == 0.0,
            DropModel::Markov { p_bad, .. } => p_bad == 0.0,
        }
    }

    /// The i.i.d. erasure probability when the process is memoryless:
    /// `Some(p)` for [`DropModel::Iid`], and `Some(p_bad)` for a Markov
    /// chain with `p_gb = p_bg = 1` (which redraws every sample and is
    /// therefore exactly Bernoulli). `None` for a bursty chain — those
    /// specs are outside the i.i.d. closed-form theory (DESIGN.md §12).
    ///
    /// Memoryless specs dispatch to the exact historical i.i.d. draw
    /// expression, so their RNG consumption — hence every downstream
    /// byte — matches the equivalent [`DropModel::Iid`] spec.
    pub fn iid_prob(&self) -> Option<f64> {
        match *self {
            DropModel::Iid(p) => Some(p),
            DropModel::Markov { p_bad, p_gb, p_bg } => {
                if p_gb == 1.0 && p_bg == 1.0 {
                    Some(p_bad)
                } else {
                    None
                }
            }
        }
    }

    /// Long-run erasure rate: `p` for i.i.d., the stationary Bad
    /// occupancy `π_B` for the Markov chain. Memoryless cases return
    /// the plain probability directly (no formula round-off), so the
    /// expected-combiner and theory paths of a `markov:p,1,1` spec are
    /// bit-identical to the `prob:p` spec.
    pub fn mean_drop(&self) -> f64 {
        if let Some(p) = self.iid_prob() {
            return p;
        }
        match *self {
            DropModel::Iid(p) => p,
            DropModel::Markov { p_bad, p_gb, p_bg } => {
                let num = p_gb * p_bad;
                let den = num + p_bg * (1.0 - p_bad);
                if den == 0.0 {
                    0.0
                } else {
                    num / den
                }
            }
        }
    }

    /// Mean length of a bad burst in sampled steps: `1 / (p_bg·(1 −
    /// p_bad))` for the Markov chain, `1 / (1 − p)` for i.i.d. erasures
    /// (a geometric run of failures). `None` when bursts cannot end.
    pub fn mean_bad_burst(&self) -> Option<f64> {
        let q = match *self {
            DropModel::Iid(p) => 1.0 - p,
            DropModel::Markov { p_bad, p_bg, .. } => p_bg * (1.0 - p_bad),
        };
        if q > 0.0 {
            Some(1.0 / q)
        } else {
            None
        }
    }

    /// Range checks.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            DropModel::Iid(p) => {
                if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                    return Err(format!("impairments: drop_prob {p} outside [0, 1]"));
                }
            }
            DropModel::Markov { p_bad, p_gb, p_bg } => {
                if !p_bad.is_finite() || !(0.0..=1.0).contains(&p_bad) {
                    return Err(format!("impairments: markov p_bad {p_bad} outside [0, 1]"));
                }
                for (name, p) in [("p_gb", p_gb), ("p_bg", p_bg)] {
                    if !p.is_finite() || !(p > 0.0 && p <= 1.0) {
                        return Err(format!(
                            "impairments: markov {name} {p} outside (0, 1]"
                        ));
                    }
                }
            }
        }
        Ok(())
    }
}

impl Default for DropModel {
    fn default() -> Self {
        Self::none()
    }
}

impl std::fmt::Display for DropModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DropModel::Iid(p) => write!(f, "prob:{p}"),
            DropModel::Markov { p_bad, p_gb, p_bg } => {
                write!(f, "markov:{p_bad},{p_gb},{p_bg}")
            }
        }
    }
}

impl std::str::FromStr for DropModel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if let Some(p) = s.strip_prefix("prob:") {
            return p
                .parse::<f64>()
                .map(DropModel::Iid)
                .map_err(|e| format!("drop {s:?}: {e}"));
        }
        if let Some(rest) = s.strip_prefix("markov:") {
            let parts: Vec<&str> = rest.split(',').collect();
            if parts.len() != 3 {
                return Err(format!(
                    "drop {s:?}: expected markov:<p_bad>,<p_gb>,<p_bg>"
                ));
            }
            let mut v = [0.0f64; 3];
            for (dst, part) in v.iter_mut().zip(parts.iter()) {
                *dst = part
                    .trim()
                    .parse::<f64>()
                    .map_err(|e| format!("drop {s:?}: {e}"))?;
            }
            return Ok(DropModel::Markov { p_bad: v[0], p_gb: v[1], p_bg: v[2] });
        }
        Err(format!(
            "drop {s:?}: expected prob:<p> | markov:<p_bad>,<p_gb>,<p_bg>"
        ))
    }
}

/// Declarative link-impairment model for one scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkImpairments {
    /// Per-directed-link erasure process (i.i.d. or Gilbert–Elliott).
    pub drop: DropModel,
    /// Per-node transmit gate.
    pub gating: Gating,
    /// Uniform quantizer step Δ for the stored estimates (0 = off).
    pub quant_step: f64,
    /// Per-leg erasures (DESIGN.md §13): when `false` (the historical
    /// default, §7 assumption 6) the solicited-gradient exchange shares
    /// one erasure event with the reply-direction estimate frame. When
    /// `true`, every frame is its own event: the adapt exchange into
    /// receiver `k` over link `l → k` survives only when the *request*
    /// leg (`k`'s own estimate broadcast reaching `l`) and an
    /// independent *reply*-frame draw on `l → k` both deliver. With a
    /// zero drop rate no extra randomness is consumed, so an otherwise
    /// ideal per-leg model stays byte-identical to the legacy path.
    pub per_leg: bool,
}

impl LinkImpairments {
    /// Ideal links: nothing dropped, nobody gated, full precision.
    pub fn ideal() -> Self {
        Self {
            drop: DropModel::none(),
            gating: Gating::Always,
            quant_step: 0.0,
            per_leg: false,
        }
    }

    /// The historical i.i.d.-erasure constructor.
    pub fn with_drop_prob(p: f64) -> Self {
        Self { drop: DropModel::Iid(p), ..Self::ideal() }
    }

    /// True when the model is a no-op (the coordinator then takes the
    /// exact legacy code path). `per_leg` is deliberately ignored: with
    /// nothing to drop, per-leg and shared-leg erasures are the same
    /// (empty) event set, so an otherwise ideal per-leg spec rides the
    /// ideal fast path byte-for-byte (DESIGN.md §13).
    pub fn is_ideal(&self) -> bool {
        self.drop.drops_nothing() && self.gating == Gating::Always && self.quant_step == 0.0
    }

    /// True when link-level events (drops or gating) can occur — i.e.
    /// the per-iteration effective-matrix rebuild is actually needed.
    /// Quantization-only models return `false` and skip that work.
    pub fn affects_links(&self) -> bool {
        !self.drop.drops_nothing() || self.gating != Gating::Always
    }

    /// P that a directed link delivers its *combine* frame (transmitter
    /// on the air and no erasure): `p_tx · (1 − p_drop)`, where the drop
    /// rate is the process's long-run mean ([`DropModel::mean_drop`]).
    /// `None` under event-triggered gating, which has no fixed transmit
    /// probability.
    pub fn combine_keep_prob(&self) -> Option<f64> {
        self.gating.transmit_prob().map(|p| p * (1.0 - self.drop.mean_drop()))
    }

    /// P that the *adapt* (solicited-gradient) exchange on a directed
    /// link survives: the transmitter is on the air, the frame is
    /// delivered, *and* the receiver solicited it by broadcasting its
    /// own estimate — `p_tx² · (1 − p_drop)` under the shared-leg model
    /// (DESIGN.md §7), `p_tx² · (1 − p_drop)²` under per-leg erasures
    /// (request and reply frames drawn independently, DESIGN.md §13).
    /// `None` under event-triggered gating.
    pub fn adapt_keep_prob(&self) -> Option<f64> {
        self.gating.transmit_prob().map(|p| {
            let keep = 1.0 - self.drop.mean_drop();
            p * p * if self.per_leg { keep * keep } else { keep }
        })
    }

    /// Expected effective combiners `(Ā, C̄) = (E{A(i)}, E{C(i)})` under
    /// the independent-Bernoulli link-state model: exactly the
    /// per-iteration reallocation of [`ImpairmentState::begin_iteration`]
    /// taken in expectation — surviving off-diagonal mass scaled by the
    /// keep probability, the complement moved to the receiver's self
    /// weight. These are the matrices the impaired-link theory engine
    /// anchors on (DESIGN.md §7). `None` under event-triggered gating.
    pub fn expected_combiners(&self, net: &NetworkConfig) -> Option<(Combiner, Combiner)> {
        let pa = self.combine_keep_prob()?;
        let pc = self.adapt_keep_prob()?;
        Some((
            reallocate_expected(&net.a, pa),
            reallocate_expected(&net.c, pc),
        ))
    }

    /// [`Self::expected_combiners`] into caller-owned buffers: no
    /// allocation once `a_out`/`c_out` have the right structure
    /// (alloc-free discipline, `tests/alloc_free.rs`).
    pub fn expected_combiners_into(
        &self,
        net: &NetworkConfig,
        a_out: &mut Combiner,
        c_out: &mut Combiner,
    ) -> Option<()> {
        let pa = self.combine_keep_prob()?;
        let pc = self.adapt_keep_prob()?;
        reallocate_expected_into(&net.a, pa, a_out);
        reallocate_expected_into(&net.c, pc, c_out);
        Some(())
    }

    /// Range checks for every knob.
    pub fn validate(&self) -> Result<(), String> {
        self.drop.validate()?;
        match self.gating {
            Gating::Always => {}
            Gating::Probabilistic(p) => {
                if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                    return Err(format!("impairments: gating prob {p} outside [0, 1]"));
                }
            }
            Gating::EventTriggered(d) => {
                if !d.is_finite() || d < 0.0 {
                    return Err(format!("impairments: event threshold {d} must be >= 0"));
                }
            }
        }
        if !self.quant_step.is_finite() || self.quant_step < 0.0 {
            return Err(format!(
                "impairments: quant_step {} must be >= 0",
                self.quant_step
            ));
        }
        Ok(())
    }
}

impl Default for LinkImpairments {
    fn default() -> Self {
        Self::ideal()
    }
}

/// Scale every off-diagonal entry of `m` by `keep`, re-allocating the
/// complement to the column's diagonal — the expected-value form of the
/// per-iteration erasure reallocation (DESIGN.md §7). The single source
/// of that rule in expectation: shared by
/// [`LinkImpairments::expected_combiners`] and the theory engine's
/// expected-combiner construction (`theory/linkstate.rs`).
pub(crate) fn reallocate_expected(m: &Combiner, keep: f64) -> Combiner {
    let mut out = m.clone();
    reallocate_expected_into(m, keep, &mut out);
    out
}

/// [`reallocate_expected`] into a caller-owned combiner, reusing its
/// buffers. O(nnz): the CSR rows *are* the dense columns, walked in the
/// same ascending-sender order as the historical dense loop, so the
/// diagonal accumulates in the identical floating-point order.
pub(crate) fn reallocate_expected_into(m: &Combiner, keep: f64, out: &mut Combiner) {
    out.clone_from(m);
    for k in 0..m.n() {
        let di = m.diag_idx(k);
        let vals = out.vals_mut();
        for idx in m.row_span(k) {
            if idx == di {
                continue;
            }
            let v = m.vals()[idx];
            if v != 0.0 {
                let moved = v * (1.0 - keep);
                vals[idx] -= moved;
                vals[di] += moved;
            }
        }
    }
}

/// Snap every entry of `w` to the uniform grid of step `step`
/// (mid-tread quantizer; `step <= 0` is a no-op).
pub fn quantize_in_place(w: &mut [f64], step: f64) {
    if step <= 0.0 {
        return;
    }
    for x in w.iter_mut() {
        *x = (*x / step).round() * step;
    }
}

/// Adaptive combination-weight policy (DESIGN.md §12): how the pristine
/// combiners are re-weighted around links the ledger has observed as
/// impaired. `Static` is the historical fixed-weight behaviour.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdaptivePolicy {
    /// Fixed weights (the paper's setting).
    #[default]
    Static,
    /// Metropolis-style discounting: every off-diagonal weight is scaled
    /// by the link's empirical delivery rate; the complement moves to
    /// the receiver's self weight (cf. the Metropolis construction of
    /// SNIPPETS-style `1/max(n_k, n_l)` rules).
    Metropolis,
    /// Adaptive-combination-weights normalization: rate-scaled weights
    /// renormalized over the receiver's in-neighbourhood, so relative
    /// trust shifts toward reliable links.
    Acw,
}

impl std::fmt::Display for AdaptivePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AdaptivePolicy::Static => write!(f, "static"),
            AdaptivePolicy::Metropolis => write!(f, "metropolis"),
            AdaptivePolicy::Acw => write!(f, "acw"),
        }
    }
}

impl std::str::FromStr for AdaptivePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "static" => Ok(AdaptivePolicy::Static),
            "metropolis" => Ok(AdaptivePolicy::Metropolis),
            "acw" => Ok(AdaptivePolicy::Acw),
            _ => Err(format!("adaptive {s:?}: expected static | metropolis | acw")),
        }
    }
}

/// Iterations between adaptive-combiner refreshes: the empirical
/// delivery rates are re-read and the pristine combiner values recomputed
/// every this many iterations (an O(E) in-place pass).
pub const ADAPTIVE_PERIOD: usize = 64;

/// Recompute one combiner's values from observed per-link delivery
/// rates, in place and allocation-free (DESIGN.md §12).
///
/// `structure` provides the CSR layout shared by `base_vals` (the true
/// pristine weights) and `out_vals` (the re-weighted values written
/// here); `rate(k, slot)` is the empirical delivery rate of the directed
/// link from `graph.neighbors(k)[slot]` into `k`, in `[0, 1]`.
///
/// Both policies keep every receiver's incoming weights summing to
/// exactly the pristine total (1 for a stochastic combiner), and both
/// degenerate to the pristine weights when every rate is 1 — the
/// no-impairment-observed case (property-tested in
/// `rust/tests/properties.rs`).
pub fn adaptive_reweight_into(
    policy: AdaptivePolicy,
    graph: &crate::topology::Graph,
    structure: &Combiner,
    base_vals: &[f64],
    rate: impl Fn(usize, usize) -> f64,
    out_vals: &mut [f64],
) {
    out_vals.copy_from_slice(base_vals);
    if policy == AdaptivePolicy::Static {
        return;
    }
    let n = structure.n();
    for k in 0..n {
        let diag = structure.diag_idx(k);
        match policy {
            AdaptivePolicy::Static => unreachable!(),
            AdaptivePolicy::Metropolis => {
                // w'_{lk} = w⁰_{lk} · r_{lk}; the receiver's self weight
                // absorbs the complement, preserving the row total.
                let mut moved = 0.0;
                for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                    if let Some(idx) = structure.entry_idx(k, lnb) {
                        let v = base_vals[idx];
                        if v != 0.0 {
                            let kept = v * rate(k, slot);
                            out_vals[idx] = kept;
                            moved += v - kept;
                        }
                    }
                }
                out_vals[diag] = base_vals[diag] + moved;
            }
            AdaptivePolicy::Acw => {
                // w'_{lk} = w⁰_{lk}·r_{lk} / Z_k with the self weight
                // included in Z_k, so the row renormalizes exactly.
                let total: f64 = structure.row_span(k).map(|i| base_vals[i]).sum();
                let mut z = base_vals[diag];
                for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                    if let Some(idx) = structure.entry_idx(k, lnb) {
                        z += base_vals[idx] * rate(k, slot);
                    }
                }
                if z <= 0.0 {
                    // Fully isolated and weightless: keep pristine.
                    continue;
                }
                let scale = total / z;
                for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                    if let Some(idx) = structure.entry_idx(k, lnb) {
                        out_vals[idx] = base_vals[idx] * rate(k, slot) * scale;
                    }
                }
                out_vals[diag] = base_vals[diag] * scale;
            }
        }
    }
}

/// [`adaptive_reweight_into`] returning a fresh combiner — the
/// property-test face.
pub fn adaptive_reweight(
    policy: AdaptivePolicy,
    graph: &crate::topology::Graph,
    base: &Combiner,
    rate: impl Fn(usize, usize) -> f64,
) -> Combiner {
    let mut out = base.clone();
    let mut vals = base.vals().to_vec();
    adaptive_reweight_into(policy, graph, base, base.vals(), rate, &mut vals);
    out.vals_mut().copy_from_slice(&vals);
    out
}

/// Per-run occupancy counters of the Markov link-state process
/// (DESIGN.md §12): integer tallies over every *sampled* directed-link
/// step, so merging across runs/shards is order-independent and the
/// statistical harness (`rust/tests/dynamics.rs`) can pin the empirical
/// stationary distribution and burst-length histogram against closed
/// form. Empty for i.i.d. (memoryless) drop models, which never touch
/// the chain.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub struct LinkStateStats {
    /// Sampled steps spent in the Good state.
    pub good_steps: u64,
    /// Sampled steps spent in the Bad state.
    pub bad_steps: u64,
    /// Completed bad bursts (Bad runs terminated by a Good sample).
    pub bursts: u64,
    /// Total sampled length of the completed bursts.
    pub burst_steps: u64,
    /// Burst-length histogram: bin `i` counts completed bursts of length
    /// `i + 1`; the final bin absorbs everything longer.
    pub burst_hist: Vec<u64>,
}

impl LinkStateStats {
    /// Histogram bins (the last one is the overflow bin).
    pub const HIST_BINS: usize = 32;

    /// Zeroed counters with the histogram pre-sized (so per-iteration
    /// recording never allocates).
    pub fn sized() -> Self {
        Self { burst_hist: vec![0; Self::HIST_BINS], ..Self::default() }
    }

    /// True when no chain step was ever sampled (i.i.d. models).
    pub fn is_empty(&self) -> bool {
        self.good_steps == 0 && self.bad_steps == 0
    }

    /// Record one completed bad burst of `len` sampled steps.
    pub fn record_burst(&mut self, len: u32) {
        self.bursts += 1;
        self.burst_steps += len as u64;
        if self.burst_hist.is_empty() {
            self.burst_hist = vec![0; Self::HIST_BINS];
        }
        let bin = (len as usize - 1).min(self.burst_hist.len() - 1);
        self.burst_hist[bin] += 1;
    }

    /// Empirical Bad occupancy over the sampled steps.
    pub fn bad_fraction(&self) -> Option<f64> {
        let total = self.good_steps + self.bad_steps;
        if total == 0 {
            None
        } else {
            Some(self.bad_steps as f64 / total as f64)
        }
    }

    /// Empirical mean completed-burst length.
    pub fn mean_burst(&self) -> Option<f64> {
        if self.bursts == 0 {
            None
        } else {
            Some(self.burst_steps as f64 / self.bursts as f64)
        }
    }

    /// Fold another run's counters in (integer sums: order-independent,
    /// hence bit-identical for any thread/shard layout).
    pub fn merge(&mut self, other: &LinkStateStats) {
        self.good_steps += other.good_steps;
        self.bad_steps += other.bad_steps;
        self.bursts += other.bursts;
        self.burst_steps += other.burst_steps;
        if self.burst_hist.len() < other.burst_hist.len() {
            self.burst_hist.resize(other.burst_hist.len(), 0);
        }
        for (dst, &src) in self.burst_hist.iter_mut().zip(other.burst_hist.iter()) {
            *dst += src;
        }
    }
}

/// Per-run mutable state of the link-event layer: pristine combiner
/// copies, the event-trigger reference states, and the dedicated RNG.
/// Only needed when [`LinkImpairments::affects_links`] — quantization is
/// stateless and applied directly by the scheduler.
///
/// Driven by the round scheduler: [`ImpairmentState::begin_iteration`]
/// before every [`Algorithm::step`], [`ImpairmentState::restore`] once
/// the run finishes.
pub struct ImpairmentState {
    /// Pristine CSR values of the combine matrix A (same layout as the
    /// network's combiner — the per-iteration effective matrices are
    /// rebuilt by one O(E) memcpy from these, allocation-free). Under an
    /// adaptive combiner policy these are periodically recomputed from
    /// `base_a` (DESIGN.md §12); otherwise they stay the capture-time
    /// values.
    a0: Vec<f64>,
    /// Pristine CSR values of the adapt matrix C.
    c0: Vec<f64>,
    /// True pristine values of A, never re-weighted (what `restore`
    /// reinstalls and what adaptive refreshes read from).
    base_a: Vec<f64>,
    /// True pristine values of C.
    base_c: Vec<f64>,
    /// Last-broadcast reference states w̃ (N × L, event gating).
    last_broadcast: Vec<f64>,
    /// Per-node silence decisions for the current iteration.
    silent: Vec<bool>,
    /// Edge-indexed request-delivery outcomes: did src's estimate
    /// broadcast reach dst this iteration? The single source of truth
    /// shared by the effective-matrix rebuild *and* the ledger's
    /// solicited-reply billing (DESIGN.md §9).
    delivered: LinkOutcomes,
    /// Directed-link slot base per receiver: the link
    /// `graph.neighbors(k)[slot] → k` owns slot `row_off[k] + slot` in
    /// every per-link vector below.
    row_off: Vec<usize>,
    /// Per-directed-slot CSR value index into A (None when the combiner
    /// has no entry for that edge, e.g. A = I). The CSR structure never
    /// changes, so these replace the historical per-iteration
    /// `entry_idx` binary searches — a pure index lookup, no float ops,
    /// hence bit-identical — and let the erase pass run against *bare
    /// value slices* (the lane engine's per-lane arrays) instead of a
    /// `Combiner` borrow.
    a_slot: Vec<Option<usize>>,
    /// Per-directed-slot CSR value index into C.
    c_slot: Vec<Option<usize>>,
    /// Per-receiver diagonal value index into A.
    a_diag: Vec<usize>,
    /// Per-receiver diagonal value index into C.
    c_diag: Vec<usize>,
    /// Markov link state per directed slot (`true` = Bad). Drawn from
    /// the stationary distribution on the first bursty iteration; never
    /// touched by memoryless models (DESIGN.md §12).
    link_bad: Vec<bool>,
    /// Length of the current Bad run per slot (occupancy accounting).
    burst_len: Vec<u32>,
    markov_ready: bool,
    /// Occupancy tallies of the sampled chain steps.
    stats: LinkStateStats,
    /// Sampled transmission attempts per directed slot (adaptive
    /// combiners' empirical rate denominator).
    attempts: Vec<u64>,
    /// Delivered frames per directed slot.
    deliv_count: Vec<u64>,
    /// Iterations seen by the dynamic path (adaptive refresh clock).
    dyn_iter: usize,
    rng: Pcg64,
    dim: usize,
}

impl ImpairmentState {
    /// Capture the pristine combiners of `net` and seed the impairment
    /// stream for one run (`stream` is the Monte-Carlo run stream).
    pub fn new(net: &NetworkConfig, seed: u64, stream: u64) -> Self {
        let n = net.n_nodes();
        let mut row_off = Vec::with_capacity(n + 1);
        let mut slots = 0usize;
        for k in 0..n {
            row_off.push(slots);
            slots += net.graph.neighbors(k).len();
        }
        row_off.push(slots);
        let mut a_slot = Vec::with_capacity(slots);
        let mut c_slot = Vec::with_capacity(slots);
        let mut a_diag = Vec::with_capacity(n);
        let mut c_diag = Vec::with_capacity(n);
        for k in 0..n {
            a_diag.push(net.a.diag_idx(k));
            c_diag.push(net.c.diag_idx(k));
            for &lnb in net.graph.neighbors(k) {
                a_slot.push(net.a.entry_idx(k, lnb));
                c_slot.push(net.c.entry_idx(k, lnb));
            }
        }
        Self {
            a0: net.a.vals().to_vec(),
            c0: net.c.vals().to_vec(),
            base_a: net.a.vals().to_vec(),
            base_c: net.c.vals().to_vec(),
            last_broadcast: vec![0.0; n * net.dim],
            silent: vec![false; n],
            delivered: LinkOutcomes::for_graph(&net.graph),
            row_off,
            a_slot,
            c_slot,
            a_diag,
            c_diag,
            link_bad: vec![false; slots],
            burst_len: vec![0; slots],
            markov_ready: false,
            stats: LinkStateStats::sized(),
            attempts: vec![0; slots],
            deliv_count: vec![0; slots],
            dyn_iter: 0,
            rng: Pcg64::new(seed ^ LINK_SEED_SALT, stream),
            dim: net.dim,
        }
    }

    /// The accumulated Markov link-state occupancy counters.
    pub fn stats(&self) -> &LinkStateStats {
        &self.stats
    }

    /// Consume the state, yielding the run's occupancy counters (what
    /// the round scheduler hands to [`super::round::RunResult`]).
    pub fn into_stats(self) -> LinkStateStats {
        self.stats
    }

    /// Sample the Gilbert–Elliott chain of directed slot `sidx` once
    /// (lazy-redraw semantics) and tally occupancy. Returns `true` when
    /// the frame is delivered (state Good).
    #[inline]
    fn markov_sample(&mut self, sidx: usize, p_bad: f64, p_gb: f64, p_bg: f64) -> bool {
        let bad = self.link_bad[sidx];
        let redraw = self.rng.next_bool(if bad { p_bg } else { p_gb });
        let nbad = if redraw { self.rng.next_bool(p_bad) } else { bad };
        self.link_bad[sidx] = nbad;
        if nbad {
            self.stats.bad_steps += 1;
            self.burst_len[sidx] = self.burst_len[sidx].saturating_add(1);
        } else {
            self.stats.good_steps += 1;
            let len = self.burst_len[sidx];
            if len > 0 {
                self.stats.record_burst(len);
                self.burst_len[sidx] = 0;
            }
        }
        !nbad
    }

    /// Which nodes are off the air this iteration (valid after
    /// [`Self::begin_iteration`]).
    pub fn silent(&self) -> &[bool] {
        &self.silent
    }

    /// The request-delivery outcomes of the current iteration (valid
    /// after [`Self::begin_iteration`]).
    pub fn delivered(&self) -> &LinkOutcomes {
        &self.delivered
    }

    /// Draw this iteration's link events and install their consequences:
    /// effective A/C matrices in the algorithm's network config and the
    /// transmit-mute mask in the meter.
    pub fn begin_iteration(
        &mut self,
        imp: &LinkImpairments,
        alg: &mut dyn Algorithm,
        comm: &mut CommMeter,
    ) {
        self.begin_iteration_dynamic(imp, None, alg, comm);
    }

    /// [`Self::begin_iteration`] with an optional network-dynamics layer
    /// (DESIGN.md §12): churn/mobility decisions are advanced first,
    /// absent nodes fold into the silence mask, dead support edges and
    /// link erasures erase combiner mass to the receiver's self weight,
    /// and the adaptive-combiner policy periodically re-weights the
    /// pristine copies from the observed per-link delivery rates. With
    /// `dynamics: None` and an i.i.d. drop model this is byte-for-byte
    /// the historical static path (same draws, same float ops).
    pub fn begin_iteration_dynamic(
        &mut self,
        imp: &LinkImpairments,
        mut dynamics: Option<&mut super::dynamics::DynamicsState>,
        alg: &mut dyn Algorithm,
        comm: &mut CommMeter,
    ) {
        let n = self.silent.len();

        // 0. Advance the network dynamics (churn draws, mobility marks,
        // per-node step-size masking) from their own RNG stream.
        if let Some(ds) = dynamics.as_mut() {
            ds.advance(alg);
            self.dyn_iter += 1;
        }

        // 1. Per-node transmit gate.
        self.gating_phase(imp.gating, alg.weights());

        // 1b. Absent nodes (churn) are off the air entirely: they
        // transmit nothing, are billed nothing, and solicit nothing —
        // exactly the silent-node treatment, applied after the gate so
        // the gate RNG consumption never depends on churn.
        let ds = dynamics.as_deref();
        if let Some(d) = ds {
            for k in 0..n {
                if !d.is_active(k) {
                    self.silent[k] = true;
                }
            }
            // 1c. Adaptive combiners: periodically rebuild the pristine
            // copies from the observed delivery rates (O(E), in place).
            let policy = d.adaptive();
            if policy != AdaptivePolicy::Static
                && self.dyn_iter > 1
                && (self.dyn_iter - 1) % ADAPTIVE_PERIOD == 0
            {
                let net = alg.network();
                let row_off = &self.row_off;
                let attempts = &self.attempts;
                let deliv = &self.deliv_count;
                let rate = |k: usize, slot: usize| {
                    let s = row_off[k] + slot;
                    let a = attempts[s];
                    if a == 0 {
                        1.0
                    } else {
                        deliv[s] as f64 / a as f64
                    }
                };
                adaptive_reweight_into(policy, &net.graph, &net.a, &self.base_a, &rate, &mut self.a0);
                adaptive_reweight_into(policy, &net.graph, &net.c, &self.base_c, &rate, &mut self.c0);
            }
        }

        // 2/2b/3. Effective combiners + ledger outcomes. Splitting the
        // network config lets the shared erase pass (also driven by the
        // lane engine against per-lane value arrays) borrow the graph
        // and both value slices disjointly.
        let net = alg.network_mut();
        let NetworkConfig { graph, a, c, .. } = net;
        self.erase_phase(imp, ds, graph, a.vals_mut(), c.vals_mut(), comm);
    }

    /// Phase 1 of an iteration: the per-node transmit gate. `weights`
    /// is the algorithm's current row-major estimate matrix — read only
    /// by [`Gating::EventTriggered`] (the other policies may pass `&[]`).
    fn gating_phase(&mut self, gating: Gating, weights: &[f64]) {
        let l = self.dim;
        let n = self.silent.len();
        match gating {
            Gating::Always => self.silent.iter_mut().for_each(|s| *s = false),
            Gating::Probabilistic(p) => {
                for s in self.silent.iter_mut() {
                    *s = !self.rng.next_bool(p);
                }
            }
            Gating::EventTriggered(delta) => {
                for k in 0..n {
                    let wk = &weights[k * l..(k + 1) * l];
                    let lb = &mut self.last_broadcast[k * l..(k + 1) * l];
                    let moved: f64 = wk
                        .iter()
                        .zip(lb.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    let quiet = moved <= delta;
                    self.silent[k] = quiet;
                    if !quiet {
                        // Transmitting refreshes the reference state.
                        lb.copy_from_slice(wk);
                    }
                }
            }
        }
    }

    /// Phases 2/2b/3 of an iteration, against bare CSR value slices.
    ///
    /// 2. Effective combiners: start from the pristine copies (one
    /// O(E) value memcpy — the CSR structure never changes), then
    /// erase every dead directed link (l → k), re-allocating its mass
    /// to the receiver's self weight — the completion rule of
    /// eqs. (11)-(12) applied at matrix level. A silent node also
    /// *solicits* nothing: it broadcast no estimate for neighbours to
    /// evaluate gradients at, so its whole C column collapses to the
    /// self weight and it runs a pure self-LMS adapt that iteration.
    /// The per-link outcomes recorded here are the same ones the
    /// ledger bills against in phase 3 — one draw, two consumers.
    ///
    /// The loop walks *graph* edges, not stored combiner entries:
    /// that keeps the salted-PCG64 draw order (one conditional draw
    /// per directed edge) bit-identical to the historical dense
    /// rebuild even when a combiner's support is smaller than the
    /// graph (e.g. A = I), where the erasure is then a no-op. Stored
    /// entries resolve through the slot tables computed at
    /// construction — an index load, no search, no float ops.
    ///
    /// `a_vals`/`c_vals` are the *effective* value arrays to rebuild:
    /// the algorithm's own combiner values on the scalar path, one
    /// lane's private arrays under the lane engine (DESIGN.md §14).
    fn erase_phase(
        &mut self,
        imp: &LinkImpairments,
        ds: Option<&super::dynamics::DynamicsState>,
        graph: &Graph,
        a_vals: &mut [f64],
        c_vals: &mut [f64],
        comm: &mut CommMeter,
    ) {
        let n = self.silent.len();
        a_vals.copy_from_slice(&self.a0);
        c_vals.copy_from_slice(&self.c0);
        self.delivered.reset_all_true();
        let drop_iid = imp.drop.iid_prob();
        let (mk_pb, mk_pgb, mk_pbg) = match imp.drop {
            DropModel::Markov { p_bad, p_gb, p_bg } => (p_bad, p_gb, p_bg),
            DropModel::Iid(_) => (0.0, 1.0, 1.0),
        };
        // A bursty chain starts from its stationary distribution, drawn
        // once per run from the impairment stream (memoryless models
        // never execute this, preserving their draw sequence).
        if drop_iid.is_none() && !self.markov_ready {
            let pi = imp.drop.mean_drop();
            for s in 0..self.link_bad.len() {
                self.link_bad[s] = self.rng.next_bool(pi);
            }
            self.markov_ready = true;
        }
        for k in 0..n {
            let a_diag = self.a_diag[k];
            let c_diag = self.c_diag[k];
            for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                // A link is sampled only when it is structurally alive
                // (churn/mobility) and its transmitter is on the air —
                // the short-circuit keeps the static i.i.d. path's RNG
                // consumption byte-identical to the historical loop.
                let usable = match ds {
                    Some(d) => d.edge_alive(k, slot, lnb),
                    None => true,
                } && !self.silent[lnb];
                let delivered = usable
                    && match drop_iid {
                        Some(p) => !(p > 0.0 && self.rng.next_bool(p)),
                        None => {
                            let sidx = self.row_off[k] + slot;
                            self.markov_sample(sidx, mk_pb, mk_pgb, mk_pbg)
                        }
                    };
                let sidx = self.row_off[k] + slot;
                self.attempts[sidx] += usable as u64;
                self.deliv_count[sidx] += delivered as u64;
                self.delivered.set_row_slot(k, slot, delivered);
                if !delivered {
                    if let Some(idx) = self.a_slot[sidx] {
                        let am = a_vals[idx];
                        if am != 0.0 {
                            a_vals[idx] = 0.0;
                            a_vals[a_diag] += am;
                        }
                    }
                }
                if !imp.per_leg && (!delivered || self.silent[k]) {
                    if let Some(idx) = self.c_slot[sidx] {
                        let cm = c_vals[idx];
                        if cm != 0.0 {
                            c_vals[idx] = 0.0;
                            c_vals[c_diag] += cm;
                        }
                    }
                }
            }
        }

        // 2b. Per-leg reply events (DESIGN.md §13). With the request
        // outcomes of every directed link on the table, a second pass
        // draws one *independent* reply-frame event per edge and
        // rebuilds the C erasures from the full exchange: receiver k's
        // adapt contribution from lnb survives only when k was on the
        // air, k's request broadcast reached lnb (the reverse-direction
        // table entry — exactly what the ledger's rule-3 suppression
        // reads), and lnb's reply frame itself delivered. The edge
        // order — hence the C diagonal's float accumulation order —
        // matches the shared-leg branch above, and a zero drop rate
        // short-circuits every draw, so an otherwise-lossless per-leg
        // spec is byte-identical to the legacy path.
        if imp.per_leg {
            for k in 0..n {
                let c_diag = self.c_diag[k];
                for (slot, &lnb) in graph.neighbors(k).iter().enumerate() {
                    let usable = match ds {
                        Some(d) => d.edge_alive(k, slot, lnb),
                        None => true,
                    } && !self.silent[lnb];
                    let reply = usable
                        && match drop_iid {
                            Some(p) => !(p > 0.0 && self.rng.next_bool(p)),
                            None => {
                                let sidx = self.row_off[k] + slot;
                                self.markov_sample(sidx, mk_pb, mk_pgb, mk_pbg)
                            }
                        };
                    let request = self.delivered.delivered(k, lnb);
                    if !reply || !request || self.silent[k] {
                        let sidx = self.row_off[k] + slot;
                        if let Some(idx) = self.c_slot[sidx] {
                            let cm = c_vals[idx];
                            if cm != 0.0 {
                                c_vals[idx] = 0.0;
                                c_vals[c_diag] += cm;
                            }
                        }
                    }
                }
            }
        }

        // 3. Install the outcomes in the ledger: gated nodes transmit
        // nothing and are billed nothing, and a gradient reply whose
        // soliciting broadcast died on this table is never billed
        // (DESIGN.md §9 billing rules).
        comm.set_outcomes(&self.silent, Some(&self.delivered));
    }

    /// One lane's iteration of link events for the lane engine
    /// (DESIGN.md §14): the transmit gate plus the erase pass, drawn
    /// from this state's salted PCG64 in exactly the scalar order, but
    /// rebuilt into the lane's private effective value arrays instead
    /// of the algorithm's combiners. `weights` is the lane's row-major
    /// estimate matrix (only read under event-triggered gating; the
    /// driver passes `&[]` otherwise). Network dynamics are not
    /// lane-batched — the coordinator routes those runs to the scalar
    /// path.
    pub fn begin_iteration_lanes(
        &mut self,
        imp: &LinkImpairments,
        graph: &Graph,
        weights: &[f64],
        a_vals: &mut [f64],
        c_vals: &mut [f64],
        comm: &mut CommMeter,
    ) {
        self.gating_phase(imp.gating, weights);
        self.erase_phase(imp, None, graph, a_vals, c_vals, comm);
    }

    /// Put the pristine combiners back (so a reused algorithm instance
    /// sees its original configuration — the *true* pristine values,
    /// even after adaptive re-weighting) and clear the ledger's outcome
    /// tables.
    pub fn restore(&self, alg: &mut dyn Algorithm, comm: &mut CommMeter) {
        let net = alg.network_mut();
        net.a.vals_mut().copy_from_slice(&self.base_a);
        net.c.vals_mut().copy_from_slice(&self.base_c);
        comm.clear_outcomes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    fn net(n: usize, l: usize) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l }
    }

    #[test]
    fn quantizer_snaps_to_grid() {
        let mut w = [0.1234, -0.567, 0.0, 2.0001];
        quantize_in_place(&mut w, 0.01);
        for x in &w {
            let q = x / 0.01;
            assert!((q - q.round()).abs() < 1e-9, "{x} not on grid");
        }
        assert!((w[0] - 0.12).abs() < 1e-12);
        let mut v = [0.1234];
        quantize_in_place(&mut v, 0.0);
        assert_eq!(v[0], 0.1234);
    }

    #[test]
    fn gating_parse_display_roundtrip() {
        for g in [
            Gating::Always,
            Gating::Probabilistic(0.25),
            Gating::EventTriggered(1e-6),
        ] {
            let s = g.to_string();
            assert_eq!(s.parse::<Gating>().unwrap(), g);
        }
        assert!("sometimes".parse::<Gating>().is_err());
        assert!("prob:x".parse::<Gating>().is_err());
    }

    #[test]
    fn validation_rejects_out_of_range() {
        let mut imp = LinkImpairments::ideal();
        assert!(imp.validate().is_ok());
        assert!(imp.is_ideal());
        imp.drop = DropModel::Iid(1.5);
        assert!(imp.validate().is_err());
        imp.drop = DropModel::Iid(0.2);
        assert!(!imp.is_ideal());
        assert!(imp.validate().is_ok());
        imp.gating = Gating::Probabilistic(-0.1);
        assert!(imp.validate().is_err());
        imp.gating = Gating::EventTriggered(-1.0);
        assert!(imp.validate().is_err());
        imp.gating = Gating::Always;
        imp.quant_step = f64::NAN;
        assert!(imp.validate().is_err());
    }

    #[test]
    fn full_drop_isolates_every_node() {
        let cfg = net(5, 3);
        let mut alg = Dcd::new(cfg.clone(), 2, 1);
        let mut comm = CommMeter::new(5);
        let imp = LinkImpairments {
            drop: DropModel::Iid(1.0),
            gating: Gating::Always,
            quant_step: 0.0,
            per_leg: false,
        };
        let mut state = ImpairmentState::new(alg.network(), 7, 1);
        state.begin_iteration(&imp, &mut alg, &mut comm);
        let a = &alg.network().a;
        for k in 0..5 {
            for lk in 0..5 {
                if k != lk {
                    assert_eq!(a[(lk, k)], 0.0, "({lk},{k}) should be erased");
                }
            }
            assert!((a[(k, k)] - 1.0).abs() < 1e-12);
        }
        // Column-stochasticity is preserved by the diagonal re-allocation.
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        state.restore(&mut alg, &mut comm);
        assert_eq!(alg.network().a, cfg.a, "restore must be bit-identical");
    }

    #[test]
    fn probabilistic_gate_extremes() {
        let cfg = net(6, 2);
        let mut alg = Dcd::new(cfg, 1, 1);
        let mut comm = CommMeter::new(6);
        let all_off = LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::Probabilistic(0.0),
            quant_step: 0.0,
            per_leg: false,
        };
        let mut state = ImpairmentState::new(alg.network(), 3, 1);
        state.begin_iteration(&all_off, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| s));
        let all_on = LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::Probabilistic(1.0),
            quant_step: 0.0,
            per_leg: false,
        };
        state.begin_iteration(&all_on, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| !s));
    }

    /// `expected_combiners` must be the Monte-Carlo average of the
    /// effective matrices `begin_iteration` actually installs — the
    /// closed form and the per-iteration rebuild are the same model.
    #[test]
    fn expected_combiners_match_realized_average() {
        let cfg = net(5, 2);
        let mut alg = Dcd::new(cfg.clone(), 1, 1);
        let mut comm = CommMeter::new(5);
        let imp = LinkImpairments {
            drop: DropModel::Iid(0.25),
            gating: Gating::Probabilistic(0.8),
            quant_step: 0.0,
            per_leg: false,
        };
        let (a_bar, c_bar) = imp.expected_combiners(&cfg).unwrap();
        let mut state = ImpairmentState::new(alg.network(), 13, 1);
        let trials = 60_000;
        let mut a_acc = crate::linalg::Mat::zeros(5, 5);
        let mut c_acc = crate::linalg::Mat::zeros(5, 5);
        for _ in 0..trials {
            state.begin_iteration(&imp, &mut alg, &mut comm);
            a_acc.axpy(1.0, &alg.network().a.to_dense());
            c_acc.axpy(1.0, &alg.network().c.to_dense());
        }
        a_acc.scale_in_place(1.0 / trials as f64);
        c_acc.scale_in_place(1.0 / trials as f64);
        let (a_bar, c_bar) = (a_bar.to_dense(), c_bar.to_dense());
        assert!((&a_acc - &a_bar).max_abs() < 6e-3, "Ā off by {}", (&a_acc - &a_bar).max_abs());
        assert!((&c_acc - &c_bar).max_abs() < 6e-3, "C̄ off by {}", (&c_acc - &c_bar).max_abs());
        state.restore(&mut alg, &mut comm);
        // Event-triggered gating has no closed form.
        let ev = LinkImpairments {
            drop: DropModel::Iid(0.1),
            gating: Gating::EventTriggered(1e-6),
            quant_step: 0.0,
            per_leg: false,
        };
        assert!(ev.expected_combiners(&cfg).is_none());
        assert_eq!(ev.gating.transmit_prob(), None);
        // Ideal impairments leave the combiners bit-identical.
        let (a_id, c_id) = LinkImpairments::ideal().expected_combiners(&cfg).unwrap();
        assert_eq!(a_id, cfg.a);
        assert_eq!(c_id, cfg.c);
    }

    #[test]
    fn keep_probabilities() {
        let imp = LinkImpairments {
            drop: DropModel::Iid(0.2),
            gating: Gating::Probabilistic(0.5),
            quant_step: 0.0,
            per_leg: false,
        };
        assert!((imp.combine_keep_prob().unwrap() - 0.5 * 0.8).abs() < 1e-15);
        assert!((imp.adapt_keep_prob().unwrap() - 0.25 * 0.8).abs() < 1e-15);
        assert_eq!(Gating::Always.transmit_prob(), Some(1.0));
    }

    /// The delivered table installed in the meter is the same event the
    /// effective matrices encode: with every frame erased, estimate
    /// broadcasts stay billed (transmitter pays) while every solicited
    /// gradient reply is suppressed and tracked (DESIGN.md §9).
    #[test]
    fn ledger_outcomes_follow_the_link_events() {
        use crate::algorithms::Purpose;
        let cfg = net(4, 2);
        let mut alg = Dcd::new(cfg, 1, 1);
        let mut comm = CommMeter::new(4);
        let all_dropped = LinkImpairments {
            drop: DropModel::Iid(1.0),
            gating: Gating::Always,
            quant_step: 0.0,
            per_leg: false,
        };
        let mut state = ImpairmentState::new(alg.network(), 11, 1);
        state.begin_iteration(&all_dropped, &mut alg, &mut comm);
        // Every directed edge is dead in the table...
        for k in 0..4 {
            for &lnb in alg.network().graph.neighbors(k) {
                assert!(!state.delivered().delivered(lnb, k), "{lnb}->{k} should be erased");
            }
        }
        // ... so a broadcast is billed but its solicited reply is not.
        comm.send(0, 1, Purpose::Estimate, 3);
        comm.send(1, 0, Purpose::Gradient, 2);
        assert_eq!(comm.scalars(), 3);
        assert_eq!(comm.ledger().suppressed_scalars, 2);
        assert_eq!(comm.ledger().legacy_scalars(), 5);
        state.restore(&mut alg, &mut comm);
        // Outcomes cleared: everything billed again.
        comm.send(1, 0, Purpose::Gradient, 2);
        assert_eq!(comm.scalars(), 5);
    }

    #[test]
    fn event_trigger_silences_unchanged_nodes() {
        let cfg = net(4, 3);
        let mut alg = Dcd::new(cfg, 2, 1);
        let mut comm = CommMeter::new(4);
        let imp = LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::EventTriggered(1e-9),
            quant_step: 0.0,
            per_leg: false,
        };
        let mut state = ImpairmentState::new(alg.network(), 5, 1);
        // Fresh algorithm: w == w̃ == 0, nobody has news to share.
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| s));
        // Move one node's estimate: only that node transmits.
        alg.weights_mut()[0] = 1.0;
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(!state.silent()[0]);
        assert!(state.silent()[1..].iter().all(|&s| s));
        // The broadcast refreshed w̃_0: silent again next round.
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(state.silent()[0]);
    }
}
