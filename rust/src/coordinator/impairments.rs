//! Link-impairment layer: per-edge erasures, communication gating and
//! finite-precision state for *any* [`Algorithm`](crate::algorithms::Algorithm).
//!
//! The paper's experiments assume ideal links; the scenario subsystem
//! (DESIGN.md §4) relaxes that along the axes the follow-up literature
//! studies:
//!
//! * **Packet drops** — every directed link `(l → k)` independently fails
//!   to deliver with probability `drop_prob` per iteration. The
//!   transmitter still pays for the frame (the energy is spent whether or
//!   not the packet lands), so communication metering is unchanged; the
//!   receiver falls back to its own information. This is the
//!   receiver-side erasure model of the probabilistic-link analyses
//!   (cf. Arablouei et al., arXiv:1408.5845).
//! * **Communication gating** — a per-node transmit gate: a gated node
//!   stays off the air for the whole iteration (its transmissions are
//!   neither delivered *nor billed*). [`Gating::Probabilistic`] is random
//!   duty-cycling; [`Gating::EventTriggered`] transmits only when the
//!   estimate moved by more than a threshold since the last broadcast
//!   (the event-based diffusion strategy of Wang et al.,
//!   arXiv:1803.00368).
//! * **Quantization** — every node keeps its estimate on a uniform grid
//!   of step `quant_step` (finite-precision motes): the state is snapped
//!   after each update, so every scalar a node later puts on the wire is
//!   a grid point.
//!
//! The layer is generic over algorithms because it acts only through the
//! shared plumbing: a missing delivery re-allocates the corresponding
//! combination-matrix mass to the receiver's self weight (exactly the
//! completion rule of paper eqs. (11)–(12), and the `h_kk` reweighting of
//! RCD), gating mutes the transmitter in the shared [`CommMeter`], and
//! quantization goes through [`Algorithm::weights_mut`]. No algorithm
//! contains impairment-specific code.
//!
//! Determinism: impairment decisions are drawn from a dedicated PCG64
//! stream (`seed ^ LINK_SEED_SALT`, same stream id as the data RNG), so
//! enabling impairments never perturbs the data sequence, and runs remain
//! bit-identical for any worker-thread count.

use crate::algorithms::{Algorithm, CommMeter, NetworkConfig};
use crate::energy::comm::LinkOutcomes;
use crate::rng::Pcg64;
use crate::topology::Combiner;

/// Salt XOR-ed into the master seed for the impairment RNG stream, so
/// link events are decorrelated from (and do not consume) the data RNG.
pub const LINK_SEED_SALT: u64 = 0x6c69_6e6b_7374_6174; // "linkstat"

/// Per-node transmit-gate policy (who goes on the air this iteration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Gating {
    /// Every node transmits every iteration (the paper's setting).
    Always,
    /// Each node independently transmits with probability `p` per
    /// iteration (random duty-cycling).
    Probabilistic(f64),
    /// Event-triggered communication (arXiv:1803.00368): node `k`
    /// transmits only when `‖w_k − w̃_k‖² > δ`, where `w̃_k` is the state
    /// it last put on the air; transmitting refreshes `w̃_k`.
    EventTriggered(f64),
}

impl Gating {
    /// Per-iteration transmit probability, when the gate is a Bernoulli
    /// process the closed-form impaired-link theory can average over
    /// (DESIGN.md §7): [`Gating::Always`] → 1, [`Gating::Probabilistic`]
    /// → p. Event-triggered gating depends on the trajectory itself and
    /// has no fixed transmit probability — `None`.
    pub fn transmit_prob(&self) -> Option<f64> {
        match self {
            Gating::Always => Some(1.0),
            Gating::Probabilistic(p) => Some(*p),
            Gating::EventTriggered(_) => None,
        }
    }
}

impl std::fmt::Display for Gating {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Gating::Always => write!(f, "always"),
            Gating::Probabilistic(p) => write!(f, "prob:{p}"),
            Gating::EventTriggered(d) => write!(f, "event:{d}"),
        }
    }
}

impl std::str::FromStr for Gating {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "always" {
            return Ok(Gating::Always);
        }
        if let Some(p) = s.strip_prefix("prob:") {
            return p
                .parse::<f64>()
                .map(Gating::Probabilistic)
                .map_err(|e| format!("gating {s:?}: {e}"));
        }
        if let Some(d) = s.strip_prefix("event:") {
            return d
                .parse::<f64>()
                .map(Gating::EventTriggered)
                .map_err(|e| format!("gating {s:?}: {e}"));
        }
        Err(format!(
            "gating {s:?}: expected always | prob:<p> | event:<delta>"
        ))
    }
}

/// Declarative link-impairment model for one scenario.
#[derive(Debug, Clone, PartialEq)]
pub struct LinkImpairments {
    /// Per-directed-link erasure probability per iteration, in `[0, 1]`.
    pub drop_prob: f64,
    /// Per-node transmit gate.
    pub gating: Gating,
    /// Uniform quantizer step Δ for the stored estimates (0 = off).
    pub quant_step: f64,
}

impl LinkImpairments {
    /// Ideal links: nothing dropped, nobody gated, full precision.
    pub fn ideal() -> Self {
        Self { drop_prob: 0.0, gating: Gating::Always, quant_step: 0.0 }
    }

    /// True when the model is a no-op (the coordinator then takes the
    /// exact legacy code path).
    pub fn is_ideal(&self) -> bool {
        self.drop_prob == 0.0 && self.gating == Gating::Always && self.quant_step == 0.0
    }

    /// True when link-level events (drops or gating) can occur — i.e.
    /// the per-iteration effective-matrix rebuild is actually needed.
    /// Quantization-only models return `false` and skip that work.
    pub fn affects_links(&self) -> bool {
        self.drop_prob > 0.0 || self.gating != Gating::Always
    }

    /// P that a directed link delivers its *combine* frame (transmitter
    /// on the air and no erasure): `p_tx · (1 − p_drop)`. `None` under
    /// event-triggered gating, which has no fixed transmit probability.
    pub fn combine_keep_prob(&self) -> Option<f64> {
        self.gating.transmit_prob().map(|p| p * (1.0 - self.drop_prob))
    }

    /// P that the *adapt* (solicited-gradient) exchange on a directed
    /// link survives: the transmitter is on the air, the frame is
    /// delivered, *and* the receiver solicited it by broadcasting its
    /// own estimate — `p_tx² · (1 − p_drop)` (DESIGN.md §7). `None`
    /// under event-triggered gating.
    pub fn adapt_keep_prob(&self) -> Option<f64> {
        self.gating.transmit_prob().map(|p| p * p * (1.0 - self.drop_prob))
    }

    /// Expected effective combiners `(Ā, C̄) = (E{A(i)}, E{C(i)})` under
    /// the independent-Bernoulli link-state model: exactly the
    /// per-iteration reallocation of [`ImpairmentState::begin_iteration`]
    /// taken in expectation — surviving off-diagonal mass scaled by the
    /// keep probability, the complement moved to the receiver's self
    /// weight. These are the matrices the impaired-link theory engine
    /// anchors on (DESIGN.md §7). `None` under event-triggered gating.
    pub fn expected_combiners(&self, net: &NetworkConfig) -> Option<(Combiner, Combiner)> {
        let pa = self.combine_keep_prob()?;
        let pc = self.adapt_keep_prob()?;
        Some((
            reallocate_expected(&net.a, pa),
            reallocate_expected(&net.c, pc),
        ))
    }

    /// [`Self::expected_combiners`] into caller-owned buffers: no
    /// allocation once `a_out`/`c_out` have the right structure
    /// (alloc-free discipline, `tests/alloc_free.rs`).
    pub fn expected_combiners_into(
        &self,
        net: &NetworkConfig,
        a_out: &mut Combiner,
        c_out: &mut Combiner,
    ) -> Option<()> {
        let pa = self.combine_keep_prob()?;
        let pc = self.adapt_keep_prob()?;
        reallocate_expected_into(&net.a, pa, a_out);
        reallocate_expected_into(&net.c, pc, c_out);
        Some(())
    }

    /// Range checks for every knob.
    pub fn validate(&self) -> Result<(), String> {
        if !self.drop_prob.is_finite() || !(0.0..=1.0).contains(&self.drop_prob) {
            return Err(format!(
                "impairments: drop_prob {} outside [0, 1]",
                self.drop_prob
            ));
        }
        match self.gating {
            Gating::Always => {}
            Gating::Probabilistic(p) => {
                if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                    return Err(format!("impairments: gating prob {p} outside [0, 1]"));
                }
            }
            Gating::EventTriggered(d) => {
                if !d.is_finite() || d < 0.0 {
                    return Err(format!("impairments: event threshold {d} must be >= 0"));
                }
            }
        }
        if !self.quant_step.is_finite() || self.quant_step < 0.0 {
            return Err(format!(
                "impairments: quant_step {} must be >= 0",
                self.quant_step
            ));
        }
        Ok(())
    }
}

impl Default for LinkImpairments {
    fn default() -> Self {
        Self::ideal()
    }
}

/// Scale every off-diagonal entry of `m` by `keep`, re-allocating the
/// complement to the column's diagonal — the expected-value form of the
/// per-iteration erasure reallocation (DESIGN.md §7). The single source
/// of that rule in expectation: shared by
/// [`LinkImpairments::expected_combiners`] and the theory engine's
/// expected-combiner construction (`theory/linkstate.rs`).
pub(crate) fn reallocate_expected(m: &Combiner, keep: f64) -> Combiner {
    let mut out = m.clone();
    reallocate_expected_into(m, keep, &mut out);
    out
}

/// [`reallocate_expected`] into a caller-owned combiner, reusing its
/// buffers. O(nnz): the CSR rows *are* the dense columns, walked in the
/// same ascending-sender order as the historical dense loop, so the
/// diagonal accumulates in the identical floating-point order.
pub(crate) fn reallocate_expected_into(m: &Combiner, keep: f64, out: &mut Combiner) {
    out.clone_from(m);
    for k in 0..m.n() {
        let di = m.diag_idx(k);
        let vals = out.vals_mut();
        for idx in m.row_span(k) {
            if idx == di {
                continue;
            }
            let v = m.vals()[idx];
            if v != 0.0 {
                let moved = v * (1.0 - keep);
                vals[idx] -= moved;
                vals[di] += moved;
            }
        }
    }
}

/// Snap every entry of `w` to the uniform grid of step `step`
/// (mid-tread quantizer; `step <= 0` is a no-op).
pub fn quantize_in_place(w: &mut [f64], step: f64) {
    if step <= 0.0 {
        return;
    }
    for x in w.iter_mut() {
        *x = (*x / step).round() * step;
    }
}

/// Per-run mutable state of the link-event layer: pristine combiner
/// copies, the event-trigger reference states, and the dedicated RNG.
/// Only needed when [`LinkImpairments::affects_links`] — quantization is
/// stateless and applied directly by the scheduler.
///
/// Driven by the round scheduler: [`ImpairmentState::begin_iteration`]
/// before every [`Algorithm::step`], [`ImpairmentState::restore`] once
/// the run finishes.
pub struct ImpairmentState {
    /// Pristine CSR values of the combine matrix A (same layout as the
    /// network's combiner — the per-iteration effective matrices are
    /// rebuilt by one O(E) memcpy from these, allocation-free).
    a0: Vec<f64>,
    /// Pristine CSR values of the adapt matrix C.
    c0: Vec<f64>,
    /// Last-broadcast reference states w̃ (N × L, event gating).
    last_broadcast: Vec<f64>,
    /// Per-node silence decisions for the current iteration.
    silent: Vec<bool>,
    /// Edge-indexed request-delivery outcomes: did src's estimate
    /// broadcast reach dst this iteration? The single source of truth
    /// shared by the effective-matrix rebuild *and* the ledger's
    /// solicited-reply billing (DESIGN.md §9).
    delivered: LinkOutcomes,
    rng: Pcg64,
    dim: usize,
}

impl ImpairmentState {
    /// Capture the pristine combiners of `net` and seed the impairment
    /// stream for one run (`stream` is the Monte-Carlo run stream).
    pub fn new(net: &NetworkConfig, seed: u64, stream: u64) -> Self {
        Self {
            a0: net.a.vals().to_vec(),
            c0: net.c.vals().to_vec(),
            last_broadcast: vec![0.0; net.n_nodes() * net.dim],
            silent: vec![false; net.n_nodes()],
            delivered: LinkOutcomes::for_graph(&net.graph),
            rng: Pcg64::new(seed ^ LINK_SEED_SALT, stream),
            dim: net.dim,
        }
    }

    /// Which nodes are off the air this iteration (valid after
    /// [`Self::begin_iteration`]).
    pub fn silent(&self) -> &[bool] {
        &self.silent
    }

    /// The request-delivery outcomes of the current iteration (valid
    /// after [`Self::begin_iteration`]).
    pub fn delivered(&self) -> &LinkOutcomes {
        &self.delivered
    }

    /// Draw this iteration's link events and install their consequences:
    /// effective A/C matrices in the algorithm's network config and the
    /// transmit-mute mask in the meter.
    pub fn begin_iteration(
        &mut self,
        imp: &LinkImpairments,
        alg: &mut dyn Algorithm,
        comm: &mut CommMeter,
    ) {
        let l = self.dim;
        let n = self.silent.len();

        // 1. Per-node transmit gate.
        match imp.gating {
            Gating::Always => self.silent.iter_mut().for_each(|s| *s = false),
            Gating::Probabilistic(p) => {
                for s in self.silent.iter_mut() {
                    *s = !self.rng.next_bool(p);
                }
            }
            Gating::EventTriggered(delta) => {
                let w = alg.weights();
                for k in 0..n {
                    let wk = &w[k * l..(k + 1) * l];
                    let lb = &mut self.last_broadcast[k * l..(k + 1) * l];
                    let moved: f64 = wk
                        .iter()
                        .zip(lb.iter())
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    let quiet = moved <= delta;
                    self.silent[k] = quiet;
                    if !quiet {
                        // Transmitting refreshes the reference state.
                        lb.copy_from_slice(wk);
                    }
                }
            }
        }

        // 2. Effective combiners: start from the pristine copies (one
        // O(E) value memcpy — the CSR structure never changes), then
        // erase every dead directed link (l → k), re-allocating its mass
        // to the receiver's self weight — the completion rule of
        // eqs. (11)-(12) applied at matrix level. A silent node also
        // *solicits* nothing: it broadcast no estimate for neighbours to
        // evaluate gradients at, so its whole C column collapses to the
        // self weight and it runs a pure self-LMS adapt that iteration.
        // The per-link outcomes recorded here are the same ones the
        // ledger bills against below — one draw, two consumers.
        //
        // The loop walks *graph* edges, not stored combiner entries:
        // that keeps the salted-PCG64 draw order (one conditional draw
        // per directed edge) bit-identical to the historical dense
        // rebuild even when a combiner's support is smaller than the
        // graph (e.g. A = I), where the erasure is then a no-op.
        let net = alg.network_mut();
        net.a.vals_mut().copy_from_slice(&self.a0);
        net.c.vals_mut().copy_from_slice(&self.c0);
        self.delivered.reset_all_true();
        let p = imp.drop_prob;
        for k in 0..n {
            let a_diag = net.a.diag_idx(k);
            let c_diag = net.c.diag_idx(k);
            for (slot, &lnb) in net.graph.neighbors(k).iter().enumerate() {
                let delivered = !self.silent[lnb] && !(p > 0.0 && self.rng.next_bool(p));
                self.delivered.set_row_slot(k, slot, delivered);
                if !delivered {
                    if let Some(idx) = net.a.entry_idx(k, lnb) {
                        let am = net.a.vals()[idx];
                        if am != 0.0 {
                            let vals = net.a.vals_mut();
                            vals[idx] = 0.0;
                            vals[a_diag] += am;
                        }
                    }
                }
                if !delivered || self.silent[k] {
                    if let Some(idx) = net.c.entry_idx(k, lnb) {
                        let cm = net.c.vals()[idx];
                        if cm != 0.0 {
                            let vals = net.c.vals_mut();
                            vals[idx] = 0.0;
                            vals[c_diag] += cm;
                        }
                    }
                }
            }
        }

        // 3. Install the outcomes in the ledger: gated nodes transmit
        // nothing and are billed nothing, and a gradient reply whose
        // soliciting broadcast died on this table is never billed
        // (DESIGN.md §9 billing rules).
        comm.set_outcomes(&self.silent, Some(&self.delivered));
    }

    /// Put the pristine combiners back (so a reused algorithm instance
    /// sees its original configuration) and clear the ledger's outcome
    /// tables.
    pub fn restore(&self, alg: &mut dyn Algorithm, comm: &mut CommMeter) {
        let net = alg.network_mut();
        net.a.vals_mut().copy_from_slice(&self.a0);
        net.c.vals_mut().copy_from_slice(&self.c0);
        comm.clear_outcomes();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, NetworkConfig};
    use crate::topology::{combination_matrix, Graph, Rule};

    fn net(n: usize, l: usize) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l }
    }

    #[test]
    fn quantizer_snaps_to_grid() {
        let mut w = [0.1234, -0.567, 0.0, 2.0001];
        quantize_in_place(&mut w, 0.01);
        for x in &w {
            let q = x / 0.01;
            assert!((q - q.round()).abs() < 1e-9, "{x} not on grid");
        }
        assert!((w[0] - 0.12).abs() < 1e-12);
        let mut v = [0.1234];
        quantize_in_place(&mut v, 0.0);
        assert_eq!(v[0], 0.1234);
    }

    #[test]
    fn gating_parse_display_roundtrip() {
        for g in [
            Gating::Always,
            Gating::Probabilistic(0.25),
            Gating::EventTriggered(1e-6),
        ] {
            let s = g.to_string();
            assert_eq!(s.parse::<Gating>().unwrap(), g);
        }
        assert!("sometimes".parse::<Gating>().is_err());
        assert!("prob:x".parse::<Gating>().is_err());
    }

    #[test]
    fn validation_rejects_out_of_range() {
        let mut imp = LinkImpairments::ideal();
        assert!(imp.validate().is_ok());
        assert!(imp.is_ideal());
        imp.drop_prob = 1.5;
        assert!(imp.validate().is_err());
        imp.drop_prob = 0.2;
        assert!(!imp.is_ideal());
        assert!(imp.validate().is_ok());
        imp.gating = Gating::Probabilistic(-0.1);
        assert!(imp.validate().is_err());
        imp.gating = Gating::EventTriggered(-1.0);
        assert!(imp.validate().is_err());
        imp.gating = Gating::Always;
        imp.quant_step = f64::NAN;
        assert!(imp.validate().is_err());
    }

    #[test]
    fn full_drop_isolates_every_node() {
        let cfg = net(5, 3);
        let mut alg = Dcd::new(cfg.clone(), 2, 1);
        let mut comm = CommMeter::new(5);
        let imp = LinkImpairments {
            drop_prob: 1.0,
            gating: Gating::Always,
            quant_step: 0.0,
        };
        let mut state = ImpairmentState::new(alg.network(), 7, 1);
        state.begin_iteration(&imp, &mut alg, &mut comm);
        let a = &alg.network().a;
        for k in 0..5 {
            for lk in 0..5 {
                if k != lk {
                    assert_eq!(a[(lk, k)], 0.0, "({lk},{k}) should be erased");
                }
            }
            assert!((a[(k, k)] - 1.0).abs() < 1e-12);
        }
        // Column-stochasticity is preserved by the diagonal re-allocation.
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        state.restore(&mut alg, &mut comm);
        assert_eq!(alg.network().a, cfg.a, "restore must be bit-identical");
    }

    #[test]
    fn probabilistic_gate_extremes() {
        let cfg = net(6, 2);
        let mut alg = Dcd::new(cfg, 1, 1);
        let mut comm = CommMeter::new(6);
        let all_off = LinkImpairments {
            drop_prob: 0.0,
            gating: Gating::Probabilistic(0.0),
            quant_step: 0.0,
        };
        let mut state = ImpairmentState::new(alg.network(), 3, 1);
        state.begin_iteration(&all_off, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| s));
        let all_on = LinkImpairments {
            drop_prob: 0.0,
            gating: Gating::Probabilistic(1.0),
            quant_step: 0.0,
        };
        state.begin_iteration(&all_on, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| !s));
    }

    /// `expected_combiners` must be the Monte-Carlo average of the
    /// effective matrices `begin_iteration` actually installs — the
    /// closed form and the per-iteration rebuild are the same model.
    #[test]
    fn expected_combiners_match_realized_average() {
        let cfg = net(5, 2);
        let mut alg = Dcd::new(cfg.clone(), 1, 1);
        let mut comm = CommMeter::new(5);
        let imp = LinkImpairments {
            drop_prob: 0.25,
            gating: Gating::Probabilistic(0.8),
            quant_step: 0.0,
        };
        let (a_bar, c_bar) = imp.expected_combiners(&cfg).unwrap();
        let mut state = ImpairmentState::new(alg.network(), 13, 1);
        let trials = 60_000;
        let mut a_acc = crate::linalg::Mat::zeros(5, 5);
        let mut c_acc = crate::linalg::Mat::zeros(5, 5);
        for _ in 0..trials {
            state.begin_iteration(&imp, &mut alg, &mut comm);
            a_acc.axpy(1.0, &alg.network().a.to_dense());
            c_acc.axpy(1.0, &alg.network().c.to_dense());
        }
        a_acc.scale_in_place(1.0 / trials as f64);
        c_acc.scale_in_place(1.0 / trials as f64);
        let (a_bar, c_bar) = (a_bar.to_dense(), c_bar.to_dense());
        assert!((&a_acc - &a_bar).max_abs() < 6e-3, "Ā off by {}", (&a_acc - &a_bar).max_abs());
        assert!((&c_acc - &c_bar).max_abs() < 6e-3, "C̄ off by {}", (&c_acc - &c_bar).max_abs());
        state.restore(&mut alg, &mut comm);
        // Event-triggered gating has no closed form.
        let ev = LinkImpairments {
            drop_prob: 0.1,
            gating: Gating::EventTriggered(1e-6),
            quant_step: 0.0,
        };
        assert!(ev.expected_combiners(&cfg).is_none());
        assert_eq!(ev.gating.transmit_prob(), None);
        // Ideal impairments leave the combiners bit-identical.
        let (a_id, c_id) = LinkImpairments::ideal().expected_combiners(&cfg).unwrap();
        assert_eq!(a_id, cfg.a);
        assert_eq!(c_id, cfg.c);
    }

    #[test]
    fn keep_probabilities() {
        let imp = LinkImpairments {
            drop_prob: 0.2,
            gating: Gating::Probabilistic(0.5),
            quant_step: 0.0,
        };
        assert!((imp.combine_keep_prob().unwrap() - 0.5 * 0.8).abs() < 1e-15);
        assert!((imp.adapt_keep_prob().unwrap() - 0.25 * 0.8).abs() < 1e-15);
        assert_eq!(Gating::Always.transmit_prob(), Some(1.0));
    }

    /// The delivered table installed in the meter is the same event the
    /// effective matrices encode: with every frame erased, estimate
    /// broadcasts stay billed (transmitter pays) while every solicited
    /// gradient reply is suppressed and tracked (DESIGN.md §9).
    #[test]
    fn ledger_outcomes_follow_the_link_events() {
        use crate::algorithms::Purpose;
        let cfg = net(4, 2);
        let mut alg = Dcd::new(cfg, 1, 1);
        let mut comm = CommMeter::new(4);
        let all_dropped = LinkImpairments {
            drop_prob: 1.0,
            gating: Gating::Always,
            quant_step: 0.0,
        };
        let mut state = ImpairmentState::new(alg.network(), 11, 1);
        state.begin_iteration(&all_dropped, &mut alg, &mut comm);
        // Every directed edge is dead in the table...
        for k in 0..4 {
            for &lnb in alg.network().graph.neighbors(k) {
                assert!(!state.delivered().delivered(lnb, k), "{lnb}->{k} should be erased");
            }
        }
        // ... so a broadcast is billed but its solicited reply is not.
        comm.send(0, 1, Purpose::Estimate, 3);
        comm.send(1, 0, Purpose::Gradient, 2);
        assert_eq!(comm.scalars(), 3);
        assert_eq!(comm.ledger().suppressed_scalars, 2);
        assert_eq!(comm.ledger().legacy_scalars(), 5);
        state.restore(&mut alg, &mut comm);
        // Outcomes cleared: everything billed again.
        comm.send(1, 0, Purpose::Gradient, 2);
        assert_eq!(comm.scalars(), 5);
    }

    #[test]
    fn event_trigger_silences_unchanged_nodes() {
        let cfg = net(4, 3);
        let mut alg = Dcd::new(cfg, 2, 1);
        let mut comm = CommMeter::new(4);
        let imp = LinkImpairments {
            drop_prob: 0.0,
            gating: Gating::EventTriggered(1e-9),
            quant_step: 0.0,
        };
        let mut state = ImpairmentState::new(alg.network(), 5, 1);
        // Fresh algorithm: w == w̃ == 0, nobody has news to share.
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(state.silent().iter().all(|&s| s));
        // Move one node's estimate: only that node transmits.
        alg.weights_mut()[0] = 1.0;
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(!state.silent()[0]);
        assert!(state.silent()[1..].iter().all(|&s| s));
        // The broadcast refreshed w̃_0: silent again next round.
        state.begin_iteration(&imp, &mut alg, &mut comm);
        assert!(state.silent()[0]);
    }
}
