//! Lane engine: run-batched Monte-Carlo execution (DESIGN.md §14).
//!
//! The round scheduler advances one realization at a time; at small
//! network sizes the per-iteration cost is dominated by short loops,
//! virtual dispatch and per-node temporaries rather than floating-point
//! work. The lane engine amortises all of that across *runs*: B
//! independent realizations are packed into lane-major SoA state
//! (`weights[(k·L + j)·B + b]` holds lane b's entry) and one
//! [`BatchStep::batch_step`](crate::algorithms::BatchStep::batch_step)
//! call advances all B of them with edge-major inner loops over
//! contiguous lane blocks — the same memory-motion trick the xla engine
//! plays across nodes, applied across realizations, without leaving f64
//! or the message-level billing model.
//!
//! The contract is **bit-identity** (DESIGN.md §14): lane b of a block
//! starting at run `r0` must reproduce the scalar
//! [`RoundScheduler::run`](super::round::RoundScheduler::run) with
//! stream `r0 + b + 1` byte for byte — MSD trace, ledger, link-state
//! tallies, everything. The engine gets this by construction:
//!
//! * every per-run random sequence (data, drift, impairments, selection
//!   masks) is drawn from that run's own PCG64 streams in the scalar
//!   order — lanes never share an RNG;
//! * every floating-point reduction inside a lane replicates the scalar
//!   operation order exactly (the lane-strided kernels of
//!   [`crate::linalg::kernels`] carry the same partial-sum shapes);
//! * lanes never mix: SoA rows interleave *storage*, not arithmetic.
//!
//! Runs whose configuration has no batched path (an algorithm without a
//! [`BatchStep`](crate::algorithms::BatchStep) face, network dynamics,
//! noisy DCD links) are routed to the scalar scheduler by the runner —
//! per run range, so mixed layouts still fold in run order.

use crate::algorithms::{Algorithm, BatchCtx, BatchData, CommMeter};
use crate::datamodel::DataModel;
use crate::rng::Pcg64;

use super::impairments::{
    quantize_in_place, Gating, ImpairmentState, LinkImpairments, LinkStateStats,
};
use super::round::RunResult;
use super::runner::SchedulerOptions;

/// Requested lane width for the run-batched engine (`[schedule] lanes`,
/// `--lanes`). The default `Fixed(1)` is the scalar path — artifacts are
/// byte-identical at every width, so this is a pure throughput knob (and
/// deliberately *not* part of the serve cache key).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneCount {
    /// Pick a width from the run count (currently min(4, runs)).
    Auto,
    /// Exactly this many runs per SoA block (1 = scalar scheduler).
    Fixed(usize),
}

impl Default for LaneCount {
    fn default() -> Self {
        LaneCount::Fixed(1)
    }
}

impl std::fmt::Display for LaneCount {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LaneCount::Auto => write!(f, "auto"),
            LaneCount::Fixed(n) => write!(f, "{n}"),
        }
    }
}

impl std::str::FromStr for LaneCount {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "auto" {
            return Ok(LaneCount::Auto);
        }
        match s.parse::<usize>() {
            Ok(0) => Err("lanes 0: need at least one lane per block \
                          (1 = scalar path; auto = pick from the run count)"
                .into()),
            Ok(n) => Ok(LaneCount::Fixed(n)),
            Err(e) => Err(format!("lanes {s:?}: {e} (expected auto or a positive integer)")),
        }
    }
}

impl LaneCount {
    /// Reject widths the engine cannot run (0 lanes). Parsing already
    /// refuses these; this guards values built programmatically.
    pub fn validate(&self) -> Result<(), String> {
        match self {
            LaneCount::Fixed(0) => Err("lanes 0: need at least one lane per block \
                                        (1 = scalar path; auto = pick from the run count)"
                .into()),
            _ => Ok(()),
        }
    }

    /// The effective SoA width for `runs` realizations.
    pub fn resolve(&self, runs: usize) -> usize {
        match self {
            LaneCount::Auto => runs.max(1).min(4),
            LaneCount::Fixed(n) => (*n).max(1),
        }
    }

    /// True for the default scalar width (the artifact-neutral value the
    /// serve cache canonicalises to).
    pub fn is_default(&self) -> bool {
        *self == LaneCount::Fixed(1)
    }
}

/// Execute the contiguous realization block
/// `[run_start, run_start + lanes)` in SoA lockstep and return the
/// per-run results **in run order** — each byte-identical to the scalar
/// [`RoundScheduler::run`](super::round::RoundScheduler::run) with the
/// same seed and stream `run_start + b + 1`.
///
/// `alg` must expose a batched face
/// ([`Algorithm::as_batch`](crate::algorithms::Algorithm::as_batch) →
/// `Some`) and `opts.dynamics` must be absent or static — the runner
/// routes every other configuration to the scalar path before getting
/// here.
#[allow(clippy::too_many_arguments)]
pub fn run_lane_block(
    model: &DataModel,
    opts: &SchedulerOptions,
    alg: &mut dyn Algorithm,
    iters: usize,
    seed: u64,
    record_every: usize,
    run_start: usize,
    lanes: usize,
) -> Vec<RunResult> {
    assert!(lanes >= 1, "lane block needs at least one lane");
    assert!(
        opts.dynamics.as_ref().map_or(true, |d| d.is_static()),
        "network dynamics are scalar-only; the runner must not lane-batch them"
    );
    let n = model.n_nodes;
    let l = model.dim;
    let record_every = record_every.max(1);

    // Per-lane scalar-run plumbing, each seeded exactly as the scalar
    // scheduler would for stream `run_start + b + 1`.
    let mut rngs: Vec<Pcg64> = (0..lanes)
        .map(|b| Pcg64::new(seed, (run_start + b) as u64 + 1))
        .collect();
    let mut comms: Vec<CommMeter> = (0..lanes).map(|_| CommMeter::new(n)).collect();
    let imp = opts.impairments.as_ref().filter(|imp| !imp.is_ideal());
    if let Some(imp) = imp {
        for comm in &mut comms {
            comm.set_quant_step(imp.quant_step);
        }
    }
    let ideal = LinkImpairments::ideal();
    let imp_link = imp.unwrap_or(&ideal);
    let mut states: Vec<ImpairmentState> = match imp {
        Some(i) if i.affects_links() => (0..lanes)
            .map(|b| ImpairmentState::new(alg.network(), seed, (run_start + b) as u64 + 1))
            .collect(),
        _ => Vec::new(),
    };
    let event_gating = !states.is_empty() && matches!(imp_link.gating, Gating::EventTriggered(_));

    // Per-lane *effective* CSR combiner values, lane-blocked: lane b's
    // arrays are `a_vals[b*nnz_a..(b+1)*nnz_a]` / likewise for C. Under
    // impairments the erase pass rebuilds them from the pristine copies
    // every iteration (one O(E) memcpy per lane); ideal runs install the
    // pristine values once here and never touch them again.
    let graph = alg.network().graph.clone();
    let nnz_a = alg.network().a.nnz();
    let nnz_c = alg.network().c.nnz();
    let mut a_vals = vec![0.0; nnz_a * lanes];
    let mut c_vals = vec![0.0; nnz_c * lanes];
    for b in 0..lanes {
        a_vals[b * nnz_a..(b + 1) * nnz_a].copy_from_slice(alg.network().a.vals());
        c_vals[b * nnz_c..(b + 1) * nnz_c].copy_from_slice(alg.network().c.vals());
    }

    // The drifting optimum is per-run state: each lane advances its own
    // w°(i) from its own data RNG, exactly as the scalar loop does.
    let drifting = !opts.drift.is_none();
    let mut wo_cur: Vec<Vec<f64>> = (0..lanes).map(|_| model.wo.clone()).collect();

    // Data staging: one scalar-layout snapshot per lane, scattered into
    // the shared SoA tensors. The scatter is pure data movement — lane
    // b's values are exactly the scalar run's u/d bytes.
    let mut u_tmp = vec![0.0; n * l];
    let mut d_tmp = vec![0.0; n];
    let mut u_soa = vec![0.0; n * l * lanes];
    let mut d_soa = vec![0.0; n * lanes];
    // Row-major weight gather, read only by event-triggered gating.
    let mut w_row = vec![0.0; if event_gating { n * l } else { 0 }];

    let mut msd: Vec<Vec<f64>> = (0..lanes)
        .map(|_| Vec::with_capacity(iters / record_every + 1))
        .collect();

    let batch = alg
        .as_batch()
        .expect("lane engine requires an algorithm with a batched face");
    batch.batch_reset(lanes);
    for i in 0..iters {
        for b in 0..lanes {
            if drifting {
                opts.drift.advance(&mut wo_cur[b], &mut rngs[b]);
            }
            model.sample_iteration_at(&wo_cur[b], &mut rngs[b], &mut u_tmp, &mut d_tmp);
            for (j, &x) in u_tmp.iter().enumerate() {
                u_soa[j * lanes + b] = x;
            }
            for (k, &x) in d_tmp.iter().enumerate() {
                d_soa[k * lanes + b] = x;
            }
        }
        if !states.is_empty() {
            for (b, state) in states.iter_mut().enumerate() {
                let weights: &[f64] = if event_gating {
                    let w_soa = batch.batch_weights();
                    for (jk, dst) in w_row.iter_mut().enumerate() {
                        *dst = w_soa[jk * lanes + b];
                    }
                    &w_row
                } else {
                    &[]
                };
                state.begin_iteration_lanes(
                    imp_link,
                    &graph,
                    weights,
                    &mut a_vals[b * nnz_a..(b + 1) * nnz_a],
                    &mut c_vals[b * nnz_c..(b + 1) * nnz_c],
                    &mut comms[b],
                );
            }
        }
        batch.batch_step(
            BatchData { u: &u_soa, d: &d_soa },
            BatchCtx { lanes, c_vals: &c_vals, a_vals: &a_vals },
            &mut rngs,
            &mut comms,
        );
        if let Some(imp) = imp {
            if imp.quant_step > 0.0 {
                // Elementwise snap: lane values land on exactly the grid
                // points the scalar run's would.
                quantize_in_place(batch.batch_weights_mut(), imp.quant_step);
            }
        }
        if (i + 1) % record_every == 0 {
            for (b, trace) in msd.iter_mut().enumerate() {
                trace.push(batch.batch_msd(b, &wo_cur[b]));
            }
        }
    }

    // Unpack per-lane results in run order. The algorithm's own
    // combiners were never modified (effective values lived in the lane
    // arrays), so there is nothing to restore on it.
    let mut states = states.into_iter();
    msd.into_iter()
        .zip(comms)
        .map(|(msd, mut comm)| {
            let linkstate = match states.next() {
                Some(s) => {
                    comm.clear_outcomes();
                    s.into_stats()
                }
                None => LinkStateStats::default(),
            };
            RunResult { msd, ledger: comm.into_ledger(), linkstate }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Dcd, DiffusionLms, NetworkConfig};
    use crate::coordinator::impairments::DropModel;
    use crate::coordinator::round::RoundScheduler;
    use crate::datamodel::DriftModel;
    use crate::topology::{combination_matrix, Graph, Rule};

    #[test]
    fn lane_count_parse_display_validate() {
        assert_eq!("auto".parse::<LaneCount>().unwrap(), LaneCount::Auto);
        assert_eq!("4".parse::<LaneCount>().unwrap(), LaneCount::Fixed(4));
        assert!("0".parse::<LaneCount>().unwrap_err().contains("lanes 0"));
        assert!("-2".parse::<LaneCount>().is_err());
        assert!("many".parse::<LaneCount>().is_err());
        for lc in [LaneCount::Auto, LaneCount::Fixed(1), LaneCount::Fixed(8)] {
            assert_eq!(lc.to_string().parse::<LaneCount>().unwrap(), lc);
        }
        assert!(LaneCount::Fixed(0).validate().is_err());
        assert!(LaneCount::Auto.validate().is_ok());
        assert_eq!(LaneCount::default(), LaneCount::Fixed(1));
        assert!(LaneCount::default().is_default());
        assert!(!LaneCount::Auto.is_default());
        assert_eq!(LaneCount::Auto.resolve(2), 2);
        assert_eq!(LaneCount::Auto.resolve(100), 4);
        assert_eq!(LaneCount::Auto.resolve(0), 1);
        assert_eq!(LaneCount::Fixed(8).resolve(2), 8);
    }

    fn case(n: usize, l: usize) -> (DataModel, NetworkConfig) {
        let mut rng = Pcg64::new(41, 0);
        let model = DataModel::paper(n, l, 0.8, 1.2, 1e-3, &mut rng);
        let graph = Graph::ring(n, 2);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig { graph, c, a, mu: vec![0.04; n], dim: l };
        (model, net)
    }

    fn scalar_runs(
        model: &DataModel,
        opts: &SchedulerOptions,
        make_alg: impl Fn() -> Box<dyn Algorithm>,
        iters: usize,
        seed: u64,
        record_every: usize,
        run_start: usize,
        count: usize,
    ) -> Vec<RunResult> {
        let mut sched = RoundScheduler::new(model);
        sched.record_every = record_every;
        sched.impairments = opts.impairments.clone();
        sched.dynamics = opts.dynamics.clone();
        sched.drift = opts.drift;
        (0..count)
            .map(|b| {
                let mut alg = make_alg();
                sched.run(alg.as_mut(), iters, seed, (run_start + b) as u64 + 1)
            })
            .collect()
    }

    fn assert_block_matches(a: &[RunResult], b: &[RunResult], tag: &str) {
        assert_eq!(a.len(), b.len(), "{tag}: run counts differ");
        for (r, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.msd.len(), y.msd.len(), "{tag} run {r}: trace lengths");
            for (i, (ma, mb)) in x.msd.iter().zip(y.msd.iter()).enumerate() {
                assert_eq!(
                    ma.to_bits(),
                    mb.to_bits(),
                    "{tag} run {r} iter {i}: {ma} vs {mb}"
                );
            }
            assert_eq!(x.ledger, y.ledger, "{tag} run {r}: ledgers differ");
            assert_eq!(x.linkstate, y.linkstate, "{tag} run {r}: linkstate differs");
        }
    }

    /// Every impairment axis the lane engine supports, against the
    /// scalar scheduler, bit for bit — including the block not starting
    /// at run 0 and a thinned record grid.
    #[test]
    fn lane_block_bitwise_matches_scalar_scheduler() {
        let (model, net) = case(6, 4);
        let impaired = |imp: LinkImpairments| SchedulerOptions {
            impairments: Some(imp),
            ..SchedulerOptions::default()
        };
        let cases: Vec<(&str, SchedulerOptions)> = vec![
            ("ideal", SchedulerOptions::default()),
            ("drop", impaired(LinkImpairments::with_drop_prob(0.3))),
            (
                "bursty-gated-quant",
                impaired(LinkImpairments {
                    drop: DropModel::Markov { p_bad: 0.3, p_gb: 0.25, p_bg: 0.25 },
                    gating: Gating::Probabilistic(0.8),
                    quant_step: 1e-4,
                    per_leg: false,
                }),
            ),
            (
                "per-leg-event",
                impaired(LinkImpairments {
                    drop: DropModel::Iid(0.25),
                    gating: Gating::EventTriggered(1e-6),
                    quant_step: 0.0,
                    per_leg: true,
                }),
            ),
            (
                "drift",
                SchedulerOptions {
                    drift: DriftModel::Walk { sigma: 1e-3 },
                    ..SchedulerOptions::default()
                },
            ),
        ];
        for (tag, opts) in &cases {
            for &(run_start, lanes, record_every) in
                &[(0usize, 3usize, 1usize), (2, 2, 4), (5, 1, 1)]
            {
                let make = || -> Box<dyn Algorithm> { Box::new(DiffusionLms::new(net.clone())) };
                let scalar = scalar_runs(
                    &model, opts, make, 160, 97, record_every, run_start, lanes,
                );
                let mut alg = DiffusionLms::new(net.clone());
                let laned = run_lane_block(
                    &model, opts, &mut alg, 160, 97, record_every, run_start, lanes,
                );
                assert_block_matches(&laned, &scalar, &format!("{tag}@{run_start}x{lanes}"));
            }
        }
    }

    /// DCD's batched face (mask draws from per-lane RNGs) under the same
    /// battery.
    #[test]
    fn dcd_lane_block_bitwise_matches_scalar_scheduler() {
        let (model, net) = case(5, 4);
        let opts_list: Vec<(&str, SchedulerOptions)> = vec![
            ("ideal", SchedulerOptions::default()),
            (
                "lossy",
                SchedulerOptions {
                    impairments: Some(LinkImpairments {
                        drop: DropModel::Iid(0.3),
                        gating: Gating::Probabilistic(0.7),
                        quant_step: 1e-4,
                        per_leg: true,
                    }),
                    ..SchedulerOptions::default()
                },
            ),
        ];
        for (tag, opts) in &opts_list {
            let make = || -> Box<dyn Algorithm> { Box::new(Dcd::new(net.clone(), 2, 1)) };
            let scalar = scalar_runs(&model, opts, make, 150, 53, 1, 1, 4);
            let mut alg = Dcd::new(net.clone(), 2, 1);
            let laned = run_lane_block(&model, opts, &mut alg, 150, 53, 1, 1, 4);
            assert_block_matches(&laned, &scalar, tag);
        }
    }
}
