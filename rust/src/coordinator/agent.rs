//! Per-node DCD agent state machine over the [`bus`](super::bus).
//!
//! One iteration is a three-phase protocol, matching Alg. 1:
//!
//! 1. **broadcast** — draw H_k, Q_k; send `Estimate(H_k ∘ w_k)` to every
//!    neighbour.
//! 2. **reply** — for each received estimate, fill the missing entries
//!    with the local state, evaluate the instantaneous gradient at that
//!    point, and return its Q_k-masked entries; cache the received
//!    estimate for the combine step.
//! 3. **update** — fill received gradients with the local gradient
//!    (eq. (12)), adapt (eq. (10)), combine (eq. (11)).
//!
//! N agents plus the bus reproduce the vectorised
//! [`Dcd`](crate::algorithms::Dcd) implementation bit-for-bit (see the
//! equivalence test below) — this is the end-to-end validation of the
//! wire protocol.

use super::bus::{Bus, Message, PartialVector};
use crate::rng::Pcg64;

/// Per-node static configuration.
#[derive(Debug, Clone)]
pub struct AgentConfig {
    pub id: usize,
    pub dim: usize,
    pub m: usize,
    pub m_grad: usize,
    pub mu: f64,
    /// Neighbour ids (excluding self).
    pub neighbors: Vec<usize>,
    /// c_{lk} for l = each entry of `neighbors` (adapt weights), plus
    /// the self weight c_{kk}.
    pub c_self: f64,
    pub c_neighbors: Vec<f64>,
    /// a_{lk} combine weights, aligned with `neighbors`, plus a_{kk}.
    pub a_self: f64,
    pub a_neighbors: Vec<f64>,
}

/// A DCD agent.
pub struct Agent {
    cfg: AgentConfig,
    pub w: Vec<f64>,
    h_mask: Vec<f64>,
    q_mask: Vec<f64>,
    /// Estimates received this iteration: (from, partial vector).
    cached_estimates: Vec<(usize, PartialVector)>,
    /// Gradients received this iteration.
    cached_gradients: Vec<(usize, PartialVector)>,
    /// Local data for the current iteration.
    u: Vec<f64>,
    d: f64,
    rng: Pcg64,
    scratch: Vec<usize>,
    mask32: Vec<f32>,
    /// Uniform quantizer step for every *outgoing* scalar (0 = full
    /// precision) — the message-level face of the coordinator's
    /// quantization impairment.
    quant_step: f64,
}

impl Agent {
    pub fn new(cfg: AgentConfig, seed: u64) -> Self {
        let l = cfg.dim;
        let stream = cfg.id as u64;
        Self {
            cfg,
            w: vec![0.0; l],
            h_mask: vec![0.0; l],
            q_mask: vec![0.0; l],
            cached_estimates: Vec::new(),
            cached_gradients: Vec::new(),
            u: vec![0.0; l],
            d: 0.0,
            rng: Pcg64::new(seed, stream),
            scratch: Vec::new(),
            mask32: vec![0.0; l],
            quant_step: 0.0,
        }
    }

    pub fn id(&self) -> usize {
        self.cfg.id
    }

    /// Enable finite-precision transmission: every scalar in an outgoing
    /// frame (estimates and gradient replies) is snapped to the Δ grid
    /// before it hits the bus.
    pub fn set_quant_step(&mut self, step: f64) {
        self.quant_step = step;
    }

    /// Inject this iteration's local measurements.
    pub fn observe(&mut self, u: &[f64], d: f64) {
        self.u.copy_from_slice(u);
        self.d = d;
    }

    /// Override the selection masks (mask-injection for tests).
    pub fn set_masks(&mut self, h: &[f64], q: &[f64]) {
        self.h_mask.copy_from_slice(h);
        self.q_mask.copy_from_slice(q);
    }

    fn draw_masks(&mut self) {
        self.rng
            .fill_mask(&mut self.mask32, self.cfg.m, &mut self.scratch);
        for (dst, &src) in self.h_mask.iter_mut().zip(self.mask32.iter()) {
            *dst = src as f64;
        }
        self.rng
            .fill_mask(&mut self.mask32, self.cfg.m_grad, &mut self.scratch);
        for (dst, &src) in self.q_mask.iter_mut().zip(self.mask32.iter()) {
            *dst = src as f64;
        }
    }

    /// Phase 1: draw masks (unless injected) and broadcast the masked
    /// estimate to all neighbours.
    pub fn phase_broadcast(&mut self, bus: &Bus, draw: bool) {
        if draw {
            self.draw_masks();
        }
        self.cached_estimates.clear();
        self.cached_gradients.clear();
        let mut body = PartialVector::from_mask(&self.w, &self.h_mask);
        super::impairments::quantize_in_place(&mut body.val, self.quant_step);
        for &nb in &self.cfg.neighbors {
            bus.send(nb, Message::Estimate { from: self.cfg.id, body: body.clone() });
        }
    }

    /// Phase 2: answer every received estimate with a masked gradient,
    /// caching the estimate for the combine step.
    pub fn phase_reply(&mut self, bus: &Bus) {
        let msgs = bus.drain(self.cfg.id);
        for msg in msgs {
            match msg {
                Message::Estimate { from, body } => {
                    // Fill missing entries with the local state w_l.
                    let mut x = self.w.clone();
                    body.fill_into(&mut x);
                    let e = self.d
                        - self.u.iter().zip(x.iter()).map(|(a, b)| a * b).sum::<f64>();
                    let grad: Vec<f64> = self.u.iter().map(|&uj| uj * e).collect();
                    let mut reply = PartialVector::from_mask(&grad, &self.q_mask);
                    super::impairments::quantize_in_place(&mut reply.val, self.quant_step);
                    bus.send(from, Message::Gradient { from: self.cfg.id, body: reply });
                    self.cached_estimates.push((from, body));
                }
                Message::Gradient { from, body } => {
                    self.cached_gradients.push((from, body));
                }
            }
        }
    }

    /// Collect gradient replies that arrived after phase 2 drained.
    pub fn phase_collect(&mut self, bus: &Bus) {
        for msg in bus.drain(self.cfg.id) {
            match msg {
                Message::Gradient { from, body } => self.cached_gradients.push((from, body)),
                Message::Estimate { from, body } => self.cached_estimates.push((from, body)),
            }
        }
    }

    /// Phase 3: adapt + combine.
    pub fn phase_update(&mut self) {
        let l = self.cfg.dim;
        // Own residual and gradient (fills the missing entries, eq. (12)).
        let e_self = self.d
            - self
                .u
                .iter()
                .zip(self.w.iter())
                .map(|(a, b)| a * b)
                .sum::<f64>();
        let own_grad: Vec<f64> = self.u.iter().map(|&uj| uj * e_self).collect();

        // Adapt: psi = w + mu [ c_kk own_grad + sum_l c_lk g_l ].
        let mut psi: Vec<f64> = self.w.clone();
        for j in 0..l {
            psi[j] += self.cfg.mu * self.cfg.c_self * own_grad[j];
        }
        for (from, body) in &self.cached_gradients {
            let pos = self
                .cfg
                .neighbors
                .iter()
                .position(|&n| n == *from)
                .expect("gradient from non-neighbour");
            let c_lk = self.cfg.c_neighbors[pos];
            let mut g = own_grad.clone();
            body.fill_into(&mut g);
            for j in 0..l {
                psi[j] += self.cfg.mu * c_lk * g[j];
            }
        }

        // Combine: w = a_kk psi + sum_l a_lk (H_l w_l + (1 - H_l) psi).
        let mut w_new: Vec<f64> = psi.iter().map(|&x| self.cfg.a_self * x).collect();
        for (from, body) in &self.cached_estimates {
            let pos = self
                .cfg
                .neighbors
                .iter()
                .position(|&n| n == *from)
                .expect("estimate from non-neighbour");
            let a_lk = self.cfg.a_neighbors[pos];
            let mut filled = psi.clone();
            body.fill_into(&mut filled);
            for j in 0..l {
                w_new[j] += a_lk * filled[j];
            }
        }
        self.w = w_new;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, CommMeter, Dcd, DcdMasks, NetworkConfig, StepData};
    use crate::topology::{combination_matrix, Graph, Rule};

    fn build_agents(net: &NetworkConfig, m: usize, mg: usize) -> Vec<Agent> {
        let n = net.n_nodes();
        (0..n)
            .map(|k| {
                let neighbors: Vec<usize> = net.graph.neighbors(k).to_vec();
                let cfg = AgentConfig {
                    id: k,
                    dim: net.dim,
                    m,
                    m_grad: mg,
                    mu: net.mu[k],
                    c_self: net.c[(k, k)],
                    c_neighbors: neighbors.iter().map(|&l| net.c[(l, k)]).collect(),
                    a_self: net.a[(k, k)],
                    a_neighbors: neighbors.iter().map(|&l| net.a[(l, k)]).collect(),
                    neighbors,
                };
                Agent::new(cfg, 1234)
            })
            .collect()
    }

    /// Outgoing frames are grid-aligned when transmit quantization is on.
    #[test]
    fn quantized_agent_sends_grid_values() {
        let graph = Graph::ring(3, 1);
        let net = {
            let c = combination_matrix(&graph, Rule::Metropolis);
            let a = combination_matrix(&graph, Rule::Metropolis);
            NetworkConfig { graph, c, a, mu: vec![0.05; 3], dim: 4 }
        };
        let mut agents = build_agents(&net, 4, 4);
        let step = 0.01;
        agents[0].set_quant_step(step);
        agents[0].w = vec![0.1234, -0.5678, 0.0009, 2.5];
        agents[0].set_masks(&[1.0; 4], &[1.0; 4]);
        let bus = Bus::new(3);
        agents[0].phase_broadcast(&bus, false);
        for msg in bus.drain(1) {
            if let Message::Estimate { body, .. } = msg {
                for &v in &body.val {
                    let q = v / step;
                    assert!((q - q.round()).abs() < 1e-9, "{v} off the grid");
                }
            }
        }
    }

    /// The protocol equivalence test: N agents over the bus must produce
    /// exactly the same iterate as the vectorised Dcd implementation when
    /// driven with identical masks and data.
    #[test]
    fn agents_reproduce_vectorized_dcd() {
        let n = 6;
        let l = 4;
        let (m, mg) = (2, 1);
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Uniform);
        let net = NetworkConfig { graph, c, a, mu: vec![0.08; n], dim: l };

        let mut rng = Pcg64::new(77, 0);
        let mut vectorized = Dcd::new(net.clone(), m, mg);
        let mut agents = build_agents(&net, m, mg);
        let bus = Bus::new(n);
        let mut comm = CommMeter::new(n);

        for _iter in 0..5 {
            // Shared data and masks.
            let mut u = vec![0.0; n * l];
            let mut d = vec![0.0; n];
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for dk in d.iter_mut() {
                *dk = rng.next_gaussian();
            }
            let mut h = vec![0.0; n * l];
            let mut q = vec![0.0; n * l];
            let mut scratch = Vec::new();
            let mut m32 = vec![0f32; l];
            for k in 0..n {
                rng.fill_mask(&mut m32, m, &mut scratch);
                for j in 0..l {
                    h[k * l + j] = m32[j] as f64;
                }
                rng.fill_mask(&mut m32, mg, &mut scratch);
                for j in 0..l {
                    q[k * l + j] = m32[j] as f64;
                }
            }

            vectorized.step_with_masks(
                StepData { u: &u, d: &d },
                &DcdMasks { h: h.clone(), q: q.clone() },
                &mut comm,
            );

            for (k, ag) in agents.iter_mut().enumerate() {
                ag.observe(&u[k * l..(k + 1) * l], d[k]);
                ag.set_masks(&h[k * l..(k + 1) * l], &q[k * l..(k + 1) * l]);
            }
            for ag in agents.iter_mut() {
                ag.phase_broadcast(&bus, false);
            }
            for ag in agents.iter_mut() {
                ag.phase_reply(&bus);
            }
            for ag in agents.iter_mut() {
                ag.phase_collect(&bus);
            }
            for ag in agents.iter_mut() {
                ag.phase_update();
            }

            for (k, ag) in agents.iter().enumerate() {
                for j in 0..l {
                    let v = vectorized.weights()[k * l + j];
                    let w = ag.w[j];
                    assert!(
                        (v - w).abs() < 1e-12,
                        "iter {_iter} node {k} dim {j}: vec {v} vs agent {w}"
                    );
                }
            }
        }
        // The bus must have carried exactly M + M_grad scalars per
        // directed link per iteration.
        let links: usize = (0..n).map(|k| net.graph.neighbors(k).len()).sum();
        assert_eq!(bus.delivered_scalars(), (5 * links * (m + mg)) as u64);
        // Message-level and frame-level engines bill into the *same*
        // directional ledger model: the bus ledger reproduces the
        // vectorised meter's ledger exactly — per link, per purpose,
        // per node (DESIGN.md §9).
        assert_eq!(bus.ledger(), *comm.ledger());
    }
}
