//! Streaming linear data model (paper §II-A, eq. (1)):
//!
//!   d_k(i) = u_{k,i}ᵀ w° + v_k(i)
//!
//! with zero-mean Gaussian regressors u_{k,i} ~ N(0, σ²_{u,k} I_L) and
//! i.i.d. noise v_k(i) ~ N(0, σ²_{v,k}). Per-node variances follow the
//! paper's Fig. 2 (right): σ²_{u,k} drawn uniformly per node, σ²_{v,k}
//! fixed at 1e-3 in the experiments.

use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Time variation of the optimum w°(i) for tracking experiments
/// (DESIGN.md §12). The paper's experiments keep w° fixed
/// ([`DriftModel::None`]); the tracking literature's two standard
/// benchmarks are a Gaussian random walk and a deterministic rotation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DriftModel {
    /// Static optimum (the paper's setting).
    None,
    /// Random walk: w°(i) = w°(i−1) + σ·g(i), g ~ N(0, I). Draws come
    /// from the *data* RNG (the drift is part of the data process), so
    /// static scenarios consume exactly the historical sequence.
    Walk {
        /// Per-iteration step standard deviation σ.
        sigma: f64,
    },
    /// Rotation: coordinates (0, 1) of w° rotate by `omega` radians per
    /// iteration (deterministic — no RNG consumed). Requires `dim ≥ 2`.
    Rotate {
        /// Rotation rate in radians per iteration.
        omega: f64,
    },
}

impl DriftModel {
    /// True when the optimum never moves.
    pub fn is_none(&self) -> bool {
        matches!(
            *self,
            DriftModel::None
                | DriftModel::Walk { sigma: 0.0 }
                | DriftModel::Rotate { omega: 0.0 }
        )
    }

    /// Advance w° by one iteration in place.
    pub fn advance(&self, wo: &mut [f64], rng: &mut Pcg64) {
        match *self {
            DriftModel::None => {}
            DriftModel::Walk { sigma } => {
                for x in wo.iter_mut() {
                    *x += sigma * rng.next_gaussian();
                }
            }
            DriftModel::Rotate { omega } => {
                debug_assert!(wo.len() >= 2, "rotate drift requires dim >= 2");
                let (s, c) = omega.sin_cos();
                let (a, b) = (wo[0], wo[1]);
                wo[0] = c * a - s * b;
                wo[1] = s * a + c * b;
            }
        }
    }

    /// Range checks.
    pub fn validate(&self) -> Result<(), String> {
        match *self {
            DriftModel::None => Ok(()),
            DriftModel::Walk { sigma } => {
                if !sigma.is_finite() || sigma < 0.0 {
                    Err(format!("drift: walk sigma {sigma} must be >= 0"))
                } else {
                    Ok(())
                }
            }
            DriftModel::Rotate { omega } => {
                if !omega.is_finite() {
                    Err(format!("drift: rotate omega {omega} must be finite"))
                } else {
                    Ok(())
                }
            }
        }
    }
}

impl Default for DriftModel {
    fn default() -> Self {
        DriftModel::None
    }
}

impl std::fmt::Display for DriftModel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match *self {
            DriftModel::None => write!(f, "none"),
            DriftModel::Walk { sigma } => write!(f, "walk:{sigma}"),
            DriftModel::Rotate { omega } => write!(f, "rotate:{omega}"),
        }
    }
}

impl std::str::FromStr for DriftModel {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        if s == "none" {
            return Ok(DriftModel::None);
        }
        if let Some(v) = s.strip_prefix("walk:") {
            return v
                .parse::<f64>()
                .map(|sigma| DriftModel::Walk { sigma })
                .map_err(|e| format!("drift {s:?}: {e}"));
        }
        if let Some(v) = s.strip_prefix("rotate:") {
            return v
                .parse::<f64>()
                .map(|omega| DriftModel::Rotate { omega })
                .map_err(|e| format!("drift {s:?}: {e}"));
        }
        Err(format!(
            "drift {s:?}: expected none | walk:<sigma> | rotate:<omega>"
        ))
    }
}

/// Per-node second-order statistics plus the ground truth w°.
#[derive(Debug, Clone)]
pub struct DataModel {
    pub n_nodes: usize,
    pub dim: usize,
    /// Ground-truth parameter vector w°.
    pub wo: Vec<f64>,
    /// Per-node regressor variances σ²_{u,k} (R_{u,k} = σ²_{u,k} I_L).
    pub sigma_u2: Vec<f64>,
    /// Per-node noise variances σ²_{v,k}.
    pub sigma_v2: Vec<f64>,
}

impl DataModel {
    /// Paper-style model: w° ~ N(0, I); σ²_{u,k} uniform in
    /// `[u2_min, u2_max]`; σ²_{v,k} = `v2` for all nodes.
    pub fn paper(
        n_nodes: usize,
        dim: usize,
        u2_min: f64,
        u2_max: f64,
        v2: f64,
        rng: &mut Pcg64,
    ) -> Self {
        let wo: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let sigma_u2: Vec<f64> = (0..n_nodes)
            .map(|_| u2_min + (u2_max - u2_min) * rng.next_f64())
            .collect();
        let sigma_v2 = vec![v2; n_nodes];
        Self { n_nodes, dim, wo, sigma_u2, sigma_v2 }
    }

    /// R_{u,k} as a dense matrix (σ²_{u,k} I_L).
    pub fn r_u(&self, k: usize) -> Mat {
        Mat::eye(self.dim).scale(self.sigma_u2[k])
    }

    /// Draw one synchronous snapshot: regressors U (n x L, row-major into
    /// `u_out`) and desired responses D (n) including noise.
    pub fn sample_iteration(&self, rng: &mut Pcg64, u_out: &mut [f64], d_out: &mut [f64]) {
        self.sample_iteration_at(&self.wo, rng, u_out, d_out);
    }

    /// [`Self::sample_iteration`] against a caller-supplied optimum —
    /// the tracking path, where `wo` is the drifting w°(i) the round
    /// scheduler advances via [`DriftModel`]. Identical float ops and
    /// RNG consumption as the static path (which delegates here), so
    /// `DriftModel::None` scenarios stay byte-identical.
    pub fn sample_iteration_at(
        &self,
        wo: &[f64],
        rng: &mut Pcg64,
        u_out: &mut [f64],
        d_out: &mut [f64],
    ) {
        let (n, l) = (self.n_nodes, self.dim);
        assert_eq!(wo.len(), l);
        assert_eq!(u_out.len(), n * l);
        assert_eq!(d_out.len(), n);
        for k in 0..n {
            let su = self.sigma_u2[k].sqrt();
            let sv = self.sigma_v2[k].sqrt();
            let row = &mut u_out[k * l..(k + 1) * l];
            let mut dot = 0.0;
            for (j, x) in row.iter_mut().enumerate() {
                *x = su * rng.next_gaussian();
                dot += *x * wo[j];
            }
            d_out[k] = dot + sv * rng.next_gaussian();
        }
    }

    /// Sample a whole T-iteration block in the artifact layout:
    /// `u_out` is (T, N, L) and `d_out` is (T, N), both row-major f32.
    pub fn sample_block_f32(&self, rng: &mut Pcg64, t: usize, u_out: &mut [f32], d_out: &mut [f32]) {
        let (n, l) = (self.n_nodes, self.dim);
        assert_eq!(u_out.len(), t * n * l);
        assert_eq!(d_out.len(), t * n);
        let mut u_row = vec![0.0f64; n * l];
        let mut d_row = vec![0.0f64; n];
        for ti in 0..t {
            self.sample_iteration(rng, &mut u_row, &mut d_row);
            let ubase = ti * n * l;
            for (dst, &src) in u_out[ubase..ubase + n * l].iter_mut().zip(u_row.iter()) {
                *dst = src as f32;
            }
            let dbase = ti * n;
            for (dst, &src) in d_out[dbase..dbase + n].iter_mut().zip(d_row.iter()) {
                *dst = src as f32;
            }
        }
    }

    /// w° as f32 (artifact convention).
    pub fn wo_f32(&self) -> Vec<f32> {
        self.wo.iter().map(|&x| x as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics_match_model() {
        let mut rng = Pcg64::new(1, 0);
        let model = DataModel::paper(4, 3, 0.5, 1.5, 1e-3, &mut rng);
        let trials = 20_000;
        let (n, l) = (model.n_nodes, model.dim);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut u2_acc = vec![0.0; n];
        let mut resid2_acc = vec![0.0; n];
        for _ in 0..trials {
            model.sample_iteration(&mut rng, &mut u, &mut d);
            for k in 0..n {
                let row = &u[k * l..(k + 1) * l];
                u2_acc[k] += row.iter().map(|x| x * x).sum::<f64>() / l as f64;
                let pred: f64 = row.iter().zip(model.wo.iter()).map(|(a, b)| a * b).sum();
                let r = d[k] - pred;
                resid2_acc[k] += r * r;
            }
        }
        for k in 0..n {
            let u2 = u2_acc[k] / trials as f64;
            assert!(
                (u2 - model.sigma_u2[k]).abs() < 0.05 * model.sigma_u2[k] + 0.01,
                "node {k}: u2 {u2} vs {}",
                model.sigma_u2[k]
            );
            let v2 = resid2_acc[k] / trials as f64;
            assert!((v2 - 1e-3).abs() < 5e-4, "node {k}: v2 {v2}");
        }
    }

    #[test]
    fn block_layout_matches_scalar_path() {
        let mut rng_a = Pcg64::new(5, 7);
        let mut rng_b = Pcg64::new(5, 7);
        let model = DataModel::paper(3, 2, 1.0, 1.0, 1e-3, &mut rng_a);
        let model_b = DataModel::paper(3, 2, 1.0, 1.0, 1e-3, &mut rng_b);
        assert_eq!(model.wo, model_b.wo);
        let t = 4;
        let mut u32buf = vec![0f32; t * 6];
        let mut d32buf = vec![0f32; t * 3];
        model.sample_block_f32(&mut rng_a, t, &mut u32buf, &mut d32buf);
        let mut u = vec![0.0; 6];
        let mut d = vec![0.0; 3];
        for ti in 0..t {
            model_b.sample_iteration(&mut rng_b, &mut u, &mut d);
            for j in 0..6 {
                assert!((u32buf[ti * 6 + j] as f64 - u[j]).abs() < 1e-6);
            }
            for k in 0..3 {
                assert!((d32buf[ti * 3 + k] as f64 - d[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn drift_parse_display_roundtrip() {
        for d in [
            DriftModel::None,
            DriftModel::Walk { sigma: 2e-3 },
            DriftModel::Rotate { omega: 0.01 },
        ] {
            let s = d.to_string();
            assert_eq!(s.parse::<DriftModel>().unwrap(), d);
        }
        assert!("wander".parse::<DriftModel>().is_err());
        assert!("walk:x".parse::<DriftModel>().is_err());
        assert!(DriftModel::Walk { sigma: -1.0 }.validate().is_err());
        assert!(DriftModel::Rotate { omega: f64::NAN }.validate().is_err());
        assert!(DriftModel::default().is_none());
        assert!(DriftModel::Walk { sigma: 0.0 }.is_none());
        assert!(!DriftModel::Walk { sigma: 1e-3 }.is_none());
    }

    #[test]
    fn rotate_drift_preserves_norm_and_walk_moves() {
        let mut wo = vec![3.0, 4.0, 1.0];
        let rot = DriftModel::Rotate { omega: 0.1 };
        let mut rng = Pcg64::new(9, 1);
        for _ in 0..50 {
            rot.advance(&mut wo, &mut rng);
        }
        let norm2: f64 = wo[0] * wo[0] + wo[1] * wo[1];
        assert!((norm2 - 25.0).abs() < 1e-9, "rotation must preserve |w°[0..2]|");
        assert_eq!(wo[2], 1.0, "rotation leaves higher coords untouched");
        // None consumes no RNG and moves nothing.
        let before = wo.clone();
        let mut rng_a = Pcg64::new(4, 4);
        let mut rng_b = Pcg64::new(4, 4);
        DriftModel::None.advance(&mut wo, &mut rng_a);
        assert_eq!(wo, before);
        assert_eq!(rng_a.next_u64(), rng_b.next_u64());
        // Walk perturbs every coordinate almost surely.
        let walk = DriftModel::Walk { sigma: 1e-2 };
        walk.advance(&mut wo, &mut rng_a);
        assert!(wo.iter().zip(before.iter()).all(|(a, b)| a != b));
    }

    #[test]
    fn sample_iteration_at_matches_static_path() {
        let mut rng = Pcg64::new(3, 0);
        let model = DataModel::paper(3, 2, 1.0, 1.0, 1e-3, &mut rng);
        let mut rng_a = Pcg64::new(8, 1);
        let mut rng_b = Pcg64::new(8, 1);
        let mut ua = vec![0.0; 6];
        let mut da = vec![0.0; 3];
        let mut ub = vec![0.0; 6];
        let mut db = vec![0.0; 3];
        model.sample_iteration(&mut rng_a, &mut ua, &mut da);
        let wo = model.wo.clone();
        model.sample_iteration_at(&wo, &mut rng_b, &mut ub, &mut db);
        assert_eq!(ua, ub);
        assert_eq!(da, db);
    }

    #[test]
    fn r_u_is_scaled_identity() {
        let mut rng = Pcg64::new(2, 2);
        let model = DataModel::paper(2, 4, 2.0, 2.0, 1e-3, &mut rng);
        let r = model.r_u(0);
        assert!((r.trace() - 8.0).abs() < 1e-12);
    }
}
