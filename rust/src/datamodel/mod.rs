//! Streaming linear data model (paper §II-A, eq. (1)):
//!
//!   d_k(i) = u_{k,i}ᵀ w° + v_k(i)
//!
//! with zero-mean Gaussian regressors u_{k,i} ~ N(0, σ²_{u,k} I_L) and
//! i.i.d. noise v_k(i) ~ N(0, σ²_{v,k}). Per-node variances follow the
//! paper's Fig. 2 (right): σ²_{u,k} drawn uniformly per node, σ²_{v,k}
//! fixed at 1e-3 in the experiments.

use crate::linalg::Mat;
use crate::rng::Pcg64;

/// Per-node second-order statistics plus the ground truth w°.
#[derive(Debug, Clone)]
pub struct DataModel {
    pub n_nodes: usize,
    pub dim: usize,
    /// Ground-truth parameter vector w°.
    pub wo: Vec<f64>,
    /// Per-node regressor variances σ²_{u,k} (R_{u,k} = σ²_{u,k} I_L).
    pub sigma_u2: Vec<f64>,
    /// Per-node noise variances σ²_{v,k}.
    pub sigma_v2: Vec<f64>,
}

impl DataModel {
    /// Paper-style model: w° ~ N(0, I); σ²_{u,k} uniform in
    /// `[u2_min, u2_max]`; σ²_{v,k} = `v2` for all nodes.
    pub fn paper(
        n_nodes: usize,
        dim: usize,
        u2_min: f64,
        u2_max: f64,
        v2: f64,
        rng: &mut Pcg64,
    ) -> Self {
        let wo: Vec<f64> = (0..dim).map(|_| rng.next_gaussian()).collect();
        let sigma_u2: Vec<f64> = (0..n_nodes)
            .map(|_| u2_min + (u2_max - u2_min) * rng.next_f64())
            .collect();
        let sigma_v2 = vec![v2; n_nodes];
        Self { n_nodes, dim, wo, sigma_u2, sigma_v2 }
    }

    /// R_{u,k} as a dense matrix (σ²_{u,k} I_L).
    pub fn r_u(&self, k: usize) -> Mat {
        Mat::eye(self.dim).scale(self.sigma_u2[k])
    }

    /// Draw one synchronous snapshot: regressors U (n x L, row-major into
    /// `u_out`) and desired responses D (n) including noise.
    pub fn sample_iteration(&self, rng: &mut Pcg64, u_out: &mut [f64], d_out: &mut [f64]) {
        let (n, l) = (self.n_nodes, self.dim);
        assert_eq!(u_out.len(), n * l);
        assert_eq!(d_out.len(), n);
        for k in 0..n {
            let su = self.sigma_u2[k].sqrt();
            let sv = self.sigma_v2[k].sqrt();
            let row = &mut u_out[k * l..(k + 1) * l];
            let mut dot = 0.0;
            for (j, x) in row.iter_mut().enumerate() {
                *x = su * rng.next_gaussian();
                dot += *x * self.wo[j];
            }
            d_out[k] = dot + sv * rng.next_gaussian();
        }
    }

    /// Sample a whole T-iteration block in the artifact layout:
    /// `u_out` is (T, N, L) and `d_out` is (T, N), both row-major f32.
    pub fn sample_block_f32(&self, rng: &mut Pcg64, t: usize, u_out: &mut [f32], d_out: &mut [f32]) {
        let (n, l) = (self.n_nodes, self.dim);
        assert_eq!(u_out.len(), t * n * l);
        assert_eq!(d_out.len(), t * n);
        let mut u_row = vec![0.0f64; n * l];
        let mut d_row = vec![0.0f64; n];
        for ti in 0..t {
            self.sample_iteration(rng, &mut u_row, &mut d_row);
            let ubase = ti * n * l;
            for (dst, &src) in u_out[ubase..ubase + n * l].iter_mut().zip(u_row.iter()) {
                *dst = src as f32;
            }
            let dbase = ti * n;
            for (dst, &src) in d_out[dbase..dbase + n].iter_mut().zip(d_row.iter()) {
                *dst = src as f32;
            }
        }
    }

    /// w° as f32 (artifact convention).
    pub fn wo_f32(&self) -> Vec<f32> {
        self.wo.iter().map(|&x| x as f32).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sample_statistics_match_model() {
        let mut rng = Pcg64::new(1, 0);
        let model = DataModel::paper(4, 3, 0.5, 1.5, 1e-3, &mut rng);
        let trials = 20_000;
        let (n, l) = (model.n_nodes, model.dim);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        let mut u2_acc = vec![0.0; n];
        let mut resid2_acc = vec![0.0; n];
        for _ in 0..trials {
            model.sample_iteration(&mut rng, &mut u, &mut d);
            for k in 0..n {
                let row = &u[k * l..(k + 1) * l];
                u2_acc[k] += row.iter().map(|x| x * x).sum::<f64>() / l as f64;
                let pred: f64 = row.iter().zip(model.wo.iter()).map(|(a, b)| a * b).sum();
                let r = d[k] - pred;
                resid2_acc[k] += r * r;
            }
        }
        for k in 0..n {
            let u2 = u2_acc[k] / trials as f64;
            assert!(
                (u2 - model.sigma_u2[k]).abs() < 0.05 * model.sigma_u2[k] + 0.01,
                "node {k}: u2 {u2} vs {}",
                model.sigma_u2[k]
            );
            let v2 = resid2_acc[k] / trials as f64;
            assert!((v2 - 1e-3).abs() < 5e-4, "node {k}: v2 {v2}");
        }
    }

    #[test]
    fn block_layout_matches_scalar_path() {
        let mut rng_a = Pcg64::new(5, 7);
        let mut rng_b = Pcg64::new(5, 7);
        let model = DataModel::paper(3, 2, 1.0, 1.0, 1e-3, &mut rng_a);
        let model_b = DataModel::paper(3, 2, 1.0, 1.0, 1e-3, &mut rng_b);
        assert_eq!(model.wo, model_b.wo);
        let t = 4;
        let mut u32buf = vec![0f32; t * 6];
        let mut d32buf = vec![0f32; t * 3];
        model.sample_block_f32(&mut rng_a, t, &mut u32buf, &mut d32buf);
        let mut u = vec![0.0; 6];
        let mut d = vec![0.0; 3];
        for ti in 0..t {
            model_b.sample_iteration(&mut rng_b, &mut u, &mut d);
            for j in 0..6 {
                assert!((u32buf[ti * 6 + j] as f64 - u[j]).abs() < 1e-6);
            }
            for k in 0..3 {
                assert!((d32buf[ti * 3 + k] as f64 - d[k]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn r_u_is_scaled_identity() {
        let mut rng = Pcg64::new(2, 2);
        let model = DataModel::paper(2, 4, 2.0, 2.0, 1e-3, &mut rng);
        let r = model.r_u(0);
        assert!((r.trace() - 8.0).abs() < 1e-12);
    }
}
