//! Experiment 3 (Fig. 4): the energy-harvesting WSN.
//!
//! 80 agents scattered over a hill (random geometric graph; harvest
//! scale grows with altitude to model uneven lighting), L = 40, all
//! algorithms at compression ratio r = 20 (CD at 80/65), step sizes from
//! Table II chosen by the paper to equalise steady-state MSD. Energy
//! dynamics per Table I + eqs. (70)–(72).
//!
//! Outputs: Fig. 4 (center) — mean sleep duration and harvested energy
//! vs time; Fig. 4 (right) — network MSD vs time for the six algorithm
//! settings.

use crate::algorithms::NetworkConfig;
use crate::config::Exp3Config;
use crate::coordinator::impairments::LinkImpairments;
use crate::coordinator::runner::{parallel_ordered, resolve_threads};
use crate::coordinator::wsn::{WsnAlgo, WsnConfig, WsnResult, WsnSimulation};
use crate::datamodel::DataModel;
use crate::energy::{CommLedger, RadioEnergy};
use crate::metrics::{to_db, write_csv, write_json, Series, TraceAccumulator};
use crate::rng::Pcg64;
use crate::topology::{combination_matrix, Combiner, Graph, Rule};
use anyhow::{anyhow, Result};

/// One algorithm setting's communication/energy bill, summed over the
/// Monte-Carlo runs (DESIGN.md §9).
#[derive(Debug, Clone)]
pub struct AlgoLedger {
    /// Algorithm label (matches the MSD series).
    pub label: String,
    /// Directional communication ledger (all runs).
    pub ledger: CommLedger,
    /// Per-node activation counts (all runs).
    pub per_node_activations: Vec<u64>,
    /// Table I active-phase energy e_a (J) per activation.
    pub active_energy: f64,
}

/// Everything `run_exp3` produces.
#[derive(Debug, Clone)]
pub struct Exp3Output {
    /// MSD-vs-time series, one per algorithm (dB).
    pub msd_series: Vec<Series>,
    /// Sleep-duration telemetry per algorithm (s).
    pub sleep_series: Vec<Series>,
    /// Harvested-energy telemetry (J per cycle), one (network mean).
    pub harvest_series: Vec<Series>,
    /// (label, final MSD dB, activations per run).
    pub summary: Vec<(String, f64, f64)>,
    /// Per-algorithm communication/energy ledgers (the `--ledger-csv`
    /// breakdown of the paper's Fig. 5-style analysis).
    pub ledgers: Vec<AlgoLedger>,
}

/// The per-node energy/communication breakdown as CSV text: one row per
/// (algorithm, node) with exact integer counters and the Table-I energy
/// spend — deterministic in the seed, byte-for-byte, at any thread or
/// shard count (the golden-file contract of `exp3 --ledger-csv`).
pub fn ledger_csv_string(ledgers: &[AlgoLedger]) -> String {
    let mut s = String::from(
        "algorithm,node,activations,energy_J,scalars,bits,bits_per_scalar\n",
    );
    for al in ledgers {
        for (node, &acts) in al.per_node_activations.iter().enumerate() {
            let scalars = al.ledger.per_node[node];
            s.push_str(&format!(
                "{},{},{},{},{},{},{}\n",
                al.label,
                node,
                acts,
                acts as f64 * al.active_energy,
                scalars,
                scalars * al.ledger.bits_per_scalar as u64,
                al.ledger.bits_per_scalar,
            ));
        }
    }
    s
}

/// The six algorithm settings of Fig. 4 (right). `mean_deg` sizes the
/// RCD poll count: m_links ≈ rcd_fraction · mean degree (p = 1/r·2,
/// Table II's r = 20 ⇒ p = 0.1), at least one link. Shared with the
/// WSN shard worker, which addresses one entry by index (DESIGN.md §8).
pub(crate) fn exp3_settings(cfg: &Exp3Config, mean_deg: f64) -> Vec<(WsnAlgo, f64)> {
    let m_links = ((cfg.rcd_fraction * mean_deg).round() as usize).max(1);
    vec![
        (WsnAlgo::Diffusion, cfg.mu_diffusion),
        (WsnAlgo::Rcd { m_links }, cfg.mu_rcd),
        (WsnAlgo::Partial { m: cfg.partial_m }, cfg.mu_partial),
        (WsnAlgo::Cd { m: cfg.cd_m }, cfg.mu_cd),
        (
            WsnAlgo::Dcd { m: cfg.dcd_m, m_grad: cfg.dcd_m_grad, combine: false },
            cfg.mu_dcd,
        ),
        (
            WsnAlgo::Dcd { m: cfg.dcd_m, m_grad: cfg.dcd_m_grad, combine: true },
            cfg.mu_dcd,
        ),
    ]
}

/// The deterministic exp3 setup (hill topology, harvest scales,
/// combiners, data model) — everything derived from the config and the
/// master stream `Pcg64::new(seed, 0)`. `run_exp3` and the WSN shard
/// workers build their simulations through this one constructor, which
/// is what keeps sharded realizations bit-identical to in-process ones.
pub(crate) struct Exp3Parts {
    pub graph: Graph,
    pub harvest_scale: Vec<f64>,
    pub c: Combiner,
    pub a: Combiner,
    pub model: DataModel,
    pub mean_deg: f64,
}

impl Exp3Parts {
    /// Replay the setup from the config (consumes the master stream in
    /// the fixed order: topology, then data model).
    pub fn build(cfg: &Exp3Config) -> Self {
        let mut rng = Pcg64::new(cfg.seed, 0);
        let graph = Graph::random_geometric(cfg.n_nodes, cfg.radius, &mut rng);
        // Lighting level grows with altitude (y-coordinate of the hill).
        let harvest_scale: Vec<f64> = graph
            .positions
            .as_ref()
            .expect("geometric graph has positions")
            .iter()
            .map(|&(_, y)| 0.3 + 0.7 * y)
            .collect();
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let model = DataModel::paper(
            cfg.n_nodes,
            cfg.dim,
            cfg.u2_min,
            cfg.u2_max,
            cfg.sigma_v2,
            &mut rng,
        );
        let mean_deg = (0..cfg.n_nodes)
            .map(|k| graph.neighbors(k).len())
            .sum::<usize>() as f64
            / cfg.n_nodes as f64;
        Self { graph, harvest_scale, c, a, model, mean_deg }
    }

    /// Assemble the event-driven simulation for one algorithm setting.
    pub fn simulation(&self, cfg: &Exp3Config, algo: WsnAlgo, mu: f64) -> WsnSimulation {
        let net = NetworkConfig {
            graph: self.graph.clone(),
            c: self.c.clone(),
            a: self.a.clone(),
            mu: vec![mu; cfg.n_nodes],
            dim: cfg.dim,
        };
        let wsn_cfg = WsnConfig {
            net,
            algo,
            energy: cfg.energy.clone(),
            harvest_scale: self.harvest_scale.clone(),
            duration: cfg.duration,
            sample_dt: cfg.sample_dt,
            // exp3 reproduces the paper's setting: ideal links and a
            // free radio (the impaired / radio-priced WSN regimes live
            // in the scenario subsystem).
            impairments: LinkImpairments::ideal(),
            radio: RadioEnergy::zero(),
        };
        WsnSimulation::new(wsn_cfg, self.model.clone())
    }
}

/// Run Experiment 3 end to end; with `out_dir` set, writes
/// `exp3_fig4_right_msd.csv`, `exp3_fig4_center_energy.csv` and
/// `exp3_fig4.json` there.
pub fn run_exp3(cfg: &Exp3Config, out_dir: Option<&str>, quiet: bool) -> Result<Exp3Output> {
    if cfg.shards == 0 {
        return Err(anyhow!("exp3: shards must be >= 1 (1 = in-process)"));
    }
    let parts = Exp3Parts::build(cfg);

    if !quiet {
        println!("exp3: Table II compression check (target r = 20; CD 80/65 ≈ 1.23):");
        for (name, r) in cfg.ratios() {
            println!("  {name:<10} r = {r:.3}");
        }
    }

    let mut msd_series = Vec::new();
    let mut sleep_series = Vec::new();
    let mut harvest_series: Vec<Series> = Vec::new();
    let mut summary = Vec::new();
    let mut ledgers = Vec::new();

    let settings = exp3_settings(cfg, parts.mean_deg);
    for (algo_index, (algo, mu)) in settings.into_iter().enumerate() {
        // Fan the independent WSN realizations across worker threads —
        // or, with `shards > 1`, across worker processes. Every run
        // draws from its own seed and the results are merged in run
        // order, so the averages are bit-identical either way (same
        // scheme as coordinator::runner::run_rust; DESIGN.md §8).
        let runs = if cfg.shards > 1 {
            crate::shard::run_wsn_sharded(cfg, algo_index, cfg.shards)
                .map_err(anyhow::Error::msg)?
        } else {
            let sim = parts.simulation(cfg, algo, mu);
            run_realizations(&sim, cfg.seed, cfg.runs)
        };
        let mut msd_acc = TraceAccumulator::new();
        let mut sleep_acc = TraceAccumulator::new();
        let mut harv_acc = TraceAccumulator::new();
        let mut activations = 0.0;
        let mut time_grid = Vec::new();
        let mut ledger = CommLedger::empty(0);
        let mut per_node_activations = vec![0u64; cfg.n_nodes];
        for res in &runs {
            time_grid.clone_from(&res.time);
            msd_acc.add(&res.msd);
            sleep_acc.add(&res.mean_sleep);
            harv_acc.add(&res.mean_harvest);
            activations += res.activations as f64;
            ledger.merge(&res.ledger);
            for (acc, &x) in per_node_activations.iter_mut().zip(&res.per_node_activations) {
                *acc += x;
            }
        }
        activations /= cfg.runs as f64;
        let label = algo.label();
        ledgers.push(AlgoLedger {
            label: label.clone(),
            ledger,
            per_node_activations,
            active_energy: algo.active_energy(),
        });
        let msd_db: Vec<f64> = msd_acc.mean().iter().map(|&x| to_db(x)).collect();
        let final_db = *msd_db.last().unwrap();
        if !quiet {
            println!(
                "exp3 {label:<16} final MSD {final_db:7.2} dB  activations/run {activations:8.0}"
            );
        }
        summary.push((label.clone(), final_db, activations));
        msd_series.push(Series::new(label.clone(), time_grid.clone(), msd_db));
        sleep_series.push(Series::new(
            format!("{label} sleep (s)"),
            time_grid.clone(),
            sleep_acc.mean(),
        ));
        if harvest_series.is_empty() {
            harvest_series.push(Series::new(
                "harvested energy per cycle (J)",
                time_grid,
                harv_acc.mean(),
            ));
        }
    }

    if let Some(dir) = out_dir {
        write_csv(format!("{dir}/exp3_fig4_right_msd.csv"), &msd_series)?;
        let mut center = sleep_series.clone();
        center.extend(harvest_series.clone());
        write_csv(format!("{dir}/exp3_fig4_center_energy.csv"), &center)?;
        write_json(
            format!("{dir}/exp3_fig4.json"),
            "Fig. 4: WSN energy telemetry and MSD vs time",
            &[msd_series.clone(), center].concat(),
        )?;
        if cfg.ledger_csv {
            std::fs::create_dir_all(dir)?;
            std::fs::write(
                format!("{dir}/exp3_ledger.csv"),
                ledger_csv_string(&ledgers),
            )?;
            if !quiet {
                println!("exp3: wrote {dir}/exp3_ledger.csv (per-node energy/comm breakdown)");
            }
        }
        if !quiet {
            println!("exp3: wrote {dir}/exp3_fig4_right_msd.csv, exp3_fig4_center_energy.csv");
        }
    }

    Ok(Exp3Output { msd_series, sleep_series, harvest_series, summary, ledgers })
}

/// Run `runs` independent WSN realizations of `sim` in parallel,
/// returning them **in run order**. Run `r` uses seed
/// `base_seed + r·7919 + 1` regardless of which worker executes it.
fn run_realizations(sim: &WsnSimulation, base_seed: u64, runs: usize) -> Vec<WsnResult> {
    let threads = resolve_threads(0, runs);
    parallel_ordered(runs, threads, |r| {
        sim.run(base_seed.wrapping_add(r as u64 * 7919 + 1))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shrunk WSN run: the qualitative claims of Fig. 4 must hold —
    /// cheap algorithms (DCD/PM) activate more and converge further than
    /// the expensive ones (diffusion/CD) within the same horizon.
    #[test]
    fn fig4_shape_small() {
        let cfg = Exp3Config {
            n_nodes: 20,
            dim: 12,
            radius: 0.35,
            duration: 30_000.0,
            sample_dt: 600.0,
            runs: 2,
            dcd_m: 2,
            dcd_m_grad: 2,
            partial_m: 4,
            cd_m: 8,
            ..Exp3Config::default()
        };
        let out = run_exp3(&cfg, None, true).unwrap();
        assert_eq!(out.summary.len(), 6);
        let get = |label: &str| {
            out.summary
                .iter()
                .find(|(l, _, _)| l == label)
                .unwrap_or_else(|| panic!("missing {label}"))
        };
        let diffusion = get("diffusion-lms");
        let dcd = get("dcd (A!=I)");
        // Cheap DCD gets many more activations...
        assert!(
            dcd.2 > 2.0 * diffusion.2,
            "dcd activations {} vs diffusion {}",
            dcd.2,
            diffusion.2
        );
        // ...and converges further in the same horizon.
        assert!(
            dcd.1 < diffusion.1 - 3.0,
            "dcd {} dB vs diffusion {} dB",
            dcd.1,
            diffusion.1
        );
        // All algorithms make progress from the initial MSD.
        for s in &out.msd_series {
            let first = s.y[1];
            let last = *s.y.last().unwrap();
            assert!(last < first, "{}: {first} -> {last}", s.label);
        }
    }

    /// The `--ledger-csv` artifact is a golden file: byte-identical
    /// across repeated runs (pure integer counters + shortest-round-trip
    /// floats), schema-stable, and its rows cross-foot against the
    /// in-memory ledgers.
    #[test]
    fn ledger_csv_is_byte_stable_and_cross_foots() {
        let cfg = Exp3Config {
            n_nodes: 10,
            dim: 6,
            radius: 0.45,
            duration: 6_000.0,
            sample_dt: 600.0,
            runs: 2,
            dcd_m: 2,
            dcd_m_grad: 1,
            partial_m: 2,
            cd_m: 4,
            ..Exp3Config::default()
        };
        let a = run_exp3(&cfg, None, true).unwrap();
        let b = run_exp3(&cfg, None, true).unwrap();
        let csv = ledger_csv_string(&a.ledgers);
        assert_eq!(csv, ledger_csv_string(&b.ledgers), "ledger CSV not deterministic");
        let mut lines = csv.lines();
        assert_eq!(
            lines.next().unwrap(),
            "algorithm,node,activations,energy_J,scalars,bits,bits_per_scalar"
        );
        // 6 algorithm settings x n_nodes rows.
        assert_eq!(csv.lines().count(), 1 + 6 * cfg.n_nodes);
        // Rows cross-foot: per-node scalars sum to each ledger's total.
        for al in &a.ledgers {
            assert_eq!(al.ledger.per_node.iter().sum::<u64>(), al.ledger.scalars);
            assert_eq!(al.per_node_activations.len(), cfg.n_nodes);
            assert!(al.ledger.scalars > 0, "{}: empty ledger", al.label);
        }
        // Diffusion bills 2L per link; DCD (A=I) bills M + M_grad — the
        // Fig. 5-style per-algorithm ordering.
        let get = |label: &str| {
            a.ledgers
                .iter()
                .find(|l| l.label == label)
                .unwrap_or_else(|| panic!("missing {label}"))
        };
        let per_act = |al: &AlgoLedger| {
            al.ledger.scalars as f64 / al.per_node_activations.iter().sum::<u64>() as f64
        };
        assert!(per_act(get("diffusion-lms")) > per_act(get("dcd (A=I)")));
    }
}
