//! Experiment drivers: one module per paper experiment, regenerating
//! every figure/table (DESIGN.md §3 index).
//!
//! * [`exp1`] — Fig. 3 (left): theoretical vs simulated MSD for
//!   diffusion LMS, CD, DCD on the 10-node network.
//! * [`exp2`] — Fig. 3 (center/right): steady-state MSD vs compression
//!   ratio for CD and DCD on the 50-node / L = 50 network.
//! * [`exp3`] — Fig. 4: the 80-node energy-harvesting WSN (sleep/harvest
//!   telemetry + MSD-vs-time for all five algorithms, Tables I/II).
//! * [`exp4`] — beyond the paper: predicted vs simulated steady-state
//!   MSD under per-link drops (the impaired-link theory of DESIGN.md §7
//!   against the scenario runner's Monte-Carlo).
//!
//! Each driver writes `results/<name>.csv` + `.json` and returns the
//! series so tests/benches can assert on them.

pub mod exp1;
pub mod exp2;
pub mod exp3;
pub mod exp4;

pub use exp1::{run_exp1, Exp1Output};
pub use exp2::{run_exp2, Exp2Output};
pub use exp3::{ledger_csv_string, run_exp3, AlgoLedger, Exp3Output};
pub use exp4::{run_exp4, Exp4Config, Exp4Output, Exp4Point};

/// Execution engine selection for the synchronous experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Message-level rust engine (f64).
    Rust,
    /// AOT-compiled xla engine (f32, requires `make artifacts`).
    Xla,
}

impl std::str::FromStr for Engine {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rust" => Ok(Engine::Rust),
            "xla" => Ok(Engine::Xla),
            other => Err(format!("unknown engine {other:?} (rust|xla)")),
        }
    }
}
