//! Experiment 2 (Fig. 3 center/right): steady-state MSD as a function of
//! the compression ratio on a 50-node network with L = 50, μ = 3e-2.
//!
//! The CD sweep varies M (ratio 2L/(M+L), capped at 100/55); the DCD
//! sweep varies (M, M_grad) (ratio 2L/(M+M_grad), up to 20 and beyond).
//! The paper ran these with C-language MC scripts because the 𝓕 matrix
//! is (2500²)² — here the compiled xla engine plays that role (the rust
//! engine is available for cross-checking via `--engine rust`).

use crate::algorithms::{Dcd, DiffusionLms, NetworkConfig};
use crate::config::Exp2Config;
use crate::coordinator::runner::{MonteCarlo, XlaAlgo};
use crate::datamodel::DataModel;
use crate::metrics::{to_db, write_csv, write_json, Series};
use crate::rng::Pcg64;
use crate::runtime::Runtime;
use crate::scenario::{AlgorithmSpec, Scenario, TopologySpec};
use crate::topology::{combination_matrix, Graph, Rule};
use anyhow::{anyhow, Result};

use super::Engine;

/// The exp2 geometric-graph connection radius (the paper does not print
/// this topology; the value is part of the reproduction's contract and
/// is shared with the sharded job description below).
const EXP2_RADIUS: f64 = 0.25;

/// One exp2 sweep point as a scenario job for the shard workers —
/// `mc_parts` rebuilds the geometric graph and data model from the same
/// master stream in the same order as [`run_exp2`], so per-run results
/// are bit-identical to the in-process sweep (DESIGN.md §8).
fn sim_scenario(cfg: &Exp2Config, m: usize, m_grad: usize, record_every: usize) -> Scenario {
    let mut sc = Scenario::base("exp2", "exp2 sweep point (sharded)");
    sc.topology = TopologySpec::Geometric { n: cfg.n_nodes, radius: EXP2_RADIUS };
    sc.combine_rule = Rule::Identity;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = cfg.dim;
    sc.u2_min = cfg.u2_min;
    sc.u2_max = cfg.u2_max;
    sc.sigma_v2 = cfg.sigma_v2;
    sc.algorithm = AlgorithmSpec::Dcd { m, m_grad };
    sc.mu = cfg.mu;
    sc.runs = cfg.runs;
    sc.iters = cfg.iters;
    sc.seed = cfg.seed;
    sc.record_every = record_every;
    sc.threads = 0;
    sc.shards = cfg.shards;
    sc.lanes = cfg.lanes;
    sc
}

#[derive(Debug, Clone)]
pub struct Exp2Output {
    /// CD sweep: (ratio, steady-state MSD dB).
    pub cd: Vec<(f64, f64)>,
    /// DCD sweep.
    pub dcd: Vec<(f64, f64)>,
    /// Uncompressed diffusion-LMS reference (ratio 1).
    pub baseline_db: f64,
}

pub fn run_exp2(
    cfg: &Exp2Config,
    engine: Engine,
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<Exp2Output> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.shards > 1 && engine == Engine::Xla {
        return Err(anyhow!(
            "exp2: --shards applies to the rust engine (the xla engine runs in-process)"
        ));
    }
    let mut rng = Pcg64::new(cfg.seed, 0);
    // Experiment 2 network: connected random geometric graph over the
    // unit square (the paper does not print this topology).
    let graph = Graph::random_geometric(cfg.n_nodes, EXP2_RADIUS, &mut rng);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = crate::topology::Combiner::eye(cfg.n_nodes);
    let model = DataModel::paper(
        cfg.n_nodes,
        cfg.dim,
        cfg.u2_min,
        cfg.u2_max,
        cfg.sigma_v2,
        &mut rng,
    );
    let net = NetworkConfig {
        graph,
        c: c.clone(),
        a,
        mu: vec![cfg.mu; cfg.n_nodes],
        dim: cfg.dim,
    };
    let mc = MonteCarlo {
        runs: cfg.runs,
        iters: cfg.iters,
        seed: cfg.seed,
        record_every: (cfg.iters / 500).max(1),
        threads: 0,
    };

    let mut xla_rt = match engine {
        Engine::Xla => Some(Runtime::open_default()?),
        Engine::Rust => None,
    };

    let mut run_point = |m: usize, m_grad: usize| -> Result<f64> {
        let res = match engine {
            Engine::Rust => {
                if cfg.shards > 1 {
                    let sc = sim_scenario(cfg, m, m_grad, mc.record_every);
                    crate::shard::run_scenario_sharded(&sc).map_err(anyhow::Error::msg)?
                } else {
                    let net = net.clone();
                    // Lane dispatch (DESIGN.md §14): bit-identical to
                    // `run_rust` at every width, so purely throughput.
                    mc.run_rust_lanes_opts(
                        &model,
                        &Default::default(),
                        cfg.lanes.resolve(cfg.runs),
                        move || Box::new(Dcd::new(net.clone(), m, m_grad)),
                    )
                }
            }
            Engine::Xla => mc.run_xla(
                xla_rt.as_mut().unwrap(),
                "exp2",
                &XlaAlgo::Dcd { m, m_grad },
                &model,
                &net.c_f32(),
                &net.a_f32(),
                &net.mu_f32(),
            )?,
        };
        Ok(to_db(res.steady_state))
    };

    // Baseline: uncompressed diffusion LMS (ratio 1).
    let baseline_db = run_point(cfg.dim, cfg.dim)?;
    if !quiet {
        println!("exp2 baseline (diffusion LMS): {baseline_db:.2} dB");
    }

    let l = cfg.dim as f64;
    let mut cd = Vec::new();
    for &m in &cfg.cd_m_values {
        let ratio = 2.0 * l / (m as f64 + l);
        let db = run_point(m, cfg.dim)?;
        if !quiet {
            println!("exp2 CD  M={m:<3} ratio {ratio:6.3}: {db:7.2} dB");
        }
        cd.push((ratio, db));
    }

    let mut dcd = Vec::new();
    for &(m, mg) in &cfg.dcd_pairs {
        let ratio = 2.0 * l / (m + mg) as f64;
        let db = run_point(m, mg)?;
        if !quiet {
            println!("exp2 DCD M={m:<3} M∇={mg:<3} ratio {ratio:6.2}: {db:7.2} dB");
        }
        dcd.push((ratio, db));
    }

    // Keep an explicit rust-engine spot check available to tests: the
    // DiffusionLms implementation must agree with the Dcd full-mask point.
    let _ = DiffusionLms::new(net.clone());

    if let Some(dir) = out_dir {
        let cd_series = Series::new(
            "cd steady-state (dB)",
            cd.iter().map(|p| p.0).collect(),
            cd.iter().map(|p| p.1).collect(),
        );
        let dcd_series = Series::new(
            "dcd steady-state (dB)",
            dcd.iter().map(|p| p.0).collect(),
            dcd.iter().map(|p| p.1).collect(),
        );
        write_csv(format!("{dir}/exp2_fig3_center_cd.csv"), &[cd_series.clone()])?;
        write_csv(format!("{dir}/exp2_fig3_right_dcd.csv"), &[dcd_series.clone()])?;
        write_json(
            format!("{dir}/exp2_fig3_sweep.json"),
            "Fig. 3 (center/right): MSD vs compression ratio",
            &[cd_series, dcd_series],
        )?;
        if !quiet {
            println!("exp2: wrote {dir}/exp2_fig3_center_cd.csv, exp2_fig3_right_dcd.csv");
        }
    }
    Ok(Exp2Output { cd, dcd, baseline_db })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shrunk sweep on the rust engine: MSD must degrade monotonically
    /// (within MC noise) as the ratio grows, and every compressed point
    /// must sit above the uncompressed baseline.
    #[test]
    fn sweep_shape_small() {
        let cfg = Exp2Config {
            n_nodes: 12,
            dim: 12,
            runs: 6,
            iters: 1_500,
            mu: 3e-2,
            cd_m_values: vec![9, 5, 1],
            dcd_pairs: vec![(9, 9), (5, 5), (2, 2)],
            ..Exp2Config::default()
        };
        let out = run_exp2(&cfg, Engine::Rust, None, true).unwrap();
        assert_eq!(out.cd.len(), 3);
        assert_eq!(out.dcd.len(), 3);
        for (_r, db) in out.cd.iter().chain(out.dcd.iter()) {
            assert!(*db >= out.baseline_db - 0.8, "{db} vs baseline {}", out.baseline_db);
        }
        // Higher compression ⇒ (weakly) higher steady-state MSD.
        assert!(out.dcd[2].1 >= out.dcd[0].1 - 0.8);
    }
}
