//! Experiment 4 (beyond the paper): predicted vs simulated steady-state
//! MSD as a function of the per-link drop probability — the impaired
//! analogue of exp1's theory-vs-simulation anchoring (DESIGN.md §7).
//!
//! For each swept drop probability the driver runs the base scenario's
//! Monte-Carlo simulation *and* the closed-form [`ImpairedMsdModel`]
//! (through the scenario runner's theory column), then writes the two
//! steady-state curves to `results/exp4_theory_impaired.{csv,json}`.
//! The base scenario must be inside the analysis scope — the default,
//! `lossy-geometric`, is built for exactly this.
//!
//! [`ImpairedMsdModel`]: crate::theory::ImpairedMsdModel

use crate::coordinator::impairments::DropModel;
use crate::metrics::{write_csv, write_json, Series};
use crate::scenario::{find, run_scenario, theory_scope};
use anyhow::{anyhow, Result};

/// Configuration of the drop-probability sweep.
#[derive(Debug, Clone)]
pub struct Exp4Config {
    /// Base scenario name from the registry (its own `drop_prob` is
    /// overridden per sweep point).
    pub scenario: String,
    /// Drop probabilities to sweep, in plot order.
    pub drop_probs: Vec<f64>,
    /// Monte-Carlo runs per point (0 = the scenario's own schedule).
    pub runs: usize,
    /// Iterations per realization (0 = the scenario's own schedule).
    pub iters: usize,
    /// Master seed override (`None` = the scenario's own seed).
    pub seed: Option<u64>,
    /// Worker processes per sweep point (1 = in-process; the
    /// simulation half of each point shards, the closed-form theory
    /// column is cheap and stays local — DESIGN.md §8).
    pub shards: usize,
}

impl Default for Exp4Config {
    fn default() -> Self {
        Self {
            scenario: "lossy-geometric".to_string(),
            drop_probs: vec![0.0, 0.05, 0.1, 0.2, 0.3, 0.4],
            runs: 0,
            iters: 0,
            seed: None,
            shards: 1,
        }
    }
}

/// One sweep point: predicted and simulated steady-state MSD.
#[derive(Debug, Clone)]
pub struct Exp4Point {
    /// The swept per-link drop probability.
    pub drop_prob: f64,
    /// Closed-form steady-state MSD prediction (dB).
    pub theory_db: f64,
    /// Monte-Carlo steady-state MSD estimate (dB).
    pub sim_db: f64,
}

/// Everything the sweep produces.
#[derive(Debug, Clone)]
pub struct Exp4Output {
    /// Per-point summary, in sweep order.
    pub points: Vec<Exp4Point>,
    /// The two steady-state curves (theory, sim) over drop probability.
    pub series: Vec<Series>,
}

/// Run the predicted-vs-simulated drop-probability sweep. With
/// `out_dir` set, writes `<out_dir>/exp4_theory_impaired.{csv,json}`.
pub fn run_exp4(cfg: &Exp4Config, out_dir: Option<&str>, quiet: bool) -> Result<Exp4Output> {
    if cfg.drop_probs.is_empty() {
        return Err(anyhow!("exp4: empty drop-probability list"));
    }
    if cfg.shards == 0 {
        return Err(anyhow!("exp4: shards must be >= 1 (1 = in-process)"));
    }
    let base = find(&cfg.scenario).ok_or_else(|| {
        anyhow!(
            "exp4: unknown scenario {:?} (run `scenario list` for the registry)",
            cfg.scenario
        )
    })?;
    // Fail fast on an out-of-scope base scenario — before spending a
    // full Monte-Carlo run discovering the missing theory column.
    theory_scope(&base).map_err(|why| {
        anyhow!(
            "exp4: scenario {:?} is outside the impaired-theory scope ({why}; \
             see DESIGN.md §7)",
            cfg.scenario
        )
    })?;
    let mut points = Vec::with_capacity(cfg.drop_probs.len());
    for &p in &cfg.drop_probs {
        let mut sc = base.clone();
        sc.impairments.drop = DropModel::Iid(p);
        if cfg.runs > 0 {
            sc.runs = cfg.runs;
        }
        if cfg.iters > 0 {
            sc.iters = cfg.iters;
        }
        if let Some(seed) = cfg.seed {
            sc.seed = seed;
        }
        sc.shards = cfg.shards;
        let out = run_scenario(&sc, None, true).map_err(anyhow::Error::msg)?;
        let theory_db = out.theory_steady_db.ok_or_else(|| {
            anyhow!(
                "exp4: scenario {:?} is outside the impaired-theory scope \
                 (needs combine_rule = identity, a DCD-family algorithm and \
                 non-event gating; see DESIGN.md §7)",
                sc.name
            )
        })?;
        if !quiet {
            println!(
                "exp4 drop {p:<5} theory {theory_db:7.2} dB  sim {:7.2} dB  (|gap| {:.2} dB)",
                out.steady_db,
                (theory_db - out.steady_db).abs()
            );
        }
        points.push(Exp4Point { drop_prob: p, theory_db, sim_db: out.steady_db });
    }

    let x: Vec<f64> = points.iter().map(|pt| pt.drop_prob).collect();
    let ty: Vec<f64> = points.iter().map(|pt| pt.theory_db).collect();
    let sy: Vec<f64> = points.iter().map(|pt| pt.sim_db).collect();
    let series = vec![
        Series::new("steady-state MSD dB (theory)", x.clone(), ty),
        Series::new("steady-state MSD dB (sim)", x, sy),
    ];
    if let Some(dir) = out_dir {
        write_csv(format!("{dir}/exp4_theory_impaired.csv"), &series)?;
        write_json(
            format!("{dir}/exp4_theory_impaired.json"),
            &format!(
                "Exp 4: predicted vs simulated steady-state MSD under per-link \
                 drops ({} base scenario)",
                cfg.scenario
            ),
            &series,
        )?;
        if !quiet {
            println!("exp4: wrote {dir}/exp4_theory_impaired.csv and .json");
        }
    }
    Ok(Exp4Output { points, series })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shrunk end-to-end sweep: two points, theory column present, both
    /// curves rise with the drop probability and track each other. The
    /// horizon must clear the ≈140-iteration time constant by a wide
    /// margin so steady-state estimates are not transient artefacts.
    #[test]
    fn sweep_produces_tracking_curves() {
        let cfg = Exp4Config {
            drop_probs: vec![0.0, 0.4],
            runs: 6,
            iters: 2_000,
            ..Exp4Config::default()
        };
        let out = run_exp4(&cfg, None, true).unwrap();
        assert_eq!(out.points.len(), 2);
        assert_eq!(out.series.len(), 2);
        for pt in &out.points {
            assert!(pt.theory_db.is_finite() && pt.sim_db.is_finite());
            assert!(
                (pt.theory_db - pt.sim_db).abs() < 3.0,
                "drop {}: theory {} dB vs sim {} dB",
                pt.drop_prob,
                pt.theory_db,
                pt.sim_db
            );
        }
        assert!(
            out.points[1].sim_db > out.points[0].sim_db,
            "drops should raise the simulated floor"
        );
        assert!(
            out.points[1].theory_db > out.points[0].theory_db,
            "drops should raise the predicted floor"
        );
    }

    #[test]
    fn bad_configs_error() {
        let empty = Exp4Config { drop_probs: vec![], ..Exp4Config::default() };
        assert!(run_exp4(&empty, None, true).is_err());
        let unknown = Exp4Config {
            scenario: "no-such-scenario".to_string(),
            ..Exp4Config::default()
        };
        assert!(run_exp4(&unknown, None, true).is_err());
        // A scenario outside the theory scope is rejected with a
        // pointer at the analysis assumptions.
        let out_of_scope = Exp4Config {
            scenario: "event-triggered-ring".to_string(),
            drop_probs: vec![0.1],
            runs: 2,
            iters: 50,
            ..Exp4Config::default()
        };
        let err = run_exp4(&out_of_scope, None, true).unwrap_err().to_string();
        assert!(err.contains("scope"), "{err}");
    }
}
