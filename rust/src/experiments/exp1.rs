//! Experiment 1 (Fig. 3 left): theory vs simulation on the 10-node
//! network — MSD learning curves for diffusion LMS, CD and DCD
//! (L = 5, M = 3, M_grad = 1, μ = 1e-3, σ²_v = 1e-3, 100 MC runs).

use crate::algorithms::{Dcd, NetworkConfig};
use crate::config::Exp1Config;
use crate::coordinator::runner::{MonteCarlo, XlaAlgo};
use crate::datamodel::DataModel;
use crate::metrics::{to_db, write_csv, write_json, Series};
use crate::rng::Pcg64;
use crate::runtime::Runtime;
use crate::scenario::{AlgorithmSpec, Scenario, TopologySpec};
use crate::theory::{MsdModel, TheorySetup};
use crate::topology::{combination_matrix, Graph, Rule};
use anyhow::{anyhow, Result};

use super::Engine;

/// The exp1 simulation of one `(M, M_grad)` setting expressed as a
/// scenario job — the payload a shard worker replays. The mapping is
/// exact: `mc_parts` consumes the master stream in the same order as
/// [`run_exp1`] (paper-10 topology draws nothing, then the data model),
/// `combine_rule = identity` is `Combiner::eye`, and all three Fig. 3
/// algorithms are `Dcd` instances here, so sharded results match the
/// in-process runner byte for byte (asserted by the CI CSV diff and
/// `rust/tests/shard.rs`).
fn sim_scenario(cfg: &Exp1Config, m: usize, m_grad: usize, record_every: usize) -> Scenario {
    let mut sc = Scenario::base("exp1", "exp1 simulation block (sharded)");
    sc.topology = TopologySpec::Paper10;
    sc.combine_rule = Rule::Identity;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = cfg.dim;
    sc.u2_min = cfg.u2_min;
    sc.u2_max = cfg.u2_max;
    sc.sigma_v2 = cfg.sigma_v2;
    sc.algorithm = AlgorithmSpec::Dcd { m, m_grad };
    sc.mu = cfg.mu;
    sc.runs = cfg.runs;
    sc.iters = cfg.iters;
    sc.seed = cfg.seed;
    sc.record_every = record_every;
    sc.threads = 0;
    sc.shards = cfg.shards;
    sc.lanes = cfg.lanes;
    sc
}

/// All series of Fig. 3 (left) plus summary numbers.
#[derive(Debug, Clone)]
pub struct Exp1Output {
    pub series: Vec<Series>,
    /// (label, theory steady state dB, simulated steady state dB).
    pub steady: Vec<(String, f64, f64)>,
}

/// The three algorithm settings of the figure, as (label, M, M_grad).
fn settings(cfg: &Exp1Config) -> Vec<(String, usize, usize)> {
    vec![
        ("diffusion-lms".into(), cfg.dim, cfg.dim),
        ("cd".into(), cfg.m, cfg.dim),
        ("dcd".into(), cfg.m, cfg.m_grad),
    ]
}

pub fn run_exp1(
    cfg: &Exp1Config,
    engine: Engine,
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<Exp1Output> {
    cfg.validate().map_err(anyhow::Error::msg)?;
    if cfg.shards > 1 && engine == Engine::Xla {
        return Err(anyhow!(
            "exp1: --shards applies to the rust engine (the xla engine runs in-process)"
        ));
    }
    let mut rng = Pcg64::new(cfg.seed, 0);
    let graph = Graph::paper_ten_node();
    assert_eq!(graph.n(), cfg.n_nodes, "exp1 preset is the 10-node network");
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = crate::topology::Combiner::eye(cfg.n_nodes);
    let model = DataModel::paper(
        cfg.n_nodes,
        cfg.dim,
        cfg.u2_min,
        cfg.u2_max,
        cfg.sigma_v2,
        &mut rng,
    );
    let net = NetworkConfig {
        graph,
        c: c.clone(),
        a,
        mu: vec![cfg.mu; cfg.n_nodes],
        dim: cfg.dim,
    };

    let record_every = (cfg.iters / 2000).max(1);
    // threads: 0 = auto — realizations fan out across cores with
    // bit-identical results (see coordinator::runner).
    let mc = MonteCarlo {
        runs: cfg.runs,
        iters: cfg.iters,
        seed: cfg.seed,
        record_every,
        threads: 0,
    };
    let mut series = Vec::new();
    let mut steady = Vec::new();

    let mut xla_rt = match engine {
        Engine::Xla => Some(Runtime::open_default()?),
        Engine::Rust => None,
    };

    for (label, m, m_grad) in settings(cfg) {
        // --- theory ---------------------------------------------------
        let setup = TheorySetup {
            n_nodes: cfg.n_nodes,
            dim: cfg.dim,
            m,
            m_grad,
            c: c.to_dense(),
            mu: vec![cfg.mu; cfg.n_nodes],
            sigma_u2: model.sigma_u2.clone(),
            sigma_v2: model.sigma_v2.clone(),
        };
        let theory = MsdModel::new(setup);
        let tr = theory.trajectory(&model.wo, cfg.iters);
        let theory_db: Vec<f64> = tr
            .msd
            .iter()
            .skip(record_every - 1)
            .step_by(record_every)
            .map(|&x| to_db(x))
            .collect();
        let x: Vec<f64> = (1..=theory_db.len())
            .map(|i| (i * record_every) as f64)
            .collect();
        series.push(Series::new(format!("{label} (theory)"), x.clone(), theory_db));

        // --- simulation -------------------------------------------------
        let res = match engine {
            Engine::Rust => {
                if cfg.shards > 1 {
                    let sc = sim_scenario(cfg, m, m_grad, record_every);
                    crate::shard::run_scenario_sharded(&sc).map_err(anyhow::Error::msg)?
                } else {
                    let net = net.clone();
                    // Lane dispatch (DESIGN.md §14): bit-identical to
                    // `run_rust` at every width, so purely throughput.
                    mc.run_rust_lanes_opts(
                        &model,
                        &Default::default(),
                        cfg.lanes.resolve(cfg.runs),
                        move || Box::new(Dcd::new(net.clone(), m, m_grad)),
                    )
                }
            }
            Engine::Xla => mc.run_xla(
                xla_rt.as_mut().unwrap(),
                "exp1",
                &XlaAlgo::Dcd { m, m_grad },
                &model,
                &net.c_f32(),
                &net.a_f32(),
                &net.mu_f32(),
            )?,
        };
        let sim_db: Vec<f64> = res.msd.iter().map(|&v| to_db(v)).collect();
        series.push(Series::new(format!("{label} (sim)"), x, sim_db));

        let t_db = to_db(tr.steady_state);
        let s_db = to_db(res.steady_state);
        if !quiet {
            println!(
                "exp1 {label:<16} steady-state: theory {t_db:7.2} dB  sim {s_db:7.2} dB  (|gap| {:.2} dB)",
                (t_db - s_db).abs()
            );
        }
        steady.push((label, t_db, s_db));
    }

    if let Some(dir) = out_dir {
        write_csv(format!("{dir}/exp1_fig3_left.csv"), &series)?;
        write_json(
            format!("{dir}/exp1_fig3_left.json"),
            "Fig. 3 (left): theoretical and simulated MSD",
            &series,
        )?;
        if !quiet {
            println!("exp1: wrote {dir}/exp1_fig3_left.csv");
        }
    }
    Ok(Exp1Output { series, steady })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Shrunk end-to-end exp1 on the rust engine: theory and simulation
    /// must land within 2 dB at steady state for all three algorithms.
    #[test]
    fn theory_matches_simulation_small() {
        let cfg = Exp1Config {
            runs: 12,
            iters: 8_000,
            mu: 5e-3, // faster convergence for the shrunk test
            ..Exp1Config::default()
        };
        let out = run_exp1(&cfg, Engine::Rust, None, true).unwrap();
        assert_eq!(out.series.len(), 6);
        for (label, theory_db, sim_db) in &out.steady {
            assert!(
                (theory_db - sim_db).abs() < 2.0,
                "{label}: theory {theory_db} dB vs sim {sim_db} dB"
            );
        }
        // Ordering: diffusion LMS <= CD <= DCD steady-state MSD.
        let ss: Vec<f64> = out.steady.iter().map(|s| s.2).collect();
        assert!(ss[0] <= ss[1] + 0.8, "dLMS {} vs CD {}", ss[0], ss[1]);
        assert!(ss[1] <= ss[2] + 0.8, "CD {} vs DCD {}", ss[1], ss[2]);
    }
}
