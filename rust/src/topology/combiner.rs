//! Sparse combination matrices (DESIGN.md §10).
//!
//! A combination matrix over an N-node graph has exactly one nonzero
//! column entry per in-neighbour plus the diagonal — O(E) entries, not
//! O(N²). `Combiner` stores them in CSR, *receiver-major*: storage row k
//! holds dense **column** k, i.e. the in-weights at receiver k, with
//! column ids sorted ascending ({k} ∪ N(k) — the graph's sorted-neighbour
//! invariant carries over). That orientation makes the per-iteration
//! impairment rebuild and every algorithm's combine step walk one
//! contiguous slice per node.
//!
//! Dense-matrix indexing convention is preserved: `c[(l, k)]` is the
//! weight of sender l at receiver k (storage row k, column id l), so all
//! call sites written against `Mat` compile unchanged.

use std::ops::Index;

use crate::linalg::Mat;

use super::{Graph, Rule};

static ZERO: f64 = 0.0;

/// CSR combination matrix, receiver-major (see module docs). The
/// diagonal entry of every receiver row is always stored, even when its
/// value is zero, so in-place reallocation always has a slot to move
/// weight into.
#[derive(Debug, Clone, PartialEq)]
pub struct Combiner {
    n: usize,
    /// Row k (receiver k) spans `indptr[k]..indptr[k + 1]`.
    indptr: Vec<usize>,
    /// Sender ids per row, sorted ascending.
    cols: Vec<usize>,
    vals: Vec<f64>,
    /// Position of the diagonal entry of each row inside `vals`.
    diag: Vec<usize>,
}

impl Combiner {
    /// Identity combiner (no cooperation): one diagonal entry per row.
    pub fn eye(n: usize) -> Self {
        Self {
            n,
            indptr: (0..=n).collect(),
            cols: (0..n).collect(),
            vals: vec![1.0; n],
            diag: (0..n).collect(),
        }
    }

    /// Build the combination matrix for `rule` on `g`, sparse natively.
    /// Entry [l, k] = weight of neighbour l at node k; the arithmetic is
    /// ordered exactly as the historical dense construction (Metropolis
    /// subtracts neighbour weights from the diagonal in sorted-neighbour
    /// order), so converted outputs are bit-identical.
    pub fn from_rule(g: &Graph, rule: Rule) -> Self {
        let n = g.n();
        let mut out = Self::with_graph_structure(g);
        match rule {
            Rule::Identity => {
                for k in 0..n {
                    out.vals[out.diag[k]] = 1.0;
                }
            }
            Rule::Uniform => {
                for k in 0..n {
                    let w = 1.0 / g.degree_incl(k) as f64;
                    let span = out.indptr[k]..out.indptr[k + 1];
                    for v in &mut out.vals[span] {
                        *v = w;
                    }
                }
            }
            Rule::Metropolis => {
                for k in 0..n {
                    let mut diag = 1.0;
                    for &l in g.neighbors(k) {
                        let w = 1.0 / g.degree_incl(k).max(g.degree_incl(l)) as f64;
                        let idx = out.entry_idx(k, l).expect("neighbour slot exists");
                        out.vals[idx] = w;
                        diag -= w;
                    }
                    out.vals[out.diag[k]] = diag;
                }
            }
        }
        out
    }

    /// All-zero values on the graph's structure ({k} ∪ N(k) per row).
    fn with_graph_structure(g: &Graph) -> Self {
        let n = g.n();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::new();
        let mut diag = Vec::with_capacity(n);
        indptr.push(0);
        for k in 0..n {
            let mut placed = false;
            for &l in g.neighbors(k) {
                if !placed && l > k {
                    diag.push(cols.len());
                    cols.push(k);
                    placed = true;
                }
                cols.push(l);
            }
            if !placed {
                diag.push(cols.len());
                cols.push(k);
            }
            indptr.push(cols.len());
        }
        let vals = vec![0.0; cols.len()];
        Self { n, indptr, cols, vals, diag }
    }

    /// Sparsify a dense combination matrix. Nonzero entries of each
    /// dense column become a storage row; the diagonal is always kept
    /// structurally.
    pub fn from_dense(m: &Mat) -> Self {
        assert!(m.is_square(), "combiner must be square");
        let n = m.rows();
        let mut indptr = Vec::with_capacity(n + 1);
        let mut cols = Vec::new();
        let mut vals = Vec::new();
        let mut diag = Vec::with_capacity(n);
        indptr.push(0);
        for k in 0..n {
            for l in 0..n {
                let v = m[(l, k)];
                if l == k {
                    diag.push(cols.len());
                    cols.push(l);
                    vals.push(v);
                } else if v != 0.0 {
                    cols.push(l);
                    vals.push(v);
                }
            }
            indptr.push(cols.len());
        }
        Self { n, indptr, cols, vals, diag }
    }

    /// Densify (exact: values copy bit for bit).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.n, self.n);
        for k in 0..self.n {
            let (senders, weights) = self.row(k);
            for (&l, &v) in senders.iter().zip(weights) {
                out[(l, k)] = v;
            }
        }
        out
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Dense-shape compatibility: square, n x n.
    pub fn rows(&self) -> usize {
        self.n
    }

    pub fn cols(&self) -> usize {
        self.n
    }

    /// Number of stored entries (≈ 2E + N on a graph structure).
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Receiver k's in-edges as parallel (sender ids, weights) slices.
    /// Sender ids are sorted ascending and include k itself.
    pub fn row(&self, k: usize) -> (&[usize], &[f64]) {
        let span = self.row_span(k);
        (&self.cols[span.clone()], &self.vals[span])
    }

    /// The range of positions inside `vals` holding receiver k's row.
    pub fn row_span(&self, k: usize) -> std::ops::Range<usize> {
        self.indptr[k]..self.indptr[k + 1]
    }

    /// Position inside `vals` of the (receiver, sender) entry, if stored.
    pub fn entry_idx(&self, receiver: usize, sender: usize) -> Option<usize> {
        let span = self.indptr[receiver]..self.indptr[receiver + 1];
        self.cols[span.clone()]
            .binary_search(&sender)
            .ok()
            .map(|i| span.start + i)
    }

    /// Position inside `vals` of receiver k's diagonal entry. O(1).
    pub fn diag_idx(&self, k: usize) -> usize {
        self.diag[k]
    }

    /// The diagonal weight at node k. O(1).
    pub fn diag(&self, k: usize) -> f64 {
        self.vals[self.diag[k]]
    }

    /// Weight of sender l at receiver k (0 for non-stored pairs).
    pub fn get(&self, l: usize, k: usize) -> f64 {
        match self.entry_idx(k, l) {
            Some(i) => self.vals[i],
            None => 0.0,
        }
    }

    /// Stored weights, receiver-major.
    pub fn vals(&self) -> &[f64] {
        &self.vals
    }

    /// Mutable stored weights — structure is fixed, which is what the
    /// O(E) impairment rebuild relies on.
    pub fn vals_mut(&mut self) -> &mut [f64] {
        &mut self.vals
    }

    /// Dense-column sums (sum of in-weights per receiver): one stored
    /// row each, O(nnz) total. A left-stochastic combiner has all 1s.
    pub fn col_sums(&self) -> Vec<f64> {
        (0..self.n)
            .map(|k| self.row(k).1.iter().sum())
            .collect()
    }

    /// Dense-row sums (sum of out-weights per sender), O(nnz). A
    /// right-stochastic combiner has all 1s.
    pub fn row_sums(&self) -> Vec<f64> {
        let mut out = vec![0.0; self.n];
        for (&l, &v) in self.cols.iter().zip(&self.vals) {
            out[l] += v;
        }
        out
    }

    /// Whether this combiner equals the identity to 1e-12 (diagonal 1,
    /// everything stored off-diagonal 0). O(nnz) — replaces the dense
    /// O(N²) scans the algorithms used for no-cooperation detection.
    pub fn is_identity(&self) -> bool {
        for k in 0..self.n {
            let (senders, weights) = self.row(k);
            for (&l, &v) in senders.iter().zip(weights) {
                let want = if l == k { 1.0 } else { 0.0 };
                if (v - want).abs() > 1e-12 {
                    return false;
                }
            }
        }
        true
    }
}

impl Index<(usize, usize)> for Combiner {
    type Output = f64;

    /// Dense-style indexing: `c[(l, k)]` = weight of sender l at
    /// receiver k. Non-stored pairs read as 0.
    fn index(&self, (l, k): (usize, usize)) -> &f64 {
        match self.entry_idx(k, l) {
            Some(i) => &self.vals[i],
            None => &ZERO,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eye_is_identity() {
        let c = Combiner::eye(5);
        assert!(c.is_identity());
        assert_eq!(c.nnz(), 5);
        assert_eq!(c[(2, 2)], 1.0);
        assert_eq!(c[(1, 2)], 0.0);
        assert_eq!(c.to_dense(), Mat::eye(5));
    }

    #[test]
    fn dense_roundtrip_preserves_values() {
        let g = Graph::paper_ten_node();
        let c = Combiner::from_rule(&g, Rule::Metropolis);
        let d = c.to_dense();
        let c2 = Combiner::from_dense(&d);
        assert_eq!(c2.to_dense(), d);
        for k in 0..10 {
            for l in 0..10 {
                assert_eq!(c[(l, k)], d[(l, k)], "entry ({l},{k})");
            }
        }
    }

    #[test]
    fn structure_matches_graph() {
        let g = Graph::ring(6, 1);
        let c = Combiner::from_rule(&g, Rule::Uniform);
        // 6 nodes x (2 neighbours + self) entries.
        assert_eq!(c.nnz(), 18);
        for k in 0..6 {
            let (senders, _) = c.row(k);
            assert!(senders.windows(2).all(|w| w[0] < w[1]));
            assert!(senders.contains(&k));
            assert_eq!(c.diag(k), 1.0 / 3.0);
        }
        assert_eq!(c.col_sums(), vec![1.0; 6]);
    }

    #[test]
    fn identity_rule_keeps_structural_zeros() {
        // Structural slots for every graph edge survive under Identity,
        // so an impairment rebuild can still find them.
        let g = Graph::ring(4, 1);
        let c = Combiner::from_rule(&g, Rule::Identity);
        assert!(c.is_identity());
        assert_eq!(c.nnz(), 12);
        assert!(c.entry_idx(0, 1).is_some());
        assert_eq!(c[(1, 0)], 0.0);
    }

    #[test]
    fn diag_index_is_consistent() {
        let g = Graph::paper_ten_node();
        let c = Combiner::from_rule(&g, Rule::Metropolis);
        for k in 0..10 {
            assert_eq!(c.entry_idx(k, k), Some(c.diag_idx(k)));
            assert_eq!(c.diag(k), c[(k, k)]);
        }
    }
}
