//! Network topology: graphs, combination rules, node placement.
//!
//! Provides the paper's three networks — the 10-node topology of Fig. 2,
//! the 50-node network of Experiment 2, the 80-node hillside WSN of
//! Fig. 4 — plus generic generators (ring, random geometric) and the
//! Metropolis / uniform combination-weight rules of [1].

use crate::linalg::Mat;
use crate::rng::Pcg64;

mod combiner;

pub use combiner::Combiner;

/// Undirected connected graph over `n` nodes.
#[derive(Debug, Clone)]
pub struct Graph {
    n: usize,
    /// Sorted neighbour lists, **excluding** self.
    adj: Vec<Vec<usize>>,
    /// Optional 2-D positions (used by geometric networks / plots).
    pub positions: Option<Vec<(f64, f64)>>,
}

impl Graph {
    /// Build from an undirected edge list. O(E log E): duplicates are
    /// removed by sort + dedup rather than per-edge linear scans.
    pub fn from_edges(n: usize, edges: &[(usize, usize)]) -> Self {
        let mut adj = vec![Vec::new(); n];
        for &(a, b) in edges {
            assert!(a < n && b < n && a != b, "bad edge ({a},{b})");
            adj[a].push(b);
            adj[b].push(a);
        }
        for list in &mut adj {
            list.sort_unstable();
            list.dedup();
        }
        Self { n, adj, positions: None }
    }

    /// Number of nodes.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Neighbours of `k`, excluding `k` itself.
    pub fn neighbors(&self, k: usize) -> &[usize] {
        &self.adj[k]
    }

    /// |N_k| including the node itself (the paper's convention).
    pub fn degree_incl(&self, k: usize) -> usize {
        self.adj[k].len() + 1
    }

    /// Number of undirected edges.
    pub fn edge_count(&self) -> usize {
        self.adj.iter().map(Vec::len).sum::<usize>() / 2
    }

    /// Whether nodes `a` and `b` are linked.
    pub fn has_edge(&self, a: usize, b: usize) -> bool {
        self.adj[a].binary_search(&b).is_ok()
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        let mut seen = Vec::new();
        let mut stack = Vec::new();
        self.is_connected_with(&mut seen, &mut stack)
    }

    /// Connectivity check with caller-owned scratch buffers — iterative
    /// BFS, no per-call allocation once the buffers have grown to n.
    /// On return `seen` marks the component containing node 0 (so a
    /// `false` result leaves the caller with the partition for free).
    pub fn is_connected_with(&self, seen: &mut Vec<bool>, stack: &mut Vec<usize>) -> bool {
        if self.n == 0 {
            return true;
        }
        seen.clear();
        seen.resize(self.n, false);
        stack.clear();
        stack.push(0);
        seen[0] = true;
        let mut count = 1;
        while let Some(k) = stack.pop() {
            for &j in &self.adj[k] {
                if !seen[j] {
                    seen[j] = true;
                    count += 1;
                    stack.push(j);
                }
            }
        }
        count == self.n
    }

    /// Connectivity of the subgraph induced by the `active` nodes
    /// (BFS from the first active node over active-only neighbours,
    /// caller-owned scratch — no allocation once buffers have grown).
    /// Vacuously true when no node is active. This is the churn veto
    /// of the dynamics layer (DESIGN.md §12).
    pub fn is_connected_subset(
        &self,
        active: &[bool],
        seen: &mut Vec<bool>,
        stack: &mut Vec<usize>,
    ) -> bool {
        debug_assert_eq!(active.len(), self.n);
        let target = active.iter().filter(|&&a| a).count();
        let Some(start) = active.iter().position(|&a| a) else {
            return true;
        };
        seen.clear();
        seen.resize(self.n, false);
        stack.clear();
        stack.push(start);
        seen[start] = true;
        let mut count = 1;
        while let Some(k) = stack.pop() {
            for &j in &self.adj[k] {
                if active[j] && !seen[j] {
                    seen[j] = true;
                    count += 1;
                    stack.push(j);
                }
            }
        }
        count == target
    }

    /// Mobility support graph (DESIGN.md §12): the union of this
    /// graph's edges with every node pair whose placement distance is
    /// within `radius + 2·rho` — everything two nodes orbiting their
    /// homes with amplitude `rho` could ever bring within radio reach.
    /// The dynamics layer builds combiners once over this support and
    /// then only toggles per-slot liveness masks, so rewiring costs
    /// O(E) per iteration with no rebuild. Requires positions; consumes
    /// no RNG (scenario seed-stream neutral).
    pub fn with_mobility_support(&self, radius: f64, rho: f64) -> Self {
        let pos = self
            .positions
            .as_ref()
            .expect("mobility support requires node positions");
        let reach = radius + 2.0 * rho;
        let mut g = self.clone();
        for i in 0..self.n {
            for j in (i + 1)..self.n {
                if !g.has_edge(i, j) && dist(pos[i], pos[j]) <= reach {
                    g.insert_edge(i, j);
                }
            }
        }
        g
    }

    /// Ring lattice where each node links to `hops` nodes on each side.
    pub fn ring(n: usize, hops: usize) -> Self {
        let mut edges = Vec::new();
        for i in 0..n {
            for h in 1..=hops {
                edges.push((i, (i + h) % n));
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Random geometric graph on the unit square: nodes within `radius`
    /// are linked; extra nearest-neighbour edges are added until the
    /// graph is connected (so the constructor always succeeds).
    pub fn random_geometric(n: usize, radius: f64, rng: &mut Pcg64) -> Self {
        let pos: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.next_f64(), rng.next_f64()))
            .collect();
        let mut edges = Vec::new();
        for i in 0..n {
            for j in (i + 1)..n {
                if dist(pos[i], pos[j]) <= radius {
                    edges.push((i, j));
                }
            }
        }
        let mut g = Self::from_edges(n, &edges);
        // Stitch components together through their closest node pairs.
        // The BFS scratch doubles as the component mask, and each new
        // edge is inserted in place — no graph rebuild per stitch.
        let mut seen = Vec::new();
        let mut stack = Vec::new();
        while !g.is_connected_with(&mut seen, &mut stack) {
            let (mut best, mut bd) = ((0, 0), f64::INFINITY);
            for i in 0..n {
                if !seen[i] {
                    continue;
                }
                for j in 0..n {
                    if seen[j] {
                        continue;
                    }
                    let d = dist(pos[i], pos[j]);
                    if d < bd {
                        bd = d;
                        best = (i, j);
                    }
                }
            }
            g.insert_edge(best.0, best.1);
        }
        g.positions = Some(pos);
        g
    }

    /// Insert an undirected edge, keeping neighbour lists sorted.
    fn insert_edge(&mut self, a: usize, b: usize) {
        debug_assert!(a != b && a < self.n && b < self.n);
        if let Err(i) = self.adj[a].binary_search(&b) {
            self.adj[a].insert(i, b);
        }
        if let Err(i) = self.adj[b].binary_search(&a) {
            self.adj[b].insert(i, a);
        }
    }

    /// Rectangular 4-neighbour lattice (`rows * cols` nodes, node id
    /// `r * cols + c`), with positions on the unit square. This is the
    /// generator behind the large-N `mega-grid` scenario: building it is
    /// O(N), unlike the O(N²) pair scan of `random_geometric`.
    pub fn grid(rows: usize, cols: usize) -> Self {
        assert!(rows * cols > 0, "empty grid");
        let n = rows * cols;
        let mut adj = vec![Vec::new(); n];
        for r in 0..rows {
            for c in 0..cols {
                let id = r * cols + c;
                // Pushed in ascending order: up, left, right, down.
                if r > 0 {
                    adj[id].push(id - cols);
                }
                if c > 0 {
                    adj[id].push(id - 1);
                }
                if c + 1 < cols {
                    adj[id].push(id + 1);
                }
                if r + 1 < rows {
                    adj[id].push(id + cols);
                }
            }
        }
        let pos = (0..n)
            .map(|id| {
                let (r, c) = (id / cols, id % cols);
                (
                    c as f64 / (cols.max(2) - 1) as f64,
                    r as f64 / (rows.max(2) - 1) as f64,
                )
            })
            .collect();
        Self { n, adj, positions: Some(pos) }
    }

    /// The 10-node topology used in Experiment 1 (Fig. 2 left). The paper
    /// prints the drawing, not the adjacency list; this is a connected
    /// 10-node graph with comparable density (16 edges, degrees 2–5),
    /// which is what the theoretical model consumes.
    pub fn paper_ten_node() -> Self {
        let edges = [
            (0, 1), (0, 2), (0, 3),
            (1, 2), (1, 4),
            (2, 3), (2, 5),
            (3, 6),
            (4, 5), (4, 7),
            (5, 6), (5, 8),
            (6, 9),
            (7, 8),
            (8, 9), (3, 9),
        ];
        Self::from_edges(10, &edges)
    }
}

fn dist(a: (f64, f64), b: (f64, f64)) -> f64 {
    ((a.0 - b.0).powi(2) + (a.1 - b.1).powi(2)).sqrt()
}

/// Combination-weight rules (paper ref. [1]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rule {
    /// Metropolis: a_{lk} = 1/max(|N_k|, |N_l|) for l in N_k \ {k},
    /// diagonal absorbs the rest. Symmetric ⇒ doubly stochastic.
    Metropolis,
    /// Uniform averaging: a_{lk} = 1/|N_k|.
    Uniform,
    /// Identity (no cooperation).
    Identity,
}

/// Build an N x N combination matrix with entry [l, k] = weight of
/// neighbour l at node k. Metropolis is doubly stochastic; Uniform is
/// left-stochastic (columns sum to 1). Sparse natively (O(E) storage);
/// call [`Combiner::to_dense`] for the dense form the theory layer uses.
pub fn combination_matrix(g: &Graph, rule: Rule) -> Combiner {
    Combiner::from_rule(g, rule)
}

/// Column sums (for left-stochastic checks).
pub fn col_sums(m: &Mat) -> Vec<f64> {
    let mut out = vec![0.0; m.cols()];
    for i in 0..m.rows() {
        for (j, s) in out.iter_mut().enumerate() {
            *s += m[(i, j)];
        }
    }
    out
}

/// Row sums (for right-stochastic checks).
pub fn row_sums(m: &Mat) -> Vec<f64> {
    (0..m.rows())
        .map(|i| m.row(i).iter().sum())
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_network_is_connected() {
        let g = Graph::paper_ten_node();
        assert_eq!(g.n(), 10);
        assert!(g.is_connected());
        assert_eq!(g.edge_count(), 16);
        for k in 0..10 {
            let d = g.degree_incl(k);
            assert!((3..=6).contains(&d), "node {k} degree {d}");
        }
    }

    #[test]
    fn ring_structure() {
        let g = Graph::ring(6, 1);
        assert!(g.is_connected());
        assert_eq!(g.edge_count(), 6);
        assert!(g.has_edge(0, 1));
        assert!(g.has_edge(0, 5));
        assert!(!g.has_edge(0, 3));
    }

    #[test]
    fn geometric_always_connected() {
        let mut rng = Pcg64::new(3, 0);
        for seed in 0..5 {
            let mut r = Pcg64::new(seed, 9);
            let g = Graph::random_geometric(30, 0.15, &mut r);
            assert!(g.is_connected());
            assert!(g.positions.is_some());
        }
        let _ = rng.next_u64();
    }

    #[test]
    fn metropolis_doubly_stochastic() {
        let g = Graph::paper_ten_node();
        let a = combination_matrix(&g, Rule::Metropolis);
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        for s in a.row_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        // Symmetry.
        let d = a.to_dense();
        assert!((&d - &d.transpose()).max_abs() < 1e-12);
        // Dense conversion agrees with the historical dense builder.
        for s in col_sums(&d) {
            assert!((s - 1.0).abs() < 1e-12);
        }
        // Support matches the graph.
        for k in 0..g.n() {
            for l in 0..g.n() {
                let linked = k == l || g.has_edge(k, l);
                assert_eq!(a[(l, k)] > 0.0, linked, "({l},{k})");
            }
        }
    }

    #[test]
    fn uniform_left_stochastic() {
        let g = Graph::ring(7, 2);
        let a = combination_matrix(&g, Rule::Uniform);
        for s in a.col_sums() {
            assert!((s - 1.0).abs() < 1e-12);
        }
        assert!((a[(0, 0)] - 0.2).abs() < 1e-12); // degree_incl = 5
    }

    #[test]
    fn identity_rule() {
        let g = Graph::ring(4, 1);
        let a = combination_matrix(&g, Rule::Identity);
        assert!((&a.to_dense() - &Mat::eye(4)).max_abs() == 0.0);
    }

    #[test]
    fn grid_structure() {
        let g = Graph::grid(3, 4);
        assert_eq!(g.n(), 12);
        assert!(g.is_connected());
        // 3 * 3 horizontal + 2 * 4 vertical edges.
        assert_eq!(g.edge_count(), 17);
        // Interior node 5 = (1, 1): 4 neighbours.
        assert_eq!(g.neighbors(5), &[1, 4, 6, 9]);
        // Corners have 2.
        assert_eq!(g.degree_incl(0), 3);
        assert!(g.positions.is_some());
    }

    #[test]
    fn connectivity_scratch_marks_component() {
        let g = Graph::from_edges(5, &[(0, 1), (2, 3), (3, 4)]);
        let mut seen = Vec::new();
        let mut stack = Vec::new();
        assert!(!g.is_connected_with(&mut seen, &mut stack));
        assert_eq!(seen, vec![true, true, false, false, false]);
    }

    #[test]
    #[should_panic(expected = "bad edge")]
    fn rejects_self_loop() {
        let _ = Graph::from_edges(3, &[(1, 1)]);
    }

    #[test]
    fn subset_connectivity() {
        let g = Graph::from_edges(5, &[(0, 1), (1, 2), (2, 3), (3, 4)]);
        let mut seen = Vec::new();
        let mut stack = Vec::new();
        assert!(g.is_connected_subset(&[true; 5], &mut seen, &mut stack));
        // Dropping an endpoint keeps the path connected...
        assert!(g.is_connected_subset(
            &[false, true, true, true, true],
            &mut seen,
            &mut stack
        ));
        // ... dropping an interior node cuts it.
        assert!(!g.is_connected_subset(
            &[true, true, false, true, true],
            &mut seen,
            &mut stack
        ));
        // Vacuous and singleton subsets are connected.
        assert!(g.is_connected_subset(&[false; 5], &mut seen, &mut stack));
        assert!(g.is_connected_subset(
            &[false, false, true, false, false],
            &mut seen,
            &mut stack
        ));
    }

    #[test]
    fn mobility_support_is_superset() {
        let mut rng = Pcg64::new(17, 9);
        let base = Graph::random_geometric(25, 0.2, &mut rng);
        let sup = base.with_mobility_support(0.2, 0.05);
        assert!(sup.edge_count() >= base.edge_count());
        assert!(sup.is_connected());
        assert_eq!(sup.positions.as_ref(), base.positions.as_ref());
        for k in 0..base.n() {
            for &j in base.neighbors(k) {
                assert!(sup.has_edge(k, j), "support lost base edge ({k},{j})");
            }
        }
        // Every added edge is within the orbit reach.
        let pos = base.positions.as_ref().unwrap();
        for k in 0..sup.n() {
            for &j in sup.neighbors(k) {
                if !base.has_edge(k, j) {
                    assert!(dist(pos[k], pos[j]) <= 0.2 + 2.0 * 0.05);
                }
            }
        }
        // rho = 0 adds nothing beyond the existing radius edges.
        let same = base.with_mobility_support(0.2, 0.0);
        assert_eq!(same.edge_count(), base.edge_count());
    }
}
