//! Metrics: MSD traces, Monte-Carlo averaging, dB conversion, CSV/JSON
//! result writers.

use crate::jsonio::{obj, Json};
use std::io::Write;
use std::path::Path;

/// Convert a linear MSD value to dB.
#[inline]
pub fn to_db(x: f64) -> f64 {
    10.0 * x.max(1e-300).log10()
}

/// Running element-wise mean of equal-length traces (MC averaging).
#[derive(Debug, Clone, Default)]
pub struct TraceAccumulator {
    sum: Vec<f64>,
    sum_sq: Vec<f64>,
    count: usize,
}

impl TraceAccumulator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, trace: &[f64]) {
        if self.sum.is_empty() {
            self.sum = vec![0.0; trace.len()];
            self.sum_sq = vec![0.0; trace.len()];
        }
        assert_eq!(self.sum.len(), trace.len(), "trace length changed");
        for ((s, sq), &x) in self.sum.iter_mut().zip(self.sum_sq.iter_mut()).zip(trace) {
            *s += x;
            *sq += x * x;
        }
        self.count += 1;
    }

    pub fn count(&self) -> usize {
        self.count
    }

    pub fn mean(&self) -> Vec<f64> {
        assert!(self.count > 0, "no traces accumulated");
        self.sum.iter().map(|&s| s / self.count as f64).collect()
    }

    /// Per-point standard deviation across runs.
    pub fn std(&self) -> Vec<f64> {
        assert!(self.count > 1, "need >= 2 traces for std");
        let n = self.count as f64;
        self.sum
            .iter()
            .zip(self.sum_sq.iter())
            .map(|(&s, &sq)| ((sq / n - (s / n) * (s / n)).max(0.0)).sqrt())
            .collect()
    }

    /// Mean of the trailing `tail` points of the mean trace — the
    /// steady-state estimate used across the experiments.
    pub fn steady_state(&self, tail: usize) -> f64 {
        let m = self.mean();
        let tail = tail.min(m.len()).max(1);
        m[m.len() - tail..].iter().sum::<f64>() / tail as f64
    }
}

/// A labelled series (one curve of a figure).
#[derive(Debug, Clone)]
pub struct Series {
    pub label: String,
    pub x: Vec<f64>,
    pub y: Vec<f64>,
}

impl Series {
    pub fn new(label: impl Into<String>, x: Vec<f64>, y: Vec<f64>) -> Self {
        assert_eq!(x.len(), y.len());
        Self { label: label.into(), x, y }
    }

    pub fn from_trace(label: impl Into<String>, y: Vec<f64>) -> Self {
        let x = (1..=y.len()).map(|i| i as f64).collect();
        Self::new(label, x, y)
    }
}

/// Write a set of series as CSV: `x,label1,label2,...` (series must share
/// the x grid; ragged series are written as separate files by caller).
pub fn write_csv(path: impl AsRef<Path>, series: &[Series]) -> std::io::Result<()> {
    assert!(!series.is_empty());
    let x = &series[0].x;
    for s in series {
        assert_eq!(s.x, *x, "series {label} has a different x grid", label = s.label);
    }
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let mut f = std::fs::File::create(path)?;
    write!(f, "x")?;
    for s in series {
        write!(f, ",{}", s.label.replace(',', ";"))?;
    }
    writeln!(f)?;
    for (i, &xv) in x.iter().enumerate() {
        write!(f, "{xv}")?;
        for s in series {
            write!(f, ",{}", s.y[i])?;
        }
        writeln!(f)?;
    }
    Ok(())
}

/// Write series as a JSON document (self-describing, ragged-safe).
pub fn write_json(path: impl AsRef<Path>, title: &str, series: &[Series]) -> std::io::Result<()> {
    write_json_with_meta(path, title, None, series)
}

/// [`write_json`] with an optional `"manifest"` object recorded next to
/// the series — the scenario runner uses it to pin down how a result
/// was produced (runs/seed/threads/shard layout; DESIGN.md §8), so a
/// results file is auditable without the invocation that made it.
pub fn write_json_with_meta(
    path: impl AsRef<Path>,
    title: &str,
    manifest: Option<Json>,
    series: &[Series],
) -> std::io::Result<()> {
    if let Some(parent) = path.as_ref().parent() {
        std::fs::create_dir_all(parent)?;
    }
    let arr = Json::Arr(
        series
            .iter()
            .map(|s| {
                obj(vec![
                    ("label", Json::Str(s.label.clone())),
                    ("x", Json::Arr(s.x.iter().map(|&v| Json::Num(v)).collect())),
                    ("y", Json::Arr(s.y.iter().map(|&v| Json::Num(v)).collect())),
                ])
            })
            .collect(),
    );
    let mut pairs = vec![("title", Json::Str(title.to_string())), ("series", arr)];
    if let Some(meta) = manifest {
        pairs.push(("manifest", meta));
    }
    let doc = obj(pairs);
    std::fs::write(path, doc.to_string_pretty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn db_conversion() {
        assert!((to_db(1.0) - 0.0).abs() < 1e-12);
        assert!((to_db(0.1) + 10.0).abs() < 1e-12);
        assert!(to_db(0.0).is_finite()); // clamped, no -inf
    }

    #[test]
    fn accumulator_mean_std() {
        let mut acc = TraceAccumulator::new();
        acc.add(&[1.0, 2.0]);
        acc.add(&[3.0, 4.0]);
        assert_eq!(acc.mean(), vec![2.0, 3.0]);
        assert_eq!(acc.count(), 2);
        let std = acc.std();
        assert!((std[0] - 1.0).abs() < 1e-12);
        assert!((acc.steady_state(1) - 3.0).abs() < 1e-12);
        assert!((acc.steady_state(2) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn csv_roundtrip() {
        let dir = std::env::temp_dir().join("dcd_lms_test_csv");
        let path = dir.join("out.csv");
        let s1 = Series::from_trace("a", vec![1.0, 2.0]);
        let s2 = Series::from_trace("b", vec![3.0, 4.0]);
        write_csv(&path, &[s1, s2]).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("x,a,b"));
        assert!(text.contains("1,1,3"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn json_output_parses() {
        let dir = std::env::temp_dir().join("dcd_lms_test_json");
        let path = dir.join("out.json");
        let s = Series::new("msd", vec![1.0], vec![-20.0]);
        write_json(&path, "fig", &[s]).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("title").as_str(), Some("fig"));
        assert_eq!(doc.get("series").as_arr().unwrap().len(), 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
