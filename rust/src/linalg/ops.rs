//! Structured operations the theory engine leans on: Kronecker products,
//! Hadamard products, block-diagonal assembly, vec/unvec.
//!
//! Conventions follow the paper: `vec` stacks **columns** (so that
//! vec(AΣB) = (Bᵀ ⊗ A) vec(Σ), identity (114)).

use super::Mat;

/// Kronecker product A ⊗ B.
pub fn kron(a: &Mat, b: &Mat) -> Mat {
    let (ar, ac, br, bc) = (a.rows(), a.cols(), b.rows(), b.cols());
    let mut out = Mat::zeros(ar * br, ac * bc);
    for i in 0..ar {
        for j in 0..ac {
            let aij = a[(i, j)];
            if aij == 0.0 {
                continue;
            }
            for p in 0..br {
                for q in 0..bc {
                    out[(i * br + p, j * bc + q)] = aij * b[(p, q)];
                }
            }
        }
    }
    out
}

/// Hadamard (entry-wise) product A ⊙ B.
pub fn hadamard(a: &Mat, b: &Mat) -> Mat {
    assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
    let mut out = a.clone();
    for (x, &y) in out.data_mut().iter_mut().zip(b.data().iter()) {
        *x *= y;
    }
    out
}

/// Block-diagonal matrix from equally-sized square blocks.
pub fn block_diag(blocks: &[Mat]) -> Mat {
    assert!(!blocks.is_empty());
    let b = blocks[0].rows();
    for blk in blocks {
        assert!(blk.is_square() && blk.rows() == b, "blocks must be equal square");
    }
    let n = blocks.len();
    let mut out = Mat::zeros(n * b, n * b);
    for (k, blk) in blocks.iter().enumerate() {
        out.set_block(k, k, blk);
    }
    out
}

/// Column-stacking vec(M).
pub fn vec_of(m: &Mat) -> Vec<f64> {
    let mut v = Vec::with_capacity(m.rows() * m.cols());
    for j in 0..m.cols() {
        for i in 0..m.rows() {
            v.push(m[(i, j)]);
        }
    }
    v
}

/// Inverse of `vec_of`.
pub fn unvec(v: &[f64], rows: usize, cols: usize) -> Mat {
    assert_eq!(v.len(), rows * cols);
    let mut m = Mat::zeros(rows, cols);
    let mut idx = 0;
    for j in 0..cols {
        for i in 0..rows {
            m[(i, j)] = v[idx];
            idx += 1;
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kron_identity() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let k = kron(&Mat::eye(2), &a);
        // block-diagonal with two copies of a
        assert_eq!(k.block(0, 0, 2, 2), a);
        assert_eq!(k.block(1, 1, 2, 2), a);
        assert_eq!(k.block(0, 1, 2, 2), Mat::zeros(2, 2));
    }

    #[test]
    fn kron_mixed_product() {
        // (A ⊗ B)(C ⊗ D) = (AC) ⊗ (BD)
        let a = Mat::from_rows(&[&[1.0, 2.0], &[0.0, 1.0]]);
        let b = Mat::from_rows(&[&[2.0, 0.0], &[1.0, 1.0]]);
        let c = Mat::from_rows(&[&[1.0, 1.0], &[1.0, 0.0]]);
        let d = Mat::from_rows(&[&[0.0, 1.0], &[2.0, 1.0]]);
        let lhs = &kron(&a, &b) * &kron(&c, &d);
        let rhs = kron(&(&a * &c), &(&b * &d));
        assert!((&lhs - &rhs).max_abs() < 1e-12);
    }

    #[test]
    fn vec_identity_114() {
        // vec(AΣB) = (Bᵀ ⊗ A) vec(Σ) — the paper's (114).
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[0.5, 1.0], &[2.0, -1.0]]);
        let s = Mat::from_rows(&[&[1.0, 0.0], &[2.0, 5.0]]);
        let asb = &(&a * &s) * &b;
        let lhs = vec_of(&asb);
        let rhs = kron(&b.transpose(), &a).matvec(&vec_of(&s));
        for (x, y) in lhs.iter().zip(rhs.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn vec_unvec_roundtrip() {
        let m = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let v = vec_of(&m);
        assert_eq!(unvec(&v, 2, 3), m);
    }

    #[test]
    fn hadamard_with_identity_extracts_diag() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let d = hadamard(&Mat::eye(2), &a);
        assert_eq!(d, Mat::diag(&[1.0, 4.0]));
    }

    #[test]
    fn block_diag_assembly() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let bd = block_diag(&[a.clone(), b.clone()]);
        assert_eq!(bd.block(0, 0, 2, 2), a);
        assert_eq!(bd.block(1, 1, 2, 2), b);
        assert_eq!(bd.rows(), 4);
    }
}
