//! Compressed-sparse-row (CSR) f64 matrix.
//!
//! The large-N fast path of DESIGN.md §10: diffusion networks are sparse
//! (E ≪ N²), so the topology layer, the per-iteration impairment rebuild
//! and the theory engine's recursion matrix 𝓑 all store O(nnz) instead of
//! O(N²). The dense [`Mat`](super::Mat) stays the substrate for the small
//! problems the closed-form tests exercise; `SparseMat` converts to and
//! from it losslessly, and the CSR × dense product bottoms out in the
//! same 4-lane [`kernels`](super::kernels) the dense multiply uses.
//!
//! Row indices within a row are kept sorted ascending — the same
//! invariant the topology layer's neighbour lists rely on — so per-entry
//! lookup is a binary search and row iteration streams contiguously.

use super::{kernels, Mat};

/// CSR matrix: `indptr[r]..indptr[r + 1]` delimits row `r`'s entries in
/// `indices` (column ids, sorted ascending per row) and `vals`.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMat {
    rows: usize,
    cols: usize,
    indptr: Vec<usize>,
    indices: Vec<usize>,
    vals: Vec<f64>,
}

impl SparseMat {
    /// Build from raw CSR parts, validating the invariants (monotone
    /// `indptr`, in-bounds and strictly ascending column ids per row).
    pub fn from_parts(
        rows: usize,
        cols: usize,
        indptr: Vec<usize>,
        indices: Vec<usize>,
        vals: Vec<f64>,
    ) -> Self {
        assert_eq!(indptr.len(), rows + 1, "indptr must have rows + 1 entries");
        assert_eq!(indices.len(), vals.len(), "indices/vals length mismatch");
        assert_eq!(indptr[0], 0, "indptr must start at 0");
        assert_eq!(*indptr.last().unwrap(), indices.len(), "indptr must end at nnz");
        for r in 0..rows {
            assert!(indptr[r] <= indptr[r + 1], "indptr must be monotone");
            let row = &indices[indptr[r]..indptr[r + 1]];
            for w in row.windows(2) {
                assert!(w[0] < w[1], "row {r}: column ids must be strictly ascending");
            }
            if let Some(&last) = row.last() {
                assert!(last < cols, "row {r}: column id {last} out of bounds");
            }
        }
        Self { rows, cols, indptr, indices, vals }
    }

    /// An empty (all-zero, no stored entries) matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self {
            rows,
            cols,
            indptr: vec![0; rows + 1],
            indices: Vec::new(),
            vals: Vec::new(),
        }
    }

    /// Sparsify a dense matrix (stores exactly the nonzero entries).
    pub fn from_dense(m: &Mat) -> Self {
        let (rows, cols) = (m.rows(), m.cols());
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut vals = Vec::new();
        indptr.push(0);
        for i in 0..rows {
            for (j, &v) in m.row(i).iter().enumerate() {
                if v != 0.0 {
                    indices.push(j);
                    vals.push(v);
                }
            }
            indptr.push(indices.len());
        }
        Self { rows, cols, indptr, indices, vals }
    }

    /// Densify (exact: stored values are copied bit for bit).
    pub fn to_dense(&self) -> Mat {
        let mut out = Mat::zeros(self.rows, self.cols);
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let orow = &mut out.data_mut()[r * self.cols..(r + 1) * self.cols];
            for (&c, &v) in cols.iter().zip(vals) {
                orow[c] = v;
            }
        }
        out
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Number of stored entries.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row `r` as parallel (column ids, values) slices.
    pub fn row(&self, r: usize) -> (&[usize], &[f64]) {
        let span = self.indptr[r]..self.indptr[r + 1];
        (&self.indices[span.clone()], &self.vals[span])
    }

    /// Stored values (row-major within the CSR layout).
    pub fn vals(&self) -> &[f64] {
        &self.vals
    }

    /// Mutable stored values — the structure (indptr/indices) is fixed,
    /// which is exactly what the O(E) impairment rebuild needs.
    pub fn vals_mut(&mut self) -> &mut [f64] {
        &mut self.vals
    }

    /// Entry (r, c), defaulting to 0 for non-stored positions.
    pub fn get(&self, r: usize, c: usize) -> f64 {
        let (cols, vals) = self.row(r);
        match cols.binary_search(&c) {
            Ok(i) => vals[i],
            Err(_) => 0.0,
        }
    }

    /// Sparse matrix–vector product `self · x`.
    pub fn spmv(&self, x: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.rows];
        self.spmv_into(x, &mut out);
        out
    }

    /// `out = self · x` without allocating.
    pub fn spmv_into(&self, x: &[f64], out: &mut [f64]) {
        assert_eq!(x.len(), self.cols, "spmv: dimension mismatch");
        assert_eq!(out.len(), self.rows, "spmv: output length mismatch");
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let mut acc = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v * x[c];
            }
            out[r] = acc;
        }
    }

    /// CSR × dense product `out = self · rhs` (the matrix-free theory
    /// engine's 𝓑ᵀΣ step): each stored entry contributes a scaled rhs
    /// row, accumulated through the 4-lane axpy kernel. O(nnz · rhs.cols).
    pub fn mul_dense_into(&self, rhs: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, rhs.rows, "mul_dense_into: dim mismatch");
        assert_eq!(
            (out.rows(), out.cols()),
            (self.rows, rhs.cols()),
            "mul_dense_into: output shape mismatch"
        );
        let w = rhs.cols();
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            let orow = &mut out.data_mut()[r * w..(r + 1) * w];
            orow.iter_mut().for_each(|x| *x = 0.0);
            for (&c, &v) in cols.iter().zip(vals) {
                kernels::axpy(v, rhs.row(c), orow);
            }
        }
    }

    /// Transpose (O(nnz + rows + cols), counting-sort by column).
    pub fn transpose(&self) -> SparseMat {
        let mut out = SparseMat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into `out`, reusing its buffers (allocation-free once
    /// the shapes have stabilised). `out` must not alias `self`.
    pub fn transpose_into(&self, out: &mut SparseMat) {
        out.rows = self.cols;
        out.cols = self.rows;
        out.indptr.clear();
        out.indptr.resize(self.cols + 1, 0);
        out.indices.clear();
        out.indices.resize(self.nnz(), 0);
        out.vals.clear();
        out.vals.resize(self.nnz(), 0.0);
        // Column occupancy counts -> output row offsets.
        for &c in &self.indices {
            out.indptr[c + 1] += 1;
        }
        for i in 1..out.indptr.len() {
            out.indptr[i] += out.indptr[i - 1];
        }
        // Scatter: source rows ascend, so each output row's column ids
        // (= source row ids) come out sorted ascending as required.
        let mut cursor = out.indptr.clone();
        for r in 0..self.rows {
            let (cols, vals) = self.row(r);
            for (&c, &v) in cols.iter().zip(vals) {
                let slot = cursor[c];
                out.indices[slot] = r;
                out.vals[slot] = v;
                cursor[c] += 1;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Mat {
        Mat::from_rows(&[
            &[1.0, 0.0, 2.0, 0.0],
            &[0.0, 0.0, 0.0, 0.0],
            &[-3.0, 4.0, 0.0, 0.5],
        ])
    }

    #[test]
    fn dense_roundtrip_is_exact() {
        let d = sample();
        let s = SparseMat::from_dense(&d);
        assert_eq!(s.nnz(), 5);
        assert_eq!(s.to_dense(), d);
        assert_eq!(s.get(0, 2), 2.0);
        assert_eq!(s.get(1, 1), 0.0);
        assert_eq!(s.get(2, 3), 0.5);
    }

    #[test]
    fn spmv_matches_dense_matvec() {
        let d = sample();
        let s = SparseMat::from_dense(&d);
        let x = [0.5, -1.0, 2.0, 4.0];
        let want = d.matvec(&x);
        let got = s.spmv(&x);
        for (a, b) in want.iter().zip(&got) {
            assert!((a - b).abs() < 1e-15, "{a} vs {b}");
        }
    }

    #[test]
    fn transpose_matches_dense() {
        let d = sample();
        let s = SparseMat::from_dense(&d);
        assert_eq!(s.transpose().to_dense(), d.transpose());
        // Reused buffers give the same result.
        let mut out = SparseMat::zeros(0, 0);
        s.transpose_into(&mut out);
        assert_eq!(out.to_dense(), d.transpose());
    }

    #[test]
    fn mul_dense_matches_dense_product() {
        let d = sample();
        let s = SparseMat::from_dense(&d);
        let rhs = Mat::from_rows(&[
            &[1.0, 2.0],
            &[0.5, -1.0],
            &[3.0, 0.0],
            &[-2.0, 1.5],
        ]);
        let want = &d * &rhs;
        let mut got = Mat::zeros(3, 2);
        s.mul_dense_into(&rhs, &mut got);
        assert!((&want - &got).max_abs() < 1e-14);
    }

    #[test]
    #[should_panic(expected = "ascending")]
    fn rejects_unsorted_rows() {
        let _ = SparseMat::from_parts(1, 3, vec![0, 2], vec![2, 0], vec![1.0, 2.0]);
    }
}
