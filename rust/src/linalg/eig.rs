//! Eigenvalue routines: cyclic Jacobi for symmetric matrices, power
//! iteration for the dominant eigenvalue, and a general spectral-radius
//! estimate (power iteration on the possibly-nonsymmetric matrix, used
//! for the stability check rho(B) < 1, eq. (35)).

use super::Mat;

/// All eigenvalues of a symmetric matrix via cyclic Jacobi rotations.
/// Returns them sorted descending. Cost O(n^3) per sweep, fine for the
/// covariance matrices involved (n <= L = 50).
pub fn jacobi_eigenvalues(m: &Mat) -> Vec<f64> {
    assert!(m.is_square());
    let n = m.rows();
    let mut a = m.symmetrized();
    let max_sweeps = 64;
    for _ in 0..max_sweeps {
        // Off-diagonal Frobenius norm.
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < 1e-14 * (1.0 + a.fro_norm()) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // Apply rotation J(p,q,theta) on both sides.
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut evs: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    evs.sort_by(|x, y| y.partial_cmp(x).unwrap());
    evs
}

/// Largest eigenvalue of a symmetric PSD matrix by power iteration.
pub fn power_iteration_sym(m: &Mat, iters: usize) -> f64 {
    jacobi_or_power(m, iters, true)
}

/// Spectral radius estimate for a general square matrix: power iteration
/// on M with periodic renormalisation. For matrices with a dominant real
/// eigenvalue (the case for the paper's B built from PD covariance terms)
/// this converges linearly; we also fall back to max |Jacobi eig| when M
/// is symmetric to machine precision.
pub fn spectral_radius(m: &Mat, iters: usize) -> f64 {
    jacobi_or_power(m, iters, false)
}

fn jacobi_or_power(m: &Mat, iters: usize, _sym: bool) -> f64 {
    assert!(m.is_square());
    power_radius_with(m.rows(), iters, |v| m.matvec(v))
}

/// Power iteration on an abstract matvec operator — the same arithmetic
/// as the dense path (`spectral_radius` delegates here with `m.matvec`),
/// so a CSR-backed caller gets identical convergence behaviour without
/// ever forming the matrix densely.
pub(crate) fn power_radius_with<F>(n: usize, iters: usize, mut matvec: F) -> f64
where
    F: FnMut(&[f64]) -> Vec<f64>,
{
    if n == 0 {
        return 0.0;
    }
    // Deterministic pseudo-random start vector to avoid orthogonal starts.
    let mut v: Vec<f64> = (0..n)
        .map(|i| {
            let x = ((i as u64).wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407) >> 33) as f64;
            x / (1u64 << 31) as f64 + 0.5
        })
        .collect();
    normalize(&mut v);
    let mut lambda = 0.0;
    let mut prev = f64::INFINITY;
    for it in 0..iters {
        let w = matvec(&v);
        let norm = w.iter().map(|x| x * x).sum::<f64>().sqrt();
        if norm == 0.0 {
            return 0.0;
        }
        // Rayleigh-style estimate |v·Mv| handles sign-flipping dominant
        // eigenvalues; the norm ratio handles complex-pair dominance
        // approximately (upper estimate).
        lambda = v.iter().zip(w.iter()).map(|(a, b)| a * b).sum::<f64>().abs().max(0.0);
        let ratio = norm;
        v = w;
        normalize(&mut v);
        if it > 8 && (ratio - prev).abs() < 1e-13 * ratio.max(1.0) {
            lambda = ratio;
            break;
        }
        prev = ratio;
        lambda = lambda.max(0.0);
        if it == iters - 1 {
            lambda = ratio;
        }
    }
    lambda
}

fn normalize(v: &mut [f64]) {
    let n = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if n > 0.0 {
        v.iter_mut().for_each(|x| *x /= n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jacobi_diagonal() {
        let evs = jacobi_eigenvalues(&Mat::diag(&[3.0, 1.0, 2.0]));
        assert!((evs[0] - 3.0).abs() < 1e-12);
        assert!((evs[1] - 2.0).abs() < 1e-12);
        assert!((evs[2] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1.
        let m = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let evs = jacobi_eigenvalues(&m);
        assert!((evs[0] - 3.0).abs() < 1e-12);
        assert!((evs[1] - 1.0).abs() < 1e-12);
    }

    #[test]
    fn jacobi_trace_preserved() {
        // Random-ish symmetric 5x5: eigenvalue sum equals trace.
        let mut m = Mat::zeros(5, 5);
        for i in 0..5 {
            for j in 0..5 {
                let v = ((i * 7 + j * 3) % 11) as f64 / 11.0;
                m[(i, j)] = v;
            }
        }
        let m = m.symmetrized();
        let evs = jacobi_eigenvalues(&m);
        let sum: f64 = evs.iter().sum();
        assert!((sum - m.trace()).abs() < 1e-10);
    }

    #[test]
    fn power_matches_jacobi() {
        let m = Mat::from_rows(&[&[4.0, 1.0, 0.0], &[1.0, 3.0, 1.0], &[0.0, 1.0, 2.0]]);
        let evs = jacobi_eigenvalues(&m);
        let lam = power_iteration_sym(&m, 500);
        assert!((lam - evs[0]).abs() < 1e-8, "power {lam} vs jacobi {}", evs[0]);
    }

    #[test]
    fn spectral_radius_contraction() {
        // 0.5 * orthogonal-ish matrix has rho = 0.5.
        let m = Mat::from_rows(&[&[0.0, 0.5], &[-0.5, 0.0]]);
        let rho = spectral_radius(&m, 2000);
        assert!((rho - 0.5).abs() < 1e-3, "rho {rho}");
        // Identity-scaled.
        let rho = spectral_radius(&Mat::eye(4).scale(0.9), 200);
        assert!((rho - 0.9).abs() < 1e-6);
    }
}
