//! Chunked scalar kernels shared by the theory engine and the
//! message-level simulator.
//!
//! Both hot paths bottom out in dot products and scaled accumulations
//! over contiguous `f64` slices. The kernels here process four lanes per
//! step with independent partial accumulators, which breaks the
//! loop-carried dependence of a naive fold and lets the compiler keep
//! four FMAs in flight (the slice iterators also guarantee the bounds
//! checks are hoisted). Summation order differs from a sequential fold,
//! so results may differ from a naive loop in the last ulps — every
//! consumer is tolerance-based.

/// Dot product with four independent partial sums.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        s0 += xa[0] * xb[0];
        s1 += xa[1] * xb[1];
        s2 += xa[2] * xb[2];
        s3 += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `y += alpha * x`, four lanes per step, no allocation.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    let mut cx = x.chunks_exact(4);
    let mut cy = y.chunks_exact_mut(4);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += alpha * x;
    }
}

// ---------------------------------------------------------------------
// Lane-strided kernels (DESIGN.md §14).
//
// The lane engine packs B independent Monte-Carlo runs into SoA buffers
// where element j of lane b lives at `j * lanes + b`. Each kernel below
// replicates its scalar counterpart's floating-point operation sequence
// *per lane* — same partial-sum shapes, same tail handling, same final
// fold — so lane b's result is bit-identical to running the scalar
// kernel on lane b's gathered vector. The j-outer / lane-inner loop
// order keeps every inner trip contiguous in memory (the compiler
// vectorises across lanes), while the 4-wide j unroll of `lane_dot`
// mirrors `dot`'s four independent accumulators exactly.

/// Per-lane dot product over lane-major SoA slices: writes
/// `out[b] = Σ_j a[j*lanes + b] · b[j*lanes + b]` with the *same*
/// summation order as [`dot`] applied to lane b alone (four independent
/// partial sums over j-chunks of 4, a sequential tail, and the
/// `(s0 + s1) + (s2 + s3) + tail` fold). `acc` is caller scratch of
/// length `4 * lanes` (allocation-free hot loop).
pub fn lane_dot(a: &[f64], b: &[f64], lanes: usize, acc: &mut [f64], out: &mut [f64]) {
    assert_eq!(a.len(), b.len(), "lane_dot: length mismatch");
    assert_eq!(acc.len(), 4 * lanes, "lane_dot: scratch must be 4*lanes");
    assert_eq!(out.len(), lanes, "lane_dot: out must be lanes");
    debug_assert_eq!(a.len() % lanes.max(1), 0);
    let l = a.len() / lanes.max(1);
    acc.iter_mut().for_each(|x| *x = 0.0);
    out.iter_mut().for_each(|x| *x = 0.0);
    let (s0, rest) = acc.split_at_mut(lanes);
    let (s1, rest) = rest.split_at_mut(lanes);
    let (s2, s3) = rest.split_at_mut(lanes);
    let chunks = l / 4;
    for c in 0..chunks {
        let base = 4 * c * lanes;
        let (xa, xb) = (&a[base..base + 4 * lanes], &b[base..base + 4 * lanes]);
        for lb in 0..lanes {
            s0[lb] += xa[lb] * xb[lb];
            s1[lb] += xa[lanes + lb] * xb[lanes + lb];
            s2[lb] += xa[2 * lanes + lb] * xb[2 * lanes + lb];
            s3[lb] += xa[3 * lanes + lb] * xb[3 * lanes + lb];
        }
    }
    // Sequential tail, ascending j — `out` doubles as the tail
    // accumulator so the final fold reads `tail` from it.
    for j in 4 * chunks..l {
        let base = j * lanes;
        for lb in 0..lanes {
            out[lb] += a[base + lb] * b[base + lb];
        }
    }
    for lb in 0..lanes {
        out[lb] = (s0[lb] + s1[lb]) + (s2[lb] + s3[lb]) + out[lb];
    }
}

/// Per-lane scale into a fresh target: `y[j*lanes+b] = alpha[b] ·
/// x[j*lanes+b]` (the combine step's unconditional diagonal term,
/// `out[j] = a_kk * psi_k[j]`, replicated per lane).
pub fn lane_scale(alpha: &[f64], x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), y.len(), "lane_scale: length mismatch");
    debug_assert_eq!(alpha.len(), lanes);
    for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
        for lb in 0..lanes {
            yr[lb] = alpha[lb] * xr[lb];
        }
    }
}

/// Per-lane gated accumulate: `y[j*lanes+b] += alpha[b] · x[j*lanes+b]`
/// for every lane with `alpha[b] != 0.0`. The zero-alpha lanes are
/// *skipped*, not multiplied — the scalar loops guard with `if a_lk ==
/// 0.0 { continue }` and a literal `+= 0.0 * x` is not a bitwise no-op
/// (`-0.0 + 0.0` flips the sign bit, `0 · inf` is NaN), so the skip is
/// part of the bit-identity contract.
pub fn lane_axpy(alpha: &[f64], x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), y.len(), "lane_axpy: length mismatch");
    debug_assert_eq!(alpha.len(), lanes);
    let all_live = alpha.iter().all(|&a| a != 0.0);
    if all_live {
        for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
            for lb in 0..lanes {
                yr[lb] += alpha[lb] * xr[lb];
            }
        }
    } else {
        for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
            for lb in 0..lanes {
                if alpha[lb] != 0.0 {
                    yr[lb] += alpha[lb] * xr[lb];
                }
            }
        }
    }
}

/// Per-lane fused gradient accumulate:
/// `y[j*lanes+b] += alpha[b] · x[j*lanes+b] · e[b]`
/// with the scalar left-associated order `((alpha · x) · e)` — the adapt
/// step's `psi_k[j] += mu_k * c_lk * ul[j] * e` shape. Lanes where
/// `gate[b] == 0.0` are skipped (the scalar `if c_lk == 0.0 { continue }`
/// guard); pass `gate = alpha` when the weight itself is the gate, or a
/// gate of all-ones semantics via `gated = false` call sites using
/// [`lane_fused_accum_all`].
pub fn lane_fused_accum(
    gate: &[f64],
    alpha: &[f64],
    e: &[f64],
    x: &[f64],
    y: &mut [f64],
    lanes: usize,
) {
    assert_eq!(x.len(), y.len(), "lane_fused_accum: length mismatch");
    debug_assert_eq!(alpha.len(), lanes);
    debug_assert_eq!(e.len(), lanes);
    let all_live = gate.iter().all(|&g| g != 0.0);
    if all_live {
        for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
            for lb in 0..lanes {
                yr[lb] += alpha[lb] * xr[lb] * e[lb];
            }
        }
    } else {
        for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
            for lb in 0..lanes {
                if gate[lb] != 0.0 {
                    yr[lb] += alpha[lb] * xr[lb] * e[lb];
                }
            }
        }
    }
}

/// Ungated [`lane_fused_accum`]: every lane accumulates (the self-
/// gradient term `psi_k[j] += mu_k * c_kk * uk[j] * e_k`, which the
/// scalar loop applies unconditionally — even a zero diagonal is added).
pub fn lane_fused_accum_all(alpha: &[f64], e: &[f64], x: &[f64], y: &mut [f64], lanes: usize) {
    assert_eq!(x.len(), y.len(), "lane_fused_accum_all: length mismatch");
    debug_assert_eq!(alpha.len(), lanes);
    debug_assert_eq!(e.len(), lanes);
    for (xr, yr) in x.chunks_exact(lanes).zip(y.chunks_exact_mut(lanes)) {
        for lb in 0..lanes {
            yr[lb] += alpha[lb] * xr[lb] * e[lb];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        for n in [0usize, 1, 3, 4, 5, 8, 17] {
            let a: Vec<f64> = (0..n).map(|i| 0.3 * i as f64 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.7 - 0.2 * i as f64).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 16, 21] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
            let mut y: Vec<f64> = (0..n).map(|i| 1.0 - i as f64).collect();
            let mut want = y.clone();
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += -0.7 * xv;
            }
            axpy(-0.7, &x, &mut y);
            assert_eq!(y, want, "n={n}");
        }
    }

    /// Pack per-lane vectors `vs[b]` into one lane-major SoA buffer.
    fn pack(vs: &[Vec<f64>]) -> Vec<f64> {
        let lanes = vs.len();
        let l = vs[0].len();
        let mut soa = vec![0.0; l * lanes];
        for (b, v) in vs.iter().enumerate() {
            for (j, &x) in v.iter().enumerate() {
                soa[j * lanes + b] = x;
            }
        }
        soa
    }

    fn lane_vecs(lanes: usize, l: usize, salt: f64) -> Vec<Vec<f64>> {
        (0..lanes)
            .map(|b| {
                (0..l)
                    .map(|j| (0.37 * j as f64 - 1.1) * (1.0 + salt * b as f64))
                    .collect()
            })
            .collect()
    }

    #[test]
    fn lane_dot_bitwise_matches_scalar_dot_per_lane() {
        for lanes in [1usize, 2, 3, 4, 8] {
            for l in [0usize, 1, 3, 4, 5, 8, 17] {
                let avs = lane_vecs(lanes, l, 0.31);
                let bvs = lane_vecs(lanes, l, -0.13);
                let a = pack(&avs);
                let b = pack(&bvs);
                let mut acc = vec![0.0; 4 * lanes];
                let mut out = vec![0.0; lanes];
                lane_dot(&a, &b, lanes, &mut acc, &mut out);
                for lb in 0..lanes {
                    let want = dot(&avs[lb], &bvs[lb]);
                    assert_eq!(out[lb].to_bits(), want.to_bits(), "lanes={lanes} l={l} b={lb}");
                }
            }
        }
    }

    #[test]
    fn lane_axpy_skips_zero_lanes_exactly() {
        let lanes = 4;
        let l = 7;
        let xs = lane_vecs(lanes, l, 0.21);
        let mut ys = lane_vecs(lanes, l, -0.4);
        // Lane 2 gated off; its y must be bitwise untouched even where
        // x holds -0.0 (a multiply-by-zero would flip sign bits).
        let alpha = [0.5, -1.25, 0.0, 2.0];
        let mut x = pack(&xs);
        x[2] = -0.0; // j = 0, lane 2
        let mut y = pack(&ys);
        let before = y.clone();
        lane_axpy(&alpha, &x, &mut y, lanes);
        for (b, a) in alpha.iter().enumerate() {
            for j in 0..l {
                let got = y[j * lanes + b];
                if *a == 0.0 {
                    assert_eq!(got.to_bits(), before[j * lanes + b].to_bits());
                } else {
                    ys[b][j] += a * x[j * lanes + b];
                    assert_eq!(got.to_bits(), ys[b][j].to_bits());
                }
            }
        }
    }

    #[test]
    fn lane_scale_and_fused_accum_match_scalar_shapes() {
        let lanes = 3;
        let l = 5;
        let xs = lane_vecs(lanes, l, 0.7);
        let x = pack(&xs);
        let alpha = [0.25, -0.75, 1.5];
        let mut y = vec![0.0; l * lanes];
        lane_scale(&alpha, &x, &mut y, lanes);
        for b in 0..lanes {
            for j in 0..l {
                assert_eq!(y[j * lanes + b].to_bits(), (alpha[b] * xs[b][j]).to_bits());
            }
        }
        let e = [1.1, -0.2, 0.0];
        let gate = [1.0, 0.0, 1.0];
        let mut z = y.clone();
        lane_fused_accum(&gate, &alpha, &e, &x, &mut z, lanes);
        for b in 0..lanes {
            for j in 0..l {
                let want = if gate[b] != 0.0 {
                    y[j * lanes + b] + alpha[b] * xs[b][j] * e[b]
                } else {
                    y[j * lanes + b]
                };
                assert_eq!(z[j * lanes + b].to_bits(), want.to_bits());
            }
        }
        let mut w = y.clone();
        lane_fused_accum_all(&alpha, &e, &x, &mut w, lanes);
        for b in 0..lanes {
            for j in 0..l {
                let want = y[j * lanes + b] + alpha[b] * xs[b][j] * e[b];
                assert_eq!(w[j * lanes + b].to_bits(), want.to_bits());
            }
        }
    }
}
