//! Chunked scalar kernels shared by the theory engine and the
//! message-level simulator.
//!
//! Both hot paths bottom out in dot products and scaled accumulations
//! over contiguous `f64` slices. The kernels here process four lanes per
//! step with independent partial accumulators, which breaks the
//! loop-carried dependence of a naive fold and lets the compiler keep
//! four FMAs in flight (the slice iterators also guarantee the bounds
//! checks are hoisted). Summation order differs from a sequential fold,
//! so results may differ from a naive loop in the last ulps — every
//! consumer is tolerance-based.

/// Dot product with four independent partial sums.
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len(), "dot: length mismatch");
    let mut s0 = 0.0;
    let mut s1 = 0.0;
    let mut s2 = 0.0;
    let mut s3 = 0.0;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (xa, xb) in (&mut ca).zip(&mut cb) {
        s0 += xa[0] * xb[0];
        s1 += xa[1] * xb[1];
        s2 += xa[2] * xb[2];
        s3 += xa[3] * xb[3];
    }
    let mut tail = 0.0;
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        tail += x * y;
    }
    (s0 + s1) + (s2 + s3) + tail
}

/// `y += alpha * x`, four lanes per step, no allocation.
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len(), "axpy: length mismatch");
    let mut cx = x.chunks_exact(4);
    let mut cy = y.chunks_exact_mut(4);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        ys[0] += alpha * xs[0];
        ys[1] += alpha * xs[1];
        ys[2] += alpha * xs[2];
        ys[3] += alpha * xs[3];
    }
    for (x, y) in cx.remainder().iter().zip(cy.into_remainder()) {
        *y += alpha * x;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        for n in [0usize, 1, 3, 4, 5, 8, 17] {
            let a: Vec<f64> = (0..n).map(|i| 0.3 * i as f64 - 1.0).collect();
            let b: Vec<f64> = (0..n).map(|i| 1.7 - 0.2 * i as f64).collect();
            let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            assert!((dot(&a, &b) - naive).abs() < 1e-12 * (1.0 + naive.abs()), "n={n}");
        }
    }

    #[test]
    fn axpy_matches_naive() {
        for n in [0usize, 1, 3, 4, 7, 16, 21] {
            let x: Vec<f64> = (0..n).map(|i| i as f64 * 0.5).collect();
            let mut y: Vec<f64> = (0..n).map(|i| 1.0 - i as f64).collect();
            let mut want = y.clone();
            for (w, xv) in want.iter_mut().zip(&x) {
                *w += -0.7 * xv;
            }
            axpy(-0.7, &x, &mut y);
            assert_eq!(y, want, "n={n}");
        }
    }
}
