//! Row-major dense f64 matrix.

use super::kernels;
use std::fmt;
use std::ops::{Add, AddAssign, Index, IndexMut, Mul, Neg, Sub, SubAssign};

/// Row-major dense matrix of f64.
#[derive(Clone, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Self::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// All-ones matrix (the paper's 1_{LL}).
    pub fn ones(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![1.0; rows * cols] }
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |row| row.len());
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Self { rows: r, cols: c, data }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        Self { rows, cols, data }
    }

    /// Column vector from a slice.
    pub fn col_vec(v: &[f64]) -> Self {
        Self { rows: v.len(), cols: 1, data: v.to_vec() }
    }

    /// Diagonal matrix from a slice.
    pub fn diag(v: &[f64]) -> Self {
        let mut m = Self::zeros(v.len(), v.len());
        for (i, &x) in v.iter().enumerate() {
            m[(i, i)] = x;
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        self.transpose_into(&mut out);
        out
    }

    /// Transpose into a preallocated output (allocation-free hot path).
    /// `out` must not alias `self`.
    pub fn transpose_into(&self, out: &mut Mat) {
        assert_eq!((out.rows, out.cols), (self.cols, self.rows));
        for i in 0..self.rows {
            let row = &self.data[i * self.cols..(i + 1) * self.cols];
            for (j, &v) in row.iter().enumerate() {
                out.data[j * self.rows + i] = v;
            }
        }
    }

    pub fn trace(&self) -> f64 {
        assert!(self.is_square());
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    pub fn scale(&self, s: f64) -> Mat {
        let mut out = self.clone();
        out.data.iter_mut().for_each(|x| *x *= s);
        out
    }

    pub fn scale_in_place(&mut self, s: f64) {
        self.data.iter_mut().for_each(|x| *x *= s);
    }

    /// `self += s * other` without allocating.
    pub fn axpy(&mut self, s: f64, other: &Mat) {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        kernels::axpy(s, &other.data, &mut self.data);
    }

    /// Matrix product into a preallocated output (the hot path of the
    /// theory engine). `out` must not alias either operand.
    ///
    /// i-k-j loop order (streams rhs rows, accumulates into out rows),
    /// unrolled four k-rows deep so each pass over the output row feeds
    /// four multiply-adds, with a skip for all-zero coefficient blocks
    /// (𝓑 is sparse: ~N·deg·L of (NL)² entries are nonzero).
    pub fn mul_into(&self, rhs: &Mat, out: &mut Mat) {
        assert_eq!(self.cols, rhs.rows, "dim mismatch {}x{} * {}x{}",
                   self.rows, self.cols, rhs.rows, rhs.cols);
        assert_eq!((out.rows, out.cols), (self.rows, rhs.cols));
        let n = rhs.cols;
        out.data.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..self.rows {
            let arow = &self.data[i * self.cols..(i + 1) * self.cols];
            let orow = &mut out.data[i * n..(i + 1) * n];
            let mut k = 0;
            while k + 4 <= self.cols {
                let (a0, a1, a2, a3) = (arow[k], arow[k + 1], arow[k + 2], arow[k + 3]);
                if a0 != 0.0 || a1 != 0.0 || a2 != 0.0 || a3 != 0.0 {
                    let b0 = &rhs.data[k * n..(k + 1) * n];
                    let b1 = &rhs.data[(k + 1) * n..(k + 2) * n];
                    let b2 = &rhs.data[(k + 2) * n..(k + 3) * n];
                    let b3 = &rhs.data[(k + 3) * n..(k + 4) * n];
                    for ((((o, &x0), &x1), &x2), &x3) in
                        orow.iter_mut().zip(b0).zip(b1).zip(b2).zip(b3)
                    {
                        *o += a0 * x0 + a1 * x1 + a2 * x2 + a3 * x3;
                    }
                }
                k += 4;
            }
            while k < self.cols {
                let a = arow[k];
                if a != 0.0 {
                    let brow = &rhs.data[k * n..(k + 1) * n];
                    for (o, &b) in orow.iter_mut().zip(brow.iter()) {
                        *o += a * b;
                    }
                }
                k += 1;
            }
        }
    }

    /// Quadratic form xᵀ M y.
    pub fn quad_form(&self, x: &[f64], y: &[f64]) -> f64 {
        assert_eq!(x.len(), self.rows);
        assert_eq!(y.len(), self.cols);
        let mut total = 0.0;
        for (i, &xi) in x.iter().enumerate() {
            total += xi * kernels::dot(self.row(i), y);
        }
        total
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols);
        (0..self.rows).map(|i| kernels::dot(self.row(i), x)).collect()
    }

    /// Max |entry| — used for convergence checks.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|&x| x * x).sum::<f64>().sqrt()
    }

    /// Extract the (bi, bj) block of size (br, bc).
    pub fn block(&self, bi: usize, bj: usize, br: usize, bc: usize) -> Mat {
        let mut out = Mat::zeros(br, bc);
        for i in 0..br {
            for j in 0..bc {
                out[(i, j)] = self[(bi * br + i, bj * bc + j)];
            }
        }
        out
    }

    /// Overwrite the (bi, bj) block (of `blk`'s size) with `blk`.
    pub fn set_block(&mut self, bi: usize, bj: usize, blk: &Mat) {
        for i in 0..blk.rows {
            for j in 0..blk.cols {
                self[(bi * blk.rows + i, bj * blk.cols + j)] = blk[(i, j)];
            }
        }
    }

    /// Symmetrize: (M + Mᵀ)/2 — guards against numerical asymmetry drift.
    pub fn symmetrized(&self) -> Mat {
        assert!(self.is_square());
        let mut out = Mat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(i, j)] = 0.5 * (self[(i, j)] + self[(j, i)]);
            }
        }
        out
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;

    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

impl Mul<&Mat> for &Mat {
    type Output = Mat;

    fn mul(self, rhs: &Mat) -> Mat {
        let mut out = Mat::zeros(self.rows, rhs.cols);
        self.mul_into(rhs, &mut out);
        out
    }
}

impl Add<&Mat> for &Mat {
    type Output = Mat;

    fn add(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        out.axpy(1.0, rhs);
        out
    }
}

impl Sub<&Mat> for &Mat {
    type Output = Mat;

    fn sub(self, rhs: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (rhs.rows, rhs.cols));
        let mut out = self.clone();
        out.axpy(-1.0, rhs);
        out
    }
}

impl Neg for &Mat {
    type Output = Mat;

    fn neg(self) -> Mat {
        self.scale(-1.0)
    }
}

impl AddAssign<&Mat> for Mat {
    fn add_assign(&mut self, rhs: &Mat) {
        self.axpy(1.0, rhs);
    }
}

impl SubAssign<&Mat> for Mat {
    fn sub_assign(&mut self, rhs: &Mat) {
        self.axpy(-1.0, rhs);
    }
}

impl fmt::Debug for Mat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "Mat {}x{} [", self.rows, self.cols)?;
        for i in 0..self.rows.min(8) {
            write!(f, "  ")?;
            for j in 0..self.cols.min(8) {
                write!(f, "{:10.4} ", self[(i, j)])?;
            }
            writeln!(f, "{}", if self.cols > 8 { "…" } else { "" })?;
        }
        if self.rows > 8 {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_ops() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = &a * &b;
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
        assert_eq!(a.trace(), 5.0);
        assert_eq!(a.transpose(), Mat::from_rows(&[&[1.0, 3.0], &[2.0, 4.0]]));
        let s = &a + &b;
        assert_eq!(s[(0, 0)], 6.0);
        let d = &b - &a;
        assert_eq!(d[(1, 1)], 4.0);
    }

    #[test]
    fn identity_is_neutral() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let i = Mat::eye(2);
        assert_eq!(&a * &i, a);
        assert_eq!(&i * &a, a);
    }

    #[test]
    fn quad_form_matches_explicit() {
        let m = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let x = [1.0, 2.0];
        // xᵀ M x = 2 + 1*2 + 2*1 + 3*4 = 18
        assert_eq!(m.quad_form(&x, &x), 18.0);
    }

    #[test]
    fn blocks_roundtrip() {
        let mut m = Mat::zeros(4, 4);
        let b = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        m.set_block(1, 0, &b);
        assert_eq!(m.block(1, 0, 2, 2), b);
        assert_eq!(m.block(0, 1, 2, 2), Mat::zeros(2, 2));
    }

    #[test]
    fn matvec_and_axpy() {
        let m = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(m.matvec(&[1.0, 1.0]), vec![3.0, 7.0]);
        let mut a = Mat::eye(2);
        a.axpy(2.0, &m);
        assert_eq!(a[(0, 0)], 3.0);
        assert_eq!(a[(0, 1)], 4.0);
    }
}
