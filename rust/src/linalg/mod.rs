//! Dense linear-algebra substrate (f64).
//!
//! `nalgebra`/`ndarray` are unavailable offline (DESIGN.md §2, S1); the
//! theory engine (eqs. (31), (38)–(39), (45)–(68) of the paper) needs
//! dense matrices with Kronecker/Hadamard/block structure and symmetric
//! eigenvalues, all provided here. Sizes are modest (≤ NL = 500 for the
//! theory path), so clarity beats BLAS trickery — but the multiply is
//! still cache-blocked and allocation-free in the hot loop.

mod eig;
pub mod kernels;
mod mat;
mod ops;
mod sparse;

pub use eig::{jacobi_eigenvalues, power_iteration_sym, spectral_radius};
pub(crate) use eig::power_radius_with;
pub use mat::Mat;
pub use ops::{block_diag, hadamard, kron, vec_of, unvec};
pub use sparse::SparseMat;
