//! `dcd-lms` — launcher CLI for the DCD reproduction.
//!
//! ```text
//! dcd-lms exp1 [--engine rust|xla] [--runs N] [--iters N] [--out DIR] ...
//! dcd-lms exp2 [--engine rust|xla] ...
//! dcd-lms exp3 [--fast] ...
//! dcd-lms exp4 [--name SCENARIO] [--values P1,P2,...]  # theory vs sim, lossy links
//! dcd-lms scenario list                     # built-in scenario registry
//! dcd-lms scenario run --name NAME [...]    # one declarative scenario
//! dcd-lms scenario sweep --name NAME --key K --values V1,V2,...
//! dcd-lms frontier --name NAME [--axis k=v1,v2]...  # comm-cost-vs-MSD Pareto frontier
//! dcd-lms theory  --m M --m-grad MG [--drop-prob P] [...]  # stability + steady state
//! dcd-lms serve [--listen HOST:PORT] [--cache DIR]  # resident daemon + result cache
//! dcd-lms scenario run --name NAME --via HOST:PORT  # submit to a resident daemon
//! dcd-lms validate                          # rust engine ≡ xla engine
//! dcd-lms info                              # artifact manifest
//! ```
//!
//! `exp1..exp4` and `scenario run|sweep` accept `--shards N` to fan the
//! Monte-Carlo realizations across N worker processes (`shard-worker`,
//! a hidden subcommand of this same binary) with bit-identical results
//! — see DESIGN.md §8 and docs/HANDBOOK.md. `exp1`, `exp2` and
//! `scenario run|sweep` additionally accept `--lanes auto|N` to batch
//! runs through the SoA lane engine (DESIGN.md §14) — again
//! bit-identical, at any lanes × threads × shards layout.

use anyhow::{anyhow, Result};
use dcd_lms::cli::{App, Command, ParsedArgs};
use dcd_lms::config::{Exp1Config, Exp2Config, Exp3Config, IniDoc};
use dcd_lms::coordinator::impairments::{DropModel, Gating, LinkImpairments};
use dcd_lms::coordinator::LaneCount;
use dcd_lms::experiments::{run_exp1, run_exp2, run_exp3, run_exp4, Engine, Exp4Config};
use dcd_lms::linalg::Mat;
use dcd_lms::metrics::to_db;
use dcd_lms::rng::Pcg64;
use dcd_lms::runtime::Runtime;
use dcd_lms::theory::{ImpairedMsdModel, MeanModel, MsdModel, TheorySetup};
use dcd_lms::topology::{combination_matrix, Graph, Rule};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let app = build_app();
    match app.dispatch(&argv) {
        Err(help) => {
            println!("{help}");
        }
        Ok((cmd, args)) => {
            if let Err(e) = run(cmd.name, &args) {
                eprintln!("error: {e:#}");
                std::process::exit(1);
            }
        }
    }
}

fn build_app() -> App {
    let common = |c: Command| {
        c.opt("config", "INI config file with [exp*] sections")
            .opt_repeated("set", "override: section.key=value")
            .opt("out", "output directory for CSV/JSON results (default results/)")
            .flag("fast", "shrunk workload (smoke runs)")
            .flag("quiet", "suppress progress output")
    };
    App {
        name: "dcd-lms",
        about: "doubly-compressed diffusion LMS over adaptive networks (Harrane, Flamary, Richard)",
        commands: vec![
            common(
                Command::new("exp1", "Fig. 3 left: theory vs simulation, 10-node network")
                    .opt("engine", "rust|xla (default rust)")
                    .opt("runs", "Monte-Carlo runs")
                    .opt("iters", "iterations per run")
                    .opt("shards", "worker processes for the MC runs (default 1)")
                    .opt("lanes", "SoA runs per lane block: auto|N (default 1; bit-identical)"),
            ),
            common(
                Command::new("exp2", "Fig. 3 center/right: MSD vs compression ratio, N=50 L=50")
                    .opt("engine", "rust|xla (default xla)")
                    .opt("runs", "Monte-Carlo runs")
                    .opt("iters", "iterations per run")
                    .opt("shards", "worker processes per sweep point (rust engine)")
                    .opt("lanes", "SoA runs per lane block: auto|N (default 1; bit-identical)"),
            ),
            common(
                Command::new("exp3", "Fig. 4: energy-harvesting WSN, N=80 L=40")
                    .opt("runs", "Monte-Carlo runs")
                    .opt("duration", "virtual-time horizon (s)")
                    .opt("shards", "worker processes for the WSN realizations (default 1)")
                    .opt("lanes", "rejected: the event-driven WSN engine is not run-batched")
                    .flag(
                        "ledger-csv",
                        "also write exp3_ledger.csv (per-node energy/comm breakdown)",
                    ),
            ),
            common(
                Command::new(
                    "exp4",
                    "theory vs simulation under impaired links (drop-probability sweep)",
                )
                .opt("name", "base scenario, must be theory-anchored (default lossy-geometric)")
                .opt("values", "comma-separated drop probabilities to sweep")
                .opt("runs", "Monte-Carlo runs per point (default: scenario schedule)")
                .opt("iters", "iterations per realization (default: scenario schedule)")
                .opt("seed", "master seed override")
                .opt("shards", "worker processes per sweep point (default 1)"),
            ),
            common(
                Command::new(
                    "scenario",
                    "declarative scenarios (impaired/async networks): list | run | sweep",
                )
                .opt("name", "registry scenario name (see `scenario list`)")
                .opt("seed", "override the scenario seed")
                .opt("runs", "override Monte-Carlo runs")
                .opt("iters", "override iterations per run")
                .opt("threads", "worker threads (0 = auto)")
                .opt("shards", "worker processes (default 1; bit-identical results)")
                .opt("lanes", "SoA runs per lane block: auto|N (default 1; bit-identical)")
                .opt("key", "sweep: dotted scenario key, e.g. impairments.drop_prob")
                .opt("values", "sweep: comma-separated values for --key")
                .opt("via", "run: submit to a resident serve daemon at HOST:PORT"),
            ),
            common(
                Command::new(
                    "frontier",
                    "map the comm-cost-vs-MSD Pareto frontier of one scenario (DESIGN.md §13)",
                )
                .opt("name", "base scenario from the registry (see `scenario list`)")
                .opt("seed", "override the scenario seed")
                .opt("runs", "override Monte-Carlo runs per grid point")
                .opt("iters", "override iterations per run")
                .opt("threads", "worker threads (0 = auto)")
                .opt("shards", "worker processes (default 1; bit-identical results)")
                .opt_repeated(
                    "axis",
                    "swept policy axis dotted.key=v1,v2,... (repeatable; \
                     default: gating x quantization [x DCD m])",
                ),
            ),
            Command::new(
                "serve",
                "resident scenario service with a content-addressed result cache",
            )
            .opt("listen", "HOST:PORT to listen on (default: one session on stdin/stdout)")
            .opt("stop", "drain and stop the daemon at HOST:PORT, then exit")
            .opt("cache", "result-cache root directory (default serve-cache/)")
            .opt("workers", "worker threads draining the job queue (default 2)")
            .opt("queue-depth", "max queued jobs before submits are refused (default 64)")
            .opt("cache-max-entries", "FIFO cache eviction bound (default 0 = unlimited)"),
            Command::new("theory", "stability bounds + theoretical steady state")
                .opt("n", "nodes (default 10)")
                .opt("dim", "dimension L (default 5)")
                .opt("m", "shared estimate entries M (default 3)")
                .opt("m-grad", "shared gradient entries M_grad (default 1)")
                .opt("mu", "step size (default 1e-3)")
                .opt("iters", "trajectory length (default 20000)")
                .opt("drop-prob", "per-link drop probability for the impaired model (default 0)")
                .opt("gate-prob", "per-node transmit probability (default: always on)")
                .opt("quant-step", "quantizer step for the impaired noise floor (default 0)"),
            Command::new("validate", "drive rust and xla engines with identical inputs")
                .opt("config", "artifact shape config (default smoke)"),
            Command::new("info", "print artifact manifest and build info"),
            // Internal: the child-process half of --shards (DESIGN.md §8).
            // Speaks the versioned JSON frame protocol on stdin/stdout;
            // never invoked by hand, so it stays out of the help text.
            Command::new(
                "shard-worker",
                "internal: execute one shard of a Monte-Carlo job (frame protocol on stdio)",
            )
            .hide(),
        ],
    }
}

/// Parse `--shards`, rejecting the nonsensical 0 up front (a negative
/// value is already a usize parse error with the offending text).
fn parse_shards(args: &ParsedArgs) -> Result<Option<usize>> {
    match args.get_parse::<usize>("shards").map_err(anyhow::Error::msg)? {
        Some(0) => Err(anyhow!(
            "--shards 0: need at least one worker process (1 = in-process; \
             there is no process-count auto mode)"
        )),
        other => Ok(other),
    }
}

/// Parse `--lanes` through [`LaneCount`]'s own parser, so the CLI, the
/// INI layer and the scenario validator reject `0`, negatives and
/// overflow with one message (same style as [`parse_shards`]).
fn parse_lanes(args: &ParsedArgs) -> Result<Option<LaneCount>> {
    match args.get("lanes") {
        None => Ok(None),
        Some(v) => v
            .parse::<LaneCount>()
            .map(Some)
            .map_err(|e| anyhow!("--{e}")),
    }
}

fn load_overrides(args: &ParsedArgs) -> Result<IniDoc> {
    let mut doc = match args.get("config") {
        Some(path) => IniDoc::load(path).map_err(anyhow::Error::msg)?,
        None => IniDoc::default(),
    };
    for s in args.get_all("set") {
        doc.set_dotted(s).map_err(anyhow::Error::msg)?;
    }
    Ok(doc)
}

fn out_dir(args: &ParsedArgs) -> String {
    args.get("out").unwrap_or("results").to_string()
}

fn run(cmd: &str, args: &ParsedArgs) -> Result<()> {
    match cmd {
        "exp1" => {
            let doc = load_overrides(args)?;
            let mut cfg = Exp1Config::default();
            cfg.apply(&doc).map_err(anyhow::Error::msg)?;
            if args.flag("fast") {
                cfg.runs = 10;
                cfg.iters = 6_000;
                cfg.mu = 5e-3;
            }
            if let Some(r) = args.get_parse::<usize>("runs").map_err(anyhow::Error::msg)? {
                cfg.runs = r;
            }
            if let Some(i) = args.get_parse::<usize>("iters").map_err(anyhow::Error::msg)? {
                cfg.iters = i;
            }
            if let Some(s) = parse_shards(args)? {
                cfg.shards = s;
            }
            if let Some(l) = parse_lanes(args)? {
                cfg.lanes = l;
            }
            let engine: Engine = args
                .get("engine")
                .unwrap_or("rust")
                .parse()
                .map_err(anyhow::Error::msg)?;
            run_exp1(&cfg, engine, Some(&out_dir(args)), args.flag("quiet"))?;
            Ok(())
        }
        "exp2" => {
            let doc = load_overrides(args)?;
            let mut cfg = Exp2Config::default();
            cfg.apply(&doc).map_err(anyhow::Error::msg)?;
            if args.flag("fast") {
                cfg.runs = 3;
                cfg.iters = 600;
                cfg.cd_m_values = vec![35, 15, 5];
                cfg.dcd_pairs = vec![(25, 25), (5, 5), (2, 2)];
            }
            if let Some(r) = args.get_parse::<usize>("runs").map_err(anyhow::Error::msg)? {
                cfg.runs = r;
            }
            if let Some(i) = args.get_parse::<usize>("iters").map_err(anyhow::Error::msg)? {
                cfg.iters = i;
            }
            if let Some(s) = parse_shards(args)? {
                cfg.shards = s;
            }
            if let Some(l) = parse_lanes(args)? {
                cfg.lanes = l;
            }
            let engine: Engine = args
                .get("engine")
                .unwrap_or("xla")
                .parse()
                .map_err(anyhow::Error::msg)?;
            run_exp2(&cfg, engine, Some(&out_dir(args)), args.flag("quiet"))?;
            Ok(())
        }
        "exp3" => {
            let doc = load_overrides(args)?;
            let mut cfg = Exp3Config::default();
            cfg.apply(&doc).map_err(anyhow::Error::msg)?;
            if args.flag("fast") {
                cfg.n_nodes = 24;
                cfg.dim = 16;
                cfg.radius = 0.32;
                cfg.duration = 30_000.0;
                cfg.sample_dt = 600.0;
                cfg.runs = 2;
                cfg.cd_m = 10;
            }
            if let Some(r) = args.get_parse::<usize>("runs").map_err(anyhow::Error::msg)? {
                cfg.runs = r;
            }
            if let Some(d) = args.get_parse::<f64>("duration").map_err(anyhow::Error::msg)? {
                cfg.duration = d;
            }
            if let Some(s) = parse_shards(args)? {
                cfg.shards = s;
            }
            if args.get("lanes").is_some() {
                return Err(anyhow!(
                    "exp3: --lanes applies to the synchronous-round engine; \
                     the event-driven WSN scheduler is not run-batched"
                ));
            }
            cfg.ledger_csv = args.flag("ledger-csv");
            run_exp3(&cfg, Some(&out_dir(args)), args.flag("quiet"))?;
            Ok(())
        }
        "exp4" => {
            let mut cfg = Exp4Config::default();
            if let Some(name) = args.get("name") {
                cfg.scenario = name.to_string();
            }
            if args.flag("fast") {
                cfg.drop_probs = vec![0.0, 0.1, 0.3];
                cfg.runs = 3;
                cfg.iters = 800;
            }
            if let Some(values) = args.get("values") {
                cfg.drop_probs = values
                    .split(',')
                    .map(|v| v.trim())
                    .filter(|v| !v.is_empty())
                    .map(|v| {
                        v.parse::<f64>()
                            .map_err(|e| anyhow!("exp4 --values {v:?}: {e}"))
                    })
                    .collect::<Result<Vec<f64>>>()?;
            }
            if let Some(r) = args.get_parse::<usize>("runs").map_err(anyhow::Error::msg)? {
                cfg.runs = r;
            }
            if let Some(i) = args.get_parse::<usize>("iters").map_err(anyhow::Error::msg)? {
                cfg.iters = i;
            }
            cfg.seed = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)?;
            if let Some(s) = parse_shards(args)? {
                cfg.shards = s;
            }
            run_exp4(&cfg, Some(&out_dir(args)), args.flag("quiet"))?;
            Ok(())
        }
        "scenario" => cmd_scenario(args),
        "frontier" => cmd_frontier(args),
        "serve" => cmd_serve(args),
        "shard-worker" => dcd_lms::shard::worker_main().map_err(|e| anyhow!(e)),
        "theory" => cmd_theory(args),
        "validate" => cmd_validate(args),
        "info" => cmd_info(),
        other => Err(anyhow!("unhandled command {other}")),
    }
}

/// Resolve the scenario a `scenario run`/`scenario sweep` invocation
/// addresses: registry preset or `--config` file, then `--set` dotted
/// overrides through the INI layer, then the CLI convenience flags.
fn resolve_scenario(args: &ParsedArgs) -> Result<dcd_lms::scenario::Scenario> {
    let mut doc = match args.get("config") {
        Some(path) => IniDoc::load(path).map_err(anyhow::Error::msg)?,
        None => {
            let name = args
                .get("name")
                .ok_or_else(|| anyhow!("scenario: --name <scenario> or --config <file> required"))?;
            let base = dcd_lms::scenario::find(name).ok_or_else(|| {
                anyhow!("unknown scenario {name:?} (run `scenario list` for the registry)")
            })?;
            IniDoc::parse(&base.to_ini_string()).map_err(anyhow::Error::msg)?
        }
    };
    for s in args.get_all("set") {
        // Unknown keys are rejected up front: the INI layer itself is
        // schemaless and a typo would otherwise silently change nothing.
        let path = s.split('=').next().unwrap_or("").trim();
        dcd_lms::scenario::Scenario::check_key(path).map_err(anyhow::Error::msg)?;
        doc.set_dotted(s).map_err(anyhow::Error::msg)?;
    }
    let mut sc = dcd_lms::scenario::Scenario::from_ini(&doc).map_err(anyhow::Error::msg)?;
    if args.flag("fast") {
        sc.runs = 3;
        sc.iters = 800;
        sc.record_every = 1;
        if matches!(sc.mode, dcd_lms::scenario::ScheduleMode::Wsn { .. }) {
            // Shrink the virtual-time horizon too (iters is unused
            // under the event-driven schedule).
            sc.mode = dcd_lms::scenario::ScheduleMode::Wsn {
                duration: 20_000.0,
                sample_dt: 500.0,
            };
        }
    }
    if let Some(v) = args.get_parse::<u64>("seed").map_err(anyhow::Error::msg)? {
        sc.seed = v;
    }
    if let Some(v) = args.get_parse::<usize>("runs").map_err(anyhow::Error::msg)? {
        sc.runs = v;
    }
    if let Some(v) = args.get_parse::<usize>("iters").map_err(anyhow::Error::msg)? {
        sc.iters = v;
    }
    if let Some(v) = args.get_parse::<usize>("threads").map_err(anyhow::Error::msg)? {
        sc.threads = v;
    }
    if let Some(v) = parse_shards(args)? {
        sc.shards = v;
    }
    if let Some(v) = parse_lanes(args)? {
        sc.lanes = v;
    }
    sc.validate().map_err(anyhow::Error::msg)?;
    Ok(sc)
}

fn cmd_scenario(args: &ParsedArgs) -> Result<()> {
    let action = args.positional.first().map(String::as_str).unwrap_or("list");
    match action {
        "list" => {
            println!("{:<22} {}", "name", "description");
            println!("{}", "-".repeat(78));
            for sc in dcd_lms::scenario::builtins() {
                println!("{:<22} {}", sc.name, sc.description);
            }
            println!(
                "\nrun one with `scenario run --name <name>`; \
                 sweep a knob with `scenario sweep --name <name> --key <k> --values a,b,c`"
            );
            Ok(())
        }
        "run" => {
            let sc = resolve_scenario(args)?;
            if let Some(addr) = args.get("via") {
                // Hand the run to a resident daemon; artifacts come
                // back inline and land in --out byte-identical to a
                // local run (DESIGN.md §11).
                dcd_lms::serve::run_via(addr, &sc, Some(&out_dir(args)), args.flag("quiet"))
                    .map_err(anyhow::Error::msg)?;
                return Ok(());
            }
            dcd_lms::scenario::run_scenario(&sc, Some(&out_dir(args)), args.flag("quiet"))
                .map_err(anyhow::Error::msg)?;
            Ok(())
        }
        "sweep" => {
            let sc = resolve_scenario(args)?;
            let key = args
                .get("key")
                .ok_or_else(|| anyhow!("scenario sweep: --key <dotted.key> required"))?;
            let values: Vec<String> = args
                .get("values")
                .ok_or_else(|| anyhow!("scenario sweep: --values v1,v2,... required"))?
                .split(',')
                .map(|v| v.trim().to_string())
                .filter(|v| !v.is_empty())
                .collect();
            dcd_lms::scenario::sweep_scenario(
                &sc,
                key,
                &values,
                Some(&out_dir(args)),
                args.flag("quiet"),
            )
            .map_err(anyhow::Error::msg)?;
            Ok(())
        }
        other => Err(anyhow!(
            "unknown scenario action {other:?} (expected list | run | sweep)"
        )),
    }
}

/// `dcd-lms frontier`: sweep the policy grid of one scenario and write
/// the dominated-point-pruned Pareto table (DESIGN.md §13).
fn cmd_frontier(args: &ParsedArgs) -> Result<()> {
    let sc = resolve_scenario(args)?;
    let axis_specs = args.get_all("axis");
    let axes: Vec<dcd_lms::scenario::FrontierAxis> = if axis_specs.is_empty() {
        dcd_lms::scenario::default_axes(&sc)
    } else {
        axis_specs
            .iter()
            .map(|s| dcd_lms::scenario::FrontierAxis::parse(s).map_err(anyhow::Error::msg))
            .collect::<Result<Vec<_>>>()?
    };
    dcd_lms::scenario::frontier_scenario(&sc, &axes, Some(&out_dir(args)), args.flag("quiet"))
        .map_err(anyhow::Error::msg)?;
    Ok(())
}

/// `dcd-lms serve`: run a resident daemon (stdio or TCP), or stop one.
fn cmd_serve(args: &ParsedArgs) -> Result<()> {
    if let Some(addr) = args.get("stop") {
        return dcd_lms::serve::stop_via(addr).map_err(anyhow::Error::msg);
    }
    let cfg = dcd_lms::serve::ServeConfig {
        cache_dir: args.get("cache").unwrap_or("serve-cache").to_string(),
        workers: args.get_or("workers", 2).map_err(anyhow::Error::msg)?,
        queue_depth: args.get_or("queue-depth", 64).map_err(anyhow::Error::msg)?,
        max_entries: args.get_or("cache-max-entries", 0).map_err(anyhow::Error::msg)?,
    };
    match args.get("listen") {
        Some(addr) => dcd_lms::serve::serve_tcp(&cfg, addr).map_err(anyhow::Error::msg),
        None => dcd_lms::serve::serve_stdio(&cfg).map_err(anyhow::Error::msg),
    }
}

fn cmd_theory(args: &ParsedArgs) -> Result<()> {
    let n: usize = args.get_or("n", 10).map_err(anyhow::Error::msg)?;
    let dim: usize = args.get_or("dim", 5).map_err(anyhow::Error::msg)?;
    let m: usize = args.get_or("m", 3).map_err(anyhow::Error::msg)?;
    let m_grad: usize = args.get_or("m-grad", 1).map_err(anyhow::Error::msg)?;
    let mu: f64 = args.get_or("mu", 1e-3).map_err(anyhow::Error::msg)?;
    let iters: usize = args.get_or("iters", 20_000).map_err(anyhow::Error::msg)?;

    let graph = if n == 10 { Graph::paper_ten_node() } else { Graph::ring(n, 2) };
    let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
    let mut rng = Pcg64::new(2017, 0);
    let model = dcd_lms::datamodel::DataModel::paper(n, dim, 0.8, 1.2, 1e-3, &mut rng);
    let setup = TheorySetup {
        n_nodes: n,
        dim,
        m,
        m_grad,
        c,
        mu: vec![mu; n],
        sigma_u2: model.sigma_u2.clone(),
        sigma_v2: model.sigma_v2.clone(),
    };
    setup.validate().map_err(anyhow::Error::msg)?;
    let mean = MeanModel::new(setup.clone());
    println!("network: N={n} L={dim} M={m} M∇={m_grad} μ={mu}");
    println!(
        "compression ratio 2L/(M+M∇) = {:.3}",
        2.0 * dim as f64 / (m + m_grad) as f64
    );
    println!("ρ(𝓑) = {:.6}  (mean-stable: {})", mean.rho(), mean.is_mean_stable());
    let bounds = mean.paper_mu_bounds();
    let min_bound = bounds.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("paper step-size bound (38)-(39): μ < {min_bound:.4} (tightest node)");
    let msd = MsdModel::new(setup.clone());
    let (ss, used) = msd.steady_state(&model.wo, 1e-10, iters);
    println!(
        "theoretical steady-state MSD: {:.2} dB (converged in {used} iterations)",
        to_db(ss)
    );

    // Impaired-link model (DESIGN.md §7) when any impairment knob is set.
    let drop_prob: f64 = args.get_or("drop-prob", 0.0).map_err(anyhow::Error::msg)?;
    let gate_prob = args.get_parse::<f64>("gate-prob").map_err(anyhow::Error::msg)?;
    let quant_step: f64 = args.get_or("quant-step", 0.0).map_err(anyhow::Error::msg)?;
    // `!= 0.0` (not `> 0.0`) so negative typos reach validate() and
    // error instead of silently printing only the ideal numbers.
    if drop_prob != 0.0 || gate_prob.is_some() || quant_step != 0.0 {
        let imp = LinkImpairments {
            drop: DropModel::Iid(drop_prob),
            gating: match gate_prob {
                Some(p) => Gating::Probabilistic(p),
                None => Gating::Always,
            },
            quant_step,
            per_leg: false,
        };
        let impaired = ImpairedMsdModel::new(setup, &imp).map_err(anyhow::Error::msg)?;
        println!(
            "impaired links [drop {} gate {} quant {}]:",
            imp.drop, imp.gating, imp.quant_step
        );
        println!(
            "  ρ(𝓑̄) = {:.6}  (mean-stable: {})",
            impaired.mean_rho(),
            impaired.is_mean_stable()
        );
        let (ss_i, used_i) = impaired.steady_state(&model.wo, 1e-10, iters);
        println!(
            "  steady-state MSD: {:.2} dB (converged in {used_i} iterations, {:+.2} dB vs ideal)",
            to_db(ss_i),
            to_db(ss_i) - to_db(ss)
        );
    }
    Ok(())
}

/// Drive the rust and xla engines with byte-identical inputs and report
/// the trajectory deviation (the CLI face of rust/tests/engines_agree.rs).
fn cmd_validate(args: &ParsedArgs) -> Result<()> {
    use dcd_lms::algorithms::{Algorithm, CommMeter, Dcd, DcdMasks, NetworkConfig, StepData};

    if !dcd_lms::runtime::xla_available() {
        println!(
            "validate skipped: xla runtime unavailable in this build \
             (offline `xla` stub; see rust/vendor/README.md)"
        );
        return Ok(());
    }
    let config = args.get("config").unwrap_or("smoke");
    let mut rt = Runtime::open_default()?;
    let spec = rt
        .manifest()
        .find("dcd", config)
        .ok_or_else(|| anyhow!("no dcd artifact for config {config:?} (run `make artifacts`)"))?
        .clone();
    let (n, l, t) = (spec.n_nodes, spec.dim, spec.chunk_len);
    println!("validating dcd_{config}: N={n} L={l} chunk T={t}");

    let mut rng = Pcg64::new(99, 0);
    let graph = Graph::ring(n, 1);
    let c = combination_matrix(&graph, Rule::Metropolis);
    let a = combination_matrix(&graph, Rule::Metropolis);
    let net = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l };
    let model = dcd_lms::datamodel::DataModel::paper(n, l, 0.9, 1.1, 1e-3, &mut rng);
    let (m, m_grad) = ((l / 2).max(1), (l / 3).max(1));

    // Generate one chunk of shared inputs.
    let mut u = vec![0f32; t * n * l];
    let mut d = vec![0f32; t * n];
    model.sample_block_f32(&mut rng, t, &mut u, &mut d);
    let mut h = vec![0f32; t * n * l];
    let mut q = vec![0f32; t * n * l];
    let mut scratch = Vec::new();
    for slot in 0..t * n {
        rng.fill_mask(&mut h[slot * l..(slot + 1) * l], m, &mut scratch);
        rng.fill_mask(&mut q[slot * l..(slot + 1) * l], m_grad, &mut scratch);
    }

    // xla engine.
    let w0 = vec![0f32; n * l];
    let c32 = net.c_f32();
    let a32 = net.a_f32();
    let mu32 = net.mu_f32();
    let wo32 = model.wo_f32();
    let out = rt.execute_chunk(&spec.name, &[&w0, &u, &d, &h, &q, &c32, &a32, &mu32, &wo32])?;

    // rust engine with identical data + masks.
    let mut alg = Dcd::new(net, m, m_grad);
    let mut comm = CommMeter::new(n);
    let mut max_dev = 0.0f64;
    for step in 0..t {
        let u64v: Vec<f64> =
            u[step * n * l..(step + 1) * n * l].iter().map(|&x| x as f64).collect();
        let d64v: Vec<f64> = d[step * n..(step + 1) * n].iter().map(|&x| x as f64).collect();
        let masks = DcdMasks {
            h: h[step * n * l..(step + 1) * n * l].iter().map(|&x| x as f64).collect(),
            q: q[step * n * l..(step + 1) * n * l].iter().map(|&x| x as f64).collect(),
        };
        alg.step_with_masks(StepData { u: &u64v, d: &d64v }, &masks, &mut comm);
        let msd_rust = alg.msd(&model.wo);
        let row = &out.msd[step * n..(step + 1) * n];
        let msd_xla = row.iter().map(|&x| x as f64).sum::<f64>() / n as f64;
        max_dev = max_dev.max((msd_rust - msd_xla).abs() / msd_rust.max(1e-12));
    }
    // Final weights.
    let mut w_dev = 0.0f64;
    for (rw, xw) in alg.weights().iter().zip(out.w_final.iter()) {
        w_dev = w_dev.max((rw - *xw as f64).abs());
    }
    println!("max relative MSD deviation over {t} steps: {max_dev:.3e}");
    println!("max final-weight deviation:              {w_dev:.3e}");
    if max_dev < 1e-3 && w_dev < 1e-3 {
        println!("engines agree ✓");
        Ok(())
    } else {
        Err(anyhow!("engines diverged"))
    }
}

fn cmd_info() -> Result<()> {
    println!(
        "dcd-lms {} — three-layer rust+JAX+Pallas build",
        env!("CARGO_PKG_VERSION")
    );
    match Runtime::open_default() {
        Ok(rt) => {
            println!("artifacts:");
            for m in &rt.manifest().modules {
                println!(
                    "  {:<16} N={:<3} L={:<3} T={:<4} inputs={} ({})",
                    m.name,
                    m.n_nodes,
                    m.dim,
                    m.chunk_len,
                    m.inputs.len(),
                    m.path
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e}) — run `make artifacts`"),
    }
    // A tiny self-check of the core substrates.
    let g = Graph::paper_ten_node();
    let a = combination_matrix(&g, Rule::Metropolis);
    let eye = Mat::eye(3);
    let _ = &eye * &eye;
    println!(
        "paper 10-node network: {} edges, connected: {}",
        g.edge_count(),
        g.is_connected()
    );
    println!("metropolis doubly stochastic: {}", {
        let cs = a.col_sums();
        cs.iter().all(|s| (s - 1.0).abs() < 1e-9)
    });
    Ok(())
}
