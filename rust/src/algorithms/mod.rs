//! Algorithm library: the paper's DCD plus every compared baseline.
//!
//! All five algorithms of §IV are implemented message-accurately in f64:
//!
//! * [`DiffusionLms`] — ATC diffusion LMS, eqs. (4)–(5), general A and C.
//! * [`Rcd`] — reduced-communication diffusion LMS [29], eq. (7).
//! * [`PartialDiffusion`] — partial-diffusion LMS [31]–[33], eq. (8).
//! * [`Dcd`] — the paper's doubly-compressed diffusion LMS, Alg. 1 /
//!   eqs. (10)–(12); the compressed-diffusion LMS (CD) is the
//!   `M_grad = L` special case (constructor [`Dcd::cd`]).
//! * [`CompressiveDiffusion`] — the projection-based compressive
//!   diffusion LMS [30], eq. (9) (the third reduction family of Fig. 1).
//!
//! Each step consumes a synchronous data snapshot and an RNG (for the
//! per-iteration selection matrices), updates the per-node state, and
//! reports every scalar that crossed a link to the [`CommMeter`] — the
//! meter totals are what the energy model of Experiment 3 consumes, and
//! property tests pin them to the paper's closed-form compression ratios.

mod compressive;
mod dcd;
mod diffusion_lms;
mod partial;
mod rcd;
mod traits;

pub use compressive::CompressiveDiffusion;
pub use dcd::{Dcd, DcdMasks};
pub use diffusion_lms::DiffusionLms;
pub use partial::{PartialDiffusion, PartialMasks};
pub use rcd::{Rcd, RcdSelection};
pub use traits::{
    soa_lane_msd, Algorithm, BatchCtx, BatchData, BatchStep, CommLedger, CommMeter, NetworkConfig,
    Purpose, StepData,
};
