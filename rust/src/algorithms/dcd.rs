//! Doubly-compressed diffusion LMS (the paper's contribution, Alg. 1).
//!
//! Per iteration, node k draws H_{k,i} (M of L entries) and Q_{k,i}
//! (M_grad of L entries). It sends the masked estimate H_k ∘ w_k to each
//! neighbour; each neighbour l fills the missing entries with its own
//! w_l, evaluates the instantaneous gradient there, and returns the
//! Q_l-masked gradient. Node k fills the missing gradient entries with
//! its own gradient (eq. (12)), adapts (eq. (10)), and combines the
//! masked estimates received earlier (eq. (11)).
//!
//! The compressed-diffusion LMS (CD) of §IV is the `m_grad = L` special
//! case, built by [`Dcd::cd`].

use super::traits::{Algorithm, CommMeter, NetworkConfig, Purpose, StepData};
use crate::rng::Pcg64;

/// Externally supplied selection patterns for one iteration (used by the
/// engine-equivalence tests to drive rust and xla with identical masks).
#[derive(Debug, Clone)]
pub struct DcdMasks {
    /// Row-major (N x L) 0/1; row k = diag of H_{k,i}.
    pub h: Vec<f64>,
    /// Row-major (N x L) 0/1; row l = diag of Q_{l,i}.
    pub q: Vec<f64>,
}

/// DCD algorithm state.
pub struct Dcd {
    cfg: NetworkConfig,
    /// Entries shared per estimate (M).
    pub m: usize,
    /// Entries shared per gradient (M_grad).
    pub m_grad: usize,
    /// When true (CD / plain-LMS limits), gradients are not exchanged at
    /// all (C = I); estimate sharing still happens for the combine step.
    grad_sharing: bool,
    name: &'static str,
    /// Std-dev of additive noise on every *received* scalar (imperfect
    /// links, cf. paper refs. [14], [33]); 0 = ideal links.
    pub link_noise_sigma: f64,
    w: Vec<f64>,    // (N, L) current estimates
    psi: Vec<f64>,  // (N, L) intermediate estimates
    wnew: Vec<f64>, // (N, L) scratch for the combine
    h: Vec<f64>,    // (N, L) current H masks
    q: Vec<f64>,    // (N, L) current Q masks
    /// Per-iteration link-noise samples for the estimate exchange
    /// ((N, L); entry (k, j) perturbs H_k w_k as received by neighbours).
    est_noise: Vec<f64>,
    /// Reused per-step residual buffer (allocation-free hot loop).
    e_self: Vec<f64>,
    scratch: Vec<usize>,
}

impl Dcd {
    pub fn new(cfg: NetworkConfig, m: usize, m_grad: usize) -> Self {
        Self::with_name(cfg, m, m_grad, "dcd")
    }

    /// Compressed diffusion LMS: full gradients (M_grad = L).
    pub fn cd(cfg: NetworkConfig, m: usize) -> Self {
        let l = cfg.dim;
        Self::with_name(cfg, m, l, "cd")
    }

    fn with_name(cfg: NetworkConfig, m: usize, m_grad: usize, name: &'static str) -> Self {
        assert!(m <= cfg.dim && m_grad <= cfg.dim, "M, M_grad must be <= L");
        let n = cfg.n_nodes();
        let l = cfg.dim;
        // C == I disables gradient exchange entirely (O(nnz) check).
        let grad_sharing = !cfg.c.is_identity();
        Self {
            cfg,
            m,
            m_grad,
            grad_sharing,
            name,
            link_noise_sigma: 0.0,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            h: vec![0.0; n * l],
            q: vec![0.0; n * l],
            est_noise: vec![0.0; n * l],
            e_self: vec![0.0; n],
            scratch: Vec::new(),
        }
    }

    /// Enable imperfect-exchange simulation: every received scalar is
    /// perturbed by N(0, sigma²) noise (failure injection; cf. the
    /// noisy-links analyses of paper refs. [14], [33]).
    pub fn with_link_noise(mut self, sigma: f64) -> Self {
        self.link_noise_sigma = sigma;
        self
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    /// Draw fresh H/Q masks for every node (directly into the f64
    /// buffers — no f32 staging; §Perf).
    fn draw_masks(&mut self, rng: &mut Pcg64) {
        let l = self.cfg.dim;
        let n = self.cfg.n_nodes();
        for k in 0..n {
            let hk = &mut self.h[k * l..(k + 1) * l];
            hk.iter_mut().for_each(|x| *x = 0.0);
            rng.sample_indices(l, self.m, &mut self.scratch);
            for &i in self.scratch.iter() {
                hk[i] = 1.0;
            }
            let qk = &mut self.q[k * l..(k + 1) * l];
            qk.iter_mut().for_each(|x| *x = 0.0);
            rng.sample_indices(l, self.m_grad, &mut self.scratch);
            for &i in self.scratch.iter() {
                qk[i] = 1.0;
            }
        }
    }

    /// One iteration with externally supplied masks (no RNG draw; ideal
    /// links — the engine-equivalence tests depend on exactness).
    pub fn step_with_masks(
        &mut self,
        data: StepData<'_>,
        masks: &DcdMasks,
        comm: &mut CommMeter,
    ) {
        self.h.copy_from_slice(&masks.h);
        self.q.copy_from_slice(&masks.q);
        self.step_inner(data, comm, None);
    }

    fn step_inner(
        &mut self,
        data: StepData<'_>,
        comm: &mut CommMeter,
        mut noise_rng: Option<&mut Pcg64>,
    ) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);
        debug_assert_eq!(u.len(), n * l);
        debug_assert_eq!(d.len(), n);

        // Imperfect links: each node's broadcast H_k o w_k is perturbed
        // once per iteration (broadcast medium — all receivers see the
        // same corrupted frame); gradient replies get fresh per-link
        // noise below.
        let sigma = self.link_noise_sigma;
        if sigma > 0.0 {
            if let Some(rng) = noise_rng.as_deref_mut() {
                rng.fill_gaussian(&mut self.est_noise, sigma);
            } else {
                self.est_noise.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        // sigma == 0: est_noise stays all-zero (invariant from init).

        // -- Adapt (eqs. (10)/(12)) -------------------------------------
        // Per-node self residuals e_self[k] = d_k - u_k^T w_k.
        // (§Perf: the whole step is allocation-free — `e_self` is the
        // only per-call buffer and the per-node state is addressed by
        // disjoint-field slices instead of clones; see EXPERIMENTS.md.)
        self.e_self.resize(n, 0.0);
        for k in 0..n {
            let uk = &u[k * l..(k + 1) * l];
            let wk = &self.w[k * l..(k + 1) * l];
            self.e_self[k] = d[k] - dot(uk, wk);
        }

        let w = &self.w;
        let h = &self.h;
        let q = &self.q;
        let est = &self.est_noise;
        let psi = &mut self.psi;

        for k in 0..n {
            let base = k * l;
            let mu_k = self.cfg.mu[k];
            let e_self_k = self.e_self[k];
            let wk = &w[base..base + l];
            let uk = &u[base..base + l];
            let hk = &h[base..base + l];
            let nk = &est[base..base + l];

            // psi_k starts from w_k plus the (free) self-gradient term.
            let c_kk = mu_k * self.cfg.c[(k, k)];
            {
                let psi_k = &mut psi[base..base + l];
                for ((p, &wj), &uj) in psi_k.iter_mut().zip(wk).zip(uk) {
                    *p = wj + c_kk * uj * e_self_k;
                }
            }

            if self.grad_sharing {
                for &lnb in self.cfg.graph.neighbors(k) {
                    let c_lk = self.cfg.c[(lnb, k)];
                    // Node k sends H_k o w_k to neighbour l  (M scalars).
                    comm.send(k, lnb, Purpose::Estimate, self.m);
                    // Neighbour l fills with its own w_l, evaluates its
                    // instantaneous gradient there...
                    let lb = lnb * l;
                    let wl = &w[lb..lb + l];
                    let ul = &u[lb..lb + l];
                    let ql = &q[lb..lb + l];
                    let mut e = d[lnb];
                    for (((&hj, &wj), (&nj, &wlj)), &ulj) in
                        hk.iter().zip(wk).zip(nk.iter().zip(wl)).zip(ul)
                    {
                        // The received selected entries carry link noise.
                        e -= ulj * (hj * (wj + nj) + (1.0 - hj) * wlj);
                    }
                    // ... and returns the Q_l-masked entries (M_grad
                    // scalars) — a solicited reply: the ledger bills it
                    // only when k's broadcast actually reached l.
                    comm.send(lnb, k, Purpose::Gradient, self.m_grad);
                    if c_lk == 0.0 {
                        continue;
                    }
                    let mu_c = mu_k * c_lk;
                    let psi_k = &mut psi[base..base + l];
                    if sigma > 0.0 {
                        // Noisy-link path (per-entry RNG draw, unvectorised).
                        let rng = noise_rng.as_deref_mut();
                        if let Some(rng) = rng {
                            for j in 0..l {
                                let qlj = ql[j];
                                let gn = if qlj != 0.0 { sigma * rng.next_gaussian() } else { 0.0 };
                                let g = qlj * (ul[j] * e + gn)
                                    + (1.0 - qlj) * (uk[j] * e_self_k);
                                psi_k[j] += mu_c * g;
                            }
                            continue;
                        }
                    }
                    // Ideal-link fast path (eq. (12)): fully vectorisable.
                    for (((p, &qlj), &ulj), &ukj) in
                        psi_k.iter_mut().zip(ql).zip(ul).zip(uk)
                    {
                        *p += mu_c * (qlj * (ulj * e) + (1.0 - qlj) * (ukj * e_self_k));
                    }
                }
            } else {
                // C = I: no gradient exchange, but the estimates still have
                // to reach the neighbours for the combine step below.
                for &lnb in self.cfg.graph.neighbors(k) {
                    comm.send(k, lnb, Purpose::Estimate, self.m);
                }
            }
        }

        // -- Combine (eq. (11)) ------------------------------------------
        // Uses the H_l o w_{l,i-1} received during the adapt phase (no
        // additional traffic).
        let psi = &self.psi;
        let wnew = &mut self.wnew;
        for k in 0..n {
            let base = k * l;
            let a_kk = self.cfg.a[(k, k)];
            let psi_k = &psi[base..base + l];
            {
                let out = &mut wnew[base..base + l];
                for (o, &p) in out.iter_mut().zip(psi_k) {
                    *o = a_kk * p;
                }
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if a_lk == 0.0 {
                    continue;
                }
                let lb = lnb * l;
                let wl = &w[lb..lb + l];
                let hl = &h[lb..lb + l];
                let nl = &est[lb..lb + l];
                let out = &mut wnew[base..base + l];
                for ((o, &p), ((&hj, &wj), &nj)) in out
                    .iter_mut()
                    .zip(psi_k)
                    .zip(hl.iter().zip(wl).zip(nl))
                {
                    // Same received (possibly noisy) frame as the adapt phase.
                    *o += a_lk * (hj * (wj + nj) + (1.0 - hj) * p);
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

impl Algorithm for Dcd {
    fn name(&self) -> &'static str {
        self.name
    }

    fn step(&mut self, data: StepData<'_>, rng: &mut Pcg64, comm: &mut CommMeter) {
        self.draw_masks(rng);
        self.step_inner(data, comm, Some(rng));
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        let per_link = if self.grad_sharing {
            (self.m + self.m_grad) as f64
        } else {
            self.m as f64
        };
        (0..self.cfg.n_nodes())
            .map(|k| self.cfg.graph.neighbors(k).len() as f64 * per_link)
            .sum()
    }

    fn compression_ratio(&self) -> Option<f64> {
        let l = self.cfg.dim as f64;
        Some(2.0 * l / (self.m as f64 + self.m_grad as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_noiseless() {
        let mut rng = Pcg64::new(1, 0);
        let n = 6;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| 0.3 * j as f64 - 0.4).collect();
        let mut alg = Dcd::new(cfg(n, l, 0.08), 2, 2);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..800 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert!(alg.msd(&wo) < 1e-4, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn full_masks_equal_diffusion_lms_with_identity_a() {
        // M = M_grad = L and A = I reduce DCD to diffusion LMS (§III).
        let mut rng = Pcg64::new(3, 0);
        let n = 5;
        let l = 3;
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::topology::Combiner::eye(n);
        let cfg = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l };
        let mut dcd = Dcd::new(cfg.clone(), l, l);
        let mut lms = super::super::DiffusionLms::new(cfg);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..30 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for (k, dk) in d.iter_mut().enumerate() {
                *dk = 0.5 * u[k * l] + rng.next_gaussian() * 0.01;
            }
            dcd.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            lms.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            for (x, y) in dcd.weights().iter().zip(lms.weights().iter()) {
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn comm_meter_matches_expectation() {
        let mut rng = Pcg64::new(5, 0);
        let n = 6;
        let l = 5;
        let mut alg = Dcd::new(cfg(n, l, 0.01), 3, 1);
        let mut comm = CommMeter::new(n);
        let u = vec![0.1; n * l];
        let d = vec![0.2; n];
        let iters = 7;
        for _ in 0..iters {
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert_eq!(
            comm.scalars(),
            (alg.expected_scalars_per_iter() * iters as f64) as u64
        );
        // The ledger's breakdowns are conservative: per-node, per-link
        // and per-purpose views all sum back to the same total.
        let ledger = comm.ledger();
        assert_eq!(ledger.per_node.iter().sum::<u64>(), ledger.scalars);
        assert_eq!(ledger.per_link.iter().sum::<u64>(), ledger.scalars);
        assert_eq!(ledger.per_purpose.iter().sum::<u64>(), ledger.scalars);
        // DCD splits traffic M : M_grad between the two purposes.
        assert_eq!(
            ledger.purpose_scalars(Purpose::Estimate) * alg.m_grad as u64,
            ledger.purpose_scalars(Purpose::Gradient) * alg.m as u64
        );
    }

    #[test]
    fn cd_ratio_formula() {
        let alg = Dcd::cd(cfg(4, 10, 0.01), 3);
        // CD: 2L / (M + L) = 20 / 13.
        assert!((alg.compression_ratio().unwrap() - 20.0 / 13.0).abs() < 1e-12);
        let alg = Dcd::new(cfg(4, 10, 0.01), 3, 2);
        assert!((alg.compression_ratio().unwrap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn link_noise_raises_floor_but_stays_stable() {
        // Failure injection: noisy links (refs [14], [33]) degrade the
        // steady state without destroying convergence at small mu.
        let run = |sigma: f64| {
            let mut rng = Pcg64::new(19, 0);
            let n = 6;
            let l = 4;
            let wo: Vec<f64> = (0..l).map(|j| 0.25 * j as f64 - 0.3).collect();
            let mut alg = Dcd::new(cfg(n, l, 0.05), 2, 2).with_link_noise(sigma);
            let mut comm = CommMeter::new(n);
            let mut u = vec![0.0; n * l];
            let mut d = vec![0.0; n];
            let mut tail = 0.0;
            for it in 0..3000 {
                for x in u.iter_mut() {
                    *x = rng.next_gaussian();
                }
                for k in 0..n {
                    d[k] = dot(&u[k * l..(k + 1) * l], &wo) + 0.01 * rng.next_gaussian();
                }
                alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
                if it >= 2700 {
                    tail += alg.msd(&wo);
                }
            }
            tail / 300.0
        };
        let clean = run(0.0);
        let noisy = run(0.1);
        let very_noisy = run(0.4);
        assert!(noisy > 2.0 * clean, "clean {clean} noisy {noisy}");
        assert!(very_noisy > noisy, "noisy {noisy} very {very_noisy}");
        assert!(very_noisy.is_finite() && very_noisy < 1.0);
    }

    #[test]
    fn identity_c_skips_gradient_traffic() {
        let mut c = cfg(4, 6, 0.01);
        c.c = crate::topology::Combiner::eye(4);
        let mut alg = Dcd::new(c, 2, 3);
        let mut rng = Pcg64::new(7, 0);
        let mut comm = CommMeter::new(4);
        let u = vec![0.1; 24];
        let d = vec![0.0; 4];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        // Ring of 4, 1 hop: every node has 2 neighbours; M = 2 scalars each.
        assert_eq!(comm.scalars(), (4 * 2 * 2) as u64);
        assert_eq!(comm.ledger().purpose_scalars(Purpose::Gradient), 0);
    }
}
