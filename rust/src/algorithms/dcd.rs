//! Doubly-compressed diffusion LMS (the paper's contribution, Alg. 1).
//!
//! Per iteration, node k draws H_{k,i} (M of L entries) and Q_{k,i}
//! (M_grad of L entries). It sends the masked estimate H_k ∘ w_k to each
//! neighbour; each neighbour l fills the missing entries with its own
//! w_l, evaluates the instantaneous gradient there, and returns the
//! Q_l-masked gradient. Node k fills the missing gradient entries with
//! its own gradient (eq. (12)), adapts (eq. (10)), and combines the
//! masked estimates received earlier (eq. (11)).
//!
//! The compressed-diffusion LMS (CD) of §IV is the `m_grad = L` special
//! case, built by [`Dcd::cd`].

use super::traits::{
    soa_lane_msd, Algorithm, BatchCtx, BatchData, BatchStep, CommMeter, NetworkConfig, Purpose,
    StepData,
};
use crate::linalg::kernels;
use crate::rng::Pcg64;

/// Externally supplied selection patterns for one iteration (used by the
/// engine-equivalence tests to drive rust and xla with identical masks).
#[derive(Debug, Clone)]
pub struct DcdMasks {
    /// Row-major (N x L) 0/1; row k = diag of H_{k,i}.
    pub h: Vec<f64>,
    /// Row-major (N x L) 0/1; row l = diag of Q_{l,i}.
    pub q: Vec<f64>,
}

/// DCD algorithm state.
pub struct Dcd {
    cfg: NetworkConfig,
    /// Entries shared per estimate (M).
    pub m: usize,
    /// Entries shared per gradient (M_grad).
    pub m_grad: usize,
    /// When true (CD / plain-LMS limits), gradients are not exchanged at
    /// all (C = I); estimate sharing still happens for the combine step.
    grad_sharing: bool,
    name: &'static str,
    /// Std-dev of additive noise on every *received* scalar (imperfect
    /// links, cf. paper refs. [14], [33]); 0 = ideal links.
    pub link_noise_sigma: f64,
    w: Vec<f64>,    // (N, L) current estimates
    psi: Vec<f64>,  // (N, L) intermediate estimates
    wnew: Vec<f64>, // (N, L) scratch for the combine
    h: Vec<f64>,    // (N, L) current H masks
    q: Vec<f64>,    // (N, L) current Q masks
    /// Per-iteration link-noise samples for the estimate exchange
    /// ((N, L); entry (k, j) perturbs H_k w_k as received by neighbours).
    est_noise: Vec<f64>,
    /// Reused per-step residual buffer (allocation-free hot loop).
    e_self: Vec<f64>,
    scratch: Vec<usize>,
    // Lane-engine SoA state (DESIGN.md §14): sized by `batch_reset`,
    // empty (zero cost) on the scalar path.
    lanes: usize,
    bw: Vec<f64>,
    bpsi: Vec<f64>,
    bwnew: Vec<f64>,
    bh: Vec<f64>,
    bq: Vec<f64>,
    be_self: Vec<f64>,
    le: Vec<f64>,
    lgate: Vec<f64>,
    lmu: Vec<f64>,
    lacc: Vec<f64>,
}

impl Dcd {
    pub fn new(cfg: NetworkConfig, m: usize, m_grad: usize) -> Self {
        Self::with_name(cfg, m, m_grad, "dcd")
    }

    /// Compressed diffusion LMS: full gradients (M_grad = L).
    pub fn cd(cfg: NetworkConfig, m: usize) -> Self {
        let l = cfg.dim;
        Self::with_name(cfg, m, l, "cd")
    }

    fn with_name(cfg: NetworkConfig, m: usize, m_grad: usize, name: &'static str) -> Self {
        assert!(m <= cfg.dim && m_grad <= cfg.dim, "M, M_grad must be <= L");
        let n = cfg.n_nodes();
        let l = cfg.dim;
        // C == I disables gradient exchange entirely (O(nnz) check).
        let grad_sharing = !cfg.c.is_identity();
        Self {
            cfg,
            m,
            m_grad,
            grad_sharing,
            name,
            link_noise_sigma: 0.0,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            h: vec![0.0; n * l],
            q: vec![0.0; n * l],
            est_noise: vec![0.0; n * l],
            e_self: vec![0.0; n],
            scratch: Vec::new(),
            lanes: 0,
            bw: Vec::new(),
            bpsi: Vec::new(),
            bwnew: Vec::new(),
            bh: Vec::new(),
            bq: Vec::new(),
            be_self: Vec::new(),
            le: Vec::new(),
            lgate: Vec::new(),
            lmu: Vec::new(),
            lacc: Vec::new(),
        }
    }

    /// Enable imperfect-exchange simulation: every received scalar is
    /// perturbed by N(0, sigma²) noise (failure injection; cf. the
    /// noisy-links analyses of paper refs. [14], [33]).
    pub fn with_link_noise(mut self, sigma: f64) -> Self {
        self.link_noise_sigma = sigma;
        self
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    /// Draw fresh H/Q masks for every node (directly into the f64
    /// buffers — no f32 staging; §Perf).
    fn draw_masks(&mut self, rng: &mut Pcg64) {
        let l = self.cfg.dim;
        let n = self.cfg.n_nodes();
        for k in 0..n {
            let hk = &mut self.h[k * l..(k + 1) * l];
            hk.iter_mut().for_each(|x| *x = 0.0);
            rng.sample_indices(l, self.m, &mut self.scratch);
            for &i in self.scratch.iter() {
                hk[i] = 1.0;
            }
            let qk = &mut self.q[k * l..(k + 1) * l];
            qk.iter_mut().for_each(|x| *x = 0.0);
            rng.sample_indices(l, self.m_grad, &mut self.scratch);
            for &i in self.scratch.iter() {
                qk[i] = 1.0;
            }
        }
    }

    /// One iteration with externally supplied masks (no RNG draw; ideal
    /// links — the engine-equivalence tests depend on exactness).
    pub fn step_with_masks(
        &mut self,
        data: StepData<'_>,
        masks: &DcdMasks,
        comm: &mut CommMeter,
    ) {
        self.h.copy_from_slice(&masks.h);
        self.q.copy_from_slice(&masks.q);
        self.step_inner(data, comm, None);
    }

    fn step_inner(
        &mut self,
        data: StepData<'_>,
        comm: &mut CommMeter,
        mut noise_rng: Option<&mut Pcg64>,
    ) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);
        debug_assert_eq!(u.len(), n * l);
        debug_assert_eq!(d.len(), n);

        // Imperfect links: each node's broadcast H_k o w_k is perturbed
        // once per iteration (broadcast medium — all receivers see the
        // same corrupted frame); gradient replies get fresh per-link
        // noise below.
        let sigma = self.link_noise_sigma;
        if sigma > 0.0 {
            if let Some(rng) = noise_rng.as_deref_mut() {
                rng.fill_gaussian(&mut self.est_noise, sigma);
            } else {
                self.est_noise.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        // sigma == 0: est_noise stays all-zero (invariant from init).

        // -- Adapt (eqs. (10)/(12)) -------------------------------------
        // Per-node self residuals e_self[k] = d_k - u_k^T w_k.
        // (§Perf: the whole step is allocation-free — `e_self` is the
        // only per-call buffer and the per-node state is addressed by
        // disjoint-field slices instead of clones; see EXPERIMENTS.md.)
        self.e_self.resize(n, 0.0);
        for k in 0..n {
            let uk = &u[k * l..(k + 1) * l];
            let wk = &self.w[k * l..(k + 1) * l];
            self.e_self[k] = d[k] - dot(uk, wk);
        }

        let w = &self.w;
        let h = &self.h;
        let q = &self.q;
        let est = &self.est_noise;
        let psi = &mut self.psi;

        for k in 0..n {
            let base = k * l;
            let mu_k = self.cfg.mu[k];
            let e_self_k = self.e_self[k];
            let wk = &w[base..base + l];
            let uk = &u[base..base + l];
            let hk = &h[base..base + l];
            let nk = &est[base..base + l];

            // psi_k starts from w_k plus the (free) self-gradient term.
            let c_kk = mu_k * self.cfg.c[(k, k)];
            {
                let psi_k = &mut psi[base..base + l];
                for ((p, &wj), &uj) in psi_k.iter_mut().zip(wk).zip(uk) {
                    *p = wj + c_kk * uj * e_self_k;
                }
            }

            if self.grad_sharing {
                for &lnb in self.cfg.graph.neighbors(k) {
                    let c_lk = self.cfg.c[(lnb, k)];
                    // Node k sends H_k o w_k to neighbour l  (M scalars).
                    comm.send(k, lnb, Purpose::Estimate, self.m);
                    // Neighbour l fills with its own w_l, evaluates its
                    // instantaneous gradient there...
                    let lb = lnb * l;
                    let wl = &w[lb..lb + l];
                    let ul = &u[lb..lb + l];
                    let ql = &q[lb..lb + l];
                    let mut e = d[lnb];
                    for (((&hj, &wj), (&nj, &wlj)), &ulj) in
                        hk.iter().zip(wk).zip(nk.iter().zip(wl)).zip(ul)
                    {
                        // The received selected entries carry link noise.
                        e -= ulj * (hj * (wj + nj) + (1.0 - hj) * wlj);
                    }
                    // ... and returns the Q_l-masked entries (M_grad
                    // scalars) — a solicited reply: the ledger bills it
                    // only when k's broadcast actually reached l.
                    comm.send(lnb, k, Purpose::Gradient, self.m_grad);
                    if c_lk == 0.0 {
                        continue;
                    }
                    let mu_c = mu_k * c_lk;
                    let psi_k = &mut psi[base..base + l];
                    if sigma > 0.0 {
                        // Noisy-link path (per-entry RNG draw, unvectorised).
                        let rng = noise_rng.as_deref_mut();
                        if let Some(rng) = rng {
                            for j in 0..l {
                                let qlj = ql[j];
                                let gn = if qlj != 0.0 { sigma * rng.next_gaussian() } else { 0.0 };
                                let g = qlj * (ul[j] * e + gn)
                                    + (1.0 - qlj) * (uk[j] * e_self_k);
                                psi_k[j] += mu_c * g;
                            }
                            continue;
                        }
                    }
                    // Ideal-link fast path (eq. (12)): fully vectorisable.
                    for (((p, &qlj), &ulj), &ukj) in
                        psi_k.iter_mut().zip(ql).zip(ul).zip(uk)
                    {
                        *p += mu_c * (qlj * (ulj * e) + (1.0 - qlj) * (ukj * e_self_k));
                    }
                }
            } else {
                // C = I: no gradient exchange, but the estimates still have
                // to reach the neighbours for the combine step below.
                for &lnb in self.cfg.graph.neighbors(k) {
                    comm.send(k, lnb, Purpose::Estimate, self.m);
                }
            }
        }

        // -- Combine (eq. (11)) ------------------------------------------
        // Uses the H_l o w_{l,i-1} received during the adapt phase (no
        // additional traffic).
        let psi = &self.psi;
        let wnew = &mut self.wnew;
        for k in 0..n {
            let base = k * l;
            let a_kk = self.cfg.a[(k, k)];
            let psi_k = &psi[base..base + l];
            {
                let out = &mut wnew[base..base + l];
                for (o, &p) in out.iter_mut().zip(psi_k) {
                    *o = a_kk * p;
                }
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if a_lk == 0.0 {
                    continue;
                }
                let lb = lnb * l;
                let wl = &w[lb..lb + l];
                let hl = &h[lb..lb + l];
                let nl = &est[lb..lb + l];
                let out = &mut wnew[base..base + l];
                for ((o, &p), ((&hj, &wj), &nj)) in out
                    .iter_mut()
                    .zip(psi_k)
                    .zip(hl.iter().zip(wl).zip(nl))
                {
                    // Same received (possibly noisy) frame as the adapt phase.
                    *o += a_lk * (hj * (wj + nj) + (1.0 - hj) * p);
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

impl Algorithm for Dcd {
    fn name(&self) -> &'static str {
        self.name
    }

    fn step(&mut self, data: StepData<'_>, rng: &mut Pcg64, comm: &mut CommMeter) {
        self.draw_masks(rng);
        self.step_inner(data, comm, Some(rng));
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        let per_link = if self.grad_sharing {
            (self.m + self.m_grad) as f64
        } else {
            self.m as f64
        };
        (0..self.cfg.n_nodes())
            .map(|k| self.cfg.graph.neighbors(k).len() as f64 * per_link)
            .sum()
    }

    fn compression_ratio(&self) -> Option<f64> {
        let l = self.cfg.dim as f64;
        Some(2.0 * l / (self.m as f64 + self.m_grad as f64))
    }

    fn as_batch(&mut self) -> Option<&mut dyn BatchStep> {
        // The noisy-link path draws per-(edge, entry) Gaussians from the
        // run RNG in an order the lane engine cannot replicate without
        // serialising — those runs stay on the scalar path.
        if self.link_noise_sigma > 0.0 {
            None
        } else {
            Some(self)
        }
    }
}

// Run-batched step (DESIGN.md §14), ideal links only (`as_batch` gates
// on `link_noise_sigma == 0`). Each loop replicates the scalar
// `step_inner` per lane — including the literal `(w + 0.0)` where the
// scalar path adds the (all-zero at sigma = 0) link-noise entry, and the
// estimate-send → residual → gradient-send → `c_lk` gate ordering.
impl BatchStep for Dcd {
    fn batch_reset(&mut self, lanes: usize) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        self.lanes = lanes;
        for buf in [&mut self.bw, &mut self.bpsi, &mut self.bwnew, &mut self.bh, &mut self.bq] {
            buf.clear();
            buf.resize(n * l * lanes, 0.0);
        }
        self.be_self.clear();
        self.be_self.resize(n * lanes, 0.0);
        for buf in [&mut self.le, &mut self.lgate, &mut self.lmu] {
            buf.clear();
            buf.resize(lanes, 0.0);
        }
        self.lacc.clear();
        self.lacc.resize(4 * lanes, 0.0);
    }

    fn batch_step(
        &mut self,
        data: BatchData<'_>,
        ctx: BatchCtx<'_>,
        rngs: &mut [Pcg64],
        comms: &mut [CommMeter],
    ) {
        assert!(self.link_noise_sigma == 0.0, "noisy links are scalar-only");
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let lanes = ctx.lanes;
        debug_assert_eq!(lanes, self.lanes, "batch_step before batch_reset");
        let nnz_c = self.cfg.c.nnz();
        let nnz_a = self.cfg.a.nnz();
        let (u, d) = (data.u, data.d);
        let row = l * lanes;

        // Mask draws: lane b consumes rngs[b] exactly as a scalar run
        // consumes its run RNG (per node: H then Q).
        let (m, m_grad) = (self.m, self.m_grad);
        for (b, rng) in rngs.iter_mut().enumerate().take(lanes) {
            for k in 0..n {
                let base = k * row;
                for j in 0..l {
                    self.bh[base + j * lanes + b] = 0.0;
                }
                rng.sample_indices(l, m, &mut self.scratch);
                for &i in self.scratch.iter() {
                    self.bh[base + i * lanes + b] = 1.0;
                }
                for j in 0..l {
                    self.bq[base + j * lanes + b] = 0.0;
                }
                rng.sample_indices(l, m_grad, &mut self.scratch);
                for &i in self.scratch.iter() {
                    self.bq[base + i * lanes + b] = 1.0;
                }
            }
        }

        // -- Adapt (eqs. (10)/(12)) -------------------------------------
        // Self residuals e_self[k, b] = d[k, b] − u_k·w_k.
        {
            let w = &self.bw;
            let es = &mut self.be_self;
            let acc = &mut self.lacc;
            let e = &mut self.le;
            for k in 0..n {
                let uk = &u[k * row..(k + 1) * row];
                let wk = &w[k * row..(k + 1) * row];
                kernels::lane_dot(uk, wk, lanes, acc, e);
                for b in 0..lanes {
                    es[k * lanes + b] = d[k * lanes + b] - e[b];
                }
            }
        }

        {
            let cfg = &self.cfg;
            let w = &self.bw;
            let h = &self.bh;
            let q = &self.bq;
            let es = &self.be_self;
            let psi = &mut self.bpsi;
            let gate = &mut self.lgate;
            let muc = &mut self.lmu;
            let e = &mut self.le;
            for k in 0..n {
                let base = k * row;
                let mu_k = cfg.mu[k];
                let wk = &w[base..base + row];
                let uk = &u[base..base + row];
                let hk = &h[base..base + row];
                let es_k = &es[k * lanes..(k + 1) * lanes];

                // psi_k = w_k + (mu_k c_kk) u_k e_self, per lane.
                let cd = cfg.c.diag_idx(k);
                for b in 0..lanes {
                    muc[b] = mu_k * ctx.c_vals[b * nnz_c + cd];
                }
                {
                    let psi_k = &mut psi[base..base + row];
                    for j in 0..l {
                        let jb = j * lanes;
                        for b in 0..lanes {
                            psi_k[jb + b] = wk[jb + b] + muc[b] * uk[jb + b] * es_k[b];
                        }
                    }
                }

                if self.grad_sharing {
                    for &lnb in cfg.graph.neighbors(k) {
                        let cidx = cfg.c.entry_idx(k, lnb);
                        for comm in comms.iter_mut().take(lanes) {
                            comm.send(k, lnb, Purpose::Estimate, m);
                        }
                        let lb = lnb * row;
                        let wl = &w[lb..lb + row];
                        let ul = &u[lb..lb + row];
                        let ql = &q[lb..lb + row];
                        // e[b] = d[lnb, b] − Σ_j u_l (h (w + 0) + (1−h) w_l),
                        // sequential in j like the scalar fold.
                        for b in 0..lanes {
                            e[b] = d[lnb * lanes + b];
                        }
                        for j in 0..l {
                            let jb = j * lanes;
                            for b in 0..lanes {
                                e[b] -= ul[jb + b]
                                    * (hk[jb + b] * (wk[jb + b] + 0.0)
                                        + (1.0 - hk[jb + b]) * wl[jb + b]);
                            }
                        }
                        for comm in comms.iter_mut().take(lanes) {
                            comm.send(lnb, k, Purpose::Gradient, m_grad);
                        }
                        let Some(cidx) = cidx else { continue };
                        for b in 0..lanes {
                            gate[b] = ctx.c_vals[b * nnz_c + cidx];
                        }
                        for b in 0..lanes {
                            muc[b] = mu_k * gate[b];
                        }
                        let psi_k = &mut psi[base..base + row];
                        let all_live = gate.iter().all(|&g| g != 0.0);
                        if all_live {
                            for j in 0..l {
                                let jb = j * lanes;
                                for b in 0..lanes {
                                    psi_k[jb + b] += muc[b]
                                        * (ql[jb + b] * (ul[jb + b] * e[b])
                                            + (1.0 - ql[jb + b]) * (uk[jb + b] * es_k[b]));
                                }
                            }
                        } else {
                            for j in 0..l {
                                let jb = j * lanes;
                                for b in 0..lanes {
                                    if gate[b] != 0.0 {
                                        psi_k[jb + b] += muc[b]
                                            * (ql[jb + b] * (ul[jb + b] * e[b])
                                                + (1.0 - ql[jb + b]) * (uk[jb + b] * es_k[b]));
                                    }
                                }
                            }
                        }
                    }
                } else {
                    for &lnb in cfg.graph.neighbors(k) {
                        for comm in comms.iter_mut().take(lanes) {
                            comm.send(k, lnb, Purpose::Estimate, m);
                        }
                    }
                }
            }
        }

        // -- Combine (eq. (11)) ------------------------------------------
        {
            let cfg = &self.cfg;
            let w = &self.bw;
            let h = &self.bh;
            let psi = &self.bpsi;
            let wnew = &mut self.bwnew;
            let gate = &mut self.lgate;
            for k in 0..n {
                let base = k * row;
                let ad = cfg.a.diag_idx(k);
                for b in 0..lanes {
                    gate[b] = ctx.a_vals[b * nnz_a + ad];
                }
                let psi_k = &psi[base..base + row];
                kernels::lane_scale(gate, psi_k, &mut wnew[base..base + row], lanes);
                for &lnb in cfg.graph.neighbors(k) {
                    let Some(idx) = cfg.a.entry_idx(k, lnb) else { continue };
                    for b in 0..lanes {
                        gate[b] = ctx.a_vals[b * nnz_a + idx];
                    }
                    let lb = lnb * row;
                    let wl = &w[lb..lb + row];
                    let hl = &h[lb..lb + row];
                    let out = &mut wnew[base..base + row];
                    let all_live = gate.iter().all(|&g| g != 0.0);
                    if all_live {
                        for j in 0..l {
                            let jb = j * lanes;
                            for b in 0..lanes {
                                out[jb + b] += gate[b]
                                    * (hl[jb + b] * (wl[jb + b] + 0.0)
                                        + (1.0 - hl[jb + b]) * psi_k[jb + b]);
                            }
                        }
                    } else {
                        for j in 0..l {
                            let jb = j * lanes;
                            for b in 0..lanes {
                                if gate[b] != 0.0 {
                                    out[jb + b] += gate[b]
                                        * (hl[jb + b] * (wl[jb + b] + 0.0)
                                            + (1.0 - hl[jb + b]) * psi_k[jb + b]);
                                }
                            }
                        }
                    }
                }
            }
        }
        std::mem::swap(&mut self.bw, &mut self.bwnew);
    }

    fn batch_weights(&self) -> &[f64] {
        &self.bw
    }

    fn batch_weights_mut(&mut self) -> &mut [f64] {
        &mut self.bw
    }

    fn batch_msd(&self, b: usize, wo: &[f64]) -> f64 {
        soa_lane_msd(&self.bw, self.lanes, b, wo)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_noiseless() {
        let mut rng = Pcg64::new(1, 0);
        let n = 6;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| 0.3 * j as f64 - 0.4).collect();
        let mut alg = Dcd::new(cfg(n, l, 0.08), 2, 2);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..800 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert!(alg.msd(&wo) < 1e-4, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn full_masks_equal_diffusion_lms_with_identity_a() {
        // M = M_grad = L and A = I reduce DCD to diffusion LMS (§III).
        let mut rng = Pcg64::new(3, 0);
        let n = 5;
        let l = 3;
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = crate::topology::Combiner::eye(n);
        let cfg = NetworkConfig { graph, c, a, mu: vec![0.05; n], dim: l };
        let mut dcd = Dcd::new(cfg.clone(), l, l);
        let mut lms = super::super::DiffusionLms::new(cfg);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..30 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for (k, dk) in d.iter_mut().enumerate() {
                *dk = 0.5 * u[k * l] + rng.next_gaussian() * 0.01;
            }
            dcd.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            lms.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            for (x, y) in dcd.weights().iter().zip(lms.weights().iter()) {
                assert!((x - y).abs() < 1e-12, "{x} vs {y}");
            }
        }
    }

    #[test]
    fn comm_meter_matches_expectation() {
        let mut rng = Pcg64::new(5, 0);
        let n = 6;
        let l = 5;
        let mut alg = Dcd::new(cfg(n, l, 0.01), 3, 1);
        let mut comm = CommMeter::new(n);
        let u = vec![0.1; n * l];
        let d = vec![0.2; n];
        let iters = 7;
        for _ in 0..iters {
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert_eq!(
            comm.scalars(),
            (alg.expected_scalars_per_iter() * iters as f64) as u64
        );
        // The ledger's breakdowns are conservative: per-node, per-link
        // and per-purpose views all sum back to the same total.
        let ledger = comm.ledger();
        assert_eq!(ledger.per_node.iter().sum::<u64>(), ledger.scalars);
        assert_eq!(ledger.per_link.iter().sum::<u64>(), ledger.scalars);
        assert_eq!(ledger.per_purpose.iter().sum::<u64>(), ledger.scalars);
        // DCD splits traffic M : M_grad between the two purposes.
        assert_eq!(
            ledger.purpose_scalars(Purpose::Estimate) * alg.m_grad as u64,
            ledger.purpose_scalars(Purpose::Gradient) * alg.m as u64
        );
    }

    #[test]
    fn cd_ratio_formula() {
        let alg = Dcd::cd(cfg(4, 10, 0.01), 3);
        // CD: 2L / (M + L) = 20 / 13.
        assert!((alg.compression_ratio().unwrap() - 20.0 / 13.0).abs() < 1e-12);
        let alg = Dcd::new(cfg(4, 10, 0.01), 3, 2);
        assert!((alg.compression_ratio().unwrap() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn link_noise_raises_floor_but_stays_stable() {
        // Failure injection: noisy links (refs [14], [33]) degrade the
        // steady state without destroying convergence at small mu.
        let run = |sigma: f64| {
            let mut rng = Pcg64::new(19, 0);
            let n = 6;
            let l = 4;
            let wo: Vec<f64> = (0..l).map(|j| 0.25 * j as f64 - 0.3).collect();
            let mut alg = Dcd::new(cfg(n, l, 0.05), 2, 2).with_link_noise(sigma);
            let mut comm = CommMeter::new(n);
            let mut u = vec![0.0; n * l];
            let mut d = vec![0.0; n];
            let mut tail = 0.0;
            for it in 0..3000 {
                for x in u.iter_mut() {
                    *x = rng.next_gaussian();
                }
                for k in 0..n {
                    d[k] = dot(&u[k * l..(k + 1) * l], &wo) + 0.01 * rng.next_gaussian();
                }
                alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
                if it >= 2700 {
                    tail += alg.msd(&wo);
                }
            }
            tail / 300.0
        };
        let clean = run(0.0);
        let noisy = run(0.1);
        let very_noisy = run(0.4);
        assert!(noisy > 2.0 * clean, "clean {clean} noisy {noisy}");
        assert!(very_noisy > noisy, "noisy {noisy} very {very_noisy}");
        assert!(very_noisy.is_finite() && very_noisy < 1.0);
    }

    /// Lane b of one batched instance must reproduce an independent
    /// scalar instance with the same run RNG (mask draws) and lane data —
    /// weights, meter, and MSD all bitwise — with and without gradient
    /// sharing.
    #[test]
    fn batched_lanes_bitwise_match_scalar_runs() {
        let n = 5;
        let l = 4;
        let lanes = 3;
        let mut ident = cfg(n, l, 0.05);
        ident.c = crate::topology::Combiner::eye(n);
        for base in [cfg(n, l, 0.05), ident] {
            let mut scalars: Vec<Dcd> =
                (0..lanes).map(|_| Dcd::new(base.clone(), 2, 1)).collect();
            let mut batched = Dcd::new(base.clone(), 2, 1);
            assert!(batched.as_batch().is_some());
            batched.batch_reset(lanes);
            let (nnz_c, nnz_a) = (base.c.nnz(), base.a.nnz());
            let mut c_vals = vec![0.0; nnz_c * lanes];
            let mut a_vals = vec![0.0; nnz_a * lanes];
            for b in 0..lanes {
                c_vals[b * nnz_c..(b + 1) * nnz_c].copy_from_slice(base.c.vals());
                a_vals[b * nnz_a..(b + 1) * nnz_a].copy_from_slice(base.a.vals());
            }
            let mut data_rngs: Vec<Pcg64> =
                (0..lanes).map(|b| Pcg64::new(7, b as u64 + 1)).collect();
            let mut run_rngs_s: Vec<Pcg64> =
                (0..lanes).map(|b| Pcg64::new(11, b as u64 + 1)).collect();
            let mut run_rngs_b: Vec<Pcg64> =
                (0..lanes).map(|b| Pcg64::new(11, b as u64 + 1)).collect();
            let mut comms_s: Vec<CommMeter> = (0..lanes).map(|_| CommMeter::new(n)).collect();
            let mut comms_b: Vec<CommMeter> = (0..lanes).map(|_| CommMeter::new(n)).collect();
            let mut u = vec![0.0; n * l];
            let mut d = vec![0.0; n];
            let mut u_soa = vec![0.0; n * l * lanes];
            let mut d_soa = vec![0.0; n * lanes];
            for _ in 0..40 {
                for b in 0..lanes {
                    for (idx, x) in u.iter_mut().enumerate() {
                        *x = data_rngs[b].next_gaussian();
                        u_soa[idx * lanes + b] = *x;
                    }
                    for (k, x) in d.iter_mut().enumerate() {
                        *x = data_rngs[b].next_gaussian();
                        d_soa[k * lanes + b] = *x;
                    }
                    scalars[b].step(StepData { u: &u, d: &d }, &mut run_rngs_s[b], &mut comms_s[b]);
                }
                batched.batch_step(
                    BatchData { u: &u_soa, d: &d_soa },
                    BatchCtx { lanes, c_vals: &c_vals, a_vals: &a_vals },
                    &mut run_rngs_b,
                    &mut comms_b,
                );
            }
            let wo: Vec<f64> = (0..l).map(|j| 0.25 * j as f64 - 0.3).collect();
            for b in 0..lanes {
                assert_eq!(
                    run_rngs_s[b].next_u64(),
                    run_rngs_b[b].next_u64(),
                    "lane {b} rng desynchronised"
                );
                for (idx, &x) in scalars[b].weights().iter().enumerate() {
                    assert_eq!(
                        batched.bw[idx * lanes + b].to_bits(),
                        x.to_bits(),
                        "lane {b} weight {idx}"
                    );
                }
                assert_eq!(comms_s[b].scalars(), comms_b[b].scalars(), "lane {b} meter");
                assert_eq!(
                    scalars[b].msd(&wo).to_bits(),
                    batched.batch_msd(b, &wo).to_bits(),
                    "lane {b} msd"
                );
            }
        }
    }

    /// Noisy links cannot be lane-batched: the per-entry RNG order is
    /// inherently scalar, so `as_batch` must decline.
    #[test]
    fn link_noise_opts_out_of_batching() {
        let mut alg = Dcd::new(cfg(4, 3, 0.05), 2, 2).with_link_noise(0.1);
        assert!(alg.as_batch().is_none());
        let mut clean = Dcd::new(cfg(4, 3, 0.05), 2, 2);
        assert!(clean.as_batch().is_some());
    }

    #[test]
    fn identity_c_skips_gradient_traffic() {
        let mut c = cfg(4, 6, 0.01);
        c.c = crate::topology::Combiner::eye(4);
        let mut alg = Dcd::new(c, 2, 3);
        let mut rng = Pcg64::new(7, 0);
        let mut comm = CommMeter::new(4);
        let u = vec![0.1; 24];
        let d = vec![0.0; 4];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        // Ring of 4, 1 hop: every node has 2 neighbours; M = 2 scalars each.
        assert_eq!(comm.scalars(), (4 * 2 * 2) as u64);
        assert_eq!(comm.ledger().purpose_scalars(Purpose::Gradient), 0);
    }
}
