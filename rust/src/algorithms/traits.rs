//! Shared algorithm plumbing: network configuration, data snapshots,
//! communication metering, and the `Algorithm` trait the coordinator
//! drives.
//!
//! The communication meter itself lives with the energy substrate
//! ([`crate::energy::comm`], DESIGN.md §9) — communication cost *is*
//! energy in this system — and is re-exported here because every
//! algorithm step reports its traffic to it.

use crate::rng::Pcg64;
use crate::topology::{Combiner, Graph};

pub use crate::energy::comm::{CommLedger, CommMeter, Purpose};

/// Static network configuration shared by all algorithms.
#[derive(Debug, Clone)]
pub struct NetworkConfig {
    pub graph: Graph,
    /// Right-stochastic adapt combiner; entry `[l, k]` = c_{lk}. Support
    /// must match the graph (plus the diagonal). Stored sparse (CSR,
    /// DESIGN.md §10) — O(E), not O(N²).
    pub c: Combiner,
    /// Left-stochastic combine matrix; entry `[l, k]` = a_{lk}.
    pub a: Combiner,
    /// Per-node step sizes μ_k.
    pub mu: Vec<f64>,
    /// Parameter dimension L.
    pub dim: usize,
}

impl NetworkConfig {
    pub fn n_nodes(&self) -> usize {
        self.graph.n()
    }

    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_nodes();
        if self.c.rows() != n || self.c.cols() != n {
            return Err(format!("C must be {n}x{n}"));
        }
        if self.a.rows() != n || self.a.cols() != n {
            return Err(format!("A must be {n}x{n}"));
        }
        if self.mu.len() != n {
            return Err(format!("mu must have {n} entries"));
        }
        // O(nnz) stochasticity checks via the CSR row/column sums.
        for (k, col) in self.a.col_sums().into_iter().enumerate() {
            if (col - 1.0).abs() > 1e-9 {
                return Err(format!("A column {k} sums to {col}, not 1"));
            }
        }
        for (l, row) in self.c.row_sums().into_iter().enumerate() {
            if (row - 1.0).abs() > 1e-9 {
                return Err(format!("C row {l} sums to {row}, not 1"));
            }
        }
        Ok(())
    }

    /// f32 copies in the artifact layout (for the xla engine).
    pub fn c_f32(&self) -> Vec<f32> {
        self.c.to_dense().data().iter().map(|&x| x as f32).collect()
    }

    pub fn a_f32(&self) -> Vec<f32> {
        self.a.to_dense().data().iter().map(|&x| x as f32).collect()
    }

    pub fn mu_f32(&self) -> Vec<f32> {
        self.mu.iter().map(|&x| x as f32).collect()
    }
}

/// One synchronous data snapshot: row-major U (N x L) and D (N).
#[derive(Debug, Clone, Copy)]
pub struct StepData<'a> {
    pub u: &'a [f64],
    pub d: &'a [f64],
}

/// A distributed estimation algorithm driven one synchronous iteration at
/// a time by the coordinator.
pub trait Algorithm {
    fn name(&self) -> &'static str;

    /// Advance one network iteration: draw selection patterns from `rng`,
    /// exchange messages, update all node states. Every exchanged frame
    /// is reported to the directional ledger as
    /// `(source, destination, purpose, scalars)` — see
    /// [`CommMeter::send`] and DESIGN.md §9 for the billing rules.
    fn step(&mut self, data: StepData<'_>, rng: &mut Pcg64, comm: &mut CommMeter);

    /// Current estimates, row-major (N x L).
    fn weights(&self) -> &[f64];

    /// Mutable view of the estimates, row-major (N x L). The
    /// coordinator's impairment layer uses this to emulate
    /// finite-precision state storage (per-link quantization).
    fn weights_mut(&mut self) -> &mut [f64];

    /// The static network configuration the algorithm runs on.
    fn network(&self) -> &NetworkConfig;

    /// Mutable access to the network configuration. The coordinator's
    /// impairment layer swaps in per-iteration *effective* combination
    /// matrices (erased links re-allocated to the diagonal) through this
    /// — which is what makes impairments algorithm-agnostic.
    fn network_mut(&mut self) -> &mut NetworkConfig;

    /// Reset all node states to zero.
    fn reset(&mut self);

    /// Expected scalars transmitted per iteration by the whole network
    /// (closed form; property-tested against the meter).
    fn expected_scalars_per_iter(&self) -> f64;

    /// The paper's compression ratio vs. two-way diffusion LMS (2L per
    /// directed neighbour pair); `None` for the uncompressed baseline.
    fn compression_ratio(&self) -> Option<f64>;

    /// Network MSD against `wo`: (1/N) Σ_k ||w° − w_k||².
    fn msd(&self, wo: &[f64]) -> f64 {
        let w = self.weights();
        let l = wo.len();
        let n = w.len() / l;
        let mut total = 0.0;
        for k in 0..n {
            let row = &w[k * l..(k + 1) * l];
            total += row
                .iter()
                .zip(wo.iter())
                .map(|(a, b)| (a - b) * (a - b))
                .sum::<f64>();
        }
        total / n as f64
    }

    /// The run-batched face of this algorithm, if it has one.
    ///
    /// Returning `Some` opts into the lane engine (DESIGN.md §14): the
    /// coordinator packs B independent Monte-Carlo runs into SoA state
    /// and drives [`BatchStep::batch_step`] once per iteration instead
    /// of [`Algorithm::step`] B times. The contract is *bit-identity*:
    /// lane b's weight trajectory, ledger, and MSD trace must match a
    /// scalar run with the same seed/stream exactly. Algorithms whose
    /// step draws from a shared noise source in a non-per-lane order
    /// (or that simply have no batched implementation) return `None`
    /// and the coordinator falls back to the scalar path — the default.
    fn as_batch(&mut self) -> Option<&mut dyn BatchStep> {
        None
    }
}

/// Lane-major SoA data for one batched iteration: `u[(k*L + j)*lanes + b]`
/// and `d[k*lanes + b]` hold lane b's regressor entry (k, j) and desired
/// response at node k.
#[derive(Debug, Clone, Copy)]
pub struct BatchData<'a> {
    pub u: &'a [f64],
    pub d: &'a [f64],
}

/// Per-iteration combiner context for a batched step. The lane engine
/// rebuilds each lane's *effective* CSR combiner values (after erasures)
/// every iteration; structure (indices) never changes, so algorithms keep
/// reading indptr/cols from their own [`NetworkConfig`] and take only the
/// values from here.
#[derive(Debug, Clone, Copy)]
pub struct BatchCtx<'a> {
    /// Number of lanes B in flight.
    pub lanes: usize,
    /// Effective adapt-combiner (C) values, lane-blocked: lane b's CSR
    /// value array is `c_vals[b*nnz_c .. (b+1)*nnz_c]`.
    pub c_vals: &'a [f64],
    /// Effective combine-matrix (A) values, lane-blocked like `c_vals`.
    pub a_vals: &'a [f64],
}

/// A run-batched algorithm: B independent runs advanced in SoA lockstep,
/// each lane bit-identical to the scalar path (DESIGN.md §14).
pub trait BatchStep {
    /// Size the SoA state for `lanes` concurrent runs and zero every
    /// lane (the batched analogue of [`Algorithm::reset`]).
    fn batch_reset(&mut self, lanes: usize);

    /// Advance every lane one synchronous network iteration. `rngs[b]`
    /// is lane b's run RNG (selection-mask draws must consume it in the
    /// scalar per-run order); `comms[b]` is lane b's meter, billed with
    /// the scalar path's exact send sequence.
    fn batch_step(
        &mut self,
        data: BatchData<'_>,
        ctx: BatchCtx<'_>,
        rngs: &mut [Pcg64],
        comms: &mut [CommMeter],
    );

    /// Lane-major SoA weights, `w[(k*L + j)*lanes + b]`.
    fn batch_weights(&self) -> &[f64];

    /// Mutable SoA weights (the impairment layer quantizes in place —
    /// elementwise, so lane values stay bit-identical to scalar).
    fn batch_weights_mut(&mut self) -> &mut [f64];

    /// Network MSD of lane `b` against `wo`, replicating the scalar
    /// [`Algorithm::msd`] fold order exactly.
    fn batch_msd(&self, b: usize, wo: &[f64]) -> f64;
}

/// MSD of lane `b` over lane-major SoA weights `w[(k*L + j)*lanes + b]`,
/// folding in exactly the scalar [`Algorithm::msd`] order: a sequential
/// per-row sum over j, rows accumulated in ascending k, divided by N
/// last. Shared by every [`BatchStep`] implementation.
pub fn soa_lane_msd(w: &[f64], lanes: usize, b: usize, wo: &[f64]) -> f64 {
    let l = wo.len();
    let n = w.len() / (l * lanes);
    let mut total = 0.0;
    for k in 0..n {
        let mut row_sum = 0.0;
        for (j, &wj) in wo.iter().enumerate() {
            let x = w[(k * l + j) * lanes + b] - wj;
            row_sum += x * x;
        }
        total += row_sum;
    }
    total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Rule};

    pub(crate) fn tiny_config() -> NetworkConfig {
        let graph = Graph::ring(4, 1);
        let a = combination_matrix(&graph, Rule::Metropolis);
        let c = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![0.05; 4], dim: 3 }
    }

    #[test]
    fn validate_accepts_stochastic() {
        assert!(tiny_config().validate().is_ok());
    }

    #[test]
    fn validate_rejects_bad_sums() {
        let mut cfg = tiny_config();
        cfg.a = Combiner::from_dense(&crate::linalg::Mat::eye(4).scale(0.5));
        assert!(cfg.validate().is_err());
        let mut cfg = tiny_config();
        cfg.mu = vec![0.1; 3];
        assert!(cfg.validate().is_err());
    }

    /// The re-exported ledger is the meter every algorithm bills into
    /// (its own unit tests live in `energy::comm`).
    #[test]
    fn meter_reexport_is_the_ledger() {
        let mut m = CommMeter::new(3);
        m.send(0, 1, Purpose::Estimate, 5);
        m.send(2, 0, Purpose::Gradient, 2);
        assert_eq!(m.scalars(), 7);
        assert_eq!(m.ledger().link_scalars(0, 1), 5);
    }
}
