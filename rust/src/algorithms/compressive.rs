//! Compressive diffusion LMS [30] (paper eq. (9)) — the projection-based
//! third family of Fig. 1 (c).
//!
//! Instead of sending vector *entries*, each node broadcasts the scalar
//! projection p_{l,i}ᵀ ψ_{l,i} of its intermediate estimate onto a
//! (pseudo-random, receiver-reproducible) projection vector. Receivers
//! maintain a *constructed estimate* γ_{l,i} of each neighbour, corrected
//! adaptively:
//!
//!   ε_{l,i} = p_{l,i}ᵀ(ψ_{l,i} − γ_{l,i-1}),
//!   γ_{l,i} = γ_{l,i-1} + η_l p_{l,i} ε_{l,i},
//!   w_{k,i} = a_kk ψ_{k,i} + Σ_{l≠k} a_lk γ_{l,i}.
//!
//! Communication cost: **one scalar** (the projection ε or equivalently
//! the projected value) per link per iteration — ratio 2L vs the
//! diffusion-LMS baseline — at the price of an extra adaptive loop whose
//! step η trades reconstruction lag for noise (the "additional adaptive
//! step which can increase the algorithm complexity" noted in §II-B).

use super::traits::{Algorithm, CommMeter, NetworkConfig, Purpose, StepData};
use crate::rng::Pcg64;

/// Compressive diffusion LMS state.
pub struct CompressiveDiffusion {
    cfg: NetworkConfig,
    /// Reconstruction step size η.
    pub eta: f64,
    w: Vec<f64>,
    psi: Vec<f64>,
    wnew: Vec<f64>,
    /// Constructed estimates γ_l maintained network-wide (every node in
    /// the neighbourhood tracks the same γ_l since the projection vector
    /// and ε are shared).
    gamma: Vec<f64>,
    /// Scratch for the per-iteration projection vectors.
    proj: Vec<f64>,
    /// Dedicated stream for the (shared) projection vectors: receivers
    /// regenerate them from the same seed, so they are never transmitted.
    proj_rng: Pcg64,
}

impl CompressiveDiffusion {
    pub fn new(cfg: NetworkConfig, eta: f64, proj_seed: u64) -> Self {
        let n = cfg.n_nodes();
        let l = cfg.dim;
        Self {
            cfg,
            eta,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            gamma: vec![0.0; n * l],
            proj: vec![0.0; n * l],
            proj_rng: Pcg64::new(proj_seed, 0x9a0c),
        }
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }

    /// Current constructed estimates (for tests).
    pub fn constructed(&self) -> &[f64] {
        &self.gamma
    }
}

impl Algorithm for CompressiveDiffusion {
    fn name(&self) -> &'static str {
        "compressive-diffusion"
    }

    fn step(&mut self, data: StepData<'_>, _rng: &mut Pcg64, comm: &mut CommMeter) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);

        // Self-only adapt (C = I in [30]).
        for k in 0..n {
            let uk = &u[k * l..(k + 1) * l];
            let wk = &self.w[k * l..(k + 1) * l];
            let e = d[k] - dot(uk, wk);
            let mu_k = self.cfg.mu[k];
            let psi_k = &mut self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                psi_k[j] = wk[j] + mu_k * uk[j] * e;
            }
        }

        // Fresh normalized gaussian projection vectors (shared PRNG).
        for x in self.proj.iter_mut() {
            *x = self.proj_rng.next_gaussian();
        }
        for k in 0..n {
            let p = &mut self.proj[k * l..(k + 1) * l];
            let norm = p.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
            p.iter_mut().for_each(|x| *x /= norm);
        }

        // Broadcast one scalar per node (the projection error), update the
        // constructed estimates.
        for k in 0..n {
            let p = &self.proj[k * l..(k + 1) * l];
            let psi_k = &self.psi[k * l..(k + 1) * l];
            let gamma_k = &mut self.gamma[k * l..(k + 1) * l];
            let eps: f64 = p
                .iter()
                .zip(psi_k.iter().zip(gamma_k.iter()))
                .map(|(pj, (s, g))| pj * (s - g))
                .sum();
            // One projection-residue scalar to each neighbour.
            for &lnb in self.cfg.graph.neighbors(k) {
                comm.send(k, lnb, Purpose::Residue, 1);
            }
            for (g, pj) in gamma_k.iter_mut().zip(p.iter()) {
                *g += self.eta * pj * eps;
            }
        }

        // Combine with the constructed estimates (eq. (9)).
        for k in 0..n {
            let a_kk = self.cfg.a[(k, k)];
            let psi_k = &self.psi[k * l..(k + 1) * l];
            let out = &mut self.wnew[k * l..(k + 1) * l];
            for j in 0..l {
                out[j] = a_kk * psi_k[j];
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if a_lk == 0.0 {
                    continue;
                }
                let gamma_l = &self.gamma[lnb * l..(lnb + 1) * l];
                for j in 0..l {
                    out[j] += a_lk * gamma_l[j];
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        for buf in [&mut self.w, &mut self.psi, &mut self.gamma] {
            buf.iter_mut().for_each(|x| *x = 0.0);
        }
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        (0..self.cfg.n_nodes())
            .map(|k| self.cfg.graph.neighbors(k).len() as f64)
            .sum()
    }

    /// One scalar per link vs 2L: ratio 2L.
    fn compression_ratio(&self) -> Option<f64> {
        Some(2.0 * self.cfg.dim as f64)
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = crate::topology::Combiner::eye(n);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_noiseless() {
        let mut rng = Pcg64::new(3, 0);
        let n = 8;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| 0.3 - 0.2 * j as f64).collect();
        let mut alg = CompressiveDiffusion::new(cfg(n, l, 0.08), 0.8, 7);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..4000 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert!(alg.msd(&wo) < 1e-3, "msd {}", alg.msd(&wo));
        // Constructed estimates converge to the true estimates too.
        let mut gap = 0.0f64;
        for (g, w) in alg.constructed().iter().zip(alg.weights().iter()) {
            gap = gap.max((g - w).abs());
        }
        assert!(gap < 0.3, "reconstruction gap {gap}");
    }

    #[test]
    fn one_scalar_per_link() {
        let n = 6;
        let l = 9;
        let mut alg = CompressiveDiffusion::new(cfg(n, l, 0.05), 0.5, 1);
        let mut rng = Pcg64::new(4, 0);
        let mut comm = CommMeter::new(n);
        let u = vec![0.1; n * l];
        let d = vec![0.0; n];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        assert_eq!(comm.scalars(), (n * 2) as u64); // ring: 2 neighbours
        assert_eq!(alg.compression_ratio(), Some(18.0));
        assert_eq!(
            alg.expected_scalars_per_iter() as u64,
            comm.scalars()
        );
        assert_eq!(comm.ledger().purpose_scalars(Purpose::Residue), comm.scalars());
    }

    #[test]
    fn reconstruction_tracks_slowly_varying_target() {
        // With psi frozen, gamma must converge to psi (the correction
        // loop is a normalized-projection LMS on the identity model).
        let n = 4;
        let l = 6;
        let mut alg = CompressiveDiffusion::new(cfg(n, l, 0.0), 1.0, 11);
        // mu = 0 keeps psi = w = 0... instead seed w directly.
        for (i, x) in alg.w.iter_mut().enumerate() {
            *x = (i % 5) as f64 * 0.2 - 0.4;
        }
        let mut rng = Pcg64::new(5, 0);
        let mut comm = CommMeter::new(n);
        let u = vec![0.0; n * l];
        let d = vec![0.0; n];
        for _ in 0..600 {
            // mu=0: psi == w stays fixed; only the gamma loop runs. The
            // combine mixes w with gammas, so freeze w back each step to
            // isolate the reconstruction loop.
            let w_snapshot = alg.w.clone();
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            alg.w.copy_from_slice(&w_snapshot);
        }
        for (g, w) in alg.constructed().iter().zip(alg.w.iter()) {
            assert!((g - w).abs() < 1e-2, "gamma {g} vs psi {w}");
        }
    }
}
