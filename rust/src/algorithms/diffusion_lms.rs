//! ATC diffusion LMS (paper eqs. (4)–(5)): the uncompressed baseline.
//!
//! With C ≠ I the adapt step is a two-way exchange per directed link —
//! node k sends its full estimate (L scalars) to every neighbour and each
//! neighbour returns its full instantaneous gradient (L scalars) — which
//! is exactly the 2L-per-link cost the paper's compression ratios are
//! quoted against. The combine step reuses the estimates already held by
//! the neighbours, matching the accounting of §IV.

use super::traits::{Algorithm, CommMeter, NetworkConfig, Purpose, StepData};
use crate::rng::Pcg64;

/// ATC diffusion LMS state.
pub struct DiffusionLms {
    cfg: NetworkConfig,
    grad_sharing: bool,
    w: Vec<f64>,
    psi: Vec<f64>,
    wnew: Vec<f64>,
}

impl DiffusionLms {
    pub fn new(cfg: NetworkConfig) -> Self {
        let n = cfg.n_nodes();
        let l = cfg.dim;
        Self {
            grad_sharing: !cfg.c.is_identity(),
            cfg,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
        }
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }
}

impl Algorithm for DiffusionLms {
    fn name(&self) -> &'static str {
        "diffusion-lms"
    }

    fn step(&mut self, data: StepData<'_>, _rng: &mut Pcg64, comm: &mut CommMeter) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);

        // Adapt: psi_k = w_k + mu_k sum_l c_lk u_l (d_l - u_l^T w_k).
        for k in 0..n {
            let wk: Vec<f64> = self.w[k * l..(k + 1) * l].to_vec();
            let mu_k = self.cfg.mu[k];
            let psi_k = &mut self.psi[k * l..(k + 1) * l];
            psi_k.copy_from_slice(&wk);
            // Self gradient (free).
            let uk = &u[k * l..(k + 1) * l];
            let e_k = d[k] - dot(uk, &wk);
            let c_kk = self.cfg.c[(k, k)];
            for j in 0..l {
                psi_k[j] += mu_k * c_kk * uk[j] * e_k;
            }
            if self.grad_sharing {
                for &lnb in self.cfg.graph.neighbors(k) {
                    // k -> l: full estimate; l -> k: the solicited full
                    // gradient (billed only when the request arrived).
                    comm.send(k, lnb, Purpose::Estimate, l);
                    comm.send(lnb, k, Purpose::Gradient, l);
                    let c_lk = self.cfg.c[(lnb, k)];
                    if c_lk == 0.0 {
                        continue;
                    }
                    let ul = &u[lnb * l..(lnb + 1) * l];
                    let e = d[lnb] - dot(ul, &wk);
                    for j in 0..l {
                        psi_k[j] += mu_k * c_lk * ul[j] * e;
                    }
                }
            }
        }

        // Combine: w_k = sum_l a_lk psi_l. With C = I the psi_l must be
        // shipped now (L scalars per link); with gradient sharing the
        // neighbours rebuilt psi already — but ATC still transmits the
        // intermediate estimates, so the full 2L baseline stands either way.
        for k in 0..n {
            let out = &mut self.wnew[k * l..(k + 1) * l];
            let a_kk = self.cfg.a[(k, k)];
            let psi_k = &self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                out[j] = a_kk * psi_k[j];
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if !self.grad_sharing {
                    comm.send(lnb, k, Purpose::Estimate, l);
                }
                if a_lk == 0.0 {
                    continue;
                }
                let psi_l = &self.psi[lnb * l..(lnb + 1) * l];
                for j in 0..l {
                    out[j] += a_lk * psi_l[j];
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        let l = self.cfg.dim as f64;
        let per_link = if self.grad_sharing { 2.0 * l } else { l };
        (0..self.cfg.n_nodes())
            .map(|k| self.cfg.graph.neighbors(k).len() as f64 * per_link)
            .sum()
    }

    fn compression_ratio(&self) -> Option<f64> {
        None
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_and_beats_single_node_variance() {
        let mut rng = Pcg64::new(11, 0);
        let n = 8;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| (j as f64) * 0.25 - 0.3).collect();
        let mut alg = DiffusionLms::new(cfg(n, l, 0.05));
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..2000 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo) + 0.03 * rng.next_gaussian();
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        // Steady-state MSD must be well below the noise floor of a
        // non-cooperative LMS (~ mu sigma_v^2 L / 2 per node).
        assert!(alg.msd(&wo) < 1e-3, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn comm_cost_is_2l_per_link() {
        let n = 5;
        let l = 7;
        let mut alg = DiffusionLms::new(cfg(n, l, 0.01));
        let mut comm = CommMeter::new(n);
        let mut rng = Pcg64::new(1, 1);
        let u = vec![0.0; n * l];
        let d = vec![0.0; n];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        // Ring: 2 neighbours each, 2L scalars per directed link.
        assert_eq!(comm.scalars(), (n * 2 * 2 * l) as u64);
        assert_eq!(alg.expected_scalars_per_iter() as u64, comm.scalars());
        // Half the traffic is estimates, half solicited gradients.
        assert_eq!(
            comm.ledger().purpose_scalars(Purpose::Estimate),
            comm.ledger().purpose_scalars(Purpose::Gradient)
        );
    }

    #[test]
    fn identity_c_halves_traffic() {
        let mut c = cfg(5, 7, 0.01);
        c.c = crate::topology::Combiner::eye(5);
        let mut alg = DiffusionLms::new(c);
        let mut comm = CommMeter::new(5);
        let mut rng = Pcg64::new(1, 1);
        let u = vec![0.0; 35];
        let d = vec![0.0; 5];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        assert_eq!(comm.scalars(), (5 * 2 * 7) as u64);
    }
}
