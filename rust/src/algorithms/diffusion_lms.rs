//! ATC diffusion LMS (paper eqs. (4)–(5)): the uncompressed baseline.
//!
//! With C ≠ I the adapt step is a two-way exchange per directed link —
//! node k sends its full estimate (L scalars) to every neighbour and each
//! neighbour returns its full instantaneous gradient (L scalars) — which
//! is exactly the 2L-per-link cost the paper's compression ratios are
//! quoted against. The combine step reuses the estimates already held by
//! the neighbours, matching the accounting of §IV.

use super::traits::{
    soa_lane_msd, Algorithm, BatchCtx, BatchData, BatchStep, CommMeter, NetworkConfig, Purpose,
    StepData,
};
use crate::linalg::kernels;
use crate::rng::Pcg64;

/// ATC diffusion LMS state.
pub struct DiffusionLms {
    cfg: NetworkConfig,
    grad_sharing: bool,
    w: Vec<f64>,
    psi: Vec<f64>,
    wnew: Vec<f64>,
    // Lane-engine SoA state (DESIGN.md §14): sized by `batch_reset`,
    // empty (zero cost) on the scalar path.
    lanes: usize,
    bw: Vec<f64>,
    bpsi: Vec<f64>,
    bwnew: Vec<f64>,
    le: Vec<f64>,
    lgate: Vec<f64>,
    lalpha: Vec<f64>,
    lacc: Vec<f64>,
}

impl DiffusionLms {
    pub fn new(cfg: NetworkConfig) -> Self {
        let n = cfg.n_nodes();
        let l = cfg.dim;
        Self {
            grad_sharing: !cfg.c.is_identity(),
            cfg,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            lanes: 0,
            bw: Vec::new(),
            bpsi: Vec::new(),
            bwnew: Vec::new(),
            le: Vec::new(),
            lgate: Vec::new(),
            lalpha: Vec::new(),
            lacc: Vec::new(),
        }
    }

    pub fn config(&self) -> &NetworkConfig {
        &self.cfg
    }
}

impl Algorithm for DiffusionLms {
    fn name(&self) -> &'static str {
        "diffusion-lms"
    }

    fn step(&mut self, data: StepData<'_>, _rng: &mut Pcg64, comm: &mut CommMeter) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);

        // Adapt: psi_k = w_k + mu_k sum_l c_lk u_l (d_l - u_l^T w_k).
        for k in 0..n {
            let wk: Vec<f64> = self.w[k * l..(k + 1) * l].to_vec();
            let mu_k = self.cfg.mu[k];
            let psi_k = &mut self.psi[k * l..(k + 1) * l];
            psi_k.copy_from_slice(&wk);
            // Self gradient (free).
            let uk = &u[k * l..(k + 1) * l];
            let e_k = d[k] - dot(uk, &wk);
            let c_kk = self.cfg.c[(k, k)];
            for j in 0..l {
                psi_k[j] += mu_k * c_kk * uk[j] * e_k;
            }
            if self.grad_sharing {
                for &lnb in self.cfg.graph.neighbors(k) {
                    // k -> l: full estimate; l -> k: the solicited full
                    // gradient (billed only when the request arrived).
                    comm.send(k, lnb, Purpose::Estimate, l);
                    comm.send(lnb, k, Purpose::Gradient, l);
                    let c_lk = self.cfg.c[(lnb, k)];
                    if c_lk == 0.0 {
                        continue;
                    }
                    let ul = &u[lnb * l..(lnb + 1) * l];
                    let e = d[lnb] - dot(ul, &wk);
                    for j in 0..l {
                        psi_k[j] += mu_k * c_lk * ul[j] * e;
                    }
                }
            }
        }

        // Combine: w_k = sum_l a_lk psi_l. With C = I the psi_l must be
        // shipped now (L scalars per link); with gradient sharing the
        // neighbours rebuilt psi already — but ATC still transmits the
        // intermediate estimates, so the full 2L baseline stands either way.
        for k in 0..n {
            let out = &mut self.wnew[k * l..(k + 1) * l];
            let a_kk = self.cfg.a[(k, k)];
            let psi_k = &self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                out[j] = a_kk * psi_k[j];
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if !self.grad_sharing {
                    comm.send(lnb, k, Purpose::Estimate, l);
                }
                if a_lk == 0.0 {
                    continue;
                }
                let psi_l = &self.psi[lnb * l..(lnb + 1) * l];
                for j in 0..l {
                    out[j] += a_lk * psi_l[j];
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        let l = self.cfg.dim as f64;
        let per_link = if self.grad_sharing { 2.0 * l } else { l };
        (0..self.cfg.n_nodes())
            .map(|k| self.cfg.graph.neighbors(k).len() as f64 * per_link)
            .sum()
    }

    fn compression_ratio(&self) -> Option<f64> {
        None
    }

    fn as_batch(&mut self) -> Option<&mut dyn BatchStep> {
        Some(self)
    }
}

// Run-batched step (DESIGN.md §14). Every loop below replicates the
// scalar `step` above per lane: same expression shapes, same `== 0.0`
// gates, same send ordering — the lane index is the only new axis, and
// lanes never mix, so lane b's f64 stream is the scalar stream of run b.
impl BatchStep for DiffusionLms {
    fn batch_reset(&mut self, lanes: usize) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        self.lanes = lanes;
        for buf in [&mut self.bw, &mut self.bpsi, &mut self.bwnew] {
            buf.clear();
            buf.resize(n * l * lanes, 0.0);
        }
        for buf in [&mut self.le, &mut self.lgate, &mut self.lalpha] {
            buf.clear();
            buf.resize(lanes, 0.0);
        }
        self.lacc.clear();
        self.lacc.resize(4 * lanes, 0.0);
    }

    fn batch_step(
        &mut self,
        data: BatchData<'_>,
        ctx: BatchCtx<'_>,
        _rngs: &mut [Pcg64],
        comms: &mut [CommMeter],
    ) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let lanes = ctx.lanes;
        debug_assert_eq!(lanes, self.lanes, "batch_step before batch_reset");
        let nnz_c = self.cfg.c.nnz();
        let nnz_a = self.cfg.a.nnz();
        let (u, d) = (data.u, data.d);
        let row = l * lanes;

        // Adapt: psi_k = w_k + mu_k sum_l c_lk u_l (d_l - u_l^T w_k).
        {
            let cfg = &self.cfg;
            let w = &self.bw;
            let psi = &mut self.bpsi;
            let e = &mut self.le;
            let gate = &mut self.lgate;
            let alpha = &mut self.lalpha;
            let acc = &mut self.lacc;
            for k in 0..n {
                let base = k * row;
                let mu_k = cfg.mu[k];
                let wk = &w[base..base + row];
                let psi_k = &mut psi[base..base + row];
                psi_k.copy_from_slice(wk);
                let uk = &u[base..base + row];
                // e_k[b] = d[k, b] − u_k·w_k  (lane_dot folds like scalar dot).
                kernels::lane_dot(uk, wk, lanes, acc, e);
                for b in 0..lanes {
                    e[b] = d[k * lanes + b] - e[b];
                }
                // Self gradient — unconditional, like the scalar loop.
                let cd = cfg.c.diag_idx(k);
                for b in 0..lanes {
                    alpha[b] = mu_k * ctx.c_vals[b * nnz_c + cd];
                }
                kernels::lane_fused_accum_all(alpha, e, uk, psi_k, lanes);
                if self.grad_sharing {
                    for &lnb in cfg.graph.neighbors(k) {
                        // Sends precede the c_lk gate in the scalar path.
                        for comm in comms.iter_mut().take(lanes) {
                            comm.send(k, lnb, Purpose::Estimate, l);
                            comm.send(lnb, k, Purpose::Gradient, l);
                        }
                        // One CSR lookup serves every lane.
                        let Some(idx) = cfg.c.entry_idx(k, lnb) else { continue };
                        for b in 0..lanes {
                            gate[b] = ctx.c_vals[b * nnz_c + idx];
                        }
                        let ul = &u[lnb * row..(lnb + 1) * row];
                        kernels::lane_dot(ul, wk, lanes, acc, e);
                        for b in 0..lanes {
                            e[b] = d[lnb * lanes + b] - e[b];
                        }
                        for b in 0..lanes {
                            alpha[b] = mu_k * gate[b];
                        }
                        kernels::lane_fused_accum(gate, alpha, e, ul, psi_k, lanes);
                    }
                }
            }
        }

        // Combine: w_k = sum_l a_lk psi_l.
        {
            let cfg = &self.cfg;
            let psi = &self.bpsi;
            let wnew = &mut self.bwnew;
            let alpha = &mut self.lalpha;
            for k in 0..n {
                let base = k * row;
                let ad = cfg.a.diag_idx(k);
                for b in 0..lanes {
                    alpha[b] = ctx.a_vals[b * nnz_a + ad];
                }
                let psi_k = &psi[base..base + row];
                let out = &mut wnew[base..base + row];
                kernels::lane_scale(alpha, psi_k, out, lanes);
                for &lnb in cfg.graph.neighbors(k) {
                    if !self.grad_sharing {
                        for comm in comms.iter_mut().take(lanes) {
                            comm.send(lnb, k, Purpose::Estimate, l);
                        }
                    }
                    let Some(idx) = cfg.a.entry_idx(k, lnb) else { continue };
                    for b in 0..lanes {
                        alpha[b] = ctx.a_vals[b * nnz_a + idx];
                    }
                    let psi_l = &psi[lnb * row..(lnb + 1) * row];
                    kernels::lane_axpy(alpha, psi_l, out, lanes);
                }
            }
        }
        std::mem::swap(&mut self.bw, &mut self.bwnew);
    }

    fn batch_weights(&self) -> &[f64] {
        &self.bw
    }

    fn batch_weights_mut(&mut self) -> &mut [f64] {
        &mut self.bw
    }

    fn batch_msd(&self, b: usize, wo: &[f64]) -> f64 {
        soa_lane_msd(&self.bw, self.lanes, b, wo)
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_and_beats_single_node_variance() {
        let mut rng = Pcg64::new(11, 0);
        let n = 8;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| (j as f64) * 0.25 - 0.3).collect();
        let mut alg = DiffusionLms::new(cfg(n, l, 0.05));
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..2000 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo) + 0.03 * rng.next_gaussian();
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        // Steady-state MSD must be well below the noise floor of a
        // non-cooperative LMS (~ mu sigma_v^2 L / 2 per node).
        assert!(alg.msd(&wo) < 1e-3, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn comm_cost_is_2l_per_link() {
        let n = 5;
        let l = 7;
        let mut alg = DiffusionLms::new(cfg(n, l, 0.01));
        let mut comm = CommMeter::new(n);
        let mut rng = Pcg64::new(1, 1);
        let u = vec![0.0; n * l];
        let d = vec![0.0; n];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        // Ring: 2 neighbours each, 2L scalars per directed link.
        assert_eq!(comm.scalars(), (n * 2 * 2 * l) as u64);
        assert_eq!(alg.expected_scalars_per_iter() as u64, comm.scalars());
        // Half the traffic is estimates, half solicited gradients.
        assert_eq!(
            comm.ledger().purpose_scalars(Purpose::Estimate),
            comm.ledger().purpose_scalars(Purpose::Gradient)
        );
    }

    /// Lane b of one batched instance must reproduce an independent
    /// scalar instance fed lane b's data — weights, meter, and MSD all
    /// bitwise — with and without gradient sharing.
    #[test]
    fn batched_lanes_bitwise_match_scalar_runs() {
        let n = 6;
        let l = 5;
        let lanes = 3;
        let mut ident = cfg(n, l, 0.04);
        ident.c = crate::topology::Combiner::eye(n);
        for base in [cfg(n, l, 0.04), ident] {
            let mut scalars: Vec<DiffusionLms> =
                (0..lanes).map(|_| DiffusionLms::new(base.clone())).collect();
            let mut batched = DiffusionLms::new(base.clone());
            batched.batch_reset(lanes);
            let (nnz_c, nnz_a) = (base.c.nnz(), base.a.nnz());
            let mut c_vals = vec![0.0; nnz_c * lanes];
            let mut a_vals = vec![0.0; nnz_a * lanes];
            for b in 0..lanes {
                c_vals[b * nnz_c..(b + 1) * nnz_c].copy_from_slice(base.c.vals());
                a_vals[b * nnz_a..(b + 1) * nnz_a].copy_from_slice(base.a.vals());
            }
            let mut data_rngs: Vec<Pcg64> =
                (0..lanes).map(|b| Pcg64::new(7, b as u64 + 1)).collect();
            let mut step_rngs: Vec<Pcg64> = (0..lanes).map(|b| Pcg64::new(9, b as u64)).collect();
            let mut comms_s: Vec<CommMeter> = (0..lanes).map(|_| CommMeter::new(n)).collect();
            let mut comms_b: Vec<CommMeter> = (0..lanes).map(|_| CommMeter::new(n)).collect();
            let mut u = vec![0.0; n * l];
            let mut d = vec![0.0; n];
            let mut u_soa = vec![0.0; n * l * lanes];
            let mut d_soa = vec![0.0; n * lanes];
            for _ in 0..40 {
                for b in 0..lanes {
                    for (idx, x) in u.iter_mut().enumerate() {
                        *x = data_rngs[b].next_gaussian();
                        u_soa[idx * lanes + b] = *x;
                    }
                    for (k, x) in d.iter_mut().enumerate() {
                        *x = data_rngs[b].next_gaussian();
                        d_soa[k * lanes + b] = *x;
                    }
                    let mut dummy = Pcg64::new(1, 1);
                    scalars[b].step(StepData { u: &u, d: &d }, &mut dummy, &mut comms_s[b]);
                }
                batched.batch_step(
                    BatchData { u: &u_soa, d: &d_soa },
                    BatchCtx { lanes, c_vals: &c_vals, a_vals: &a_vals },
                    &mut step_rngs,
                    &mut comms_b,
                );
            }
            let wo: Vec<f64> = (0..l).map(|j| 0.2 * j as f64 - 0.3).collect();
            for b in 0..lanes {
                for (idx, &x) in scalars[b].weights().iter().enumerate() {
                    assert_eq!(
                        batched.bw[idx * lanes + b].to_bits(),
                        x.to_bits(),
                        "lane {b} weight {idx}"
                    );
                }
                assert_eq!(comms_s[b].scalars(), comms_b[b].scalars(), "lane {b} meter");
                assert_eq!(
                    scalars[b].msd(&wo).to_bits(),
                    batched.batch_msd(b, &wo).to_bits(),
                    "lane {b} msd"
                );
            }
        }
    }

    #[test]
    fn identity_c_halves_traffic() {
        let mut c = cfg(5, 7, 0.01);
        c.c = crate::topology::Combiner::eye(5);
        let mut alg = DiffusionLms::new(c);
        let mut comm = CommMeter::new(5);
        let mut rng = Pcg64::new(1, 1);
        let u = vec![0.0; 35];
        let d = vec![0.0; 5];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        assert_eq!(comm.scalars(), (5 * 2 * 7) as u64);
    }
}
