//! Partial-diffusion LMS [31]–[33] (paper eq. (8)).
//!
//! C = I (self-only adapt). Each node broadcasts M of the L entries of
//! its intermediate estimate ψ; receivers substitute their own entries
//! for the missing ones:
//!
//!   w_k = a_kk ψ_k + Σ_{l≠k} a_lk ( H_l ψ_l + (I − H_l) ψ_k ).

use super::traits::{Algorithm, CommMeter, NetworkConfig, Purpose, StepData};
use crate::rng::Pcg64;

/// Externally supplied masks for one iteration (N x L row-major 0/1).
#[derive(Debug, Clone)]
pub struct PartialMasks {
    pub h: Vec<f64>,
}

/// Partial-diffusion LMS state.
pub struct PartialDiffusion {
    cfg: NetworkConfig,
    /// Entries of ψ shared per iteration (M).
    pub m: usize,
    w: Vec<f64>,
    psi: Vec<f64>,
    wnew: Vec<f64>,
    h: Vec<f64>,
    scratch: Vec<usize>,
}

impl PartialDiffusion {
    pub fn new(cfg: NetworkConfig, m: usize) -> Self {
        assert!(m <= cfg.dim);
        let n = cfg.n_nodes();
        let l = cfg.dim;
        Self {
            cfg,
            m,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            h: vec![0.0; n * l],
            scratch: Vec::new(),
        }
    }

    fn draw_masks(&mut self, rng: &mut Pcg64) {
        let l = self.cfg.dim;
        let mut mask32 = vec![0f32; l];
        for k in 0..self.cfg.n_nodes() {
            rng.fill_mask(&mut mask32, self.m, &mut self.scratch);
            for (dst, &src) in self.h[k * l..(k + 1) * l].iter_mut().zip(mask32.iter()) {
                *dst = src as f64;
            }
        }
    }

    pub fn step_with_masks(
        &mut self,
        data: StepData<'_>,
        masks: &PartialMasks,
        comm: &mut CommMeter,
    ) {
        self.h.copy_from_slice(&masks.h);
        self.step_inner(data, comm);
    }

    fn step_inner(&mut self, data: StepData<'_>, comm: &mut CommMeter) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);

        // Self-only adapt.
        for k in 0..n {
            let uk = &u[k * l..(k + 1) * l];
            let wk = &self.w[k * l..(k + 1) * l];
            let e = d[k] - dot(uk, wk);
            let mu_k = self.cfg.mu[k];
            let psi_k = &mut self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                psi_k[j] = wk[j] + mu_k * uk[j] * e;
            }
        }

        // Masked combine (eq. (8)); each node ships M entries per neighbour.
        for k in 0..n {
            for &lnb in self.cfg.graph.neighbors(k) {
                comm.send(k, lnb, Purpose::Estimate, self.m);
            }
        }
        for k in 0..n {
            let a_kk = self.cfg.a[(k, k)];
            let psi_k: Vec<f64> = self.psi[k * l..(k + 1) * l].to_vec();
            let out = &mut self.wnew[k * l..(k + 1) * l];
            for j in 0..l {
                out[j] = a_kk * psi_k[j];
            }
            for &lnb in self.cfg.graph.neighbors(k) {
                let a_lk = self.cfg.a[(lnb, k)];
                if a_lk == 0.0 {
                    continue;
                }
                let psi_l = &self.psi[lnb * l..(lnb + 1) * l];
                let h_l = &self.h[lnb * l..(lnb + 1) * l];
                for j in 0..l {
                    out[j] += a_lk * (h_l[j] * psi_l[j] + (1.0 - h_l[j]) * psi_k[j]);
                }
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }
}

impl Algorithm for PartialDiffusion {
    fn name(&self) -> &'static str {
        "partial-diffusion"
    }

    fn step(&mut self, data: StepData<'_>, rng: &mut Pcg64, comm: &mut CommMeter) {
        self.draw_masks(rng);
        self.step_inner(data, comm);
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        (0..self.cfg.n_nodes())
            .map(|k| (self.cfg.graph.neighbors(k).len() * self.m) as f64)
            .sum()
    }

    /// Ratio vs. the 2L-per-link diffusion LMS baseline: 2L / M.
    fn compression_ratio(&self) -> Option<f64> {
        Some(2.0 * self.cfg.dim as f64 / self.m as f64)
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 1);
        let c = crate::topology::Combiner::eye(n);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_noiseless() {
        let mut rng = Pcg64::new(6, 0);
        let n = 8;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| -0.1 * j as f64 + 0.5).collect();
        let mut alg = PartialDiffusion::new(cfg(n, l, 0.1), 2);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..1500 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert!(alg.msd(&wo) < 1e-4, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn meter_and_ratio() {
        let n = 6;
        let l = 8;
        let mut alg = PartialDiffusion::new(cfg(n, l, 0.05), 2);
        let mut rng = Pcg64::new(8, 0);
        let mut comm = CommMeter::new(n);
        let u = vec![0.0; n * l];
        let d = vec![0.0; n];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        assert_eq!(comm.scalars(), (6 * 2 * 2) as u64);
        assert!((alg.compression_ratio().unwrap() - 8.0).abs() < 1e-12);
    }

    #[test]
    fn full_mask_equals_plain_combine() {
        // M = L: partial diffusion == standard (A, C=I) diffusion LMS.
        let n = 5;
        let l = 3;
        let network = cfg(n, l, 0.07);
        let mut pd = PartialDiffusion::new(network.clone(), l);
        let mut lms = super::super::DiffusionLms::new(network);
        let mut rng = Pcg64::new(10, 0);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..25 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for (k, dk) in d.iter_mut().enumerate() {
                *dk = u[k * l] * 0.7 + 0.01 * rng.next_gaussian();
            }
            pd.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            lms.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
            for (x, y) in pd.weights().iter().zip(lms.weights().iter()) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }
}
