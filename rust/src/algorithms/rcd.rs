//! Reduced-communication diffusion LMS [29] (paper eq. (7)).
//!
//! C = I (self-only adapt). At each iteration every node k selects a
//! random subset of `m_k` of its neighbours; only the selected neighbours
//! transmit their full intermediate estimates (L scalars). The combine
//! reweights the diagonal so the weights still sum to one:
//!
//!   h_kk,i = 1 − Σ_{l ∈ selected} a_lk,
//!   w_k,i  = h_kk,i ψ_k,i + Σ_{l ∈ selected} a_lk ψ_l,i.

use super::traits::{Algorithm, CommMeter, NetworkConfig, Purpose, StepData};
use crate::rng::Pcg64;

/// Externally supplied neighbour selection for one iteration: row-major
/// (N x N) 0/1, entry [l, k] = 1 iff node k polls neighbour l.
#[derive(Debug, Clone)]
pub struct RcdSelection {
    pub s: Vec<f64>,
}

/// RCD algorithm state.
pub struct Rcd {
    cfg: NetworkConfig,
    /// Number of neighbours polled per iteration (m_k, same for all k,
    /// capped at the node degree).
    pub m_links: usize,
    w: Vec<f64>,
    psi: Vec<f64>,
    wnew: Vec<f64>,
    sel: Vec<f64>, // (N x N) current selection, [l * n + k]
    scratch: Vec<usize>,
}

impl Rcd {
    pub fn new(cfg: NetworkConfig, m_links: usize) -> Self {
        let n = cfg.n_nodes();
        let l = cfg.dim;
        Self {
            cfg,
            m_links,
            w: vec![0.0; n * l],
            psi: vec![0.0; n * l],
            wnew: vec![0.0; n * l],
            sel: vec![0.0; n * n],
            scratch: Vec::new(),
        }
    }

    /// Selection probability p_k = m_k / |N_k| (eq. (6)).
    pub fn selection_probability(&self, k: usize) -> f64 {
        let nk = self.cfg.graph.degree_incl(k) as f64;
        (self.m_links as f64 / nk).min(1.0)
    }

    fn draw_selection(&mut self, rng: &mut Pcg64) {
        let n = self.cfg.n_nodes();
        self.sel.iter_mut().for_each(|x| *x = 0.0);
        for k in 0..n {
            let nbrs = self.cfg.graph.neighbors(k);
            let m = self.m_links.min(nbrs.len());
            rng.sample_indices(nbrs.len(), m, &mut self.scratch);
            for &idx in self.scratch.iter() {
                let l = nbrs[idx];
                self.sel[l * n + k] = 1.0;
            }
        }
    }

    /// One iteration with an externally supplied selection pattern.
    pub fn step_with_selection(
        &mut self,
        data: StepData<'_>,
        selection: &RcdSelection,
        comm: &mut CommMeter,
    ) {
        self.sel.copy_from_slice(&selection.s);
        self.step_inner(data, comm);
    }

    fn step_inner(&mut self, data: StepData<'_>, comm: &mut CommMeter) {
        let n = self.cfg.n_nodes();
        let l = self.cfg.dim;
        let (u, d) = (data.u, data.d);

        // Self-only adapt.
        for k in 0..n {
            let uk = &u[k * l..(k + 1) * l];
            let wk = &self.w[k * l..(k + 1) * l];
            let e = d[k] - dot(uk, wk);
            let mu_k = self.cfg.mu[k];
            let psi_k = &mut self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                psi_k[j] = wk[j] + mu_k * uk[j] * e;
            }
        }

        // Combine over the selected subset with diagonal reweighting.
        for k in 0..n {
            let mut h_kk = 1.0;
            let out = &mut self.wnew[k * l..(k + 1) * l];
            out.iter_mut().for_each(|x| *x = 0.0);
            for &lnb in self.cfg.graph.neighbors(k) {
                if self.sel[lnb * n + k] == 0.0 {
                    continue;
                }
                // Selected neighbour transmits its full psi (L scalars).
                comm.send(lnb, k, Purpose::Estimate, l);
                let a_lk = self.cfg.a[(lnb, k)];
                h_kk -= a_lk;
                let psi_l = &self.psi[lnb * l..(lnb + 1) * l];
                for j in 0..l {
                    out[j] += a_lk * psi_l[j];
                }
            }
            let psi_k = &self.psi[k * l..(k + 1) * l];
            for j in 0..l {
                out[j] += h_kk * psi_k[j];
            }
        }
        std::mem::swap(&mut self.w, &mut self.wnew);
    }
}

impl Algorithm for Rcd {
    fn name(&self) -> &'static str {
        "rcd"
    }

    fn step(&mut self, data: StepData<'_>, rng: &mut Pcg64, comm: &mut CommMeter) {
        self.draw_selection(rng);
        self.step_inner(data, comm);
    }

    fn weights(&self) -> &[f64] {
        &self.w
    }

    fn weights_mut(&mut self) -> &mut [f64] {
        &mut self.w
    }

    fn network(&self) -> &NetworkConfig {
        &self.cfg
    }

    fn network_mut(&mut self) -> &mut NetworkConfig {
        &mut self.cfg
    }

    fn reset(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0.0);
        self.psi.iter_mut().for_each(|x| *x = 0.0);
    }

    fn expected_scalars_per_iter(&self) -> f64 {
        let l = self.cfg.dim as f64;
        (0..self.cfg.n_nodes())
            .map(|k| self.m_links.min(self.cfg.graph.neighbors(k).len()) as f64 * l)
            .sum()
    }

    /// Ratio vs. the 2L-per-link diffusion LMS baseline: the expected
    /// per-link traffic is p_k L, so r = 2 / p̄ with p̄ the mean selection
    /// probability.
    fn compression_ratio(&self) -> Option<f64> {
        let n = self.cfg.n_nodes();
        let p_mean: f64 =
            (0..n).map(|k| self.selection_probability(k)).sum::<f64>() / n as f64;
        Some(2.0 / p_mean)
    }
}

#[inline]
fn dot(a: &[f64], b: &[f64]) -> f64 {
    crate::linalg::kernels::dot(a, b)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn cfg(n: usize, l: usize, mu: f64) -> NetworkConfig {
        let graph = Graph::ring(n, 2);
        let c = crate::topology::Combiner::eye(n);
        let a = combination_matrix(&graph, Rule::Metropolis);
        NetworkConfig { graph, c, a, mu: vec![mu; n], dim: l }
    }

    #[test]
    fn converges_noiseless() {
        let mut rng = Pcg64::new(2, 0);
        let n = 8;
        let l = 4;
        let wo: Vec<f64> = (0..l).map(|j| 0.2 * j as f64 + 0.1).collect();
        let mut alg = Rcd::new(cfg(n, l, 0.1), 2);
        let mut comm = CommMeter::new(n);
        let mut u = vec![0.0; n * l];
        let mut d = vec![0.0; n];
        for _ in 0..1200 {
            for x in u.iter_mut() {
                *x = rng.next_gaussian();
            }
            for k in 0..n {
                d[k] = dot(&u[k * l..(k + 1) * l], &wo);
            }
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        assert!(alg.msd(&wo) < 1e-4, "msd {}", alg.msd(&wo));
    }

    #[test]
    fn meter_matches_expectation() {
        let n = 6;
        let l = 5;
        let mut alg = Rcd::new(cfg(n, l, 0.05), 3);
        let mut rng = Pcg64::new(4, 0);
        let mut comm = CommMeter::new(n);
        let u = vec![0.0; n * l];
        let d = vec![0.0; n];
        for _ in 0..10 {
            alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        }
        // Ring(6,2): every node has 4 neighbours, 3 polled, L scalars each.
        assert_eq!(comm.scalars(), 10 * 6 * 3 * 5);
        assert_eq!(alg.expected_scalars_per_iter() as u64 * 10, comm.scalars());
    }

    #[test]
    fn combine_weights_sum_to_one() {
        // With all psi equal, combine must return the same vector for any
        // random selection (diagonal reweighting).
        let n = 6;
        let l = 3;
        let mut alg = Rcd::new(cfg(n, l, 0.0), 1);
        // mu = 0 keeps psi = w; seed w with a constant row.
        for k in 0..n {
            for j in 0..l {
                alg.w[k * l + j] = 2.5;
            }
        }
        let mut rng = Pcg64::new(9, 0);
        let mut comm = CommMeter::new(n);
        let u = vec![0.3; n * l];
        let d = vec![0.1; n];
        alg.step(StepData { u: &u, d: &d }, &mut rng, &mut comm);
        for &x in alg.weights() {
            assert!((x - 2.5).abs() < 1e-12);
        }
    }

    #[test]
    fn selection_probability_eq6() {
        let alg = Rcd::new(cfg(8, 3, 0.1), 2);
        // Ring(8,2): |N_k| = 5 including self.
        assert!((alg.selection_probability(0) - 2.0 / 5.0).abs() < 1e-12);
    }
}
