//! Mini property-testing harness (the `proptest` substitute, DESIGN.md
//! §2 S14).
//!
//! Generates seeded random cases from composable [`Gen`] closures, runs
//! a property over each, and on failure re-reports the failing seed so
//! the case can be replayed deterministically. A bounded linear "shrink"
//! retries the property on cases drawn with progressively smaller size
//! hints to report a small counterexample when one exists.

use crate::rng::Pcg64;

/// A generator: draws a case from RNG + size hint (1..=255).
pub struct Gen<T> {
    f: Box<dyn Fn(&mut Pcg64, u8) -> T>,
}

impl<T: 'static> Gen<T> {
    pub fn new(f: impl Fn(&mut Pcg64, u8) -> T + 'static) -> Self {
        Self { f: Box::new(f) }
    }

    pub fn sample(&self, rng: &mut Pcg64, size: u8) -> T {
        (self.f)(rng, size)
    }

    pub fn map<U: 'static>(self, g: impl Fn(T) -> U + 'static) -> Gen<U> {
        Gen::new(move |rng, size| g(self.sample(rng, size)))
    }
}

/// usize in [lo, hi], scaled by the size hint.
pub fn usize_in(lo: usize, hi: usize) -> Gen<usize> {
    assert!(lo <= hi);
    Gen::new(move |rng, size| {
        let span = hi - lo;
        let scaled = (span * size as usize) / 255;
        lo + if scaled == 0 { 0 } else { rng.next_below(scaled + 1) }
    })
}

/// f64 in [lo, hi).
pub fn f64_in(lo: f64, hi: f64) -> Gen<f64> {
    Gen::new(move |rng, _| lo + (hi - lo) * rng.next_f64())
}

/// Vector of gaussians with the given length generator.
pub fn gaussian_vec(len: Gen<usize>, sigma: f64) -> Gen<Vec<f64>> {
    Gen::new(move |rng, size| {
        let n = len.sample(rng, size);
        (0..n).map(|_| sigma * rng.next_gaussian()).collect()
    })
}

/// Configuration for a property run.
#[derive(Debug, Clone)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        Self { cases: 100, seed: 0x5eed }
    }
}

/// Run `prop` over generated cases; panics with the failing seed/case on
/// the first failure (after trying smaller sizes for a simpler failure).
pub fn check<T: std::fmt::Debug + 'static>(
    cfg: &PropConfig,
    gen: &Gen<T>,
    prop: impl Fn(&T) -> Result<(), String>,
) {
    for case_idx in 0..cfg.cases {
        // Size ramps up over the run: small cases first.
        let size = (((case_idx * 255) / cfg.cases.max(1)) as u8).max(1);
        let mut rng = Pcg64::new(cfg.seed, case_idx as u64);
        let case = gen.sample(&mut rng, size);
        if let Err(msg) = prop(&case) {
            // Shrink: retry with smaller sizes from the same stream family.
            let mut smallest: Option<(u8, T, String)> = None;
            for s in 1..size {
                let mut rng = Pcg64::new(cfg.seed, case_idx as u64);
                let c = gen.sample(&mut rng, s);
                if let Err(m) = prop(&c) {
                    smallest = Some((s, c, m));
                    break;
                }
            }
            match smallest {
                Some((s, c, m)) => panic!(
                    "property failed (seed {}, case {case_idx}, shrunk to size {s}):\n  {m}\n  case: {c:?}",
                    cfg.seed
                ),
                None => panic!(
                    "property failed (seed {}, case {case_idx}, size {size}):\n  {msg}\n  case: {case:?}",
                    cfg.seed
                ),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let gen = usize_in(0, 10);
        check(&PropConfig::default(), &gen, |&x| {
            if x <= 10 {
                Ok(())
            } else {
                Err(format!("{x} > 10"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_case() {
        let gen = usize_in(0, 100);
        check(&PropConfig { cases: 200, seed: 1 }, &gen, |&x| {
            if x < 50 {
                Ok(())
            } else {
                Err(format!("{x} >= 50"))
            }
        });
    }

    #[test]
    fn generators_are_deterministic() {
        let gen = gaussian_vec(usize_in(1, 8), 1.0);
        let mut r1 = Pcg64::new(3, 3);
        let mut r2 = Pcg64::new(3, 3);
        assert_eq!(gen.sample(&mut r1, 100), gen.sample(&mut r2, 100));
    }

    #[test]
    fn size_scaling() {
        let gen = usize_in(2, 200);
        let mut rng = Pcg64::new(5, 0);
        for _ in 0..50 {
            let small = gen.sample(&mut rng, 1);
            assert!(small <= 2, "size-1 case {small} should be near lo");
        }
    }
}
