//! Config system: experiment presets (the paper's §IV settings, Tables I
//! and II) plus an INI-style config-file / key=value override layer.
//!
//! Precedence: preset defaults < config file < CLI `--set key=value`.

mod ini;
pub use ini::IniDoc;

use crate::coordinator::lanes::LaneCount;
use crate::energy::EnergyParams;

/// Experiment 1 (Fig. 3 left): N = 10, L = 5, M = 3, M_grad = 1,
/// μ = 1e-3, σ²_v = 1e-3, 100 MC runs.
#[derive(Debug, Clone)]
pub struct Exp1Config {
    pub n_nodes: usize,
    pub dim: usize,
    pub m: usize,
    pub m_grad: usize,
    pub mu: f64,
    pub sigma_v2: f64,
    /// Regressor-variance range (Fig. 2 right, Experiment 1 row).
    pub u2_min: f64,
    pub u2_max: f64,
    pub runs: usize,
    pub iters: usize,
    pub seed: u64,
    /// Worker processes the Monte-Carlo runs are sharded across
    /// (1 = in-process; rust engine only — see DESIGN.md §8).
    pub shards: usize,
    /// SoA lane width of the run-batched engine (1 = scalar path;
    /// bit-identical at every width — see DESIGN.md §14).
    pub lanes: LaneCount,
}

impl Default for Exp1Config {
    fn default() -> Self {
        Self {
            n_nodes: 10,
            dim: 5,
            m: 3,
            m_grad: 1,
            mu: 1e-3,
            sigma_v2: 1e-3,
            u2_min: 0.8,
            u2_max: 1.2,
            runs: 100,
            iters: 40_000,
            seed: 2017,
            shards: 1,
            lanes: LaneCount::default(),
        }
    }
}

/// Experiment 2 (Fig. 3 center/right): N = 50, L = 50, μ = 3e-2;
/// MSD-vs-compression-ratio sweeps.
#[derive(Debug, Clone)]
pub struct Exp2Config {
    pub n_nodes: usize,
    pub dim: usize,
    pub mu: f64,
    pub sigma_v2: f64,
    pub u2_min: f64,
    pub u2_max: f64,
    pub runs: usize,
    pub iters: usize,
    pub seed: u64,
    /// Worker processes per sweep point (1 = in-process; rust engine
    /// only — see DESIGN.md §8).
    pub shards: usize,
    /// SoA lane width of the run-batched engine (1 = scalar path;
    /// bit-identical at every width — see DESIGN.md §14).
    pub lanes: LaneCount,
    /// M values for the CD sweep (ratio 2L/(M+L)).
    pub cd_m_values: Vec<usize>,
    /// (M, M_grad) pairs for the DCD sweep (ratio 2L/(M+M_grad)).
    pub dcd_pairs: Vec<(usize, usize)>,
}

impl Default for Exp2Config {
    fn default() -> Self {
        Self {
            n_nodes: 50,
            dim: 50,
            mu: 3e-2,
            sigma_v2: 1e-3,
            // Experiment 2's regressor variances (Fig. 2 bottom-right) are
            // milder than Experiment 1's: with σ²_u ≈ 1 and L = 50,
            // μ = 3e-2 sits at the mean-square stability edge for the
            // heavily-masked CD endpoint (M = 5) — the paper's setup is
            // only consistent with smaller variances.
            u2_min: 0.4,
            u2_max: 0.8,
            runs: 10,
            iters: 4_000,
            seed: 2018,
            shards: 1,
            lanes: LaneCount::default(),
            // Ratios 2L/(M+L): 100/95 ... 100/55 (paper: max 100/55 at M = 5).
            cd_m_values: vec![45, 35, 25, 15, 5],
            // Ratios 2L/(M+M_grad): from 100/90 up to 20 (M + M_grad = 5).
            dcd_pairs: vec![
                (45, 45),
                (35, 35),
                (25, 25),
                (15, 15),
                (10, 10),
                (5, 5),
                (4, 2),
                (3, 2),
                (2, 2),
                (3, 1),
                (2, 1),
            ],
        }
    }
}

/// Experiment 3 (Fig. 4): N = 80 hillside WSN, L = 40, ratio r = 20
/// (CD: 80/65), step sizes from Table II.
#[derive(Debug, Clone)]
pub struct Exp3Config {
    pub n_nodes: usize,
    pub dim: usize,
    pub sigma_v2: f64,
    pub u2_min: f64,
    pub u2_max: f64,
    /// Geometric-graph connection radius (unit square).
    pub radius: f64,
    pub energy: EnergyParams,
    /// Virtual-time horizon (s).
    pub duration: f64,
    pub sample_dt: f64,
    pub runs: usize,
    pub seed: u64,
    /// Worker processes the WSN realizations are sharded across
    /// (1 = in-process; see DESIGN.md §8).
    pub shards: usize,
    /// Also write `exp3_ledger.csv` — the per-node energy/communication
    /// breakdown from the directional ledger (DESIGN.md §9). An output
    /// knob (CLI `--ledger-csv`), deliberately outside the INI
    /// round-trip: it defines no part of the simulation.
    pub ledger_csv: bool,
    // Table II step sizes.
    pub mu_diffusion: f64,
    pub mu_rcd: f64,
    pub mu_partial: f64,
    pub mu_cd: f64,
    pub mu_dcd: f64,
    // Compression settings for r = 20 (L = 40): PM shares M = 4 of 80
    // two-way scalars; DCD shares M + M_grad = 4; CD shares M = 25
    // (r = 80/65); RCD polls 1/10 of neighbours (r = 2/p = 20).
    pub partial_m: usize,
    pub dcd_m: usize,
    pub dcd_m_grad: usize,
    pub cd_m: usize,
    pub rcd_fraction: f64,
}

impl Default for Exp3Config {
    fn default() -> Self {
        Self {
            n_nodes: 80,
            dim: 40,
            sigma_v2: 1e-3,
            u2_min: 0.8,
            u2_max: 1.2,
            radius: 0.18,
            energy: EnergyParams::default(),
            duration: 200_000.0,
            sample_dt: 500.0,
            runs: 4,
            seed: 2019,
            shards: 1,
            ledger_csv: false,
            mu_diffusion: 5.4e-3,
            mu_rcd: 1.14e-2,
            mu_partial: 4.4e-3,
            mu_cd: 4.8e-2,
            mu_dcd: 6e-3,
            partial_m: 4,
            // DCD budget split at r = 20: M + M∇ = 4. The (3,1) split
            // (more estimate sharing) dominates (2,2) in the WSN runs —
            // see EXPERIMENTS.md E3/A2.
            dcd_m: 3,
            dcd_m_grad: 1,
            cd_m: 25,
            rcd_fraction: 0.1,
        }
    }
}

macro_rules! apply_override {
    ($doc:expr, $section:expr, $cfg:expr, { $($key:literal => $field:expr => $ty:ty),+ $(,)? }) => {
        $(
            if let Some(v) = $doc.get($section, $key) {
                $field = v.parse::<$ty>().map_err(|e| {
                    format!("config {}.{}: cannot parse {:?}: {e}", $section, $key, v)
                })?;
            }
        )+
    };
}

impl Exp1Config {
    /// Apply `[exp1]` overrides from an INI document.
    pub fn apply(&mut self, doc: &IniDoc) -> Result<(), String> {
        apply_override!(doc, "exp1", self, {
            "n_nodes" => self.n_nodes => usize,
            "dim" => self.dim => usize,
            "m" => self.m => usize,
            "m_grad" => self.m_grad => usize,
            "mu" => self.mu => f64,
            "sigma_v2" => self.sigma_v2 => f64,
            "runs" => self.runs => usize,
            "iters" => self.iters => usize,
            "seed" => self.seed => u64,
            "shards" => self.shards => usize,
            "lanes" => self.lanes => LaneCount,
        });
        self.validate()
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.m > self.dim || self.m_grad > self.dim {
            return Err("exp1: M, M_grad must be <= L".into());
        }
        if self.runs == 0 || self.iters == 0 {
            return Err("exp1: runs and iters must be positive".into());
        }
        if self.shards == 0 {
            return Err("exp1: shards must be >= 1 (1 = in-process)".into());
        }
        self.lanes.validate().map_err(|e| format!("exp1: {e}"))?;
        Ok(())
    }
}

impl Exp2Config {
    pub fn apply(&mut self, doc: &IniDoc) -> Result<(), String> {
        apply_override!(doc, "exp2", self, {
            "n_nodes" => self.n_nodes => usize,
            "dim" => self.dim => usize,
            "mu" => self.mu => f64,
            "runs" => self.runs => usize,
            "iters" => self.iters => usize,
            "seed" => self.seed => u64,
            "shards" => self.shards => usize,
            "lanes" => self.lanes => LaneCount,
        });
        self.validate()
    }

    /// Semantic checks shared by the INI layer and `run_exp2` (which
    /// also covers programmatic construction).
    pub fn validate(&self) -> Result<(), String> {
        if self.shards == 0 {
            return Err("exp2: shards must be >= 1 (1 = in-process)".into());
        }
        self.lanes.validate().map_err(|e| format!("exp2: {e}"))?;
        Ok(())
    }
}

impl Exp3Config {
    /// Apply `[exp3]` + `[energy]` overrides from an INI document. The
    /// key set covers **every** field, so [`Exp3Config::to_ini_string`]
    /// round-trips losslessly — the contract the WSN shard workers rely
    /// on to replay the exact job (DESIGN.md §8).
    pub fn apply(&mut self, doc: &IniDoc) -> Result<(), String> {
        apply_override!(doc, "exp3", self, {
            "n_nodes" => self.n_nodes => usize,
            "dim" => self.dim => usize,
            "sigma_v2" => self.sigma_v2 => f64,
            "u2_min" => self.u2_min => f64,
            "u2_max" => self.u2_max => f64,
            "radius" => self.radius => f64,
            "duration" => self.duration => f64,
            "sample_dt" => self.sample_dt => f64,
            "runs" => self.runs => usize,
            "seed" => self.seed => u64,
            "shards" => self.shards => usize,
            "mu_diffusion" => self.mu_diffusion => f64,
            "mu_rcd" => self.mu_rcd => f64,
            "mu_partial" => self.mu_partial => f64,
            "mu_cd" => self.mu_cd => f64,
            "mu_dcd" => self.mu_dcd => f64,
            "partial_m" => self.partial_m => usize,
            "dcd_m" => self.dcd_m => usize,
            "dcd_m_grad" => self.dcd_m_grad => usize,
            "cd_m" => self.cd_m => usize,
            "rcd_fraction" => self.rcd_fraction => f64,
        });
        apply_override!(doc, "energy", self, {
            "c_s" => self.energy.c_s => f64,
            "p_leak" => self.energy.p_leak => f64,
            "p_sleep" => self.energy.p_sleep => f64,
            "t_s_min" => self.energy.t_s_min => f64,
            "t_s_max" => self.energy.t_s_max => f64,
            "v_ref" => self.energy.v_ref => f64,
            "eta" => self.energy.eta => f64,
            "e0" => self.energy.e0 => f64,
            "f" => self.energy.f => f64,
            "sigma_n2" => self.energy.sigma_n2 => f64,
            "v_max" => self.energy.v_max => f64,
        });
        if self.shards == 0 {
            return Err("exp3: shards must be >= 1 (1 = in-process)".into());
        }
        Ok(())
    }

    /// Serialize every simulation-defining field (`[exp3]` + `[energy]`;
    /// the `shards` execution knob is deliberately excluded — a shard
    /// worker must never shard recursively). `apply` on the output
    /// reproduces the config exactly: f64 fields go through rust's
    /// shortest-round-trip formatter.
    pub fn to_ini_string(&self) -> String {
        let mut s = String::new();
        s.push_str("[exp3]\n");
        s.push_str(&format!("n_nodes = {}\n", self.n_nodes));
        s.push_str(&format!("dim = {}\n", self.dim));
        s.push_str(&format!("sigma_v2 = {}\n", self.sigma_v2));
        s.push_str(&format!("u2_min = {}\n", self.u2_min));
        s.push_str(&format!("u2_max = {}\n", self.u2_max));
        s.push_str(&format!("radius = {}\n", self.radius));
        s.push_str(&format!("duration = {}\n", self.duration));
        s.push_str(&format!("sample_dt = {}\n", self.sample_dt));
        s.push_str(&format!("runs = {}\n", self.runs));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("mu_diffusion = {}\n", self.mu_diffusion));
        s.push_str(&format!("mu_rcd = {}\n", self.mu_rcd));
        s.push_str(&format!("mu_partial = {}\n", self.mu_partial));
        s.push_str(&format!("mu_cd = {}\n", self.mu_cd));
        s.push_str(&format!("mu_dcd = {}\n", self.mu_dcd));
        s.push_str(&format!("partial_m = {}\n", self.partial_m));
        s.push_str(&format!("dcd_m = {}\n", self.dcd_m));
        s.push_str(&format!("dcd_m_grad = {}\n", self.dcd_m_grad));
        s.push_str(&format!("cd_m = {}\n", self.cd_m));
        s.push_str(&format!("rcd_fraction = {}\n", self.rcd_fraction));
        s.push_str("\n[energy]\n");
        s.push_str(&format!("c_s = {}\n", self.energy.c_s));
        s.push_str(&format!("p_leak = {}\n", self.energy.p_leak));
        s.push_str(&format!("p_sleep = {}\n", self.energy.p_sleep));
        s.push_str(&format!("t_s_min = {}\n", self.energy.t_s_min));
        s.push_str(&format!("t_s_max = {}\n", self.energy.t_s_max));
        s.push_str(&format!("v_ref = {}\n", self.energy.v_ref));
        s.push_str(&format!("eta = {}\n", self.energy.eta));
        s.push_str(&format!("e0 = {}\n", self.energy.e0));
        s.push_str(&format!("f = {}\n", self.energy.f));
        s.push_str(&format!("sigma_n2 = {}\n", self.energy.sigma_n2));
        s.push_str(&format!("v_max = {}\n", self.energy.v_max));
        s
    }

    /// The paper's compression check: all compared algorithms sit at
    /// r = 20 except CD at 80/65.
    pub fn ratios(&self) -> Vec<(String, f64)> {
        let l = self.dim as f64;
        vec![
            ("partial".into(), 2.0 * l / self.partial_m as f64),
            ("dcd".into(), 2.0 * l / (self.dcd_m + self.dcd_m_grad) as f64),
            ("cd".into(), 2.0 * l / (self.cd_m as f64 + l)),
            ("rcd".into(), 2.0 / self.rcd_fraction),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let e1 = Exp1Config::default();
        assert_eq!((e1.n_nodes, e1.dim, e1.m, e1.m_grad), (10, 5, 3, 1));
        assert_eq!(e1.mu, 1e-3);
        assert_eq!(e1.runs, 100);
        let e2 = Exp2Config::default();
        assert_eq!((e2.n_nodes, e2.dim), (50, 50));
        assert_eq!(e2.mu, 3e-2);
        let e3 = Exp3Config::default();
        assert_eq!((e3.n_nodes, e3.dim), (80, 40));
        // Table II step sizes.
        assert_eq!(e3.mu_diffusion, 5.4e-3);
        assert_eq!(e3.mu_rcd, 1.14e-2);
        assert_eq!(e3.mu_partial, 4.4e-3);
        assert_eq!(e3.mu_cd, 4.8e-2);
        assert_eq!(e3.mu_dcd, 6e-3);
    }

    #[test]
    fn exp3_ratios_match_table_ii() {
        let e3 = Exp3Config::default();
        let ratios = e3.ratios();
        let get = |name: &str| ratios.iter().find(|(n, _)| n == name).unwrap().1;
        assert!((get("partial") - 20.0).abs() < 1e-12);
        assert!((get("dcd") - 20.0).abs() < 1e-12);
        assert!((get("rcd") - 20.0).abs() < 1e-12);
        assert!((get("cd") - 80.0 / 65.0).abs() < 1e-12);
    }

    #[test]
    fn overrides_apply() {
        let doc = IniDoc::parse("[exp1]\nruns = 5\nmu = 0.01\n").unwrap();
        let mut cfg = Exp1Config::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.runs, 5);
        assert_eq!(cfg.mu, 0.01);
    }

    #[test]
    fn exp3_ini_roundtrip_is_lossless() {
        let mut cfg = Exp3Config {
            n_nodes: 17,
            dim: 9,
            sigma_v2: 2.5e-3,
            u2_min: 0.45,
            u2_max: 1.35,
            radius: 0.27,
            duration: 12_345.5,
            sample_dt: 111.25,
            runs: 3,
            seed: 77,
            mu_dcd: 7.3e-3,
            rcd_fraction: 0.15,
            ..Exp3Config::default()
        };
        cfg.energy.eta = 0.75;
        cfg.energy.sigma_n2 = 2e-6;
        let text = cfg.to_ini_string();
        let doc = IniDoc::parse(&text).unwrap();
        let mut back = Exp3Config::default();
        back.apply(&doc).unwrap();
        // Field-by-field spot checks incl. the energy section; the f64
        // fields must round-trip exactly (shard workers replay this).
        assert_eq!(back.n_nodes, 17);
        assert_eq!(back.dim, 9);
        assert_eq!(back.sigma_v2.to_bits(), cfg.sigma_v2.to_bits());
        assert_eq!(back.radius.to_bits(), cfg.radius.to_bits());
        assert_eq!(back.duration.to_bits(), cfg.duration.to_bits());
        assert_eq!(back.sample_dt.to_bits(), cfg.sample_dt.to_bits());
        assert_eq!(back.mu_dcd.to_bits(), cfg.mu_dcd.to_bits());
        assert_eq!(back.mu_cd.to_bits(), cfg.mu_cd.to_bits());
        assert_eq!(back.rcd_fraction.to_bits(), cfg.rcd_fraction.to_bits());
        assert_eq!(back.energy.eta.to_bits(), cfg.energy.eta.to_bits());
        assert_eq!(back.energy.sigma_n2.to_bits(), cfg.energy.sigma_n2.to_bits());
        assert_eq!(back.seed, 77);
        assert_eq!(back.runs, 3);
        // `shards` is an execution knob, not part of the job payload.
        assert_eq!(back.shards, 1);
    }

    #[test]
    fn shards_zero_rejected_in_configs() {
        let doc = IniDoc::parse("[exp1]\nshards = 0\n").unwrap();
        assert!(Exp1Config::default().apply(&doc).is_err());
        let doc = IniDoc::parse("[exp2]\nshards = 0\n").unwrap();
        assert!(Exp2Config::default().apply(&doc).is_err());
        let doc = IniDoc::parse("[exp3]\nshards = 0\n").unwrap();
        assert!(Exp3Config::default().apply(&doc).is_err());
    }

    #[test]
    fn lanes_key_parses_and_rejects_zero() {
        let doc = IniDoc::parse("[exp1]\nlanes = auto\n").unwrap();
        let mut cfg = Exp1Config::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.lanes, LaneCount::Auto);
        let doc = IniDoc::parse("[exp2]\nlanes = 4\n").unwrap();
        let mut cfg = Exp2Config::default();
        cfg.apply(&doc).unwrap();
        assert_eq!(cfg.lanes, LaneCount::Fixed(4));
        // 0, negatives and overflow all fail through LaneCount's parser.
        for bad in ["0", "-2", "99999999999999999999"] {
            let doc = IniDoc::parse(&format!("[exp1]\nlanes = {bad}\n")).unwrap();
            let err = Exp1Config::default().apply(&doc).unwrap_err();
            assert!(err.contains("lanes"), "{err}");
        }
    }

    #[test]
    fn bad_override_rejected() {
        let doc = IniDoc::parse("[exp1]\nruns = banana\n").unwrap();
        let mut cfg = Exp1Config::default();
        assert!(cfg.apply(&doc).is_err());
        let doc = IniDoc::parse("[exp1]\nm = 99\n").unwrap();
        let mut cfg = Exp1Config::default();
        assert!(cfg.apply(&doc).is_err());
    }
}
