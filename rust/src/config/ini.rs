//! Tiny INI-style parser for experiment config files:
//!
//! ```ini
//! # comment
//! [exp1]
//! runs = 100
//! mu = 1e-3
//! ```
//!
//! Sections group keys; `key = value` with `#`/`;` comments. Values are
//! kept as strings; typed parsing happens at the consumer.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct IniDoc {
    sections: BTreeMap<String, BTreeMap<String, String>>,
}

impl IniDoc {
    pub fn parse(src: &str) -> Result<Self, String> {
        let mut doc = IniDoc::default();
        let mut current = String::new();
        for (lineno, raw) in src.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| format!("line {}: unterminated section", lineno + 1))?;
                current = name.trim().to_string();
                doc.sections.entry(current.clone()).or_default();
            } else if let Some((k, v)) = line.split_once('=') {
                doc.sections
                    .entry(current.clone())
                    .or_default()
                    .insert(k.trim().to_string(), v.trim().to_string());
            } else {
                return Err(format!("line {}: expected `key = value`", lineno + 1));
            }
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self, String> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| format!("{}: {e}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&str> {
        self.sections.get(section)?.get(key).map(String::as_str)
    }

    /// Insert/override a value using `section.key=value` dotted syntax
    /// (CLI `--set`).
    pub fn set_dotted(&mut self, dotted: &str) -> Result<(), String> {
        let (path, value) = dotted
            .split_once('=')
            .ok_or_else(|| format!("--set {dotted:?}: expected section.key=value"))?;
        let (section, key) = path
            .split_once('.')
            .ok_or_else(|| format!("--set {dotted:?}: expected section.key=value"))?;
        self.sections
            .entry(section.trim().to_string())
            .or_default()
            .insert(key.trim().to_string(), value.trim().to_string());
        Ok(())
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    match line.find(['#', ';']) {
        Some(pos) => &line[..pos],
        None => line,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_comments() {
        let doc = IniDoc::parse(
            "# top comment\n[a]\nx = 1 ; inline\ny = hello world\n\n[b]\nz=2\n",
        )
        .unwrap();
        assert_eq!(doc.get("a", "x"), Some("1"));
        assert_eq!(doc.get("a", "y"), Some("hello world"));
        assert_eq!(doc.get("b", "z"), Some("2"));
        assert_eq!(doc.get("b", "missing"), None);
        assert_eq!(doc.sections().count(), 2);
    }

    #[test]
    fn rejects_malformed() {
        assert!(IniDoc::parse("[unterminated\n").is_err());
        assert!(IniDoc::parse("not a kv line\n").is_err());
    }

    #[test]
    fn set_dotted_overrides() {
        let mut doc = IniDoc::parse("[exp1]\nruns = 1\n").unwrap();
        doc.set_dotted("exp1.runs=9").unwrap();
        doc.set_dotted("exp2.iters = 50").unwrap();
        assert_eq!(doc.get("exp1", "runs"), Some("9"));
        assert_eq!(doc.get("exp2", "iters"), Some("50"));
        assert!(doc.set_dotted("no-equals").is_err());
        assert!(doc.set_dotted("nodot=1").is_err());
    }
}
