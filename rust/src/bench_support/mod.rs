//! Bench harness (the `criterion` substitute, DESIGN.md §2 S15).
//!
//! Warms up, runs timed repetitions until a time budget is exhausted,
//! and reports median / IQR. Benches print paper-style tables so
//! `cargo bench` regenerates every figure/table of the evaluation.

use std::time::{Duration, Instant};

/// One measured statistic.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub median: Duration,
    pub p25: Duration,
    pub p75: Duration,
    pub iters: usize,
}

impl BenchStats {
    pub fn per_unit(&self, units: usize) -> f64 {
        self.median.as_secs_f64() / units.max(1) as f64
    }
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} median {:>12?}  IQR [{:>10?} … {:>10?}]  ({} iters)",
            self.name, self.median, self.p25, self.p75, self.iters
        )
    }
}

/// Time `f` repeatedly within `budget`, after `warmup` runs.
pub fn bench(name: &str, warmup: usize, budget: Duration, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchStats {
        name: name.to_string(),
        median: q(0.5),
        p25: q(0.25),
        p75: q(0.75),
        iters: samples.len(),
    }
}

/// Simple aligned table printer for paper-style outputs.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

/// `--fast` support: benches honour DCD_BENCH_FAST=1 to shrink workloads
/// (used by `make test` smoke and CI-style runs).
pub fn fast_mode() -> bool {
    std::env::var("DCD_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--fast")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_quartiles() {
        let stats = bench("noop", 2, Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(stats.p25 <= stats.median);
        assert!(stats.median <= stats.p75);
        assert!(stats.iters >= 3);
        assert!(stats.per_unit(10) >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["algo", "msd"]);
        t.row(&["dcd".into(), "-38.2".into()]);
        t.print();
    }
}
