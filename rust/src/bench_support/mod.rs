//! Bench harness (the `criterion` substitute, DESIGN.md §2 S15).
//!
//! Warms up, runs timed repetitions until a time budget is exhausted,
//! and reports median / IQR. Benches print paper-style tables so
//! `cargo bench` regenerates every figure/table of the evaluation.

use crate::jsonio::{obj, Json};
use std::path::Path;
use std::time::{Duration, Instant};

/// One measured statistic.
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub median: Duration,
    pub p25: Duration,
    pub p75: Duration,
    pub iters: usize,
}

impl BenchStats {
    pub fn per_unit(&self, units: usize) -> f64 {
        self.median.as_secs_f64() / units.max(1) as f64
    }

    /// Median iterations per second (0 when the median rounds to zero).
    pub fn iters_per_sec(&self) -> f64 {
        let s = self.median.as_secs_f64();
        if s > 0.0 {
            1.0 / s
        } else {
            0.0
        }
    }
}

/// A machine-readable bench record destined for a `BENCH_*.json` file
/// (the perf trajectory future PRs regress against).
#[derive(Debug, Clone)]
pub struct BenchRecord {
    /// Operation name, e.g. `"apply_into"`.
    pub name: String,
    /// Workload configuration, e.g. `"NL=800"`.
    pub config: String,
    /// Median wall-clock nanoseconds per call.
    pub median_ns: f64,
    /// Median calls per second.
    pub iters_per_sec: f64,
}

impl BenchRecord {
    pub fn from_stats(stats: &BenchStats, name: &str, config: &str) -> Self {
        Self {
            name: name.to_string(),
            config: config.to_string(),
            median_ns: stats.median.as_secs_f64() * 1e9,
            iters_per_sec: stats.iters_per_sec(),
        }
    }
}

/// Write bench records as a `BENCH_*.json` document:
/// `{"title": ..., "records": [{"name", "config", "median_ns",
/// "iters_per_sec"}, ...]}`.
pub fn write_bench_json(
    path: impl AsRef<Path>,
    title: &str,
    records: &[BenchRecord],
) -> std::io::Result<()> {
    let arr = Json::Arr(
        records
            .iter()
            .map(|r| {
                obj(vec![
                    ("name", Json::Str(r.name.clone())),
                    ("config", Json::Str(r.config.clone())),
                    ("median_ns", Json::Num(r.median_ns)),
                    ("iters_per_sec", Json::Num(r.iters_per_sec)),
                ])
            })
            .collect(),
    );
    let doc = obj(vec![("title", Json::Str(title.to_string())), ("records", arr)]);
    if let Some(parent) = path.as_ref().parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, doc.to_string_pretty())
}

impl std::fmt::Display for BenchStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<42} median {:>12?}  IQR [{:>10?} … {:>10?}]  ({} iters)",
            self.name, self.median, self.p25, self.p75, self.iters
        )
    }
}

/// Time `f` repeatedly within `budget`, after `warmup` runs.
pub fn bench(name: &str, warmup: usize, budget: Duration, mut f: impl FnMut()) -> BenchStats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.len() < 3 {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort();
    let q = |p: f64| samples[((samples.len() - 1) as f64 * p) as usize];
    BenchStats {
        name: name.to_string(),
        median: q(0.5),
        p25: q(0.25),
        p75: q(0.75),
        iters: samples.len(),
    }
}

/// Simple aligned table printer for paper-style outputs.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self { header: header.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.header);
        println!("{}", widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>());
        for row in &self.rows {
            line(row);
        }
    }
}

/// `--fast` support: benches honour DCD_BENCH_FAST=1 to shrink workloads
/// (used by `make test` smoke and CI-style runs).
pub fn fast_mode() -> bool {
    std::env::var("DCD_BENCH_FAST").map(|v| v == "1").unwrap_or(false)
        || std::env::args().any(|a| a == "--fast")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_ordered_quartiles() {
        let stats = bench("noop", 2, Duration::from_millis(20), || {
            std::hint::black_box(1 + 1);
        });
        assert!(stats.p25 <= stats.median);
        assert!(stats.median <= stats.p75);
        assert!(stats.iters >= 3);
        assert!(stats.per_unit(10) >= 0.0);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["algo", "msd"]);
        t.row(&["dcd".into(), "-38.2".into()]);
        t.print();
    }

    #[test]
    fn bench_json_roundtrip() {
        let dir = std::env::temp_dir().join("dcd_bench_json_test");
        let path = dir.join("BENCH_test.json");
        let stats = bench("noop", 0, Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        let rec = BenchRecord::from_stats(&stats, "apply", "NL=50");
        assert!(rec.iters_per_sec >= 0.0);
        write_bench_json(&path, "theory ops", &[rec]).unwrap();
        let doc =
            crate::jsonio::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(doc.get("title").as_str(), Some("theory ops"));
        let records = doc.get("records").as_arr().unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].get("name").as_str(), Some("apply"));
        assert_eq!(records[0].get("config").as_str(), Some("NL=50"));
        std::fs::remove_dir_all(&dir).ok();
    }
}
