//! Moments of the random selection vectors.
//!
//! `h_{k,i}` (resp. `q_{k,i}`) is a 0/1 vector with exactly M (resp.
//! M_grad) ones among L entries, all outcomes equally likely, i.i.d.
//! over time and nodes (paper's Assumption 2). Exchangeability gives,
//! for one vector p with m ones:
//!
//!   E[p_i]       = m/L
//!   E[p_i p_j]   = m/L                     (i = j)
//!                = (m/L)·(m−1)/(L−1)       (i ≠ j)
//!
//! which are exactly the paper's identities (13), (48), (73).

/// Pairwise moments for one family of selection vectors (all nodes share
/// the same (m, L)).
#[derive(Debug, Clone, Copy)]
pub struct MaskMoments {
    /// Number of selected entries m.
    pub m: usize,
    /// Vector length L.
    pub l: usize,
}

impl MaskMoments {
    /// Moments of a selection vector with `m` ones among `l` entries.
    pub fn new(m: usize, l: usize) -> Self {
        assert!(m <= l && l >= 1);
        Self { m, l }
    }

    /// E[p_i] = m/L.
    #[inline]
    pub fn mean(&self) -> f64 {
        self.m as f64 / self.l as f64
    }

    /// E[p_{a,i} p_{b,j}] for masks of nodes `a`, `b` and the entry
    /// relation `same_entry` (i == j).
    #[inline]
    pub fn pair(&self, a: usize, b: usize, same_entry: bool) -> f64 {
        let p = self.mean();
        if a != b {
            p * p
        } else if same_entry {
            p
        } else if self.l == 1 {
            // Degenerate: only one entry, i ≠ j cannot happen; return 0.
            0.0
        } else {
            p * (self.m as f64 - 1.0) / (self.l as f64 - 1.0)
        }
    }

    /// E[p_{a,i} (1 − p_{b,j})].
    #[inline]
    pub fn pair_comp(&self, a: usize, b: usize, same_entry: bool) -> f64 {
        self.mean() - self.pair(a, b, same_entry)
    }

    /// E[(1 − p_{a,i})(1 − p_{b,j})].
    #[inline]
    pub fn comp_comp(&self, a: usize, b: usize, same_entry: bool) -> f64 {
        1.0 - 2.0 * self.mean() + self.pair(a, b, same_entry)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    /// Brute-force MC check of the exchangeable pair moments — this pins
    /// the closed forms behind the paper's (48)/(73).
    #[test]
    fn pair_moments_match_monte_carlo() {
        let (m, l) = (3usize, 5usize);
        let mm = MaskMoments::new(m, l);
        let mut rng = Pcg64::new(99, 0);
        let trials = 200_000;
        let mut scratch = Vec::new();
        let mut mask = vec![0f32; l];
        let (mut e_i, mut e_ii, mut e_ij) = (0.0, 0.0, 0.0);
        for _ in 0..trials {
            rng.fill_mask(&mut mask, m, &mut scratch);
            e_i += mask[0] as f64;
            e_ii += (mask[1] * mask[1]) as f64;
            e_ij += (mask[0] * mask[2]) as f64;
        }
        let t = trials as f64;
        assert!((e_i / t - mm.mean()).abs() < 5e-3);
        assert!((e_ii / t - mm.pair(0, 0, true)).abs() < 5e-3);
        assert!((e_ij / t - mm.pair(0, 0, false)).abs() < 5e-3);
        // Independent masks factorize.
        assert!((mm.pair(0, 1, true) - mm.mean() * mm.mean()).abs() < 1e-15);
    }

    /// The matrix identity (48): E{QΣQ} = (M/L)[(1 − (M−1)/(L−1)) I⊙Σ
    /// + (M−1)/(L−1) Σ] — reconstructed entrywise from `pair`.
    #[test]
    fn identity_48_from_pair_moments() {
        let (m, l) = (2usize, 4usize);
        let mm = MaskMoments::new(m, l);
        let p = mm.mean();
        let gamma = (m as f64 - 1.0) / (l as f64 - 1.0);
        // Entry (i,j) of E{QΣQ} is E[q_i q_j] Σ_{ij}.
        for same in [true, false] {
            let expect = if same { p } else { p * gamma };
            assert!((mm.pair(0, 0, same) - expect).abs() < 1e-15);
        }
    }

    #[test]
    fn degenerate_full_and_empty_masks() {
        let full = MaskMoments::new(4, 4);
        assert_eq!(full.mean(), 1.0);
        assert_eq!(full.pair(0, 0, false), 1.0);
        assert_eq!(full.comp_comp(0, 0, false), 0.0);
        let empty = MaskMoments::new(0, 4);
        assert_eq!(empty.mean(), 0.0);
        assert_eq!(empty.pair(0, 0, true), 0.0);
        assert_eq!(empty.comp_comp(0, 0, true), 1.0);
        let single = MaskMoments::new(1, 1);
        assert_eq!(single.pair(0, 0, true), 1.0);
    }
}
