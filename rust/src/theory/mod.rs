//! Theory engine: closed-form mean and mean-square models of DCD
//! (paper §III-A / §III-B).
//!
//! Scope matches the paper's analysis setting: `A = I`, `C` doubly
//! stochastic, Gaussian regressors with `R_{u,k} = σ²_{u,k} I_L`, and the
//! small-step-size approximation (83) (`E{R_{u,i} Φ R_{u,i}} ≈ R_u Φ R_u`).
//!
//! Implementation note (DESIGN.md §2, S6): rather than transcribing the
//! appendix's P₁–P₆ matrix identities, the weighted-variance operator
//! Σ ↦ Σ' = E{𝓑ᵢᵀ Σ 𝓑ᵢ} is built from first principles. With
//! `R_{u,k} = σ²_{u,k} I_L`, every block of the error-recursion matrix
//! 𝓑ᵢ = I − 𝓜𝓧ᵢ is a *diagonal* random matrix:
//!
//!   [𝓧ᵢ]_{kℓ} = δ_{kℓ} Σ_m c_{mk}(σ²_m Q_m H_k + σ²_k (I−Q_m))
//!             + c_{ℓk} σ²_ℓ Q_ℓ (I−H_k)                      (from (25))
//!
//! so E{[𝓧]ᵀ_{ka} Φ [𝓧]_{ℓb}} = G ⊙ Φ_{kℓ} with G_{ij} = E[x_{ka,i} x_{ℓb,j}],
//! and — by the exchangeability of the without-replacement selection
//! vectors — G takes only two values (i = j vs i ≠ j). The operator is
//! therefore precomputed as a sparse set of per-block (g_off, g_diag)
//! coefficients, making one application O(N²·deg²·L²).
//!
//! The same machinery yields the driving-noise term
//! trace(E{𝓖ᵢᵀ Σ 𝓖ᵢ} 𝓢) of (42), and the module cross-validates every
//! closed form against brute-force Monte-Carlo over random masks (tests).
//!
//! [`ImpairedMsdModel`] extends the analysis to the coordinator's
//! link-impairment layer (per-link Bernoulli drops, probabilistic
//! gating, quantized state): the same operator with every combiner
//! product replaced by its link-state expectation, plus a quantization
//! noise floor — see DESIGN.md §7 and `theory/impaired.rs`.

mod impaired;
mod linkstate;
mod mean;
mod moments;
mod msd;

pub use impaired::ImpairedMsdModel;
pub use mean::MeanModel;
pub use moments::MaskMoments;
pub use msd::{MsdModel, MsdTrajectory, MsdWorkspace};

use crate::linalg::Mat;

/// Problem description consumed by the theory models.
#[derive(Debug, Clone)]
pub struct TheorySetup {
    pub n_nodes: usize,
    pub dim: usize,
    /// Entries shared per estimate (M).
    pub m: usize,
    /// Entries shared per gradient (M_grad).
    pub m_grad: usize,
    /// Right-stochastic (here: doubly stochastic) adapt combiner, [l, k].
    pub c: Mat,
    /// Per-node step sizes.
    pub mu: Vec<f64>,
    /// Per-node regressor variances σ²_{u,k}.
    pub sigma_u2: Vec<f64>,
    /// Per-node noise variances σ²_{v,k}.
    pub sigma_v2: Vec<f64>,
}

impl TheorySetup {
    /// Reject dimension mismatches, out-of-range mask sizes, and a
    /// non-doubly-stochastic adapt combiner (the analysis setting).
    pub fn validate(&self) -> Result<(), String> {
        let n = self.n_nodes;
        if self.c.rows() != n || self.c.cols() != n {
            return Err("C dimension mismatch".into());
        }
        if self.mu.len() != n || self.sigma_u2.len() != n || self.sigma_v2.len() != n {
            return Err("per-node vector length mismatch".into());
        }
        if self.m > self.dim || self.m_grad > self.dim {
            return Err("M, M_grad must be <= L".into());
        }
        if self.dim < 1 {
            return Err("L must be >= 1".into());
        }
        for l in 0..n {
            let row: f64 = self.c.row(l).iter().sum();
            let col: f64 = (0..n).map(|k| self.c[(k, l)]).sum();
            if (row - 1.0).abs() > 1e-9 || (col - 1.0).abs() > 1e-9 {
                return Err("C must be doubly stochastic for the analysis".into());
            }
        }
        Ok(())
    }

    /// R_k = Σ_l c_{lk} R_{u_l} — as a scalar multiple of I (eq. (34)).
    pub fn r_k_scale(&self, k: usize) -> f64 {
        (0..self.n_nodes)
            .map(|l| self.c[(l, k)] * self.sigma_u2[l])
            .sum()
    }
}
