//! Impaired-link mean-square model (DESIGN.md §7): the paper's §III
//! analysis extended to the probabilistic combination matrices of the
//! coordinator's link-impairment layer, à la Arablouei et al.
//! (arXiv:1408.5845).
//!
//! The model consumes the *same* [`LinkImpairments`] spec the
//! coordinator executes. Under independent Bernoulli link states the
//! error-recursion matrix 𝓑ᵢ = I − 𝓜𝓧ᵢ(C(i), H, Q) is random in both
//! the selection masks and the effective adapt combiner `C(i)`, and the
//! two sources are independent, so
//!
//! * the mean matrix is 𝓑̄ = I − 𝓜 E{𝓧} — the ideal construction
//!   evaluated at the *expected* combiner C̄ = E{C(i)} (𝓧 is linear in
//!   the combiner entries);
//! * the weighted-variance operator Σ ↦ E{𝓑ᵢᵀΣ𝓑ᵢ} keeps the ideal
//!   structure 𝓑̄ᵀΣ + Σ𝓑̄ − Σ + Y(𝓜Σ𝓜), with every quadratic and
//!   noise coefficient's combiner product `c_{mk} c_{nl}` replaced by
//!   the link-state second moment `E[C_{mk} C_{nl}]`
//!   (`theory/linkstate.rs`, closed form for Bernoulli links);
//! * quantization enters as an additive white term in the driving
//!   covariance: a mid-tread quantizer of step Δ injects per-entry
//!   variance Δ²/12 per iteration, i.e. `(Δ²/12)·tr(Σ)` in the variance
//!   recursion.
//!
//! Everything else — the allocation-free fast path, the ping-pong
//! trajectory/steady-state loops, the operator-level stability radius —
//! is the ideal [`MsdModel`] engine, reused verbatim via its
//! crate-internal `from_parts` constructor. At zero impairment the substituted
//! coefficients are *bit-identical* to the ideal ones (the correction
//! terms are exact float zeros), so the impaired model degenerates to
//! [`MsdModel`] exactly (tested to 1e-12 in
//! `rust/tests/theory_impaired.rs`).
//!
//! Scope and assumptions (DESIGN.md §7 for the full list): the paper's
//! analysis setting `A = I` and doubly stochastic pristine `C`; gating
//! must be `always` or `prob:p` (event-triggered gating is
//! state-dependent and has no product-form link-state distribution);
//! the white-noise quantization model is accurate while per-iteration
//! estimate increments exceed Δ.

use super::linkstate::LinkStateMoments;
use super::msd::{
    build_noise_coeffs, build_quad_terms, BOperator, MsdModel, MsdTrajectory, MsdWorkspace,
};
use super::TheorySetup;
use crate::coordinator::impairments::LinkImpairments;
use crate::linalg::Mat;

/// Mean-square model of DCD under per-link drops, probabilistic gating
/// and quantized state — the theoretical anchor for the scenario
/// subsystem's impaired presets (`lossy-geometric` etc.).
pub struct ImpairedMsdModel {
    inner: MsdModel,
    imp: LinkImpairments,
}

impl ImpairedMsdModel {
    /// Build the model for `setup` (the *pristine* network: the paper's
    /// validation rules apply to it, not to the expected combiner) under
    /// the impairment spec `imp`.
    ///
    /// Errors on invalid setups/specs and on event-triggered gating,
    /// which admits no closed-form link-state distribution.
    pub fn new(setup: TheorySetup, imp: &LinkImpairments) -> Result<Self, String> {
        setup.validate()?;
        imp.validate()?;
        let tx_prob = imp.gating.transmit_prob().ok_or_else(|| {
            format!(
                "impaired theory: gating {} is state-dependent and has no \
                 closed-form link-state distribution (DESIGN.md §7)",
                imp.gating
            )
        })?;
        let lm = LinkStateMoments::new(&setup.c, imp.drop.mean_drop(), tx_prob);
        let eff = TheorySetup { c: lm.mean_matrix(), ..setup };
        let bop = BOperator::build(&eff);
        let quad = build_quad_terms(&eff, &lm);
        let w_noise = build_noise_coeffs(&eff, &lm);
        let quant_tr = imp.quant_step * imp.quant_step / 12.0;
        Ok(Self {
            inner: MsdModel::from_parts(eff, bop, quad, w_noise, quant_tr),
            imp: imp.clone(),
        })
    }

    /// The underlying mean-square engine (operator application, EMSE
    /// weightings, workspaces) — identical API to the ideal model.
    pub fn model(&self) -> &MsdModel {
        &self.inner
    }

    /// The impairment spec the model was built for.
    pub fn impairments(&self) -> &LinkImpairments {
        &self.imp
    }

    /// The expected adapt combiner C̄ = E{C(i)} the mean recursion runs
    /// on (also available via [`MsdModel::setup`] on [`Self::model`]).
    pub fn c_bar(&self) -> &Mat {
        &self.inner.setup().c
    }

    /// ρ(𝓑̄) — the algorithm converges in the mean under the impairment
    /// model iff this is < 1. Matrix-free above the dense size limit.
    pub fn mean_rho(&self) -> f64 {
        self.inner.mean_radius(5000)
    }

    /// Mean stability under the impairment model.
    pub fn is_mean_stable(&self) -> bool {
        self.mean_rho() < 1.0
    }

    /// A scratch workspace sized for this model.
    pub fn workspace(&self) -> MsdWorkspace {
        self.inner.workspace()
    }

    /// Reference (allocating) application of the impaired variance
    /// operator Σ ↦ E{𝓑ᵢᵀΣ𝓑ᵢ}.
    pub fn apply(&self, sigma: &Mat) -> Mat {
        self.inner.apply(sigma)
    }

    /// Allocation-free fast path of the impaired variance operator
    /// (symmetric Σ; see [`MsdModel::apply_into`]).
    pub fn apply_into(&self, sigma: &Mat, ws: &mut MsdWorkspace, out: &mut Mat) {
        self.inner.apply_into(sigma, ws, out)
    }

    /// Per-iteration driving-noise injection, including the quantization
    /// floor `(Δ²/12)·tr(Σ)`.
    pub fn noise(&self, sigma: &Mat) -> f64 {
        self.inner.noise(sigma)
    }

    /// Theoretical network-MSD learning curve under the impairment model.
    pub fn learning_curve(&self, wo: &[f64], iters: usize) -> MsdTrajectory {
        self.inner.learning_curve(wo, iters)
    }

    /// Theoretical network-MSD trajectory (see [`MsdModel::trajectory`]).
    pub fn trajectory(&self, wo: &[f64], iters: usize) -> MsdTrajectory {
        self.inner.trajectory(wo, iters)
    }

    /// MSD/EMSE-style weighted trajectory (see
    /// [`MsdModel::trajectory_weighted`]).
    pub fn trajectory_weighted(
        &self,
        wo: &[f64],
        iters: usize,
        weighting: Option<&[f64]>,
    ) -> MsdTrajectory {
        self.inner.trajectory_weighted(wo, iters, weighting)
    }

    /// Steady-state MSD under the impairment model (see
    /// [`MsdModel::steady_state`]).
    pub fn steady_state(&self, wo: &[f64], tol: f64, max_iters: usize) -> (f64, usize) {
        self.inner.steady_state(wo, tol, max_iters)
    }

    /// Mean-square stability radius ρ(𝓕) of the impaired operator.
    pub fn ms_stability_radius(&self, iters: usize) -> f64 {
        self.inner.ms_stability_radius(iters)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algorithms::{Algorithm, CommMeter, Dcd, NetworkConfig};
    use crate::coordinator::impairments::{DropModel, Gating, ImpairmentState};
    use crate::rng::Pcg64;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn setup(n: usize, l: usize, m: usize, mg: usize, mu: f64) -> (TheorySetup, NetworkConfig) {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis);
        let net = NetworkConfig {
            graph: graph.clone(),
            c: c.clone(),
            a: crate::topology::Combiner::eye(n),
            mu: vec![mu; n],
            dim: l,
        };
        let s = TheorySetup {
            n_nodes: n,
            dim: l,
            m,
            m_grad: mg,
            c: c.to_dense(),
            mu: vec![mu; n],
            sigma_u2: (0..n).map(|k| 0.7 + 0.15 * k as f64).collect(),
            sigma_v2: (0..n).map(|k| 1e-3 * (1.0 + 0.3 * k as f64)).collect(),
        };
        (s, net)
    }

    fn imp(drop: f64, gate: Gating) -> LinkImpairments {
        LinkImpairments {
            drop: DropModel::Iid(drop),
            gating: gate,
            quant_step: 0.0,
            per_leg: false,
        }
    }

    fn random_sigma(nl: usize, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(nl, nl);
        for i in 0..nl {
            for j in 0..nl {
                m[(i, j)] = rng.next_gaussian();
            }
        }
        let mt = m.transpose();
        &m * &mt
    }

    /// Draw masks and build 𝓑ᵢ for a *given* effective combiner (same
    /// construction as the ideal model's MC test, with C(i) plugged in).
    fn sample_b_i(s: &TheorySetup, ceff: &crate::topology::Combiner, rng: &mut Pcg64) -> Mat {
        let (n, l) = (s.n_nodes, s.dim);
        let mut scratch = Vec::new();
        let mut h = vec![vec![0f32; l]; n];
        let mut q = vec![vec![0f32; l]; n];
        for k in 0..n {
            rng.fill_mask(&mut h[k], s.m, &mut scratch);
            rng.fill_mask(&mut q[k], s.m_grad, &mut scratch);
        }
        let mut b = Mat::eye(n * l);
        for k in 0..n {
            for lnb in 0..n {
                let clk = ceff[(lnb, k)];
                for j in 0..l {
                    let mut x = 0.0;
                    if lnb == k {
                        for m_ in 0..n {
                            let cmk = ceff[(m_, k)];
                            if cmk == 0.0 {
                                continue;
                            }
                            x += cmk
                                * (s.sigma_u2[m_] * q[m_][j] as f64 * h[k][j] as f64
                                    + s.sigma_u2[k] * (1.0 - q[m_][j] as f64));
                        }
                    }
                    if clk != 0.0 {
                        x += clk * s.sigma_u2[lnb] * q[lnb][j] as f64 * (1.0 - h[k][j] as f64);
                    }
                    b[(k * l + j, lnb * l + j)] -= s.mu[k] * x;
                }
            }
        }
        b
    }

    /// The core validation: the impaired closed-form operator must equal
    /// the Monte-Carlo average of 𝓑ᵢᵀΣ𝓑ᵢ where the effective combiner of
    /// every trial is produced by the *real* coordinator impairment layer
    /// (`ImpairmentState::begin_iteration`).
    #[test]
    fn impaired_operator_matches_coordinator_monte_carlo() {
        let (s, net) = setup(4, 3, 2, 1, 0.3);
        let im = imp(0.3, Gating::Probabilistic(0.8));
        let model = ImpairedMsdModel::new(s.clone(), &im).unwrap();
        let mut rng = Pcg64::new(29, 0);
        let sigma = random_sigma(12, &mut rng);
        let closed = model.apply(&sigma);

        let mut alg = Dcd::new(net.clone(), s.m, s.m_grad);
        let mut comm = CommMeter::new(4);
        let mut state = ImpairmentState::new(alg.network(), 91, 1);
        let trials = 60_000;
        let mut acc = Mat::zeros(12, 12);
        for _ in 0..trials {
            state.begin_iteration(&im, &mut alg, &mut comm);
            let b_i = sample_b_i(&s, &alg.network().c, &mut rng);
            let prod = &(&b_i.transpose() * &sigma) * &b_i;
            acc.axpy(1.0, &prod);
        }
        acc.scale_in_place(1.0 / trials as f64);
        let diff = (&acc - &closed).max_abs();
        let scale = closed.max_abs();
        assert!(diff < 0.02 * scale, "MC mismatch: {diff} (scale {scale})");
    }

    /// The impaired driving-noise term against the same coordinator-
    /// sampled effective combiners.
    #[test]
    fn impaired_noise_matches_coordinator_monte_carlo() {
        let (s, net) = setup(4, 3, 2, 1, 0.3);
        let im = imp(0.25, Gating::Probabilistic(0.85));
        let model = ImpairedMsdModel::new(s.clone(), &im).unwrap();
        let mut rng = Pcg64::new(31, 0);
        let sigma = random_sigma(12, &mut rng);
        let closed = model.noise(&sigma);

        let (n, l) = (4usize, 3usize);
        let mut alg = Dcd::new(net, s.m, s.m_grad);
        let mut comm = CommMeter::new(n);
        let mut state = ImpairmentState::new(alg.network(), 47, 1);
        let trials = 60_000;
        let mut acc = 0.0;
        let mut scratch = Vec::new();
        let mut q = vec![vec![0f32; l]; n];
        for _ in 0..trials {
            state.begin_iteration(&im, &mut alg, &mut comm);
            let ceff = &alg.network().c;
            for qk in q.iter_mut() {
                rng.fill_mask(qk, s.m_grad, &mut scratch);
            }
            let mut g = Mat::zeros(n * l, n * l);
            for k in 0..n {
                for lnb in 0..n {
                    for j in 0..l {
                        let mut y = ceff[(lnb, k)] * q[lnb][j] as f64;
                        if lnb == k {
                            for m_ in 0..n {
                                y += ceff[(m_, k)] * (1.0 - q[m_][j] as f64);
                            }
                        }
                        g[(k * l + j, lnb * l + j)] = s.mu[k] * y;
                    }
                }
            }
            let gts_g = &(&g.transpose() * &sigma) * &g;
            for b in 0..n {
                let sb = s.sigma_v2[b] * s.sigma_u2[b];
                for j in 0..l {
                    acc += sb * gts_g[(b * l + j, b * l + j)];
                }
            }
        }
        let mc = acc / trials as f64;
        assert!(
            (mc - closed).abs() < 0.02 * closed.abs().max(1e-12),
            "noise MC {mc} vs closed {closed}"
        );
    }

    /// Gating probability 0 isolates every node: the model must coincide
    /// with the ideal model on C = I (pure self-LMS per node).
    #[test]
    fn zero_transmit_prob_reduces_to_self_lms() {
        let (s, _) = setup(5, 3, 2, 1, 0.1);
        let gated = ImpairedMsdModel::new(s.clone(), &imp(0.0, Gating::Probabilistic(0.0)))
            .unwrap();
        let mut iso = s.clone();
        iso.c = Mat::eye(5);
        let ideal = MsdModel::new(iso);
        let mut rng = Pcg64::new(7, 0);
        let sigma = random_sigma(15, &mut rng);
        let a = gated.apply(&sigma);
        let b = ideal.apply(&sigma);
        let diff = (&a - &b).max_abs();
        assert!(diff < 1e-12 * b.max_abs().max(1.0), "diff {diff}");
        assert!((gated.c_bar() - &Mat::eye(5)).max_abs() < 1e-12);
    }

    /// C̄ must agree with the coordinator's `expected_combiners` — the
    /// reallocation rule exists in both layers (the theory cannot take a
    /// `NetworkConfig`), and this sweep over the (drop, gate) grid is
    /// what keeps the two copies from drifting apart.
    #[test]
    fn c_bar_matches_coordinator_expected_combiners() {
        let (s, net) = setup(6, 2, 1, 1, 0.05);
        for &drop in &[0.0, 0.15, 0.5, 1.0] {
            for &gate in &[1.0, 0.9, 0.4, 0.0] {
                let im = imp(drop, Gating::Probabilistic(gate));
                let model = ImpairedMsdModel::new(s.clone(), &im).unwrap();
                let (_, c_bar) = im.expected_combiners(&net).unwrap();
                let diff = (model.c_bar() - &c_bar.to_dense()).max_abs();
                assert!(diff < 1e-12, "drop {drop} gate {gate}: C̄ diff {diff}");
            }
        }
    }

    /// Worse links ⇒ worse steady state: drops, duty-cycling and
    /// quantization each raise the floor monotonically.
    #[test]
    fn impairments_raise_the_steady_state() {
        let (s, _) = setup(5, 4, 2, 1, 0.05);
        let wo = vec![0.5, -0.3, 0.8, 0.1];
        let ss = |im: &LinkImpairments| {
            ImpairedMsdModel::new(s.clone(), im)
                .unwrap()
                .steady_state(&wo, 1e-10, 30_000)
                .0
        };
        let ideal = ss(&LinkImpairments::ideal());
        let drops = ss(&imp(0.4, Gating::Always));
        let heavy_drops = ss(&imp(0.8, Gating::Always));
        assert!(ideal <= drops * 1.02, "{ideal} vs {drops}");
        assert!(drops <= heavy_drops * 1.02, "{drops} vs {heavy_drops}");
        let gated = ss(&imp(0.0, Gating::Probabilistic(0.5)));
        assert!(ideal <= gated * 1.02, "{ideal} vs {gated}");
        let quant = ss(&LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::Always,
            quant_step: 1e-3,
            per_leg: false,
        });
        assert!(quant > ideal, "{quant} vs {ideal}");
        // The Σ-recursion is untouched by quantization, so the steady
        // state is exactly affine in Δ²: a 10× step must raise the
        // quantization excess by 100×.
        let quant_big = ss(&LinkImpairments {
            drop: DropModel::none(),
            gating: Gating::Always,
            quant_step: 1e-2,
            per_leg: false,
        });
        let ratio = (quant_big - ideal) / (quant - ideal);
        assert!((ratio - 100.0).abs() < 1.0, "Δ² scaling off: ratio {ratio}");
    }

    /// Event-triggered gating is out of analysis scope and must error.
    #[test]
    fn event_triggered_gating_is_rejected() {
        let (s, _) = setup(4, 3, 2, 1, 0.1);
        let err = ImpairedMsdModel::new(s, &imp(0.0, Gating::EventTriggered(1e-6)))
            .unwrap_err();
        assert!(err.contains("event"), "{err}");
    }

    /// Mean stability degrades gracefully: the impaired model stays
    /// mean-stable at small μ and reports instability at huge μ.
    #[test]
    fn impaired_mean_stability_tracks_mu() {
        let (s, _) = setup(4, 3, 2, 1, 0.05);
        let model = ImpairedMsdModel::new(s.clone(), &imp(0.3, Gating::Probabilistic(0.7)))
            .unwrap();
        assert!(model.is_mean_stable(), "rho {}", model.mean_rho());
        let mut bad = s;
        bad.mu = vec![3.0; 4];
        let model = ImpairedMsdModel::new(bad, &imp(0.3, Gating::Probabilistic(0.7))).unwrap();
        assert!(!model.is_mean_stable(), "rho {}", model.mean_rho());
    }
}
