//! Mean weight-error model (paper §III-A).
//!
//! E{w̃_i} = 𝓑 E{w̃_{i−1}} with 𝓑 from (31); convergence in the mean iff
//! ρ(𝓑) < 1 (35), with the sufficient step-size condition (38)–(39).

use super::TheorySetup;
use crate::linalg::{spectral_radius, Mat, SparseMat};

/// The mean model: 𝓑 and stability diagnostics.
#[derive(Debug, Clone)]
pub struct MeanModel {
    setup: TheorySetup,
    /// 𝓑, dense (NL x NL).
    pub b: Mat,
}

impl MeanModel {
    /// Build 𝓑 for `setup` (eq. (31)).
    pub fn new(setup: TheorySetup) -> Self {
        let b = build_b(&setup);
        Self { setup, b }
    }

    /// ρ(𝓑) — the algorithm converges in the mean iff this is < 1.
    pub fn rho(&self) -> f64 {
        spectral_radius(&self.b, 5000)
    }

    /// Convergence in the mean: ρ(𝓑) < 1.
    pub fn is_mean_stable(&self) -> bool {
        self.rho() < 1.0
    }

    /// The paper's sufficient bound (38): μ_k < 2 / λ_{max,k} with
    /// λ_{max,k} from (39). Returns the per-node bounds.
    pub fn paper_mu_bounds(&self) -> Vec<f64> {
        let s = &self.setup;
        let (l, m, mg) = (s.dim as f64, s.m as f64, s.m_grad as f64);
        (0..s.n_nodes)
            .map(|k| {
                // R_{u_k} = σ²_{u,k} I ⇒ λ_max(R_{u_k}) = σ²_{u,k};
                // R_k = Σ_l c_{lk} R_{u_l} ⇒ λ_max(R_k) = Σ_l c_{lk} σ²_{u,l}.
                let lam_rk = s.r_k_scale(k);
                let lam_ruk = s.sigma_u2[k];
                let max_neighbor = (0..s.n_nodes)
                    .map(|lnb| s.c[(lnb, k)] * s.sigma_u2[lnb])
                    .fold(0.0f64, f64::max);
                let lam = (m * mg / (l * l)) * lam_rk
                    + (m / l) * (1.0 - mg / l) * lam_ruk
                    + (mg / l) * (1.0 - m / l) * max_neighbor;
                if lam > 0.0 {
                    2.0 / lam
                } else {
                    f64::INFINITY
                }
            })
            .collect()
    }

    /// Mean trajectory: returns E{w̃_i} norms per iteration starting from
    /// w̃_0 (stacked, length NL).
    pub fn mean_deviation_norms(&self, w_tilde0: &[f64], iters: usize) -> Vec<f64> {
        let mut v = w_tilde0.to_vec();
        let mut out = Vec::with_capacity(iters);
        for _ in 0..iters {
            v = self.b.matvec(&v);
            out.push(v.iter().map(|x| x * x).sum::<f64>().sqrt());
        }
        out
    }
}

/// Build 𝓑 = I − 𝓜 E{𝓧} per (31):
///   𝓑 = I − (M·M∇/L²) 𝓜𝓡 − (1 − M∇/L) 𝓜𝓡_u − (M∇/L)(1 − M/L) 𝓜𝓒ᵀ𝓡_u.
pub fn build_b(s: &TheorySetup) -> Mat {
    let (n, l) = (s.n_nodes, s.dim);
    let (lf, mf, mgf) = (l as f64, s.m as f64, s.m_grad as f64);
    let qh = mf * mgf / (lf * lf);
    let q_only = 1.0 - mgf / lf;
    let cross = (mgf / lf) * (1.0 - mf / lf);
    let mut b = Mat::eye(n * l);
    for k in 0..n {
        let mu_k = s.mu[k];
        // Diagonal block: I − μ_k [ qh R_k + q_only σ²_{u,k} ] I
        //               − μ_k cross c_{kk} σ²_{u,k} I   (the l = k term of 𝓒ᵀ𝓡_u).
        let diag_scale =
            mu_k * (qh * s.r_k_scale(k) + q_only * s.sigma_u2[k] + cross * s.c[(k, k)] * s.sigma_u2[k]);
        for j in 0..l {
            b[(k * l + j, k * l + j)] -= diag_scale;
        }
        // Off-diagonal blocks (k, lnb): −μ_k cross c_{lnb,k} σ²_{u,lnb} I.
        for lnb in 0..n {
            if lnb == k {
                continue;
            }
            let w = mu_k * cross * s.c[(lnb, k)] * s.sigma_u2[lnb];
            if w == 0.0 {
                continue;
            }
            for j in 0..l {
                b[(k * l + j, lnb * l + j)] -= w;
            }
        }
    }
    b
}

/// Sparse (CSR) construction of the same 𝓑 — identical values, stored
/// row by row. Every block of 𝓑 is a diagonal L×L matrix, so dense row
/// k·L+j holds one entry per block column: the diagonal block plus one
/// per neighbour with `c_{lk} σ²_{u,l} ≠ 0`. nnz ≈ (2E + N)·L — this is
/// what lets the variance operator run above `DENSE_NL_LIMIT` without
/// ever materialising the (NL)² matrix (DESIGN.md §10).
pub(super) fn build_b_csr(s: &TheorySetup) -> SparseMat {
    let (n, l) = (s.n_nodes, s.dim);
    let (lf, mf, mgf) = (l as f64, s.m as f64, s.m_grad as f64);
    let qh = mf * mgf / (lf * lf);
    let q_only = 1.0 - mgf / lf;
    let cross = (mgf / lf) * (1.0 - mf / lf);
    let nl = n * l;
    let mut indptr = Vec::with_capacity(nl + 1);
    let mut cols = Vec::new();
    let mut vals = Vec::new();
    indptr.push(0);
    // Per block row: the (block-column, value) pattern is shared by all
    // L scalar rows, so compute it once and replicate with shifted ids.
    let mut entries: Vec<(usize, f64)> = Vec::new();
    for k in 0..n {
        let mu_k = s.mu[k];
        let diag_val = 1.0
            - mu_k
                * (qh * s.r_k_scale(k)
                    + q_only * s.sigma_u2[k]
                    + cross * s.c[(k, k)] * s.sigma_u2[k]);
        entries.clear();
        for lnb in 0..n {
            if lnb == k {
                entries.push((k, diag_val));
                continue;
            }
            let w = mu_k * cross * s.c[(lnb, k)] * s.sigma_u2[lnb];
            if w != 0.0 {
                entries.push((lnb, -w));
            }
        }
        for j in 0..l {
            for &(lnb, v) in &entries {
                cols.push(lnb * l + j);
                vals.push(v);
            }
            indptr.push(cols.len());
        }
    }
    SparseMat::from_parts(nl, nl, indptr, cols, vals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::topology::{combination_matrix, Graph, Rule};

    pub(crate) fn setup(n: usize, l: usize, m: usize, mg: usize, mu: f64) -> TheorySetup {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
        TheorySetup {
            n_nodes: n,
            dim: l,
            m,
            m_grad: mg,
            c,
            mu: vec![mu; n],
            sigma_u2: (0..n).map(|k| 0.8 + 0.1 * k as f64).collect(),
            sigma_v2: vec![1e-3; n],
        }
    }

    #[test]
    fn full_masks_recover_diffusion_lms_b() {
        // M = M_grad = L ⇒ 𝓑 = I − 𝓜𝓡 (paper (40) remark).
        let s = setup(4, 3, 3, 3, 0.1);
        let model = MeanModel::new(s.clone());
        for k in 0..4 {
            let expect = 1.0 - s.mu[k] * s.r_k_scale(k);
            for j in 0..3 {
                assert!((model.b[(k * 3 + j, k * 3 + j)] - expect).abs() < 1e-12);
            }
        }
    }

    /// 𝓑 must equal the Monte-Carlo average of the per-iteration
    /// coefficient matrix 𝓑_i = I − 𝓜𝓧_i over random masks.
    #[test]
    fn b_matches_monte_carlo() {
        let s = setup(4, 4, 2, 1, 0.07);
        let model = MeanModel::new(s.clone());
        let (n, l) = (s.n_nodes, s.dim);
        let mut acc = Mat::zeros(n * l, n * l);
        let mut rng = Pcg64::new(21, 0);
        let trials = 40_000;
        let mut scratch = Vec::new();
        let mut h = vec![vec![0f32; l]; n];
        let mut q = vec![vec![0f32; l]; n];
        for _ in 0..trials {
            for k in 0..n {
                rng.fill_mask(&mut h[k], s.m, &mut scratch);
                rng.fill_mask(&mut q[k], s.m_grad, &mut scratch);
            }
            // X_i blocks (diagonal matrices) — see theory/mod.rs.
            for k in 0..n {
                for lnb in 0..n {
                    let clk = s.c[(lnb, k)];
                    for j in 0..l {
                        let mut x = 0.0;
                        if lnb == k {
                            for m_ in 0..n {
                                let cmk = s.c[(m_, k)];
                                if cmk == 0.0 {
                                    continue;
                                }
                                x += cmk
                                    * (s.sigma_u2[m_] * q[m_][j] as f64 * h[k][j] as f64
                                        + s.sigma_u2[k] * (1.0 - q[m_][j] as f64));
                            }
                        }
                        if clk != 0.0 {
                            x += clk * s.sigma_u2[lnb] * q[lnb][j] as f64 * (1.0 - h[k][j] as f64);
                        }
                        acc[(k * l + j, lnb * l + j)] += s.mu[k] * x;
                    }
                }
            }
        }
        acc.scale_in_place(1.0 / trials as f64);
        let b_mc = &Mat::eye(n * l) - &acc;
        let diff = (&b_mc - &model.b).max_abs();
        assert!(diff < 5e-3, "MC vs closed-form B: max diff {diff}");
    }

    /// The CSR construction must reproduce the dense 𝓑 bit for bit —
    /// the sparse theory path above `DENSE_NL_LIMIT` rests on this.
    #[test]
    fn sparse_b_matches_dense_b() {
        for &(n, l, m, mg) in &[(6usize, 4usize, 2usize, 1usize), (5, 3, 3, 3), (8, 2, 1, 2)] {
            let s = setup(n, l, m, mg, 0.08);
            let dense = build_b(&s);
            let sparse = build_b_csr(&s);
            assert_eq!(sparse.to_dense(), dense, "N={n} L={l} M={m} Mg={mg}");
        }
    }

    #[test]
    fn stability_bound_is_respected() {
        let s = setup(6, 5, 3, 2, 0.0);
        let bounds = MeanModel::new(s.clone()).paper_mu_bounds();
        // At 50% of the bound, ρ(B) < 1; at 300%, ρ(B) > 1.
        let mut s_ok = s.clone();
        s_ok.mu = bounds.iter().map(|b| 0.5 * b).collect();
        assert!(MeanModel::new(s_ok).is_mean_stable());
        let mut s_bad = s;
        s_bad.mu = bounds.iter().map(|b| 3.0 * b).collect();
        assert!(!MeanModel::new(s_bad).is_mean_stable());
    }

    #[test]
    fn mean_deviation_decays_when_stable() {
        let s = setup(5, 4, 2, 2, 0.1);
        let model = MeanModel::new(s);
        let w0 = vec![1.0; 20];
        let norms = model.mean_deviation_norms(&w0, 300);
        assert!(norms[299] < 0.01 * norms[0]);
    }
}
