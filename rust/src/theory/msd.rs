//! Mean-square (MSD/EMSE) model (paper §III-B).
//!
//! Implements the weighted-variance recursion (69):
//!
//!   E‖w̃_i‖²_Σ = E‖w̃_{i−1}‖²_{Σ'} + trace(E{𝓖ᵢᵀ Σ 𝓖ᵢ} 𝓢),
//!   Σ' = E{𝓑ᵢᵀ Σ 𝓑ᵢ}
//!
//! as a *linear operator* Σ ↦ Σ' applied directly — the (NL)²×(NL)²
//! matrix 𝓕 of (68) is never materialised. See `theory/mod.rs` for why
//! the diagonal-mask structure makes the operator exact (under the
//! paper's small-μ approximation (83)) and cheap.

use super::moments::MaskMoments;
use super::{
    mean::{build_b, build_b_csr},
    TheorySetup,
};
use crate::linalg::{power_radius_with, Mat, SparseMat};

/// Largest N·L for which 𝓑/𝓑ᵀ are kept dense. At or below this size the
/// operator is bit-identical to the historical dense implementation (the
/// existing presets and golden outputs live here); above it the linear
/// part switches to CSR and one application costs O(nnz(𝓑)·NL) instead
/// of O((NL)³), which is what lifts the scenario-theory cap to
/// N·L ~ 10⁴ (DESIGN.md §10).
pub(super) const DENSE_NL_LIMIT: usize = 256;

/// The mean coefficient matrix 𝓑 of the variance operator, stored dense
/// (small setups, bit-compatible legacy path) or CSR (large setups;
/// nnz ≈ (2E + N)·L since every block of 𝓑 is a diagonal L×L matrix).
/// Both representations carry the cached transpose: the fast path
/// multiplies by 𝓑ᵀ every iteration.
pub(super) enum BOperator {
    Dense { b: Mat, bt: Mat },
    Sparse { b: SparseMat, bt: SparseMat },
}

impl BOperator {
    /// Build 𝓑 for `s`, choosing the representation by N·L.
    pub(super) fn build(s: &TheorySetup) -> Self {
        if s.n_nodes * s.dim <= DENSE_NL_LIMIT {
            Self::from_dense_b(build_b(s))
        } else {
            let b = build_b_csr(s);
            let bt = b.transpose();
            Self::Sparse { b, bt }
        }
    }

    /// Wrap an externally built dense 𝓑 (caches the transpose).
    pub(super) fn from_dense_b(b: Mat) -> Self {
        let mut bt = Mat::zeros(b.cols(), b.rows());
        b.transpose_into(&mut bt);
        Self::Dense { b, bt }
    }

    /// Operator dimension (NL).
    fn nl(&self) -> usize {
        match self {
            Self::Dense { b, .. } => b.rows(),
            Self::Sparse { b, .. } => b.rows(),
        }
    }

    /// 𝓑 · x (the mean recursion step; powers the spectral radius).
    fn matvec(&self, x: &[f64]) -> Vec<f64> {
        match self {
            Self::Dense { b, .. } => b.matvec(x),
            Self::Sparse { b, .. } => b.spmv(x),
        }
    }

    /// `out = 𝓑ᵀ · sigma` — the one matrix product of the fast path.
    fn mul_bt_into(&self, sigma: &Mat, out: &mut Mat) {
        match self {
            Self::Dense { bt, .. } => bt.mul_into(sigma, out),
            Self::Sparse { bt, .. } => bt.mul_dense_into(sigma, out),
        }
    }

    /// Densified 𝓑 (allocating; reference/oracle paths only).
    fn to_dense_b(&self) -> Mat {
        match self {
            Self::Dense { b, .. } => b.clone(),
            Self::Sparse { b, .. } => b.to_dense(),
        }
    }
}

/// Joint second moments of the (possibly random) adapt-combiner entries,
/// abstracting the only thing that differs between the ideal operator
/// (deterministic `C`) and the impaired-link operator (random effective
/// `C(i)`, DESIGN.md §7): every quadratic coefficient of the variance
/// operator is a sum of products `c_{mk} c_{nl}`, and — because the
/// per-iteration link states are independent of the selection masks —
/// the impaired coefficients are obtained by replacing each product with
/// `E[C_{mk}(i) C_{nl}(i)]`. The builders below
/// ([`build_quad_terms`], [`build_noise_coeffs`]) are written against
/// this trait so both models share one (tested) code path.
pub(super) trait CombinerMoments {
    /// Support of column `k`: every `m` with `P(C_{mk} ≠ 0) > 0`
    /// (for random combiners this always includes the diagonal `k`,
    /// where erased mass lands).
    fn supp(&self, k: usize) -> &[usize];
    /// Whether entry `(m, k)` can be nonzero.
    fn has(&self, m: usize, k: usize) -> bool;
    /// `E[C_{mk} C_{nl}]` over the link-state distribution (for a
    /// deterministic combiner: the plain product).
    fn cc(&self, m: usize, k: usize, n: usize, l: usize) -> f64;
}

/// The deterministic provider backing the ideal [`MsdModel`]:
/// `cc` is the plain entry product and the support is `C`'s sparsity.
pub(super) struct DetCombiner<'a> {
    c: &'a Mat,
    supp: Vec<Vec<usize>>,
}

impl<'a> DetCombiner<'a> {
    pub(super) fn new(c: &'a Mat) -> Self {
        let n = c.cols();
        let supp = (0..n)
            .map(|k| (0..n).filter(|&m| c[(m, k)] != 0.0).collect())
            .collect();
        Self { c, supp }
    }
}

impl CombinerMoments for DetCombiner<'_> {
    fn supp(&self, k: usize) -> &[usize] {
        &self.supp[k]
    }

    fn has(&self, m: usize, k: usize) -> bool {
        self.c[(m, k)] != 0.0
    }

    fn cc(&self, m: usize, k: usize, n: usize, l: usize) -> f64 {
        self.c[(m, k)] * self.c[(n, l)]
    }
}

/// One precomputed quadratic coefficient: the contribution of input
/// block (k, l) to output block (a, b).
#[derive(Debug, Clone, Copy)]
pub(super) struct QuadTerm {
    a: usize,
    b: usize,
    k: usize,
    l: usize,
    /// Coefficient for off-diagonal entries of Φ_{kl}.
    g_off: f64,
    /// Coefficient for diagonal entries of Φ_{kl}.
    g_diag: f64,
}

/// A quadratic term of the symmetric fast path ([`MsdModel::apply_into`]):
/// coefficients are μ_k μ_l-prescaled, and only the lexicographic half of
/// each mirror pair {(a,b,k,l), (b,a,l,k)} is kept — a `mirror` term
/// writes its contribution to both the (a,b) and the transposed (b,a)
/// position (Y(Φ) is symmetric for symmetric Φ), halving the Σ reads and
/// coefficient work.
#[derive(Debug, Clone, Copy)]
struct SymQuadTerm {
    a: usize,
    b: usize,
    k: usize,
    l: usize,
    g_off: f64,
    g_diag: f64,
    mirror: bool,
}

/// Reusable scratch for the allocation-free operator application: holds
/// the 𝓑ᵀΣ product buffer. Create once per (NL) size (via
/// [`MsdModel::workspace`]) and reuse across iterations — no heap
/// traffic per [`MsdModel::apply_into`] call.
pub struct MsdWorkspace {
    /// 𝓑ᵀ Σ product buffer.
    bt_sigma: Mat,
}

impl MsdWorkspace {
    /// Allocate scratch for an `nl`-dimensional (NL × NL) operator.
    pub fn new(nl: usize) -> Self {
        Self { bt_sigma: Mat::zeros(nl, nl) }
    }
}

/// The mean-square evolution model.
pub struct MsdModel {
    setup: TheorySetup,
    /// 𝓑 with its cached transpose, dense or CSR by size (see
    /// [`BOperator`]).
    bop: BOperator,
    /// Full quadratic-term list (reference operator [`MsdModel::apply`]).
    quad: Vec<QuadTerm>,
    /// Halved, μ-prescaled term list (fast path).
    quad_sym: Vec<SymQuadTerm>,
    /// Noise coefficients: noise(Σ) = Σ_{k,l} w_noise[k*n+l] · tr(Σ_{kl}).
    w_noise: Vec<f64>,
    /// Extra per-iteration injection `extra_tr_noise · tr(Σ)` — the
    /// quantization-noise floor of the impaired model (DESIGN.md §7);
    /// exactly 0 for the ideal model.
    extra_tr_noise: f64,
}

/// A computed theoretical trajectory.
#[derive(Debug, Clone)]
pub struct MsdTrajectory {
    /// Network MSD (linear scale) after each iteration, 1-based.
    pub msd: Vec<f64>,
    /// Steady-state estimate (last value).
    pub steady_state: f64,
}

impl MsdModel {
    /// Build the ideal-link model: validates `setup` and precomputes
    /// 𝓑, 𝓑ᵀ and the quadratic/noise coefficient lists.
    pub fn new(setup: TheorySetup) -> Self {
        setup.validate().expect("invalid theory setup");
        let det = DetCombiner::new(&setup.c);
        let bop = BOperator::build(&setup);
        let quad = build_quad_terms(&setup, &det);
        let w_noise = build_noise_coeffs(&setup, &det);
        Self::from_parts(setup, bop, quad, w_noise, 0.0)
    }

    /// Assemble a model from externally built parts — the impaired-link
    /// model (DESIGN.md §7) constructs `b` from the *expected* combiner
    /// C̄ and the quadratic/noise coefficient lists from the link-state
    /// second moments, then reuses this whole engine (fast path,
    /// trajectory/steady-state loops) unchanged. Performs no
    /// double-stochasticity validation: C̄ need not be doubly stochastic
    /// even when the pristine `C` is.
    pub(super) fn from_parts(
        setup: TheorySetup,
        bop: BOperator,
        quad: Vec<QuadTerm>,
        w_noise: Vec<f64>,
        extra_tr_noise: f64,
    ) -> Self {
        // Keep the lexicographic representative of each mirror pair
        // {(a,b,k,l), (b,a,l,k)}; self-mirrored terms (a = b, k = l)
        // contribute a single symmetric write.
        let quad_sym = quad
            .iter()
            .filter(|t| t.a < t.b || (t.a == t.b && t.k <= t.l))
            .map(|t| SymQuadTerm {
                a: t.a,
                b: t.b,
                k: t.k,
                l: t.l,
                g_off: t.g_off * setup.mu[t.k] * setup.mu[t.l],
                g_diag: t.g_diag * setup.mu[t.k] * setup.mu[t.l],
                mirror: !(t.a == t.b && t.k == t.l),
            })
            .collect();
        Self { setup, bop, quad, quad_sym, w_noise, extra_tr_noise }
    }

    /// ρ(𝓑) by power iteration *on the operator* — matrix-free on the
    /// sparse path, and bit-identical to `spectral_radius(&b, iters)` on
    /// the dense path (both run the same core over `b.matvec`). For the
    /// impaired model this is ρ(𝓑̄), the mean-stability radius.
    pub(super) fn mean_radius(&self, iters: usize) -> f64 {
        power_radius_with(self.bop.nl(), iters, |v| self.bop.matvec(v))
    }

    /// The problem description the model was built for (the impaired
    /// model stores the expected combiner C̄ here).
    pub fn setup(&self) -> &TheorySetup {
        &self.setup
    }

    /// A scratch workspace sized for this model (see [`MsdWorkspace`]).
    pub fn workspace(&self) -> MsdWorkspace {
        MsdWorkspace::new(self.bop.nl())
    }

    /// Reference implementation of the weighting-update operator:
    ///   Σ' = E{𝓑ᵢᵀ Σ 𝓑ᵢ} = 𝓑ᵀΣ + Σ𝓑 − Σ + Y(𝓜Σ𝓜).
    ///
    /// Allocates freely and accepts arbitrary Σ; kept as the oracle the
    /// equivalence tests and `theory_ops` bench compare against. The
    /// iteration loops use the allocation-free [`Self::apply_into`].
    pub fn apply(&self, sigma: &Mat) -> Mat {
        let nl = self.bop.nl();
        assert_eq!((sigma.rows(), sigma.cols()), (nl, nl));
        let b = self.bop.to_dense_b();
        let bt_sigma = &b.transpose() * sigma;
        let sigma_b = sigma * &b;
        let mut out = &(&bt_sigma + &sigma_b) - sigma;
        // Quadratic part Y(Φ), Φ_{kl} = μ_k μ_l Σ_{kl}.
        let l = self.setup.dim;
        for t in &self.quad {
            let mu2 = self.setup.mu[t.k] * self.setup.mu[t.l];
            let go = t.g_off * mu2;
            let gd = t.g_diag * mu2;
            for i in 0..l {
                let row_in = t.k * l + i;
                let row_out = t.a * l + i;
                for j in 0..l {
                    let v = sigma[(row_in, t.l * l + j)];
                    let g = if i == j { gd } else { go };
                    out[(row_out, t.b * l + j)] += g * v;
                }
            }
        }
        out
    }

    /// Allocation-free fast path of the weighting-update operator for
    /// **symmetric** Σ (every production iterate is: Σ₀ is diagonal and
    /// 𝓕 maps symmetric matrices to symmetric matrices; debug-checked).
    ///
    /// Σ = Σᵀ ⇒ Σ𝓑 = (𝓑ᵀΣ)ᵀ, so a single `mul_into` against the cached
    /// 𝓑ᵀ feeds a fused, tiled pass computing 𝓑ᵀΣ + (𝓑ᵀΣ)ᵀ − Σ; the
    /// quadratic part Y(𝓜Σ𝓜) walks the halved mirror-paired term list.
    /// `out` must not alias `sigma`.
    pub fn apply_into(&self, sigma: &Mat, ws: &mut MsdWorkspace, out: &mut Mat) {
        let nl = self.bop.nl();
        assert_eq!((sigma.rows(), sigma.cols()), (nl, nl));
        assert_eq!((out.rows(), out.cols()), (nl, nl));
        debug_assert!(max_asymmetry(sigma) <= 1e-9 * sigma.max_abs().max(1e-300),
            "apply_into requires (numerically) symmetric Σ");
        self.bop.mul_bt_into(sigma, &mut ws.bt_sigma);
        let t = ws.bt_sigma.data();
        let s = sigma.data();
        let o = out.data_mut();
        // Fused linear part, tiled so the transposed read of 𝓑ᵀΣ stays
        // cache-resident.
        const TILE: usize = 64;
        for ib in (0..nl).step_by(TILE) {
            let imax = (ib + TILE).min(nl);
            for jb in (0..nl).step_by(TILE) {
                let jmax = (jb + TILE).min(nl);
                for i in ib..imax {
                    for j in jb..jmax {
                        o[i * nl + j] = t[i * nl + j] + t[j * nl + i] - s[i * nl + j];
                    }
                }
            }
        }
        // Quadratic part: μ-prescaled halved term list; mirror terms also
        // write the transposed position (exact for symmetric Σ).
        let l = self.setup.dim;
        for term in &self.quad_sym {
            for i in 0..l {
                let row_in = (term.k * l + i) * nl + term.l * l;
                let row_out = (term.a * l + i) * nl + term.b * l;
                for j in 0..l {
                    let v = s[row_in + j];
                    let g = if i == j { term.g_diag } else { term.g_off };
                    let add = g * v;
                    o[row_out + j] += add;
                    if term.mirror {
                        o[(term.b * l + j) * nl + term.a * l + i] += add;
                    }
                }
            }
        }
    }

    /// Per-iteration driving-noise injection for the weighting Σ:
    /// trace(E{𝓖ᵢᵀ Σ 𝓖ᵢ} 𝓢), plus — for the impaired model — the
    /// additive quantization floor `(Δ²/12) · tr(Σ)` (DESIGN.md §7).
    pub fn noise(&self, sigma: &Mat) -> f64 {
        let (n, l) = (self.setup.n_nodes, self.setup.dim);
        let mut total = 0.0;
        if self.extra_tr_noise != 0.0 {
            total += self.extra_tr_noise * sigma.trace();
        }
        for k in 0..n {
            for lnb in 0..n {
                let w = self.w_noise[k * n + lnb];
                if w == 0.0 {
                    continue;
                }
                let mut tr = 0.0;
                for j in 0..l {
                    tr += sigma[(k * l + j, lnb * l + j)];
                }
                total += w * tr;
            }
        }
        total
    }

    /// Theoretical network-MSD learning curve (Fig. 3 left): w_k,0 = 0
    /// ⇒ w̃_{k,0} = w°. Alias of [`Self::trajectory`].
    pub fn learning_curve(&self, wo: &[f64], iters: usize) -> MsdTrajectory {
        self.trajectory_weighted(wo, iters, None)
    }

    /// Theoretical network-MSD trajectory: w_k,0 = 0 ⇒ w̃_{k,0} = w°.
    /// `weighting`: `None` for MSD (Σ₀ = I), `Some(ru)` for EMSE-style
    /// weightings (Σ₀ block-diagonal with the given per-node scales).
    pub fn trajectory(&self, wo: &[f64], iters: usize) -> MsdTrajectory {
        self.trajectory_weighted(wo, iters, None)
    }

    /// Weighted-variance trajectory: `weighting = None` gives the MSD
    /// (Σ₀ = I); `Some(scales)` installs a block-diagonal Σ₀ with the
    /// given per-node scales (EMSE-style weightings).
    pub fn trajectory_weighted(
        &self,
        wo: &[f64],
        iters: usize,
        weighting: Option<&[f64]>,
    ) -> MsdTrajectory {
        let (n, l) = (self.setup.n_nodes, self.setup.dim);
        assert_eq!(wo.len(), l);
        let nl = n * l;
        // Stacked initial deviation col{w°, ..., w°}.
        let mut w0 = Vec::with_capacity(nl);
        for _ in 0..n {
            w0.extend_from_slice(wo);
        }
        let mut sigma = match weighting {
            None => Mat::eye(nl),
            Some(scales) => {
                assert_eq!(scales.len(), n);
                let mut m = Mat::zeros(nl, nl);
                for k in 0..n {
                    for j in 0..l {
                        m[(k * l + j, k * l + j)] = scales[k];
                    }
                }
                m
            }
        };
        // Ping-pong buffers + workspace: the loop below performs zero
        // heap allocations per iteration (asserted by
        // rust/tests/alloc_free.rs).
        let mut sigma_next = Mat::zeros(nl, nl);
        let mut ws = self.workspace();
        let mut noise_acc = 0.0;
        let mut msd = Vec::with_capacity(iters);
        for _ in 0..iters {
            noise_acc += self.noise(&sigma);
            self.apply_into(&sigma, &mut ws, &mut sigma_next);
            std::mem::swap(&mut sigma, &mut sigma_next);
            let v = (sigma.quad_form(&w0, &w0) + noise_acc) / n as f64;
            msd.push(v);
        }
        let steady_state = *msd.last().unwrap_or(&f64::NAN);
        MsdTrajectory { msd, steady_state }
    }

    /// Mean-square stability radius: the spectral radius of the linear
    /// operator 𝓕 : Σ ↦ E{𝓑ᵢᵀΣ𝓑ᵢ} (eq. (68)) estimated by power
    /// iteration *on the operator* — the (NL)²×(NL)² matrix itself is
    /// never formed, and the loop is allocation-free (ping-pong Σ
    /// buffers). The algorithm is mean-square stable iff this is < 1.
    pub fn ms_stability_radius(&self, iters: usize) -> f64 {
        let nl = self.bop.nl();
        let mut sigma = Mat::eye(nl);
        let mut next = Mat::zeros(nl, nl);
        let mut ws = self.workspace();
        let mut rho = 0.0;
        for _ in 0..iters {
            // Keep the iterate symmetric PSD-ish; F preserves the cone,
            // so the Frobenius growth ratio converges to rho(F).
            self.apply_into(&sigma, &mut ws, &mut next);
            let norm = next.fro_norm();
            if norm == 0.0 {
                return 0.0;
            }
            rho = norm / sigma.fro_norm().max(1e-300);
            std::mem::swap(&mut sigma, &mut next);
            sigma.scale_in_place(1.0 / norm);
        }
        rho
    }

    /// Iterate until the MSD increment falls below `tol` (relative),
    /// returning (steady-state MSD, iterations used). Allocation-free
    /// per iteration (ping-pong Σ buffers + workspace).
    pub fn steady_state(&self, wo: &[f64], tol: f64, max_iters: usize) -> (f64, usize) {
        let (n, l) = (self.setup.n_nodes, self.setup.dim);
        let nl = n * l;
        let mut w0 = Vec::with_capacity(nl);
        for _ in 0..n {
            w0.extend_from_slice(wo);
        }
        let mut sigma = Mat::eye(nl);
        let mut sigma_next = Mat::zeros(nl, nl);
        let mut ws = self.workspace();
        let mut noise_acc = 0.0;
        let mut prev = f64::INFINITY;
        for i in 1..=max_iters {
            noise_acc += self.noise(&sigma);
            self.apply_into(&sigma, &mut ws, &mut sigma_next);
            std::mem::swap(&mut sigma, &mut sigma_next);
            let v = (sigma.quad_form(&w0, &w0) + noise_acc) / n as f64;
            if (v - prev).abs() <= tol * v.abs().max(1e-30) {
                return (v, i);
            }
            prev = v;
        }
        (prev, max_iters)
    }
}

/// Largest |Σ_{ij} − Σ_{ji}| — symmetry diagnostic for the fast-path
/// debug assertion.
fn max_asymmetry(m: &Mat) -> f64 {
    let mut worst = 0.0f64;
    for i in 0..m.rows() {
        for j in (i + 1)..m.cols() {
            worst = worst.max((m[(i, j)] - m[(j, i)]).abs());
        }
    }
    worst
}

/// Precompute the quadratic coefficients g_off/g_diag for every
/// contributing (a, b, k, l) quadruple.
///
/// Y_{ab} = Σ_{k,l} E{[𝓧]ᵀ_{ka} Φ_{kl} [𝓧]_{lb}} with
///   [𝓧]_{ka} = δ_{ka} D_k + c_{ak} σ²_a Q_a (I − H_k),
///   D_k = Σ_m c_{mk} (σ²_m Q_m H_k + σ²_k (I − Q_m)),
/// all diagonal, so the coefficient of Φ_{kl} entry (i, j) is
/// E[x_{ka,i} x_{lb,j}], which only depends on i = j vs i ≠ j.
///
/// Combiner entries are consumed only through `cm` (supports and pair
/// moments `E[C_{mk} C_{nl}]`), so the same builder serves the ideal
/// model (deterministic products) and the impaired model (link-state
/// second moments, DESIGN.md §7).
pub(super) fn build_quad_terms(s: &TheorySetup, cm: &dyn CombinerMoments) -> Vec<QuadTerm> {
    let n = s.n_nodes;
    let qm = MaskMoments::new(s.m_grad, s.dim);
    let hm = MaskMoments::new(s.m, s.dim);

    let eval = |a: usize, k: usize, b: usize, l: usize, same: bool| -> f64 {
        let su = &s.sigma_u2;
        let mut total = 0.0;
        let diag_a = k == a;
        let diag_b = l == b;
        let off_a = cm.has(a, k);
        let off_b = cm.has(b, l);
        // A: diag × diag.
        if diag_a && diag_b {
            for &m in cm.supp(k) {
                for &nn in cm.supp(l) {
                    // E[(σ²_m q_m h_k + σ²_k(1−q_m))(σ²_n q_n h_l + σ²_l(1−q_n))]
                    // expanded into its four sub-products:
                    let t1 = su[m] * su[nn] * qm.pair(m, nn, same) * hm.pair(k, l, same);
                    let t2 = su[m] * su[l] * qm.pair_comp(m, nn, same) * hm.mean();
                    let t3 = su[k] * su[nn] * qm.pair_comp(nn, m, same) * hm.mean();
                    let t4 = su[k] * su[l] * qm.comp_comp(m, nn, same);
                    total += cm.cc(m, k, nn, l) * (t1 + t2 + t3 + t4);
                }
            }
        }
        // B: diag(k=a) × off(l, b).
        if diag_a && off_b {
            for &m in cm.supp(k) {
                let t1 = su[m] * qm.pair(m, b, same) * hm.pair_comp(k, l, same);
                let t2 = su[k] * qm.pair_comp(b, m, same) * (1.0 - hm.mean());
                total += cm.cc(m, k, b, l) * su[b] * (t1 + t2);
            }
        }
        // C: off(k, a) × diag(l=b).
        if off_a && diag_b {
            for &nn in cm.supp(l) {
                let t1 = su[nn] * qm.pair(a, nn, same) * hm.pair_comp(l, k, same);
                let t2 = su[l] * qm.pair_comp(a, nn, same) * (1.0 - hm.mean());
                total += cm.cc(a, k, nn, l) * su[a] * (t1 + t2);
            }
        }
        // D: off × off.
        if off_a && off_b {
            total += cm.cc(a, k, b, l)
                * su[a]
                * su[b]
                * qm.pair(a, b, same)
                * hm.comp_comp(k, l, same);
        }
        total
    };

    // k must satisfy k == a or C_{ak} possibly nonzero (k ∈ N_a ∪ {a}).
    // Hoisted: invert the supports once (O(nnz)) instead of scanning all
    // n columns per (a, b) pair — the ascending push order reproduces the
    // historical filter order exactly, so the term list is unchanged.
    let mut ks_of: Vec<Vec<usize>> = vec![Vec::new(); n];
    for k in 0..n {
        for &m in cm.supp(k) {
            ks_of[m].push(k);
        }
    }
    for (a, list) in ks_of.iter_mut().enumerate() {
        if let Err(pos) = list.binary_search(&a) {
            list.insert(pos, a);
        }
    }

    let mut out = Vec::new();
    for a in 0..n {
        let ks = &ks_of[a];
        for b in 0..n {
            let ls = &ks_of[b];
            for &k in ks {
                for &l in ls {
                    let g_off = eval(a, k, b, l, false);
                    let g_diag = eval(a, k, b, l, true);
                    if g_off != 0.0 || g_diag != 0.0 {
                        out.push(QuadTerm { a, b, k, l, g_off, g_diag });
                    }
                }
            }
        }
    }
    out
}

/// Noise coefficients: noise(Σ) = Σ_{k,l} w[k*n+l] tr(Σ_{kl}) with
/// w[k*n+l] = Σ_b σ²_{v,b} σ²_{u,b} μ_k μ_l gN(k, l, b) and
/// gN = E[y_{kb,i} y_{lb,i}] for [𝓖]_{kb} = μ_k (c_{bk} Q_b + δ_{kb} Σ_m c_{mk}(I − Q_m)).
///
/// Like [`build_quad_terms`], combiner entries enter only through the
/// pair moments of `cm`, so the impaired model reuses this builder with
/// its link-state moments (DESIGN.md §7).
pub(super) fn build_noise_coeffs(s: &TheorySetup, cm: &dyn CombinerMoments) -> Vec<f64> {
    let n = s.n_nodes;
    let qm = MaskMoments::new(s.m_grad, s.dim);
    let mut w = vec![0.0; n * n];
    for k in 0..n {
        for lnb in 0..n {
            let mut acc = 0.0;
            for b in 0..n {
                let sb = s.sigma_v2[b] * s.sigma_u2[b];
                if sb == 0.0 {
                    continue;
                }
                let mut g = cm.cc(b, k, b, lnb) * qm.pair(b, b, true); // term 1
                if lnb == b {
                    // term 2: c_{bk} Σ_n c_{n,l} E[q_b (1 − q_n)]  (same entry)
                    for &nn in cm.supp(lnb) {
                        g += cm.cc(b, k, nn, lnb) * qm.pair_comp(b, nn, true);
                    }
                }
                if k == b {
                    // term 3 (mirror).
                    for &m in cm.supp(k) {
                        g += cm.cc(m, k, b, lnb) * qm.pair_comp(b, m, true);
                    }
                }
                if k == b && lnb == b {
                    // term 4.
                    for &m in cm.supp(k) {
                        for &nn in cm.supp(lnb) {
                            g += cm.cc(m, k, nn, lnb) * qm.comp_comp(m, nn, true);
                        }
                    }
                }
                acc += sb * g;
            }
            w[k * n + lnb] = acc * s.mu[k] * s.mu[lnb];
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn setup(n: usize, l: usize, m: usize, mg: usize, mu: f64) -> TheorySetup {
        let graph = Graph::ring(n, 1);
        let c = combination_matrix(&graph, Rule::Metropolis).to_dense();
        TheorySetup {
            n_nodes: n,
            dim: l,
            m,
            m_grad: mg,
            c,
            mu: vec![mu; n],
            sigma_u2: (0..n).map(|k| 0.7 + 0.15 * k as f64).collect(),
            sigma_v2: (0..n).map(|k| 1e-3 * (1.0 + k as f64 * 0.3)).collect(),
        }
    }

    /// Random full (non-block-diagonal) weighting matrix.
    fn random_sigma(nl: usize, rng: &mut Pcg64) -> Mat {
        let mut m = Mat::zeros(nl, nl);
        for i in 0..nl {
            for j in 0..nl {
                m[(i, j)] = rng.next_gaussian();
            }
        }
        // Symmetric PSD-ish: M Mᵀ.
        let mt = m.transpose();
        &m * &mt
    }

    /// Draw masks and build 𝓑ᵢ explicitly (with R_{u,i} frozen at R_u,
    /// matching the operator's (83) approximation).
    fn sample_b_i(s: &TheorySetup, rng: &mut Pcg64) -> Mat {
        let (n, l) = (s.n_nodes, s.dim);
        let mut scratch = Vec::new();
        let mut h = vec![vec![0f32; l]; n];
        let mut q = vec![vec![0f32; l]; n];
        for k in 0..n {
            rng.fill_mask(&mut h[k], s.m, &mut scratch);
            rng.fill_mask(&mut q[k], s.m_grad, &mut scratch);
        }
        let mut b = Mat::eye(n * l);
        for k in 0..n {
            for lnb in 0..n {
                let clk = s.c[(lnb, k)];
                for j in 0..l {
                    let mut x = 0.0;
                    if lnb == k {
                        for m_ in 0..n {
                            let cmk = s.c[(m_, k)];
                            if cmk == 0.0 {
                                continue;
                            }
                            x += cmk
                                * (s.sigma_u2[m_] * q[m_][j] as f64 * h[k][j] as f64
                                    + s.sigma_u2[k] * (1.0 - q[m_][j] as f64));
                        }
                    }
                    if clk != 0.0 {
                        x += clk * s.sigma_u2[lnb] * q[lnb][j] as f64 * (1.0 - h[k][j] as f64);
                    }
                    b[(k * l + j, lnb * l + j)] -= s.mu[k] * x;
                }
            }
        }
        b
    }

    /// The core validation of the whole theory engine: the closed-form
    /// operator must equal the Monte-Carlo average of 𝓑ᵢᵀ Σ 𝓑ᵢ.
    #[test]
    fn operator_matches_monte_carlo() {
        let s = setup(4, 3, 2, 1, 0.3);
        let model = MsdModel::new(s.clone());
        let mut rng = Pcg64::new(31, 0);
        let sigma = random_sigma(12, &mut rng);
        let closed = model.apply(&sigma);
        let trials = 60_000;
        let mut acc = Mat::zeros(12, 12);
        for _ in 0..trials {
            let b_i = sample_b_i(&s, &mut rng);
            let prod = &(&b_i.transpose() * &sigma) * &b_i;
            acc.axpy(1.0, &prod);
        }
        acc.scale_in_place(1.0 / trials as f64);
        let diff = (&acc - &closed).max_abs();
        let scale = closed.max_abs();
        assert!(diff < 0.02 * scale, "MC mismatch: {diff} (scale {scale})");
    }

    /// Noise term trace(E{𝓖ᵀΣ𝓖}𝓢) vs Monte-Carlo.
    #[test]
    fn noise_matches_monte_carlo() {
        let s = setup(4, 3, 2, 1, 0.3);
        let model = MsdModel::new(s.clone());
        let mut rng = Pcg64::new(37, 0);
        let sigma = random_sigma(12, &mut rng);
        let closed = model.noise(&sigma);
        let (n, l) = (4usize, 3usize);
        let trials = 60_000;
        let mut acc = 0.0;
        let mut scratch = Vec::new();
        let mut q = vec![vec![0f32; l]; n];
        for _ in 0..trials {
            for k in 0..n {
                rng.fill_mask(&mut q[k], s.m_grad, &mut scratch);
            }
            // G blocks are diagonal: [G]_{kl} = μ_k (c_{lk} Q_l + δ_{kl} Σ_m c_{mk}(I−Q_m)).
            let mut g = Mat::zeros(n * l, n * l);
            for k in 0..n {
                for lnb in 0..n {
                    for j in 0..l {
                        let mut y = s.c[(lnb, k)] * q[lnb][j] as f64;
                        if lnb == k {
                            for m_ in 0..n {
                                y += s.c[(m_, k)] * (1.0 - q[m_][j] as f64);
                            }
                        }
                        g[(k * l + j, lnb * l + j)] = s.mu[k] * y;
                    }
                }
            }
            // trace(GᵀΣG S) with S = blockdiag(σ²_v σ²_u I).
            let gts_g = &(&g.transpose() * &sigma) * &g;
            for b in 0..n {
                let sb = s.sigma_v2[b] * s.sigma_u2[b];
                for j in 0..l {
                    acc += sb * gts_g[(b * l + j, b * l + j)];
                }
            }
        }
        let mc = acc / trials as f64;
        assert!(
            (mc - closed).abs() < 0.02 * closed.abs().max(1e-12),
            "noise MC {mc} vs closed {closed}"
        );
    }

    /// Full masks (M = M_grad = L) are deterministic: the operator must
    /// be exactly 𝓑ᵀΣ𝓑 with 𝓑 = I − 𝓜𝓡 (diffusion LMS with C).
    #[test]
    fn full_masks_reduce_to_diffusion_lms() {
        let s = setup(4, 3, 3, 3, 0.2);
        let model = MsdModel::new(s.clone());
        let mut rng = Pcg64::new(41, 0);
        let sigma = random_sigma(12, &mut rng);
        let closed = model.apply(&sigma);
        let b = build_b(&s);
        let exact = &(&b.transpose() * &sigma) * &b;
        let diff = (&exact - &closed).max_abs();
        assert!(diff < 1e-9 * exact.max_abs().max(1.0), "diff {diff}");
    }

    /// Trajectory sanity: decreasing from ‖w°‖², converging, positive.
    #[test]
    fn trajectory_converges() {
        let s = setup(5, 4, 2, 2, 0.05);
        let model = MsdModel::new(s);
        let wo = vec![0.5, -0.3, 0.8, 0.1];
        let tr = model.trajectory(&wo, 2000);
        let norm2: f64 = wo.iter().map(|x| x * x).sum();
        assert!((tr.msd[0] - norm2).abs() < norm2 * 0.5);
        assert!(tr.steady_state > 0.0);
        assert!(tr.steady_state < 1e-2);
        // Monotone-ish decay towards steady state.
        assert!(tr.msd[10] > tr.msd[500]);
        let (ss, iters) = model.steady_state(&wo, 1e-9, 20_000);
        assert!(iters < 20_000);
        assert!((ss - tr.steady_state).abs() < 0.1 * ss);
    }

    /// Mean-square stability radius separates stable from unstable step
    /// sizes, and is strictly larger than the mean radius would suggest
    /// (mean-square stability is the stricter requirement).
    #[test]
    fn ms_stability_radius_tracks_mu() {
        let stable = MsdModel::new(setup(4, 3, 2, 1, 0.05));
        let rho = stable.ms_stability_radius(400);
        assert!(rho < 1.0, "rho {rho}");
        let unstable = MsdModel::new(setup(4, 3, 2, 1, 2.5));
        let rho_bad = unstable.ms_stability_radius(400);
        assert!(rho_bad > 1.0, "rho {rho_bad}");
        // Note: rho(F) ≈ 1 − 2μλ + O(μ²) is *not* monotone in μ — it dips
        // before the mean-square edge; we only assert the two regimes.
        let mid = MsdModel::new(setup(4, 3, 2, 1, 0.5)).ms_stability_radius(400);
        assert!(mid < 1.0, "mid {mid}");
    }

    /// The allocation-free fast path must reproduce the reference
    /// operator on random symmetric Σ across the whole (N, L) sweep the
    /// experiments exercise.
    #[test]
    fn apply_into_matches_reference_apply() {
        let mut rng = Pcg64::new(71, 0);
        for &n in &[2usize, 5, 10] {
            for &l in &[1usize, 2, 5] {
                let m = ((3 * l) / 5).max(1);
                let mg = (l / 2).max(1);
                let s = setup(n, l, m, mg, 0.2);
                let model = MsdModel::new(s);
                let nl = n * l;
                let mut ws = model.workspace();
                let mut fast = Mat::zeros(nl, nl);
                // Reuse the same workspace across draws (it must not
                // carry state between applications).
                for _ in 0..3 {
                    let sigma = random_sigma(nl, &mut rng);
                    let reference = model.apply(&sigma);
                    model.apply_into(&sigma, &mut ws, &mut fast);
                    let tol = 1e-12 * reference.max_abs().max(1.0);
                    let diff = (&fast - &reference).max_abs();
                    assert!(diff < tol, "N={n} L={l}: diff {diff} (tol {tol})");
                }
            }
        }
    }

    /// Iterating the fast path (as the trajectory/steady-state loops do)
    /// must track the iterated reference operator, and the fast-path
    /// iterates must stay exactly symmetric (that is what licenses the
    /// Σ𝓑 = (𝓑ᵀΣ)ᵀ fusion on the next application).
    #[test]
    fn iterated_fast_path_matches_iterated_reference() {
        let s = setup(5, 4, 2, 1, 0.1);
        let model = MsdModel::new(s);
        let nl = 20;
        let mut reference = Mat::eye(nl);
        let mut sigma = Mat::eye(nl);
        let mut next = Mat::zeros(nl, nl);
        let mut ws = model.workspace();
        for it in 0..8 {
            reference = model.apply(&reference);
            model.apply_into(&sigma, &mut ws, &mut next);
            std::mem::swap(&mut sigma, &mut next);
            assert_eq!(max_asymmetry(&sigma), 0.0, "iteration {it} broke symmetry");
            let tol = 1e-10 * reference.max_abs().max(1.0);
            let diff = (&sigma - &reference).max_abs();
            assert!(diff < tol, "iteration {it}: diff {diff} (tol {tol})");
        }
    }

    /// The CSR linear path (used automatically above `DENSE_NL_LIMIT`)
    /// must agree with the dense path on the full model surface: fast
    /// operator application, trajectories, and both stability radii.
    #[test]
    fn sparse_linear_path_matches_dense() {
        let s = setup(6, 4, 2, 1, 0.1);
        let dense = MsdModel::new(s.clone());
        let mut sparse = MsdModel::new(s.clone());
        let b = build_b_csr(&s);
        let bt = b.transpose();
        sparse.bop = BOperator::Sparse { b, bt };

        let mut rng = Pcg64::new(83, 0);
        let nl = 24;
        let mut ws_d = dense.workspace();
        let mut ws_s = sparse.workspace();
        let mut out_d = Mat::zeros(nl, nl);
        let mut out_s = Mat::zeros(nl, nl);
        for _ in 0..3 {
            let sigma = random_sigma(nl, &mut rng);
            dense.apply_into(&sigma, &mut ws_d, &mut out_d);
            sparse.apply_into(&sigma, &mut ws_s, &mut out_s);
            let tol = 1e-12 * out_d.max_abs().max(1.0);
            let diff = (&out_s - &out_d).max_abs();
            assert!(diff < tol, "apply_into diff {diff} (tol {tol})");
        }

        let wo = vec![0.5, -0.3, 0.8, 0.1];
        let td = dense.trajectory(&wo, 200);
        let ts = sparse.trajectory(&wo, 200);
        for (x, y) in td.msd.iter().zip(&ts.msd) {
            assert!((x - y).abs() < 1e-10 * x.abs().max(1e-30));
        }
        let rd = dense.ms_stability_radius(200);
        let rs = sparse.ms_stability_radius(200);
        assert!((rd - rs).abs() < 1e-10, "{rd} vs {rs}");
        let md = dense.mean_radius(2000);
        let ms = sparse.mean_radius(2000);
        assert!((md - ms).abs() < 1e-10, "{md} vs {ms}");
    }

    /// More compression (smaller M, M_grad) must not *decrease* the
    /// steady-state MSD.
    #[test]
    fn compression_monotonicity() {
        let wo = vec![0.5, -0.4, 0.3];
        let ss = |m: usize, mg: usize| {
            let s = setup(4, 3, m, mg, 0.05);
            MsdModel::new(s).steady_state(&wo, 1e-10, 30_000).0
        };
        let full = ss(3, 3);
        let compressed = ss(2, 1);
        let very = ss(1, 1);
        assert!(full <= compressed * 1.05, "{full} vs {compressed}");
        assert!(compressed <= very * 1.05, "{compressed} vs {very}");
    }
}
