//! Closed-form moments of the random effective adapt combiner under
//! independent Bernoulli link states (DESIGN.md §7).
//!
//! The coordinator's impairment layer (`coordinator/impairments.rs`)
//! draws, per iteration, a transmit gate `g_m ~ Bernoulli(p_tx)` per
//! node and an erasure `d_{mk} ~ Bernoulli(1 − p_drop)` per directed
//! link, and keeps node k's adapt weight for source m iff
//!
//! ```text
//!   y_{mk} = g_m · d_{mk} · g_k
//! ```
//!
//! (transmitter on the air, frame delivered, receiver soliciting).
//! Erased mass is re-allocated to the receiver's self weight, so the
//! effective combiner of one iteration is
//!
//! ```text
//!   C_{mk}(i) = c⁰_{mk} · y_{mk}                            (m ≠ k)
//!   C_{kk}(i) = c⁰_{kk} + Σ_{m ∈ N(k)} c⁰_{mk} (1 − y_{mk})
//! ```
//!
//! Every `y` is a product of independent Bernoullis shared across links
//! only through the per-node gates, so joint moments have closed form:
//!
//! ```text
//!   E[y_{mk} y_{nl}] = p_tx^{|{m,k} ∪ {n,l}|} · (1 − p_drop)^{#distinct links}
//! ```
//!
//! (a gate squared is itself, so repeated node indices collapse). This
//! module packages the first moment (the expected combiner C̄) and every
//! pair moment `E[C_{mk} C_{nl}]` — including the diagonal-collapse
//! expansions — behind the [`CombinerMoments`] interface the variance-
//! operator builders consume, and is cross-validated against the *real*
//! coordinator reallocation by Monte-Carlo in `theory/impaired.rs`.
//!
//! At `p_drop = 0`, `p_tx = 1` every `y ≡ 1` and all formulas reduce to
//! the deterministic products *exactly* (the correction terms are exact
//! float zeros), which is what makes the impaired model degenerate to
//! the ideal [`super::MsdModel`] at zero impairment.

use super::msd::CombinerMoments;
use crate::coordinator::impairments::reallocate_expected;
use crate::linalg::Mat;

/// Bernoulli link-state moments over a pristine adapt combiner `c⁰`.
pub(super) struct LinkStateMoments {
    /// Pristine combiner (owned copy; columns indexed as `c0[(m, k)]`).
    c0: Mat,
    /// Off-diagonal support per column: sources `m ≠ k` with `c⁰_{mk} ≠ 0`.
    nb: Vec<Vec<usize>>,
    /// Full support per column including the diagonal (erased mass can
    /// always land there).
    supp: Vec<Vec<usize>>,
    /// Per-node transmit probability `p_tx`.
    p_tx: f64,
    /// Per-link survival probability `1 − p_drop`.
    keep: f64,
}

impl LinkStateMoments {
    pub(super) fn new(c0: &Mat, drop_prob: f64, tx_prob: f64) -> Self {
        assert!(c0.is_square());
        let n = c0.cols();
        let nb = (0..n)
            .map(|k| (0..n).filter(|&m| m != k && c0[(m, k)] != 0.0).collect())
            .collect();
        let supp = (0..n)
            .map(|k| (0..n).filter(|&m| m == k || c0[(m, k)] != 0.0).collect())
            .collect();
        Self { c0: c0.clone(), nb, supp, p_tx: tx_prob, keep: 1.0 - drop_prob }
    }

    /// `E[y_{mk}]` for any off-diagonal link: both gates up, no erasure.
    fn y1(&self) -> f64 {
        self.p_tx * self.p_tx * self.keep
    }

    /// `E[y_{mk} y_{nl}]` for two (possibly equal) off-diagonal links.
    fn y2(&self, m: usize, k: usize, n: usize, l: usize) -> f64 {
        let d = if m == n && k == l { self.keep } else { self.keep * self.keep };
        let mut v = [m, k, n, l];
        v.sort_unstable();
        let mut distinct = 1i32;
        for i in 1..4 {
            if v[i] != v[i - 1] {
                distinct += 1;
            }
        }
        self.p_tx.powi(distinct) * d
    }

    /// The expected effective combiner C̄ = E{C(i)}: off-diagonal mass
    /// scaled by `E[y]`, the complement re-allocated to the diagonal —
    /// the coordinator's per-iteration reallocation, in expectation
    /// (the same shared `reallocate_expected` the coordinator's
    /// `expected_combiners` uses, so the two layers cannot drift).
    pub(super) fn mean_matrix(&self) -> Mat {
        reallocate_expected(&self.c0, self.y1())
    }

    /// `E[C_{kk} C_{nl}]` for an off-diagonal `(n, l)`: expand the
    /// diagonal collapse sum against the single survival indicator.
    fn diag_off(&self, k: usize, n: usize, l: usize) -> f64 {
        let y1 = self.y1();
        let mut t = self.c0[(k, k)] * y1;
        for &mp in &self.nb[k] {
            t += self.c0[(mp, k)] * (y1 - self.y2(mp, k, n, l));
        }
        self.c0[(n, l)] * t
    }

    /// `E[C_{kk} C_{ll}]`: both diagonal collapse sums expanded, with
    /// `E[(1 − y)(1 − y')] = 1 − 2·E[y] + E[y y']` per cross term.
    fn diag_diag(&self, k: usize, l: usize) -> f64 {
        let y1 = self.y1();
        let mut t = self.c0[(k, k)] * self.c0[(l, l)];
        for &np in &self.nb[l] {
            t += self.c0[(k, k)] * self.c0[(np, l)] * (1.0 - y1);
        }
        for &mp in &self.nb[k] {
            t += self.c0[(l, l)] * self.c0[(mp, k)] * (1.0 - y1);
        }
        for &mp in &self.nb[k] {
            for &np in &self.nb[l] {
                t += self.c0[(mp, k)]
                    * self.c0[(np, l)]
                    * (1.0 - 2.0 * y1 + self.y2(mp, k, np, l));
            }
        }
        t
    }
}

impl CombinerMoments for LinkStateMoments {
    fn supp(&self, k: usize) -> &[usize] {
        &self.supp[k]
    }

    fn has(&self, m: usize, k: usize) -> bool {
        m == k || self.c0[(m, k)] != 0.0
    }

    fn cc(&self, m: usize, k: usize, n: usize, l: usize) -> f64 {
        match (m == k, n == l) {
            (false, false) => self.c0[(m, k)] * self.c0[(n, l)] * self.y2(m, k, n, l),
            (true, false) => self.diag_off(k, n, l),
            (false, true) => self.diag_off(l, m, k),
            (true, true) => self.diag_diag(k, l),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;
    use crate::topology::{combination_matrix, Graph, Rule};

    fn c0(n: usize) -> Mat {
        combination_matrix(&Graph::ring(n, 1), Rule::Metropolis)
    }

    /// Zero impairment must reproduce the deterministic products and the
    /// pristine matrix *exactly* (the degeneration the impaired model's
    /// 1e-12 equivalence test relies on).
    #[test]
    fn ideal_limit_is_exact() {
        let c = c0(5);
        let lm = LinkStateMoments::new(&c, 0.0, 1.0);
        assert_eq!(lm.mean_matrix(), c);
        for m in 0..5 {
            for k in 0..5 {
                for n in 0..5 {
                    for l in 0..5 {
                        if lm.has(m, k) && lm.has(n, l) {
                            assert_eq!(lm.cc(m, k, n, l), c[(m, k)] * c[(n, l)]);
                        }
                    }
                }
            }
        }
    }

    /// Every pair moment against brute-force Monte-Carlo over the
    /// Bernoulli gates and erasures (the coordinator's sampling rule).
    #[test]
    fn pair_moments_match_monte_carlo() {
        let n = 4;
        let c = c0(n);
        let (pd, pg) = (0.3, 0.7);
        let lm = LinkStateMoments::new(&c, pd, pg);
        let mut rng = Pcg64::new(77, 0);
        let trials = 200_000;
        let mut acc = vec![0.0f64; n * n * n * n];
        let mut ceff = Mat::zeros(n, n);
        for _ in 0..trials {
            let g: Vec<bool> = (0..n).map(|_| rng.next_bool(pg)).collect();
            ceff.data_mut().copy_from_slice(c.data());
            for k in 0..n {
                for m in 0..n {
                    if m == k || c[(m, k)] == 0.0 {
                        continue;
                    }
                    let delivered = g[m] && !rng.next_bool(pd);
                    if !delivered || !g[k] {
                        let w = ceff[(m, k)];
                        ceff[(m, k)] = 0.0;
                        ceff[(k, k)] += w;
                    }
                }
            }
            for m in 0..n {
                for k in 0..n {
                    for nn in 0..n {
                        for l in 0..n {
                            acc[((m * n + k) * n + nn) * n + l] +=
                                ceff[(m, k)] * ceff[(nn, l)];
                        }
                    }
                }
            }
        }
        for m in 0..n {
            for k in 0..n {
                for nn in 0..n {
                    for l in 0..n {
                        if !(lm.has(m, k) && lm.has(nn, l)) {
                            continue;
                        }
                        let mc = acc[((m * n + k) * n + nn) * n + l] / trials as f64;
                        let closed = lm.cc(m, k, nn, l);
                        assert!(
                            (mc - closed).abs() < 8e-3,
                            "E[C_{m}{k} C_{nn}{l}]: MC {mc} vs closed {closed}"
                        );
                    }
                }
            }
        }
    }

    /// C̄ keeps columns stochastic (mass is only re-allocated).
    #[test]
    fn mean_matrix_columns_sum_to_one() {
        let c = c0(6);
        let lm = LinkStateMoments::new(&c, 0.25, 0.8);
        let cb = lm.mean_matrix();
        for k in 0..6 {
            let s: f64 = (0..6).map(|m| cb[(m, k)]).sum();
            assert!((s - 1.0).abs() < 1e-12, "column {k} sums to {s}");
        }
    }
}
