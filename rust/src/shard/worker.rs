//! The `dcd-lms shard-worker` loop: the child-process half of the
//! sharded Monte-Carlo runner (DESIGN.md §8).
//!
//! A worker reads exactly one [`Frame::Job`] line from stdin, replays
//! the job description (a scenario INI or an exp3 INI — the *same*
//! builders the in-process runner uses, which is what makes per-run
//! results bit-identical), executes its contiguous realization block
//! fanned across its in-process thread budget, and then writes one
//! [`Frame::Run`] per realization to stdout in run order, terminated by
//! [`Frame::Done`]. (The block completes before the frames go out —
//! the in-worker thread pool returns results all at once; "streaming"
//! is per run on the wire, not overlapped with compute.) Any failure
//! is reported as a terminal [`Frame::Error`] frame *and* a non-zero
//! exit, so the supervisor can distinguish a clean refusal from a
//! crash either way.

use std::io::{BufRead, Write};

use crate::config::{Exp3Config, IniDoc};
use crate::coordinator::runner::{parallel_ordered, resolve_threads};
use crate::experiments::exp3::{exp3_settings, Exp3Parts};
use crate::scenario::{mc_parts, scheduler_options, wsn_block, Scenario, ScheduleMode};

use super::protocol::{Frame, JobKind, RunPayload, ShardJob};

/// Env hook for the crash tests: a worker that finds this set to a path
/// atomically creates the file and exits 17 — exactly once across all
/// workers sharing the marker (`create_new`), so the supervisor's
/// re-spawn path gets one deterministic crash to recover from.
pub const CRASH_ONCE_ENV: &str = "DCD_SHARD_TEST_CRASH_ONCE";

/// Env hook for the crash tests: a worker whose block contains this
/// global run index exits 17 just before emitting that run's frame
/// (i.e. mid-stream, after earlier frames already went out) — on every
/// attempt, so with retries exhausted the supervisor must surface a
/// clean error.
pub const CRASH_RUN_ENV: &str = "DCD_SHARD_TEST_CRASH_RUN";

/// Run the shard-worker protocol over this process's stdin/stdout.
/// On error the terminal [`Frame::Error`] has already been emitted;
/// the caller (main) should still exit non-zero with the message.
pub fn worker_main() -> Result<(), String> {
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    match run_worker(&mut out) {
        Ok(()) => Ok(()),
        Err(message) => {
            let message = format!("shard-worker: {message}");
            // Best effort: the supervisor may already be gone.
            let _ = writeln!(out, "{}", Frame::Error { message: message.clone() }.encode());
            let _ = out.flush();
            Err(message)
        }
    }
}

fn run_worker(out: &mut impl Write) -> Result<(), String> {
    let mut line = String::new();
    std::io::stdin()
        .lock()
        .read_line(&mut line)
        .map_err(|e| format!("reading the job frame from stdin: {e}"))?;
    if line.trim().is_empty() {
        return Err("empty input: expected one job frame on stdin".to_string());
    }
    let job = match Frame::decode(&line)? {
        Frame::Job(job) => job,
        other => {
            return Err(format!(
                "expected a job frame on stdin, got a {} frame",
                frame_name(&other)
            ))
        }
    };
    crash_once_hook();
    let payloads = match job.kind {
        JobKind::Mc => run_mc_block(&job)?,
        JobKind::Wsn => run_wsn_block(&job)?,
    };
    debug_assert_eq!(payloads.len(), job.run_count);
    let crash_run = crash_run_index();
    for (i, payload) in payloads.into_iter().enumerate() {
        let run = job.run_start + i;
        if crash_run == Some(run) {
            // Simulated kill mid-stream (after earlier frames went out).
            std::process::exit(17);
        }
        writeln!(out, "{}", Frame::Run { run, payload }.encode())
            .map_err(|e| format!("writing run frame {run}: {e}"))?;
    }
    writeln!(out, "{}", Frame::Done { runs: job.run_count }.encode())
        .map_err(|e| format!("writing done frame: {e}"))?;
    out.flush().map_err(|e| format!("flushing stdout: {e}"))?;
    Ok(())
}

/// Replay a scenario job and execute its realization block on the same
/// code path `run_scenario` uses in-process. A `mode = wsn` scenario
/// dispatches to the event-driven scheduler and answers with WSN run
/// frames; the default rounds mode stays on the Monte-Carlo runner.
fn run_mc_block(job: &ShardJob) -> Result<Vec<RunPayload>, String> {
    let sc = Scenario::parse_str(&job.payload)
        .map_err(|e| format!("job payload is not a valid scenario: {e}"))?;
    sc.validate()?;
    check_block(job, sc.runs)?;
    if matches!(sc.mode, ScheduleMode::Wsn { .. }) {
        let results = wsn_block(&sc, job.run_start, job.run_count, job.threads)?;
        return Ok(results.into_iter().map(RunPayload::Wsn).collect());
    }
    let (model, net, mut mc) = mc_parts(&sc)?;
    // The supervisor divides the machine across the concurrent shards;
    // its budget overrides the scenario's own (whole-machine) setting.
    mc.threads = job.threads;
    let opts = scheduler_options(&sc);
    // Same lane dispatch as the in-process path (DESIGN.md §14): the
    // engine is bit-identical per run, so sharding composes freely.
    let results = mc.run_rust_lanes_range_opts(
        &model,
        &opts,
        sc.lanes.resolve(sc.runs),
        || sc.algorithm.build(net.clone()),
        job.run_start,
        job.run_count,
    );
    Ok(results.into_iter().map(RunPayload::Mc).collect())
}

/// Replay an exp3 WSN job and execute its realization block with the
/// per-run seeds of `experiments::exp3` (`seed + r·7919 + 1`).
fn run_wsn_block(job: &ShardJob) -> Result<Vec<RunPayload>, String> {
    let doc = IniDoc::parse(&job.payload)
        .map_err(|e| format!("job payload is not a valid exp3 INI: {e}"))?;
    let mut cfg = Exp3Config::default();
    cfg.apply(&doc)?;
    check_block(job, cfg.runs)?;
    let parts = Exp3Parts::build(&cfg);
    let settings = exp3_settings(&cfg, parts.mean_deg);
    let (algo, mu) = *settings.get(job.algo_index).ok_or_else(|| {
        format!(
            "algo_index {} out of range (exp3 has {} settings)",
            job.algo_index,
            settings.len()
        )
    })?;
    let sim = parts.simulation(&cfg, algo, mu);
    let seed = cfg.seed;
    let threads = resolve_threads(job.threads, job.run_count);
    let results = parallel_ordered(job.run_count, threads, |i| {
        sim.run(seed.wrapping_add((job.run_start + i) as u64 * 7919 + 1))
    });
    Ok(results.into_iter().map(RunPayload::Wsn).collect())
}

/// Validate the job's block against the replayed config's run count.
fn check_block(job: &ShardJob, total_runs: usize) -> Result<(), String> {
    if job.run_count == 0 {
        return Err("job has an empty run block".to_string());
    }
    if job.run_start + job.run_count > total_runs {
        return Err(format!(
            "run block {}..{} exceeds the job's {} runs",
            job.run_start,
            job.run_start + job.run_count,
            total_runs
        ));
    }
    Ok(())
}

fn frame_name(f: &Frame) -> &'static str {
    match f {
        Frame::Job(_) => "job",
        Frame::Run { .. } => "run",
        Frame::Done { .. } => "done",
        Frame::Error { .. } => "error",
    }
}

fn crash_once_hook() {
    if let Ok(path) = std::env::var(CRASH_ONCE_ENV) {
        if std::fs::OpenOptions::new()
            .write(true)
            .create_new(true)
            .open(&path)
            .is_ok()
        {
            std::process::exit(17);
        }
    }
}

fn crash_run_index() -> Option<usize> {
    std::env::var(CRASH_RUN_ENV).ok()?.parse().ok()
}
