//! The versioned JSON frame protocol spoken between the shard
//! supervisor and `dcd-lms shard-worker` processes (DESIGN.md §8).
//!
//! Frames are newline-delimited JSON objects, one frame per line; every
//! frame carries the protocol version (`"v"`) and a `"type"` tag.
//! Exactly one [`Frame::Job`] travels supervisor → worker on stdin; the
//! worker answers on stdout with one [`Frame::Run`] per realization of
//! its block (in run order) and a terminal [`Frame::Done`], or a
//! terminal [`Frame::Error`]. Finite floats are serialized through
//! `jsonio`'s shortest-round-trip formatter, non-finite ones as the
//! strings `"inf"`/`"-inf"`/`"NaN"` (divergent runs must shard like
//! they run serially), and all counters fit in 2⁵³ — so a decoded
//! frame reproduces the worker's numbers bit-exactly, the property the
//! run-order merge needs to keep sharded results byte-identical to the
//! serial runner.

use crate::coordinator::impairments::LinkStateStats;
use crate::coordinator::round::RunResult;
use crate::coordinator::wsn::WsnResult;
use crate::energy::{CommLedger, N_PURPOSES};
use crate::jsonio::{obj, Json};

/// Protocol version; a worker rejects any other value with a
/// [`Frame::Error`] so mixed-binary deployments fail loudly instead of
/// silently misreading frames. v2: run frames carry the directional
/// communication ledger (DESIGN.md §9) instead of bare scalar counters,
/// and WSN frames gained the gating/activation breakdown.
pub const PROTOCOL_VERSION: u64 = 2;

/// Version of the **session** frame grammar spoken by `dcd-lms serve`
/// (DESIGN.md §11): v3 extends this worker-pipe grammar with the
/// submit / status / progress / result / cancel session frames. The
/// two grammars travel on different channels — supervisor ↔ worker
/// pipes stay on v2 [`Frame`]s; daemon ↔ client sessions speak the v3
/// `serve::session::SessionFrame`s — so a session frame fed to the
/// worker pipe (or vice versa) is rejected by the version check
/// instead of being misread.
pub const SESSION_PROTOCOL_VERSION: u64 = 3;

/// What a shard worker is asked to execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobKind {
    /// A synchronous-round Monte-Carlo block: the payload is a scenario
    /// INI document (`Scenario::to_ini_string`).
    Mc,
    /// An exp3 WSN realization block: the payload is an `[exp3]` +
    /// `[energy]` INI document (`Exp3Config::to_ini_string`) and
    /// `algo_index` selects the Fig. 4 algorithm setting.
    Wsn,
}

impl JobKind {
    fn name(self) -> &'static str {
        match self {
            JobKind::Mc => "mc",
            JobKind::Wsn => "wsn",
        }
    }

    fn parse(s: &str) -> Result<Self, String> {
        match s {
            "mc" => Ok(JobKind::Mc),
            "wsn" => Ok(JobKind::Wsn),
            other => Err(format!("unknown job kind {other:?} (expected mc | wsn)")),
        }
    }
}

/// The supervisor → worker work order: replay `payload` and execute the
/// contiguous realization block `[run_start, run_start + run_count)`.
#[derive(Debug, Clone)]
pub struct ShardJob {
    /// Payload interpretation (see [`JobKind`]).
    pub kind: JobKind,
    /// Self-contained INI description of the job the worker replays.
    pub payload: String,
    /// First global run index of this shard's block.
    pub run_start: usize,
    /// Number of realizations in this shard's block.
    pub run_count: usize,
    /// In-process worker threads for this block (0 = auto). The
    /// supervisor divides the machine's cores across the shards here,
    /// so concurrent workers do not each grab full parallelism.
    pub threads: usize,
    /// WSN jobs only: index into the exp3 algorithm settings.
    pub algo_index: usize,
}

/// Per-realization result payload of a [`Frame::Run`].
#[derive(Debug, Clone)]
pub enum RunPayload {
    /// Synchronous-round result (MSD trace + communication counters).
    Mc(RunResult),
    /// WSN result (time grid, MSD, telemetry, activation counters).
    Wsn(WsnResult),
}

/// One protocol frame.
#[derive(Debug, Clone)]
pub enum Frame {
    /// Supervisor → worker: the work order (exactly one, then EOF).
    Job(ShardJob),
    /// Worker → supervisor: one finished realization.
    Run {
        /// Global run index of this realization.
        run: usize,
        /// The realization's result.
        payload: RunPayload,
    },
    /// Worker → supervisor: terminal success marker; `runs` must equal
    /// the job's `run_count` (a truncated stream is detected by its
    /// absence).
    Done {
        /// Number of run frames that preceded this marker.
        runs: usize,
    },
    /// Worker → supervisor: terminal failure with a human-readable
    /// reason; the worker also exits non-zero.
    Error {
        /// What went wrong, with context.
        message: String,
    },
}

fn num(x: usize) -> Json {
    Json::Num(x as f64)
}

/// Encode a u64 counter; panics past 2⁵³, where the f64 transport would
/// silently round — a loud worker death (the supervisor reports it)
/// instead of a corrupt counter. Unreachable at any physical workload:
/// 2⁵³ scalar transmissions is ~10⁶ node-years of simulation.
fn num_u64(x: u64) -> Json {
    assert!(x <= 1 << 53, "counter {x} exceeds exact f64 range");
    Json::Num(x as f64)
}

/// Encode one f64: finite values as JSON numbers (shortest round-trip
/// formatting ⇒ bit-exact), non-finite ones as the strings `"inf"` /
/// `"-inf"` / `"NaN"` — plain `Json::Num` would emit invalid JSON for
/// them, and a *divergent* simulation must shard exactly like it runs
/// serially (reporting its infinities) rather than die on a malformed
/// frame.
fn num_f64(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Str(format!("{v}"))
    }
}

fn f64_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&v| num_f64(v)).collect())
}

fn get_f64_arr(v: &Json, key: &str) -> Result<Vec<f64>, String> {
    v.get(key)
        .as_arr()
        .ok_or_else(|| format!("frame field {key:?} must be an array of numbers"))?
        .iter()
        .map(|x| decode_f64(x, key))
        .collect()
}

/// Decode one f64: a number, or one of the non-finite strings
/// [`num_f64`] emits (a string holding a finite number is rejected —
/// only the values `Json::Num` cannot carry may ride in a string).
fn decode_f64(x: &Json, key: &str) -> Result<f64, String> {
    if let Some(v) = x.as_f64() {
        return Ok(v);
    }
    if let Some(v) = x.as_str().and_then(|s| s.parse::<f64>().ok()) {
        if !v.is_finite() {
            return Ok(v);
        }
    }
    Err(format!("frame field {key:?} contains a non-number"))
}

fn get_usize(v: &Json, key: &str) -> Result<usize, String> {
    v.get(key)
        .as_usize()
        .ok_or_else(|| format!("frame field {key:?} must be a non-negative integer"))
}

fn get_u64(v: &Json, key: &str) -> Result<u64, String> {
    v.get(key)
        .as_u64()
        .ok_or_else(|| format!("frame field {key:?} must be an exact u64"))
}

fn get_str(v: &Json, key: &str) -> Result<String, String> {
    Ok(v.get(key)
        .as_str()
        .ok_or_else(|| format!("frame field {key:?} must be a string"))?
        .to_string())
}

fn u64_arr(xs: &[u64]) -> Json {
    Json::Arr(xs.iter().map(|&x| num_u64(x)).collect())
}

fn get_u64_arr(v: &Json, key: &str) -> Result<Vec<u64>, String> {
    v.get(key)
        .as_arr()
        .ok_or_else(|| format!("frame field {key:?} must be an array of integers"))?
        .iter()
        .map(|x| {
            x.as_u64()
                .ok_or_else(|| format!("frame field {key:?} contains a non-u64"))
        })
        .collect()
}

/// Encode a [`CommLedger`] as a frame object: exact u64 counters, with
/// the dense per-link table shipped sparsely as `[index, scalars]`
/// pairs (geometric graphs leave most of the N² table zero).
fn ledger_json(l: &CommLedger) -> Json {
    // `LinkCounts::pairs` yields the nonzero (index, count) entries in
    // ascending index order on both the dense and sparse storage, so
    // the wire form is identical whichever representation the worker
    // happened to hold.
    let per_link: Vec<Json> = l
        .per_link
        .pairs()
        .map(|(i, c)| Json::Arr(vec![num(i), num_u64(c)]))
        .collect();
    obj(vec![
        ("n", num(l.n_nodes)),
        ("scalars", num_u64(l.scalars)),
        ("messages", num_u64(l.messages)),
        ("suppressed", num_u64(l.suppressed_scalars)),
        ("dropped_s", num_u64(l.dropped_scalars)),
        ("dropped_m", num_u64(l.dropped_messages)),
        ("width", num(l.bits_per_scalar as usize)),
        ("per_node", u64_arr(&l.per_node)),
        ("per_purpose", u64_arr(&l.per_purpose)),
        ("per_link", Json::Arr(per_link)),
    ])
}

/// Encode the Gilbert–Elliott occupancy counters of one realization
/// (DESIGN.md §12). Always present on Mc run frames; all-zero for
/// memoryless drop models.
fn linkstate_json(s: &LinkStateStats) -> Json {
    obj(vec![
        ("good", num_u64(s.good_steps)),
        ("bad", num_u64(s.bad_steps)),
        ("bursts", num_u64(s.bursts)),
        ("burst_steps", num_u64(s.burst_steps)),
        ("hist", u64_arr(&s.burst_hist)),
    ])
}

/// Decode the link-state block of an Mc run frame. An absent block
/// (frames written before the dynamics axes existed) decodes as the
/// empty chain — the merge treats both identically.
fn decode_linkstate(v: &Json) -> Result<LinkStateStats, String> {
    let l = v.get("linkstate");
    if matches!(l, &Json::Null) {
        return Ok(LinkStateStats::default());
    }
    Ok(LinkStateStats {
        good_steps: get_u64(l, "good")?,
        bad_steps: get_u64(l, "bad")?,
        bursts: get_u64(l, "bursts")?,
        burst_steps: get_u64(l, "burst_steps")?,
        burst_hist: get_u64_arr(l, "hist")?,
    })
}

/// Decode the ledger object of a run frame (see [`ledger_json`]).
fn decode_ledger(v: &Json) -> Result<CommLedger, String> {
    let l = v.get("ledger");
    if matches!(l, &Json::Null) {
        return Err("frame field \"ledger\" missing".to_string());
    }
    let n = get_usize(l, "n")?;
    let mut ledger = CommLedger::empty(n);
    ledger.scalars = get_u64(l, "scalars")?;
    ledger.messages = get_u64(l, "messages")?;
    ledger.suppressed_scalars = get_u64(l, "suppressed")?;
    ledger.dropped_scalars = get_u64(l, "dropped_s")?;
    ledger.dropped_messages = get_u64(l, "dropped_m")?;
    ledger.bits_per_scalar = get_usize(l, "width")? as u32;
    let per_node = get_u64_arr(l, "per_node")?;
    if per_node.len() != n {
        return Err(format!("ledger per_node has {} entries, want {n}", per_node.len()));
    }
    ledger.per_node = per_node;
    let per_purpose = get_u64_arr(l, "per_purpose")?;
    if per_purpose.len() != N_PURPOSES {
        return Err(format!(
            "ledger per_purpose has {} entries, want {N_PURPOSES}",
            per_purpose.len()
        ));
    }
    ledger.per_purpose.copy_from_slice(&per_purpose);
    for entry in l
        .get("per_link")
        .as_arr()
        .ok_or("ledger per_link must be an array")?
    {
        let pair = entry.as_arr().ok_or("ledger per_link entry must be a pair")?;
        if pair.len() != 2 {
            return Err("ledger per_link entry must be a pair".to_string());
        }
        let idx = pair[0]
            .as_usize()
            .ok_or("ledger per_link index must be a usize")?;
        let count = pair[1].as_u64().ok_or("ledger per_link count must be a u64")?;
        if idx >= n * n {
            return Err(format!("ledger per_link index {idx} out of range"));
        }
        ledger.per_link.set(idx, count);
    }
    Ok(ledger)
}

impl Frame {
    /// Serialize as one line of compact JSON (newlines in strings are
    /// escaped by the writer, so the frame never spans lines).
    pub fn encode(&self) -> String {
        let v = ("v", Json::Num(PROTOCOL_VERSION as f64));
        let doc = match self {
            Frame::Job(job) => obj(vec![
                v,
                ("type", Json::Str("job".into())),
                ("kind", Json::Str(job.kind.name().into())),
                ("payload", Json::Str(job.payload.clone())),
                ("run_start", num(job.run_start)),
                ("run_count", num(job.run_count)),
                ("threads", num(job.threads)),
                ("algo_index", num(job.algo_index)),
            ]),
            Frame::Run { run, payload } => match payload {
                RunPayload::Mc(res) => obj(vec![
                    v,
                    ("type", Json::Str("run".into())),
                    ("kind", Json::Str("mc".into())),
                    ("run", num(*run)),
                    ("msd", f64_arr(&res.msd)),
                    ("ledger", ledger_json(&res.ledger)),
                    ("linkstate", linkstate_json(&res.linkstate)),
                ]),
                RunPayload::Wsn(res) => obj(vec![
                    v,
                    ("type", Json::Str("run".into())),
                    ("kind", Json::Str("wsn".into())),
                    ("run", num(*run)),
                    ("time", f64_arr(&res.time)),
                    ("msd", f64_arr(&res.msd)),
                    ("mean_sleep", f64_arr(&res.mean_sleep)),
                    ("mean_harvest", f64_arr(&res.mean_harvest)),
                    ("activations", num_u64(res.activations)),
                    ("skipped", num_u64(res.skipped)),
                    ("gated", num_u64(res.gated)),
                    ("per_node_activations", u64_arr(&res.per_node_activations)),
                    ("radio_joules", f64_arr(&res.radio_joules)),
                    ("ledger", ledger_json(&res.ledger)),
                ]),
            },
            Frame::Done { runs } => obj(vec![
                v,
                ("type", Json::Str("done".into())),
                ("runs", num(*runs)),
            ]),
            Frame::Error { message } => obj(vec![
                v,
                ("type", Json::Str("error".into())),
                ("message", Json::Str(message.clone())),
            ]),
        };
        doc.to_string_compact()
    }

    /// Parse one frame line; errors carry enough context to point at
    /// the offending field.
    pub fn decode(line: &str) -> Result<Frame, String> {
        let doc = Json::parse(line.trim())
            .map_err(|e| format!("shard protocol: not a JSON frame ({e}): {line:?}"))?;
        let version = get_u64(&doc, "v")
            .map_err(|e| format!("shard protocol: {e} (missing version?)"))?;
        if version != PROTOCOL_VERSION {
            return Err(format!(
                "shard protocol: frame version {version} != supported {PROTOCOL_VERSION} \
                 (mixed dcd-lms binaries?)"
            ));
        }
        let ty = get_str(&doc, "type").map_err(|e| format!("shard protocol: {e}"))?;
        let frame = match ty.as_str() {
            "job" => Frame::Job(ShardJob {
                kind: JobKind::parse(&get_str(&doc, "kind")?)?,
                payload: get_str(&doc, "payload")?,
                run_start: get_usize(&doc, "run_start")?,
                run_count: get_usize(&doc, "run_count")?,
                threads: get_usize(&doc, "threads")?,
                algo_index: get_usize(&doc, "algo_index")?,
            }),
            "run" => {
                let run = get_usize(&doc, "run")?;
                let payload = match JobKind::parse(&get_str(&doc, "kind")?)? {
                    JobKind::Mc => RunPayload::Mc(RunResult {
                        msd: get_f64_arr(&doc, "msd")?,
                        ledger: decode_ledger(&doc)?,
                        linkstate: decode_linkstate(&doc)?,
                    }),
                    JobKind::Wsn => {
                        let ledger = decode_ledger(&doc)?;
                        // Frames from binaries that predate the radio
                        // model carry no radio block: decode it as the
                        // free radio, exactly what those workers billed.
                        let radio_joules = if matches!(doc.get("radio_joules"), &Json::Null) {
                            vec![0.0; ledger.n_nodes]
                        } else {
                            get_f64_arr(&doc, "radio_joules")?
                        };
                        RunPayload::Wsn(WsnResult {
                            time: get_f64_arr(&doc, "time")?,
                            msd: get_f64_arr(&doc, "msd")?,
                            mean_sleep: get_f64_arr(&doc, "mean_sleep")?,
                            mean_harvest: get_f64_arr(&doc, "mean_harvest")?,
                            activations: get_u64(&doc, "activations")?,
                            skipped: get_u64(&doc, "skipped")?,
                            gated: get_u64(&doc, "gated")?,
                            per_node_activations: get_u64_arr(&doc, "per_node_activations")?,
                            radio_joules,
                            ledger,
                        })
                    }
                };
                Frame::Run { run, payload }
            }
            "done" => Frame::Done { runs: get_usize(&doc, "runs")? },
            "error" => Frame::Error { message: get_str(&doc, "message")? },
            other => {
                return Err(format!(
                    "shard protocol: unknown frame type {other:?} \
                     (expected job | run | done | error)"
                ))
            }
        };
        Ok(frame)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_frame_roundtrips_multiline_payload() {
        let job = ShardJob {
            kind: JobKind::Mc,
            payload: "[scenario]\nname = x\n\n[schedule]\nruns = 4\n".to_string(),
            run_start: 3,
            run_count: 2,
            threads: 1,
            algo_index: 0,
        };
        let line = Frame::Job(job.clone()).encode();
        assert!(!line.contains('\n'), "frame spans lines: {line}");
        match Frame::decode(&line).unwrap() {
            Frame::Job(back) => {
                assert_eq!(back.kind, job.kind);
                assert_eq!(back.payload, job.payload);
                assert_eq!(back.run_start, 3);
                assert_eq!(back.run_count, 2);
                assert_eq!(back.threads, 1);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    fn sample_ledger() -> CommLedger {
        let mut l = CommLedger::empty(3);
        l.scalars = 9_007_199_254_740_992; // 2^53: largest exact counter
        l.messages = 12_345;
        l.suppressed_scalars = 77;
        l.dropped_scalars = 5;
        l.dropped_messages = 1;
        l.bits_per_scalar = 11;
        l.per_node = vec![10, 0, 32];
        l.per_purpose = [30, 12, 0];
        l.per_link.set(1, 10); // 0 -> 1
        l.per_link.set(5, 32); // 1 -> 2
        l
    }

    #[test]
    fn mc_run_frame_roundtrips_bit_exactly() {
        let mut linkstate = LinkStateStats::sized();
        linkstate.good_steps = 900;
        linkstate.bad_steps = 100;
        linkstate.record_burst(3);
        linkstate.record_burst(97);
        let res = RunResult {
            msd: vec![1.0, 0.123456789012345e-7, 3.5e300, 0.0],
            ledger: sample_ledger(),
            linkstate,
        };
        let line = Frame::Run { run: 7, payload: RunPayload::Mc(res.clone()) }.encode();
        match Frame::decode(&line).unwrap() {
            Frame::Run { run, payload: RunPayload::Mc(back) } => {
                assert_eq!(run, 7);
                // The whole directional ledger survives the pipe —
                // sparse per-link encoding included.
                assert_eq!(back.ledger, res.ledger);
                // As do the Gilbert–Elliott occupancy counters, the
                // overflow histogram bin included.
                assert_eq!(back.linkstate, res.linkstate);
                assert_eq!(back.msd.len(), res.msd.len());
                for (a, b) in back.msd.iter().zip(res.msd.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
            }
            other => panic!("decoded {other:?}"),
        }
        // Frames from binaries that predate the dynamics axes carry no
        // linkstate block: it decodes as the empty chain.
        let legacy = "{\"v\":2,\"type\":\"run\",\"kind\":\"mc\",\"run\":0,\"msd\":[1.0],\
                      \"ledger\":{\"n\":1,\"scalars\":0,\"messages\":0,\"suppressed\":0,\
                      \"dropped_s\":0,\"dropped_m\":0,\"width\":64,\"per_node\":[0],\
                      \"per_purpose\":[0,0,0],\"per_link\":[]}}";
        match Frame::decode(legacy).unwrap() {
            Frame::Run { payload: RunPayload::Mc(back), .. } => {
                assert!(back.linkstate.is_empty());
            }
            other => panic!("decoded {other:?}"),
        }
    }

    /// A divergent simulation's infinities must survive the pipe: the
    /// sharded run has to report exactly what the serial run would.
    #[test]
    fn non_finite_msd_values_survive_the_frame() {
        let res = RunResult {
            msd: vec![f64::INFINITY, f64::NEG_INFINITY, f64::NAN, 1.5],
            ledger: CommLedger::empty(2),
            linkstate: LinkStateStats::default(),
        };
        let line = Frame::Run { run: 0, payload: RunPayload::Mc(res) }.encode();
        match Frame::decode(&line).unwrap() {
            Frame::Run { payload: RunPayload::Mc(back), .. } => {
                assert_eq!(back.msd[0], f64::INFINITY);
                assert_eq!(back.msd[1], f64::NEG_INFINITY);
                assert!(back.msd[2].is_nan());
                assert_eq!(back.msd[3], 1.5);
            }
            other => panic!("decoded {other:?}"),
        }
        // A finite number hiding in a string is still rejected.
        let sneaky = "{\"v\":2,\"type\":\"run\",\"kind\":\"mc\",\"run\":0,\
                      \"msd\":[\"1.5\"],\"scalars\":0,\"messages\":0}";
        assert!(Frame::decode(sneaky).unwrap_err().contains("non-number"));
    }

    #[test]
    fn wsn_run_frame_roundtrips() {
        let res = WsnResult {
            time: vec![500.0, 1000.0],
            msd: vec![0.5, 0.25],
            mean_sleep: vec![10.0, 20.5],
            mean_harvest: vec![0.01, 0.02],
            activations: 321,
            skipped: 7,
            gated: 13,
            per_node_activations: vec![200, 121, 0],
            radio_joules: vec![1.25e-3, 0.0, 7.771561000000001e-4],
            ledger: sample_ledger(),
        };
        let line = Frame::Run { run: 0, payload: RunPayload::Wsn(res.clone()) }.encode();
        match Frame::decode(&line).unwrap() {
            Frame::Run { payload: RunPayload::Wsn(back), .. } => {
                assert_eq!(back.time, res.time);
                assert_eq!(back.msd, res.msd);
                assert_eq!(back.mean_sleep, res.mean_sleep);
                assert_eq!(back.mean_harvest, res.mean_harvest);
                assert_eq!(back.activations, 321);
                assert_eq!(back.skipped, 7);
                assert_eq!(back.gated, 13);
                assert_eq!(back.per_node_activations, res.per_node_activations);
                // The radio bill rides the same shortest-round-trip
                // float transport as the MSD trace: bit-exact.
                assert_eq!(back.radio_joules.len(), res.radio_joules.len());
                for (a, b) in back.radio_joules.iter().zip(res.radio_joules.iter()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{a} != {b}");
                }
                assert_eq!(back.ledger, res.ledger);
            }
            other => panic!("decoded {other:?}"),
        }
        // Frames from binaries that predate the radio model carry no
        // radio_joules array: it decodes as the free radio, sized to
        // the ledger's node count.
        let legacy = "{\"v\":2,\"type\":\"run\",\"kind\":\"wsn\",\"run\":0,\
                      \"time\":[500.0],\"msd\":[0.5],\"mean_sleep\":[10.0],\
                      \"mean_harvest\":[0.01],\"activations\":1,\"skipped\":0,\
                      \"gated\":0,\"per_node_activations\":[1,0,0],\
                      \"ledger\":{\"n\":3,\"scalars\":0,\"messages\":0,\"suppressed\":0,\
                      \"dropped_s\":0,\"dropped_m\":0,\"width\":64,\"per_node\":[0,0,0],\
                      \"per_purpose\":[0,0,0],\"per_link\":[]}}";
        match Frame::decode(legacy).unwrap() {
            Frame::Run { payload: RunPayload::Wsn(back), .. } => {
                assert_eq!(back.radio_joules, vec![0.0, 0.0, 0.0]);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn malformed_frames_are_rejected_with_context() {
        let err = Frame::decode("not json at all").unwrap_err();
        assert!(err.contains("shard protocol"), "{err}");
        let err = Frame::decode("{\"type\":\"job\"}").unwrap_err();
        assert!(err.contains("version"), "{err}");
        let err = Frame::decode("{\"v\":99,\"type\":\"done\",\"runs\":0}").unwrap_err();
        assert!(err.contains("version 99"), "{err}");
        // v1 frames (pre-ledger) are rejected, not misread.
        let err = Frame::decode("{\"v\":1,\"type\":\"done\",\"runs\":0}").unwrap_err();
        assert!(err.contains("version 1"), "{err}");
        let err = Frame::decode("{\"v\":2,\"type\":\"frobnicate\"}").unwrap_err();
        assert!(err.contains("frobnicate"), "{err}");
        let headless_run = "{\"v\":2,\"type\":\"run\",\"kind\":\"mc\",\"run\":0}";
        let err = Frame::decode(headless_run).unwrap_err();
        assert!(err.contains("msd"), "{err}");
        // A run frame without its ledger is malformed.
        let ledgerless = "{\"v\":2,\"type\":\"run\",\"kind\":\"mc\",\"run\":0,\"msd\":[1.0]}";
        let err = Frame::decode(ledgerless).unwrap_err();
        assert!(err.contains("ledger"), "{err}");
        // A done/error frame round-trips.
        match Frame::decode(&Frame::Done { runs: 5 }.encode()).unwrap() {
            Frame::Done { runs } => assert_eq!(runs, 5),
            other => panic!("decoded {other:?}"),
        }
        let err_frame = Frame::Error { message: "boom\nline2".into() };
        match Frame::decode(&err_frame.encode()).unwrap() {
            Frame::Error { message } => assert_eq!(message, "boom\nline2"),
            other => panic!("decoded {other:?}"),
        }
    }
}
