//! The shard supervisor: spawns `dcd-lms shard-worker` processes over a
//! contiguous run-range plan, streams their per-run result frames back,
//! re-spawns crashed shards, and reassembles everything **in run
//! order** so sharded results are bit-identical to the serial runner
//! (DESIGN.md §8).
//!
//! Failure semantics: a shard whose worker exits non-zero, truncates
//! its stream before the `done` frame, or emits a malformed/out-of-range
//! frame is re-spawned up to [`shard_retries`] times (the whole block
//! re-runs — realizations are deterministic, so a re-run reproduces the
//! exact same frames). When the retry budget is exhausted the supervisor
//! returns a contextual error naming the shard, its run range and the
//! worker's last words (stderr tail), and the CLI exits non-zero.

use std::io::{BufRead, BufReader, Read, Write};
use std::path::PathBuf;
use std::process::{Command, Stdio};

use crate::config::Exp3Config;
use crate::coordinator::runner::{shard_ranges, McResult, MonteCarlo};
use crate::coordinator::wsn::WsnResult;
use crate::scenario::Scenario;

use super::protocol::{Frame, JobKind, RunPayload, ShardJob};

/// Env override for the worker binary path (defaults to the current
/// executable). Tests point this at the real `dcd-lms` binary — or at
/// an impostor, to exercise the malformed-frame handling.
pub const WORKER_BIN_ENV: &str = "DCD_SHARD_WORKER";

/// Env override for the per-shard re-spawn budget (default 1).
pub const RETRIES_ENV: &str = "DCD_SHARD_RETRIES";

/// How many times a failed shard is re-spawned before the supervisor
/// gives up: the `DCD_SHARD_RETRIES` env var, else 1.
pub fn shard_retries() -> usize {
    std::env::var(RETRIES_ENV)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1)
}

/// A per-shard completion callback `(shard_idx, done_shards,
/// total_shards)`, invoked from the supervisor's per-shard threads as
/// each shard's block lands (hence `Sync`). Purely observational: it
/// sees completions in wall-clock order while reassembly stays in run
/// order, so wiring one in (the serve daemon streams these as progress
/// frames, DESIGN.md §11) cannot change result bytes.
pub type ShardProgress<'a> = &'a (dyn Fn(usize, usize, usize) + Sync);

/// The per-worker in-process thread budget: an explicit request passes
/// through unchanged; auto (0) divides the machine's cores across the
/// concurrent shards, so `--shards N` never oversubscribes the host by
/// N × cores (threads never affect result bytes, only wall-clock).
fn per_worker_threads(requested: usize, shards: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    (cores / shards.max(1)).max(1)
}

/// Run a scenario's Monte-Carlo job across `sc.shards` worker
/// processes and merge the per-run results in run order. The result is
/// bit-identical to the in-process runner at any shards × threads
/// combination (tested end-to-end in `rust/tests/shard.rs`).
pub fn run_scenario_sharded(sc: &Scenario) -> Result<McResult, String> {
    run_scenario_sharded_progress(sc, None)
}

/// [`run_scenario_sharded`] with an optional per-shard progress
/// callback (the serve daemon's streaming hook; `None` is the exact
/// historical code path).
pub fn run_scenario_sharded_progress(
    sc: &Scenario,
    progress: Option<ShardProgress>,
) -> Result<McResult, String> {
    // The payload the workers replay: the same scenario, but with the
    // shard knob reset so a worker never tries to shard recursively.
    let mut job_sc = sc.clone();
    job_sc.shards = 1;
    let payload = job_sc.to_ini_string();
    let threads = per_worker_threads(sc.threads, sc.shards);
    let collected = collect_sharded(
        sc.runs,
        sc.shards,
        progress,
        &|run_start, run_count| ShardJob {
            kind: JobKind::Mc,
            payload: payload.clone(),
            run_start,
            run_count,
            threads,
            algo_index: 0,
        },
    )?;
    let mut results = Vec::with_capacity(collected.len());
    for payload in collected {
        match payload {
            RunPayload::Mc(res) => results.push(res),
            RunPayload::Wsn(_) => {
                return Err("shard worker answered an mc job with a wsn frame".to_string())
            }
        }
    }
    let mc = MonteCarlo {
        runs: sc.runs,
        iters: sc.iters,
        seed: sc.seed,
        record_every: sc.effective_record_every(),
        threads: sc.threads,
    };
    Ok(mc.merge(results.into_iter()))
}

/// Run a `mode = wsn` scenario's event-driven realizations across
/// `sc.shards` worker processes, in run order. The job payload is the
/// scenario INI (same `JobKind::Mc` envelope as the round-mode jobs —
/// the worker dispatches on the replayed scenario's schedule mode) and
/// the workers answer with WSN run frames carrying the full ledger
/// (DESIGN.md §8, §9).
pub fn run_scenario_wsn_sharded(sc: &Scenario) -> Result<Vec<WsnResult>, String> {
    run_scenario_wsn_sharded_progress(sc, None)
}

/// [`run_scenario_wsn_sharded`] with an optional per-shard progress
/// callback (see [`run_scenario_sharded_progress`]).
pub fn run_scenario_wsn_sharded_progress(
    sc: &Scenario,
    progress: Option<ShardProgress>,
) -> Result<Vec<WsnResult>, String> {
    let mut job_sc = sc.clone();
    job_sc.shards = 1;
    let payload = job_sc.to_ini_string();
    let threads = per_worker_threads(sc.threads, sc.shards);
    let collected = collect_sharded(
        sc.runs,
        sc.shards,
        progress,
        &|run_start, run_count| ShardJob {
            kind: JobKind::Mc,
            payload: payload.clone(),
            run_start,
            run_count,
            threads,
            algo_index: 0,
        },
    )?;
    let mut results = Vec::with_capacity(collected.len());
    for payload in collected {
        match payload {
            RunPayload::Wsn(res) => results.push(res),
            RunPayload::Mc(_) => {
                return Err(
                    "shard worker answered a wsn-mode scenario with an mc frame".to_string()
                )
            }
        }
    }
    Ok(results)
}

/// Run one exp3 algorithm setting's WSN realizations across `shards`
/// worker processes, returning the per-run results in run order (the
/// same contract as the in-process `parallel_ordered` fan-out).
pub fn run_wsn_sharded(
    cfg: &Exp3Config,
    algo_index: usize,
    shards: usize,
) -> Result<Vec<WsnResult>, String> {
    let payload = cfg.to_ini_string();
    let threads = per_worker_threads(0, shards);
    let collected = collect_sharded(cfg.runs, shards, None, &|run_start, run_count| ShardJob {
        kind: JobKind::Wsn,
        payload: payload.clone(),
        run_start,
        run_count,
        threads,
        algo_index,
    })?;
    let mut results = Vec::with_capacity(collected.len());
    for payload in collected {
        match payload {
            RunPayload::Wsn(res) => results.push(res),
            RunPayload::Mc(_) => {
                return Err("shard worker answered a wsn job with an mc frame".to_string())
            }
        }
    }
    Ok(results)
}

/// Fan a run-range plan across worker processes (one concurrent
/// supervisor thread per shard) and reassemble the per-run payloads by
/// global run index. Every run must be reported exactly once.
fn collect_sharded(
    runs: usize,
    shards: usize,
    progress: Option<ShardProgress>,
    make_job: &(dyn Fn(usize, usize) -> ShardJob + Sync),
) -> Result<Vec<RunPayload>, String> {
    if runs == 0 {
        return Err("sharded run: zero realizations".to_string());
    }
    let ranges = shard_ranges(runs, shards);
    let total = ranges.len();
    let done = std::sync::atomic::AtomicUsize::new(0);
    let done = &done;
    let mut shard_outputs: Vec<Result<Vec<(usize, RunPayload)>, String>> =
        Vec::with_capacity(ranges.len());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(ranges.len());
        for (idx, &(start, count)) in ranges.iter().enumerate() {
            let job = make_job(start, count);
            handles.push(scope.spawn(move || {
                let out = run_shard_with_retries(idx, job);
                if let (Ok(_), Some(report)) = (&out, progress) {
                    let n = done.fetch_add(1, std::sync::atomic::Ordering::SeqCst) + 1;
                    report(idx, n, total);
                }
                out
            }));
        }
        for handle in handles {
            shard_outputs.push(handle.join().expect("shard supervisor thread panicked"));
        }
    });
    let mut slots: Vec<Option<RunPayload>> = (0..runs).map(|_| None).collect();
    for output in shard_outputs {
        for (run, payload) in output? {
            if slots[run].is_some() {
                return Err(format!("run {run} reported by more than one shard"));
            }
            slots[run] = Some(payload);
        }
    }
    slots
        .into_iter()
        .enumerate()
        .map(|(run, slot)| slot.ok_or_else(|| format!("run {run} missing from shard outputs")))
        .collect()
}

/// Drive one shard to completion, re-spawning on failure within the
/// retry budget.
fn run_shard_with_retries(
    shard_idx: usize,
    job: ShardJob,
) -> Result<Vec<(usize, RunPayload)>, String> {
    let attempts = shard_retries() + 1;
    let mut last_err = String::new();
    for attempt in 1..=attempts {
        match run_shard_once(&job) {
            Ok(results) => return Ok(results),
            Err(e) => {
                last_err = e;
                if attempt < attempts {
                    eprintln!(
                        "shard {shard_idx} (runs {}..{}) attempt {attempt} failed: \
                         {last_err}; re-spawning",
                        job.run_start,
                        job.run_start + job.run_count
                    );
                }
            }
        }
    }
    Err(format!(
        "shard {shard_idx} (runs {}..{}) failed after {attempts} attempt(s): {last_err}",
        job.run_start,
        job.run_start + job.run_count
    ))
}

/// The worker binary to spawn: `DCD_SHARD_WORKER` override, else this
/// very executable (the worker is a hidden subcommand of `dcd-lms`).
fn worker_binary() -> Result<PathBuf, String> {
    if let Ok(path) = std::env::var(WORKER_BIN_ENV) {
        return Ok(PathBuf::from(path));
    }
    std::env::current_exe().map_err(|e| format!("cannot locate the worker binary: {e}"))
}

/// One spawn → stream → wait cycle for a shard.
fn run_shard_once(job: &ShardJob) -> Result<Vec<(usize, RunPayload)>, String> {
    let bin = worker_binary()?;
    let mut child = Command::new(&bin)
        .arg("shard-worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .stderr(Stdio::piped())
        .spawn()
        .map_err(|e| format!("spawning {}: {e}", bin.display()))?;

    // Hand the worker its job. A write failure is not fatal by itself
    // (the worker may have exited already); the read loop below
    // surfaces the real error.
    if let Some(mut stdin) = child.stdin.take() {
        let _ = writeln!(stdin, "{}", Frame::Job(job.clone()).encode());
        // stdin drops here -> EOF for the worker.
    }

    let stdout = child.stdout.take().expect("stdout was piped");
    // Drain stderr concurrently: a worker that fills the stderr pipe
    // while we are still reading stdout would otherwise deadlock the
    // whole run (write(2) blocks on the full pipe, we block on stdout).
    let mut stderr = child.stderr.take().expect("stderr was piped");
    let stderr_drain = std::thread::spawn(move || {
        let mut text = String::new();
        let _ = stderr.read_to_string(&mut text);
        text
    });
    let run_end = job.run_start + job.run_count;
    let mut results: Vec<(usize, RunPayload)> = Vec::with_capacity(job.run_count);
    let mut done = false;
    let mut frame_err: Option<String> = None;
    for (lineno, line) in BufReader::new(stdout).lines().enumerate() {
        let line = match line {
            Ok(l) => l,
            Err(e) => {
                frame_err = Some(format!("reading worker stdout: {e}"));
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        match Frame::decode(&line) {
            Ok(Frame::Run { run, payload }) => {
                if run < job.run_start || run >= run_end {
                    frame_err = Some(format!(
                        "worker reported run {run} outside its block {}..{run_end}",
                        job.run_start
                    ));
                    break;
                }
                if results.iter().any(|(r, _)| *r == run) {
                    frame_err = Some(format!("worker reported run {run} twice"));
                    break;
                }
                results.push((run, payload));
            }
            Ok(Frame::Done { runs }) => {
                if runs != job.run_count || results.len() != job.run_count {
                    frame_err = Some(format!(
                        "worker finished with {} of {} runs (done frame said {runs})",
                        results.len(),
                        job.run_count
                    ));
                } else {
                    done = true;
                }
                break;
            }
            Ok(Frame::Error { message }) => {
                frame_err = Some(format!("worker error: {message}"));
                break;
            }
            Ok(Frame::Job(_)) => {
                frame_err = Some("worker echoed a job frame".to_string());
                break;
            }
            Err(e) => {
                frame_err = Some(format!("worker frame {} malformed: {e}", lineno + 1));
                break;
            }
        }
    }

    // Collect the exit status and stderr tail for diagnostics; a
    // protocol error above still drains the child so nothing leaks.
    let status = child.wait().map_err(|e| format!("waiting for worker: {e}"))?;
    let stderr_text = stderr_drain.join().unwrap_or_default();
    if let Some(err) = frame_err {
        // The frame error is the primary diagnosis; the exit status is
        // secondary noise once the stream already went wrong.
        return Err(with_stderr(err, &stderr_text));
    }
    if !status.success() {
        return Err(with_stderr(
            format!("worker exited with {status} before completing its block"),
            &stderr_text,
        ));
    }
    if !done {
        return Err(with_stderr(
            format!(
                "worker stream ended after {} of {} runs without a done frame",
                results.len(),
                job.run_count
            ),
            &stderr_text,
        ));
    }
    Ok(results)
}

fn with_stderr(err: String, stderr_text: &str) -> String {
    let lines: Vec<&str> = stderr_text.lines().collect();
    let tail = lines[lines.len().saturating_sub(3)..].join(" | ");
    if tail.is_empty() {
        err
    } else {
        format!("{err} [worker stderr: {tail}]")
    }
}
