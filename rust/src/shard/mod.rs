//! Sharded multi-process Monte-Carlo execution (DESIGN.md §8).
//!
//! The in-process parallel runner (`coordinator::runner`) tops out at
//! one machine's thread pool; this module is the next scaling rung: a
//! **supervisor** splits a Monte-Carlo job's realizations into
//! contiguous run-index ranges ([`crate::coordinator::runner::shard_ranges`]),
//! spawns one `dcd-lms shard-worker` process per range (the same
//! binary, a hidden subcommand), and the workers stream per-run partial
//! results back over a versioned JSON frame protocol on stdin/stdout
//! (the [`Frame`] grammar of `shard/protocol.rs`).
//!
//! Determinism is preserved by construction, exactly as in the threaded
//! runner: realization `r` always draws from PCG64 stream `r + 1` of
//! the master seed no matter which process executes it, and the
//! supervisor folds the streamed per-run results **sequentially in run
//! order** with the very same merge the serial runner uses — so results
//! are bit-identical to `run_rust_serial` at any `--shards × --threads`
//! combination (tested end-to-end in `rust/tests/shard.rs` and by the
//! CI byte-for-byte CSV diff).
//!
//! Crash handling: a worker that dies mid-stream (non-zero exit,
//! truncated stream, malformed frame) is re-spawned with its whole
//! block — re-runs are deterministic, so the replacement reproduces the
//! exact frames the casualty would have sent. See DESIGN.md §8 for the
//! frame grammar, versioning and failure semantics.

mod protocol;
mod supervisor;
mod worker;

pub use protocol::{
    Frame, JobKind, RunPayload, ShardJob, PROTOCOL_VERSION, SESSION_PROTOCOL_VERSION,
};
pub use supervisor::{
    run_scenario_sharded, run_scenario_sharded_progress, run_scenario_wsn_sharded,
    run_scenario_wsn_sharded_progress, run_wsn_sharded, shard_retries, ShardProgress, RETRIES_ENV,
    WORKER_BIN_ENV,
};
pub use worker::{worker_main, CRASH_ONCE_ENV, CRASH_RUN_ENV};
