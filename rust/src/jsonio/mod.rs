//! Minimal JSON reader/writer.
//!
//! `serde`/`serde_json` are unavailable in this offline environment
//! (DESIGN.md §2, S10), so the artifact manifest and result dumps go
//! through this self-contained implementation. It supports the full JSON
//! grammar we emit and consume: objects, arrays, strings (with escapes),
//! numbers, booleans, null. Numbers are parsed as f64 (adequate: the
//! manifest only carries shapes and hashes; results are floats).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing garbage is an error).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { src: src.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value(0)?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    /// The object's map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// The array's items, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// The string value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// The value as a non-negative integer, if it is one exactly.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u32::MAX as f64 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// The value as a `u64`, if it is a non-negative integer that an f64
    /// represents exactly (|x| ≤ 2⁵³ — the shard frame protocol ships
    /// communication counters through this, and they must round-trip
    /// bit-exactly; see DESIGN.md §8).
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= 9_007_199_254_740_992.0 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    /// The boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` when absent.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    // -- writer --------------------------------------------------------------

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 1-space indentation (matches python `json.dump(indent=1)`).
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !map.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Arr` of numbers.
pub fn num_arr<T: Into<f64> + Copy>(xs: &[T]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x.into())).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn fmt_num(x: f64) -> String {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        // Shortest round-trippable representation rust offers by default.
        format!("{x}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Nesting cap for the recursive-descent parser: the grammar recurses
/// per `[`/`{`, so without a cap a line of a few hundred kilobytes of
/// `[[[[…` overflows the thread stack — an *abort*, not a catchable
/// error, and reachable from any malformed protocol frame. Nothing the
/// repo emits nests deeper than ~6 levels; 256 is three orders of
/// magnitude of headroom while keeping worst-case recursion a few
/// hundred stack frames.
const MAX_DEPTH: usize = 256;

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, JsonError> {
        if depth > MAX_DEPTH {
            return Err(self.err(&format!("nesting deeper than {MAX_DEPTH} levels")));
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("expected low surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let v = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(v).ok_or_else(|| self.err("bad codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.src.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.src[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let c = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (c as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[0].as_usize(), Some(1));
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert_eq!(v.get("missing"), &Json::Null);
    }

    #[test]
    fn u64_accessor_is_exact() {
        assert_eq!(Json::parse("0").unwrap().as_u64(), Some(0));
        assert_eq!(Json::parse("4294967296").unwrap().as_u64(), Some(1 << 32));
        assert_eq!(
            Json::parse("9007199254740992").unwrap().as_u64(),
            Some(9_007_199_254_740_992)
        );
        assert_eq!(Json::parse("-1").unwrap().as_u64(), None);
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("\"7\"").unwrap().as_u64(), None);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    /// Pathological nesting is a parse error, not a stack overflow:
    /// the depth cap has to trip well before the recursion can abort
    /// the process (malformed protocol frames reach this parser).
    #[test]
    fn rejects_pathological_nesting() {
        let deep = "[".repeat(100_000);
        let err = Json::parse(&deep).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        let deep_objs = "{\"k\":".repeat(50_000) + "1";
        let err = Json::parse(&deep_objs).unwrap_err();
        assert!(err.msg.contains("nesting"), "{err}");
        // Within the cap, deep-but-sane documents still parse.
        let ok = "[".repeat(200) + &"]".repeat(200);
        assert!(Json::parse(&ok).is_ok());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
        assert_eq!(
            Json::parse(r#""😀""#).unwrap(),
            Json::Str("😀".into())
        );
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"modules":[{"name":"dcd_smoke","shape":[4,3],"ok":true}],"v":1.5}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, v3);
    }
}
