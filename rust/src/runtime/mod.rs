//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The python compile path (`make artifacts`) lowers each
//! (algorithm, shape) variant of the L2 scan-chunk model to
//! `artifacts/<name>.hlo.txt` and records the calling convention in
//! `artifacts/manifest.json`. This module loads the manifest, compiles
//! modules on the PJRT CPU client (caching executables by name), and
//! drives multi-chunk simulations by threading the carried weights
//! between chunk executions.
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md §1).

mod manifest;
pub use manifest::{Manifest, ModuleSpec, TensorSpec};

use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A loaded PJRT executable plus its manifest entry.
pub struct LoadedModule {
    pub spec: ModuleSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// Output of one chunk execution.
#[derive(Debug, Clone)]
pub struct ChunkOutput {
    /// Final weights, row-major `(n_nodes, dim)`.
    pub w_final: Vec<f32>,
    /// Per-step, per-node squared deviation, row-major `(chunk_len, n_nodes)`.
    pub msd: Vec<f32>,
}

/// `true` when real PJRT bindings are linked in; `false` under the
/// offline `xla` stub (vendor/README.md). Callers that need the
/// compiled engine (CLI `validate`, the xla-backed tests) check this and
/// skip gracefully instead of failing at first execution.
pub fn xla_available() -> bool {
    xla::runtime_available()
}

/// PJRT CPU runtime with an executable cache. The PJRT client is created
/// lazily on first compilation, so manifest-only operations (`info`,
/// shape lookups) work even where the native runtime is absent.
pub struct Runtime {
    client: Option<xla::PjRtClient>,
    dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, LoadedModule>,
}

impl Runtime {
    /// Open the artifact directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Self { client: None, dir, manifest, cache: HashMap::new() })
    }

    /// Default artifact directory: `$DCD_ARTIFACTS` or `artifacts/` under the
    /// crate root (works from `cargo run`/`cargo test` CWDs).
    pub fn open_default() -> Result<Self> {
        Self::open(default_artifact_dir()?)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) a module by manifest name,
    /// e.g. `"dcd_exp1"`.
    pub fn load(&mut self, name: &str) -> Result<&LoadedModule> {
        if !self.cache.contains_key(name) {
            let spec = self
                .manifest
                .module(name)
                .ok_or_else(|| anyhow!("module {name:?} not in manifest"))?
                .clone();
            if self.client.is_none() {
                self.client = Some(xla::PjRtClient::cpu().map_err(wrap_xla)?);
            }
            let path = self.dir.join(&spec.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(wrap_xla)
                .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let client = self.client.as_ref().expect("client just created");
            let exe = client.compile(&comp).map_err(wrap_xla)?;
            self.cache.insert(name.to_string(), LoadedModule { spec, exe });
        }
        Ok(&self.cache[name])
    }

    /// Execute one chunk. `inputs` must match the manifest order/shapes;
    /// each entry is a flat row-major f32 buffer.
    pub fn execute_chunk(&mut self, name: &str, inputs: &[&[f32]]) -> Result<ChunkOutput> {
        // Validate + build literals first (immutable borrow of manifest via
        // loaded spec), then run.
        let module = self.load(name)?;
        let spec = module.spec.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "module {name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, tspec) in inputs.iter().zip(&spec.inputs) {
            let want: usize = tspec.shape.iter().product();
            if buf.len() != want {
                bail!(
                    "module {name}: input {:?} expects {} elems ({:?}), got {}",
                    tspec.name,
                    want,
                    tspec.shape,
                    buf.len()
                );
            }
            let lit = xla::Literal::vec1(buf)
                .reshape(&tspec.shape.iter().map(|&d| d as i64).collect::<Vec<_>>())
                .map_err(wrap_xla)?;
            literals.push(lit);
        }
        let module = self.cache.get(name).expect("just loaded");
        let result = module.exe.execute::<xla::Literal>(&literals).map_err(wrap_xla)?;
        let tuple = result[0][0].to_literal_sync().map_err(wrap_xla)?;
        // Lowered with return_tuple=True: (W_T, MSD).
        let elems = tuple.to_tuple().map_err(wrap_xla)?;
        if elems.len() != 2 {
            bail!("module {name}: expected 2 outputs, got {}", elems.len());
        }
        let mut it = elems.into_iter();
        let w_final = it.next().unwrap().to_vec::<f32>().map_err(wrap_xla)?;
        let msd = it.next().unwrap().to_vec::<f32>().map_err(wrap_xla)?;
        Ok(ChunkOutput { w_final, msd })
    }

    /// Run `n_chunks` successive chunks, threading `W` between them and
    /// pulling fresh per-chunk tensors from `feed`. `fixed` are the
    /// trailing chunk-invariant inputs (combiners, step sizes, wo, ...).
    ///
    /// `feed(chunk_idx)` must return the per-chunk buffers in manifest
    /// order (everything between `W0` and the fixed tail).
    pub fn run_chunks(
        &mut self,
        name: &str,
        w0: &[f32],
        n_chunks: usize,
        mut feed: impl FnMut(usize) -> Vec<Vec<f32>>,
        fixed: &[&[f32]],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let mut w = w0.to_vec();
        let mut msd_all = Vec::new();
        for c in 0..n_chunks {
            let per_chunk = feed(c);
            let mut inputs: Vec<&[f32]> = Vec::with_capacity(1 + per_chunk.len() + fixed.len());
            inputs.push(&w);
            for b in &per_chunk {
                inputs.push(b);
            }
            inputs.extend_from_slice(fixed);
            let out = self.execute_chunk(name, &inputs)?;
            w = out.w_final;
            msd_all.extend_from_slice(&out.msd);
        }
        Ok((w, msd_all))
    }
}

/// Locate `artifacts/` from the environment or relative to the crate root.
pub fn default_artifact_dir() -> Result<PathBuf> {
    if let Ok(dir) = std::env::var("DCD_ARTIFACTS") {
        return Ok(PathBuf::from(dir));
    }
    // CARGO_MANIFEST_DIR is baked in at compile time for this crate.
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    let cand = root.join("artifacts");
    if cand.join("manifest.json").exists() {
        return Ok(cand);
    }
    let cwd = std::env::current_dir()?;
    let cand = cwd.join("artifacts");
    if cand.join("manifest.json").exists() {
        return Ok(cand);
    }
    bail!(
        "artifacts/manifest.json not found (run `make artifacts`, or set DCD_ARTIFACTS)"
    )
}

fn wrap_xla(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_missing_dir_errors() {
        assert!(Runtime::open("/nonexistent/dir").is_err());
    }
}
