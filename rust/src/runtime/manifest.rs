//! Artifact manifest: the calling convention of each AOT-lowered module.
//!
//! Written by `python/compile/aot.py`; read here with the in-tree JSON
//! parser (`jsonio`).

use crate::jsonio::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::path::Path;

/// Shape + dtype of one input/output tensor.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled module (algorithm x shape configuration).
#[derive(Debug, Clone)]
pub struct ModuleSpec {
    pub name: String,
    pub algo: String,
    pub config: String,
    pub path: String,
    pub n_nodes: usize,
    pub dim: usize,
    pub chunk_len: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub sha256: String,
}

/// The parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub modules: Vec<ModuleSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Self> {
        let root = Json::parse(text).map_err(|e| anyhow!("{e}"))?;
        if root.get("format").as_str() != Some("hlo-text") {
            bail!("manifest: unsupported format {:?}", root.get("format"));
        }
        let mods = root
            .get("modules")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing modules array"))?;
        let mut modules = Vec::with_capacity(mods.len());
        for m in mods {
            modules.push(parse_module(m)?);
        }
        Ok(Manifest { modules })
    }

    pub fn module(&self, name: &str) -> Option<&ModuleSpec> {
        self.modules.iter().find(|m| m.name == name)
    }

    /// Find a module by algorithm + shape config, e.g. `("dcd", "exp1")`.
    pub fn find(&self, algo: &str, config: &str) -> Option<&ModuleSpec> {
        self.modules
            .iter()
            .find(|m| m.algo == algo && m.config == config)
    }
}

fn parse_module(m: &Json) -> Result<ModuleSpec> {
    let get_str = |k: &str| -> Result<String> {
        m.get(k)
            .as_str()
            .map(str::to_string)
            .ok_or_else(|| anyhow!("manifest module: missing string {k:?}"))
    };
    let get_usize = |k: &str| -> Result<usize> {
        m.get(k)
            .as_usize()
            .ok_or_else(|| anyhow!("manifest module: missing integer {k:?}"))
    };
    let tensors = |k: &str| -> Result<Vec<TensorSpec>> {
        m.get(k)
            .as_arr()
            .ok_or_else(|| anyhow!("manifest module: missing array {k:?}"))?
            .iter()
            .map(|t| {
                let name = t
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("tensor: missing name"))?
                    .to_string();
                let shape = t
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| anyhow!("tensor {name}: missing shape"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow!("tensor {name}: bad dim")))
                    .collect::<Result<Vec<_>>>()?;
                Ok(TensorSpec { name, shape })
            })
            .collect()
    };
    Ok(ModuleSpec {
        name: get_str("name")?,
        algo: get_str("algo")?,
        config: get_str("config")?,
        path: get_str("path")?,
        n_nodes: get_usize("n_nodes")?,
        dim: get_usize("dim")?,
        chunk_len: get_usize("chunk_len")?,
        inputs: tensors("inputs")?,
        outputs: tensors("outputs")?,
        sha256: get_str("sha256")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text", "version": 1,
      "modules": [{
        "name": "dcd_smoke", "algo": "dcd", "config": "smoke",
        "path": "dcd_smoke.hlo.txt",
        "n_nodes": 4, "dim": 3, "chunk_len": 8,
        "inputs": [{"name": "W0", "shape": [4, 3], "dtype": "f32"}],
        "outputs": [{"name": "W_T", "shape": [4, 3], "dtype": "f32"}],
        "sha256": "abc"
      }]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.modules.len(), 1);
        let spec = m.module("dcd_smoke").unwrap();
        assert_eq!(spec.n_nodes, 4);
        assert_eq!(spec.inputs[0].num_elements(), 12);
        assert!(m.find("dcd", "smoke").is_some());
        assert!(m.find("dcd", "exp9").is_none());
    }

    #[test]
    fn rejects_bad_format() {
        assert!(Manifest::parse(r#"{"format": "proto", "modules": []}"#).is_err());
        assert!(Manifest::parse("not json").is_err());
    }
}
