//! CLI argument parser (the `clap` substitute, DESIGN.md §2 S11).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`,
//! repeated options, and positional arguments, with generated help text.

use std::collections::BTreeMap;

/// Declarative option spec.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    /// Flag (no value) vs option (takes a value).
    pub takes_value: bool,
    /// May be given multiple times.
    pub repeated: bool,
}

/// A parsed command line.
#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    pub values: BTreeMap<String, Vec<String>>,
    pub positional: Vec<String>,
}

impl ParsedArgs {
    pub fn flag(&self, name: &str) -> bool {
        self.values.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    pub fn get_all(&self, name: &str) -> &[String] {
        self.values.get(name).map(Vec::as_slice).unwrap_or(&[])
    }

    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| format!("--{name} {v:?}: {e}")),
        }
    }

    pub fn get_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        Ok(self.get_parse(name)?.unwrap_or(default))
    }
}

/// A subcommand definition.
#[derive(Debug, Clone)]
pub struct Command {
    /// Subcommand name (the first argv token).
    pub name: &'static str,
    /// One-line description shown in the global help.
    pub about: &'static str,
    /// Declared options.
    pub opts: Vec<OptSpec>,
    /// Hidden commands dispatch normally but are omitted from the
    /// global help (internal plumbing like `shard-worker`).
    pub hidden: bool,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new(), hidden: false }
    }

    /// Mark the command as hidden (dispatchable, but not listed).
    pub fn hide(mut self) -> Self {
        self.hidden = true;
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: false, repeated: false });
        self
    }

    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, repeated: false });
        self
    }

    pub fn opt_repeated(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, takes_value: true, repeated: true });
        self
    }

    fn spec(&self, name: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.name == name)
    }

    /// Parse the arguments following the subcommand name.
    pub fn parse(&self, args: &[String]) -> Result<ParsedArgs, String> {
        let mut out = ParsedArgs::default();
        let mut it = args.iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (body, None),
                };
                let spec = self
                    .spec(name)
                    .ok_or_else(|| format!("unknown option --{name} (see `{} --help`)", self.name))?;
                let value = if !spec.takes_value {
                    if inline.is_some() {
                        return Err(format!("--{name} does not take a value"));
                    }
                    String::new()
                } else if let Some(v) = inline {
                    v
                } else {
                    it.next()
                        .ok_or_else(|| format!("--{name} requires a value"))?
                        .clone()
                };
                let slot = out.values.entry(name.to_string()).or_default();
                if !slot.is_empty() && !spec.repeated && spec.takes_value {
                    return Err(format!("--{name} given more than once"));
                }
                slot.push(value);
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }

    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\noptions:\n", self.name, self.about);
        for o in &self.opts {
            let val = if o.takes_value { " <value>" } else { "" };
            let rep = if o.repeated { " (repeatable)" } else { "" };
            s.push_str(&format!("  --{}{val}\n      {}{rep}\n", o.name, o.help));
        }
        s
    }
}

/// Top-level application: subcommand dispatch + global help.
pub struct App {
    pub name: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl App {
    pub fn help(&self) -> String {
        let mut s = format!("{} — {}\n\ncommands:\n", self.name, self.about);
        for c in self.commands.iter().filter(|c| !c.hidden) {
            s.push_str(&format!("  {:<14} {}\n", c.name, c.about));
        }
        s.push_str("\nrun `<command> --help` for command options\n");
        s
    }

    /// Split argv into (command, parsed args). Returns `Err(help_text)`
    /// for `--help`/missing/unknown commands.
    pub fn dispatch(&self, argv: &[String]) -> Result<(&Command, ParsedArgs), String> {
        let Some(first) = argv.first() else {
            return Err(self.help());
        };
        if first == "--help" || first == "-h" || first == "help" {
            return Err(self.help());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == first)
            .ok_or_else(|| format!("unknown command {first:?}\n\n{}", self.help()))?;
        let rest = &argv[1..];
        if rest.iter().any(|a| a == "--help" || a == "-h") {
            return Err(cmd.help());
        }
        let parsed = cmd.parse(rest)?;
        Ok((cmd, parsed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("run", "run things")
            .flag("fast", "fewer iterations")
            .opt("runs", "MC runs")
            .opt_repeated("set", "override")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_options_positionals() {
        let p = cmd()
            .parse(&s(&["--fast", "--runs", "5", "pos1", "--set=a.b=1", "--set", "c.d=2"]))
            .unwrap();
        assert!(p.flag("fast"));
        assert!(!p.flag("slow"));
        assert_eq!(p.get("runs"), Some("5"));
        assert_eq!(p.get_or("runs", 0usize).unwrap(), 5);
        assert_eq!(p.get_all("set"), &["a.b=1".to_string(), "c.d=2".to_string()]);
        assert_eq!(p.positional, vec!["pos1"]);
    }

    #[test]
    fn rejects_unknown_and_missing_values() {
        assert!(cmd().parse(&s(&["--bogus"])).is_err());
        assert!(cmd().parse(&s(&["--runs"])).is_err());
        assert!(cmd().parse(&s(&["--fast=1"])).is_err());
        assert!(cmd().parse(&s(&["--runs", "1", "--runs", "2"])).is_err());
        let err = cmd().parse(&s(&["--runs", "x"])).unwrap().get_or("runs", 0usize);
        assert!(err.is_err());
    }

    #[test]
    fn app_dispatch() {
        let app = App {
            name: "dcd-lms",
            about: "test",
            commands: vec![cmd(), Command::new("info", "print info")],
        };
        let (c, p) = app.dispatch(&s(&["run", "--fast"])).unwrap();
        assert_eq!(c.name, "run");
        assert!(p.flag("fast"));
        assert!(app.dispatch(&s(&["nope"])).is_err());
        assert!(app.dispatch(&s(&[])).is_err());
        assert!(app.dispatch(&s(&["run", "--help"])).is_err());
    }

    /// Hidden commands dispatch but stay out of the global help.
    #[test]
    fn hidden_commands_dispatch_without_listing() {
        let app = App {
            name: "dcd-lms",
            about: "test",
            commands: vec![cmd(), Command::new("secret", "internal").hide()],
        };
        assert!(!app.help().contains("secret"));
        let (c, _) = app.dispatch(&s(&["secret"])).unwrap();
        assert_eq!(c.name, "secret");
    }
}
