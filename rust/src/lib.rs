//! # dcd-lms — Doubly-Compressed Diffusion LMS over adaptive networks
//!
//! Reproduction of *“On reducing the communication cost of the diffusion
//! LMS algorithm”* (Harrane, Flamary, Richard — IEEE TSIPN 2018) as a
//! three-layer rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the network coordinator: agents, typed
//!   partial-vector messages, communication accounting, synchronous-round
//!   and energy-driven (WSN) schedulers, Monte-Carlo orchestration, the
//!   closed-form mean / mean-square theory engine, and the PJRT runtime
//!   that executes the AOT-compiled compute path.
//! * **Layer 2** — JAX network-step models (`python/compile/model.py`),
//!   lowered once to HLO text (`make artifacts`).
//! * **Layer 1** — Pallas kernels for the per-iteration hot spot
//!   (`python/compile/kernels/dcd_kernel.py`).
//!
//! Python never runs at simulation time: the rust binary is self-contained
//! once `artifacts/` is built.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.

pub mod algorithms;
pub mod bench_support;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod datamodel;
pub mod energy;
pub mod experiments;
pub mod jsonio;
pub mod linalg;
pub mod metrics;
pub mod rng;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod shard;
pub mod testing;
pub mod theory;
pub mod topology;
