//! Scenario execution: build the network and data model from a
//! [`Scenario`], fan the Monte-Carlo realizations across the parallel
//! runner, and write `results/<name>.{csv,json}` plus the per-link
//! billed-bits ledger `results/<name>_ledger.csv` (DESIGN.md §9).
//!
//! Seeding mirrors the experiment drivers exactly: the master stream
//! `Pcg64::new(seed, 0)` first builds the topology (geometric graphs
//! consume it) and then the data model; realization `r` runs on stream
//! `r + 1` (synchronous rounds) or seed `seed + r·7919 + 1` (the
//! `mode = wsn` event-driven schedule, the exp3 convention). With ideal
//! impairments this makes `paper-10-node` reproduce the `exp1` DCD
//! trajectory bit-for-bit (tested).
//!
//! Scenarios inside the analysis scope of DESIGN.md §7 additionally get
//! a closed-form **theory column** ([`ImpairedMsdModel`]) next to the
//! Monte-Carlo curve — the impaired analogue of exp1's theory-vs-sim
//! anchoring; see [`ScenarioOutput::theory_steady_db`].

use crate::algorithms::NetworkConfig;
use crate::config::IniDoc;
use crate::coordinator::impairments::LinkStateStats;
use crate::coordinator::runner::{
    parallel_ordered, resolve_threads, shard_ranges, McResult, MonteCarlo, SchedulerOptions,
};
use crate::coordinator::wsn::{WsnAlgo, WsnConfig, WsnResult, WsnSimulation};
use crate::datamodel::DataModel;
use crate::energy::{CommLedger, EnergyParams, Purpose};
use crate::jsonio::{obj, Json};
use crate::metrics::{to_db, write_csv, write_json, write_json_with_meta, Series, TraceAccumulator};
use crate::rng::Pcg64;
use crate::theory::{ImpairedMsdModel, TheorySetup};
use crate::topology::{combination_matrix, Rule};

use super::spec::{AlgorithmSpec, Scenario, ScheduleMode, TheoryColumn, TopologySpec};

/// Hard upper bound on N·L for the theory column. With the CSR 𝓑
/// operator (DESIGN.md §10) one application of the variance operator is
/// O(nnz(𝓑)·NL) instead of O((NL)³), which moves the practical limit
/// from the old 256 up to ~10⁴: there the binding constraints are the
/// dense NL×NL Σ iterates (~800 MB each at the cap) and the
/// O((Σ_k |N_k|)²) quadratic-term list, not the linear algebra.
const MAX_THEORY_NL: usize = 10_000;

/// Threshold for the *automatic* theory column (`theory = auto`, the
/// default) — kept at the historical dense limit so every pre-existing
/// preset's CSV stays byte-identical. Larger scenarios state the
/// opt-in (`theory = on`) in the "no theory column" notice.
const AUTO_THEORY_NL: usize = 256;

/// Everything one scenario run produces.
#[derive(Debug, Clone)]
pub struct ScenarioOutput {
    /// The (validated) scenario that ran.
    pub scenario: Scenario,
    /// MSD series in dB (x = iteration index for `mode = rounds`,
    /// virtual time for `mode = wsn`). The simulation curve is always
    /// `series[0]`; scenarios inside the DESIGN.md §7 analysis scope
    /// get a `… (theory)` series after it.
    pub series: Vec<Series>,
    /// Steady-state MSD estimate (dB, trailing 10 % of the mean trace).
    pub steady_db: f64,
    /// Theoretical steady-state MSD (dB) from the impaired-link model,
    /// when the scenario is inside the analysis scope (`A = I`,
    /// DCD-family algorithm, non-event gating, N·L within the cap).
    pub theory_steady_db: Option<f64>,
    /// Mean scalars transmitted per realization (reflects gating — and,
    /// since the directional ledger, dead solicited replies too).
    pub scalars_per_run: f64,
    /// The directional communication bill summed over all realizations
    /// (per-node / per-link / per-purpose breakdowns; DESIGN.md §9).
    pub ledger: CommLedger,
    /// Gilbert–Elliott occupancy counters summed over all realizations
    /// (empty unless `drop = markov:*` with memory; DESIGN.md §12).
    pub linkstate: LinkStateStats,
    /// Per-node radio joules summed over all realizations (DESIGN.md
    /// §13) — populated only by `mode = wsn` runs with a non-zero
    /// `[energy]` section, empty otherwise.
    pub radio_joules: Vec<f64>,
}

/// One point of a sweep.
#[derive(Debug, Clone)]
pub struct SweepPoint {
    /// The swept value, as given.
    pub value: String,
    /// Steady-state MSD at this value (dB).
    pub steady_db: f64,
    /// Theoretical steady-state MSD (dB), when in analysis scope.
    pub theory_db: Option<f64>,
    /// Mean scalars transmitted per realization at this value.
    pub scalars_per_run: f64,
    /// Mean billed payload bits per realization at this value
    /// (DESIGN.md §9).
    pub bits_per_run: f64,
}

/// Everything one sweep produces.
#[derive(Debug, Clone)]
pub struct SweepOutput {
    /// Summary per swept value, in input order.
    pub points: Vec<SweepPoint>,
    /// The per-value MSD traces (labelled `<key>=<value>`).
    pub traces: Vec<Series>,
}

/// Cheap scope check for the theory column, *without* building data or
/// models: `Err` is the human-readable reason a scenario has no
/// closed-form anchor. The analysis scope (DESIGN.md §7): the paper's
/// `A = I` setting (`combine_rule = identity`), a DCD-family algorithm,
/// Bernoulli-representable gating, the synchronous-round schedule, and
/// a network within the size cap. The default `theory = auto` policy
/// additionally keeps the historical N·L ≤ 256 threshold so existing
/// presets keep byte-identical outputs; `theory = on` opts in to the
/// full matrix-free cap (DESIGN.md §10).
pub fn theory_scope(sc: &Scenario) -> Result<(usize, usize), String> {
    if sc.theory == TheoryColumn::Off {
        return Err("theory = off disables the theory column".into());
    }
    if let ScheduleMode::Wsn { .. } = sc.mode {
        return Err("the event-driven WSN schedule has no closed-form model".into());
    }
    let masks = sc
        .algorithm
        .theory_masks(sc.dim)
        .ok_or_else(|| format!("no closed-form model for algorithm {}", sc.algorithm.name()))?;
    if sc.combine_rule != Rule::Identity {
        return Err("analysis assumes A = I (combine_rule = identity)".into());
    }
    if sc.impairments.gating.transmit_prob().is_none() {
        return Err(format!(
            "gating {} is state-dependent and has no closed-form link-state distribution",
            sc.impairments.gating
        ));
    }
    if sc.impairments.drop.iid_prob().is_none() {
        return Err(
            "the Gilbert-Elliott (markov) link process has memory; the closed-form \
             model assumes i.i.d. erasures (DESIGN.md §12)"
                .into(),
        );
    }
    if !sc.dynamics.is_static() {
        return Err(
            "[dynamics] (churn / mobility / drift / adaptive combiners) is outside \
             the analysis scope"
                .into(),
        );
    }
    let nl = sc.topology.n_nodes() * sc.dim;
    if nl > MAX_THEORY_NL {
        return Err(format!(
            "N·L = {nl} exceeds the theory-column cap {MAX_THEORY_NL}"
        ));
    }
    if sc.theory == TheoryColumn::Auto && nl > AUTO_THEORY_NL {
        return Err(format!(
            "N·L = {nl} exceeds the automatic theory threshold {AUTO_THEORY_NL} \
             (set [schedule] theory = on to force it, up to N·L = {MAX_THEORY_NL})"
        ));
    }
    Ok(masks)
}

/// Build the impaired-link theory anchor for a scenario, or explain why
/// it has none (see [`theory_scope`]).
fn theory_anchor(
    sc: &Scenario,
    model: &DataModel,
    c: &crate::topology::Combiner,
) -> Result<ImpairedMsdModel, String> {
    let (m, m_grad) = theory_scope(sc)?;
    let n = sc.topology.n_nodes();
    let setup = TheorySetup {
        n_nodes: n,
        dim: sc.dim,
        m,
        m_grad,
        c: c.to_dense(),
        mu: vec![sc.mu; n],
        sigma_u2: model.sigma_u2.clone(),
        sigma_v2: model.sigma_v2.clone(),
    };
    ImpairedMsdModel::new(setup, &sc.impairments)
}

/// Build the executable pieces of a scenario's Monte-Carlo job —
/// topology/combiners/data model (consumed from master stream
/// `Pcg64::new(seed, 0)` in the fixed order the experiment drivers
/// use), the [`NetworkConfig`], and the configured [`MonteCarlo`].
/// Both the in-process runner ([`run_scenario`]) and the shard worker
/// (`dcd-lms shard-worker`, DESIGN.md §8) construct their jobs through
/// this one function, which is what makes a worker's realizations
/// bit-identical to the in-process ones.
pub fn mc_parts(sc: &Scenario) -> Result<(DataModel, NetworkConfig, MonteCarlo), String> {
    let n = sc.topology.n_nodes();
    let mut rng = Pcg64::new(sc.seed, 0);
    let mut graph = sc.topology.build(&mut rng);
    if sc.dynamics.rewire > 0.0 {
        // Mobility support graph (DESIGN.md §12): the combiners are built
        // once over every pair that could ever come within range on its
        // orbit (reach = radius + 2ρ); the dynamics layer then toggles
        // those slots per iteration. Consumes no RNG, so the data-model
        // stream below is untouched.
        graph = graph.with_mobility_support(mobility_radius(sc), sc.dynamics.rewire);
    }
    let c = combination_matrix(&graph, sc.adapt_rule);
    let a = combination_matrix(&graph, sc.combine_rule);
    let model = DataModel::paper(n, sc.dim, sc.u2_min, sc.u2_max, sc.sigma_v2, &mut rng);
    let net = NetworkConfig { graph, c, a, mu: vec![sc.mu; n], dim: sc.dim };
    net.validate()?;
    let mc = MonteCarlo {
        runs: sc.runs,
        iters: sc.iters,
        seed: sc.seed,
        record_every: sc.effective_record_every(),
        threads: sc.threads,
    };
    Ok((model, net, mc))
}

/// The geometric connection radius mobility works against (0 for
/// topologies without one — the validator only admits `rewire > 0` on
/// geometric graphs).
fn mobility_radius(sc: &Scenario) -> f64 {
    match sc.topology {
        TopologySpec::Geometric { radius, .. } => radius,
        _ => 0.0,
    }
}

/// Compile a scenario's impairments + `[dynamics]` section into the
/// runtime [`SchedulerOptions`]. The in-process runner and the shard
/// worker (`run_mc_block`) both configure realizations through this one
/// function — that shared construction is what keeps sharded runs
/// bit-identical to in-process ones on every dynamic axis.
pub fn scheduler_options(sc: &Scenario) -> SchedulerOptions {
    SchedulerOptions {
        impairments: if sc.impairments.is_ideal() {
            None
        } else {
            Some(sc.impairments.clone())
        },
        dynamics: if sc.dynamics.network_static() {
            None
        } else {
            Some(sc.dynamics.to_config(mobility_radius(sc)))
        },
        drift: sc.dynamics.drift,
    }
}

/// The [`WsnAlgo`] a scenario's algorithm spec maps to under
/// `mode = wsn` (DCD's combine step follows the combine rule: `A = I`
/// ⇒ no masked-estimate combine).
fn wsn_algo(sc: &Scenario) -> WsnAlgo {
    match sc.algorithm {
        AlgorithmSpec::DiffusionLms => WsnAlgo::Diffusion,
        AlgorithmSpec::Cd { m } => WsnAlgo::Cd { m },
        AlgorithmSpec::Dcd { m, m_grad } => WsnAlgo::Dcd {
            m,
            m_grad,
            combine: sc.combine_rule != Rule::Identity,
        },
        AlgorithmSpec::Rcd { m_links } => WsnAlgo::Rcd { m_links },
        AlgorithmSpec::Partial { m } => WsnAlgo::Partial { m },
    }
}

/// Assemble the event-driven WSN simulation of a `mode = wsn` scenario:
/// the master stream builds topology then data model (the exact
/// [`mc_parts`] order), harvest scales follow the exp3 hillside law
/// over node positions (uniform mid-level lighting for topologies
/// without coordinates), and the scenario's impairment model is wired
/// straight into the scheduler (charge *and* event gating; §9).
pub fn wsn_sim(sc: &Scenario) -> Result<WsnSimulation, String> {
    let ScheduleMode::Wsn { duration, sample_dt } = sc.mode else {
        return Err(format!("scenario {} has no [wsn] schedule", sc.name));
    };
    let n = sc.topology.n_nodes();
    let mut rng = Pcg64::new(sc.seed, 0);
    let graph = sc.topology.build(&mut rng);
    let c = combination_matrix(&graph, sc.adapt_rule);
    let a = combination_matrix(&graph, sc.combine_rule);
    let model = DataModel::paper(n, sc.dim, sc.u2_min, sc.u2_max, sc.sigma_v2, &mut rng);
    let harvest_scale: Vec<f64> = match graph.positions.as_ref() {
        Some(pos) => pos.iter().map(|&(_, y)| 0.3 + 0.7 * y).collect(),
        None => vec![0.6; n],
    };
    let net = NetworkConfig { graph, c, a, mu: vec![sc.mu; n], dim: sc.dim };
    net.validate()?;
    let cfg = WsnConfig {
        net,
        algo: wsn_algo(sc),
        energy: EnergyParams::default(),
        harvest_scale,
        duration,
        sample_dt,
        impairments: sc.impairments.clone(),
        radio: sc.radio,
    };
    Ok(WsnSimulation::new(cfg, model))
}

/// Execute the contiguous WSN realization block
/// `[run_start, run_start + count)` of a `mode = wsn` scenario, in run
/// order. Realization `r` always runs on seed `seed + r·7919 + 1`
/// (the exp3 convention), so a block produces exactly the per-run
/// results the full runner would — this is what a shard worker executes
/// for WSN scenarios (DESIGN.md §8).
pub fn wsn_block(
    sc: &Scenario,
    run_start: usize,
    count: usize,
    threads: usize,
) -> Result<Vec<WsnResult>, String> {
    let sim = wsn_sim(sc)?;
    let threads = resolve_threads(threads, count);
    Ok(parallel_ordered(count, threads, |i| {
        sim.run(sc.seed.wrapping_add((run_start + i) as u64 * 7919 + 1))
    }))
}

/// Execute a scenario's Monte-Carlo simulation on pre-built parts:
/// in-process for `shards = 1`, across worker processes otherwise
/// (same result either way, bit for bit — the workers rebuild the same
/// parts from the scenario INI).
fn run_mc(
    sc: &Scenario,
    model: &DataModel,
    net: &NetworkConfig,
    mc: &MonteCarlo,
    progress: Option<crate::shard::ShardProgress>,
) -> Result<McResult, String> {
    if sc.shards > 1 {
        return crate::shard::run_scenario_sharded_progress(sc, progress);
    }
    let opts = scheduler_options(sc);
    // The lane engine (DESIGN.md §14) is byte-identical to the scalar
    // path at every width, so dispatch is purely a throughput decision.
    let lanes = sc.lanes.resolve(sc.runs);
    let res = if lanes > 1 {
        mc.run_rust_lanes_opts(model, &opts, lanes, || sc.algorithm.build(net.clone()))
    } else {
        mc.run_rust_opts(model, &opts, || sc.algorithm.build(net.clone()))
    };
    // The in-process path is one logical shard; report its completion
    // so serve-mode progress streams work at shards = 1 too.
    if let Some(report) = progress {
        report(0, 1, 1);
    }
    Ok(res)
}

/// The `"manifest"` object recorded in `results/<name>.json`: the
/// schedule that produced the result, including the shard layout
/// (DESIGN.md §8) and the directional communication bill (§9), so the
/// artifact is self-describing.
fn run_manifest(
    sc: &Scenario,
    ledger: &CommLedger,
    linkstate: &LinkStateStats,
    radio_joules: &[f64],
) -> Json {
    let layout = Json::Arr(
        shard_ranges(sc.runs, sc.shards)
            .into_iter()
            .map(|(start, count)| {
                Json::Arr(vec![Json::Num(start as f64), Json::Num(count as f64)])
            })
            .collect(),
    );
    let per_purpose = obj(Purpose::ALL
        .iter()
        .map(|&p| (p.label(), Json::Num(ledger.purpose_scalars(p) as f64)))
        .collect());
    let per_node_bits = Json::Arr(
        (0..ledger.n_nodes)
            .map(|k| Json::Num(ledger.per_node_bits(k) as f64))
            .collect(),
    );
    let ledger_obj = obj(vec![
        ("scalars", Json::Num(ledger.scalars as f64)),
        ("bits", Json::Num(ledger.bits() as f64)),
        ("messages", Json::Num(ledger.messages as f64)),
        ("suppressed_scalars", Json::Num(ledger.suppressed_scalars as f64)),
        ("bits_per_scalar", Json::Num(ledger.bits_per_scalar as f64)),
        ("per_purpose_scalars", per_purpose),
        ("per_node_bits", per_node_bits),
    ]);
    let mut fields = vec![
        ("runs", Json::Num(sc.runs as f64)),
        ("iters", Json::Num(sc.iters as f64)),
        ("seed", Json::Num(sc.seed as f64)),
        ("record_every", Json::Num(sc.effective_record_every() as f64)),
        ("threads", Json::Num(sc.threads as f64)),
        ("shards", Json::Num(sc.shards as f64)),
        ("shard_layout", layout),
        ("ledger", ledger_obj),
    ];
    // Gilbert–Elliott occupancy (DESIGN.md §12) — only emitted when a
    // chain actually ran, so every pre-Markov artifact keeps its bytes.
    if !linkstate.is_empty() {
        let hist = Json::Arr(
            linkstate.burst_hist.iter().map(|&c| Json::Num(c as f64)).collect(),
        );
        fields.push((
            "linkstate",
            obj(vec![
                ("good_steps", Json::Num(linkstate.good_steps as f64)),
                ("bad_steps", Json::Num(linkstate.bad_steps as f64)),
                ("bursts", Json::Num(linkstate.bursts as f64)),
                ("burst_steps", Json::Num(linkstate.burst_steps as f64)),
                ("bad_fraction", Json::Num(linkstate.bad_fraction().unwrap_or(0.0))),
                ("mean_burst", Json::Num(linkstate.mean_burst().unwrap_or(0.0))),
                ("burst_hist", hist),
            ]),
        ));
    }
    // Radio energy (DESIGN.md §13) — only emitted when the scenario
    // prices the radio, so every pre-radio artifact keeps its bytes.
    if !sc.radio.is_zero() {
        let per_node = Json::Arr(radio_joules.iter().map(|&j| Json::Num(j)).collect());
        fields.push((
            "radio",
            obj(vec![
                ("tx_j_per_bit", Json::Num(sc.radio.tx_j_per_bit)),
                ("rx_j_per_bit", Json::Num(sc.radio.rx_j_per_bit)),
                ("total_joules", Json::Num(radio_joules.iter().sum())),
                ("per_node_joules", per_node),
            ]),
        ));
    }
    obj(fields)
}

/// The per-directed-link billed-bits table as CSV text (`src,dst,
/// scalars,bits`; zero links omitted) — `results/<name>_ledger.csv`.
fn ledger_csv(ledger: &CommLedger) -> String {
    let mut s = String::from("src,dst,scalars,bits\n");
    let n = ledger.n_nodes;
    // `pairs()` yields nonzero links in ascending src*n+dst order — the
    // exact rows (and row order) the historical dense double loop wrote.
    for (idx, scalars) in ledger.per_link.pairs() {
        let (src, dst) = (idx / n, idx % n);
        s.push_str(&format!(
            "{src},{dst},{scalars},{}\n",
            scalars * ledger.bits_per_scalar as u64
        ));
    }
    s
}

/// Run one scenario (validated first). With `out_dir` set, writes
/// `<out_dir>/<name>.csv`, `<out_dir>/<name>.json` (manifest includes
/// the ledger summary) and `<out_dir>/<name>_ledger.csv` (per-link
/// billed bits).
pub fn run_scenario(
    sc: &Scenario,
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<ScenarioOutput, String> {
    run_scenario_with_progress(sc, out_dir, quiet, None)
}

/// [`run_scenario`] with an optional per-shard progress callback
/// `(shard_idx, done_shards, total_shards)` — the serve daemon's
/// streaming hook (DESIGN.md §11). The callback is observational only
/// (`None` is the exact historical code path), so serve-mode execution
/// writes byte-identical artifacts.
pub fn run_scenario_with_progress(
    sc: &Scenario,
    out_dir: Option<&str>,
    quiet: bool,
    progress: Option<crate::shard::ShardProgress>,
) -> Result<ScenarioOutput, String> {
    sc.validate()?;
    let out = match sc.mode {
        ScheduleMode::Rounds => run_rounds_scenario(sc, quiet, progress)?,
        ScheduleMode::Wsn { .. } => run_wsn_scenario(sc, progress)?,
    };

    if !quiet {
        let theory = match out.theory_steady_db {
            Some(t) => format!("  theory {t:7.2} dB"),
            None => String::new(),
        };
        println!(
            "scenario {:<22} steady-state {:7.2} dB{}  scalars/run {:.0}  bits/run {:.0}  \
             [drop {} gate {} quant {}]",
            sc.name,
            out.steady_db,
            theory,
            out.scalars_per_run,
            out.ledger.bits() as f64 / sc.runs as f64,
            sc.impairments.drop,
            sc.impairments.gating,
            sc.impairments.quant_step,
        );
    }
    if let Some(dir) = out_dir {
        write_csv(format!("{dir}/{}.csv", sc.name), &out.series).map_err(|e| e.to_string())?;
        write_json_with_meta(
            format!("{dir}/{}.json", sc.name),
            &format!("scenario {}: {}", sc.name, sc.description),
            Some(run_manifest(sc, &out.ledger, &out.linkstate, &out.radio_joules)),
            &out.series,
        )
        .map_err(|e| e.to_string())?;
        std::fs::write(
            format!("{dir}/{}_ledger.csv", sc.name),
            ledger_csv(&out.ledger),
        )
        .map_err(|e| e.to_string())?;
        if !quiet {
            println!(
                "scenario {}: wrote {dir}/{}.csv, .json and _ledger.csv",
                sc.name, sc.name
            );
        }
    }
    Ok(out)
}

/// The synchronous-round execution path (the default mode).
fn run_rounds_scenario(
    sc: &Scenario,
    quiet: bool,
    progress: Option<crate::shard::ShardProgress>,
) -> Result<ScenarioOutput, String> {
    let record_every = sc.effective_record_every();
    let (model, net, mc) = mc_parts(sc)?;
    let res = run_mc(sc, &model, &net, &mc, progress)?;

    let x: Vec<f64> = (1..=res.msd.len()).map(|i| (i * record_every) as f64).collect();
    let y: Vec<f64> = res.msd.iter().map(|&v| to_db(v)).collect();
    let mut series = vec![Series::new(format!("{} (sim)", sc.algorithm.name()), x.clone(), y)];
    let steady_db = to_db(res.steady_state);

    // Theory column (exp1-style anchoring for impaired scenarios).
    let mut theory_steady_db = None;
    match theory_anchor(sc, &model, &net.c) {
        Ok(theory) => {
            let tr = theory.trajectory(&model.wo, sc.iters);
            let ty: Vec<f64> = tr
                .msd
                .iter()
                .skip(record_every - 1)
                .step_by(record_every)
                .map(|&v| to_db(v))
                .collect();
            debug_assert_eq!(ty.len(), x.len());
            series.push(Series::new(format!("{} (theory)", sc.algorithm.name()), x, ty));
            theory_steady_db = Some(to_db(tr.steady_state));
        }
        Err(why) => {
            if !quiet {
                println!("scenario {}: no theory column ({why})", sc.name);
            }
        }
    }

    Ok(ScenarioOutput {
        scenario: sc.clone(),
        series,
        steady_db,
        theory_steady_db,
        scalars_per_run: res.scalars_per_run,
        ledger: res.ledger,
        linkstate: res.linkstate,
        radio_joules: Vec::new(),
    })
}

/// The `mode = wsn` execution path: independent event-driven
/// realizations fanned across threads (or worker processes with
/// `shards > 1`), merged in run order.
fn run_wsn_scenario(
    sc: &Scenario,
    progress: Option<crate::shard::ShardProgress>,
) -> Result<ScenarioOutput, String> {
    let results = if sc.shards > 1 {
        crate::shard::run_scenario_wsn_sharded_progress(sc, progress)?
    } else {
        let results = wsn_block(sc, 0, sc.runs, sc.threads)?;
        if let Some(report) = progress {
            report(0, 1, 1);
        }
        results
    };
    let mut acc = TraceAccumulator::new();
    let mut ledger = CommLedger::empty(0);
    let mut time = Vec::new();
    let mut radio_joules = Vec::new();
    for res in &results {
        time.clone_from(&res.time);
        acc.add(&res.msd);
        ledger.merge(&res.ledger);
        // Element-wise sum in run order — the same float accumulation
        // order at any thread or shard count (bit-identity; §8, §13).
        if radio_joules.is_empty() {
            radio_joules = vec![0.0; res.radio_joules.len()];
        }
        for (acc_j, &v) in radio_joules.iter_mut().zip(res.radio_joules.iter()) {
            *acc_j += v;
        }
    }
    let mean = acc.mean();
    let tail = (mean.len() / 10).max(1);
    let steady_db = to_db(acc.steady_state(tail));
    let y: Vec<f64> = mean.iter().map(|&v| to_db(v)).collect();
    let series = vec![Series::new(format!("{} (sim)", sc.algorithm.name()), time, y)];
    Ok(ScenarioOutput {
        scenario: sc.clone(),
        series,
        steady_db,
        theory_steady_db: None,
        scalars_per_run: ledger.scalars as f64 / sc.runs as f64,
        ledger,
        linkstate: LinkStateStats::default(),
        radio_joules,
    })
}

/// Sweep one dotted scenario key (e.g. `impairments.drop_prob`) over a
/// list of values: each point re-parses the base scenario through the
/// INI override layer, re-validates, and runs on the parallel runner.
/// With `out_dir` set, writes `<out_dir>/<name>_sweep.csv` (steady-state
/// summary) and `<out_dir>/<name>_sweep.json` (summary + full traces).
pub fn sweep_scenario(
    base: &Scenario,
    key: &str,
    values: &[String],
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<SweepOutput, String> {
    if values.is_empty() {
        return Err("scenario sweep: empty value list".into());
    }
    Scenario::check_key(key)?;
    let mut points = Vec::with_capacity(values.len());
    let mut traces = Vec::with_capacity(values.len());
    for value in values {
        let mut doc = IniDoc::parse(&base.to_ini_string())?;
        doc.set_dotted(&format!("{key}={value}"))?;
        let sc = Scenario::from_ini(&doc)?;
        let out = run_scenario(&sc, None, true)?;
        if !quiet {
            let theory = match out.theory_steady_db {
                Some(t) => format!("  theory {t:7.2} dB"),
                None => String::new(),
            };
            println!(
                "sweep {:<18} {key} = {value:<10} steady-state {:7.2} dB{}  scalars/run {:.0}",
                base.name, out.steady_db, theory, out.scalars_per_run
            );
        }
        // Keep only the simulated trace per point (always series[0]);
        // the per-point theory curve is summarized by the scalar
        // `theory_db` column instead of a full trace, keeping sweep
        // artifacts one-series-per-value.
        let bits_per_run = out.ledger.bits() as f64 / sc.runs as f64;
        let mut trace = out.series.into_iter().next().expect("sim series is always present");
        trace.label = format!("{key}={value}");
        traces.push(trace);
        points.push(SweepPoint {
            value: value.clone(),
            steady_db: out.steady_db,
            theory_db: out.theory_steady_db,
            scalars_per_run: out.scalars_per_run,
            bits_per_run,
        });
    }

    if let Some(dir) = out_dir {
        // Summary CSV: x = swept value when numeric, else its index;
        // one simulated column, a billed-bits column (§9), plus a
        // predicted column when every point is inside the theory scope
        // (DESIGN.md §7).
        let xs: Vec<f64> = points
            .iter()
            .enumerate()
            .map(|(i, p)| p.value.parse::<f64>().unwrap_or(i as f64))
            .collect();
        let ys: Vec<f64> = points.iter().map(|p| p.steady_db).collect();
        let bits: Vec<f64> = points.iter().map(|p| p.bits_per_run).collect();
        let mut summaries = vec![
            Series::new(format!("steady-state dB vs {key}"), xs.clone(), ys),
            Series::new(format!("billed bits/run vs {key}"), xs.clone(), bits),
        ];
        if points.iter().all(|p| p.theory_db.is_some()) {
            let ty: Vec<f64> = points
                .iter()
                .map(|p| p.theory_db.expect("guarded by the all() above"))
                .collect();
            summaries.push(Series::new(format!("theory steady-state dB vs {key}"), xs, ty));
        }
        write_csv(format!("{dir}/{}_sweep.csv", base.name), &summaries)
            .map_err(|e| e.to_string())?;
        let mut all = summaries;
        all.extend(traces.iter().cloned());
        write_json(
            format!("{dir}/{}_sweep.json", base.name),
            &format!("scenario {} sweep over {key}", base.name),
            &all,
        )
        .map_err(|e| e.to_string())?;
        if !quiet {
            println!(
                "sweep {}: wrote {dir}/{}_sweep.csv and .json",
                base.name, base.name
            );
        }
    }
    Ok(SweepOutput { points, traces })
}

#[cfg(test)]
mod tests {
    use super::super::builtins::find;
    use super::*;

    fn small(name: &str) -> Scenario {
        let mut sc = find(name).unwrap();
        sc.runs = 3;
        sc.iters = 400;
        sc.record_every = 1;
        sc
    }

    #[test]
    fn lossy_scenario_runs_and_converges() {
        let sc = small("lossy-geometric");
        let out = run_scenario(&sc, None, true).unwrap();
        // Simulation first, then the DESIGN.md §7 theory column (the
        // preset sits inside the analysis scope).
        assert_eq!(out.series.len(), 2);
        assert_eq!(out.series[0].y.len(), 400);
        assert_eq!(out.series[1].y.len(), 400);
        assert!(out.series[1].label.contains("theory"), "{}", out.series[1].label);
        assert!(out.theory_steady_db.is_some());
        let y = &out.series[0].y;
        assert!(y[399] < y[0], "no convergence: {} -> {}", y[0], y[399]);
        assert!(out.scalars_per_run > 0.0);
        // The ledger reconciles with the legacy transmitter-only bill:
        // drops suppress exactly the dead solicited replies.
        assert!(out.ledger.suppressed_scalars > 0);
        assert_eq!(
            out.ledger.per_link.iter().sum::<u64>(),
            out.ledger.scalars
        );
    }

    /// Scenarios outside the analysis scope run fine, just without the
    /// theory column: event gating (no Bernoulli representation) and a
    /// non-identity combine matrix both disqualify.
    #[test]
    fn out_of_scope_scenarios_have_no_theory_column() {
        let gated = small("event-triggered-ring");
        let out = run_scenario(&gated, None, true).unwrap();
        assert_eq!(out.series.len(), 1);
        assert!(out.theory_steady_db.is_none());
        let quantized = small("quantized-dense"); // combine = metropolis
        let out = run_scenario(&quantized, None, true).unwrap();
        assert_eq!(out.series.len(), 1);
        assert!(out.theory_steady_db.is_none());
    }

    #[test]
    fn event_gating_spends_fewer_scalars_than_always_on() {
        let sc = small("event-triggered-ring");
        let gated = run_scenario(&sc, None, true).unwrap();
        let mut always = sc.clone();
        always.impairments = crate::coordinator::impairments::LinkImpairments::ideal();
        let full = run_scenario(&always, None, true).unwrap();
        assert!(
            gated.scalars_per_run < full.scalars_per_run,
            "gated {} >= full {}",
            gated.scalars_per_run,
            full.scalars_per_run
        );
    }

    /// The `mode = wsn` path end-to-end on a shrunk `wsn-80`: the
    /// scenario drives `WsnSimulation` with its (non-trivial)
    /// impairment spec, converges, and reports an exact bill.
    #[test]
    fn wsn_mode_scenario_runs_the_event_scheduler() {
        let mut sc = find("wsn-80").unwrap();
        assert!(matches!(sc.mode, ScheduleMode::Wsn { .. }));
        assert!(!sc.impairments.is_ideal(), "wsn-80 should exercise impairments");
        sc.topology = super::super::spec::TopologySpec::Geometric { n: 16, radius: 0.45 };
        sc.dim = 8;
        sc.runs = 2;
        sc.mu = 0.05; // shrunk horizon: converge well inside 6000 s
        sc.mode = ScheduleMode::Wsn { duration: 6_000.0, sample_dt: 300.0 };
        sc.validate().unwrap();
        let out = run_scenario(&sc, None, true).unwrap();
        assert_eq!(out.series.len(), 1, "wsn mode has no closed-form theory column");
        assert!(out.theory_steady_db.is_none());
        let y = &out.series[0].y;
        assert!(y[y.len() - 1] < y[1], "no convergence: {} -> {}", y[1], y[y.len() - 1]);
        assert!(out.ledger.scalars > 0);
        // x axis is virtual time on the sample grid.
        assert_eq!(out.series[0].x.len(), 20);
        assert!((out.series[0].x[0] - 300.0).abs() < 1e-9);
    }

    /// WSN-mode realizations fan across threads with bit-identical
    /// results — including the integer billed-bits ledger (the
    /// determinism half of the WSN × impairments acceptance).
    #[test]
    fn wsn_mode_bit_identical_across_thread_counts() {
        let mut sc = find("wsn-80").unwrap();
        sc.topology = super::super::spec::TopologySpec::Geometric { n: 12, radius: 0.5 };
        sc.dim = 6;
        sc.runs = 4;
        sc.mode = ScheduleMode::Wsn { duration: 4_000.0, sample_dt: 400.0 };
        sc.threads = 1;
        let reference = run_scenario(&sc, None, true).unwrap();
        for threads in [2usize, 4] {
            let mut sct = sc.clone();
            sct.threads = threads;
            let out = run_scenario(&sct, None, true).unwrap();
            assert_eq!(out.series[0].y, reference.series[0].y, "threads = {threads}");
            assert_eq!(out.ledger, reference.ledger, "threads = {threads}");
        }
    }

    #[test]
    fn sweep_over_drop_prob_degrades_monotonically_in_tendency() {
        let sc = small("lossy-geometric");
        let values: Vec<String> = ["0", "0.5"].iter().map(|s| s.to_string()).collect();
        let out =
            sweep_scenario(&sc, "impairments.drop_prob", &values, None, true).unwrap();
        assert_eq!(out.points.len(), 2);
        assert_eq!(out.traces.len(), 2);
        assert!(
            out.points[1].steady_db > out.points[0].steady_db,
            "drop 0.5 {} dB <= drop 0 {} dB",
            out.points[1].steady_db,
            out.points[0].steady_db
        );
        // The theory column tracks the degradation across the sweep.
        let t0 = out.points[0].theory_db.expect("in-scope sweep point");
        let t1 = out.points[1].theory_db.expect("in-scope sweep point");
        assert!(t1 > t0, "theory: drop 0.5 {t1} dB <= drop 0 {t0} dB");
        // Exact billing: more drops ⇒ fewer billed bits (dead replies).
        assert!(
            out.points[1].bits_per_run < out.points[0].bits_per_run,
            "bits/run did not drop: {} vs {}",
            out.points[1].bits_per_run,
            out.points[0].bits_per_run
        );
    }

    #[test]
    fn sweep_rejects_bad_overrides() {
        let sc = small("lossy-geometric");
        let vals = vec!["2.0".to_string()];
        assert!(sweep_scenario(&sc, "impairments.drop_prob", &vals, None, true).is_err());
        assert!(sweep_scenario(&sc, "nodot", &[], None, true).is_err());
        // A typo'd key must error, not silently sweep nothing.
        let vals = vec!["0.1".to_string()];
        let err = sweep_scenario(&sc, "impairments.dropprob", &vals, None, true).unwrap_err();
        assert!(err.contains("unknown scenario key"), "{err}");
    }

    #[test]
    fn results_files_are_written() {
        let dir = std::env::temp_dir().join("dcd_scenario_run_test");
        std::fs::remove_dir_all(&dir).ok();
        let sc = small("quantized-dense");
        let out_dir = dir.to_str().unwrap().to_string();
        run_scenario(&sc, Some(&out_dir), true).unwrap();
        assert!(dir.join("quantized-dense.csv").exists());
        assert!(dir.join("quantized-dense.json").exists());
        let doc = crate::jsonio::Json::parse(
            &std::fs::read_to_string(dir.join("quantized-dense.json")).unwrap(),
        )
        .unwrap();
        assert_eq!(doc.get("series").as_arr().unwrap().len(), 1);
        // The manifest records the schedule + shard layout (§8) and the
        // ledger summary (§9).
        let manifest = doc.get("manifest");
        assert_eq!(manifest.get("runs").as_usize(), Some(3));
        assert_eq!(manifest.get("shards").as_usize(), Some(1));
        let layout = manifest.get("shard_layout").as_arr().unwrap();
        assert_eq!(layout.len(), 1);
        assert_eq!(layout[0].as_arr().unwrap()[1].as_usize(), Some(3));
        let ledger = manifest.get("ledger");
        assert!(ledger.get("scalars").as_u64().unwrap_or(0) > 0);
        // quantized-dense stores on a 1e-3 grid: 14-bit payloads
        // (16001 levels over the ±8 fixed-point range).
        assert_eq!(ledger.get("bits_per_scalar").as_u64(), Some(14));
        assert!(ledger.get("per_purpose_scalars").get("estimate-broadcast").as_f64().is_some());
        // The per-link billed-bits table rides next to the results.
        let ledger_csv =
            std::fs::read_to_string(dir.join("quantized-dense_ledger.csv")).unwrap();
        assert!(ledger_csv.starts_with("src,dst,scalars,bits\n"), "{ledger_csv}");
        assert!(ledger_csv.lines().count() > 1);
        std::fs::remove_dir_all(&dir).ok();
    }
}
