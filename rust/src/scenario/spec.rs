//! The [`Scenario`] specification: a declarative, validated, INI
//! round-trippable description of one experiment —
//! topology × data model × algorithm × link impairments × schedule.

use crate::algorithms::{Algorithm, Dcd, DiffusionLms, NetworkConfig, PartialDiffusion, Rcd};
use crate::config::IniDoc;
use crate::coordinator::dynamics::DynamicsConfig;
use crate::coordinator::impairments::{AdaptivePolicy, DropModel, Gating, LinkImpairments};
use crate::coordinator::lanes::LaneCount;
use crate::datamodel::DriftModel;
use crate::energy::RadioEnergy;
use crate::rng::Pcg64;
use crate::topology::{Graph, Rule};

/// Topology generator selection.
#[derive(Debug, Clone, PartialEq)]
pub enum TopologySpec {
    /// The paper's fixed 10-node network (Fig. 2 left).
    Paper10,
    /// Ring lattice: `n` nodes, each linked to `hops` nodes per side.
    Ring {
        /// Number of nodes.
        n: usize,
        /// Links per side (`hops = 0` is disconnected and rejected by
        /// the validator).
        hops: usize,
    },
    /// Random geometric graph on the unit square (stitched until
    /// connected, like the Experiment 2/3 networks).
    Geometric {
        /// Number of nodes.
        n: usize,
        /// Connection radius.
        radius: f64,
    },
    /// 4-connected `rows x cols` lattice — deterministic, bounded-degree,
    /// and therefore the natural shape for very large N on the sparse
    /// (CSR) path (DESIGN.md §10).
    Grid {
        /// Lattice rows.
        rows: usize,
        /// Lattice columns.
        cols: usize,
    },
}

impl TopologySpec {
    /// Number of nodes the generated graph will have.
    pub fn n_nodes(&self) -> usize {
        match self {
            TopologySpec::Paper10 => 10,
            TopologySpec::Ring { n, .. } | TopologySpec::Geometric { n, .. } => *n,
            TopologySpec::Grid { rows, cols } => rows * cols,
        }
    }

    /// Instantiate the graph. Geometric topologies consume `rng` (the
    /// scenario runner passes the master stream, exactly like exp2/exp3).
    pub fn build(&self, rng: &mut Pcg64) -> Graph {
        match self {
            TopologySpec::Paper10 => Graph::paper_ten_node(),
            TopologySpec::Ring { n, hops } => Graph::ring(*n, *hops),
            TopologySpec::Geometric { n, radius } => Graph::random_geometric(*n, *radius, rng),
            TopologySpec::Grid { rows, cols } => Graph::grid(*rows, *cols),
        }
    }
}

/// Algorithm selection plus its compression knobs.
#[derive(Debug, Clone, PartialEq)]
pub enum AlgorithmSpec {
    /// Uncompressed ATC diffusion LMS (the 2L-per-link baseline).
    DiffusionLms,
    /// Compressed diffusion LMS: masked estimates, full gradients.
    Cd {
        /// Estimate entries shared per exchange.
        m: usize,
    },
    /// Doubly-compressed diffusion LMS (the paper's Alg. 1).
    Dcd {
        /// Estimate entries shared per exchange.
        m: usize,
        /// Gradient entries shared per exchange.
        m_grad: usize,
    },
    /// Reduced-communication diffusion LMS: poll a neighbour subset.
    Rcd {
        /// Neighbours polled per iteration.
        m_links: usize,
    },
    /// Partial-diffusion LMS: masked intermediate estimates.
    Partial {
        /// Estimate entries shared per exchange.
        m: usize,
    },
}

impl AlgorithmSpec {
    /// The registry name (also the `[algorithm] name` INI value).
    pub fn name(&self) -> &'static str {
        match self {
            AlgorithmSpec::DiffusionLms => "diffusion-lms",
            AlgorithmSpec::Cd { .. } => "cd",
            AlgorithmSpec::Dcd { .. } => "dcd",
            AlgorithmSpec::Rcd { .. } => "rcd",
            AlgorithmSpec::Partial { .. } => "partial",
        }
    }

    /// The `(M, M_grad)` selection-mask pair the mean-square analysis
    /// (paper §III ideal, DESIGN.md §7 impaired) models for this
    /// algorithm: diffusion LMS is the uncompressed limit
    /// (M = M_grad = L), CD masks estimates only, DCD masks both. RCD
    /// and partial diffusion follow different update equations and are
    /// outside the analysis — `None`.
    pub fn theory_masks(&self, dim: usize) -> Option<(usize, usize)> {
        match self {
            AlgorithmSpec::DiffusionLms => Some((dim, dim)),
            AlgorithmSpec::Cd { m } => Some((*m, dim)),
            AlgorithmSpec::Dcd { m, m_grad } => Some((*m, *m_grad)),
            AlgorithmSpec::Rcd { .. } | AlgorithmSpec::Partial { .. } => None,
        }
    }

    /// Instantiate the algorithm on `net`.
    pub fn build(&self, net: NetworkConfig) -> Box<dyn Algorithm> {
        match self {
            AlgorithmSpec::DiffusionLms => Box::new(DiffusionLms::new(net)),
            AlgorithmSpec::Cd { m } => Box::new(Dcd::cd(net, *m)),
            AlgorithmSpec::Dcd { m, m_grad } => Box::new(Dcd::new(net, *m, *m_grad)),
            AlgorithmSpec::Rcd { m_links } => Box::new(Rcd::new(net, *m_links)),
            AlgorithmSpec::Partial { m } => Box::new(PartialDiffusion::new(net, *m)),
        }
    }
}

/// The `[dynamics]` INI section (DESIGN.md §12): time variation of the
/// network and the optimum. The default is fully static — exactly the
/// historical behavior, and the section is only serialized when some
/// knob moved, so pre-existing canonical INIs (hence cache keys and
/// preset CSVs) keep their bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct DynamicsSpec {
    /// Per-iteration probability that an active node leaves (churn).
    pub leave: f64,
    /// Per-iteration probability that an absent node rejoins.
    pub join: f64,
    /// Veto departures that would disconnect the active subgraph.
    pub require_connected: bool,
    /// Mobility orbit radius ρ around each home placement (0 = off;
    /// requires a geometric topology, whose radius bounds link reach).
    pub rewire: f64,
    /// Mobility orbit period in iterations.
    pub rewire_period: usize,
    /// Time variation of the optimum w°(i) (tracking experiments).
    pub drift: DriftModel,
    /// Adaptive combination-weight policy re-weighting around links the
    /// ledger observes as impaired.
    pub adaptive: AdaptivePolicy,
}

impl Default for DynamicsSpec {
    fn default() -> Self {
        Self {
            leave: 0.0,
            join: 0.0,
            require_connected: false,
            rewire: 0.0,
            rewire_period: 1000,
            drift: DriftModel::None,
            adaptive: AdaptivePolicy::Static,
        }
    }
}

impl DynamicsSpec {
    /// True when every network-side axis is off (drift rides the data
    /// model, not the dynamics state, and is excluded here).
    pub fn network_static(&self) -> bool {
        self.leave == 0.0
            && self.join == 0.0
            && self.rewire == 0.0
            && self.adaptive == AdaptivePolicy::Static
    }

    /// True when the whole section is a no-op — the scenario then runs
    /// the exact legacy static path.
    pub fn is_static(&self) -> bool {
        self.network_static() && self.drift.is_none()
    }

    /// The runtime configuration for the round scheduler; `radius` is
    /// the geometric topology's connection radius (link reach under
    /// mobility — 0 when the topology carries none).
    pub fn to_config(&self, radius: f64) -> DynamicsConfig {
        DynamicsConfig {
            leave: self.leave,
            join: self.join,
            require_connected: self.require_connected,
            rewire: self.rewire,
            rewire_period: self.rewire_period,
            radius,
            adaptive: self.adaptive,
        }
    }

    /// Range checks (topology/dim cross-checks live in
    /// [`Scenario::validate`]).
    pub fn validate(&self) -> Result<(), String> {
        for (name, p) in [("leave", self.leave), ("join", self.join)] {
            if !p.is_finite() || !(0.0..=1.0).contains(&p) {
                return Err(format!("dynamics: {name} {p} outside [0, 1]"));
            }
        }
        if !self.rewire.is_finite() || self.rewire < 0.0 {
            return Err(format!("dynamics: rewire {} must be >= 0", self.rewire));
        }
        if self.rewire > 0.0 && self.rewire_period == 0 {
            return Err("dynamics: rewire_period must be >= 1".into());
        }
        self.drift.validate().map_err(|e| format!("dynamics: {e}"))
    }
}

/// Whether the runner attaches the closed-form theory column
/// (`… (theory)` series + steady-state anchor) to a scenario's output.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TheoryColumn {
    /// Attach when the scenario is inside the analysis scope *and*
    /// N·L is at or below the automatic threshold (256) — exactly the
    /// historical behavior, so existing presets keep byte-identical
    /// outputs.
    Auto,
    /// Attach whenever the scenario is in scope, up to the hard engine
    /// cap (N·L ≤ 10 000 on the matrix-free path; DESIGN.md §10).
    On,
    /// Never attach.
    Off,
}

impl TheoryColumn {
    fn name(self) -> &'static str {
        match self {
            TheoryColumn::Auto => "auto",
            TheoryColumn::On => "on",
            TheoryColumn::Off => "off",
        }
    }
}

/// How a scenario's schedule drives the network.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ScheduleMode {
    /// Synchronous rounds on the Monte-Carlo round scheduler (the
    /// default; `iters` iterations per realization).
    Rounds,
    /// The energy-harvesting event-driven WSN scheduler
    /// ([`crate::coordinator::WsnSimulation`]): nodes duty-cycle on the
    /// ENO model and gate on charge *and* the scenario's impairment
    /// gate (DESIGN.md §9). `iters` is ignored; virtual time rules.
    Wsn {
        /// Virtual-time horizon (s).
        duration: f64,
        /// MSD/telemetry sampling interval (s).
        sample_dt: f64,
    },
}

/// One declarative experiment. Parse with [`Scenario::from_ini`] /
/// [`Scenario::parse_str`], serialize with [`Scenario::to_ini_string`]
/// (a lossless round-trip), check with [`Scenario::validate`], execute
/// with [`super::run_scenario`].
#[derive(Debug, Clone, PartialEq)]
pub struct Scenario {
    /// Registry name; also the `results/<name>.{csv,json}` stem.
    pub name: String,
    /// One-line human description (shown by `scenario list`).
    pub description: String,
    /// Network topology generator.
    pub topology: TopologySpec,
    /// Rule for the combine matrix A.
    pub combine_rule: Rule,
    /// Rule for the adapt matrix C (`identity` = no gradient exchange).
    pub adapt_rule: Rule,
    /// Parameter dimension L.
    pub dim: usize,
    /// Lower bound of the per-node regressor-variance range.
    pub u2_min: f64,
    /// Upper bound of the per-node regressor-variance range.
    pub u2_max: f64,
    /// Observation-noise variance σ²_v (all nodes).
    pub sigma_v2: f64,
    /// Algorithm and its compression knobs.
    pub algorithm: AlgorithmSpec,
    /// Step size μ (all nodes).
    pub mu: f64,
    /// Link-impairment model.
    pub impairments: LinkImpairments,
    /// Per-bit radio energy prices debited from the activating node
    /// under `mode = wsn` (`[energy]` section; the zero-cost default is
    /// the exact legacy path and the section is only serialized when a
    /// rate is non-zero, keeping pre-radio canonical INI bytes —
    /// DESIGN.md §13).
    pub radio: RadioEnergy,
    /// Time-varying network / optimum axes (`[dynamics]`; all off by
    /// default, which reproduces the static legacy path exactly).
    pub dynamics: DynamicsSpec,
    /// Monte-Carlo realizations.
    pub runs: usize,
    /// Iterations per realization.
    pub iters: usize,
    /// Master seed (model/topology stream 0; run r uses stream r + 1).
    pub seed: u64,
    /// MSD recording stride; 0 = auto (`(iters / 2000).max(1)`, the
    /// exp1 convention).
    pub record_every: usize,
    /// Worker threads (0 = auto, see `coordinator::runner`).
    pub threads: usize,
    /// Worker *processes* the realizations are sharded across (1 = run
    /// in-process; must be ≥ 1). Results are bit-identical for any
    /// value — see DESIGN.md §8 and [`crate::shard`].
    pub shards: usize,
    /// SoA lane width for the run-batched engine (`[schedule] lanes`,
    /// DESIGN.md §14): runs advanced per scheduler pass. Artifacts are
    /// byte-identical at every width, so — like threads and shards —
    /// this is a pure throughput knob and stays out of the serve cache
    /// key.
    pub lanes: LaneCount,
    /// Schedule mode: synchronous rounds (default) or the event-driven
    /// energy-harvesting WSN scheduler (`[schedule] mode = wsn` plus a
    /// `[wsn]` section).
    pub mode: ScheduleMode,
    /// Theory-column policy (`[schedule] theory = auto | on | off`).
    pub theory: TheoryColumn,
}

impl Scenario {
    /// A neutral base scenario: 10-node paper network, DCD (3, 1),
    /// ideal links, exp1-style data model.
    pub fn base(name: &str, description: &str) -> Self {
        Self {
            name: name.to_string(),
            description: description.to_string(),
            topology: TopologySpec::Paper10,
            combine_rule: Rule::Metropolis,
            adapt_rule: Rule::Metropolis,
            dim: 5,
            u2_min: 0.8,
            u2_max: 1.2,
            sigma_v2: 1e-3,
            algorithm: AlgorithmSpec::Dcd { m: 3, m_grad: 1 },
            mu: 1e-2,
            impairments: LinkImpairments::ideal(),
            radio: RadioEnergy::zero(),
            dynamics: DynamicsSpec::default(),
            runs: 10,
            iters: 4_000,
            seed: 2024,
            record_every: 0,
            threads: 0,
            shards: 1,
            lanes: LaneCount::default(),
            mode: ScheduleMode::Rounds,
            theory: TheoryColumn::Auto,
        }
    }

    /// Every `section.key` the scenario INI schema understands — the
    /// whitelist behind [`Scenario::check_key`].
    pub fn known_keys() -> &'static [&'static str] {
        &[
            "scenario.name",
            "scenario.description",
            "topology.kind",
            "topology.n",
            "topology.hops",
            "topology.radius",
            "topology.rows",
            "topology.cols",
            "topology.combine_rule",
            "topology.adapt_rule",
            "data.dim",
            "data.u2_min",
            "data.u2_max",
            "data.sigma_v2",
            "algorithm.name",
            "algorithm.m",
            "algorithm.m_grad",
            "algorithm.m_links",
            "algorithm.mu",
            "impairments.drop_prob",
            "impairments.drop",
            "impairments.gating",
            "impairments.quant_step",
            "impairments.per_leg",
            "energy.tx_j_per_bit",
            "energy.rx_j_per_bit",
            "dynamics.leave",
            "dynamics.join",
            "dynamics.require_connected",
            "dynamics.rewire",
            "dynamics.rewire_period",
            "dynamics.drift",
            "dynamics.adaptive",
            "schedule.runs",
            "schedule.iters",
            "schedule.seed",
            "schedule.record_every",
            "schedule.threads",
            "schedule.shards",
            "schedule.lanes",
            "schedule.mode",
            "schedule.theory",
            "wsn.duration",
            "wsn.sample_dt",
        ]
    }

    /// Reject dotted override keys the schema does not understand —
    /// without this, a typo like `impairments.dropprob` would silently
    /// run the unmodified scenario for every sweep point.
    pub fn check_key(dotted: &str) -> Result<(), String> {
        if Self::known_keys().contains(&dotted) {
            Ok(())
        } else {
            Err(format!(
                "unknown scenario key {dotted:?}; known keys: {}",
                Self::known_keys().join(", ")
            ))
        }
    }

    /// The recording stride actually used (resolves `record_every = 0`).
    pub fn effective_record_every(&self) -> usize {
        if self.record_every == 0 {
            (self.iters / 2000).max(1)
        } else {
            self.record_every
        }
    }

    /// Parse from INI text (see `to_ini_string` for the schema).
    pub fn parse_str(src: &str) -> Result<Self, String> {
        Self::from_ini(&IniDoc::parse(src)?)
    }

    /// Build a scenario from an INI document. Missing keys fall back to
    /// the [`Scenario::base`] defaults; `[topology] kind` and
    /// `[algorithm] name` select the variants.
    pub fn from_ini(doc: &IniDoc) -> Result<Self, String> {
        let mut sc = Self::base("unnamed", "");
        if let Some(v) = doc.get("scenario", "name") {
            sc.name = v.to_string();
        }
        if let Some(v) = doc.get("scenario", "description") {
            sc.description = v.to_string();
        }

        // -- topology -----------------------------------------------------
        let kind = doc.get("topology", "kind").unwrap_or("paper10");
        sc.topology = match kind {
            "paper10" => TopologySpec::Paper10,
            "ring" => TopologySpec::Ring {
                n: get_or(doc, "topology", "n", 10)?,
                hops: get_or(doc, "topology", "hops", 1)?,
            },
            "geometric" => TopologySpec::Geometric {
                n: get_or(doc, "topology", "n", 20)?,
                radius: get_or(doc, "topology", "radius", 0.3)?,
            },
            "grid" => TopologySpec::Grid {
                rows: get_or(doc, "topology", "rows", 10)?,
                cols: get_or(doc, "topology", "cols", 10)?,
            },
            other => {
                return Err(format!(
                    "topology.kind {other:?}: expected paper10 | ring | geometric | grid"
                ))
            }
        };
        if let Some(v) = doc.get("topology", "combine_rule") {
            sc.combine_rule = parse_rule(v)?;
        }
        if let Some(v) = doc.get("topology", "adapt_rule") {
            sc.adapt_rule = parse_rule(v)?;
        }

        // -- data model ---------------------------------------------------
        sc.dim = get_or(doc, "data", "dim", sc.dim)?;
        sc.u2_min = get_or(doc, "data", "u2_min", sc.u2_min)?;
        sc.u2_max = get_or(doc, "data", "u2_max", sc.u2_max)?;
        sc.sigma_v2 = get_or(doc, "data", "sigma_v2", sc.sigma_v2)?;

        // -- algorithm ----------------------------------------------------
        let alg = doc.get("algorithm", "name").unwrap_or("dcd");
        sc.algorithm = match alg {
            "diffusion-lms" => AlgorithmSpec::DiffusionLms,
            "cd" => AlgorithmSpec::Cd { m: get_or(doc, "algorithm", "m", 3)? },
            "dcd" => AlgorithmSpec::Dcd {
                m: get_or(doc, "algorithm", "m", 3)?,
                m_grad: get_or(doc, "algorithm", "m_grad", 1)?,
            },
            "rcd" => AlgorithmSpec::Rcd { m_links: get_or(doc, "algorithm", "m_links", 1)? },
            "partial" => AlgorithmSpec::Partial { m: get_or(doc, "algorithm", "m", 3)? },
            other => {
                return Err(format!(
                    "algorithm.name {other:?}: expected diffusion-lms | cd | dcd | rcd | partial"
                ))
            }
        };
        sc.mu = get_or(doc, "algorithm", "mu", sc.mu)?;

        // -- impairments --------------------------------------------------
        // `drop_prob` is the legacy scalar spelling (i.i.d. Bernoulli);
        // the structured `drop` key (`prob:p` | `markov:p,p_gb,p_bg`)
        // wins when both are present.
        sc.impairments.drop = DropModel::Iid(get_or(doc, "impairments", "drop_prob", 0.0)?);
        if let Some(v) = doc.get("impairments", "drop") {
            sc.impairments.drop = v.parse::<DropModel>()?;
        }
        if let Some(v) = doc.get("impairments", "gating") {
            sc.impairments.gating = v.parse::<Gating>()?;
        }
        sc.impairments.quant_step = get_or(doc, "impairments", "quant_step", 0.0)?;
        sc.impairments.per_leg = get_or(doc, "impairments", "per_leg", false)?;

        // -- radio energy (DESIGN.md §13) ---------------------------------
        sc.radio.tx_j_per_bit = get_or(doc, "energy", "tx_j_per_bit", 0.0)?;
        sc.radio.rx_j_per_bit = get_or(doc, "energy", "rx_j_per_bit", 0.0)?;

        // -- dynamics -----------------------------------------------------
        sc.dynamics.leave = get_or(doc, "dynamics", "leave", sc.dynamics.leave)?;
        sc.dynamics.join = get_or(doc, "dynamics", "join", sc.dynamics.join)?;
        sc.dynamics.require_connected =
            get_or(doc, "dynamics", "require_connected", sc.dynamics.require_connected)?;
        sc.dynamics.rewire = get_or(doc, "dynamics", "rewire", sc.dynamics.rewire)?;
        sc.dynamics.rewire_period =
            get_or(doc, "dynamics", "rewire_period", sc.dynamics.rewire_period)?;
        if let Some(v) = doc.get("dynamics", "drift") {
            sc.dynamics.drift = v.parse::<DriftModel>()?;
        }
        if let Some(v) = doc.get("dynamics", "adaptive") {
            sc.dynamics.adaptive = v.parse::<AdaptivePolicy>()?;
        }

        // -- schedule -----------------------------------------------------
        sc.runs = get_or(doc, "schedule", "runs", sc.runs)?;
        sc.iters = get_or(doc, "schedule", "iters", sc.iters)?;
        sc.seed = get_or(doc, "schedule", "seed", sc.seed)?;
        sc.record_every = get_or(doc, "schedule", "record_every", sc.record_every)?;
        sc.threads = get_or(doc, "schedule", "threads", sc.threads)?;
        sc.shards = get_or(doc, "schedule", "shards", sc.shards)?;
        sc.lanes = get_or(doc, "schedule", "lanes", sc.lanes)?;
        sc.mode = match doc.get("schedule", "mode").unwrap_or("rounds") {
            "rounds" => ScheduleMode::Rounds,
            "wsn" => ScheduleMode::Wsn {
                duration: get_or(doc, "wsn", "duration", 200_000.0)?,
                sample_dt: get_or(doc, "wsn", "sample_dt", 500.0)?,
            },
            other => {
                return Err(format!("schedule.mode {other:?}: expected rounds | wsn"))
            }
        };
        sc.theory = match doc.get("schedule", "theory").unwrap_or("auto") {
            "auto" => TheoryColumn::Auto,
            "on" => TheoryColumn::On,
            "off" => TheoryColumn::Off,
            other => {
                return Err(format!("schedule.theory {other:?}: expected auto | on | off"))
            }
        };
        Ok(sc)
    }

    /// Serialize as INI; `Scenario::parse_str(&sc.to_ini_string())`
    /// reproduces `sc` exactly (round-trip tested).
    pub fn to_ini_string(&self) -> String {
        let mut s = String::new();
        s.push_str("[scenario]\n");
        s.push_str(&format!("name = {}\n", self.name));
        s.push_str(&format!("description = {}\n", self.description));
        s.push_str("\n[topology]\n");
        match &self.topology {
            TopologySpec::Paper10 => s.push_str("kind = paper10\n"),
            TopologySpec::Ring { n, hops } => {
                s.push_str(&format!("kind = ring\nn = {n}\nhops = {hops}\n"));
            }
            TopologySpec::Geometric { n, radius } => {
                s.push_str(&format!("kind = geometric\nn = {n}\nradius = {radius}\n"));
            }
            TopologySpec::Grid { rows, cols } => {
                s.push_str(&format!("kind = grid\nrows = {rows}\ncols = {cols}\n"));
            }
        }
        s.push_str(&format!("combine_rule = {}\n", rule_name(self.combine_rule)));
        s.push_str(&format!("adapt_rule = {}\n", rule_name(self.adapt_rule)));
        s.push_str("\n[data]\n");
        s.push_str(&format!("dim = {}\n", self.dim));
        s.push_str(&format!("u2_min = {}\n", self.u2_min));
        s.push_str(&format!("u2_max = {}\n", self.u2_max));
        s.push_str(&format!("sigma_v2 = {}\n", self.sigma_v2));
        s.push_str("\n[algorithm]\n");
        s.push_str(&format!("name = {}\n", self.algorithm.name()));
        match &self.algorithm {
            AlgorithmSpec::DiffusionLms => {}
            AlgorithmSpec::Cd { m } | AlgorithmSpec::Partial { m } => {
                s.push_str(&format!("m = {m}\n"));
            }
            AlgorithmSpec::Dcd { m, m_grad } => {
                s.push_str(&format!("m = {m}\nm_grad = {m_grad}\n"));
            }
            AlgorithmSpec::Rcd { m_links } => {
                s.push_str(&format!("m_links = {m_links}\n"));
            }
        }
        s.push_str(&format!("mu = {}\n", self.mu));
        s.push_str("\n[impairments]\n");
        match self.impairments.drop {
            // The legacy scalar spelling keeps its exact bytes so every
            // pre-Markov canonical INI (and its cache key) is unchanged.
            DropModel::Iid(p) => s.push_str(&format!("drop_prob = {p}\n")),
            m @ DropModel::Markov { .. } => s.push_str(&format!("drop = {m}\n")),
        }
        s.push_str(&format!("gating = {}\n", self.impairments.gating));
        s.push_str(&format!("quant_step = {}\n", self.impairments.quant_step));
        if self.impairments.per_leg {
            // Emitted only when set, so every pre-existing canonical INI
            // (hence every serve cache key and preset CSV) keeps its
            // bytes (DESIGN.md §13).
            s.push_str("per_leg = true\n");
        }
        if !self.radio.is_zero() {
            // Same byte-stability contract as per_leg above.
            s.push_str("\n[energy]\n");
            s.push_str(&format!("tx_j_per_bit = {}\n", self.radio.tx_j_per_bit));
            s.push_str(&format!("rx_j_per_bit = {}\n", self.radio.rx_j_per_bit));
        }
        if self.dynamics != DynamicsSpec::default() {
            s.push_str("\n[dynamics]\n");
            s.push_str(&format!("leave = {}\n", self.dynamics.leave));
            s.push_str(&format!("join = {}\n", self.dynamics.join));
            s.push_str(&format!("require_connected = {}\n", self.dynamics.require_connected));
            s.push_str(&format!("rewire = {}\n", self.dynamics.rewire));
            s.push_str(&format!("rewire_period = {}\n", self.dynamics.rewire_period));
            s.push_str(&format!("drift = {}\n", self.dynamics.drift));
            s.push_str(&format!("adaptive = {}\n", self.dynamics.adaptive));
        }
        s.push_str("\n[schedule]\n");
        s.push_str(&format!("runs = {}\n", self.runs));
        s.push_str(&format!("iters = {}\n", self.iters));
        s.push_str(&format!("seed = {}\n", self.seed));
        s.push_str(&format!("record_every = {}\n", self.record_every));
        s.push_str(&format!("threads = {}\n", self.threads));
        s.push_str(&format!("shards = {}\n", self.shards));
        if !self.lanes.is_default() {
            // Emitted only when set, so every pre-existing canonical INI
            // (hence every serve cache key and preset CSV) keeps its
            // bytes — and the serve cache additionally canonicalises the
            // key away entirely (lanes never change artifacts).
            s.push_str(&format!("lanes = {}\n", self.lanes));
        }
        s.push_str(&format!("theory = {}\n", self.theory.name()));
        match &self.mode {
            ScheduleMode::Rounds => s.push_str("mode = rounds\n"),
            ScheduleMode::Wsn { duration, sample_dt } => {
                s.push_str("mode = wsn\n");
                s.push_str("\n[wsn]\n");
                s.push_str(&format!("duration = {duration}\n"));
                s.push_str(&format!("sample_dt = {sample_dt}\n"));
            }
        }
        s
    }

    /// Full semantic validation: name usable as a file stem, connected
    /// topology, algorithm knobs within the dimension, impairment ranges,
    /// positive workload.
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty()
            || !self
                .name
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '-' || c == '_')
        {
            return Err(format!(
                "scenario name {:?} must be non-empty [A-Za-z0-9_-] (it names the result files)",
                self.name
            ));
        }
        let n = self.topology.n_nodes();
        if n < 2 {
            return Err(format!("scenario {}: need at least 2 nodes", self.name));
        }
        if let TopologySpec::Geometric { radius, .. } = self.topology {
            if !radius.is_finite() || radius <= 0.0 {
                return Err(format!("scenario {}: radius {radius} must be > 0", self.name));
            }
        }
        // Build the graph exactly as the runner will and check it is
        // connected (e.g. a ring with hops = 0 is not).
        let mut rng = Pcg64::new(self.seed, 0);
        let graph = self.topology.build(&mut rng);
        if !graph.is_connected() {
            return Err(format!(
                "scenario {}: generated topology is disconnected",
                self.name
            ));
        }
        if self.dim == 0 {
            return Err(format!("scenario {}: dim must be >= 1", self.name));
        }
        if !(self.u2_min > 0.0 && self.u2_max >= self.u2_min) {
            return Err(format!(
                "scenario {}: need 0 < u2_min <= u2_max (got {} / {})",
                self.name, self.u2_min, self.u2_max
            ));
        }
        if !(self.sigma_v2 >= 0.0 && self.sigma_v2.is_finite()) {
            return Err(format!("scenario {}: bad sigma_v2 {}", self.name, self.sigma_v2));
        }
        if !(self.mu > 0.0 && self.mu.is_finite()) {
            return Err(format!("scenario {}: step size {} must be > 0", self.name, self.mu));
        }
        match self.algorithm {
            AlgorithmSpec::DiffusionLms => {}
            AlgorithmSpec::Cd { m } | AlgorithmSpec::Partial { m } => {
                if m == 0 || m > self.dim {
                    return Err(format!(
                        "scenario {}: m = {m} outside 1..={}",
                        self.name, self.dim
                    ));
                }
            }
            AlgorithmSpec::Dcd { m, m_grad } => {
                if m == 0 || m > self.dim || m_grad == 0 || m_grad > self.dim {
                    return Err(format!(
                        "scenario {}: (m, m_grad) = ({m}, {m_grad}) outside 1..={}",
                        self.name, self.dim
                    ));
                }
            }
            AlgorithmSpec::Rcd { m_links } => {
                if m_links == 0 {
                    return Err(format!("scenario {}: m_links must be >= 1", self.name));
                }
            }
        }
        self.impairments
            .validate()
            .map_err(|e| format!("scenario {}: {e}", self.name))?;
        self.radio
            .validate()
            .map_err(|e| format!("scenario {}: {e}", self.name))?;
        if self.impairments.per_leg && !matches!(self.mode, ScheduleMode::Rounds) {
            return Err(format!(
                "scenario {}: impairments.per_leg needs schedule.mode = rounds \
                 (the event-driven WSN engine draws no independent reply leg)",
                self.name
            ));
        }
        if !self.radio.is_zero() && !matches!(self.mode, ScheduleMode::Wsn { .. }) {
            return Err(format!(
                "scenario {}: a non-zero [energy] radio model needs \
                 schedule.mode = wsn (only the WSN engine carries a charge state)",
                self.name
            ));
        }
        self.dynamics
            .validate()
            .map_err(|e| format!("scenario {}: {e}", self.name))?;
        if self.dynamics.rewire > 0.0 && !matches!(self.topology, TopologySpec::Geometric { .. }) {
            return Err(format!(
                "scenario {}: dynamics.rewire needs a geometric topology \
                 (mobility reach is bounded by its radius)",
                self.name
            ));
        }
        if matches!(self.dynamics.drift, DriftModel::Rotate { .. }) && self.dim < 2 {
            return Err(format!(
                "scenario {}: drift = rotate needs dim >= 2",
                self.name
            ));
        }
        if !self.dynamics.is_static() && !matches!(self.mode, ScheduleMode::Rounds) {
            return Err(format!(
                "scenario {}: [dynamics] is only supported with schedule.mode = rounds",
                self.name
            ));
        }
        if let ScheduleMode::Wsn { duration, sample_dt } = self.mode {
            if !(duration.is_finite() && duration > 0.0) {
                return Err(format!(
                    "scenario {}: wsn duration {duration} must be > 0",
                    self.name
                ));
            }
            if !(sample_dt.is_finite() && sample_dt > 0.0 && sample_dt <= duration) {
                return Err(format!(
                    "scenario {}: wsn sample_dt {sample_dt} must be in (0, duration]",
                    self.name
                ));
            }
        }
        if self.runs == 0 || self.iters == 0 {
            return Err(format!(
                "scenario {}: runs and iters must be positive",
                self.name
            ));
        }
        if self.shards == 0 {
            return Err(format!(
                "scenario {}: shards must be >= 1 (1 = in-process; \
                 there is no process-count auto mode)",
                self.name
            ));
        }
        self.lanes
            .validate()
            .map_err(|e| format!("scenario {}: {e}", self.name))?;
        if !self.lanes.is_default() && !matches!(self.mode, ScheduleMode::Rounds) {
            return Err(format!(
                "scenario {}: [schedule] lanes needs schedule.mode = rounds \
                 (the event-driven WSN engine is not run-batched)",
                self.name
            ));
        }
        Ok(())
    }
}

fn rule_name(r: Rule) -> &'static str {
    match r {
        Rule::Metropolis => "metropolis",
        Rule::Uniform => "uniform",
        Rule::Identity => "identity",
    }
}

fn parse_rule(s: &str) -> Result<Rule, String> {
    match s {
        "metropolis" => Ok(Rule::Metropolis),
        "uniform" => Ok(Rule::Uniform),
        "identity" => Ok(Rule::Identity),
        other => Err(format!(
            "combination rule {other:?}: expected metropolis | uniform | identity"
        )),
    }
}

/// Typed lookup with default: absent key ⇒ `default`, unparsable ⇒ error.
fn get_or<T: std::str::FromStr>(
    doc: &IniDoc,
    section: &str,
    key: &str,
    default: T,
) -> Result<T, String>
where
    T::Err: std::fmt::Display,
{
    match doc.get(section, key) {
        None => Ok(default),
        Some(v) => v
            .parse::<T>()
            .map_err(|e| format!("scenario config {section}.{key}: cannot parse {v:?}: {e}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ini_roundtrip_is_lossless() {
        let mut sc = Scenario::base("round-trip", "parse -> serialize -> parse");
        sc.topology = TopologySpec::Geometric { n: 24, radius: 0.27 };
        sc.combine_rule = Rule::Uniform;
        sc.adapt_rule = Rule::Identity;
        sc.dim = 7;
        sc.u2_min = 0.5;
        sc.u2_max = 1.5;
        sc.sigma_v2 = 2e-3;
        sc.algorithm = AlgorithmSpec::Rcd { m_links: 2 };
        sc.mu = 0.025;
        sc.impairments = LinkImpairments {
            drop: DropModel::Iid(0.15),
            gating: Gating::EventTriggered(1e-6),
            quant_step: 1e-4,
            per_leg: false,
        };
        sc.runs = 7;
        sc.iters = 1234;
        sc.seed = 99;
        sc.record_every = 3;
        sc.threads = 2;
        sc.shards = 4;
        let text = sc.to_ini_string();
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        // And once more through the serializer (fixed point).
        assert_eq!(back.to_ini_string(), text);
    }

    #[test]
    fn roundtrip_every_algorithm_and_topology() {
        let algos = [
            AlgorithmSpec::DiffusionLms,
            AlgorithmSpec::Cd { m: 2 },
            AlgorithmSpec::Dcd { m: 2, m_grad: 2 },
            AlgorithmSpec::Rcd { m_links: 1 },
            AlgorithmSpec::Partial { m: 2 },
        ];
        let topos = [
            TopologySpec::Paper10,
            TopologySpec::Ring { n: 12, hops: 2 },
            TopologySpec::Geometric { n: 15, radius: 0.4 },
            TopologySpec::Grid { rows: 4, cols: 5 },
        ];
        for algo in &algos {
            for topo in &topos {
                let mut sc = Scenario::base("x", "");
                sc.algorithm = algo.clone();
                sc.topology = topo.clone();
                let back = Scenario::parse_str(&sc.to_ini_string()).unwrap();
                assert_eq!(back, sc, "{:?} / {:?}", algo, topo);
            }
        }
    }

    #[test]
    fn validator_rejects_disconnected_graph() {
        let mut sc = Scenario::base("disconnected", "");
        sc.topology = TopologySpec::Ring { n: 6, hops: 0 };
        let err = sc.validate().unwrap_err();
        assert!(err.contains("disconnected"), "{err}");
    }

    #[test]
    fn validator_rejects_bad_drop_prob() {
        let mut sc = Scenario::base("bad-drop", "");
        sc.impairments.drop = DropModel::Iid(1.5);
        let err = sc.validate().unwrap_err();
        assert!(err.contains("drop"), "{err}");
    }

    #[test]
    fn unknown_algorithm_is_an_error() {
        let err = Scenario::parse_str("[algorithm]\nname = quantum-lms\n").unwrap_err();
        assert!(err.contains("quantum-lms"), "{err}");
        let err = Scenario::parse_str("[topology]\nkind = torus\n").unwrap_err();
        assert!(err.contains("torus"), "{err}");
    }

    #[test]
    fn validator_rejects_bad_knobs() {
        let mut sc = Scenario::base("bad", "");
        sc.algorithm = AlgorithmSpec::Dcd { m: 9, m_grad: 1 }; // m > dim = 5
        assert!(sc.validate().is_err());
        let mut sc = Scenario::base("bad", "");
        sc.mu = 0.0;
        assert!(sc.validate().is_err());
        let mut sc = Scenario::base("bad name!", "");
        assert!(sc.validate().is_err());
        let mut sc = Scenario::base("bad", "");
        sc.runs = 0;
        assert!(sc.validate().is_err());
        let mut sc = Scenario::base("bad", "");
        sc.shards = 0;
        let err = sc.validate().unwrap_err();
        assert!(err.contains("shards"), "{err}");
    }

    #[test]
    fn defaults_parse_from_minimal_ini() {
        let sc = Scenario::parse_str("[scenario]\nname = tiny\n").unwrap();
        assert_eq!(sc.name, "tiny");
        assert_eq!(sc.topology, TopologySpec::Paper10);
        assert_eq!(sc.algorithm, AlgorithmSpec::Dcd { m: 3, m_grad: 1 });
        assert!(sc.impairments.is_ideal());
        assert!(sc.validate().is_ok());
    }

    #[test]
    fn key_whitelist_catches_typos() {
        assert!(Scenario::check_key("impairments.drop_prob").is_ok());
        assert!(Scenario::check_key("schedule.iters").is_ok());
        assert!(Scenario::check_key("impairments.dropprob").is_err());
        assert!(Scenario::check_key("bogus.key").is_err());
        assert!(Scenario::check_key("").is_err());
    }

    #[test]
    fn wsn_mode_roundtrips_and_validates() {
        let mut sc = Scenario::base("wsn-mode", "event-driven schedule");
        sc.mode = ScheduleMode::Wsn { duration: 12_345.0, sample_dt: 123.0 };
        sc.impairments.gating = Gating::EventTriggered(1e-4);
        let text = sc.to_ini_string();
        assert!(text.contains("mode = wsn"), "{text}");
        assert!(text.contains("[wsn]"), "{text}");
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        assert!(back.validate().is_ok());
        // Bad schedules are rejected.
        sc.mode = ScheduleMode::Wsn { duration: -1.0, sample_dt: 1.0 };
        assert!(sc.validate().is_err());
        sc.mode = ScheduleMode::Wsn { duration: 100.0, sample_dt: 500.0 };
        assert!(sc.validate().is_err());
        let err = Scenario::parse_str("[schedule]\nmode = warp\n").unwrap_err();
        assert!(err.contains("warp"), "{err}");
        // The rounds default round-trips too.
        let plain = Scenario::base("plain", "");
        assert_eq!(Scenario::parse_str(&plain.to_ini_string()).unwrap(), plain);
        assert!(Scenario::check_key("wsn.duration").is_ok());
        assert!(Scenario::check_key("schedule.mode").is_ok());
    }

    #[test]
    fn grid_topology_builds_and_validates() {
        let mut sc = Scenario::base("grid-check", "");
        sc.topology = TopologySpec::Grid { rows: 3, cols: 7 };
        assert_eq!(sc.topology.n_nodes(), 21);
        assert!(sc.validate().is_ok());
        let back = Scenario::parse_str(&sc.to_ini_string()).unwrap();
        assert_eq!(back, sc);
        let mut rng = Pcg64::new(1, 0);
        let g = sc.topology.build(&mut rng);
        assert_eq!(g.n(), 21);
        assert!(g.is_connected());
        // Degenerate lattices are rejected before Graph::grid runs.
        sc.topology = TopologySpec::Grid { rows: 1, cols: 1 };
        assert!(sc.validate().is_err());
        assert!(Scenario::check_key("topology.rows").is_ok());
        assert!(Scenario::check_key("topology.cols").is_ok());
    }

    #[test]
    fn theory_key_roundtrips_and_rejects_garbage() {
        for (mode, text) in [
            (TheoryColumn::Auto, "theory = auto"),
            (TheoryColumn::On, "theory = on"),
            (TheoryColumn::Off, "theory = off"),
        ] {
            let mut sc = Scenario::base("theory-mode", "");
            sc.theory = mode;
            let ini = sc.to_ini_string();
            assert!(ini.contains(text), "{ini}");
            assert_eq!(Scenario::parse_str(&ini).unwrap(), sc);
        }
        // Absent key ⇒ the legacy automatic behavior.
        let sc = Scenario::parse_str("[scenario]\nname = t\n").unwrap();
        assert_eq!(sc.theory, TheoryColumn::Auto);
        let err = Scenario::parse_str("[schedule]\ntheory = maybe\n").unwrap_err();
        assert!(err.contains("maybe"), "{err}");
        assert!(Scenario::check_key("schedule.theory").is_ok());
    }

    #[test]
    fn markov_drop_key_roundtrips_and_legacy_bytes_are_stable() {
        // Markov drop serializes via the structured key and survives the
        // parse -> serialize -> parse loop losslessly.
        let mut sc = Scenario::base("bursty", "");
        sc.impairments.drop = DropModel::Markov { p_bad: 0.3, p_gb: 0.2, p_bg: 0.25 };
        let text = sc.to_ini_string();
        assert!(text.contains("drop = markov:0.3,0.2,0.25"), "{text}");
        assert!(!text.contains("drop_prob"), "{text}");
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_ini_string(), text);
        assert!(sc.validate().is_ok());
        // An i.i.d. drop keeps the legacy scalar spelling, byte for byte.
        let mut sc = Scenario::base("iid", "");
        sc.impairments.drop = DropModel::Iid(0.2);
        let text = sc.to_ini_string();
        assert!(text.contains("drop_prob = 0.2"), "{text}");
        assert!(!text.contains("drop ="), "{text}");
        assert_eq!(Scenario::parse_str(&text).unwrap(), sc);
        // The structured key also accepts the prob: spelling, and wins
        // over a drop_prob in the same document.
        let sc = Scenario::parse_str(
            "[scenario]\nname = w\n\n[impairments]\ndrop_prob = 0.5\ndrop = prob:0.1\n",
        )
        .unwrap();
        assert_eq!(sc.impairments.drop, DropModel::Iid(0.1));
        // Malformed specs are parse errors, not silent defaults.
        assert!(Scenario::parse_str("[impairments]\ndrop = markov:0.3\n").is_err());
        // Out-of-range markov parameters are rejected by the validator.
        let mut sc = Scenario::base("bad-markov", "");
        sc.impairments.drop = DropModel::Markov { p_bad: 0.3, p_gb: 0.0, p_bg: 0.5 };
        assert!(sc.validate().is_err());
        assert!(Scenario::check_key("impairments.drop").is_ok());
    }

    #[test]
    fn dynamics_section_roundtrips_and_validates() {
        // Static dynamics emit no [dynamics] section at all — the
        // canonical bytes of every pre-existing scenario are unchanged.
        let plain = Scenario::base("plain", "");
        assert!(plain.dynamics.is_static());
        assert!(!plain.to_ini_string().contains("[dynamics]"));

        let mut sc = Scenario::base("dyn", "");
        sc.topology = TopologySpec::Geometric { n: 24, radius: 0.3 };
        sc.dynamics = DynamicsSpec {
            leave: 0.01,
            join: 0.2,
            require_connected: true,
            rewire: 0.05,
            rewire_period: 250,
            drift: DriftModel::Walk { sigma: 2e-3 },
            adaptive: AdaptivePolicy::Metropolis,
        };
        let text = sc.to_ini_string();
        assert!(text.contains("[dynamics]"), "{text}");
        assert!(text.contains("drift = walk:0.002"), "{text}");
        assert!(text.contains("adaptive = metropolis"), "{text}");
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_ini_string(), text);
        assert!(sc.validate().is_ok());

        // Even a non-running knob (rewire_period with rewire = 0) must
        // survive the round-trip: serialization keys off != default, not
        // is_static().
        let mut sc = Scenario::base("period-only", "");
        sc.dynamics.rewire_period = 7;
        let back = Scenario::parse_str(&sc.to_ini_string()).unwrap();
        assert_eq!(back, sc);

        // Cross-checks: mobility needs a geometric topology, rotation
        // needs a plane, and the WSN engine has no dynamics support.
        let mut sc = Scenario::base("bad-rewire", "");
        sc.dynamics.rewire = 0.1;
        let err = sc.validate().unwrap_err();
        assert!(err.contains("geometric"), "{err}");
        let mut sc = Scenario::base("bad-rotate", "");
        sc.dim = 1;
        sc.algorithm = AlgorithmSpec::DiffusionLms;
        sc.dynamics.drift = DriftModel::Rotate { omega: 0.02 };
        let err = sc.validate().unwrap_err();
        assert!(err.contains("dim >= 2"), "{err}");
        let mut sc = Scenario::base("bad-wsn-dyn", "");
        sc.mode = ScheduleMode::Wsn { duration: 1000.0, sample_dt: 10.0 };
        sc.dynamics.leave = 0.01;
        sc.dynamics.join = 0.5;
        let err = sc.validate().unwrap_err();
        assert!(err.contains("rounds"), "{err}");
        let mut sc = Scenario::base("bad-leave", "");
        sc.dynamics.leave = 1.5;
        assert!(sc.validate().is_err());
        for key in [
            "dynamics.leave",
            "dynamics.join",
            "dynamics.require_connected",
            "dynamics.rewire",
            "dynamics.rewire_period",
            "dynamics.drift",
            "dynamics.adaptive",
        ] {
            assert!(Scenario::check_key(key).is_ok(), "{key}");
        }
    }

    #[test]
    fn per_leg_key_roundtrips_and_legacy_bytes_are_stable() {
        // Default (shared-leg) specs emit no per_leg key at all — every
        // pre-existing canonical INI keeps its bytes.
        let plain = Scenario::base("plain", "");
        assert!(!plain.to_ini_string().contains("per_leg"));

        let mut sc = Scenario::base("legs", "");
        sc.impairments.per_leg = true;
        let text = sc.to_ini_string();
        assert!(text.contains("per_leg = true"), "{text}");
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_ini_string(), text);
        assert!(sc.validate().is_ok());
        assert!(Scenario::check_key("impairments.per_leg").is_ok());

        // The WSN engine has no reply-leg draw: per_leg is rejected
        // under mode = wsn.
        sc.mode = ScheduleMode::Wsn { duration: 1000.0, sample_dt: 10.0 };
        let err = sc.validate().unwrap_err();
        assert!(err.contains("per_leg"), "{err}");
        assert!(err.contains("rounds"), "{err}");
    }

    #[test]
    fn energy_section_roundtrips_and_validates() {
        // Zero radio (the default) emits no [energy] section.
        let plain = Scenario::base("plain", "");
        assert!(plain.radio.is_zero());
        assert!(!plain.to_ini_string().contains("[energy]"));

        let mut sc = Scenario::base("priced", "");
        sc.mode = ScheduleMode::Wsn { duration: 10_000.0, sample_dt: 100.0 };
        sc.radio = RadioEnergy { tx_j_per_bit: 5e-8, rx_j_per_bit: 2e-8 };
        let text = sc.to_ini_string();
        assert!(text.contains("[energy]"), "{text}");
        assert!(text.contains("tx_j_per_bit = 0.00000005"), "{text}");
        let back = Scenario::parse_str(&text).unwrap();
        assert_eq!(back, sc);
        assert_eq!(back.to_ini_string(), text);
        assert!(sc.validate().is_ok());
        for key in ["energy.tx_j_per_bit", "energy.rx_j_per_bit"] {
            assert!(Scenario::check_key(key).is_ok(), "{key}");
        }

        // A radio price without a charge state is meaningless: rejected
        // under the round schedule.
        sc.mode = ScheduleMode::Rounds;
        let err = sc.validate().unwrap_err();
        assert!(err.contains("wsn"), "{err}");
        // Negative / non-finite rates are rejected.
        sc.mode = ScheduleMode::Wsn { duration: 10_000.0, sample_dt: 100.0 };
        sc.radio.rx_j_per_bit = -1.0;
        let err = sc.validate().unwrap_err();
        assert!(err.contains("rx_j_per_bit"), "{err}");
    }

    #[test]
    fn lanes_key_roundtrips_and_legacy_bytes_are_stable() {
        // The default (scalar) width emits no lanes key at all — every
        // pre-existing canonical INI keeps its bytes.
        let plain = Scenario::base("plain", "");
        assert_eq!(plain.lanes, LaneCount::Fixed(1));
        assert!(!plain.to_ini_string().contains("lanes"));

        for (lanes, text) in [(LaneCount::Auto, "lanes = auto"), (LaneCount::Fixed(4), "lanes = 4")]
        {
            let mut sc = Scenario::base("laned", "");
            sc.lanes = lanes;
            let ini = sc.to_ini_string();
            assert!(ini.contains(text), "{ini}");
            let back = Scenario::parse_str(&ini).unwrap();
            assert_eq!(back, sc);
            assert_eq!(back.to_ini_string(), ini);
            assert!(sc.validate().is_ok());
        }
        assert!(Scenario::check_key("schedule.lanes").is_ok());

        // Zero lanes are rejected at parse time (shards error style) and
        // by the validator for programmatically built scenarios.
        let err = Scenario::parse_str("[schedule]\nlanes = 0\n").unwrap_err();
        assert!(err.contains("lanes 0"), "{err}");
        assert!(Scenario::parse_str("[schedule]\nlanes = -3\n").is_err());
        assert!(Scenario::parse_str("[schedule]\nlanes = 99999999999999999999\n").is_err());
        let mut sc = Scenario::base("bad-lanes", "");
        sc.lanes = LaneCount::Fixed(0);
        let err = sc.validate().unwrap_err();
        assert!(err.contains("lanes"), "{err}");

        // The WSN engine is not run-batched: lanes != 1 is rejected.
        let mut sc = Scenario::base("wsn-lanes", "");
        sc.mode = ScheduleMode::Wsn { duration: 1000.0, sample_dt: 10.0 };
        sc.lanes = LaneCount::Fixed(4);
        let err = sc.validate().unwrap_err();
        assert!(err.contains("lanes"), "{err}");
        assert!(err.contains("rounds"), "{err}");
    }

    #[test]
    fn effective_record_every_auto() {
        let mut sc = Scenario::base("x", "");
        sc.iters = 40_000;
        sc.record_every = 0;
        assert_eq!(sc.effective_record_every(), 20);
        sc.iters = 500;
        assert_eq!(sc.effective_record_every(), 1);
        sc.record_every = 7;
        assert_eq!(sc.effective_record_every(), 7);
    }
}
