//! The built-in scenario registry: named, reproducible presets covering
//! the paper's settings plus the impaired/asynchronous regimes the
//! follow-up literature studies (see DESIGN.md §4 for the axes).

use crate::coordinator::impairments::{AdaptivePolicy, DropModel, Gating, LinkImpairments};
use crate::datamodel::DriftModel;
use crate::energy::RadioEnergy;
use crate::topology::Rule;

use super::spec::{AlgorithmSpec, DynamicsSpec, Scenario, ScheduleMode, TopologySpec};

/// All built-in scenarios, in display order.
pub fn builtins() -> Vec<Scenario> {
    vec![
        paper_10_node(),
        fifty_node_sweep(),
        wsn_80(),
        lossy_geometric(),
        per_leg_lossy(),
        priced_wsn(),
        event_triggered_ring(),
        quantized_dense(),
        mega_grid(),
        bursty_geometric(),
        churn_grid(),
        tracking_ring(),
    ]
}

/// Look a built-in up by name.
pub fn find(name: &str) -> Option<Scenario> {
    builtins().into_iter().find(|sc| sc.name == name)
}

/// Experiment 1's DCD setting as a scenario: with ideal links this
/// reproduces the `exp1` dcd trajectory bit-for-bit (tested in
/// `rust/tests/scenario.rs`).
fn paper_10_node() -> Scenario {
    let mut sc = Scenario::base(
        "paper-10-node",
        "Fig. 3 left DCD setting: 10-node paper network, L=5, M=3, Mgrad=1",
    );
    sc.topology = TopologySpec::Paper10;
    sc.combine_rule = Rule::Identity; // exp1 runs A = I
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 5;
    sc.u2_min = 0.8;
    sc.u2_max = 1.2;
    sc.sigma_v2 = 1e-3;
    sc.algorithm = AlgorithmSpec::Dcd { m: 3, m_grad: 1 };
    sc.mu = 1e-3;
    sc.runs = 100;
    sc.iters = 40_000;
    sc.seed = 2017;
    sc
}

/// Experiment 2's 50-node network, sized for `scenario sweep` over the
/// impairment or compression axes.
fn fifty_node_sweep() -> Scenario {
    let mut sc = Scenario::base(
        "fifty-node-sweep",
        "Exp-2-style N=50 L=50 network, sized for sweeps over drop_prob or m",
    );
    sc.topology = TopologySpec::Geometric { n: 50, radius: 0.25 };
    sc.combine_rule = Rule::Identity; // exp2 runs A = I
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 50;
    sc.u2_min = 0.4;
    sc.u2_max = 0.8;
    sc.sigma_v2 = 1e-3;
    sc.algorithm = AlgorithmSpec::Dcd { m: 5, m_grad: 5 };
    sc.mu = 3e-2;
    sc.runs = 10;
    sc.iters = 4_000;
    sc.seed = 2018;
    sc
}

/// The Experiment 3 hillside WSN on the event-driven scheduler: nodes
/// duty-cycle on the ENO energy model and gate on charge *and* events
/// (`event:δ` change detection), with a lightly lossy radio — the
/// ROADMAP's "impairments through the WSN scheduler" scenario
/// (DESIGN.md §9). The exact per-node billed bits land in the run's
/// ledger artifacts.
fn wsn_80() -> Scenario {
    let mut sc = Scenario::base(
        "wsn-80",
        "80-node energy-harvesting WSN, L=40, DCD at ratio 20, event-gated lossy radio",
    );
    sc.topology = TopologySpec::Geometric { n: 80, radius: 0.18 };
    sc.combine_rule = Rule::Metropolis;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 40;
    sc.u2_min = 0.8;
    sc.u2_max = 1.2;
    sc.sigma_v2 = 1e-3;
    sc.algorithm = AlgorithmSpec::Dcd { m: 3, m_grad: 1 };
    sc.mu = 6e-3;
    sc.impairments = LinkImpairments {
        drop: DropModel::Iid(0.05),
        gating: Gating::EventTriggered(1e-4),
        quant_step: 0.0,
        per_leg: false,
    };
    sc.runs = 4;
    sc.iters = 6_000; // unused under mode = wsn (virtual time rules)
    sc.seed = 2019;
    sc.mode = ScheduleMode::Wsn { duration: 200_000.0, sample_dt: 2_000.0 };
    sc
}

/// An ad-hoc network with unreliable links: every directed link erases
/// 20 % of its frames (receiver-side fallback per eqs. (11)-(12)).
/// Runs in the analysis setting `A = I` (like exp1/exp2) so the
/// impaired-link theory (DESIGN.md §7) anchors it: the steady-state
/// prediction must match the Monte-Carlo estimate within 1 dB
/// (`rust/tests/theory_impaired.rs`).
fn lossy_geometric() -> Scenario {
    let mut sc = Scenario::base(
        "lossy-geometric",
        "30-node geometric network where every link drops 20% of its frames (theory-anchored)",
    );
    sc.topology = TopologySpec::Geometric { n: 30, radius: 0.25 };
    sc.combine_rule = Rule::Identity; // the §III/§7 analysis setting A = I
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 8;
    sc.algorithm = AlgorithmSpec::Dcd { m: 3, m_grad: 1 };
    // Small enough for the small-step-size analysis (83) to be sharp
    // (the regime the ideal theory-vs-sim tests validate), large enough
    // to converge well inside the 3000-iteration schedule.
    sc.mu = 5e-3;
    sc.impairments = LinkImpairments {
        drop: DropModel::Iid(0.2),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 11;
    sc
}

/// `lossy-geometric` with the shared request/reply erasure split into
/// independent per-leg events (DESIGN.md §13): the request and the
/// solicited reply each face their own Bernoulli draw, so a combination
/// entry survives with probability (1−p)² instead of (1−p) — §7
/// assumption 6 made physical. Still theory-anchored: the impaired
/// model squares the keep probability along with the scheduler.
fn per_leg_lossy() -> Scenario {
    let mut sc = lossy_geometric();
    sc.name = "per-leg-lossy".into();
    sc.description = "lossy-geometric with independent request/reply erasure legs \
                      (keep prob squared, theory-anchored)"
        .into();
    sc.impairments.per_leg = true;
    sc
}

/// A small energy-harvesting WSN whose radio is **priced** (DESIGN.md
/// §13): every billed bit debits the activating node's charge at
/// datasheet-scale per-bit costs, so compression policies feed back
/// into the ENO duty cycle — the base preset of the `frontier` driver
/// and the CI `frontier-smoke` job.
fn priced_wsn() -> Scenario {
    let mut sc = Scenario::base(
        "priced-wsn",
        "16-node harvesting WSN with a priced radio (50/20 nJ per bit), DCD at ratio 5.3",
    );
    sc.topology = TopologySpec::Ring { n: 16, hops: 2 };
    sc.combine_rule = Rule::Metropolis;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 8;
    sc.u2_min = 0.8;
    sc.u2_max = 1.2;
    sc.sigma_v2 = 1e-3;
    sc.algorithm = AlgorithmSpec::Dcd { m: 2, m_grad: 1 };
    sc.mu = 1e-2;
    sc.radio = RadioEnergy { tx_j_per_bit: 5e-8, rx_j_per_bit: 2e-8 };
    sc.runs = 4;
    sc.iters = 6_000; // unused under mode = wsn (virtual time rules)
    sc.seed = 2020;
    sc.mode = ScheduleMode::Wsn { duration: 40_000.0, sample_dt: 1_000.0 };
    sc
}

/// Event-based diffusion (arXiv:1803.00368): nodes broadcast only while
/// their estimate is still moving, so traffic fades out as the network
/// converges.
fn event_triggered_ring() -> Scenario {
    let mut sc = Scenario::base(
        "event-triggered-ring",
        "20-node ring running diffusion LMS, transmitting only when the estimate moved",
    );
    sc.topology = TopologySpec::Ring { n: 20, hops: 2 };
    sc.dim = 6;
    sc.algorithm = AlgorithmSpec::DiffusionLms;
    sc.mu = 2e-2;
    sc.impairments = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::EventTriggered(1e-6),
        quant_step: 0.0,
        per_leg: false,
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 5;
    sc
}

/// Finite-precision motes on a dense ring: every stored (hence every
/// exchanged) scalar lives on a 1e-3 grid.
fn quantized_dense() -> Scenario {
    let mut sc = Scenario::base(
        "quantized-dense",
        "16-node dense ring with estimates kept on a 1e-3 quantization grid",
    );
    sc.topology = TopologySpec::Ring { n: 16, hops: 4 };
    sc.dim = 8;
    sc.algorithm = AlgorithmSpec::Dcd { m: 4, m_grad: 2 };
    sc.mu = 2e-2;
    sc.impairments = LinkImpairments {
        drop: DropModel::none(),
        gating: Gating::Always,
        quant_step: 1e-3,
        per_leg: false,
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 3;
    sc
}

/// The sparse-path stress preset (DESIGN.md §10): a 320 x 320 lattice —
/// 102 400 nodes, 204 160 undirected links — that only exists because
/// every per-iteration structure (combiners, effective-matrix rebuild,
/// ledger) is CSR / O(E). Bounded degree keeps the per-iteration cost at
/// ~N·L + E·L flops, so a short schedule completes in seconds in release
/// mode; the lossy links exercise the in-place impairment rebuild at
/// full scale. N·L = 409 600 is far beyond the theory cap, so the run
/// carries no theory column.
fn mega_grid() -> Scenario {
    let mut sc = Scenario::base(
        "mega-grid",
        "320x320 lattice (102400 nodes) on the CSR fast path, lossy links, DCD at ratio 4",
    );
    sc.topology = TopologySpec::Grid { rows: 320, cols: 320 };
    sc.combine_rule = Rule::Metropolis;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 4;
    sc.u2_min = 0.8;
    sc.u2_max = 1.2;
    sc.sigma_v2 = 1e-3;
    sc.algorithm = AlgorithmSpec::Dcd { m: 2, m_grad: 1 };
    sc.mu = 1e-2;
    sc.impairments = LinkImpairments {
        drop: DropModel::Iid(0.05),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    sc.runs = 2;
    sc.iters = 100;
    sc.seed = 2025;
    sc.shards = 2; // exercises the sharded runner by default
    sc
}

/// Bursty (Gilbert–Elliott) link erasures (DESIGN.md §12): the same
/// 20 % stationary loss as `lossy-geometric`, but correlated into mean
/// bursts of 5 samples (π_B = p_gb·p_bad / (p_gb·p_bad + p_bg·(1−p_bad))
/// = 0.2, mean burst 1 / (p_bg·(1−p_bad)) = 5). The statistical
/// harness (`rust/tests/dynamics.rs`) pins both moments against the
/// run's occupancy counters. The chain has memory, so the run carries
/// no closed-form theory column.
fn bursty_geometric() -> Scenario {
    let mut sc = Scenario::base(
        "bursty-geometric",
        "30-node geometric network with Gilbert-Elliott bursty erasures (pi_B=0.2, mean burst 5)",
    );
    sc.topology = TopologySpec::Geometric { n: 30, radius: 0.25 };
    sc.combine_rule = Rule::Identity;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 8;
    sc.algorithm = AlgorithmSpec::Dcd { m: 3, m_grad: 1 };
    sc.mu = 5e-3;
    sc.impairments = LinkImpairments {
        drop: DropModel::Markov { p_bad: 0.2, p_gb: 0.25, p_bg: 0.25 },
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 12;
    sc
}

/// Node churn on a lattice (DESIGN.md §12): nodes leave and rejoin at
/// random while the connectivity veto keeps the active subgraph in one
/// piece, and the Metropolis adaptive policy re-weights combiners
/// around links the ledger observes as lossy.
fn churn_grid() -> Scenario {
    let mut sc = Scenario::base(
        "churn-grid",
        "12x12 lattice with node churn (connectivity-vetoed) and adaptive Metropolis combiners",
    );
    sc.topology = TopologySpec::Grid { rows: 12, cols: 12 };
    sc.combine_rule = Rule::Metropolis;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 4;
    sc.algorithm = AlgorithmSpec::Dcd { m: 2, m_grad: 1 };
    sc.mu = 1e-2;
    sc.impairments = LinkImpairments {
        drop: DropModel::Iid(0.1),
        gating: Gating::Always,
        quant_step: 0.0,
        per_leg: false,
    };
    sc.dynamics = DynamicsSpec {
        leave: 0.002,
        join: 0.05,
        require_connected: true,
        adaptive: AdaptivePolicy::Metropolis,
        ..DynamicsSpec::default()
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 21;
    sc
}

/// A drifting optimum w°(i) (DESIGN.md §12): the random walk keeps the
/// network in perpetual pursuit, so the MSD floors at the tracking
/// error instead of the static steady state — the classic
/// tracking-analysis setting (EXPERIMENTS.md worked example).
fn tracking_ring() -> Scenario {
    let mut sc = Scenario::base(
        "tracking-ring",
        "20-node ring chasing a random-walk optimum (sigma=2e-3 per step)",
    );
    sc.topology = TopologySpec::Ring { n: 20, hops: 2 };
    sc.combine_rule = Rule::Metropolis;
    sc.adapt_rule = Rule::Metropolis;
    sc.dim = 6;
    sc.algorithm = AlgorithmSpec::DiffusionLms;
    sc.mu = 5e-2; // a tracker needs a fast step size
    sc.dynamics = DynamicsSpec {
        drift: DriftModel::Walk { sigma: 2e-3 },
        ..DynamicsSpec::default()
    };
    sc.runs = 10;
    sc.iters = 3_000;
    sc.seed = 7;
    sc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_has_at_least_six_valid_scenarios() {
        let all = builtins();
        assert!(all.len() >= 10, "only {} built-ins", all.len());
        for sc in &all {
            sc.validate().unwrap_or_else(|e| panic!("{}: {e}", sc.name));
        }
        // Names are unique (they name result files).
        let mut names: Vec<&str> = all.iter().map(|s| s.name.as_str()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), all.len());
    }

    #[test]
    fn every_builtin_roundtrips_through_ini() {
        for sc in builtins() {
            let back = Scenario::parse_str(&sc.to_ini_string()).unwrap();
            assert_eq!(back, sc, "{}", sc.name);
        }
    }

    #[test]
    fn find_by_name() {
        assert!(find("lossy-geometric").is_some());
        assert!(find("paper-10-node").is_some());
        assert!(find("no-such-scenario").is_none());
    }

    #[test]
    fn dynamic_presets_state_their_axes() {
        let bursty = find("bursty-geometric").unwrap();
        let DropModel::Markov { p_bad, p_gb, p_bg } = bursty.impairments.drop else {
            panic!("bursty-geometric must use a markov drop model");
        };
        // Stationary Bad occupancy 0.2, mean burst 5 — the closed forms
        // the statistical harness pins.
        let pi_b = p_gb * p_bad / (p_gb * p_bad + p_bg * (1.0 - p_bad));
        assert!((pi_b - 0.2).abs() < 1e-12, "pi_B = {pi_b}");
        let mean_burst = 1.0 / (p_bg * (1.0 - p_bad));
        assert!((mean_burst - 5.0).abs() < 1e-12, "mean burst = {mean_burst}");

        let churn = find("churn-grid").unwrap();
        assert!(churn.dynamics.leave > 0.0 && churn.dynamics.require_connected);
        assert_eq!(churn.dynamics.adaptive, AdaptivePolicy::Metropolis);
        assert!(!churn.dynamics.network_static());

        let tracking = find("tracking-ring").unwrap();
        assert!(matches!(tracking.dynamics.drift, DriftModel::Walk { sigma } if sigma > 0.0));
        assert!(tracking.dynamics.network_static() && !tracking.dynamics.is_static());
    }

    #[test]
    fn energy_loop_presets_state_their_axes() {
        // Validated cross-checks (DESIGN.md §13): per-leg erasures need
        // the round scheduler, a priced radio needs the WSN charge state.
        let pl = find("per-leg-lossy").unwrap();
        assert!(pl.impairments.per_leg);
        assert!(matches!(pl.mode, ScheduleMode::Rounds));
        let pw = find("priced-wsn").unwrap();
        assert!(!pw.radio.is_zero());
        assert!(matches!(pw.mode, ScheduleMode::Wsn { .. }));
    }

    #[test]
    fn paper_scenario_matches_exp1_preset() {
        let sc = find("paper-10-node").unwrap();
        let e1 = crate::config::Exp1Config::default();
        assert_eq!(sc.dim, e1.dim);
        assert_eq!(sc.mu, e1.mu);
        assert_eq!(sc.runs, e1.runs);
        assert_eq!(sc.iters, e1.iters);
        assert_eq!(sc.seed, e1.seed);
        assert_eq!(sc.algorithm, AlgorithmSpec::Dcd { m: e1.m, m_grad: e1.m_grad });
    }
}
