//! The comm-cost-vs-MSD Pareto frontier driver (DESIGN.md §13).
//!
//! The paper's whole argument is a trade-off: every compression policy
//! (partial-update masks, event gating, quantization) buys transmitted
//! bits with steady-state MSD. [`frontier_scenario`] maps that
//! trade-off for one scenario: it takes a list of policy **axes**
//! (dotted scenario keys, each with a value list — gating probability,
//! quantizer step, DCD mask sizes, compressive-projection dimension),
//! runs every point of the cartesian grid through the same INI-override
//! layer `scenario sweep` uses, and marks the points no other point
//! dominates.
//!
//! A point dominates another when it is no worse on **both** objectives
//! — mean billed bits per realization (DESIGN.md §9) and steady-state
//! MSD in dB — and strictly better on at least one. The surviving
//! points are the empirical Pareto front, the artifact the ROADMAP's
//! "Pareto frontier" item asks for.
//!
//! Determinism contract: every point runs on the deterministic
//! Monte-Carlo runner (bit-identical at any `--threads`/`--shards`
//! setting, §8), points are visited in cartesian order (first axis
//! outermost), and [`pareto_front`] breaks ties by input index — so
//! `results/frontier_<name>.{csv,json}` are byte-identical however the
//! work was spread. The CI `frontier-smoke` job holds this pinned.

use crate::config::IniDoc;
use crate::jsonio::{obj, Json};

use super::run::run_scenario;
use super::spec::Scenario;

/// One swept policy axis: a dotted scenario key and its value list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FrontierAxis {
    /// Dotted scenario key (validated against `Scenario::known_keys`).
    pub key: String,
    /// Values to sweep, as INI value strings, in sweep order.
    pub values: Vec<String>,
}

impl FrontierAxis {
    /// Parse an `--axis` argument: `dotted.key=v1,v2,...`.
    pub fn parse(spec: &str) -> Result<Self, String> {
        let (key, list) = spec
            .split_once('=')
            .ok_or_else(|| format!("frontier axis {spec:?}: expected dotted.key=v1,v2,..."))?;
        let key = key.trim();
        Scenario::check_key(key)?;
        let values: Vec<String> = list
            .split(',')
            .map(|v| v.trim().to_string())
            .filter(|v| !v.is_empty())
            .collect();
        if values.is_empty() {
            return Err(format!("frontier axis {spec:?}: empty value list"));
        }
        Ok(FrontierAxis { key: key.to_string(), values })
    }
}

/// One evaluated grid point.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    /// The `(key, value)` overrides this point applied, in axis order.
    pub settings: Vec<(String, String)>,
    /// Steady-state MSD (dB, trailing 10 % of the mean trace).
    pub steady_db: f64,
    /// Mean billed payload bits per realization (DESIGN.md §9).
    pub bits_per_run: f64,
    /// Mean scalars transmitted per realization.
    pub scalars_per_run: f64,
    /// Total radio joules across nodes and realizations (0 unless the
    /// scenario prices the radio; DESIGN.md §13).
    pub radio_joules: f64,
    /// Whether the point survived Pareto pruning.
    pub pareto: bool,
}

/// Everything one frontier mapping produces.
#[derive(Debug, Clone)]
pub struct FrontierOutput {
    /// Every grid point in cartesian order (first axis outermost),
    /// each flagged with its Pareto verdict.
    pub points: Vec<FrontierPoint>,
}

impl FrontierOutput {
    /// The dominated-point-pruned front, in cartesian order.
    pub fn pareto_points(&self) -> Vec<&FrontierPoint> {
        self.points.iter().filter(|p| p.pareto).collect()
    }
}

/// Default policy axes for a scenario with no explicit `--axis` list:
/// the transmit-gating probability and the quantizer step (the two
/// knobs every algorithm in the registry has), plus the DCD estimate
/// mask size M — the compressive-projection dimension — when the base
/// algorithm is DCD with room to shrink it.
pub fn default_axes(sc: &Scenario) -> Vec<FrontierAxis> {
    let mut axes = vec![
        FrontierAxis {
            key: "impairments.gating".into(),
            values: vec!["always".into(), "prob:0.5".into(), "prob:0.25".into()],
        },
        FrontierAxis {
            key: "impairments.quant_step".into(),
            values: vec!["0".into(), "0.001".into(), "0.01".into()],
        },
    ];
    if let super::spec::AlgorithmSpec::Dcd { m, .. } = sc.algorithm {
        if m > 1 {
            axes.push(FrontierAxis {
                key: "algorithm.m".into(),
                values: vec![format!("{m}"), format!("{}", (m / 2).max(1))],
            });
        }
    }
    axes
}

/// Mark the Pareto-optimal points of a 2-D minimization: input
/// `(bits, msd_db)` pairs, output one keep-flag per point. A point is
/// kept iff no other point is ≤ on both coordinates and < on at least
/// one; exact duplicates are all kept (neither dominates). Sort-sweep,
/// O(n log n), fully deterministic (ties broken by input index).
/// Points with a non-finite MSD (divergent runs) are never kept.
pub fn pareto_front(points: &[(f64, f64)]) -> Vec<bool> {
    let mut idx: Vec<usize> = (0..points.len()).collect();
    idx.sort_by(|&a, &b| {
        points[a]
            .0
            .total_cmp(&points[b].0)
            .then(points[a].1.total_cmp(&points[b].1))
            .then(a.cmp(&b))
    });
    let mut keep = vec![false; points.len()];
    // Sweeping in ascending-bits order, a point survives iff it strictly
    // improves the best MSD seen so far — or exactly repeats the point
    // that set it (a duplicate, which nothing strictly dominates).
    let mut best_msd = f64::INFINITY;
    let mut best_bits = f64::INFINITY;
    for &i in &idx {
        let (bits, msd) = points[i];
        if !msd.is_finite() {
            continue;
        }
        if msd < best_msd {
            best_msd = msd;
            best_bits = bits;
            keep[i] = true;
        } else if msd == best_msd && bits == best_bits {
            keep[i] = true;
        }
    }
    keep
}

/// Map the frontier of `base` over `axes`: run every cartesian grid
/// point through the INI-override layer on the (sharded) runner, prune
/// dominated points, and — with `out_dir` set — write
/// `<out_dir>/frontier_<name>.csv` (one row per point, Pareto flag
/// last) and `<out_dir>/frontier_<name>.json` (the same table plus the
/// pruned front).
pub fn frontier_scenario(
    base: &Scenario,
    axes: &[FrontierAxis],
    out_dir: Option<&str>,
    quiet: bool,
) -> Result<FrontierOutput, String> {
    if axes.is_empty() {
        return Err("frontier: no axes (give --axis or use a registry scenario)".into());
    }
    for axis in axes {
        Scenario::check_key(&axis.key)?;
        if axis.values.is_empty() {
            return Err(format!("frontier axis {:?}: empty value list", axis.key));
        }
    }
    let total: usize = axes.iter().map(|a| a.values.len()).product();

    let mut points = Vec::with_capacity(total);
    // Cartesian order, first axis outermost: point p selects value
    // (p / stride_i) % len_i on axis i — the row order of the CSV.
    for p in 0..total {
        let mut settings = Vec::with_capacity(axes.len());
        let mut stride = total;
        for axis in axes {
            stride /= axis.values.len();
            let value = &axis.values[(p / stride) % axis.values.len()];
            settings.push((axis.key.clone(), value.clone()));
        }
        let mut doc = IniDoc::parse(&base.to_ini_string())?;
        for (key, value) in &settings {
            doc.set_dotted(&format!("{key}={value}"))?;
        }
        let sc = Scenario::from_ini(&doc)?;
        let out = run_scenario(&sc, None, true)?;
        let bits_per_run = out.ledger.bits() as f64 / sc.runs as f64;
        let radio_joules: f64 = out.radio_joules.iter().sum();
        if !quiet {
            let label: Vec<String> =
                settings.iter().map(|(k, v)| format!("{k}={v}")).collect();
            println!(
                "frontier {:<18} [{}/{total}] {}  steady-state {:7.2} dB  bits/run {:.0}",
                base.name,
                p + 1,
                label.join(" "),
                out.steady_db,
                bits_per_run
            );
        }
        points.push(FrontierPoint {
            settings,
            steady_db: out.steady_db,
            bits_per_run,
            scalars_per_run: out.scalars_per_run,
            radio_joules,
            pareto: false,
        });
    }

    let objectives: Vec<(f64, f64)> =
        points.iter().map(|p| (p.bits_per_run, p.steady_db)).collect();
    for (point, keep) in points.iter_mut().zip(pareto_front(&objectives)) {
        point.pareto = keep;
    }
    let front = points.iter().filter(|p| p.pareto).count();
    if !quiet {
        println!(
            "frontier {}: {front} of {} points on the Pareto front",
            base.name,
            points.len()
        );
    }

    if let Some(dir) = out_dir {
        std::fs::create_dir_all(dir).map_err(|e| e.to_string())?;
        let csv_path = format!("{dir}/frontier_{}.csv", base.name);
        std::fs::write(&csv_path, frontier_csv(axes, &points)).map_err(|e| e.to_string())?;
        let json_path = format!("{dir}/frontier_{}.json", base.name);
        std::fs::write(&json_path, frontier_json(base, axes, &points).to_string_pretty())
            .map_err(|e| e.to_string())?;
        if !quiet {
            println!("frontier {}: wrote {csv_path} and {json_path}", base.name);
        }
    }
    Ok(FrontierOutput { points })
}

/// The frontier table as CSV text: one column per axis key, then the
/// two objectives, the auxiliary counters, and the Pareto flag. Floats
/// print through the shortest-round-trip formatter, so the bytes are a
/// pure function of the (bit-identical) run results.
fn frontier_csv(axes: &[FrontierAxis], points: &[FrontierPoint]) -> String {
    let mut s = String::new();
    for axis in axes {
        s.push_str(&axis.key);
        s.push(',');
    }
    s.push_str("steady_db,bits_per_run,scalars_per_run,radio_joules,pareto\n");
    for p in points {
        for (_, value) in &p.settings {
            s.push_str(&value.replace(',', ";"));
            s.push(',');
        }
        s.push_str(&format!(
            "{},{},{},{},{}\n",
            p.steady_db,
            p.bits_per_run,
            p.scalars_per_run,
            p.radio_joules,
            u8::from(p.pareto)
        ));
    }
    s
}

/// The frontier artifact as JSON: scenario name, the axes, every point
/// (with its Pareto verdict), and the pruned front size.
fn frontier_json(base: &Scenario, axes: &[FrontierAxis], points: &[FrontierPoint]) -> Json {
    let axes_json = Json::Arr(
        axes.iter()
            .map(|a| {
                obj(vec![
                    ("key", Json::Str(a.key.clone())),
                    (
                        "values",
                        Json::Arr(a.values.iter().map(|v| Json::Str(v.clone())).collect()),
                    ),
                ])
            })
            .collect(),
    );
    let points_json = Json::Arr(
        points
            .iter()
            .map(|p| {
                let settings = Json::Arr(
                    p.settings
                        .iter()
                        .map(|(k, v)| {
                            Json::Arr(vec![Json::Str(k.clone()), Json::Str(v.clone())])
                        })
                        .collect(),
                );
                obj(vec![
                    ("settings", settings),
                    ("steady_db", Json::Num(p.steady_db)),
                    ("bits_per_run", Json::Num(p.bits_per_run)),
                    ("scalars_per_run", Json::Num(p.scalars_per_run)),
                    ("radio_joules", Json::Num(p.radio_joules)),
                    ("pareto", Json::Bool(p.pareto)),
                ])
            })
            .collect(),
    );
    obj(vec![
        ("title", Json::Str(format!("frontier {}", base.name))),
        ("scenario", Json::Str(base.name.clone())),
        ("axes", axes_json),
        ("points", points_json),
        (
            "pareto_size",
            Json::Num(points.iter().filter(|p| p.pareto).count() as f64),
        ),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pareto_front_keeps_exactly_the_undominated_points() {
        // (bits, msd): b dominates d; c dominates nothing and survives
        // (cheapest); e is a duplicate of b — both stay.
        let pts = [
            (100.0, -30.0), // a: most bits, best msd — on the front
            (50.0, -20.0),  // b
            (10.0, -10.0),  // c: fewest bits — on the front
            (60.0, -19.0),  // d: dominated by b (more bits, worse msd)
            (50.0, -20.0),  // e: duplicate of b
        ];
        assert_eq!(pareto_front(&pts), vec![true, true, true, false, true]);
    }

    #[test]
    fn pareto_front_drops_equal_bits_worse_msd_and_nonfinite() {
        let pts = [
            (10.0, -5.0),
            (10.0, -4.0), // same bits, strictly worse msd
            (5.0, f64::NAN),
            (5.0, f64::INFINITY),
        ];
        assert_eq!(pareto_front(&pts), vec![true, false, false, false]);
        // Every point dominated except one ⇒ front of one.
        assert_eq!(pareto_front(&[(1.0, -1.0)]), vec![true]);
        assert_eq!(pareto_front(&[]), Vec::<bool>::new());
    }

    #[test]
    fn axis_parse_validates_keys_and_values() {
        let axis = FrontierAxis::parse("impairments.gating=always, prob:0.5").unwrap();
        assert_eq!(axis.key, "impairments.gating");
        assert_eq!(axis.values, vec!["always".to_string(), "prob:0.5".to_string()]);
        assert!(FrontierAxis::parse("no-equals").is_err());
        assert!(FrontierAxis::parse("impairments.gating=").is_err());
        assert!(FrontierAxis::parse("not.a.key=1,2").is_err());
    }

    #[test]
    fn default_axes_cover_gating_quantization_and_dcd_compression() {
        let sc = super::super::builtins::find("quantized-dense").unwrap();
        let axes = default_axes(&sc);
        let keys: Vec<&str> = axes.iter().map(|a| a.key.as_str()).collect();
        assert_eq!(
            keys,
            vec!["impairments.gating", "impairments.quant_step", "algorithm.m"]
        );
        // Every default axis parses back through the INI layer.
        for axis in &axes {
            Scenario::check_key(&axis.key).unwrap();
        }
    }

    #[test]
    fn tiny_frontier_prunes_dominated_points_deterministically() {
        let mut sc = super::super::builtins::find("paper-10-node").unwrap();
        sc.runs = 2;
        sc.iters = 300;
        sc.record_every = 1;
        let axes = [FrontierAxis {
            key: "impairments.gating".into(),
            values: vec!["always".into(), "prob:0.5".into()],
        }];
        let out = frontier_scenario(&sc, &axes, None, true).unwrap();
        assert_eq!(out.points.len(), 2);
        assert!(
            !out.pareto_points().is_empty(),
            "a non-empty grid always has a non-empty front"
        );
        // Gating halves the billed bits — the two points differ on the
        // bits axis, so at most one direction of domination is possible
        // and the cheaper point is always on the front.
        assert!(out.points[1].bits_per_run < out.points[0].bits_per_run);
        assert!(out.points[1].pareto);
        // Determinism: a second mapping reproduces the table bit-exactly.
        let again = frontier_scenario(&sc, &axes, None, true).unwrap();
        for (a, b) in out.points.iter().zip(again.points.iter()) {
            assert_eq!(a.steady_db.to_bits(), b.steady_db.to_bits());
            assert_eq!(a.bits_per_run.to_bits(), b.bits_per_run.to_bits());
            assert_eq!(a.pareto, b.pareto);
        }
    }
}
