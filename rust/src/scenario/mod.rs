//! Declarative scenario subsystem (DESIGN.md §4): compose a topology
//! generator × data model × algorithm × link impairments × schedule into
//! a named, reproducible experiment.
//!
//! The paper replays three fixed experiments; the ROADMAP's north star
//! asks for "as many scenarios as you can imagine". This module is the
//! workload generator that gets there:
//!
//! * [`Scenario`] — the declarative description, parsed from and
//!   serialized to the repo's INI config format (round-trip lossless),
//!   with a semantic validator (connected topology, knobs within the
//!   dimension, impairment ranges).
//! * [`builtins()`] — a registry of named presets: the paper's settings
//!   (`paper-10-node` reproduces the exp1 DCD trajectory bit-for-bit)
//!   plus impaired/asynchronous regimes from the follow-up literature
//!   (`lossy-geometric`, `event-triggered-ring`, `quantized-dense`, ...).
//! * [`run_scenario`] / [`sweep_scenario`] — execution on the parallel
//!   Monte-Carlo runner with the link-impairment layer
//!   ([`crate::coordinator::impairments`]) wrapped around every
//!   iteration; results land in `results/<name>.{csv,json}`. Scenarios
//!   inside the impaired-link analysis scope (DESIGN.md §7) also emit a
//!   closed-form theory column next to the Monte-Carlo curve, the way
//!   exp1 anchors the ideal setting.
//!
//! CLI face: `dcd-lms scenario list | run | sweep` (see the README's
//! scenario section for a tour); `dcd-lms exp4` sweeps the drop
//! probability of a theory-anchored scenario and plots predicted vs
//! simulated steady-state MSD; `dcd-lms frontier` maps the
//! comm-cost-vs-MSD Pareto frontier over a grid of policy axes
//! ([`frontier_scenario`], DESIGN.md §13).

mod builtins;
mod frontier;
mod run;
mod spec;

pub use builtins::{builtins, find};
pub use frontier::{
    default_axes, frontier_scenario, pareto_front, FrontierAxis, FrontierOutput, FrontierPoint,
};
pub use run::{
    mc_parts, run_scenario, run_scenario_with_progress, scheduler_options, sweep_scenario,
    theory_scope, wsn_block, wsn_sim, ScenarioOutput, SweepOutput, SweepPoint,
};
pub use spec::{AlgorithmSpec, DynamicsSpec, Scenario, ScheduleMode, TheoryColumn, TopologySpec};
