//! Directional, purpose-tagged message ledger (DESIGN.md §9).
//!
//! The paper's whole subject is the communication/performance trade-off,
//! so the communication accounting has to be exact. The ledger replaces
//! the original frame-level meter (which billed transmitters only) with
//! a model of every metered exchange as a directed, purpose-tagged
//! message:
//!
//! ```text
//!   (source, destination, purpose, payload scalars × payload width)
//!
//!   purpose ∈ { estimate-broadcast,   unsolicited push of (masked)
//!                                     estimate entries,
//!               gradient-reply,       reply to a soliciting estimate
//!                                     broadcast,
//!               dcd-residue }         compressive diffusion's one-scalar
//!                                     projection residue
//! ```
//!
//! Billing rules (the §9 message grammar):
//!
//! 1. A **gated (silent) transmitter** puts nothing on the air: none of
//!    its messages are billed (unchanged from the mute-mask meter).
//! 2. A **broadcast** (estimate or residue) from an on-air transmitter
//!    is always billed — the energy is spent whether or not a lossy
//!    link erases the frame in flight (receiver-side erasure,
//!    cf. arXiv:1408.5845).
//! 3. A **solicited reply** (gradient) is billed only when its request
//!    leg was actually delivered: a reply to a gated or erased estimate
//!    broadcast was never computed, never transmitted, never billed.
//!    The scalars rule 3 saves relative to the old transmitter-only
//!    meter are tracked in [`CommLedger::suppressed_scalars`], so
//!    `scalars + suppressed_scalars` reproduces the legacy bill.
//!
//! Payload width: a full-precision scalar is 64 bits on the wire; under
//! the quantization impairment a scalar is a fixed-point index into the
//! Δ grid of the `[-PAYLOAD_RANGE, PAYLOAD_RANGE]` dynamic range,
//! [`payload_bits`] wide. Billed bits are `scalars × width`.
//!
//! Determinism: the ledger draws no randomness and all counters are
//! integers, so billed scalars/bits are associative under merging —
//! bit-identical for any worker-thread or shard layout. On ideal links
//! no outcome table is installed and every send is billed, which is
//! exactly the legacy accounting (the bit-identity argument of §9).

/// What a metered message is *for* — the purpose axis of the ledger.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Purpose {
    /// Unsolicited (masked) estimate entries: DCD/CD `H_k ∘ w_k`
    /// broadcasts, partial-diffusion `H_k ∘ ψ_k`, RCD's polled ψ, and
    /// diffusion LMS's full-estimate exchanges.
    Estimate,
    /// A solicited gradient reply `Q_l ∘ ∇J_l` (DCD/CD/diffusion LMS):
    /// only transmitted when the soliciting estimate broadcast arrived.
    Gradient,
    /// Compressive diffusion's one-scalar projection residue.
    Residue,
}

/// Number of [`Purpose`] variants (sizes the per-purpose counters).
pub const N_PURPOSES: usize = 3;

impl Purpose {
    /// All purposes, in counter order.
    pub const ALL: [Purpose; N_PURPOSES] = [Purpose::Estimate, Purpose::Gradient, Purpose::Residue];

    /// Counter index of this purpose.
    #[inline]
    pub fn index(self) -> usize {
        match self {
            Purpose::Estimate => 0,
            Purpose::Gradient => 1,
            Purpose::Residue => 2,
        }
    }

    /// Stable label used in result columns and JSON manifests.
    pub fn label(self) -> &'static str {
        match self {
            Purpose::Estimate => "estimate-broadcast",
            Purpose::Gradient => "gradient-reply",
            Purpose::Residue => "dcd-residue",
        }
    }
}

/// Wire width of one full-precision scalar (bits).
pub const FULL_PRECISION_BITS: u32 = 64;

/// Half-width R of the fixed-point dynamic range `[-R, R]` quantized
/// payloads are billed over. The paper's data model draws each entry of
/// w° from a standard Gaussian, so ±8 covers every estimate a
/// converging network transmits to ≈8σ (per-entry excursion
/// probability ~1e-15); the simulated quantizer itself is unbounded —
/// this is a fixed-point wire format, not an entropy bound.
pub const PAYLOAD_RANGE: f64 = 8.0;

/// Wire width of one scalar under the quantization impairment: a
/// mid-tread quantizer of step Δ over the dynamic range
/// `[-PAYLOAD_RANGE, PAYLOAD_RANGE]` has `2R/Δ + 1` levels, so a grid
/// index costs `⌈log₂ levels⌉` bits (clamped to `[2, 64]`). `Δ <= 0`
/// means full precision (DESIGN.md §9).
pub fn payload_bits(quant_step: f64) -> u32 {
    if quant_step <= 0.0 || !quant_step.is_finite() {
        return FULL_PRECISION_BITS;
    }
    let levels = (2.0 * PAYLOAD_RANGE / quant_step + 1.0).max(2.0);
    (levels.log2().ceil() as u32).clamp(2, FULL_PRECISION_BITS)
}

/// The billed totals of one run (or the merged totals of many runs):
/// pure integer counters, so merging is associative and sharded /
/// threaded runs reproduce the serial bill bit for bit (DESIGN.md §9).
#[derive(Debug, Clone, PartialEq)]
pub struct CommLedger {
    /// Number of nodes (sizes the per-node / per-link tables).
    pub n_nodes: usize,
    /// Total billed scalars.
    pub scalars: u64,
    /// Total billed messages (one per directed metered send).
    pub messages: u64,
    /// Scalars the legacy transmitter-only meter would have billed on
    /// top of `scalars`: solicited replies whose request leg was gated
    /// or erased (billing rule 3).
    pub suppressed_scalars: u64,
    /// Billed scalars that were erased in flight (transmitter paid,
    /// receiver got nothing — the bus face's drop accounting).
    pub dropped_scalars: u64,
    /// Billed messages erased in flight.
    pub dropped_messages: u64,
    /// Wire width of one scalar (64 = full precision; see
    /// [`payload_bits`]).
    pub bits_per_scalar: u32,
    /// Billed scalars per transmitting node (length `n_nodes`).
    pub per_node: Vec<u64>,
    /// Billed scalars per purpose ([`Purpose::index`] order).
    pub per_purpose: [u64; N_PURPOSES],
    /// Billed scalars per directed link, dense `src * n_nodes + dst`.
    pub per_link: Vec<u64>,
}

impl CommLedger {
    /// An all-zero ledger for `n_nodes` nodes at full precision.
    pub fn empty(n_nodes: usize) -> Self {
        Self {
            n_nodes,
            scalars: 0,
            messages: 0,
            suppressed_scalars: 0,
            dropped_scalars: 0,
            dropped_messages: 0,
            bits_per_scalar: FULL_PRECISION_BITS,
            per_node: vec![0; n_nodes],
            per_purpose: [0; N_PURPOSES],
            per_link: vec![0; n_nodes * n_nodes],
        }
    }

    /// Total billed payload bits.
    pub fn bits(&self) -> u64 {
        self.scalars * self.bits_per_scalar as u64
    }

    /// Billed payload bits transmitted by node `k`.
    pub fn per_node_bits(&self, k: usize) -> u64 {
        self.per_node[k] * self.bits_per_scalar as u64
    }

    /// Billed scalars on the directed link `src → dst`.
    pub fn link_scalars(&self, src: usize, dst: usize) -> u64 {
        self.per_link[src * self.n_nodes + dst]
    }

    /// Billed scalars for one purpose.
    pub fn purpose_scalars(&self, p: Purpose) -> u64 {
        self.per_purpose[p.index()]
    }

    /// What the legacy transmitter-only meter would have billed: the
    /// exact bill plus the suppressed reply legs (billing rule 3).
    pub fn legacy_scalars(&self) -> u64 {
        self.scalars + self.suppressed_scalars
    }

    /// Accumulate another ledger (integer addition — order-independent,
    /// which is what keeps sharded totals bit-identical to serial).
    pub fn merge(&mut self, other: &CommLedger) {
        if self.n_nodes == 0 && self.scalars == 0 {
            *self = other.clone();
            return;
        }
        assert_eq!(self.n_nodes, other.n_nodes, "merging ledgers of different networks");
        if self.scalars == 0 {
            self.bits_per_scalar = other.bits_per_scalar;
        } else if other.scalars > 0 {
            debug_assert_eq!(
                self.bits_per_scalar, other.bits_per_scalar,
                "merging ledgers with different payload widths"
            );
        }
        self.scalars += other.scalars;
        self.messages += other.messages;
        self.suppressed_scalars += other.suppressed_scalars;
        self.dropped_scalars += other.dropped_scalars;
        self.dropped_messages += other.dropped_messages;
        for (a, b) in self.per_node.iter_mut().zip(other.per_node.iter()) {
            *a += b;
        }
        for (a, b) in self.per_purpose.iter_mut().zip(other.per_purpose.iter()) {
            *a += b;
        }
        for (a, b) in self.per_link.iter_mut().zip(other.per_link.iter()) {
            *a += b;
        }
    }
}

/// The live meter every [`Algorithm`](crate::algorithms::Algorithm)
/// step reports its traffic to: a [`CommLedger`] plus the current
/// iteration's link outcomes (who is gated, which request legs were
/// delivered), installed by the coordinator's impairment layer.
///
/// Scalars remain the paper's communication unit (compression ratios
/// are ratios of transmitted vector entries; index overhead is ignored
/// because selection patterns are reproducible from shared PRNG seeds);
/// billed bits add the payload-width axis on top.
#[derive(Debug, Clone)]
pub struct CommMeter {
    ledger: CommLedger,
    /// Per-node transmit gate (`true` = silent); empty = nobody gated.
    muted: Vec<bool>,
    /// Request-delivery table, dense `src * n + dst`: did `src`'s
    /// estimate broadcast reach `dst` this iteration? Empty = every
    /// request delivered (the ideal-links fast path).
    delivered: Vec<bool>,
}

impl CommMeter {
    /// A meter for `n_nodes` nodes with all counters at zero.
    pub fn new(n_nodes: usize) -> Self {
        Self {
            ledger: CommLedger::empty(n_nodes),
            muted: Vec::new(),
            delivered: Vec::new(),
        }
    }

    /// Number of nodes the meter was sized for.
    pub fn n_nodes(&self) -> usize {
        self.ledger.n_nodes
    }

    /// Total billed scalars.
    pub fn scalars(&self) -> u64 {
        self.ledger.scalars
    }

    /// Total billed messages.
    pub fn messages(&self) -> u64 {
        self.ledger.messages
    }

    /// Total billed payload bits.
    pub fn bits(&self) -> u64 {
        self.ledger.bits()
    }

    /// The full directional ledger.
    pub fn ledger(&self) -> &CommLedger {
        &self.ledger
    }

    /// Consume the meter, keeping only its ledger (what a finished run
    /// hands back to the scheduler).
    pub fn into_ledger(self) -> CommLedger {
        self.ledger
    }

    /// Install the payload width implied by the quantizer step Δ
    /// (0 = full precision); see [`payload_bits`].
    pub fn set_quant_step(&mut self, quant_step: f64) {
        self.ledger.bits_per_scalar = payload_bits(quant_step);
    }

    /// Install this iteration's link outcomes: the transmit-gate mask
    /// (`true` = silent) and, optionally, the dense request-delivery
    /// table (`delivered[src * n + dst]` = src's broadcast reached
    /// dst). The coordinator's impairment layer calls this before every
    /// impaired iteration; without it every send is billed (ideal
    /// links).
    pub fn set_outcomes(&mut self, muted: &[bool], delivered: Option<&[bool]>) {
        self.muted.clear();
        self.muted.extend_from_slice(muted);
        self.delivered.clear();
        if let Some(d) = delivered {
            debug_assert_eq!(d.len(), self.ledger.n_nodes * self.ledger.n_nodes);
            self.delivered.extend_from_slice(d);
        }
    }

    /// Remove the outcome tables (every send billed again).
    pub fn clear_outcomes(&mut self) {
        self.muted.clear();
        self.delivered.clear();
    }

    /// Record one directed message of `count` scalars from `src` to
    /// `dst` for `purpose`, applying the §9 billing rules against the
    /// installed outcome tables.
    #[inline]
    pub fn send(&mut self, src: usize, dst: usize, purpose: Purpose, count: usize) {
        if !self.muted.is_empty() && self.muted[src] {
            // Rule 1: a gated transmitter is off the air.
            return;
        }
        if purpose == Purpose::Gradient
            && !self.delivered.is_empty()
            && !self.delivered[dst * self.ledger.n_nodes + src]
        {
            // Rule 3: the soliciting broadcast dst → src never arrived,
            // so this reply was never computed or transmitted. The old
            // transmitter-only meter billed it anyway — track the gap.
            self.ledger.suppressed_scalars += count as u64;
            return;
        }
        self.bill(src, dst, purpose, count);
    }

    /// [`CommMeter::send`] for callers that already know whether the
    /// soliciting request leg was delivered (the WSN event scheduler,
    /// which draws link outcomes activation by activation instead of
    /// installing per-iteration tables).
    #[inline]
    pub fn send_solicited(
        &mut self,
        src: usize,
        dst: usize,
        purpose: Purpose,
        count: usize,
        request_delivered: bool,
    ) {
        if !self.muted.is_empty() && self.muted[src] {
            return;
        }
        if !request_delivered {
            self.ledger.suppressed_scalars += count as u64;
            return;
        }
        self.bill(src, dst, purpose, count);
    }

    /// Record a billed transmission that was erased in flight
    /// (transmitter pays, receiver gets nothing) — the bus face's lossy
    /// send. Returns whether the message was billed (i.e. actually
    /// transmitted).
    pub fn send_lossy(
        &mut self,
        src: usize,
        dst: usize,
        purpose: Purpose,
        count: usize,
        delivered: bool,
    ) -> bool {
        if !self.muted.is_empty() && self.muted[src] {
            return false;
        }
        self.bill(src, dst, purpose, count);
        if !delivered {
            self.ledger.dropped_scalars += count as u64;
            self.ledger.dropped_messages += 1;
        }
        true
    }

    #[inline]
    fn bill(&mut self, src: usize, dst: usize, purpose: Purpose, count: usize) {
        let count = count as u64;
        self.ledger.scalars += count;
        self.ledger.messages += 1;
        self.ledger.per_node[src] += count;
        self.ledger.per_purpose[purpose.index()] += count;
        self.ledger.per_link[src * self.ledger.n_nodes + dst] += count;
    }

    /// Zero all counters and outcome tables (the payload width is kept:
    /// it is schedule-level configuration, not per-run state).
    pub fn reset(&mut self) {
        let width = self.ledger.bits_per_scalar;
        self.ledger = CommLedger::empty(self.ledger.n_nodes);
        self.ledger.bits_per_scalar = width;
        self.muted.clear();
        self.delivered.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meter_accumulates_directionally() {
        let mut m = CommMeter::new(3);
        m.send(0, 1, Purpose::Estimate, 5);
        m.send(2, 0, Purpose::Gradient, 2);
        m.send(0, 2, Purpose::Estimate, 1);
        assert_eq!(m.scalars(), 8);
        assert_eq!(m.messages(), 3);
        assert_eq!(m.ledger().per_node, vec![6, 0, 2]);
        assert_eq!(m.ledger().link_scalars(0, 1), 5);
        assert_eq!(m.ledger().link_scalars(2, 0), 2);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Estimate), 6);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Gradient), 2);
        assert_eq!(m.bits(), 8 * 64);
        m.reset();
        assert_eq!(m.scalars(), 0);
        assert_eq!(m.ledger().per_link.iter().sum::<u64>(), 0);
    }

    #[test]
    fn muted_transmitters_are_not_billed() {
        let mut m = CommMeter::new(3);
        m.set_outcomes(&[false, true, false], None);
        m.send(0, 1, Purpose::Estimate, 4);
        m.send(1, 0, Purpose::Estimate, 4); // suppressed: gated
        m.send(2, 1, Purpose::Estimate, 4);
        assert_eq!(m.scalars(), 8);
        assert_eq!(m.messages(), 2);
        assert_eq!(m.ledger().per_node, vec![4, 0, 4]);
        // A gated node's non-transmission is not legacy over-billing:
        // the old meter's mute mask suppressed it too.
        assert_eq!(m.ledger().suppressed_scalars, 0);
        m.clear_outcomes();
        m.send(1, 0, Purpose::Estimate, 4);
        assert_eq!(m.scalars(), 12);
    }

    #[test]
    fn replies_to_dead_requests_are_suppressed_and_tracked() {
        let n = 3;
        let mut m = CommMeter::new(n);
        // Request table: node 0's broadcasts never arrive anywhere.
        let mut delivered = vec![true; n * n];
        delivered[1] = false; // 0 -> 1
        delivered[2] = false; // 0 -> 2
        m.set_outcomes(&[false; 3], Some(&delivered));
        // 0's own broadcast: billed (transmitter pays, rule 2).
        m.send(0, 1, Purpose::Estimate, 3);
        // 1's reply to 0's broadcast: the request 0 -> 1 died, so the
        // reply was never transmitted (rule 3).
        m.send(1, 0, Purpose::Gradient, 2);
        // 1's reply to 2's broadcast: request 2 -> 1 arrived.
        m.send(1, 2, Purpose::Gradient, 2);
        assert_eq!(m.scalars(), 5);
        assert_eq!(m.ledger().suppressed_scalars, 2);
        assert_eq!(m.ledger().legacy_scalars(), 7);
        assert_eq!(m.ledger().purpose_scalars(Purpose::Gradient), 2);
    }

    #[test]
    fn quantized_payload_width() {
        assert_eq!(payload_bits(0.0), 64);
        assert_eq!(payload_bits(-1.0), 64);
        assert_eq!(payload_bits(f64::NAN), 64);
        assert_eq!(payload_bits(1e-3), 14); // 16001 levels over [-8, 8]
        assert_eq!(payload_bits(0.5), 6); // 33 levels
        assert_eq!(payload_bits(1e-30), 64); // clamped
        let mut m = CommMeter::new(2);
        m.set_quant_step(1e-3);
        m.send(0, 1, Purpose::Estimate, 10);
        assert_eq!(m.bits(), 10 * 14);
        m.reset();
        // Width survives a reset (schedule-level configuration).
        m.send(0, 1, Purpose::Estimate, 1);
        assert_eq!(m.bits(), 14);
    }

    #[test]
    fn lossy_sends_bill_the_transmitter_and_track_drops() {
        let mut m = CommMeter::new(2);
        assert!(m.send_lossy(0, 1, Purpose::Estimate, 3, true));
        assert!(m.send_lossy(0, 1, Purpose::Estimate, 3, false));
        assert_eq!(m.scalars(), 6);
        assert_eq!(m.ledger().dropped_scalars, 3);
        assert_eq!(m.ledger().dropped_messages, 1);
        m.set_outcomes(&[true, false], None);
        assert!(!m.send_lossy(0, 1, Purpose::Estimate, 3, true));
        assert_eq!(m.scalars(), 6);
    }

    #[test]
    fn solicited_face_matches_table_face() {
        let mut a = CommMeter::new(2);
        let mut delivered = vec![true; 4];
        delivered[2] = false; // src 1 * n 2 + dst 0: request 1 -> 0 died
        a.set_outcomes(&[false, false], Some(&delivered));
        a.send(0, 1, Purpose::Gradient, 4);
        let mut b = CommMeter::new(2);
        b.send_solicited(0, 1, Purpose::Gradient, 4, false);
        assert_eq!(a.ledger(), b.ledger());
        assert_eq!(a.ledger().suppressed_scalars, 4);
    }

    #[test]
    fn ledgers_merge_associatively() {
        let mut a = CommMeter::new(2);
        a.send(0, 1, Purpose::Estimate, 3);
        let mut b = CommMeter::new(2);
        b.send(1, 0, Purpose::Gradient, 2);
        b.send_solicited(1, 0, Purpose::Gradient, 5, false);
        let mut left = CommLedger::empty(0);
        left.merge(a.ledger());
        left.merge(b.ledger());
        let mut right = CommLedger::empty(0);
        right.merge(b.ledger());
        right.merge(a.ledger());
        assert_eq!(left.scalars, right.scalars);
        assert_eq!(left.per_link, right.per_link);
        assert_eq!(left.suppressed_scalars, 5);
        assert_eq!(left.scalars, 5);
        assert_eq!(left.messages, 2);
    }
}
